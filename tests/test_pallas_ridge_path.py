"""Integration: the Pallas-kernel ridge path equals the pure-XLA path."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ridge
from repro.core.ridge import RidgeCVConfig


def test_ridge_cv_pallas_path_matches_xla():
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    X = jax.random.normal(k1, (200, 32), jnp.float32)
    W = jax.random.normal(k2, (32, 24), jnp.float32)
    Y = X @ W + 0.05 * jax.random.normal(jax.random.PRNGKey(2), (200, 24))
    base = ridge.ridge_cv(X, Y, RidgeCVConfig(n_folds=3))
    pall = ridge.ridge_cv(X, Y, RidgeCVConfig(n_folds=3, use_pallas=True))
    assert float(base.best_lambda) == float(pall.best_lambda)
    np.testing.assert_allclose(np.asarray(pall.weights),
                               np.asarray(base.weights), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(pall.cv_scores),
                               np.asarray(base.cv_scores), rtol=1e-3,
                               atol=1e-3)
