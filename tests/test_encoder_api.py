"""Unified BrainEncoder API: dispatch rules + single-device solver parity.

Multi-device parity (auto → B-MOR / dual B-MOR on a sharded mesh) lives in
``tests/helpers/encoder_checks.py``, run by ``test_encoder_distributed.py``.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import banded, mor, ridge
from repro.encoding import BrainEncoder, EncoderConfig, pipeline, resolve


def _make_problem(key, n=160, p=24, t=12, noise=0.05):
    k1, k2, k3 = jax.random.split(key, 3)
    X = jax.random.normal(k1, (n, p), jnp.float32)
    W = jax.random.normal(k2, (p, t), jnp.float32) / np.sqrt(p)
    Y = X @ W + noise * jax.random.normal(k3, (n, t), jnp.float32)
    return X, Y


# ---------------------------------------------------------------------------
# dispatch.resolve — pure unit tests (device_count passed explicitly)
# ---------------------------------------------------------------------------

def test_dispatch_single_device_picks_ridge():
    d = resolve(EncoderConfig(), n=1000, p=100, t=500, device_count=1)
    assert d.solver == "ridge" and d.method == "eigh"
    assert (d.data_shards, d.target_shards) == (1, 1)


def test_dispatch_dual_for_n_lt_p():
    d = resolve(EncoderConfig(), n=50, p=200, t=500, device_count=1)
    assert d.solver == "ridge" and d.method == "dual"
    d = resolve(EncoderConfig(), n=50, p=200, t=500, device_count=8)
    assert d.solver == "bmor_dual" and d.method == "dual"
    assert d.target_shards == 8 and d.data_shards == 1


def test_dispatch_bmor_when_devices_gt_1():
    d = resolve(EncoderConfig(), n=1000, p=100, t=500, device_count=8)
    assert d.solver == "bmor"
    assert d.data_shards * d.target_shards == 8
    # layout minimises T_W/c_t + T_M/c_d ⇔ t/c_t + p/c_d (common p·n·r)
    costs = {(cd, 8 // cd): 500 / (8 // cd) + 100 / cd
             for cd in (1, 2, 4, 8)}
    assert costs[(d.data_shards, d.target_shards)] == min(costs.values())


def test_dispatch_layout_follows_shape():
    # Many targets, few features → shard targets; the reverse → shard rows.
    d_t = resolve(EncoderConfig(), n=10_000, p=16, t=100_000, device_count=8)
    assert d_t.target_shards == 8
    d_d = resolve(EncoderConfig(), n=100_000, p=8_192, t=16, device_count=8)
    assert d_d.data_shards == 8


def test_dispatch_respects_explicit_overrides():
    d = resolve(EncoderConfig(solver="ridge"), n=1000, p=10, t=100,
                device_count=8)
    assert d.solver == "ridge"
    d = resolve(EncoderConfig(solver="bmor", data_shards=4, target_shards=2),
                n=1000, p=10, t=100, device_count=8)
    assert (d.data_shards, d.target_shards) == (4, 2)
    d = resolve(EncoderConfig(solver="mor", target_shards=4), n=100, p=10,
                t=20, device_count=8)
    assert d.solver == "mor" and d.target_shards == 4
    # Pinned layouts may occupy a device subset (benchmark sweeps do this).
    d = resolve(EncoderConfig(solver="bmor", data_shards=1, target_shards=1),
                n=100, p=10, t=20, device_count=8)
    assert (d.data_shards, d.target_shards) == (1, 1)
    with pytest.raises(ValueError):
        resolve(EncoderConfig(solver="bmor", data_shards=16), n=100, p=10,
                t=20, device_count=8)  # more shards than devices


def test_dispatch_never_auto_selects_mor():
    for shape in [(100, 10, 1000), (10_000, 100, 10), (50, 500, 100)]:
        for c in (1, 2, 8):
            d = resolve(EncoderConfig(), *shape, device_count=c)
            assert d.solver != "mor", (shape, c)


def test_dispatch_banded_from_bands():
    d = resolve(EncoderConfig(bands=(8, 8)), n=100, p=16, t=32,
                device_count=8)
    assert d.solver == "banded"
    with pytest.raises(ValueError):
        resolve(EncoderConfig(solver="banded"), n=100, p=16, t=32,
                device_count=1)  # bands not set


def test_dispatch_predicted_cost_ordering():
    """B-MOR's modelled critical path beats MOR's at equal parallelism."""
    cfg_bmor = EncoderConfig(solver="bmor", target_shards=8)
    cfg_mor = EncoderConfig(solver="mor", target_shards=8)
    n, p, t = 10_000, 512, 50_000
    d_bmor = resolve(cfg_bmor, n, p, t, device_count=8)
    d_mor = resolve(cfg_mor, n, p, t, device_count=8)
    assert d_bmor.predicted_cost < d_mor.predicted_cost


# ---------------------------------------------------------------------------
# BrainEncoder parity vs direct solver calls (single device)
# ---------------------------------------------------------------------------

def test_encoder_ridge_parity():
    X, Y = _make_problem(jax.random.PRNGKey(0))
    enc = BrainEncoder(n_folds=3).fit(X, Y)
    assert enc.report_.decision.solver == "ridge"
    ref = ridge.ridge_cv(X, Y, enc.config.ridge_cv_config("eigh"))
    np.testing.assert_allclose(np.asarray(enc.weights_),
                               np.asarray(ref.weights), rtol=1e-6, atol=1e-6)
    assert enc.report_.best_lambda[0] == float(ref.best_lambda)
    np.testing.assert_allclose(enc.report_.cv_scores[0],
                               np.asarray(ref.cv_scores), rtol=1e-6)


def test_encoder_mor_parity():
    X, Y = _make_problem(jax.random.PRNGKey(1), n=60, p=8, t=6)
    cfg = EncoderConfig(solver="mor", n_folds=3, lambdas=(0.1, 1.0, 100.0))
    enc = BrainEncoder(cfg).fit(X, Y)
    W_ref = mor.mor_fit(X, Y, cfg.ridge_cv_config("eigh"))
    np.testing.assert_allclose(np.asarray(enc.weights_), np.asarray(W_ref),
                               rtol=1e-6, atol=1e-6)


def test_encoder_banded_parity():
    X, Y = _make_problem(jax.random.PRNGKey(2), n=90, p=24, t=6)
    enc = BrainEncoder(bands=(12, 12), n_band_candidates=8, n_folds=3,
                       seed=3).fit(X, Y)
    ref = banded.banded_ridge_cv(jax.random.PRNGKey(3), X, Y,
                                 enc.config.banded_config())
    np.testing.assert_allclose(np.asarray(enc.weights_),
                               np.asarray(ref.weights), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(enc.report_.band_lambdas,
                               np.asarray(ref.band_lambdas), rtol=1e-6)


def test_encoder_dual_method_parity():
    X, Y = _make_problem(jax.random.PRNGKey(4), n=30, p=64, t=6)
    enc = BrainEncoder(n_folds=3).fit(X, Y)
    assert enc.report_.decision.method == "dual"
    ref = ridge.ridge_cv(X, Y, enc.config.ridge_cv_config("dual"))
    np.testing.assert_allclose(np.asarray(enc.weights_),
                               np.asarray(ref.weights), rtol=1e-6, atol=1e-6)


def test_encoder_predict_score_evaluate():
    X, Y = _make_problem(jax.random.PRNGKey(5), n=200, p=16, t=8, noise=0.01)
    enc = BrainEncoder(n_folds=3).fit(X[:160], Y[:160])
    preds = enc.predict(X[160:])
    assert preds.shape == (40, 8)
    r = enc.score(X[160:], Y[160:])
    assert r.shape == (8,) and r.mean() > 0.9
    ev = enc.evaluate(X[160:], Y[160:], n_perms=4)
    assert ev.null_r.shape == (4, 8)
    assert ev.significant  # low-noise planted model clears the null floor


def test_unfit_encoder_raises():
    with pytest.raises(AssertionError):
        BrainEncoder().predict(jnp.zeros((4, 4)))


# ---------------------------------------------------------------------------
# pipeline stages
# ---------------------------------------------------------------------------

def test_pipeline_stages_compose():
    X, Y = _make_problem(jax.random.PRNGKey(6), n=220, p=16, t=8, noise=0.05)
    state = pipeline.run_stages(X, Y, [
        pipeline.standardize(),
        pipeline.split(test_frac=0.2, seed=0),
        pipeline.fit(EncoderConfig(n_folds=3)),
        pipeline.evaluate(n_perms=3),
    ])
    assert state.X.shape[0] == 176 and state.X_test.shape[0] == 44
    assert state.report is not None and state.evaluation is not None
    assert state.evaluation.pearson_r.shape == (8,)


def test_pipeline_evaluate_without_split_refuses_silent_in_sample():
    X, Y = _make_problem(jax.random.PRNGKey(9), n=80, p=8, t=4)
    with pytest.raises(ValueError, match="no split stage"):
        pipeline.run_stages(X, Y, [
            pipeline.fit(EncoderConfig(n_folds=3)),
            pipeline.evaluate(n_perms=2),
        ])
    state = pipeline.run_stages(X, Y, [
        pipeline.fit(EncoderConfig(n_folds=3)),
        pipeline.evaluate(n_perms=2, on_train=True),   # explicit opt-in
    ])
    assert state.evaluation is not None


def test_pipeline_standardize_uses_train_stats_only():
    X, Y = _make_problem(jax.random.PRNGKey(10), n=100, p=6, t=3)
    state = pipeline.run_stages(X, Y, [
        pipeline.split(test_frac=0.2, seed=0),
        pipeline.standardize(),
    ])
    # Training rows are exactly standardized; held-out rows only approximately
    # (they were transformed with the TRAIN μ/σ, not their own).
    np.testing.assert_allclose(np.asarray(state.X.mean(0)), 0.0, atol=1e-5)
    assert float(jnp.abs(state.X_test.mean(0)).max()) > 1e-4


def test_pipeline_run_defaults():
    X, Y = _make_problem(jax.random.PRNGKey(7), n=200, p=12, t=6, noise=0.02)
    state = pipeline.run(X, Y, n_perms=2)
    assert state.evaluation.mean_r > 0.8
    assert state.report.decision.solver == "ridge"  # single device here


# ---------------------------------------------------------------------------
# dtype: f32 accumulation means bf16 inputs select the same λ (satellite)
# ---------------------------------------------------------------------------

def test_bf16_input_selects_same_lambda():
    X, Y = _make_problem(jax.random.PRNGKey(8), n=150, p=16, t=8, noise=0.5)
    cfg = ridge.RidgeCVConfig(n_folds=3)
    res32 = ridge.ridge_cv(X, Y, cfg)
    res16 = ridge.ridge_cv(X.astype(jnp.bfloat16), Y.astype(jnp.bfloat16),
                           cfg)
    assert res16.best_lambda.dtype == jnp.float32
    assert float(res16.best_lambda) == float(res32.best_lambda)
    np.testing.assert_allclose(np.asarray(res16.weights),
                               np.asarray(res32.weights), rtol=0.1, atol=0.05)
