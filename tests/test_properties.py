"""Property-based tests (hypothesis) on system invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import complexity, ridge, scoring
from repro.core.complexity import RidgeWorkload
from repro.models import layers

SETTINGS = dict(max_examples=15, deadline=None)


# ---------------------------------------------------------------------------
# Ridge algebra
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(seed=st.integers(0, 2**16), n=st.integers(20, 60),
       p=st.integers(4, 24), lam_pair=st.tuples(st.floats(0.01, 10.0),
                                                st.floats(10.1, 1e4)))
def test_ridge_shrinkage_monotone(seed, n, p, lam_pair):
    """Larger λ ⇒ smaller coefficient norm (shrinkage)."""
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(n, p)), jnp.float32)
    Y = jnp.asarray(rng.normal(size=(n, 3)), jnp.float32)
    cfg = ridge.RidgeCVConfig(method="eigh", jitter=0.0)
    f = ridge.factorize(X, cfg)
    rhs = ridge.gram_xty(X, Y)
    lam1, lam2 = lam_pair
    w1 = ridge.solve(f, rhs, jnp.float32(lam1))
    w2 = ridge.solve(f, rhs, jnp.float32(lam2))
    assert float(jnp.linalg.norm(w2)) <= float(jnp.linalg.norm(w1)) + 1e-5


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**16), n=st.integers(16, 48),
       p=st.integers(4, 16))
def test_ridge_interpolates_ols_at_zero(seed, n, p):
    """λ→0 recovers least squares (well-conditioned X)."""
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(n, p)) + np.eye(n, p) * 3, jnp.float32)
    Y = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
    cfg = ridge.RidgeCVConfig(method="eigh", jitter=0.0)
    f = ridge.factorize(X, cfg)
    W = ridge.solve(f, ridge.gram_xty(X, Y), jnp.float32(1e-6))
    W_ols, *_ = np.linalg.lstsq(np.asarray(X, np.float64),
                                np.asarray(Y, np.float64), rcond=None)
    np.testing.assert_allclose(np.asarray(W), W_ols, rtol=2e-2, atol=2e-2)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**16))
def test_ridge_target_permutation_equivariance(seed):
    """Permuting target columns permutes the weight columns (multi-target
    mutualisation never mixes targets)."""
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(40, 8)), jnp.float32)
    Y = jnp.asarray(rng.normal(size=(40, 6)), jnp.float32)
    perm = rng.permutation(6)
    cfg = ridge.RidgeCVConfig(method="eigh", jitter=0.0)
    f = ridge.factorize(X, cfg)
    W = ridge.solve(f, ridge.gram_xty(X, Y), jnp.float32(3.0))
    Wp = ridge.solve(f, ridge.gram_xty(X, Y[:, perm]), jnp.float32(3.0))
    np.testing.assert_allclose(np.asarray(Wp), np.asarray(W)[:, perm],
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Scoring
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(seed=st.integers(0, 2**16), a=st.floats(0.1, 10.0),
       b=st.floats(-5.0, 5.0))
def test_pearson_affine_invariance(seed, a, b):
    rng = np.random.default_rng(seed)
    yt = jnp.asarray(rng.normal(size=(50, 4)), jnp.float32)
    yp = jnp.asarray(rng.normal(size=(50, 4)), jnp.float32)
    r0 = scoring.pearson_r(yt, yp)
    r1 = scoring.pearson_r(yt, a * yp + b)
    np.testing.assert_allclose(np.asarray(r0), np.asarray(r1), atol=1e-3)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**16))
def test_pearson_bounded(seed):
    rng = np.random.default_rng(seed)
    yt = jnp.asarray(rng.normal(size=(30, 5)), jnp.float32)
    yp = jnp.asarray(rng.normal(size=(30, 5)), jnp.float32)
    r = np.asarray(scoring.pearson_r(yt, yp))
    assert np.all(np.abs(r) <= 1.0 + 1e-5)


# ---------------------------------------------------------------------------
# Complexity model (paper §3) — order relations hold for ALL valid workloads
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(n=st.integers(64, 10_000), p=st.integers(8, 512),
       t=st.integers(16, 100_000), c=st.integers(2, 64))
def test_complexity_order_relations(n, p, t, c):
    w = RidgeWorkload(n=n, p=p, t=t)
    if c <= t:
        assert complexity.t_bmor(w, c) <= complexity.t_mor(w, c) + 1e-6
    assert complexity.t_bmor(w, c) < complexity.t_ridge_single(w) + \
        complexity.t_m(w)  # B-MOR never worse than single + one refactor
    # Eq. check: T_MOR − T_B-MOR == (t/c − 1)·T_M
    gap = complexity.t_mor(w, c) - complexity.t_bmor(w, c)
    np.testing.assert_allclose(gap, (t / c - 1) * complexity.t_m(w),
                               rtol=1e-9)


# ---------------------------------------------------------------------------
# Model layers
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(seed=st.integers(0, 2**16), pos=st.integers(0, 10_000))
def test_rope_preserves_norm(seed, pos):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(1, 3, 2, 16)), jnp.float32)
    positions = jnp.full((1, 3), pos, jnp.int32)
    y = layers.rope(x, positions, 10_000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y)),
                               np.linalg.norm(np.asarray(x)), rtol=1e-4)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**16), cap=st.floats(1.0, 100.0))
def test_softcap_bounded_and_monotone(seed, cap):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(np.sort(rng.normal(size=(64,)) * 100), jnp.float32)
    y = np.asarray(layers._softcap(x, cap))
    assert np.all(np.abs(y) <= cap + 1e-4)
    assert np.all(np.diff(y) >= -1e-5)


def test_attention_causality():
    """Future-token perturbations must not change past outputs."""
    from repro import configs
    from repro.models import build_model
    cfg = configs.smoke(configs.get_config("qwen3-1.7b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab,
                             dtype=jnp.int32)
    logits0, _ = model.forward(params, {"tokens": tok})
    tok2 = tok.at[:, 8:].set((tok[:, 8:] + 7) % cfg.vocab)
    logits1, _ = model.forward(params, {"tokens": tok2})
    np.testing.assert_allclose(np.asarray(logits0[:, :8], np.float32),
                               np.asarray(logits1[:, :8], np.float32),
                               atol=1e-3)


def test_ssm_causality():
    from repro import configs
    from repro.models import build_model
    cfg = configs.smoke(configs.get_config("mamba2-130m"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0, cfg.vocab,
                             dtype=jnp.int32)
    logits0, _ = model.forward(params, {"tokens": tok})
    tok2 = tok.at[:, 10:].set((tok[:, 10:] + 3) % cfg.vocab)
    logits1, _ = model.forward(params, {"tokens": tok2})
    np.testing.assert_allclose(np.asarray(logits0[:, :10], np.float32),
                               np.asarray(logits1[:, :10], np.float32),
                               atol=1e-3)
