"""Direct coverage for ``checkpoint.io``: dtype round-trips, the flat
``load`` path, and typed errors on every corruption mode (the module had
zero direct tests before the serving subsystem started building on it)."""
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import io


def _tree(dtype=jnp.float32):
    k = jax.random.PRNGKey(0)
    return {
        "W": {"000": jax.random.normal(k, (8, 4), dtype),
              "001": jax.random.normal(jax.random.fold_in(k, 1), (8, 4),
                                       dtype)},
        "mu": jnp.arange(8, dtype=jnp.float32),
        "step": jnp.int32(7),
    }


def test_f32_round_trip_bit_identical(tmp_path):
    tree = _tree()
    io.save(str(tmp_path), 3, tree)
    back = io.restore(str(tmp_path), 3, jax.tree_util.tree_map(
        lambda a: jnp.zeros_like(a), tree))
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_bf16_round_trip_bit_identical(tmp_path):
    tree = _tree(jnp.bfloat16)
    io.save(str(tmp_path), 0, tree)
    # bf16 leaves are stored as uint16 bit patterns (npy has no bf16)...
    with open(tmp_path / "step_0" / "manifest.json") as f:
        manifest = json.load(f)
    assert manifest["leaves"]["W/000"]["dtype"] == "bfloat16"
    raw = np.load(tmp_path / "step_0" / "W__000.npy")
    assert raw.dtype == np.uint16
    # ...and come back viewed as bf16, bit-identical.
    back = io.restore(str(tmp_path), 0, tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        assert b.dtype == a.dtype
        a, b = np.asarray(a), np.asarray(b)
        if a.dtype == jnp.bfloat16:
            a, b = a.view(np.uint16), b.view(np.uint16)
        assert np.array_equal(a, b)


def test_flat_load_needs_no_template(tmp_path):
    tree = _tree()
    io.save(str(tmp_path), 1, tree)
    flat = io.load(str(tmp_path), 1)
    assert set(flat) == {"W/000", "W/001", "mu", "step"}
    assert np.array_equal(flat["W/000"], np.asarray(tree["W"]["000"]))
    assert flat["step"] == 7


def test_missing_leaf_file_raises_typed(tmp_path):
    io.save(str(tmp_path), 0, _tree())
    os.remove(tmp_path / "step_0" / "W__001.npy")
    with pytest.raises(io.CheckpointError, match="W/001"):
        io.load(str(tmp_path), 0)
    with pytest.raises(io.CheckpointError, match="W/001"):
        io.restore(str(tmp_path), 0, _tree())


def test_leaf_absent_from_manifest_raises_typed(tmp_path):
    """A restore template wanting leaves the manifest never recorded must
    raise CheckpointError, not KeyError."""
    tree = _tree()
    io.save(str(tmp_path), 0, tree)
    bigger = dict(tree, extra=jnp.zeros((2,)))
    with pytest.raises(io.CheckpointError, match="extra"):
        io.restore(str(tmp_path), 0, bigger)


def test_corrupt_manifest_raises_typed(tmp_path):
    io.save(str(tmp_path), 0, _tree())
    path = tmp_path / "step_0" / "manifest.json"
    path.write_text("{not json")
    with pytest.raises(io.CheckpointError, match="corrupt"):
        io.load(str(tmp_path), 0)


def test_missing_manifest_raises_typed(tmp_path):
    io.save(str(tmp_path), 0, _tree())
    os.remove(tmp_path / "step_0" / "manifest.json")
    with pytest.raises(io.CheckpointError, match="manifest"):
        io.restore(str(tmp_path), 0, _tree())


def test_shape_mismatch_raises_typed(tmp_path):
    io.save(str(tmp_path), 0, _tree())
    wrong = _tree()
    wrong["mu"] = jnp.zeros((3,))
    with pytest.raises(io.CheckpointError, match="shape"):
        io.restore(str(tmp_path), 0, wrong)


def test_save_is_atomic_no_tmp_left(tmp_path):
    io.save(str(tmp_path), 0, _tree())
    io.save(str(tmp_path), 0, _tree())          # overwrite in place
    leftovers = [d for d in os.listdir(tmp_path) if d.startswith(".tmp")]
    assert leftovers == []
    assert io.latest_step(str(tmp_path)) == 0
