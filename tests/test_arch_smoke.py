"""Per-architecture smoke tests (assignment requirement): a REDUCED variant
of each family runs one forward/train step on CPU with correct output shapes
and no NaNs; decode families also run prefill + a decode step."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import configs
from repro.configs import smoke
from repro.data import synthetic
from repro.models import build_model

BATCH, SEQ = 2, 16


def _setup(arch):
    cfg = smoke(configs.get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = synthetic.make_batch(jax.random.PRNGKey(1), cfg, BATCH, SEQ)
    return cfg, model, params, batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg, model, params, batch = _setup(arch)
    logits, aux = model.forward(params, batch)
    assert logits.shape[0] == BATCH and logits.shape[-1] == cfg.vocab
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_train_step_reduces_loss(arch):
    """One SGD step on a fixed batch must reduce its loss (end-to-end grad
    flow through every block type, incl. MoE router and SSD scan)."""
    cfg, model, params, batch = _setup(arch)
    loss_fn = lambda p: model.loss(p, batch)  # noqa: E731
    l0, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(l0)), l0
    lr = 0.1
    params2 = jax.tree_util.tree_map(
        lambda p, g: p - lr * g.astype(p.dtype), params, grads)
    l1 = loss_fn(params2)
    assert bool(jnp.isfinite(l1))
    assert float(l1) < float(l0), (arch, float(l0), float(l1))


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_hidden_state_features(arch):
    """The brain-encoding feature hook yields (B, S*, d_model) states."""
    cfg, model, params, batch = _setup(arch)
    h = model.hidden_states(params, batch)
    assert h.shape[0] == BATCH and h.shape[-1] == cfg.d_model
    assert bool(jnp.all(jnp.isfinite(h.astype(jnp.float32))))


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_prefill_and_decode_step(arch):
    cfg, model, params, _ = _setup(arch)
    batch = synthetic.make_batch(jax.random.PRNGKey(2), cfg, BATCH, SEQ,
                                 kind="prefill")
    logits, cache = model.prefill(params, batch)
    assert logits.shape == (BATCH, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    pos = jnp.int32(SEQ if cfg.family != "audio" else 1)
    logits2, cache2 = model.decode_step(params, cache, tok, pos)
    assert logits2.shape == (BATCH, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits2.astype(jnp.float32))))
    # cache must be structurally stable across steps (scan/jit friendly)
    jax.tree_util.tree_map(lambda a, b: None, cache, cache2)


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "gemma2-2b", "mamba2-130m",
                                  "zamba2-2.7b"])
def test_decode_matches_teacher_forcing(arch):
    """Greedy decode logits must match full-sequence forward logits at the
    same positions (cache correctness, incl. ring/window caches and SSM
    state recurrence vs chunked SSD)."""
    cfg = smoke(configs.get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(3), (1, SEQ), 0, cfg.vocab,
                                dtype=jnp.int32)
    full_logits, _ = model.forward(params, {"tokens": tokens})

    # Drive the cache token by token and compare logits at each position.
    cache = model.init_cache(1, SEQ)
    errs = []
    for i in range(SEQ - 1):
        step_logits, cache = model.decode_step(
            params, cache, tokens[:, i][:, None], jnp.int32(i))
        errs.append(np.max(np.abs(
            np.asarray(step_logits[:, 0], np.float32) -
            np.asarray(full_logits[:, i], np.float32))))
    assert max(errs) < 0.15, (arch, errs)  # bf16 params → loose but real
