"""Unified observability layer: spans, metrics, recompile sentinels.

Lockdown contracts of ``repro.obs``:

* spans nest (parent/depth reflect the per-thread stack) and record
  safely from concurrent threads onto distinct tracks;
* the Perfetto export carries every key the trace_event spec requires;
* ``CompileCounter.expect`` windows raise :class:`RecompileError` AT
  TRACE TIME when a fixed-shape tier retraces under
  ``REPRO_OBS_STRICT=1`` — and never raise when strict mode is off;
* the metrics snapshot round-trips losslessly through JSON;
* with no tracer installed the instrumented hot paths are free: the
  spans a chunked fit would emit cost <2% of that fit's wall time.
"""
import importlib.util
import json
import os
import threading
import time

import numpy as np
import pytest

from repro import obs
from repro.launch import obs_report

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True)
def _clean_obs_state():
    """Every test starts (and ends) with no tracer installed."""
    obs.uninstall()
    yield
    obs.uninstall()


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------

def test_span_nesting_records_parent_depth_attrs():
    tracer = obs.install()
    with obs.span("outer", phase="a"):
        with obs.span("inner") as sp:
            sp.set(bytes=128)
        obs.instant("marker", hit=True)
    obs.uninstall()

    by_name = {e["name"]: e for e in tracer.events()}
    assert set(by_name) == {"outer", "inner", "marker"}
    outer, inner, marker = (by_name[k] for k in ("outer", "inner", "marker"))
    assert outer["depth"] == 0 and outer["parent"] is None
    assert inner["depth"] == 1 and inner["parent"] == "outer"
    assert inner["attrs"] == {"bytes": 128}
    assert outer["attrs"] == {"phase": "a"}
    assert marker["instant"] is True and marker["parent"] == "outer"
    # children are contained in the parent on the monotonic clock
    assert outer["ts_us"] <= inner["ts_us"]
    assert inner["ts_us"] + inner["dur_us"] \
        <= outer["ts_us"] + outer["dur_us"] + 1.0


def test_span_disabled_is_shared_noop():
    assert obs.current() is None
    s1 = obs.span("anything", big=1)
    s2 = obs.span("else")
    assert s1 is s2                       # the shared singleton
    with s1 as sp:
        sp.set(x=1)                       # no-op, no state


def test_timed_measures_without_tracer():
    with obs.timed("region") as t:
        time.sleep(0.01)
    assert t.dur_s >= 0.009               # measured even when disabled
    tracer = obs.install()
    with obs.timed("region") as t2:
        pass
    obs.uninstall()
    (ev,) = tracer.events()
    assert ev["name"] == "region"
    assert abs(ev["dur_us"] - t2.dur_s * 1e6) < 1.0   # same measurement


def test_span_thread_safety_distinct_tracks():
    tracer = obs.install()
    n_threads, spans_each = 8, 25
    barrier = threading.Barrier(n_threads)

    def work(i):
        barrier.wait()
        for j in range(spans_each):
            with obs.span(f"t{i}", j=j):
                with obs.span(f"t{i}.child"):
                    pass

    threads = [threading.Thread(target=work, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    obs.uninstall()

    events = tracer.events()
    assert len(events) == n_threads * spans_each * 2
    tracks = {e["track"] for e in events}
    assert len(tracks) == n_threads       # one track per thread
    # nesting never leaked across threads: every child's parent is its
    # own thread's outer span
    for e in events:
        if e["name"].endswith(".child"):
            assert e["parent"] == e["name"][:-len(".child")]
            assert e["depth"] == 1


# ---------------------------------------------------------------------------
# exports
# ---------------------------------------------------------------------------

def _sample_tracer():
    tracer = obs.install()
    with obs.span("fit.wholebrain", n=64):
        with obs.span("fit.eigh"):
            pass
        obs.instant("registry.hit", model="m0")
    obs.uninstall()
    return tracer


def test_perfetto_export_required_keys():
    doc = _sample_tracer().to_perfetto()
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    assert len(doc["traceEvents"]) == 3
    for rec in doc["traceEvents"]:
        for key in ("name", "cat", "ph", "ts", "pid", "tid", "args"):
            assert key in rec, (key, rec)
        if rec["ph"] == "X":
            assert "dur" in rec and rec["dur"] >= 0
        else:
            assert rec["ph"] == "i" and rec["s"] == "t"
    cats = {r["cat"] for r in doc["traceEvents"]}
    assert cats == {"fit", "registry"}    # dotted prefix becomes category
    json.dumps(doc)                       # serialisable as-is


def test_write_trace_picks_format_by_suffix(tmp_path):
    tracer = _sample_tracer()
    jpath, lpath = str(tmp_path / "t.json"), str(tmp_path / "t.jsonl")
    assert obs.write_trace(tracer, jpath) == "perfetto"
    assert obs.write_trace(tracer, lpath) == "jsonl"
    assert "traceEvents" in json.load(open(jpath))
    lines = [json.loads(ln) for ln in open(lpath)]
    assert [e["name"] for e in lines] \
        == [e["name"] for e in tracer.events()]


def test_obs_report_coverage_and_render(tmp_path):
    tracer = obs.install()
    with obs.span("root"):
        with obs.span("a"):
            time.sleep(0.02)
        with obs.span("b"):
            time.sleep(0.02)
    obs.uninstall()
    path = str(tmp_path / "trace.jsonl")
    tracer.write_jsonl(path)

    events = obs_report.load_events(path)
    root, cov = obs_report.root_coverage(events)
    assert root["name"] == "root"
    assert cov > 0.9                      # sleeps dominate the root
    out = obs_report.render(events)
    assert "root" in out and "%wall" in out


def test_parse_sweep_log_accepts_obs_traces(tmp_path):
    spec = importlib.util.spec_from_file_location(
        "parse_sweep_log",
        os.path.join(REPO, "benchmarks", "parse_sweep_log.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)

    path = str(tmp_path / "trace.jsonl")
    _sample_tracer().write_jsonl(path)
    recs = mod.parse(path)                # sniffed as an obs trace
    assert len(recs) == 3
    kinds = {r["kind"] for r in recs}
    assert kinds == {"span", "instant"}
    assert any(r.get("model") == "m0" for r in recs)   # attrs flattened

    # legacy sweep logs still parse through the same entry point
    legacy = tmp_path / "sweep.log"
    legacy.write_text(
        "== archA × 4x8 × 1x1 (rules=on) ==\n"
        "memory_analysis: temp_size_in_bytes=10 argument_size_in_bytes=4\n"
        "cost_analysis: flops=100.0 bytes=200.0\n")
    (rec,) = mod.parse(str(legacy))
    assert rec["arch"] == "archA" and rec["flops"] == 100.0


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_metrics_snapshot_json_roundtrip(tmp_path):
    reg = obs.MetricsRegistry()
    reg.counter("compiles", tier="foldstats.chunk_update").inc()
    reg.counter("compiles", tier="foldstats.chunk_update").inc(2)
    reg.counter("bytes_staged").inc(4096)
    reg.gauge("rss_bytes").set(100.0)
    reg.gauge("rss_bytes").set(50.0)      # peak stays at the high-water
    for v in (1.0, 3.0, 2.0):
        reg.histogram("flush_ms").observe(v)

    snap = reg.snapshot()
    assert snap["schema"] == obs.SCHEMA_VERSION
    assert snap["counters"]["compiles{tier=foldstats.chunk_update}"] == 3.0
    assert snap["gauges"]["rss_bytes"] == {"value": 50.0, "peak": 100.0}
    hist = snap["histograms"]["flush_ms"]
    assert hist["count"] == 3 and hist["min"] == 1.0 and hist["max"] == 3.0
    assert hist["mean"] == pytest.approx(2.0)

    path = str(tmp_path / "metrics.json")
    reg.write_json(path)
    assert json.load(open(path)) == json.loads(json.dumps(snap))

    reg.reset()
    assert reg.snapshot()["counters"] == {}


def test_same_instrument_same_object():
    reg = obs.MetricsRegistry()
    assert reg.counter("x", a=1, b=2) is reg.counter("x", b=2, a=1)
    assert reg.counter("x") is not reg.counter("y")


# ---------------------------------------------------------------------------
# recompile sentinel
# ---------------------------------------------------------------------------

def test_sentinel_fires_on_shape_polymorphic_jit(monkeypatch):
    import jax
    import jax.numpy as jnp

    ctr = obs.CompileCounter("test.polymorphic")

    @jax.jit
    def f(x):
        ctr.mark()                        # trace-time side effect
        return jnp.sum(x * 2.0)

    monkeypatch.setenv("REPRO_OBS_STRICT", "1")
    with ctr.expect(at_most=1):
        f(jnp.ones((4,)))                 # first shape: allowed
        f(jnp.ones((4,)))                 # cache hit: no mark
        with pytest.raises(obs.RecompileError):
            f(jnp.ones((8,)))             # new shape retraces → raises
    assert ctr.count == 2


def test_sentinel_silent_without_strict(monkeypatch):
    import jax
    import jax.numpy as jnp

    ctr = obs.CompileCounter("test.lenient")

    @jax.jit
    def f(x):
        ctr.mark()
        return x + 1

    monkeypatch.delenv("REPRO_OBS_STRICT", raising=False)
    with ctr.expect(at_most=1):
        f(jnp.ones((4,)))
        f(jnp.ones((8,)))                 # over the window — counted only
    assert ctr.count == 2
    # the shared compiles{tier=...} metric saw both traces
    snap = obs.snapshot()
    assert snap["counters"]["compiles{tier=test.lenient}"] >= 2.0


def test_sentinel_windows_nest(monkeypatch):
    monkeypatch.setenv("REPRO_OBS_STRICT", "1")
    ctr = obs.CompileCounter("test.nested")
    with ctr.expect(at_most=5):
        with ctr.expect(at_most=0):       # inner window shadows outer
            with pytest.raises(obs.RecompileError):
                ctr.mark()
        ctr.mark()                        # outer window allows it again
    assert ctr.count == 2


# ---------------------------------------------------------------------------
# disabled-path overhead
# ---------------------------------------------------------------------------

def test_disabled_tracer_overhead_under_2pct(make_run_store):
    """The spans a chunked fit emits must cost <2% of its wall when no
    tracer is installed.  Measured as: (per-span disabled cost) × (spans
    an instrumented run actually records) vs the fit's own wall time."""
    from repro.encoding import BrainEncoder

    rng = np.random.default_rng(0)
    n, p, t = 4096, 32, 16
    X = rng.normal(size=(n, p)).astype(np.float32)
    Y = rng.normal(size=(n, t)).astype(np.float32)
    store = make_run_store(X, Y, n_runs=4)

    def fit():
        return BrainEncoder(n_folds=5, device_memory_budget=1,
                            chunk_rows=512).fit(store=store)

    fit()                                 # warm: compiles cached
    assert obs.current() is None
    t0 = time.perf_counter()
    fit()
    fit_wall = time.perf_counter() - t0

    tracer = obs.install()
    fit()
    obs.uninstall()
    n_spans = len(tracer.events())
    assert n_spans > 0                    # the fit path IS instrumented

    reps = 200                            # amortise timer noise
    t0 = time.perf_counter()
    for _ in range(reps * n_spans):
        with obs.span("fit.stats", bytes=1024):
            pass
    disabled_cost = (time.perf_counter() - t0) / reps
    overhead = disabled_cost / fit_wall
    assert overhead < 0.02, (
        f"disabled spans cost {overhead:.2%} of the chunked fit wall "
        f"({n_spans} spans, {disabled_cost * 1e6:.1f} µs/run vs "
        f"{fit_wall * 1e3:.1f} ms fit)")
