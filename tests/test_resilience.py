"""Resilience tier: retry policy, fault injection, crash-resume, leases.

Everything here is driven by the seeded deterministic harness in
``repro.resilience.faultsim`` — no real sleeps, no wall-clock races:

* ``FaultPolicy``/``retry_call`` — deterministic jittered backoff on a
  virtual clock, typed transient/permanent classification, deadline and
  attempt exhaustion re-raising the ORIGINAL exception.
* ``FitJournal`` — crash-consistent ledger round-trip, signature pinning,
  torn-payload reaping, and the headline contract: a fit interrupted
  right after block N resumes from the journal and produces λ AND W
  bit-identical to an uninterrupted run, replaying (never re-streaming)
  the committed blocks.
* Streaming tier under injected faults — transient chunk-read and
  shard-mmap failures mid-fit change neither λ, W, nor the compile
  count; the prefetcher's restarting reader keeps the stream
  bit-identical, frees its buffers, and joins its thread on both
  retry-success and give-up.
* Fleet liveness — heartbeat-stamped leases on an injected clock,
  ``expire_dead``/``holders(ttl_s=...)`` ignoring stale claims, the
  bounded (typed ``FleetError``) lock acquire, and ``WorkerLost``
  re-admission + ``replay`` drain.
* ``reap_stale_staging`` — age-gated orphan sweep.
"""
import json
import os

import numpy as np
import pytest

from repro import obs
from repro.data.store import RunStore
from repro.encoding import EncoderConfig
from repro.resilience import (
    NO_RETRY, FaultPolicy, FitJournal, JournalError, TransientFault,
    classify_default, reap_stale_staging, retry_call,
)
from repro.resilience.faultsim import (
    FaultInjector, InjectedFault, InjectedPermanentFault, flaky_bundle,
    truncate_file, wrap_store,
)
from repro.serving_encoders.fleet import (
    FleetError, FleetFrontend, ResidencyMap, WorkerLost, replay,
)
from repro.serving_encoders.service import PredictRequest, ServiceError
from repro.wholebrain import fit_wholebrain
from repro.wholebrain.solver import journal_signature


def _counters(prefix: str) -> float:
    return sum(v for k, v in obs.snapshot()["counters"].items()
               if k.startswith(prefix))


def _make_store(make_run_store, seed=0, n=96, p=8, t=40, k=3):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, p)).astype(np.float32)
    W = rng.normal(size=(p, t)).astype(np.float32) / np.sqrt(p)
    Y = (X @ W + 0.05 * rng.normal(size=(n, t))).astype(np.float32)
    return make_run_store(X, Y, n_folds=k)


CFG = dict(n_folds=3, chunk_rows=32, use_pallas=False)


# ---------------------------------------------------------------------------
# FaultPolicy / retry_call
# ---------------------------------------------------------------------------

def test_delay_deterministic_and_bounded():
    a = FaultPolicy(seed=7)
    b = FaultPolicy(seed=7)
    assert [a.delay_for("op", i) for i in range(1, 6)] \
        == [b.delay_for("op", i) for i in range(1, 6)]
    assert a.delay_for("op", 1) != FaultPolicy(seed=8).delay_for("op", 1)
    assert a.delay_for("op", 1) != a.delay_for("other", 1)
    for i in range(1, 12):
        assert 0.0 <= a.delay_for("op", i) \
            <= a.max_delay_s * (1 + a.jitter)


def test_classify_default():
    import errno
    assert classify_default(TransientFault("x"))
    assert classify_default(TimeoutError())
    assert classify_default(OSError(errno.EIO, "io"))
    assert classify_default(OSError(errno.EAGAIN, "again"))
    assert not classify_default(OSError(errno.ENOENT, "gone"))
    assert not classify_default(ValueError("nope"))
    assert not classify_default(InjectedPermanentFault("planned"))
    assert classify_default(InjectedFault("planned"))


def test_retry_call_retries_then_succeeds():
    policy = FaultPolicy(max_attempts=3, seed=3).with_virtual_time()
    calls = []

    def fn():
        calls.append(1)
        if len(calls) < 3:
            raise TransientFault("flake")
        return "ok"

    r0, g0 = _counters("io_retries{op=t.fn"), _counters("io_giveups{op=t.fn")
    assert retry_call(fn, policy, "t.fn") == "ok"
    assert len(calls) == 3
    assert _counters("io_retries{op=t.fn") - r0 == 2
    assert _counters("io_giveups{op=t.fn") - g0 == 0
    # Virtual time advanced by EXACTLY the two deterministic backoffs.
    expect = policy.delay_for("t.fn", 1) + policy.delay_for("t.fn", 2)
    assert policy.clock() == pytest.approx(expect)


def test_retry_call_permanent_raises_first():
    policy = FaultPolicy(max_attempts=5).with_virtual_time()
    calls = []

    def fn():
        calls.append(1)
        raise ValueError("permanent")

    with pytest.raises(ValueError):
        retry_call(fn, policy, "t.perm")
    assert len(calls) == 1
    assert policy.clock() == 0.0          # never slept


def test_retry_call_give_up_reraises_original():
    policy = FaultPolicy(max_attempts=2, seed=1).with_virtual_time()
    boom = InjectedFault("always")
    g0 = _counters("io_giveups{op=t.give")
    with pytest.raises(InjectedFault) as err:
        retry_call(lambda: (_ for _ in ()).throw(boom), policy, "t.give")
    assert err.value is boom              # the ORIGINAL exception, untyped
    assert _counters("io_giveups{op=t.give") - g0 == 1


def test_retry_call_deadline_beats_attempts():
    policy = FaultPolicy(max_attempts=100, base_delay_s=1.0, jitter=0.0,
                         deadline_s=2.5).with_virtual_time()
    calls = []

    def fn():
        calls.append(1)
        raise TransientFault("slow storage")

    with pytest.raises(TransientFault):
        retry_call(fn, policy, "t.deadline")
    # 1s + 2s(capped) backoffs put the clock past the 2.5s deadline on
    # the third failure — far short of 100 attempts.
    assert len(calls) == 3


def test_no_retry_policy():
    calls = []

    def fn():
        calls.append(1)
        raise TransientFault("x")

    with pytest.raises(TransientFault):
        retry_call(fn, None, "t.noretry")      # None -> NO_RETRY
    assert len(calls) == 1
    assert NO_RETRY.max_attempts == 1


# ---------------------------------------------------------------------------
# FaultInjector
# ---------------------------------------------------------------------------

def test_injector_plans_exact_invocations():
    inj = FaultInjector(seed=5)
    inj.plan("op", 2)
    inj.plan("op", 4, times=2)
    seen = []
    for i in range(1, 7):
        try:
            inj.check("op")
            seen.append(i)
        except InjectedFault:
            pass
    assert seen == [1, 3, 6]
    assert inj.count("op") == 6
    assert inj.fired("op") == 3
    with pytest.raises(ValueError):
        inj.plan("op", 0)


def test_injector_custom_exception():
    inj = FaultInjector()
    inj.plan("op", 1, exc=lambda: InjectedPermanentFault("dead disk"))
    with pytest.raises(InjectedPermanentFault):
        inj.check("op")


# ---------------------------------------------------------------------------
# FitJournal
# ---------------------------------------------------------------------------

def test_journal_round_trip(tmp_path):
    sig = {"n": 8, "k": 3}
    j = FitJournal.attach(str(tmp_path / "j"), sig)
    assert not j.has_xstats and j.completed_blocks() == set()
    G = np.arange(24, dtype=np.float32).reshape(3, 8)
    j.put_xstats(G, G[:, 0], np.array([2.0, 3.0, 3.0], np.float32))
    j.put_block(0, scores=np.ones((3, 4)), ahat=np.zeros((2, 5), np.float32))
    j.put_block(2, lam=1.5, curve=np.ones(4), W=np.ones((2, 3)))

    j2 = FitJournal.attach(str(tmp_path / "j"), sig)    # resume
    assert j2.has_xstats
    np.testing.assert_array_equal(j2.load_xstats()[0], G)
    assert j2.completed_blocks() == {0, 2}
    assert j2.has_block(0) and not j2.has_block(1)
    rec = j2.load_block(2)
    assert rec["lam"] == 1.5
    np.testing.assert_array_equal(rec["W"], np.ones((2, 3)))
    with pytest.raises(JournalError):
        j2.load_block(1)
    j2.finish()
    assert not os.path.isdir(str(tmp_path / "j"))


def test_journal_signature_mismatch(tmp_path):
    FitJournal.attach(str(tmp_path / "j"), {"n": 8})
    with pytest.raises(JournalError):
        FitJournal.attach(str(tmp_path / "j"), {"n": 9})


def test_journal_corrupt_ledger(tmp_path):
    j = FitJournal.attach(str(tmp_path / "j"), {"n": 8})
    path = os.path.join(j.root, "ledger.json")
    truncate_file(path, os.path.getsize(path) // 2)
    with pytest.raises(JournalError):
        FitJournal.attach(str(tmp_path / "j"), {"n": 8})


def test_journal_reaps_torn_payloads(tmp_path):
    sig = {"n": 8}
    j = FitJournal.attach(str(tmp_path / "j"), sig)
    j.put_block(0, scores=np.ones(3))
    # A crash between payload write and rename leaves a tmp orphan.
    orphan = os.path.join(j.root, "block_00001.scores.npy.tmp-999")
    with open(orphan, "wb") as f:
        f.write(b"torn")
    j2 = FitJournal.attach(str(tmp_path / "j"), sig)
    assert not os.path.exists(orphan)
    assert j2.completed_blocks() == {0}        # the committed block survives


# ---------------------------------------------------------------------------
# Crash-resume bit-identity
# ---------------------------------------------------------------------------

class _Interrupted(BaseException):
    """In-process stand-in for the kill: raised right after block N's
    ledger commit, so the journal state is exactly a crashed fit's."""


class _InterruptAfterBlock:
    def __init__(self, journal, after: int):
        self._journal = journal
        self._after = after

    def put_block(self, bi: int, **kwargs) -> None:
        self._journal.put_block(bi, **kwargs)
        if bi == self._after:
            raise _Interrupted()

    def __getattr__(self, name):
        return getattr(self._journal, name)


@pytest.mark.parametrize("lambda_mode", ["global", "per_block"])
def test_crash_resume_bit_identical(make_run_store, tmp_path, lambda_mode):
    store = _make_store(make_run_store)
    cfg = EncoderConfig(**CFG)
    ref = fit_wholebrain(store, cfg, t_block=12, lambda_mode=lambda_mode)
    assert ref.telemetry["n_blocks"] == 4

    jdir = str(tmp_path / "journal")
    sig = journal_signature(store, cfg, t_block=12, lambda_mode=lambda_mode)
    wrapped = _InterruptAfterBlock(FitJournal.attach(jdir, sig), after=1)
    with pytest.raises(_Interrupted):
        fit_wholebrain(store, cfg, t_block=12, lambda_mode=lambda_mode,
                       journal=wrapped)
    ledger = json.load(open(os.path.join(jdir, "ledger.json")))
    assert ledger["xstats"] and set(ledger["blocks"]) == {"0", "1"}

    res = fit_wholebrain(store, cfg, t_block=12, lambda_mode=lambda_mode,
                         journal=jdir)
    tel = res.telemetry
    assert tel["resumed"]
    assert tel["blocks_replayed"] == 2 and tel["blocks_streamed"] == 2
    # The journal replay does NOT re-run the X-stats pass, so the resumed
    # fit reads X at most once (the surviving blocks' restream).
    assert tel["row_passes_x"] <= ref.telemetry["row_passes_x"]
    np.testing.assert_array_equal(res.best_lambda, ref.best_lambda)
    np.testing.assert_array_equal(res.cv_scores, ref.cv_scores)
    np.testing.assert_array_equal(res.weights, ref.weights)
    np.testing.assert_array_equal(res.lambda_by_target,
                                  ref.lambda_by_target)
    assert not os.path.isdir(jdir)             # finished -> deleted


def test_journal_rejects_other_fit_shape(make_run_store, tmp_path):
    store = _make_store(make_run_store)
    cfg = EncoderConfig(**CFG)
    jdir = str(tmp_path / "journal")
    sig = journal_signature(store, cfg, t_block=12)
    wrapped = _InterruptAfterBlock(FitJournal.attach(jdir, sig), after=0)
    with pytest.raises(_Interrupted):
        fit_wholebrain(store, cfg, t_block=12, journal=wrapped)
    with pytest.raises(JournalError):          # different blocking
        fit_wholebrain(store, cfg, t_block=20, journal=jdir)


# ---------------------------------------------------------------------------
# Streamed fit under injected faults
# ---------------------------------------------------------------------------

def test_fit_unchanged_by_injected_transient_faults(make_run_store):
    store = _make_store(make_run_store)
    cfg = EncoderConfig(**CFG)
    ref = fit_wholebrain(store, cfg, t_block=12)

    store.fault_policy = FaultPolicy(max_attempts=3,
                                     seed=13).with_virtual_time()
    inj = FaultInjector(seed=13)
    inj.plan("store.mmap", 1)
    inj.plan("store.chunk", 2)
    inj.plan("store.chunk", 7)
    faulty_store = wrap_store(store, inj)
    r0 = _counters("io_retries")
    g0 = _counters("io_giveups")
    res = fit_wholebrain(faulty_store, cfg, t_block=12)
    assert inj.fired("store.chunk") == 2 and inj.fired("store.mmap") == 1
    assert _counters("io_retries") - r0 >= 3
    assert _counters("io_giveups") - g0 == 0
    np.testing.assert_array_equal(res.best_lambda, ref.best_lambda)
    np.testing.assert_array_equal(res.weights, ref.weights)
    # The fixed-shape contract is untouched by the retries: the compiled
    # updates were cached from the clean fit, so ZERO new traces.
    assert res.telemetry["colblock_compile_delta"] == 0
    assert res.telemetry["gram_compile_delta"] == 0


# ---------------------------------------------------------------------------
# ChunkPrefetcher retry paths
# ---------------------------------------------------------------------------

def _prefetcher(store, chunk_rows=32):
    return store.iter_chunks(chunk_rows, prefetch=True)


def test_prefetch_retry_success_bit_identical(make_run_store):
    store = _make_store(make_run_store)
    sync = [(x.copy(), y.copy()) for x, y in store.iter_chunks(32)]

    store.fault_policy = FaultPolicy(max_attempts=3,
                                     seed=2).with_virtual_time()
    inj = FaultInjector(seed=2)
    inj.plan("store.chunk", 2)
    faulty = wrap_store(store, inj)
    pf = _prefetcher(faulty)
    got = [(x.copy(), y.copy()) for x, y in pf]
    assert len(got) == len(sync)
    for (gx, gy), (sx, sy) in zip(got, sync):
        np.testing.assert_array_equal(gx, sx)
        np.testing.assert_array_equal(gy, sy)
    # Exhausted cleanly after the retry: buffers freed, thread joined.
    assert pf._bufs is None and pf._thread is None


def test_prefetch_give_up_frees_pool(make_run_store):
    store = _make_store(make_run_store)
    store.fault_policy = FaultPolicy(max_attempts=3,
                                     seed=2).with_virtual_time()
    inj = FaultInjector(seed=2)
    inj.plan("store.chunk", 2, times=5)        # > max_attempts: give up
    faulty = wrap_store(store, inj)
    pf = _prefetcher(faulty)
    g0 = _counters("io_giveups{op=prefetch.read")
    with pytest.raises(InjectedFault):
        list(pf)
    assert _counters("io_giveups{op=prefetch.read") - g0 == 1
    assert pf._bufs is None and pf._thread is None
    # The pool is NOT poisoned: a fresh stream over the same (now
    # exhausted-injector) store is complete and clean.
    again = [(x.copy(), y.copy()) for x, y in _prefetcher(faulty)]
    assert len(again) == len(list(store.iter_chunks(32)))


def test_prefetch_permanent_after_successful_retry(make_run_store):
    """A reader exception AFTER a successful retry must still surface to
    the consumer and release the buffer pool."""
    store = _make_store(make_run_store)
    store.fault_policy = FaultPolicy(max_attempts=3,
                                     seed=4).with_virtual_time()
    inj = FaultInjector(seed=4)
    inj.plan("store.chunk", 1)                 # transient -> retried OK
    inj.plan("store.chunk", 3,                 # then the disk truly dies
             exc=lambda: InjectedPermanentFault("dead"))
    faulty = wrap_store(store, inj)
    pf = _prefetcher(faulty)
    got = []
    with pytest.raises(InjectedPermanentFault):
        for chunk in pf:
            got.append(chunk)
    assert len(got) >= 1                       # the retried chunk arrived
    assert pf._bufs is None and pf._thread is None


# ---------------------------------------------------------------------------
# Store-level mmap retry
# ---------------------------------------------------------------------------

def test_store_mmap_retry(make_run_store):
    store = _make_store(make_run_store)
    store.fault_policy = FaultPolicy(max_attempts=3,
                                     seed=6).with_virtual_time()
    inj = FaultInjector(seed=6)
    inj.plan("store.mmap", 1)
    faulty = wrap_store(store, inj)
    r0 = _counters("io_retries{op=store.mmap")
    chunks = list(faulty.iter_chunks(32))
    assert sum(x.shape[0] for x, _ in chunks) == store.shape[0]
    assert _counters("io_retries{op=store.mmap") - r0 == 1


def test_store_mmap_no_policy_raises(make_run_store):
    store = _make_store(make_run_store)
    assert store.fault_policy is None
    inj = FaultInjector()
    inj.plan("store.mmap", 1)
    with pytest.raises(InjectedFault):
        list(wrap_store(store, inj).iter_chunks(32))


# ---------------------------------------------------------------------------
# Registry retry + typed give-up
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_fleet(tmp_path_factory):
    from repro.serving_encoders.traffic import build_synthetic_fleet
    root = tmp_path_factory.mktemp("fleet")
    return build_synthetic_fleet(str(root), 1, n=64, p=16, t=24)


def test_registry_load_retries(tiny_fleet):
    from repro.serving_encoders.registry import EncoderRegistry
    inj = FaultInjector(seed=8)
    inj.plan("bundle.load_encoder", 1)
    reg = EncoderRegistry(
        wave_rows=8,
        fault_policy=FaultPolicy(max_attempts=3, seed=8).with_virtual_time())
    name, path = tiny_fleet[0]
    reg.add(name, path)
    reg._bundles[name] = flaky_bundle(reg._bundles[name], inj)
    r0 = _counters("io_retries{op=registry.load_encoder")
    entry = reg.get(name)
    assert entry.encoder is not None
    assert _counters("io_retries{op=registry.load_encoder") - r0 == 1


def test_registry_give_up_is_typed(tiny_fleet):
    from repro.serving_encoders.bundle import BundleError
    from repro.serving_encoders.registry import EncoderRegistry
    inj = FaultInjector(seed=9)
    inj.plan("bundle.load_encoder", 1, times=10)
    reg = EncoderRegistry(
        wave_rows=8,
        fault_policy=FaultPolicy(max_attempts=3, seed=9).with_virtual_time())
    name, path = tiny_fleet[0]
    reg.add(name, path)
    reg._bundles[name] = flaky_bundle(reg._bundles[name], inj)
    g0 = _counters("io_giveups{op=registry.load_encoder")
    with pytest.raises(BundleError):           # OSError translated, typed
        reg.get(name)
    assert _counters("io_giveups{op=registry.load_encoder") - g0 == 1
    assert reg.stats()["loaded"] == 0          # no partial entry inserted


# ---------------------------------------------------------------------------
# Orphan-staging reaper
# ---------------------------------------------------------------------------

def test_reap_is_age_gated(tmp_path):
    root = str(tmp_path)
    old = tmp_path / ".tmpbundle_dead"
    old.mkdir()
    (old / "leaf.npy").write_bytes(b"x")
    fresh = tmp_path / ".tmpbundle_live"
    fresh.mkdir()
    torn = tmp_path / "shard.npy.tmp-123"
    torn.write_bytes(b"y")
    keeper = tmp_path / "manifest.json"
    keeper.write_text("{}")
    past = os.stat(root).st_mtime - 7200
    os.utime(old, (past, past))
    os.utime(torn, (past, past))

    c0 = _counters("staging_reaped")
    reaped = reap_stale_staging(root, max_age_s=3600.0)
    assert reaped == [".tmpbundle_dead", "shard.npy.tmp-123"]
    assert not old.exists() and not torn.exists()
    assert fresh.exists() and keeper.exists()  # young + non-staging survive
    assert _counters("staging_reaped") - c0 == 2
    assert reap_stale_staging(str(tmp_path / "missing")) == []


def test_bundle_writer_reaps_stale_staging(tmp_path):
    from repro.wholebrain.artifact import BundleWriter
    stale = tmp_path / ".tmpbundle_crashed"
    stale.mkdir()
    past = os.stat(str(tmp_path)).st_mtime - 7200
    os.utime(stale, (past, past))
    w = BundleWriter(str(tmp_path / "bundle"), p=4, t=8)
    try:
        assert not stale.exists()
    finally:
        w.abort()


# ---------------------------------------------------------------------------
# Fleet liveness: leases, lock timeout, WorkerLost
# ---------------------------------------------------------------------------

def _clocked_map(path, t0=1000.0, **kw):
    clk = [t0]
    rmap = ResidencyMap(path, clock=lambda: clk[0],
                        sleep=lambda s: clk.__setitem__(0, clk[0] + s),
                        **kw)
    return rmap, clk


def test_lease_heartbeat_and_expiry(tmp_path):
    rmap, clk = _clocked_map(str(tmp_path / "residency.json"))
    rmap.publish("w0", {"m": 100})
    clk[0] += 10
    rmap.publish("w1", {"m": 50})
    assert rmap.holders("m") == ["w0", "w1"]
    assert rmap.holders("m", ttl_s=5.0) == ["w1"]    # w0's stamp is stale

    clk[0] += 10                      # w0 is now 20s stale, w1 10s
    rmap.heartbeat("w1")              # refresh without touching models
    c0 = _counters("lease_expirations")
    assert rmap.expire_dead(15.0) == ["w0"]
    assert _counters("lease_expirations") - c0 == 1
    snap = rmap.snapshot()["workers"]
    assert set(snap) == {"w1"}
    assert snap["w1"]["models"] == {"m": 50}         # claims survive
    assert rmap.expire_dead(15.0) == []              # idempotent


def test_unstamped_row_counts_as_dead(tmp_path):
    rmap, clk = _clocked_map(str(tmp_path / "residency.json"))
    rmap.publish("w0", {"m": 1})
    # A row written by pre-lease code has no heartbeat field.
    data = rmap.snapshot()
    del data["workers"]["w0"]["heartbeat"]
    rmap._write(data)
    assert rmap.expire_dead(1e9) == ["w0"]


def test_heartbeat_claims_lease_before_first_load(tmp_path):
    rmap, clk = _clocked_map(str(tmp_path / "residency.json"))
    rmap.heartbeat("w0")
    row = rmap.snapshot()["workers"]["w0"]
    assert row["models"] == {} and row["heartbeat"] == clk[0]


def test_lock_timeout_is_typed(tmp_path):
    import fcntl
    path = str(tmp_path / "residency.json")
    rmap, clk = _clocked_map(path, lock_timeout_s=0.5)
    fd = os.open(path + ".lock", os.O_CREAT | os.O_RDWR, 0o644)
    fcntl.flock(fd, fcntl.LOCK_EX)             # a wedged peer holds it
    try:
        t0 = clk[0]
        with pytest.raises(FleetError):
            rmap.publish("w0", {})
        assert clk[0] - t0 >= 0.5              # bounded, virtual-time wait
    finally:
        fcntl.flock(fd, fcntl.LOCK_UN)
        os.close(fd)
    rmap.publish("w0", {})                     # released -> works again


class _FakeStats:
    def __init__(self):
        self.rejected = []

    def record_rejected(self, tenant):
        self.rejected.append(tenant)


class _FlakyService:
    """Raises ``WorkerLost`` on the first ``fail_times`` serve calls."""

    def __init__(self, fail_times=1):
        self.stats = _FakeStats()
        self.fail_times = fail_times
        self.calls = 0

    def serve(self, batch, wave_rows=None):
        self.calls += 1
        if self.calls <= self.fail_times:
            raise WorkerLost("worker died mid-batch")
        return [f"r{p.model}:{i}" for i, p in enumerate(batch)]


def _req(model="m", rows=4):
    return PredictRequest(model, np.zeros((rows, 3), np.float32))


def test_worker_lost_readmits_batch():
    svc = _FlakyService(fail_times=1)
    fe = FleetFrontend(svc, max_pending_rows=64)
    fe.submit(_req(rows=4))
    fe.submit(_req(rows=6))
    c0 = _counters("requests_replayed")
    with pytest.raises(WorkerLost):
        fe.flush()
    # The batch is back in admission order — nothing dropped.
    assert fe.pending_rows == 10 and fe.replayed == 2
    assert _counters("requests_replayed") - c0 == 2
    out = fe.flush()                           # worker back: drains clean
    assert len(out) == 2 and fe.pending_rows == 0


def test_replay_survives_lost_worker():
    svc = _FlakyService(fail_times=1)
    fe = FleetFrontend(svc, max_pending_rows=64)
    reqs = [_req(rows=4) for _ in range(5)]
    results, rejections = replay(fe, reqs)
    assert all(r is not None for r in results)
    assert rejections == [] and fe.replayed == 5


def test_replay_gives_up_after_max_attempts():
    svc = _FlakyService(fail_times=99)
    fe = FleetFrontend(svc, max_pending_rows=64)
    with pytest.raises(WorkerLost):
        replay(fe, [_req()], max_flush_attempts=3)
    assert svc.calls == 3


def test_backpressure_still_typed_alongside_replay():
    svc = _FlakyService(fail_times=0)
    fe = FleetFrontend(svc, max_pending_rows=8)
    fe.submit(_req(rows=8))
    with pytest.raises(ServiceError):
        fe.submit(_req(rows=1))
    assert svc.stats.rejected == ["m"]
