"""HLO collective parser unit tests + assigned-config validation."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import configs
from repro.launch import hlo_analysis
from repro.models import build_model, params as P


# ---------------------------------------------------------------------------
# hlo_analysis parser
# ---------------------------------------------------------------------------

def test_collective_bytes_on_synthetic_hlo():
    hlo = """
HloModule m
ENTRY e {
  %p = f32[128,64]{1,0} parameter(0)
  %ar = f32[128,64]{1,0} all-reduce(%p), replica_groups={}
  %ag = bf16[256,64]{1,0} all-gather(%p), dimensions={0}
  %aa = f32[32,8]{1,0} all-to-all(%p), dimensions={0}
  %rs.1 = f32[16,64]{1,0} reduce-scatter(%p), dimensions={0}
  %cp = u8[1024]{0} collective-permute(%p)
  ROOT %r = f32[128,64]{1,0} add(%p, %ar)
}
"""
    got = hlo_analysis.collective_bytes(hlo)
    assert got["all-reduce"] == 128 * 64 * 4
    assert got["all-gather"] == 256 * 64 * 2
    assert got["all-to-all"] == 32 * 8 * 4
    assert got["reduce-scatter"] == 16 * 64 * 4
    assert got["collective-permute"] == 1024


def test_collective_bytes_counts_start_not_done():
    hlo = """
  %s = f32[64]{0} all-reduce-start(%p)
  %d = f32[64]{0} all-reduce-done(%s)
"""
    got = hlo_analysis.collective_bytes(hlo)
    assert got["all-reduce"] == 64 * 4


def test_collective_bytes_real_psum():
    from repro.core.compat import make_mesh, shard_map
    mesh = make_mesh((1,), ("x",))

    def f(a):
        return jax.lax.psum(a, "x")

    from jax.sharding import PartitionSpec as Pspec
    g = shard_map(f, mesh=mesh, in_specs=Pspec(), out_specs=Pspec())
    hlo = jax.jit(g).lower(jnp.zeros((32, 32), jnp.float32)).compile().as_text()
    got = hlo_analysis.collective_bytes(hlo)
    assert got["all-reduce"] >= 32 * 32 * 4


def test_roofline_terms_bottleneck_logic():
    t = hlo_analysis.roofline_terms(197e12, 0.0, 0.0)
    assert t["bottleneck"] == "compute" and abs(t["t_compute_s"] - 1) < 1e-9
    t = hlo_analysis.roofline_terms(0.0, 819e9, 0.0)
    assert t["bottleneck"] == "memory"
    t = hlo_analysis.roofline_terms(0.0, 0.0, 200e9)
    assert t["bottleneck"] == "collective" and abs(t["t_collective_s"] - 1) < 1e-9


# ---------------------------------------------------------------------------
# Assigned configs: exact numbers from the assignment table
# ---------------------------------------------------------------------------

ASSIGNED = {
    # arch: (layers*, d_model, heads, kv, d_ff, vocab)
    "mamba2-130m": (24, 768, None, None, 0, 50_280),
    "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151_936),
    "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32_064),
    "llava-next-34b": (60, 7168, 56, 8, 20_480, 64_000),
    "gemma-7b": (28, 3072, 16, 16, 24_576, 256_000),
    "grok-1-314b": (64, 6144, 48, 8, 32_768, 131_072),
    "gemma3-12b": (48, 3840, 16, 8, 15_360, 262_144),
    "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256_206),
    "gemma2-2b": (26, 2304, 8, 4, 9216, 256_000),
}


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_config_matches_assignment(arch):
    c = configs.get_config(arch)
    nl, d, h, kv, dff, v = ASSIGNED[arch]
    assert c.n_layers == nl and c.d_model == d
    if h is not None:
        assert c.n_heads == h and c.n_kv_heads == kv
    assert c.d_ff == dff and c.vocab == v
    assert c.source, "every config must cite its source"


def test_zamba2_layer_accounting():
    """54 mamba blocks + 9 shared-attn applications = 63 pattern slots."""
    c = configs.get_config("zamba2-2.7b")
    n_mamba = c.n_repeats * sum(1 for k in c.pattern if k == "mamba")
    n_shared = c.n_repeats * sum(1 for k in c.pattern if k == "shared_attn")
    assert n_mamba == 54 and n_shared == 9
    assert c.ssm.d_state == 64 and c.d_model == 2560


def test_moe_configs():
    phi = configs.get_config("phi3.5-moe-42b-a6.6b")
    grok = configs.get_config("grok-1-314b")
    assert phi.moe.n_experts == 16 and phi.moe.top_k == 2
    assert grok.moe.n_experts == 8 and grok.moe.top_k == 2


def test_pattern_ratios():
    g3 = configs.get_config("gemma3-12b")
    assert g3.pattern.count("local_attn") == 5 * g3.pattern.count("global_attn")
    g2 = configs.get_config("gemma2-2b")
    assert g2.pattern == ("local_attn", "global_attn")


@pytest.mark.parametrize("arch,lo,hi", [
    ("mamba2-130m", 0.12e9, 0.15e9),
    ("qwen3-1.7b", 1.5e9, 2.0e9),
    ("phi3.5-moe-42b-a6.6b", 40e9, 44e9),
    ("grok-1-314b", 300e9, 330e9),
    ("gemma2-2b", 2.3e9, 2.8e9),
])
def test_param_counts_match_model_names(arch, lo, hi):
    cfg = configs.get_config(arch)
    n = P.count_params(build_model(cfg).param_defs())
    assert lo <= n <= hi, (arch, n)


def test_long500k_override_bounds_all_windows():
    for arch in configs.ARCH_IDS:
        c = configs.get_config(arch).with_sliding_windows()
        assert "global_attn" not in c.pattern
        assert c.window <= 4096
        if "shared_attn" in c.pattern:
            assert c.shared_attn_window <= 4096
