"""Serving engine: wave batching, sampling, eos handling."""
import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.configs import smoke
from repro.models import build_model
from repro.serving import SamplerConfig, ServeEngine, ServeRequest
from repro.serving.sampler import sample


def _engine(arch="qwen3-1.7b", **kw):
    cfg = smoke(configs.get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return ServeEngine(model, params, cfg, wave_size=2, prompt_len=8, **kw), cfg


def test_greedy_deterministic_across_waves():
    eng, cfg = _engine()
    reqs = [ServeRequest(prompt=[1, 2, 3], max_new_tokens=5)
            for _ in range(5)]                       # 3 waves (2+2+1 padded)
    out = eng.serve(reqs)
    assert len(out) == 5
    toks = [r.tokens for r in out]
    assert all(len(t) == 5 for t in toks)
    # identical prompts → identical greedy continuations, across waves
    assert all(t == toks[0] for t in toks[1:])


def test_eos_stops_generation():
    eng, cfg = _engine()
    probe = eng.serve([ServeRequest(prompt=[5], max_new_tokens=3)])[0]
    eos = probe.tokens[1]
    out = eng.serve([ServeRequest(prompt=[5], max_new_tokens=8,
                                  eos_id=eos)])[0]
    assert out.tokens[-1] == eos
    assert len(out.tokens) <= 8


def test_mixed_max_tokens():
    eng, cfg = _engine()
    out = eng.serve([ServeRequest(prompt=[1], max_new_tokens=2),
                     ServeRequest(prompt=[1], max_new_tokens=6)])
    assert len(out[0].tokens) == 2 and len(out[1].tokens) == 6


def test_sampler_greedy_topk_topp():
    logits = jnp.asarray([[0.0, 1.0, 3.0, 2.0]])
    key = jax.random.PRNGKey(0)
    assert int(sample(key, logits, SamplerConfig())[0]) == 2
    # top_k=1 at any temperature reduces to greedy
    t = sample(key, logits, SamplerConfig(temperature=1.0, top_k=1))
    assert int(t[0]) == 2
    # top_p tiny → nucleus is just the argmax
    t = sample(key, logits, SamplerConfig(temperature=1.0, top_p=0.01))
    assert int(t[0]) == 2
    # temperature sampling stays within top-k support
    cfg = SamplerConfig(temperature=2.0, top_k=2)
    draws = {int(sample(jax.random.PRNGKey(i), logits, cfg)[0])
             for i in range(20)}
    assert draws <= {2, 3}


def test_sampling_reproducible_with_seed():
    eng1, _ = _engine(sampler=SamplerConfig(temperature=1.0, top_k=16))
    eng2, _ = _engine(sampler=SamplerConfig(temperature=1.0, top_k=16))
    r = [ServeRequest(prompt=[7, 8], max_new_tokens=6)]
    a = eng1.serve(r)[0].tokens
    b = eng2.serve(r)[0].tokens
    assert a == b
