"""Unit tests: MoE dispatch semantics and attention/layer math."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import configs
from repro.configs import smoke
from repro.models import layers, moe
from repro.models.config import MoEConfig
from repro.models.params import init as init_params


def _moe_cfg(n_experts=4, top_k=2, cf=2.0, group=32):
    base = smoke(configs.get_config("phi3.5-moe-42b-a6.6b"))
    return dataclasses.replace(
        base, moe=MoEConfig(n_experts=n_experts, top_k=top_k,
                            capacity_factor=cf, group_size=group))


def test_moe_output_is_convex_combination_of_expert_outputs():
    """With top_k=1 and ample capacity, each token's output equals exactly
    one expert's FFN output."""
    cfg = _moe_cfg(top_k=1, cf=4.0)
    p = init_params(jax.random.PRNGKey(0), moe.moe_defs(cfg),
                    dtype_override=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          jnp.float32)
    out, aux = moe.moe_apply(p, cfg, x)
    assert out.shape == x.shape and bool(jnp.isfinite(aux))

    # manual per-expert FFN
    logits = jnp.einsum("bsd,de->bse", x, p["router"])
    idx = jnp.argmax(logits, axis=-1)                      # (B,S)
    h = jnp.einsum("bsd,edgf->bsegf", x, p["wi"])
    hh = jax.nn.silu(h[..., 0, :]) * h[..., 1, :]
    eo = jnp.einsum("bsef,efd->bsed", hh, p["wo"])         # (B,S,E,d)
    expect = jnp.take_along_axis(
        eo, idx[..., None, None].repeat(cfg.d_model, -1), axis=2)[:, :, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-3, atol=2e-3)


def test_moe_capacity_drops_tokens():
    """Capacity factor ≪ 1 forces drops: outputs for dropped tokens are 0."""
    cfg = _moe_cfg(n_experts=4, top_k=1, cf=0.25, group=16)
    p = init_params(jax.random.PRNGKey(0), moe.moe_defs(cfg),
                    dtype_override=jnp.float32)
    # All tokens identical → all route to one expert → capacity C=1 keeps 1.
    x = jnp.ones((1, 16, cfg.d_model), jnp.float32)
    out, _ = moe.moe_apply(p, cfg, x)
    norms = np.asarray(jnp.linalg.norm(out[0], axis=-1))
    assert (norms > 1e-6).sum() == 1, norms   # only the first token served


def test_moe_aux_loss_balanced_vs_collapsed():
    """Aux loss ≈ 1 for a uniform router, > 1 when collapsed."""
    cfg = _moe_cfg(n_experts=4, top_k=1, cf=4.0)
    p = init_params(jax.random.PRNGKey(0), moe.moe_defs(cfg),
                    dtype_override=jnp.float32)
    # Uniform router: zero weights → equal probs.
    p_uniform = dict(p)
    p_uniform["router"] = jnp.zeros_like(p["router"])
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 32, cfg.d_model))
    _, aux_u = moe.moe_apply(p_uniform, cfg, x)
    # Collapsed router: expert-0 logit ∝ Σ|x| > 0 for every token (the
    # router is bias-free, so positive inputs are needed to collapse it).
    p_col = dict(p)
    p_col["router"] = jnp.zeros_like(p["router"]).at[:, 0].set(100.0)
    _, aux_c = moe.moe_apply(p_col, cfg, jnp.abs(x))
    assert abs(float(aux_u) - 1.0) < 0.3
    assert float(aux_c) > 2.0


def test_gqa_reduces_to_mha_when_kv_equals_heads():
    """GQA grouping with G=1 must equal plain MHA math."""
    cfg = smoke(configs.get_config("gemma-7b"))          # kv == heads
    assert cfg.n_kv_heads == cfg.n_heads
    p = init_params(jax.random.PRNGKey(0), layers.attention_defs(cfg),
                    dtype_override=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 12, cfg.d_model))
    pos = jnp.arange(12, dtype=jnp.int32)[None]
    out = layers.attention(p, cfg, layers.AttnVariant(), x, pos)
    # plain MHA reference
    q, k, v = layers._qkv(p, cfg, x, pos)
    s = jnp.einsum("bshk,btHk->bhst", q, k) if False else \
        jnp.einsum("bshk,bthk->bhst", q, k)
    mask = jnp.tril(jnp.ones((12, 12), bool))
    s = jnp.where(mask[None, None], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhst,bthk->bshk", pr, v)
    want = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_ring_cache_wraparound_matches_window_attention():
    """Decode past the window: ring slots must overwrite oldest entries and
    reproduce full-context windowed attention."""
    cfg = dataclasses.replace(smoke(configs.get_config("gemma2-2b")),
                              window=8)
    from repro.models import build_model
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    S = 24  # 3× the window
    tok = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0, cfg.vocab,
                             dtype=jnp.int32)
    full, _ = model.forward(params, {"tokens": tok})
    cache = model.init_cache(1, S)
    errs = []
    for i in range(S - 1):
        lg, cache = model.decode_step(params, cache, tok[:, i][:, None],
                                      jnp.int32(i))
        errs.append(float(jnp.max(jnp.abs(
            lg[:, 0].astype(jnp.float32) - full[:, i].astype(jnp.float32)))))
    assert max(errs) < 0.15, errs


def test_rmsnorm_scale_identity():
    p = {"scale": jnp.ones(8, jnp.float32)}
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 8), jnp.float32) * 5
    y = layers.rmsnorm(p, x)
    rms = jnp.sqrt(jnp.mean(y.astype(jnp.float32) ** 2, axis=-1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, rtol=1e-3)
