"""The mixed-wave packer + its bit-identity contract.

The fleet front-end packs scored AND unscored requests from different
tenants into the same fixed-shape waves (per-row request one-hot →
per-slot Pearson sums from one compiled program).  These tests lock the
two halves down:

* ``plan_mixed_waves`` invariants — complete in-order coverage, slot
  bounds, early close on slot exhaustion — on a fixed grid;
* the contract the whole tier stands on: for ANY mix of scored/unscored
  ragged requests, every wave-bucket ladder, and every packing cut
  (including the nearly-all-padding tail wave), the packed serve is
  BIT-identical — predictions and Pearson r — to serving each request
  alone.  Exhaustive small grid always runs; hypothesis widens the search
  when the library is installed.
"""
import numpy as np
import pytest

from repro.encoding import BrainEncoder
from repro.serving_encoders import (
    EncoderRegistry, EncoderService, PredictRequest, ServiceError,
    plan_mixed_waves, reference_serve,
)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

P, T = 12, 7


@pytest.fixture(scope="module")
def fleet_dir(tmp_path_factory):
    """Two small fitted bundles sharing (p, t) — the packer's tenants."""
    import jax
    import jax.numpy as jnp

    root = tmp_path_factory.mktemp("mixed_fleet")
    for i, name in enumerate(("m0", "m1")):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(i), 3)
        X = jax.random.normal(k1, (90, P), jnp.float32)
        W = jax.random.normal(k2, (P, T), jnp.float32)
        Y = X @ W + 0.1 * jax.random.normal(k3, (90, T), jnp.float32)
        BrainEncoder(n_folds=3).fit(X, Y).save(str(root / name))
    return root


def _registry(fleet_dir):
    reg = EncoderRegistry()
    reg.add("m0", str(fleet_dir / "m0"))
    reg.add("m1", str(fleet_dir / "m1"))
    return reg


def _requests(row_sizes, scored_flags, models=None, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i, (rows, scored) in enumerate(zip(row_sizes, scored_flags)):
        X = rng.standard_normal((rows, P)).astype(np.float32)
        Y = (rng.standard_normal((rows, T)).astype(np.float32)
             if scored else None)
        model = (models[i] if models else "m0")
        reqs.append(PredictRequest(model=model, features=X, targets=Y,
                                   tenant=f"tenant-{i % 3}"))
    return reqs


def _assert_bit_identical(fleet_dir, reqs, buckets, score_slots=2):
    packed_svc = EncoderService(_registry(fleet_dir), wave_buckets=buckets,
                                score_slots=score_slots)
    ref_svc = EncoderService(_registry(fleet_dir), wave_buckets=buckets,
                             score_slots=score_slots)
    packed = packed_svc.serve(reqs)
    ref = reference_serve(ref_svc, reqs)
    for i, (got, want) in enumerate(zip(packed, ref)):
        assert got.error is None and want.error is None
        assert np.array_equal(got.predictions, want.predictions), \
            f"request {i}: packed predictions diverge from serving alone"
        assert (got.pearson_r is None) == (want.pearson_r is None)
        if got.pearson_r is not None:
            assert np.array_equal(got.pearson_r, want.pearson_r), \
                f"request {i}: packed Pearson r diverges from serving alone"
    # Packing the mix must cost one compile per wave bucket USED — never
    # one per scored/unscored combination.
    assert packed_svc.compile_count == len(packed_svc.stats.per_bucket)


# -- planner invariants ------------------------------------------------------

def _check_plan(plan, req_rows, scored, score_slots):
    consumed = [0] * len(req_rows)
    cursor = 0                               # requests fill in arrival order
    for wave in plan:
        assert 0 < wave.fill <= wave.rows
        pos, slots = 0, set()
        for seg in wave.segments:
            assert seg.wave_lo == pos        # contiguous from offset 0
            assert seg.req >= cursor
            cursor = seg.req
            assert seg.req_lo == consumed[seg.req]
            consumed[seg.req] = seg.req_hi
            pos += seg.req_hi - seg.req_lo
            if scored[seg.req]:
                assert seg.slot is not None and seg.slot not in slots
                slots.add(seg.slot)
            else:
                assert seg.slot is None
        assert pos == wave.fill
        assert len(slots) <= score_slots
    assert consumed == list(req_rows)        # complete coverage


@pytest.mark.parametrize("score_slots", [1, 2, 4])
def test_plan_covers_all_rows_in_order(score_slots):
    req_rows = [5, 1, 17, 8, 3, 30, 2]
    scored = [True, False, True, True, False, True, True]
    plan = plan_mixed_waves(req_rows, scored, lambda rem: 8, score_slots)
    _check_plan(plan, req_rows, scored, score_slots)


def test_plan_slot_exhaustion_closes_wave_early():
    # 4 one-row scored requests into 16-row waves with 2 slots: the wave
    # must close after 2 scored requests even though 14 rows are free.
    plan = plan_mixed_waves([1, 1, 1, 1], [True] * 4, lambda rem: 16, 2)
    assert [w.fill for w in plan] == [2, 2]
    assert all(w.rows == 16 for w in plan)   # the tail is padding


def test_plan_all_padding_tail():
    # 17 rows on an 8-ladder: the tail wave carries 1 real row + 7 pad.
    plan = plan_mixed_waves([17], [True], lambda rem: 8, 1)
    assert [w.fill for w in plan] == [8, 8, 1]
    _check_plan(plan, [17], [True], 1)


def test_plan_rejects_zero_slots():
    with pytest.raises(ServiceError, match="score_slots"):
        plan_mixed_waves([4], [True], lambda rem: 8, 0)


# -- bit-identity: fixed grid (always runs) ----------------------------------

LADDERS = [(8,), (8, 32), (4, 16, 64)]


@pytest.mark.parametrize("buckets", LADDERS)
def test_mixed_pack_bit_identical_grid(fleet_dir, buckets):
    # Ragged sizes straddling every bucket boundary; scored/unscored
    # interleaved; two models so waves regroup per model.
    rows = [3, 20, 1, 33, 8, 5]
    scored = [True, False, True, True, False, True]
    models = ["m0", "m0", "m1", "m0", "m1", "m0"]
    reqs = _requests(rows, scored, models, seed=buckets[0])
    _assert_bit_identical(fleet_dir, reqs, buckets)


def test_mixed_pack_bit_identical_all_padding_tail(fleet_dir):
    # One 9-row scored request on (8,): the tail wave is 1 real row + 7
    # zero rows — the padding must be absorbed exactly (±0 adds) by the
    # sequential per-slot sum chain.
    reqs = _requests([9], [True])
    _assert_bit_identical(fleet_dir, reqs, (8,))


def test_mixed_pack_bit_identical_slot_pressure(fleet_dir):
    # More scored requests than slots per wave → early closes, carries
    # chained across many waves.
    rows = [2, 3, 2, 4, 2, 5]
    reqs = _requests(rows, [True] * 6)
    _assert_bit_identical(fleet_dir, reqs, (8, 16), score_slots=1)


def test_scored_request_spanning_many_waves(fleet_dir):
    # One scored request cut across 5 waves: its Pearson sums must chain
    # through sums_in from wave to wave, staying one sequential f32 chain.
    reqs = _requests([37], [True])
    _assert_bit_identical(fleet_dir, reqs, (8,))


# -- bit-identity: hypothesis widening (gated on availability) ---------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(
        rows=st.lists(st.integers(min_value=1, max_value=40),
                      min_size=1, max_size=6),
        scored=st.lists(st.booleans(), min_size=6, max_size=6),
        which=st.lists(st.integers(min_value=0, max_value=1),
                       min_size=6, max_size=6),
        ladder=st.sampled_from(LADDERS),
        slots=st.integers(min_value=1, max_value=3),
    )
    def test_mixed_pack_bit_identical_property(fleet_dir, rows, scored,
                                               which, ladder, slots):
        n = len(rows)
        reqs = _requests(rows, scored[:n],
                         [f"m{w}" for w in which[:n]], seed=n)
        _assert_bit_identical(fleet_dir, reqs, ladder, score_slots=slots)
