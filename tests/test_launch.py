"""Launch layer: sharded train/decode steps + microbatch equivalence,
run in a subprocess with virtual devices (1-device policy here)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.timeout(900)
def test_launch_distributed_checks():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable,
         os.path.join(REPO, "tests", "helpers", "launch_checks.py")],
        capture_output=True, text=True, env=env, timeout=900)
    assert proc.returncode == 0, proc.stdout[-3000:] + "\n" + proc.stderr[-3000:]
    assert "ALL_OK" in proc.stdout
