"""BrainEncoder auto-dispatch parity on a multi-device mesh, run in a
subprocess so the virtual-device XLA flag never leaks into this test process
(per the single-device policy for smoke tests)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.timeout(600)
def test_encoder_distributed_checks():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", "helpers",
                                      "encoder_checks.py")],
        capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "ALL_OK" in proc.stdout
