"""Banded ridge (feature-space selection; paper ref [13])."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import banded, ridge
from repro.core.banded import BandedConfig


def test_equal_bands_reduce_to_plain_ridge():
    """All bands at the same λ == standard ridge at that λ."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    X = jax.random.normal(k1, (80, 24), jnp.float32)
    Y = jax.random.normal(k2, (80, 6), jnp.float32)
    lam = 7.0
    W_banded = banded.solve_banded(X, Y, jnp.asarray([lam, lam]),
                                   bands=(12, 12), jitter=0.0)
    f = ridge.factorize(X, ridge.RidgeCVConfig(method="eigh", jitter=0.0))
    W_plain = ridge.solve(f, ridge.gram_xty(X, Y), jnp.float32(lam))
    np.testing.assert_allclose(np.asarray(W_banded), np.asarray(W_plain),
                               rtol=2e-3, atol=2e-3)


def test_banded_matches_closed_form_tikhonov():
    """Against float64 numpy (XᵀX + diag(λ_f))⁻¹XᵀY."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    X = np.asarray(jax.random.normal(k1, (60, 10)), np.float64)
    Y = np.asarray(jax.random.normal(k2, (60, 3)), np.float64)
    lam_f = np.array([0.5] * 4 + [50.0] * 6)
    W_ref = np.linalg.solve(X.T @ X + np.diag(lam_f), X.T @ Y)
    W = banded.solve_banded(jnp.asarray(X, jnp.float32),
                            jnp.asarray(Y, jnp.float32),
                            jnp.asarray([0.5, 50.0]), bands=(4, 6),
                            jitter=0.0)
    np.testing.assert_allclose(np.asarray(W), W_ref, rtol=2e-3, atol=2e-3)


def test_banded_cv_selects_informative_band():
    """Band 1 carries the signal, band 2 is pure noise → the selected λ must
    shrink band 2 (feature-space selection, the point of ref [13])."""
    key = jax.random.PRNGKey(2)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    n, p1, p2, t = 300, 16, 16, 8
    X1 = jax.random.normal(k1, (n, p1), jnp.float32)
    X2 = jax.random.normal(k2, (n, p2), jnp.float32)
    W1 = jax.random.normal(k3, (p1, t), jnp.float32) / np.sqrt(p1)
    Y = X1 @ W1 + 0.1 * jax.random.normal(k4, (n, t))
    X = jnp.concatenate([X1, X2], axis=1)
    cfg = BandedConfig(bands=(p1, p2), n_candidates=24, n_folds=3)
    res = banded.banded_ridge_cv(jax.random.PRNGKey(3), X, Y, cfg)
    lam1, lam2 = float(res.band_lambdas[0]), float(res.band_lambdas[1])
    assert lam2 > lam1, (lam1, lam2)           # noise band shrunk harder
    # Predictions beat plain shared-λ ridge on held-out-ish training fit.
    W_noise_norm = float(jnp.linalg.norm(res.weights[p1:]))
    W_sig_norm = float(jnp.linalg.norm(res.weights[:p1]))
    assert W_sig_norm > 3 * W_noise_norm
