"""Synthetic fleet + request-traffic generators (``serving_encoders.traffic``).

These feed both ``launch/serve.py --encoders`` and
``benchmarks/serving_bench.py``; the contracts locked down here are the
ones the drivers rely on — seeded determinism (two drivers with the same
seed replay the same traffic), the documented ragged row-size envelope,
and fit-once bundle reuse."""
import os

import numpy as np
import pytest

from repro.serving_encoders.bundle import EncoderBundle
from repro.serving_encoders.traffic import build_synthetic_fleet, \
    ragged_requests


# ---------------------------------------------------------------------------
# ragged_requests
# ---------------------------------------------------------------------------

def test_ragged_requests_seed_deterministic():
    models = ["sub-01", "sub-02", "sub-03"]
    a = ragged_requests(np.random.default_rng(7), models, p=6, wave_rows=16,
                        count=25)
    b = ragged_requests(np.random.default_rng(7), models, p=6, wave_rows=16,
                        count=25)
    assert [r.model for r in a] == [r.model for r in b]
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(ra.features, rb.features)
    c = ragged_requests(np.random.default_rng(8), models, p=6, wave_rows=16,
                        count=25)
    assert ([r.features.shape for r in a] != [r.features.shape for r in c]
            or any((ra.features != rc.features).any()
                   for ra, rc in zip(a, c)))


def test_ragged_requests_envelope():
    """Row counts are ragged within [8, 2·wave_rows), features are f32
    with the fleet's p, and models come from the given list."""
    models = ["m0", "m1"]
    reqs = ragged_requests(np.random.default_rng(0), models, p=4,
                           wave_rows=16, count=200)
    assert len(reqs) == 200
    rows = {r.features.shape[0] for r in reqs}
    assert all(8 <= n < 32 for n in rows)
    assert len(rows) > 1                       # actually ragged
    assert {r.model for r in reqs} == set(models)
    for r in reqs:
        assert r.features.dtype == np.float32
        assert r.features.shape[1] == 4


def test_ragged_requests_tiny_wave_guard():
    """wave_rows <= 4 would make hi <= lo; the guard pins hi to 9."""
    reqs = ragged_requests(np.random.default_rng(1), ["m"], p=2,
                           wave_rows=4, count=50)
    assert all(r.features.shape[0] == 8 for r in reqs)


# ---------------------------------------------------------------------------
# build_synthetic_fleet
# ---------------------------------------------------------------------------

def test_build_synthetic_fleet_reuses_bundles(tmp_path, capsys):
    fleet = build_synthetic_fleet(str(tmp_path), 2, n=48, p=6, t=5)
    assert [name for name, _ in fleet] == ["sub-01", "sub-02"]
    mtimes = {}
    for name, path in fleet:
        b = EncoderBundle.open(path)
        assert b.shape == (6, 5)
        assert b.manifest["provenance"]["subject"] == name
        mtimes[name] = os.stat(os.path.join(str(b.root),
                                            "bundle.json")).st_mtime_ns
    capsys.readouterr()
    # Second call must reuse, not refit: same files, "reusing" messages.
    again = build_synthetic_fleet(str(tmp_path), 2, n=48, p=6, t=5)
    assert again == fleet
    out = capsys.readouterr().out
    assert out.count("reusing bundle") == 2 and "fitted" not in out
    for name, path in again:
        b = EncoderBundle.open(path)
        assert os.stat(os.path.join(str(b.root),
                                    "bundle.json")).st_mtime_ns == mtimes[name]
    # Growing the fleet refits only the new member.
    grown = build_synthetic_fleet(str(tmp_path), 3, n=48, p=6, t=5)
    assert grown[:2] == fleet and grown[2][0] == "sub-03"
    out = capsys.readouterr().out
    assert out.count("reusing bundle") == 2 and out.count("fitted") == 1


def test_build_synthetic_fleet_shape_mismatch(tmp_path):
    build_synthetic_fleet(str(tmp_path), 1, n=48, p=6, t=5)
    with pytest.raises(ValueError, match=r"\(p, t\)=\(6, 5\)"):
        build_synthetic_fleet(str(tmp_path), 1, n=48, p=6, t=7)
