"""Fault injection against the serving fleet: graceful degradation.

Every scenario corrupts ONE model's on-disk bundle *after* ``open()``
validated it (the window real fleets live in: a deploy truncates a shard,
a disk flips bits, an operator rewrites a manifest mid-serve) and then
drives a mixed multi-tenant batch through ``EncoderService.serve``.  The
contract under test:

* the fault surfaces as a TYPED error (``BundleError``/``RegistryError``)
  on each affected request's ``PredictResult.error`` — never a crash, a
  stall, or a silently wrong answer;
* the faulty bundle is evicted (no poisoned resident entry);
* every OTHER tenant in the same batch is served bit-normally, and the
  fleet keeps serving on the next batch.
"""
import json
import os

import numpy as np
import pytest

from repro.encoding import BrainEncoder
from repro.serving_encoders import (
    BundleError, EncoderBundle, EncoderRegistry, EncoderService,
    PredictRequest, RegistryError,
)

P, T = 10, 6


def _save_fleet(root, k=3):
    import jax
    import jax.numpy as jnp

    paths = []
    for i in range(k):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(i), 3)
        X = jax.random.normal(k1, (80, P), jnp.float32)
        W = jax.random.normal(k2, (P, T), jnp.float32)
        Y = X @ W + 0.1 * jax.random.normal(k3, (80, T), jnp.float32)
        path = str(root / f"m{i}")
        BrainEncoder(n_folds=3).fit(X, Y).save(path)
        paths.append(path)
    return paths


def _weight_shard_file(path):
    bundle = EncoderBundle.open(path)
    leaf = bundle._leaves()["W/000"]
    return os.path.join(path, "step_0", leaf["file"])


def _requests(rng, models):
    reqs = []
    for i, m in enumerate(models):
        rows = int(rng.integers(3, 40))
        X = rng.standard_normal((rows, P)).astype(np.float32)
        Y = (rng.standard_normal((rows, T)).astype(np.float32)
             if i % 2 else None)
        reqs.append(PredictRequest(model=m, features=X, targets=Y,
                                   tenant=f"tenant-{i}"))
    return reqs


@pytest.fixture
def fleet(tmp_path):
    paths = _save_fleet(tmp_path)
    reg = EncoderRegistry()
    names = []
    for i, path in enumerate(paths):
        name = f"m{i}"
        reg.add(name, path)            # open() validates NOW — the fault
        names.append(name)             # lands after this point
    return reg, names, paths


def _serve_and_partition(svc, reqs, bad_model):
    results = svc.serve(reqs)
    bad = [r for q, r in zip(reqs, results) if q.model == bad_model]
    good = [r for q, r in zip(reqs, results) if q.model != bad_model]
    assert bad and good
    return bad, good


def _assert_degraded_single_tenant(svc, reg, reqs, bad_model):
    bad, good = _serve_and_partition(svc, reqs, bad_model)
    for r in bad:
        assert isinstance(r.error, (BundleError, RegistryError)), \
            f"expected a typed fault, got {type(r.error)}: {r.error}"
        assert r.predictions is None and r.pearson_r is None
    for r in good:                        # the fleet keeps serving
        assert r.error is None
        assert r.predictions is not None and np.isfinite(
            r.predictions).all()
    assert bad_model not in reg.loaded_names   # evicted, not poisoned
    # Per-tenant accounting charges the fault to the affected tenants.
    errors = {t: a["errors"] for t, a in svc.stats.per_tenant.items()}
    for q in reqs:
        want = 1 if q.model == bad_model else 0
        assert errors.get(q.tenant_id, 0) == want
    # The NEXT batch (healthy tenants only) serves normally.
    rng = np.random.default_rng(99)
    healthy = [m for m in reg.names if m != bad_model]
    again = svc.serve(_requests(rng, healthy))
    assert all(r.error is None for r in again)


def test_truncated_weight_shard_degrades_one_tenant(fleet):
    reg, names, paths = fleet
    shard = _weight_shard_file(paths[1])
    with open(shard, "r+b") as f:          # drop half the payload
        f.truncate(os.path.getsize(shard) // 2)
    svc = EncoderService(reg, wave_buckets=(8, 32))
    rng = np.random.default_rng(0)
    _assert_degraded_single_tenant(svc, reg, _requests(rng, names), "m1")


def test_corrupted_weight_shard_header_degrades_one_tenant(fleet):
    reg, names, paths = fleet
    shard = _weight_shard_file(paths[0])
    with open(shard, "r+b") as f:          # stomp the .npy magic
        f.write(b"\x00" * 8)
    svc = EncoderService(reg, wave_buckets=(8, 32))
    rng = np.random.default_rng(1)
    _assert_degraded_single_tenant(svc, reg, _requests(rng, names), "m0")


def test_manifest_flip_between_open_and_first_serve(fleet):
    # The checkpoint manifest is read lazily at FIRST materialisation —
    # flipping its bytes after open() must surface there, typed.
    reg, names, paths = fleet
    manifest = os.path.join(paths[2], "step_0", "manifest.json")
    raw = bytearray(open(manifest, "rb").read())
    raw[: len(b"garbage!")] = b"garbage!"
    with open(manifest, "wb") as f:
        f.write(raw)
    svc = EncoderService(reg, wave_buckets=(8, 32))
    rng = np.random.default_rng(2)
    _assert_degraded_single_tenant(svc, reg, _requests(rng, names), "m2")


def test_deleted_shard_degrades_one_tenant(fleet):
    reg, names, paths = fleet
    os.unlink(_weight_shard_file(paths[1]))
    svc = EncoderService(reg, wave_buckets=(8, 32))
    rng = np.random.default_rng(3)
    _assert_degraded_single_tenant(svc, reg, _requests(rng, names), "m1")


def test_fault_then_repair_serves_again(fleet):
    # Eviction on fault means a REPAIRED bundle (bytes restored) serves
    # on the next get — no stale poisoned entry, no stale μ/σ cache.
    reg, names, paths = fleet
    shard = _weight_shard_file(paths[0])
    original = open(shard, "rb").read()
    with open(shard, "r+b") as f:
        f.truncate(10)
    svc = EncoderService(reg, wave_rows=16)
    rng = np.random.default_rng(4)
    reqs = _requests(rng, names)
    bad, _ = _serve_and_partition(svc, reqs, "m0")
    assert all(isinstance(r.error, BundleError) for r in bad)
    with open(shard, "wb") as f:
        f.write(original)
    again = svc.serve(reqs)
    assert all(r.error is None for r in again)


def test_fault_during_scored_request_is_typed(fleet):
    # A scored request against the faulty model gets the SAME typed
    # degradation — the Pearson path must not turn a load fault into a
    # crash or a bogus r.
    reg, names, paths = fleet
    with open(_weight_shard_file(paths[1]), "r+b") as f:
        f.truncate(4)
    svc = EncoderService(reg, wave_rows=16)
    rng = np.random.default_rng(5)
    X = rng.standard_normal((12, P)).astype(np.float32)
    Y = rng.standard_normal((12, T)).astype(np.float32)
    out = svc.serve([PredictRequest("m1", X, targets=Y, tenant="a"),
                     PredictRequest("m0", X, targets=Y, tenant="b")])
    assert isinstance(out[0].error, BundleError)
    assert out[0].pearson_r is None
    assert out[1].error is None and out[1].pearson_r is not None


def test_malformed_request_still_refuses_batch(fleet):
    # Request-shape validation is NOT degradation territory: a malformed
    # request refuses the whole batch up front (pass 1) before any device
    # work, exactly as before the fleet tier.
    from repro.serving_encoders import ServiceError

    reg, names, _ = fleet
    svc = EncoderService(reg, wave_rows=16)
    good = PredictRequest("m0", np.zeros((4, P), np.float32))
    bad = PredictRequest("m1", np.zeros((4, P + 1), np.float32))
    with pytest.raises(ServiceError, match="incompatible"):
        svc.serve([good, bad])
