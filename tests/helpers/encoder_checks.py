"""Multi-device BrainEncoder checks, run in a subprocess with 8 virtual
devices: solver="auto" must reproduce the hand-picked solver's weights on
primal, dual, and multi-device-sharded synthetic problems (ISSUE acceptance
criterion), and ShardingPlan must own rounding/padding correctly.

Usage: XLA_FLAGS=--xla_force_host_platform_device_count=8 python encoder_checks.py
Prints "ALL_OK" on success.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import bmor, ridge
from repro.encoding import BrainEncoder, EncoderConfig, ShardingPlan, resolve


def make_problem(key, n, p, t, noise=0.01):
    k1, k2, k3 = jax.random.split(key, 3)
    X = jax.random.normal(k1, (n, p), jnp.float32)
    W = jax.random.normal(k2, (p, t), jnp.float32) / np.sqrt(p)
    Y = X @ W + noise * jax.random.normal(k3, (n, t), jnp.float32)
    return X, Y


def check_auto_matches_bmor_primal():
    """auto → B-MOR; weights equal a direct bmor_fit at the same layout."""
    assert jax.device_count() == 8, jax.device_count()
    X, Y = make_problem(jax.random.PRNGKey(0), 128, 16, 64)
    enc = BrainEncoder(n_folds=4).fit(X, Y)
    d = enc.report_.decision
    assert d.solver == "bmor", d
    plan = ShardingPlan(data_shards=d.data_shards,
                        target_shards=d.target_shards)
    mesh = plan.build_mesh()
    Xs, Ys = plan.place(mesh, X, Y)
    ref = bmor.bmor_fit(Xs, Ys, mesh, cfg=enc.config.ridge_cv_config("eigh"))
    np.testing.assert_allclose(np.asarray(enc.weights_),
                               np.asarray(ref.weights), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(enc.report_.best_lambda,
                               np.asarray(ref.best_lambda), rtol=0)
    # ...and both agree with the single-device mutualised reference.
    single = ridge.ridge_cv(X, Y, enc.config.ridge_cv_config("eigh"))
    np.testing.assert_allclose(np.asarray(enc.weights_),
                               np.asarray(single.weights), rtol=2e-3,
                               atol=2e-3)
    print("auto_matches_bmor_primal OK")


def check_auto_matches_dual():
    """n < p, 8 devices → auto picks dual B-MOR; per-batch weights match the
    single-device dual solve at each batch's λ."""
    X, Y = make_problem(jax.random.PRNGKey(1), 40, 96, 16)
    enc = BrainEncoder(n_folds=4).fit(X, Y)
    d = enc.report_.decision
    assert d.solver == "bmor_dual", d
    lams = enc.report_.best_lambda
    t_shard = Y.shape[1] // lams.shape[0]
    f = ridge.factorize(X, enc.config.ridge_cv_config("dual"))
    for i, lam in enumerate(lams):
        cols = slice(i * t_shard, (i + 1) * t_shard)
        W_ref = ridge.solve(f, Y[:, cols], jnp.float32(lam), X=X)
        np.testing.assert_allclose(np.asarray(enc.weights_)[:, cols],
                                   np.asarray(W_ref), rtol=3e-3, atol=3e-3)
    print("auto_matches_dual OK")


def check_explicit_layout_and_padding():
    """Pinned 2x4 layout on t=30 targets (not divisible by 4): ShardingPlan
    pads, the report is sliced back, and weights match the reference."""
    X, Y = make_problem(jax.random.PRNGKey(2), 96, 12, 30)
    enc = BrainEncoder(solver="bmor", data_shards=2, target_shards=4,
                       n_folds=3).fit(X, Y)
    assert enc.weights_.shape == (12, 30), enc.weights_.shape
    ref = ridge.ridge_cv(X, Y, enc.config.ridge_cv_config("eigh"))
    np.testing.assert_allclose(np.asarray(enc.weights_),
                               np.asarray(ref.weights), rtol=2e-3, atol=2e-3)
    print("explicit_layout_and_padding OK")


def check_row_rounding():
    """n=101 rows on 4 data shards → plan keeps 100; fit must not crash and
    must match the reference on the kept rows."""
    X, Y = make_problem(jax.random.PRNGKey(3), 101, 8, 16)
    enc = BrainEncoder(solver="bmor", data_shards=4, target_shards=2,
                       n_folds=3).fit(X, Y)
    ref = ridge.ridge_cv(X[:100], Y[:100],
                         enc.config.ridge_cv_config("eigh"))
    np.testing.assert_allclose(np.asarray(enc.weights_),
                               np.asarray(ref.weights), rtol=2e-3, atol=2e-3)
    print("row_rounding OK")


def check_store_streamed_parity():
    """Store-backed out-of-core fit on the 8-device mesh: the sharded
    streamed accumulation (8 row windows, single psum of the stacked
    (k, p, p+t) partials at finalize) selects the bit-identical λ and
    near-identical weights vs the in-memory fit — f32 with un-standardized
    (offset) targets, and bf16 inputs."""
    import tempfile

    from repro.data.store import RunStore

    assert jax.device_count() == 8, jax.device_count()
    for dtype, y_offset, tol in ((jnp.float32, 3.0, 1e-4),
                                 (jnp.bfloat16, 0.0, 5e-2)):
        X, Y = make_problem(jax.random.PRNGKey(4), 409, 16, 8, noise=0.3)
        X = X.astype(dtype)
        Y = (Y + y_offset).astype(dtype)
        root = tempfile.mkdtemp(prefix="encoder_store_")
        store = RunStore.create(root, n_folds=5, dtype=np.dtype(dtype))
        store.write(np.asarray(X[:250]), np.asarray(Y[:250]), "r1")
        store.write(np.asarray(X[250:]), np.asarray(Y[250:]), "r2")
        store = RunStore.open(root)
        ref = BrainEncoder(n_folds=5, solver="ridge", method="eigh"
                           ).fit(X, Y)
        enc = BrainEncoder(n_folds=5, device_memory_budget=1,
                           chunk_rows=37).fit(store=store)
        d = enc.report_.decision
        assert (d.method, d.data_shards) == ("chunked", 8), d
        assert enc.report_.best_lambda[0] == ref.report_.best_lambda[0], (
            dtype, enc.report_.best_lambda, ref.report_.best_lambda)
        np.testing.assert_allclose(np.asarray(enc.weights_),
                                   np.asarray(ref.weights_), rtol=tol,
                                   atol=tol)
    print("store_streamed_parity OK")


def check_bundle_predict_parity():
    """ISSUE acceptance criterion: ``load(save(fit(...))).predict(X)`` is
    bit-identical to the in-memory encoder — f32 and bf16 weight storage,
    single-device (replicated) and 8-device column-sharded loads."""
    import tempfile

    from repro.serving_encoders import EncoderBundle

    assert jax.device_count() == 8, jax.device_count()
    X, Y = make_problem(jax.random.PRNGKey(5), 256, 24, 64)
    enc = BrainEncoder(n_folds=4, solver="ridge", method="eigh").fit(X, Y)
    X_new = jax.random.normal(jax.random.PRNGKey(6), (96, 24), jnp.float32)

    # f32 storage: parity vs the fitted weights.
    root = tempfile.mkdtemp(prefix="bundle_f32_") + "/b"
    enc.save(root, weight_shards=8)
    ref = np.asarray(enc.predict(X_new))
    for shards in (None, 8):
        enc2 = BrainEncoder.load(root, target_shards=shards)
        got = np.asarray(enc2.predict(X_new))
        assert np.array_equal(ref, got), (
            "f32", shards, np.abs(ref - got).max())
    enc_sh = BrainEncoder.load(root, target_shards=8)
    assert "model" in str(enc_sh.weights_.sharding.spec), \
        enc_sh.weights_.sharding

    # bf16 storage (u16 bit patterns on disk): parity vs the CAST weights.
    root_bf = tempfile.mkdtemp(prefix="bundle_bf16_") + "/b"
    enc.save(root_bf, weight_dtype="bfloat16", weight_shards=8)
    assert EncoderBundle.open(root_bf).weight_dtype.name == "bfloat16"
    ref_bf = np.asarray(jnp.matmul(X_new,
                                   enc.weights_.astype(jnp.bfloat16),
                                   preferred_element_type=jnp.float32))
    for shards in (None, 8):
        enc2 = BrainEncoder.load(root_bf, target_shards=shards)
        assert enc2.weights_.dtype == jnp.bfloat16
        got = np.asarray(enc2.predict(X_new))
        assert np.array_equal(ref_bf, got), (
            "bf16", shards, np.abs(ref_bf - got).max())

    # λ / CV provenance survives the round trip exactly.
    enc3 = BrainEncoder.load(root)
    assert enc3.report_.best_lambda == enc.report_.best_lambda
    np.testing.assert_array_equal(enc3.report_.cv_scores,
                                  enc.report_.cv_scores)
    print("bundle_predict_parity OK")


def check_dispatch_cost_sanity():
    """The §3 model ranks the auto layout no worse than every alternative
    divisor layout it rejected (on the modelled cost)."""
    from repro.core import complexity
    cfg = EncoderConfig()
    n, p, t = 4096, 64, 2048
    d = resolve(cfg, n, p, t, 8)
    w = complexity.RidgeWorkload(n=n, p=p, t=t, r=len(cfg.lambdas))
    for c_d in (1, 2, 4, 8):
        alt = complexity.t_bmor_sharded(w, c_d, 8 // c_d)
        assert d.predicted_cost <= alt + 1e-9, (c_d, alt, d)
    print("dispatch_cost_sanity OK")


if __name__ == "__main__":
    check_auto_matches_bmor_primal()
    check_auto_matches_dual()
    check_explicit_layout_and_padding()
    check_row_rounding()
    check_store_streamed_parity()
    check_bundle_predict_parity()
    check_dispatch_cost_sanity()
    print("ALL_OK")
