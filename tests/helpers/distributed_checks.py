"""Distributed correctness checks for B-MOR / MOR, run in a subprocess with
virtual host devices (so the main pytest process keeps 1 CPU device).

Usage: XLA_FLAGS=--xla_force_host_platform_device_count=8 python distributed_checks.py
Prints "ALL_OK" on success.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import bmor, mor, ridge
from repro.core.ridge import RidgeCVConfig


def make_problem(key, n, p, t, noise=0.01):
    k1, k2, k3 = jax.random.split(key, 3)
    X = jax.random.normal(k1, (n, p), jnp.float32)
    W = jax.random.normal(k2, (p, t), jnp.float32) / np.sqrt(p)
    Y = X @ W + noise * jax.random.normal(k3, (n, t), jnp.float32)
    return X, Y, W


def check_bmor_matches_single_device():
    assert jax.device_count() == 8, jax.device_count()
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    n, p, t = 64, 16, 32
    X, Y, _ = make_problem(jax.random.PRNGKey(0), n, p, t)
    cfg = RidgeCVConfig(n_folds=4)

    Xs = jax.device_put(X, NamedSharding(mesh, P("data", None)))
    Ys = jax.device_put(Y, NamedSharding(mesh, P("data", "model")))
    res = bmor.bmor_fit(Xs, Ys, mesh, cfg=cfg)

    ref = ridge.ridge_cv(X, Y, cfg)
    # Low-noise problem → every shard picks the same (smallest) λ as the
    # single-device reference, so weights must agree to float tolerance.
    np.testing.assert_allclose(np.asarray(res.best_lambda),
                               float(ref.best_lambda) * np.ones(4), rtol=0)
    np.testing.assert_allclose(np.asarray(res.weights),
                               np.asarray(ref.weights), rtol=2e-3, atol=2e-3)
    print("bmor_matches_single_device OK")


def check_bmor_multipod_axes():
    """B-MOR with the row shards split over two mesh axes (pod, data)."""
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    n, p, t = 48, 8, 16
    X, Y, _ = make_problem(jax.random.PRNGKey(1), n, p, t)
    cfg = RidgeCVConfig(n_folds=3)
    Xs = jax.device_put(X, NamedSharding(mesh, P(("pod", "data"), None)))
    Ys = jax.device_put(Y, NamedSharding(mesh, P(("pod", "data"), "model")))
    res = bmor.bmor_fit(Xs, Ys, mesh, data_axis=("pod", "data"), cfg=cfg)
    ref = ridge.ridge_cv(X, Y, cfg)
    np.testing.assert_allclose(np.asarray(res.weights),
                               np.asarray(ref.weights), rtol=2e-3, atol=2e-3)
    print("bmor_multipod_axes OK")


def check_mor_distributed_matches_mor():
    mesh = jax.make_mesh((1, 8), ("data", "model"))
    n, p, t = 40, 8, 16
    X, Y, _ = make_problem(jax.random.PRNGKey(2), n, p, t)
    cfg = RidgeCVConfig(n_folds=4, lambdas=(0.1, 1.0, 100.0))
    W_dist = mor.mor_fit_distributed(X, Y, mesh, cfg=cfg)
    W_ref = mor.mor_fit(X, Y, cfg)
    np.testing.assert_allclose(np.asarray(W_dist), np.asarray(W_ref),
                               rtol=1e-4, atol=1e-4)
    print("mor_distributed OK")


def check_bmor_perbatch_lambda():
    """Targets with very different SNR in different batches → per-batch λ can
    differ (Algorithm 1 line 13 semantics)."""
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    n, p = 60, 12
    key = jax.random.PRNGKey(3)
    k1, k2, k3 = jax.random.split(key, 3)
    X = jax.random.normal(k1, (n, p), jnp.float32)
    W = jax.random.normal(k2, (p, 16), jnp.float32)
    Y_clean = X @ W[:, :8] + 0.001 * jax.random.normal(k3, (n, 8))
    Y_noisy = 5.0 * jax.random.normal(k3, (n, 8))  # pure noise targets
    Y = jnp.concatenate([Y_clean, Y_noisy], axis=1)
    Xs = jax.device_put(X, NamedSharding(mesh, P("data", None)))
    Ys = jax.device_put(Y, NamedSharding(mesh, P("data", "model")))
    res = bmor.bmor_fit(Xs, Ys, mesh, cfg=RidgeCVConfig(n_folds=3))
    lams = np.asarray(res.best_lambda)
    assert lams[0] <= 1.0, lams          # clean batch: tiny λ
    assert lams[1] >= 100.0, lams        # noise batch: heavy shrinkage
    print("bmor_perbatch_lambda OK")


def check_bmor_dual_matches_single_device():
    """Dual-form B-MOR (n < p) vs the single-device dual RidgeCV."""
    mesh = jax.make_mesh((1, 4), ("data", "model"))
    n, p, t = 40, 96, 16                       # n < p → dual regime
    X, Y, _ = make_problem(jax.random.PRNGKey(9), n, p, t, noise=0.01)
    cfg = RidgeCVConfig(n_folds=4, method="dual")
    Ys = jax.device_put(Y, jax.sharding.NamedSharding(
        mesh, P(None, "model")))
    res = bmor.bmor_fit_dual(X, Ys, mesh, cfg=cfg)
    # Per-batch λ may differ between shards (Alg. 1 semantics); validate each
    # shard's weights against the single-device dual solve AT ITS OWN λ.
    lams = np.asarray(res.best_lambda)
    t_shard = Y.shape[1] // lams.shape[0]
    f = ridge.factorize(X, cfg)
    for s_i, lam in enumerate(lams):
        cols = slice(s_i * t_shard, (s_i + 1) * t_shard)
        W_ref = ridge.solve(f, Y[:, cols], jnp.float32(lam), X=X)
        np.testing.assert_allclose(
            np.asarray(res.weights)[:, cols], np.asarray(W_ref),
            rtol=3e-3, atol=3e-3)
    assert all(any(np.isclose(l, g, rtol=1e-5) for g in cfg.lambdas)
               for l in lams.tolist())
    print("bmor_dual OK")


if __name__ == "__main__":
    check_bmor_matches_single_device()
    check_bmor_multipod_axes()
    check_mor_distributed_matches_mor()
    check_bmor_perbatch_lambda()
    check_bmor_dual_matches_single_device()
    print("ALL_OK")
