"""Launch-layer distributed checks (subprocess, 8 virtual devices):
sharded train_step runs and reduces loss; decode step preserves shardings;
mini dry-run lowers representative combos; microbatching is numerically
equivalent to full-batch."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.configs import smoke
from repro.data.synthetic import make_batch
from repro.launch.steps import (build_decode_step, build_step,
                                build_train_step)
from repro.models import build_model
from repro.models.config import InputShape
from repro.optim import AdamWConfig, adamw_init


def _mesh(data=4, model=2):
    from repro.core.compat import auto_axis_types, make_mesh
    return make_mesh((data, model), ("data", "model"),
                     axis_types=auto_axis_types(2))


def check_sharded_train_step_runs():
    cfg = smoke(configs.get_config("gemma2-2b"))
    mesh = _mesh()
    shape = InputShape("t", 16, 8, "train")
    bundle = build_train_step(cfg, mesh, shape, opt=AdamWConfig(lr=1e-2))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = adamw_init(params)
    batch = make_batch(jax.random.PRNGKey(1), cfg, 8, 16)
    with mesh:
        fn = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                     out_shardings=bundle.out_shardings,
                     donate_argnums=bundle.donate_argnums)
        losses = []
        for step in range(8):
            params, opt_state, metrics = fn(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses
    assert all(np.isfinite(losses))
    print("sharded_train_step OK", losses[0], "->", losses[-1])


def check_microbatch_equivalence():
    """Grad accumulation (M=4) must match full-batch to float tolerance."""
    cfg = smoke(configs.get_config("qwen3-1.7b"))
    mesh = _mesh(data=2, model=2)
    shape = InputShape("t", 16, 8, "train")
    model = build_model(cfg)
    params0 = model.init(jax.random.PRNGKey(0))
    batch = make_batch(jax.random.PRNGKey(1), cfg, 8, 16)
    outs = {}
    for M in (1, 4):
        bundle = build_train_step(cfg, mesh, shape, microbatch=M,
                                  opt=AdamWConfig(lr=1e-2))
        opt_state = adamw_init(params0)
        with mesh:
            fn = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                         out_shardings=bundle.out_shardings)
            p1, _, m = fn(params0, opt_state, batch)
        outs[M] = (jax.device_get(m["loss"]),
                   jax.device_get(p1["final_norm"]["scale"]))
    # Mean-of-microbatch losses == full-batch loss (same per-token weights).
    np.testing.assert_allclose(outs[1][0], outs[4][0], rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(outs[1][1], outs[4][1], rtol=3e-3, atol=3e-3)
    print("microbatch_equivalence OK")


def check_decode_step_sharded():
    cfg = smoke(configs.get_config("zamba2-2.7b"))
    mesh = _mesh()
    shape = InputShape("d", 32, 8, "decode")
    bundle = build_decode_step(cfg, mesh, shape)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(8, 32)
    tok = jnp.zeros((8, 1), jnp.int32)
    with mesh:
        fn = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                     out_shardings=bundle.out_shardings,
                     donate_argnums=bundle.donate_argnums)
        logits, cache2 = fn(params, cache, tok, jnp.int32(0))
        logits2, _ = fn(params, cache2, tok, jnp.int32(1))
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))
    print("decode_step_sharded OK")


def check_seq_sharded_decode_batch1():
    """long_500k-style: batch=1 → cache seq dim sharded over data."""
    cfg = smoke(configs.get_config("qwen3-1.7b")).with_sliding_windows(32)
    mesh = _mesh(data=4, model=2)
    shape = InputShape("long", 128, 1, "decode")
    bundle = build_step(cfg, mesh, InputShape("long_500k", 128, 1, "decode"))
    # cache k sharding must put data axis on the seq dim (dim 2 of stacked).
    k_sh = bundle.in_shardings[1]["blocks"]["b0"]["attn"]["k"] \
        if "blocks" in bundle.in_shardings[1] else None
    model = build_model(cfg.with_sliding_windows(32))
    with mesh:
        fn = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                     out_shardings=bundle.out_shardings,
                     donate_argnums=bundle.donate_argnums)
        lowered = fn.lower(*bundle.abstract_inputs)
        lowered.compile()
    print("seq_sharded_decode_batch1 OK (lower+compile)")


if __name__ == "__main__":
    check_sharded_train_step_runs()
    check_microbatch_equivalence()
    check_decode_step_sharded()
    check_seq_sharded_decode_batch1()
    print("ALL_OK")
