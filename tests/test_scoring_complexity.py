"""Tests for scoring metrics and the paper's §3 complexity model."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import complexity, ridge, scoring
from repro.core.complexity import RidgeWorkload


def test_pearson_r_matches_numpy():
    rng = np.random.default_rng(0)
    Yt = rng.normal(size=(50, 7)).astype(np.float32)
    Yp = rng.normal(size=(50, 7)).astype(np.float32)
    r = np.asarray(scoring.pearson_r(jnp.asarray(Yt), jnp.asarray(Yp)))
    ref = np.array([np.corrcoef(Yt[:, i], Yp[:, i])[0, 1] for i in range(7)])
    np.testing.assert_allclose(r, ref, rtol=1e-4, atol=1e-5)


def test_perfect_prediction_scores_one():
    Y = jnp.asarray(np.random.default_rng(1).normal(size=(30, 3)),
                    dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(scoring.pearson_r(Y, Y)), 1.0,
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(scoring.r2_score(Y, Y)), 1.0,
                               atol=1e-5)


def test_null_permutation_collapses_scores():
    """Paper §4.2: shuffled features → encoding accuracy collapses."""
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    n, p, t = 400, 16, 8
    X = jax.random.normal(k1, (n, p), jnp.float32)
    W = jax.random.normal(k2, (p, t), jnp.float32)
    Y = X @ W + 0.1 * jax.random.normal(k3, (n, t))
    res = ridge.ridge_cv(X, Y)
    aligned = scoring.pearson_r(Y, ridge.predict(X, res.weights))
    null = scoring.null_permutation_scores(k3, X, Y, res.weights, n_perms=5)
    assert float(jnp.mean(aligned)) > 0.9
    assert float(jnp.max(jnp.abs(null))) < 0.3
    assert float(jnp.mean(jnp.abs(null))) < 0.1


def test_split_indices_partition():
    tr, te = scoring.train_test_split_indices(jax.random.PRNGKey(0), 100, 0.1)
    assert te.shape[0] == 10 and tr.shape[0] == 90
    assert len(set(np.asarray(tr)) | set(np.asarray(te))) == 100


# ---------------------------------------------------------------------------
# Paper §3 complexity model
# ---------------------------------------------------------------------------

def test_bmor_beats_mor_by_tm_overhead():
    """T_MOR − T_B-MOR = (t/c − 1)·T_M (paper §3.3)."""
    w = RidgeWorkload(n=1000, p=64, t=512, r=11)
    for c in (2, 8, 32):
        gap = complexity.t_mor(w, c) - complexity.t_bmor(w, c)
        expected = (w.t / c - 1.0) * complexity.t_m(w)
        np.testing.assert_allclose(gap, expected, rtol=1e-12)


def test_bmor_faster_than_single_thread_when_c_gt_1():
    w = complexity.PAPER_WORKLOADS["whole_brain_bmor"]
    assert complexity.t_bmor(w, 8) < complexity.t_ridge_single(w)
    assert complexity.t_bmor(w, 1) >= complexity.t_ridge_single(w) * 0.99


def test_mor_impractical_at_paper_scale():
    """Fig. 8: MOR on 8 nodes ≫ single-node mutualised ridge (~1000s vs ~1s)."""
    w = complexity.PAPER_WORKLOADS["whole_brain_mor"]
    assert complexity.t_mor(w, 8) > 10 * complexity.t_ridge_single(w)


def test_svd_mutualisation_wins():
    w = RidgeWorkload(n=69_202, p=16_384, t=444, r=11)
    assert complexity.t_m(w) < complexity.t_m_naive(w)


def test_speedup_saturates_with_c():
    """DSU plateaus (paper Fig. 10): going 64→512 workers gains < 2x."""
    w = complexity.PAPER_WORKLOADS["whole_brain_bmor"]
    s64 = complexity.predicted_speedup_bmor(w, 64)
    s512 = complexity.predicted_speedup_bmor(w, 512)
    assert s512 / s64 < 2.0
    assert s512 > s64  # but still monotone
