"""Fused SSD within-chunk kernel vs oracle + vs the model's SSD math."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ref, ssd


def _inputs(n, q, h, p, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    cb = jax.random.normal(k1, (n, q, q), jnp.float32) / np.sqrt(q)
    # realistic decays: la is a non-increasing cumsum of negative increments
    la = jnp.cumsum(-jnp.abs(jax.random.normal(k2, (n, q, h))) * 0.05,
                    axis=1)
    x = jax.random.normal(k3, (n, q, h, p), jnp.float32)
    return cb, la, x


@pytest.mark.parametrize("n,q,h,p", [
    (2, 16, 8, 16),
    (3, 32, 16, 32),
    (1, 64, 8, 64),
])
def test_ssd_intra_matches_oracle(n, q, h, p):
    cb, la, x = _inputs(n, q, h, p, seed=n)
    got = ssd.ssd_intra(cb, la, x, head_block=8, interpret=True)
    want = ref.ssd_intra(cb, la, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_ssd_intra_matches_model_y_intra():
    """Kernel reproduces the y_intra term of ssm.mamba_apply for G=1."""
    from repro import configs
    from repro.configs import smoke
    from repro.models import ssm as ssm_lib

    cfg = smoke(configs.get_config("mamba2-130m"))
    s = cfg.ssm
    d_inner, H, Pd, G, N = ssm_lib._dims(cfg)
    assert G == 1
    B_, S = 2, 16
    Q = s.chunk
    nc = S // Q
    key = jax.random.PRNGKey(3)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    Cc = jax.random.normal(k1, (B_, nc, Q, G, N), jnp.float32) / np.sqrt(N)
    Bc = jax.random.normal(k2, (B_, nc, Q, G, N), jnp.float32) / np.sqrt(N)
    xc = jax.random.normal(k3, (B_, nc, Q, H, Pd), jnp.float32)
    la = jnp.cumsum(-jnp.abs(jax.random.normal(k4, (B_, nc, Q, H))) * 0.1,
                    axis=2)

    # model math (ssm.mamba_apply inner block, G=1)
    diff = la[:, :, :, None, :] - la[:, :, None, :, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.exp(jnp.where(mask[None, None, :, :, None], diff, -jnp.inf))
    scores = jnp.einsum("bcqgn,bckgn->bcqkg", Cc, Bc)
    scores = jnp.repeat(scores, H, axis=-1) * decay
    want = jnp.einsum("bcqkh,bckhp->bcqhp", scores, xc)

    # kernel path
    cb = jnp.einsum("bcqgn,bckgn->bcqk", Cc, Bc).reshape(B_ * nc, Q, Q)
    got = ssd.ssd_intra(cb, la.reshape(B_ * nc, Q, H),
                        xc.reshape(B_ * nc, Q, H, Pd),
                        head_block=8, interpret=True)
    np.testing.assert_allclose(np.asarray(got).reshape(B_, nc, Q, H, Pd),
                               np.asarray(want), rtol=2e-4, atol=2e-4)


def test_model_forward_with_ssd_kernel_backend():
    """mamba2 forward with ssm.use_kernel matches the XLA einsum path."""
    import dataclasses
    import jax.numpy as jnp
    from repro import configs
    from repro.configs import smoke
    from repro.models import build_model

    cfg = smoke(configs.get_config("mamba2-130m"))
    cfg_k = dataclasses.replace(
        cfg, ssm=dataclasses.replace(cfg.ssm, use_kernel=True))
    tok = jax.random.randint(jax.random.PRNGKey(0), (1, 16), 0, cfg.vocab,
                             dtype=jnp.int32)
    m0, m1 = build_model(cfg), build_model(cfg_k)
    params = m0.init(jax.random.PRNGKey(1))
    l0, _ = m0.forward(params, {"tokens": tok})
    l1, _ = m1.forward(params, {"tokens": tok})
    np.testing.assert_allclose(np.asarray(l0, np.float32),
                               np.asarray(l1, np.float32), rtol=2e-2,
                               atol=2e-2)
