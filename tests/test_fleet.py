"""The fleet tier: registry thread safety, the shared residency map,
bounded admission, prefetch, and the deterministic traffic trace."""
import json
import os
import threading

import numpy as np
import pytest

from repro.encoding import BrainEncoder
from repro.serving_encoders import (
    EncoderBundle, EncoderRegistry, EncoderService, FleetFrontend,
    FleetRegistry, PredictRequest, ResidencyMap, ServiceError,
    reference_serve,
)
from repro.serving_encoders.fleet import replay
from repro.serving_encoders.registry import bundle_resident_bytes
from repro.serving_encoders.traffic import (
    load_trace, make_mixed_trace, replay_requests, save_trace, trace_digest,
)

P, T = 10, 6


def _save_fleet(root, k):
    import jax
    import jax.numpy as jnp

    paths = []
    for i in range(k):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(i), 3)
        X = jax.random.normal(k1, (80, P), jnp.float32)
        W = jax.random.normal(k2, (P, T), jnp.float32)
        Y = X @ W + 0.1 * jax.random.normal(k3, (80, T), jnp.float32)
        path = str(root / f"m{i}")
        BrainEncoder(n_folds=3).fit(X, Y).save(path)
        paths.append(path)
    return paths


# -- registry thread safety (the LRU bookkeeping fix) ------------------------

def test_registry_8_thread_stress_never_exceeds_budget(tmp_path):
    """8 threads hammer get+evict on 6 models under a budget that fits 2:
    the account must never overshoot (checked continuously AND via the
    lock-maintained high-water mark) and every get must return a usable
    entry."""
    paths = _save_fleet(tmp_path, 6)
    wave = 32
    need = bundle_resident_bytes(EncoderBundle.open(paths[0]), wave)
    budget = int(2.5 * need)               # fits 2, never 3
    reg = EncoderRegistry(device_memory_budget=budget, wave_rows=wave)
    for i, path in enumerate(paths):
        reg.add(f"m{i}", path)

    stop = threading.Event()
    failures = []
    overshoots = []

    def watcher():
        while not stop.is_set():
            r = reg.resident_bytes
            if r > budget:
                overshoots.append(r)

    def hammer(seed):
        rng = np.random.default_rng(seed)
        try:
            for _ in range(40):
                name = f"m{int(rng.integers(6))}"
                if rng.random() < 0.15:
                    reg.evict(name)
                    continue
                entry = reg.get(name, wave_rows=wave)
                assert entry.name == name
                assert entry.weights.shape == (P, T)
        except Exception as e:          # pragma: no cover - failure path
            failures.append(e)

    watch = threading.Thread(target=watcher, daemon=True)
    watch.start()
    threads = [threading.Thread(target=hammer, args=(s,)) for s in range(8)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    stop.set()
    watch.join()
    assert not failures, failures[:3]
    assert not overshoots, f"resident_bytes overshot budget: {overshoots[:5]}"
    assert reg.peak_resident_bytes <= budget
    assert reg.evictions > 0               # the budget actually bit
    assert reg.resident_bytes <= budget


def test_concurrent_serves_share_one_registry(tmp_path):
    """Two services (two threads) over ONE registry serve concurrently
    under a tight budget — results stay bit-identical to serving alone."""
    paths = _save_fleet(tmp_path, 3)
    need = bundle_resident_bytes(EncoderBundle.open(paths[0]), 16, None, 2)
    reg = EncoderRegistry(device_memory_budget=int(2.5 * need),
                          wave_rows=16)
    for i, path in enumerate(paths):
        reg.add(f"m{i}", path)
    rng = np.random.default_rng(0)
    X = rng.standard_normal((20, P)).astype(np.float32)
    Y = rng.standard_normal((20, T)).astype(np.float32)

    ref_reg = EncoderRegistry(wave_rows=16)
    for i, path in enumerate(paths):
        ref_reg.add(f"m{i}", path)
    ref = reference_serve(
        EncoderService(ref_reg, wave_rows=16, score_slots=2),
        [PredictRequest(f"m{i}", X, targets=Y) for i in range(3)])

    outs = [None, None]

    def worker(idx):
        svc = EncoderService(reg, wave_rows=16, score_slots=2)
        for _ in range(5):
            outs[idx] = svc.serve(
                [PredictRequest(f"m{i}", X, targets=Y) for i in range(3)])

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(2)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    for out in outs:
        for got, want in zip(out, ref):
            assert got.error is None
            assert np.array_equal(got.predictions, want.predictions)
            assert np.array_equal(got.pearson_r, want.pearson_r)


# -- residency map -----------------------------------------------------------

def test_residency_map_publish_snapshot_retire(tmp_path):
    rmap = ResidencyMap(str(tmp_path / "residency.json"))
    rmap.publish("w0", {"m0": 100, "m1": 50}, loads=2)
    rmap.publish("w1", {"m0": 100}, loads=1, evictions=3)
    snap = rmap.snapshot()
    assert snap["workers"]["w0"]["resident_bytes"] == 150
    assert snap["workers"]["w1"]["evictions"] == 3
    assert rmap.holders("m0") == ["w0", "w1"]
    assert rmap.holders("m1") == ["w0"]
    assert rmap.fleet_resident_bytes() == 250
    rmap.retire("w0")
    assert "w0" not in rmap.snapshot()["workers"]
    assert rmap.holders("m0") == ["w1"]


def test_residency_map_concurrent_publishers_stay_coherent(tmp_path):
    """8 threads publish under the file lock: the final map must hold
    every worker's LAST row and parse cleanly (no torn writes)."""
    path = str(tmp_path / "residency.json")

    def publisher(i):
        rmap = ResidencyMap(path)          # own fd per thread, like a
        for step in range(15):             # separate worker process
            rmap.publish(f"w{i}", {"m0": 10 * i + step})

    threads = [threading.Thread(target=publisher, args=(i,))
               for i in range(8)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    with open(path) as f:
        snap = json.load(f)                # parses → never torn
    assert sorted(snap["workers"]) == [f"w{i}" for i in range(8)]
    for i in range(8):
        assert snap["workers"][f"w{i}"]["models"]["m0"] == 10 * i + 14


def test_fleet_registry_publishes_loads_and_evictions(tmp_path):
    paths = _save_fleet(tmp_path, 3)
    rmap = ResidencyMap(str(tmp_path / "residency.json"))
    need = bundle_resident_bytes(EncoderBundle.open(paths[0]), 32)
    reg = FleetRegistry(worker_id="w7", residency_map=rmap,
                        device_memory_budget=int(2.5 * need), wave_rows=32)
    for i, path in enumerate(paths):
        reg.add(f"m{i}", path)
    reg.get("m0")
    assert rmap.holders("m0") == ["w7"]
    reg.get("m1")
    reg.get("m2")                          # evicts m0 under the budget
    snap = rmap.snapshot()["workers"]["w7"]
    assert "m0" not in snap["models"] and "m2" in snap["models"]
    assert snap["evictions"] >= 1
    assert snap["resident_bytes"] == reg.resident_bytes
    reg.close()
    assert rmap.snapshot()["workers"] == {}


# -- bounded admission -------------------------------------------------------

def _frontend(tmp_path, max_pending_rows, **svc_kw):
    paths = _save_fleet(tmp_path, 2)
    reg = EncoderRegistry(wave_rows=16)
    for i, path in enumerate(paths):
        reg.add(f"m{i}", path)
    svc = EncoderService(reg, wave_rows=16, **svc_kw)
    return FleetFrontend(svc, max_pending_rows=max_pending_rows), svc


def test_frontend_backpressure_rejects_typed(tmp_path):
    fe, svc = _frontend(tmp_path, max_pending_rows=30)
    X = np.zeros((20, P), np.float32)
    fe.submit(PredictRequest("m0", X, tenant="a"))
    with pytest.raises(ServiceError, match="admission rejected"):
        fe.submit(PredictRequest("m1", X, tenant="b"))
    assert fe.rejected == 1
    assert svc.stats.per_tenant["b"]["rejected"] == 1
    assert fe.pending_rows == 20           # the queue is untouched
    out = fe.flush()                       # drain → room again
    assert len(out) == 1 and out[0].error is None
    fe.submit(PredictRequest("m1", X, tenant="b"))
    assert fe.pending_rows == 20


def test_frontend_replay_drains_under_pressure(tmp_path):
    fe, svc = _frontend(tmp_path, max_pending_rows=64)
    rng = np.random.default_rng(0)
    reqs = [PredictRequest(f"m{i % 2}",
                           rng.standard_normal(
                               (int(rng.integers(5, 40)), P)
                           ).astype(np.float32),
                           tenant=f"t{i % 3}")
            for i in range(12)]
    results, rejections = replay(fe, reqs)
    assert all(r is not None and r.error is None for r in results)
    assert rejections                       # pressure actually happened
    assert fe.pending_rows == 0
    assert svc.stats.rows == sum(q.features.shape[0] for q in reqs)


def test_prefetch_next_matches_non_prefetch(tmp_path):
    paths = _save_fleet(tmp_path, 3)

    def serve(prefetch):
        reg = EncoderRegistry(wave_rows=16)
        for i, path in enumerate(paths):
            reg.add(f"m{i}", path)
        svc = EncoderService(reg, wave_rows=16,
                             prefetch_next=prefetch)
        rng = np.random.default_rng(1)
        X = rng.standard_normal((10, P)).astype(np.float32)
        out = svc.serve([PredictRequest(f"m{i}", X) for i in range(3)])
        return out, reg

    plain, _ = serve(False)
    fetched, reg = serve(True)
    for a, b in zip(plain, fetched):
        assert np.array_equal(a.predictions, b.predictions)
    assert reg.loads == 3 and reg.hits >= 2   # prefetches became hits


# -- the deterministic trace -------------------------------------------------

def test_trace_round_trip_and_digest(tmp_path):
    spec = make_mixed_trace(5, n_models=4, n_requests=20, p=P, t=T,
                            wave_rows=16)
    path = save_trace(str(tmp_path / "trace.json"), spec)
    spec2 = load_trace(path)
    assert spec2 == spec
    assert spec2.digest() == spec.digest()
    # Same seed → same schedule; different seed → different digest.
    again = make_mixed_trace(5, n_models=4, n_requests=20, p=P, t=T,
                             wave_rows=16)
    assert again.digest() == spec.digest()
    other = make_mixed_trace(6, n_models=4, n_requests=20, p=P, t=T,
                             wave_rows=16)
    assert other.digest() != spec.digest()


def test_trace_tamper_refused(tmp_path):
    spec = make_mixed_trace(5, n_models=4, n_requests=10, p=P, t=T,
                            wave_rows=16)
    path = save_trace(str(tmp_path / "trace.json"), spec)
    doc = json.load(open(path))
    doc["entries"][0][2] += 1              # quietly grow one request
    with open(path, "w") as f:
        json.dump(doc, f)
    with pytest.raises(ValueError, match="digest mismatch"):
        load_trace(path)


def test_trace_replay_is_deterministic_and_zipf(tmp_path):
    spec = make_mixed_trace(5, n_models=5, n_requests=60, p=P, t=T,
                            wave_rows=16, zipf_a=1.2)
    models = [f"m{i}" for i in range(5)]
    a = replay_requests(spec, models)
    b = replay_requests(spec, models)
    for qa, qb in zip(a, b):
        assert qa.model == qb.model and qa.tenant == qb.tenant
        assert np.array_equal(qa.features, qb.features)
        assert (qa.targets is None) == (qb.targets is None)
    # Zipf-ish popularity: the top model strictly dominates the tail.
    counts = np.bincount([e.model_idx for e in spec.entries], minlength=5)
    assert counts[0] > counts[2] and counts[0] > counts[3]


def test_checked_in_trace_loads():
    """The trace the benchmarks replay must stay loadable and digest-
    clean as checked in."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(repo, "benchmarks", "traces", "mixed_v1.json")
    spec = load_trace(path)
    assert spec.n_models > 0 and len(spec.entries) >= 20
    assert any(e.scored for e in spec.entries)
    assert any(not e.scored for e in spec.entries)
    assert len({e.tenant for e in spec.entries}) >= 2
    assert trace_digest(spec.entries) == spec.digest()
