"""The serving subsystem: EncoderBundle round-trip + validation,
EncoderRegistry LRU residency, EncoderService wave batching, and the
EncodingReport JSON provenance."""
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.encoding import BrainEncoder, EncodingReport, pipeline
from repro.serving_encoders import (
    BundleError, EncoderBundle, EncoderRegistry, EncoderService,
    PredictRequest, RegistryError, ServiceError,
)
from repro.serving_encoders.bundle import BUNDLE_MANIFEST, _lambda_by_target
from repro.serving_encoders.registry import bundle_resident_bytes


def _problem(seed=0, n=160, p=20, t=12, noise=0.1):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    X = jax.random.normal(k1, (n, p), jnp.float32)
    W = jax.random.normal(k2, (p, t), jnp.float32) / np.sqrt(p)
    Y = X @ W + noise * jax.random.normal(k3, (n, t), jnp.float32)
    return X, Y


@pytest.fixture
def fitted():
    X, Y = _problem()
    return BrainEncoder(n_folds=4).fit(X, Y), X, Y


# -- bundle round trip -------------------------------------------------------

def test_round_trip_f32_bit_identical(fitted, tmp_path):
    enc, X, _ = fitted
    enc.save(str(tmp_path / "b"))
    enc2 = BrainEncoder.load(str(tmp_path / "b"))
    assert np.array_equal(np.asarray(enc.predict(X)),
                          np.asarray(enc2.predict(X)))
    assert enc2.report_.best_lambda == enc.report_.best_lambda
    np.testing.assert_array_equal(enc2.report_.cv_scores,
                                  enc.report_.cv_scores)
    assert enc2.report_.decision == enc.report_.decision
    assert enc2.config == enc.config


def test_round_trip_bf16_inputs_bit_identical(tmp_path):
    X, Y = _problem(seed=1)
    enc = BrainEncoder(n_folds=4).fit(X.astype(jnp.bfloat16),
                                      Y.astype(jnp.bfloat16))
    enc.save(str(tmp_path / "b"))
    enc2 = BrainEncoder.load(str(tmp_path / "b"))
    assert np.array_equal(np.asarray(enc.predict(X)),
                          np.asarray(enc2.predict(X)))


def test_round_trip_bf16_storage(fitted, tmp_path):
    """weight_dtype="bfloat16" stores W as u16 bit patterns; the loaded
    encoder predicts bit-identically to the CAST in-memory weights."""
    enc, X, _ = fitted
    enc.save(str(tmp_path / "b"), weight_dtype="bfloat16", weight_shards=3)
    bundle = EncoderBundle.open(str(tmp_path / "b"))
    assert bundle.manifest["weight_dtype"] == "bfloat16"
    # On-disk shard is genuinely uint16 (npy has no bf16).
    raw = np.load(tmp_path / "b" / "step_0" / "W__000.npy")
    assert raw.dtype == np.uint16
    enc2 = bundle.load_encoder()
    assert enc2.weights_.dtype == jnp.bfloat16
    W_cast = enc.weights_.astype(jnp.bfloat16)
    ref = jnp.matmul(X, W_cast, preferred_element_type=jnp.float32)
    assert np.array_equal(np.asarray(ref), np.asarray(enc2.predict(X)))


def test_weight_sharding_on_disk(fitted, tmp_path):
    enc, X, _ = fitted
    enc.save(str(tmp_path / "b"), weight_shards=5)
    bundle = EncoderBundle.open(str(tmp_path / "b"))
    m = bundle.manifest
    assert m["weight_shards"] == 5
    bounds = m["weight_shard_bounds"]
    assert bounds[0][0] == 0 and bounds[-1][1] == m["t"]
    files = os.listdir(tmp_path / "b" / "step_0")
    assert sum(f.startswith("W__") for f in files) == 5
    assert np.array_equal(np.asarray(enc.predict(X)),
                          np.asarray(bundle.load_encoder().predict(X)))


def test_save_refuses_overwrite_and_is_atomic(fitted, tmp_path):
    enc, _, _ = fitted
    target = str(tmp_path / "b")
    enc.save(target)
    with pytest.raises(BundleError, match="already exists"):
        enc.save(target)
    enc.save(target, overwrite=True)
    leftovers = [d for d in os.listdir(tmp_path)
                 if d.startswith(".tmpbundle")]
    assert leftovers == []


def test_save_unfit_raises(tmp_path):
    with pytest.raises(BundleError, match="not fitted"):
        BrainEncoder().save(str(tmp_path / "b"))


# -- eager open() validation -------------------------------------------------

def test_open_missing_manifest(tmp_path):
    with pytest.raises(BundleError, match=BUNDLE_MANIFEST):
        EncoderBundle.open(str(tmp_path))


def test_open_corrupt_manifest(fitted, tmp_path):
    enc, _, _ = fitted
    enc.save(str(tmp_path / "b"))
    (tmp_path / "b" / BUNDLE_MANIFEST).write_text("{broken")
    with pytest.raises(BundleError, match="corrupt"):
        EncoderBundle.open(str(tmp_path / "b"))


def test_open_unsupported_version(fitted, tmp_path):
    enc, _, _ = fitted
    enc.save(str(tmp_path / "b"))
    p = tmp_path / "b" / BUNDLE_MANIFEST
    m = json.loads(p.read_text())
    m["version"] = 99
    p.write_text(json.dumps(m))
    with pytest.raises(BundleError, match="version"):
        EncoderBundle.open(str(tmp_path / "b"))


def test_open_missing_weight_shard(fitted, tmp_path):
    enc, _, _ = fitted
    enc.save(str(tmp_path / "b"), weight_shards=2)
    os.remove(tmp_path / "b" / "step_0" / "W__001.npy")
    with pytest.raises(BundleError, match="missing"):
        EncoderBundle.open(str(tmp_path / "b"))


def test_open_shape_mismatch(fitted, tmp_path):
    enc, _, _ = fitted
    enc.save(str(tmp_path / "b"))
    np.save(tmp_path / "b" / "step_0" / "best_lambda.npy",
            np.zeros((7, 7)))
    with pytest.raises(BundleError, match="shape"):
        EncoderBundle.open(str(tmp_path / "b"))


def test_open_dtype_mismatch(fitted, tmp_path):
    enc, _, _ = fitted
    enc.save(str(tmp_path / "b"))
    path = tmp_path / "b" / "step_0" / "W__000.npy"
    np.save(path, np.load(path).astype(np.float64))
    with pytest.raises(BundleError, match="dtype"):
        EncoderBundle.open(str(tmp_path / "b"))


def test_open_checkpoint_manifest_disagreement(fitted, tmp_path):
    """A leaf in bundle.json that the checkpoint manifest lost is caught
    before any load."""
    enc, _, _ = fitted
    enc.save(str(tmp_path / "b"))
    p = tmp_path / "b" / "step_0" / "manifest.json"
    m = json.loads(p.read_text())
    del m["leaves"]["cv_scores"]
    p.write_text(json.dumps(m))
    with pytest.raises(BundleError, match="cv_scores"):
        EncoderBundle.open(str(tmp_path / "b"))


def test_sharded_load_requires_divisibility(fitted, tmp_path):
    enc, _, _ = fitted                    # t=12
    enc.save(str(tmp_path / "b"))
    with pytest.raises(BundleError, match="divide"):
        BrainEncoder.load(str(tmp_path / "b"), target_shards=5)


# -- per-target λ ------------------------------------------------------------

def test_lambda_by_target_expansion():
    lam = _lambda_by_target(np.asarray([1.0, 10.0]), t=5)
    np.testing.assert_array_equal(lam, [1.0, 1.0, 1.0, 10.0, 10.0])
    assert _lambda_by_target(np.empty((0,)), t=5) is None


def test_bundle_stores_lambda_by_target(fitted, tmp_path):
    enc, _, _ = fitted
    enc.save(str(tmp_path / "b"))
    arrays = EncoderBundle.open(str(tmp_path / "b")).load_arrays()
    t = enc.weights_.shape[1]
    np.testing.assert_array_equal(
        arrays["lambda_by_target"],
        np.full((t,), float(enc.report_.best_lambda[0])))


# -- registry ----------------------------------------------------------------

def _save_fleet(tmp_path, k=3, **fit_kw):
    paths = []
    for i in range(k):
        X, Y = _problem(seed=10 + i)
        enc = BrainEncoder(n_folds=3, **fit_kw).fit(X, Y)
        path = str(tmp_path / f"m{i}")
        enc.save(path)
        paths.append(path)
    return paths


def test_registry_lazy_then_lru_eviction(tmp_path):
    paths = _save_fleet(tmp_path, 3)
    need = bundle_resident_bytes(EncoderBundle.open(paths[0]), 64)
    reg = EncoderRegistry(device_memory_budget=int(2.5 * need),
                          wave_rows=64)
    for i, p in enumerate(paths):
        reg.add(f"m{i}", p)
    assert reg.loaded_names == []                 # lazy: nothing resident
    reg.get("m0"); reg.get("m1")
    reg.get("m0")                                 # hit → MRU
    assert reg.loaded_names == ["m1", "m0"]
    reg.get("m2")                                 # evicts LRU (m1)
    assert reg.loaded_names == ["m0", "m2"]
    assert reg.evictions == 1 and reg.hits == 1 and reg.loads == 3
    assert reg.resident_bytes <= int(2.5 * need)


def test_registry_unknown_and_duplicate(tmp_path):
    paths = _save_fleet(tmp_path, 1)
    reg = EncoderRegistry()
    reg.add("m0", paths[0])
    with pytest.raises(RegistryError, match="already registered"):
        reg.add("m0", paths[0])
    with pytest.raises(RegistryError, match="unknown"):
        reg.get("nope")


def test_registry_recharges_resident_entry_on_bigger_waves(tmp_path):
    """A hit served with a bigger wave size re-charges the activation term
    of the residency account (and evicts to make room) — the budget bounds
    the waves actually flown, not the construction-time default."""
    paths = _save_fleet(tmp_path, 2)
    b = EncoderBundle.open(paths[0])
    small = bundle_resident_bytes(b, 16)
    big = bundle_resident_bytes(b, 4096)
    reg = EncoderRegistry(device_memory_budget=small + big - 1,
                          wave_rows=16)
    reg.add("a", paths[0]); reg.add("b", paths[1])
    reg.get("a"); reg.get("b")
    assert len(reg.loaded_names) == 2
    entry = reg.get("b", wave_rows=4096)      # hit, but bigger waves
    assert entry.resident_bytes == big
    assert reg.loaded_names == ["b"]          # "a" evicted to make room
    assert reg.evictions == 1
    # A wave size the budget can never support refuses up front without
    # flushing the resident entries.
    with pytest.raises(RegistryError, match="wave size"):
        reg.get("b", wave_rows=10**7)
    assert reg.loaded_names == ["b"]


def test_registry_bundle_over_budget_raises(tmp_path):
    paths = _save_fleet(tmp_path, 1)
    reg = EncoderRegistry(device_memory_budget=16, wave_rows=64)
    reg.add("m0", paths[0])
    with pytest.raises(RegistryError, match="over the registry budget"):
        reg.get("m0")


# -- service -----------------------------------------------------------------

def test_service_micro_batches_and_matches_predict(fitted, tmp_path):
    enc, X, Y = fitted
    enc.save(str(tmp_path / "b"))
    reg = EncoderRegistry()
    reg.add("m", str(tmp_path / "b"))
    svc = EncoderService(reg, wave_rows=64)
    Xn = np.asarray(X)
    # Three ragged requests for one model → concatenated into fixed waves.
    out = svc.serve([PredictRequest("m", Xn[:37]),
                     PredictRequest("m", Xn[37:90],
                                    targets=np.asarray(Y)[37:90]),
                     PredictRequest("m", Xn[90:160])])
    got = np.concatenate([r.predictions for r in out])
    assert np.array_equal(got, np.asarray(enc.predict(X)))
    # 160 rows → 3 waves of 64 with 32 pad rows.
    assert svc.stats.waves == 3 and svc.stats.pad_rows == 32
    # ONE mixed program serves scored and unscored traffic alike — the
    # request mix and model count must not add traces.
    assert svc.compile_count == 1
    svc.serve([PredictRequest("m", Xn[:5]),
               PredictRequest("m", Xn[:5], targets=np.asarray(Y)[:5])])
    assert svc.compile_count == 1
    # Scoring is fused into the compiled wave (five running sums per
    # wave, finalised from the accumulated sums) and matches the
    # host-side §4.1 metric on the unpadded rows.
    from repro.core import scoring
    ref_r = np.asarray(scoring.pearson_r(Y[37:90],
                                         enc.predict(X[37:90])))
    np.testing.assert_allclose(out[1].pearson_r, ref_r, rtol=1e-5,
                               atol=1e-6)


def test_service_one_compile_per_wave_shape(tmp_path):
    paths = _save_fleet(tmp_path, 2)
    reg = EncoderRegistry()
    reg.add("a", paths[0]); reg.add("b", paths[1])
    svc = EncoderService(reg, wave_rows=32)
    X = np.asarray(_problem(seed=99)[0])
    svc.serve([PredictRequest("a", X[:50]), PredictRequest("b", X[:20])])
    # Two models, same (wave, p, t) shape → ONE compiled predict.
    assert svc.compile_count == 1
    svc.serve([PredictRequest("a", X[:10])])
    assert svc.compile_count == 1                 # reused across calls
    svc.serve([PredictRequest("b", X[:10])], wave_rows=16)
    assert svc.compile_count == 2                 # new shape → one more


def test_service_wave_bucketing_cuts_pad(tmp_path):
    """wave_buckets picks the wave shape by the rows remaining: full
    waves at the largest bucket, the tail at the smallest that fits —
    each bucket compiled once, pad fraction tracked per bucket."""
    paths = _save_fleet(tmp_path, 1)
    reg = EncoderRegistry()
    reg.add("m", paths[0])
    svc = EncoderService(reg, wave_buckets=(16, 64))
    X = np.asarray(_problem(seed=60, n=160)[0])
    out = svc.serve([PredictRequest("m", X[:70]),
                     PredictRequest("m", X[70:140])])
    got = np.concatenate([r.predictions for r in out])
    # 140 packed rows → 64 + 64 + tail 12 in a 16-wave (pad 4), instead
    # of three 64-waves (pad 52) under a single fixed shape.
    assert svc.stats.per_bucket[64] == {"waves": 2, "rows": 128,
                                        "pad_rows": 0}
    assert svc.stats.per_bucket[16] == {"waves": 1, "rows": 12,
                                        "pad_rows": 4}
    assert svc.stats.pad_rows == 4
    assert svc.compile_count == 2                 # one per bucket used
    enc = EncoderBundle.open(paths[0]).load_encoder()
    assert np.array_equal(got, np.asarray(enc.predict(X[:140])))
    # Same buckets again: no new traces; a small batch uses only the
    # small bucket (no new compile either — shape already traced).
    svc.serve([PredictRequest("m", X[:10])])
    assert svc.compile_count == 2
    assert svc.stats.per_bucket[16]["waves"] == 2
    with pytest.raises(ServiceError, match="wave_buckets"):
        EncoderService(reg, wave_buckets=(0, 8))
    with pytest.raises(ServiceError, match="wave_rows"):
        EncoderService(reg, wave_rows=0)
    # Tail planning is min-pad: a 33-row tail on (32, 128) flies two
    # 32-row waves (pad 31), not one 128-row wave (pad 95); a 12-row tail
    # on (16, 64) prefers the single 16-row wave over ladder-descending.
    svc2 = EncoderService(reg, wave_buckets=(32, 128))
    assert svc2._plan_waves(161, None) == [128, 32, 32]
    assert svc2._plan_waves(120, None) == [128]         # equal pad → fewer
    assert svc._plan_waves(140, None) == [64, 64, 16]


def test_service_fused_scoring_across_waves_and_buckets(tmp_path):
    """A scored request spanning several waves accumulates the five
    Pearson sums across its waves; the finalised r matches the host-side
    reference — including under bucketed wave shapes."""
    from repro.core import scoring

    paths = _save_fleet(tmp_path, 1)
    enc = EncoderBundle.open(paths[0]).load_encoder()
    reg = EncoderRegistry()
    reg.add("m", paths[0])
    X, Y = _problem(seed=61, n=150)
    preds = enc.predict(X)
    for kw in ({"wave_rows": 32}, {"wave_buckets": (16, 64)}):
        svc = EncoderService(reg, **kw)
        out = svc.serve([PredictRequest("m", np.asarray(X),
                                        targets=np.asarray(Y))])[0]
        assert np.array_equal(out.predictions, np.asarray(preds))
        ref_r = np.asarray(scoring.pearson_r(Y, preds))
        np.testing.assert_allclose(out.pearson_r, ref_r, rtol=1e-5,
                                   atol=1e-6)
    # return_predictions=False still scores (the point of the fusion:
    # evaluation traffic without the (rows, t) prediction pull).
    svc = EncoderService(reg, wave_rows=32, return_predictions=False)
    out = svc.serve([PredictRequest("m", np.asarray(X),
                                    targets=np.asarray(Y))])[0]
    assert out.predictions is None
    np.testing.assert_allclose(out.pearson_r, ref_r, rtol=1e-5, atol=1e-6)


def test_service_applies_pipeline_standardizer(tmp_path):
    """A bundle saved from the pipeline carries μ/σ; the service replays
    the exact standardize → predict → de-standardize composition."""
    X, Y = _problem(seed=7, noise=0.3)
    X = X * 3.0 + 1.5                             # un-standardized features
    Y = Y * 2.0 - 4.0
    state = pipeline.run_stages(X, Y, [pipeline.split(seed=0),
                                       pipeline.standardize(),
                                       pipeline.fit(n_folds=3)])
    enc = state.encoder
    assert enc.standardizer_ is not None
    enc.save(str(tmp_path / "b"))
    reg = EncoderRegistry()
    reg.add("m", str(tmp_path / "b"))
    svc = EncoderService(reg, wave_rows=32)
    Xr = np.asarray(X)[:32]                       # raw features, full wave
    out = svc.serve([PredictRequest("m", Xr)])[0]
    std = enc.standardizer_
    entry = reg.get("m")

    @jax.jit                  # same program as the service's compiled wave
    def ref_fn(X, W, mu_x, sd_x, mu_y, sd_y):
        P = jnp.matmul((X - mu_x) / sd_x, W,
                       preferred_element_type=jnp.float32)
        return P * sd_y + mu_y

    ref = ref_fn(jnp.asarray(Xr), entry.weights, entry.mu_x, entry.sd_x,
                 entry.mu_y, entry.sd_y)
    assert np.array_equal(out.predictions, np.asarray(ref))
    # μ/σ round-tripped exactly through the bundle
    loaded_std = BrainEncoder.load(str(tmp_path / "b")).standardizer_
    np.testing.assert_array_equal(loaded_std.mu_x, np.asarray(std.mu_x))
    np.testing.assert_array_equal(loaded_std.sd_y, np.asarray(std.sd_y))


def test_service_batch_spanning_models_respects_budget(tmp_path):
    """One serve() batch touching more models than the budget fits must
    load them one at a time (pass-2 just-in-time), never pinning the whole
    fleet resident at once."""
    paths = _save_fleet(tmp_path, 3)
    need = bundle_resident_bytes(EncoderBundle.open(paths[0]), 32)
    budget = int(2.5 * need)
    reg = EncoderRegistry(device_memory_budget=budget, wave_rows=32)
    for i, p_ in enumerate(paths):
        reg.add(f"m{i}", p_)
    svc = EncoderService(reg, wave_rows=32)
    X = np.asarray(_problem(seed=50)[0])[:20]
    out = svc.serve([PredictRequest(f"m{i}", X) for i in range(3)])
    assert all(r.predictions is not None for r in out)
    assert reg.resident_bytes <= budget
    assert len(reg.loaded_names) <= 2 and reg.evictions >= 1


def test_service_validates_all_models_before_any_compute(tmp_path):
    """A malformed request for model B refuses the batch BEFORE model A
    does any device work (or any bundle is even loaded)."""
    paths = _save_fleet(tmp_path, 2)
    reg = EncoderRegistry()
    reg.add("a", paths[0]); reg.add("b", paths[1])
    svc = EncoderService(reg, wave_rows=32)
    X = np.asarray(_problem(seed=51)[0])[:16]
    with pytest.raises(ServiceError, match="incompatible"):
        svc.serve([PredictRequest("a", X),
                   PredictRequest("b", np.zeros((4, 99), np.float32))])
    assert svc.stats.waves == 0 and reg.loaded_names == []
    # Same up-front refusal for a model that could never fit the budget.
    reg2 = EncoderRegistry(device_memory_budget=16, wave_rows=32)
    reg2.add("a", paths[0]); reg2.add("b", paths[1])
    svc2 = EncoderService(reg2, wave_rows=32)
    with pytest.raises(RegistryError, match="over the registry budget"):
        svc2.serve([PredictRequest("a", X), PredictRequest("b", X)])
    assert svc2.stats.waves == 0 and reg2.loaded_names == []


def test_standardizer_apply_unapply_round_trip():
    std = pipeline.Standardizer(
        mu_x=np.asarray([1.0, -2.0], np.float32),
        sd_x=np.asarray([2.0, 0.5], np.float32),
        mu_y=np.asarray([3.0], np.float32),
        sd_y=np.asarray([4.0], np.float32))
    X = np.asarray([[3.0, -2.5], [1.0, -1.5]], np.float32)
    np.testing.assert_array_equal(std.apply_x(X), [[1.0, -1.0], [0.0, 1.0]])
    Y = np.asarray([[0.5], [-0.25]], np.float32)
    np.testing.assert_allclose(std.unapply_y(std.apply_y(Y)), Y, rtol=1e-6)
    ident = pipeline.Standardizer()
    assert ident.apply_x(X) is X and ident.unapply_y(Y) is Y


def test_service_rejects_bad_features(fitted, tmp_path):
    enc, X, _ = fitted
    enc.save(str(tmp_path / "b"))
    reg = EncoderRegistry()
    reg.add("m", str(tmp_path / "b"))
    svc = EncoderService(reg)
    with pytest.raises(ServiceError, match="incompatible"):
        svc.serve([PredictRequest("m", np.zeros((4, 99), np.float32))])
    with pytest.raises(ServiceError, match="targets"):
        svc.serve([PredictRequest("m", np.asarray(X)[:4],
                                  targets=np.zeros((4, 99), np.float32))])


# -- report provenance -------------------------------------------------------

def test_report_json_round_trip(fitted):
    enc, _, _ = fitted
    r = enc.report_
    back = EncodingReport.from_json(r.to_json())
    assert back.weights is None                   # arrays live in the bundle
    np.testing.assert_array_equal(back.best_lambda,
                                  np.asarray(r.best_lambda))
    np.testing.assert_allclose(back.cv_scores, np.asarray(r.cv_scores),
                               rtol=1e-12)
    assert back.lambdas == r.lambdas
    assert back.decision == r.decision
    assert back.solver_label == r.solver_label
    d = json.loads(r.to_json())
    assert d["weights_shape"] == list(r.weights.shape)
    # A provenance-only report (weights=None) re-serializes cleanly.
    d2 = json.loads(back.to_json())
    assert d2["weights_shape"] is None and d2["weights_dtype"] is None
    assert d2["best_lambda"] == d["best_lambda"]
