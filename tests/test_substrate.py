"""Substrate tests: optimizer, schedule, checkpointing, data pipeline."""
import os

import numpy as np
import jax
import jax.numpy as jnp

from repro import checkpoint
from repro.data import fmri, synthetic
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.optim.adamw import global_norm


def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)  # noqa: E731
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(cfg, params, g, state)
    assert float(loss(params)) < 1e-3


def test_adamw_grad_clipping():
    cfg = AdamWConfig(lr=1e-3, grad_clip_norm=1.0)
    params = {"w": jnp.zeros(4)}
    state = adamw_init(params)
    huge = {"w": jnp.full(4, 1e6)}
    _, state, metrics = adamw_update(cfg, params, huge, state)
    assert float(metrics["grad_norm"]) > 1e5          # pre-clip norm reported
    assert float(global_norm(state["mu"])) < 1.0      # clipped before moments


def test_cosine_schedule_shape():
    s0 = float(cosine_schedule(0, warmup_steps=10, total_steps=100))
    s10 = float(cosine_schedule(10, warmup_steps=10, total_steps=100))
    s100 = float(cosine_schedule(100, warmup_steps=10, total_steps=100))
    assert s0 == 0.0 and abs(s10 - 1.0) < 1e-6 and abs(s100 - 0.1) < 1e-6


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), jnp.bfloat16),
                   "step": jnp.int32(7)},
    }
    d = str(tmp_path / "ckpt")
    checkpoint.save(d, 3, tree)
    assert checkpoint.latest_step(d) == 3
    out = checkpoint.restore(d, 3, tree)
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x, np.float32),
                                                   np.asarray(y, np.float32)),
        tree, out)
    assert out["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_save_is_atomic(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = {"w": jnp.zeros(3)}
    checkpoint.save(d, 1, tree)
    checkpoint.save(d, 1, {"w": jnp.ones(3)})  # overwrite same step
    out = checkpoint.restore(d, 1, tree)
    np.testing.assert_array_equal(np.asarray(out["w"]), 1.0)
    assert not any(f.startswith(".tmp") for f in os.listdir(d))


def test_token_stream_determinism_and_shards():
    from repro import configs
    cfg = configs.smoke(configs.get_config("qwen3-1.7b"))
    s0 = synthetic.TokenStream(cfg, 2, 8, seed=0, shard=0, n_shards=2)
    s0b = synthetic.TokenStream(cfg, 2, 8, seed=0, shard=0, n_shards=2)
    s1 = synthetic.TokenStream(cfg, 2, 8, seed=0, shard=1, n_shards=2)
    a, b = s0.batch_at(5)["tokens"], s0b.batch_at(5)["tokens"]
    c = s1.batch_at(5)["tokens"]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_fmri_generator_statistics():
    spec = fmri.SubjectSpec(n=500, p=64, t=128)
    X, Y, mask = fmri.generate(jax.random.PRNGKey(0), spec)
    assert X.shape == (500, 64) and Y.shape == (500, 128)
    assert int(mask.sum()) == 32
    np.testing.assert_allclose(np.asarray(Y.mean(0)), 0.0, atol=1e-3)
    np.testing.assert_allclose(np.asarray(Y.std(0)), 1.0, atol=1e-2)


def test_detrend_removes_slow_drift():
    n = 400
    t = jnp.arange(n)[:, None] * 1.49
    drift = jnp.sin(2 * jnp.pi * 0.003 * t)          # 0.003 Hz < 0.01 cutoff
    fast = jnp.sin(2 * jnp.pi * 0.1 * t)             # 0.1 Hz — keep
    Y = drift + fast
    out = fmri.detrend(Y, n_basis=8)
    # Drift energy mostly removed, fast component mostly preserved.
    assert float(jnp.mean(out * drift)) < 0.1 * float(jnp.mean(drift * drift))
    assert float(jnp.mean(out * fast)) > 0.8 * float(jnp.mean(fast * fast))
