"""Chunked-vocab CE equals single-pass CE (loss + grads), incl. softcap."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.configs import smoke
from repro.models import build_model


def _batch(cfg, key=1):
    return {"tokens": jax.random.randint(jax.random.PRNGKey(key), (2, 16), 0,
                                         cfg.vocab, dtype=jnp.int32)}


def test_chunked_ce_matches_single_pass_loss_and_grads():
    cfg = smoke(configs.get_config("qwen3-1.7b"))
    cfg_c = dataclasses.replace(cfg, ce_vocab_chunks=8)
    m0, m1 = build_model(cfg), build_model(cfg_c)
    params = m0.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    l0, l1 = m0.loss(params, batch), m1.loss(params, batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-4)
    g0 = jax.grad(lambda p: m0.loss(p, batch))(params)
    g1 = jax.grad(lambda p: m1.loss(p, batch))(params)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=5e-2, atol=2e-3), g0, g1)


def test_chunked_ce_with_final_softcap():
    cfg = smoke(configs.get_config("gemma2-2b"))       # final softcap 30
    cfg_c = dataclasses.replace(cfg, ce_vocab_chunks=4)
    m0, m1 = build_model(cfg), build_model(cfg_c)
    params = m0.init(jax.random.PRNGKey(2))
    batch = _batch(cfg, key=3)
    np.testing.assert_allclose(float(m0.loss(params, batch)),
                               float(m1.loss(params, batch)), rtol=1e-4)


def test_chunked_ce_untied_embeddings():
    cfg = smoke(configs.get_config("phi3.5-moe-42b-a6.6b"))  # untied
    assert not cfg.tie_embeddings
    cfg_c = dataclasses.replace(cfg, ce_vocab_chunks=4)
    m0, m1 = build_model(cfg), build_model(cfg_c)
    params = m0.init(jax.random.PRNGKey(4))
    batch = _batch(cfg, key=5)
    np.testing.assert_allclose(float(m0.loss(params, batch)),
                               float(m1.loss(params, batch)), rtol=1e-4)
