"""Reporting utilities: roofline report + sweep-log parser."""
import json
import os
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "benchmarks"))


def _write_jsonl(tmp_path, rows):
    p = tmp_path / "dry.jsonl"
    with open(p, "w") as f:
        for r in rows:
            f.write(json.dumps(r) + "\n")
    return str(p)


def test_roofline_report_table(tmp_path):
    from repro.launch import roofline_report
    rows = [{
        "arch": "qwen3-1.7b", "shape": "train_4k", "mesh": "16x16",
        "rules": "tp", "flops": 7.18e13, "hlo_bytes": 3.73e12,
        "collective_bytes": {"all-reduce": 1.1e11},
        "memory": {"temp_size_in_bytes": int(7.6e9)},
    }]
    md = roofline_report.report(_write_jsonl(tmp_path, rows))
    assert "qwen3-1.7b" in md and "memory" in md
    # 6ND/HLO ratio column present and sane
    line = [l for l in md.splitlines() if "qwen3" in l][0]
    ratio = float(line.split("|")[7].strip().replace("*", ""))
    assert 0.3 < ratio < 1.0


def test_roofline_report_skips_multipod_and_dedups(tmp_path):
    from repro.launch import roofline_report
    base = {
        "arch": "mamba2-130m", "shape": "train_4k", "rules": "tp",
        "flops": 1e12, "hlo_bytes": 1e12, "collective_bytes": {},
        "memory": {"temp_size_in_bytes": 1},
    }
    rows = [dict(base, mesh="16x16"), dict(base, mesh="16x16"),
            dict(base, mesh="2x16x16")]
    md = roofline_report.report(_write_jsonl(tmp_path, rows))
    assert sum("mamba2" in l for l in md.splitlines()) == 1


def test_parse_sweep_log_roundtrip(tmp_path):
    import parse_sweep_log
    log = tmp_path / "sweep.log"
    log.write_text("""== qwen3-1.7b × train_4k × 16x16 (rules=tp) ==
memory_analysis: CompiledMemoryStats(argument_size_in_bytes=2178035716, temp_size_in_bytes=7616104608)
cost_analysis (probe-extrapolated): flops=7.184e+13 bytes=3.732e+12
collective_bytes: {'all-gather': '1.409e+09', 'all-reduce': '1.093e+11'}
== next × combo × 16x16 (rules=tp) ==
cost_analysis (probe-extrapolated): flops=1.0e+10 bytes=2.0e+10
collective_bytes: {'all-reduce': '0.0'}
""")
    recs = parse_sweep_log.parse(str(log))
    assert len(recs) == 2
    assert recs[0]["arch"] == "qwen3-1.7b"
    assert recs[0]["flops"] == pytest.approx(7.184e13)
    assert recs[0]["memory"]["temp_size_in_bytes"] == 7616104608
    assert recs[0]["collective_bytes"]["all-reduce"] == pytest.approx(1.093e11)


def test_active_params_moe_scaling():
    from repro.launch.roofline_report import active_params
    total, active = active_params("phi3.5-moe-42b-a6.6b")
    assert 40e9 < total < 44e9
    assert active < 0.25 * total          # 16 experts top-2 + shared parts
    t2, a2 = active_params("qwen3-1.7b")  # dense: active == total
    assert t2 == a2
