"""Blockwise (flash-style) attention equals dense attention."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import configs
from repro.configs import smoke
from repro.models import build_model, layers
from repro.models.config import ModelConfig


def _cfg(**kw):
    base = smoke(configs.get_config("qwen3-1.7b"))
    return dataclasses.replace(base, **kw)


def _params_and_inputs(cfg, seq, key=0):
    defs = layers.attention_defs(cfg)
    from repro.models.params import init
    p = init(jax.random.PRNGKey(key), defs, dtype_override=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(key + 1), (2, seq, cfg.d_model),
                          jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None], (2, seq))
    return p, x, pos


@pytest.mark.parametrize("window,softcap", [(None, None), (32, None),
                                            (None, 30.0), (16, 50.0)])
def test_blockwise_matches_dense(window, softcap):
    seq = 128
    cfg_dense = _cfg(flash_threshold=None, attn_logit_softcap=softcap)
    cfg_flash = _cfg(flash_threshold=1, flash_block=32,
                     attn_logit_softcap=softcap)
    var = layers.AttnVariant(window=window, softcap=softcap)
    p, x, pos = _params_and_inputs(cfg_dense, seq)
    dense = layers.attention(p, cfg_dense, var, x, pos)
    flash = layers.attention(p, cfg_flash, var, x, pos)
    np.testing.assert_allclose(np.asarray(flash), np.asarray(dense),
                               rtol=2e-4, atol=2e-4)


def test_blockwise_banded_window_correct_at_edges():
    """Window smaller than one block and window spanning past block 0."""
    seq = 64
    for window in (8, 48):
        cfg_dense = _cfg(flash_threshold=None)
        cfg_flash = _cfg(flash_threshold=1, flash_block=16)
        var = layers.AttnVariant(window=window)
        p, x, pos = _params_and_inputs(cfg_dense, seq, key=7)
        dense = layers.attention(p, cfg_dense, var, x, pos)
        flash = layers.attention(p, cfg_flash, var, x, pos)
        np.testing.assert_allclose(np.asarray(flash), np.asarray(dense),
                                   rtol=2e-4, atol=2e-4)


def test_blockwise_grads_match_dense():
    seq = 64
    cfg_dense = _cfg(flash_threshold=None)
    cfg_flash = _cfg(flash_threshold=1, flash_block=16)
    var = layers.AttnVariant(window=None)
    p, x, pos = _params_and_inputs(cfg_dense, seq, key=3)

    def loss(cfg):
        return lambda pp: jnp.sum(
            layers.attention(pp, cfg, var, x, pos) ** 2)

    g_dense = jax.grad(loss(cfg_dense))(p)
    g_flash = jax.grad(loss(cfg_flash))(p)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=5e-3, atol=5e-3),
        g_dense, g_flash)


def test_model_forward_same_with_flash():
    cfg = smoke(configs.get_config("gemma2-2b"))
    cfg_flash = dataclasses.replace(cfg, flash_threshold=1, flash_block=8)
    tok = jax.random.randint(jax.random.PRNGKey(0), (1, 16), 0, cfg.vocab,
                             dtype=jnp.int32)
    m0, m1 = build_model(cfg), build_model(cfg_flash)
    params = m0.init(jax.random.PRNGKey(1))
    l0, _ = m0.forward(params, {"tokens": tok})
    l1, _ = m1.forward(params, {"tokens": tok})
    np.testing.assert_allclose(np.asarray(l0, np.float32),
                               np.asarray(l1, np.float32), rtol=2e-2,
                               atol=2e-2)


def test_model_forward_same_with_flash_pallas_kernel():
    """The Pallas kernel backend matches dense and jnp-blockwise paths."""
    cfg = smoke(configs.get_config("qwen3-1.7b"))
    cfg_k = dataclasses.replace(cfg, flash_threshold=1, flash_block=8,
                                flash_kernel=True)
    tok = jax.random.randint(jax.random.PRNGKey(0), (1, 16), 0, cfg.vocab,
                             dtype=jnp.int32)
    m0, m1 = build_model(cfg), build_model(cfg_k)
    params = m0.init(jax.random.PRNGKey(1))
    l0, _ = m0.forward(params, {"tokens": tok})
    l1, _ = m1.forward(params, {"tokens": tok})
    np.testing.assert_allclose(np.asarray(l0, np.float32),
                               np.asarray(l1, np.float32), rtol=2e-2,
                               atol=2e-2)
