"""Out-of-core subsystem: chunk-invariance harness, store I/O, dispatch.

The lockdown contract of the streaming path: the ``FoldStats`` produced by
``compute_chunked`` / ``compute_sharded_chunked`` are INVARIANT (to f32
tolerance, against a float64 oracle) under chunk size, chunk-boundary
placement, and shard count — including 1-row chunks, chunks that straddle
fold boundaries, ragged final chunks, and shard windows that cut folds.
Property-based (hypothesis) where available, with fixed-seed parametrised
fallbacks that always run.
"""
import json
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import foldstats, ridge
from repro.core.ridge import RidgeCVConfig
from repro.data.store import ChunkPrefetcher, RunStore, StoreError
from repro.encoding import BrainEncoder, EncoderConfig, pipeline, resolve
from repro.encoding.dispatch import estimated_resident_bytes

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                # fixed-seed fallback only
    HAVE_HYPOTHESIS = False


def _make_problem(seed, n, p, t, noise=0.05, y_offset=0.0,
                  dtype=np.float32):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, p)).astype(np.float32)
    W = rng.normal(size=(p, t)).astype(np.float32) / np.sqrt(p)
    Y = (X @ W + noise * rng.normal(size=(n, t)) + y_offset).astype(
        np.float32)
    return X.astype(dtype), Y.astype(dtype)


def _oracle_stats(X, Y, n_folds):
    """Float64 per-fold statistics, computed directly."""
    X64, Y64 = np.asarray(X, np.float64), np.asarray(Y, np.float64)
    out = {}
    for f, (lo, hi) in enumerate(foldstats.fold_bounds(len(X64), n_folds)):
        Xf, Yf = X64[lo:hi], Y64[lo:hi]
        out[f] = dict(G=Xf.T @ Xf, C=Xf.T @ Yf, xsum=Xf.sum(0),
                      ysum=Yf.sum(0),
                      ysq=((Yf - Yf.mean(0)) ** 2).sum(0),
                      count=float(hi - lo))
    return out


def _chunk_stream(X, Y, lo, hi, chunk):
    pos = lo
    while pos < hi:
        end = min(pos + chunk, hi)
        yield X[pos:end], Y[pos:end]
        pos = end


def _check_invariance(n, n_folds, chunk, n_shards, seed, y_offset=0.0,
                      rtol=2e-5, atol=2e-4):
    """Core harness: chunked+sharded stats match the f64 oracle."""
    X, Y = _make_problem(seed, n, 6, 4, y_offset=y_offset)
    ranges = foldstats.shard_row_ranges(n, n_shards)
    streams = [_chunk_stream(X, Y, lo, hi, chunk) for lo, hi in ranges]
    got = foldstats.compute_sharded_chunked(streams, n, n_folds)
    oracle = _oracle_stats(X, Y, n_folds)
    for f in range(n_folds):
        for name in ("G", "C", "xsum", "ysum", "ysq", "count"):
            np.testing.assert_allclose(
                np.asarray(getattr(got, name)[f]), oracle[f][name],
                rtol=rtol, atol=atol,
                err_msg=f"{name} fold {f} (chunk={chunk}, "
                        f"shards={n_shards})")


# ---------------------------------------------------------------------------
# Chunk-invariance: fixed-seed lockdown grid (always runs)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", [1, 2, 8])
@pytest.mark.parametrize("chunk", [1, 7, 13, 64])
def test_chunk_and_shard_invariance_fixed(chunk, n_shards):
    """n=97, k=5: folds of 20/20/19/19/19 — chunk sizes {1 row,
    fold-misaligned, ragged tail} × shard counts {1, 2, 8}."""
    _check_invariance(97, 5, chunk, n_shards, seed=0)


def test_chunk_invariance_fold_boundary_straddle():
    """A chunk spanning three folds and shard windows cutting folds
    mid-chunk must agree with the oracle exactly like aligned chunks."""
    _check_invariance(30, 6, 13, 4, seed=1)       # folds of 5, chunks of 13
    _check_invariance(30, 6, 30, 1, seed=1)       # single whole-data chunk


def test_chunk_invariance_unstandardized_targets():
    """Chan-combined centred moments survive a large target mean."""
    _check_invariance(120, 5, 17, 3, seed=2, y_offset=50.0, atol=5e-3,
                      rtol=5e-4)


def test_sharded_equals_unsharded_bitwise_structure():
    """Shard count changes the combine tree, not the result beyond f32
    rounding: 1 vs 2 vs 8 shards agree pairwise."""
    X, Y = _make_problem(3, 101, 8, 5)
    n, k = 101, 4
    results = []
    for S in (1, 2, 8):
        streams = [_chunk_stream(X, Y, lo, hi, 9)
                   for lo, hi in foldstats.shard_row_ranges(n, S)]
        results.append(foldstats.compute_sharded_chunked(streams, n, k))
    for other in results[1:]:
        for name in ("G", "C", "xsum", "ysum", "ysq", "count"):
            np.testing.assert_allclose(
                np.asarray(getattr(other, name)),
                np.asarray(getattr(results[0], name)),
                rtol=2e-5, atol=2e-4)


def test_accumulator_window_and_stream_validation():
    X, Y = _make_problem(4, 40, 4, 3)
    with pytest.raises(ValueError, match="row_start"):
        foldstats.FoldStatsAccumulator(40, 4, row_start=10, row_stop=5)
    acc = foldstats.FoldStatsAccumulator(40, 4, row_start=10, row_stop=30)
    with pytest.raises(ValueError, match="overruns"):
        acc.update(X[10:35], Y[10:35])            # 25 rows > 20-row window
    acc.update(X[10:25], Y[10:25])
    with pytest.raises(ValueError, match="full window"):
        acc.finalize()                            # 5 rows short
    with pytest.raises(ValueError, match="n_shards"):
        foldstats.shard_row_ranges(4, 9)
    with pytest.raises(ValueError, match="at least one"):
        foldstats.combine([])


# ---------------------------------------------------------------------------
# Chunk-invariance: hypothesis property (skipped without hypothesis)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**16), n=st.integers(10, 160),
           n_folds=st.integers(2, 7), chunk=st.integers(1, 170),
           n_shards=st.sampled_from([1, 2, 3, 8]))
    def test_chunk_invariance_property(seed, n, n_folds, chunk, n_shards):
        if n_folds > n or n_shards > n:
            return
        _check_invariance(n, n_folds, chunk, n_shards, seed)


# ---------------------------------------------------------------------------
# Fixed-shape masked update: ONE compile per stream, however the chunks cut
# ---------------------------------------------------------------------------

def test_chunk_update_compiles_once_per_stream():
    """The whole-stream trace count is 1 for a fresh signature and 0 for a
    repeat — independent of fold alignment: 1-row chunks, fold-misaligned
    chunks, and ragged tails all reuse the one masked program (the eager
    per-segment path compiled one matmul per distinct segment length)."""
    X, Y = _make_problem(20, 53, 11, 3)
    n, k = 53, 4
    for chunk in (1, 7, 17):          # 1-row, fold-misaligned, ragged tail
        before = foldstats.chunk_update_compile_count()
        foldstats.compute_chunked(_chunk_stream(X, Y, 0, n, chunk), n, k,
                                  chunk_rows=chunk)
        assert foldstats.chunk_update_compile_count() - before == 1, chunk
        before = foldstats.chunk_update_compile_count()
        foldstats.compute_chunked(_chunk_stream(X, Y, 0, n, chunk), n, k,
                                  chunk_rows=chunk)
        assert foldstats.chunk_update_compile_count() - before == 0, chunk


def test_chunk_update_compiles_once_across_shards():
    """All 8 shard windows share one program signature when chunk_rows is
    pinned — shard boundaries cutting folds add masks, not traces."""
    X, Y = _make_problem(21, 53, 11, 3)
    n, k, chunk = 53, 4, 5
    before = foldstats.chunk_update_compile_count()
    streams = [_chunk_stream(X, Y, lo, hi, chunk)
               for lo, hi in foldstats.shard_row_ranges(n, 8)]
    foldstats.compute_sharded_chunked(streams, n, k, chunk_rows=chunk)
    assert foldstats.chunk_update_compile_count() - before == 1


# ---------------------------------------------------------------------------
# Prefetching reader: bit-identical, exception-safe, shuts down cleanly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [np.float32, "bfloat16"])
def test_prefetch_stream_bit_identical(make_run_store, dtype):
    """Prefetched chunks are the synchronous iterator's, bit for bit —
    f32 and bf16-as-u16 storage, including run-straddling and ragged
    chunks and windowed (sharded) streams."""
    if dtype == "bfloat16":
        X, Y = _make_problem(22, 87, 6, 4, dtype=jnp.bfloat16)
        X, Y = np.asarray(X), np.asarray(Y)
    else:
        X, Y = _make_problem(22, 87, 6, 4)
    store = make_run_store(X, Y, n_runs=3)
    for chunk, rr in ((13, None), (29, (11, 70)), (87, None)):
        sync = list(store.iter_chunks(chunk, row_range=rr))
        pf = store.iter_chunks(chunk, row_range=rr, prefetch=True)
        got = [(x.copy(), y.copy()) for x, y in pf]
        assert len(got) == len(sync)
        for (xs, ys), (xp, yp) in zip(sync, got):
            assert xs.dtype == xp.dtype
            np.testing.assert_array_equal(np.asarray(xs, np.float32),
                                          np.asarray(xp, np.float32))
            np.testing.assert_array_equal(np.asarray(ys, np.float32),
                                          np.asarray(yp, np.float32))
        assert pf.stats.chunks == len(sync)
        assert pf.stats.bytes_staged > 0


@pytest.mark.parametrize("y_offset", [0.0, 3.0])
def test_fit_store_prefetch_bit_identical_lambda_and_weights(
        make_run_store, y_offset):
    """Prefetch is purely a wall-time knob: λ selection AND weights are
    bit-identical with it on or off (both feed the same fixed-shape
    compiled update), and the streamed fit matches the in-memory λ."""
    X, Y = _make_problem(23, 310, 24, 12, y_offset=y_offset)
    store = make_run_store(X, Y, n_runs=3, n_folds=4)
    fits = {}
    for prefetch in (True, False):
        enc = BrainEncoder(n_folds=4, device_memory_budget=1,
                           chunk_rows=37, prefetch=prefetch).fit(store=store)
        assert enc.report_.decision.method == "chunked"
        assert enc.stream_stats_["prefetch"] is prefetch
        assert enc.stream_stats_["compile_count"] <= 1  # 0 on a warm cache
        fits[prefetch] = enc
    assert (fits[True].report_.best_lambda[0]
            == fits[False].report_.best_lambda[0])
    np.testing.assert_array_equal(np.asarray(fits[True].weights_),
                                  np.asarray(fits[False].weights_))
    ref = BrainEncoder(n_folds=4).fit(jnp.asarray(X), jnp.asarray(Y))
    assert fits[True].report_.best_lambda[0] == ref.report_.best_lambda[0]


def test_fit_store_prefetch_sharded_lambda_parity(make_run_store):
    """Shard counts {1, 2, 8} with prefetch on or off all select the
    identical λ: prefetch is bit-identical per shard window, and the
    shard split only changes the (Chan) combine tree."""
    X, Y = _make_problem(24, 290, 16, 8)
    store = make_run_store(X, Y, n_runs=3, n_folds=4)
    cfg = RidgeCVConfig(n_folds=4)
    lams = set()
    for shards in (1, 2, 8):
        for prefetch in (True, False):
            streams = [store.iter_chunks(41, row_range=(lo, hi),
                                         prefetch=prefetch)
                       for lo, hi in foldstats.shard_row_ranges(290, shards)]
            stats = foldstats.compute_sharded_chunked(streams, 290, 4,
                                                      chunk_rows=41)
            lams.add(float(ridge.ridge_cv_from_stats(stats, cfg)
                           .best_lambda))
    assert len(lams) == 1


def test_prefetch_reader_exception_propagates(make_run_store, monkeypatch):
    """A reader-thread failure re-raises in the consumer and the thread
    shuts down (no hung fit, no zombie reader)."""
    X, Y = _make_problem(25, 60, 6, 4)
    store = make_run_store(X, Y, n_runs=3)
    real_mmap = store._mmap

    def broken(r):
        if r.row_offset > 0:
            raise OSError("disk pulled mid-stream")
        return real_mmap(r)

    monkeypatch.setattr(store, "_mmap", broken)
    pf = store.iter_chunks(10, prefetch=True)
    with pytest.raises(OSError, match="disk pulled"):
        for _ in pf:
            pass
    assert pf._thread is None                     # joined by close()
    # The streaming fit surfaces the same error instead of hanging.
    def always_broken(r):
        raise OSError("gone")

    monkeypatch.setattr(store, "_mmap", always_broken)
    with pytest.raises(OSError, match="gone"):
        BrainEncoder(n_folds=5, device_memory_budget=1).fit(store=store)


def test_prefetch_close_on_early_abort(make_run_store):
    """Abandoning a prefetched stream mid-fit stops the reader thread and
    releases the staging buffers — close() is idempotent."""
    X, Y = _make_problem(26, 80, 6, 4)
    store = make_run_store(X, Y, n_runs=2)
    pf = store.iter_chunks(7, prefetch=True)
    next(pf)                                      # reader is now running
    thread = pf._thread
    assert thread is not None and thread.is_alive()
    pf.close()
    assert not thread.is_alive() and pf._thread is None
    assert pf._bufs is None
    pf.close()                                    # idempotent
    with pytest.raises(StopIteration):            # closed stream is done
        next(pf)
    # The compute_chunked consumer closes on its own failure path too.
    pf2 = store.iter_chunks(7, prefetch=True)
    with pytest.raises(ValueError, match="row_stop"):
        foldstats.compute_chunked(pf2, 40, 4)     # n_total lies: overrun
    assert pf2._thread is None


def test_prefetch_yields_read_only_views(make_run_store):
    X, Y = _make_problem(27, 30, 4, 3)
    store = make_run_store(X, Y)
    pf = store.iter_chunks(10, prefetch=True)
    X_c, _ = next(pf)
    with pytest.raises(ValueError):
        X_c[0, 0] = 1.0
    pf.close()
    with pytest.raises(ValueError, match="depth"):
        store.iter_chunks(10, prefetch=True, prefetch_depth=0)


def test_iter_chunks_aligned_dtype_returns_memmap_view(make_run_store):
    """No host copy for the aligned-dtype case: chunks inside one run are
    views of the memmap itself, with or without an explicit dtype that
    matches the stored one."""
    X, Y = _make_problem(28, 40, 4, 3)
    store = make_run_store(X, Y, n_runs=2)       # runs of 20 rows

    def is_memmap_view(a):
        while a is not None:
            if isinstance(a, np.memmap):
                return True
            a = getattr(a, "base", None)
        return False

    for kwargs in ({}, {"dtype": np.float32}, {"dtype": "float32"}):
        X_c, Y_c = next(store.iter_chunks(10, **kwargs))
        assert is_memmap_view(X_c) and not X_c.flags.owndata, kwargs
        assert is_memmap_view(Y_c) and not Y_c.flags.owndata, kwargs
    # A real cast still converts (and therefore allocates a fresh array).
    X_c, _ = next(store.iter_chunks(10, dtype=np.float64))
    assert X_c.dtype == np.float64 and X_c.flags.owndata


# ---------------------------------------------------------------------------
# RunStore: round-trip, chunk iteration, manifest validation
# ---------------------------------------------------------------------------

def test_store_round_trip_and_chunk_iteration(make_run_store):
    X, Y = _make_problem(5, 57, 6, 4)
    store = make_run_store(X, Y, n_runs=3)
    assert store.shape == (57, 6, 4)
    Xl, Yl = store.load()
    np.testing.assert_array_equal(Xl, X)
    np.testing.assert_array_equal(Yl, Y)
    for chunk in (1, 10, 57, 100):                # incl. run-straddling
        xs = [c for c, _ in store.iter_chunks(chunk)]
        assert all(len(c) <= chunk for c in xs)
        np.testing.assert_array_equal(np.concatenate(xs), X)
    # Windowed stream (the sharded path's per-shard slice).
    xs = [c for c, _ in store.iter_chunks(8, row_range=(13, 41))]
    np.testing.assert_array_equal(np.concatenate(xs), X[13:41])


def test_store_read_only_semantics(make_run_store):
    X, Y = _make_problem(6, 30, 4, 3)
    store = make_run_store(X, Y)
    X_c, _ = next(store.iter_chunks(10))
    with pytest.raises(ValueError):               # read-only memmap view
        X_c[0, 0] = 1.0
    with pytest.raises(StoreError, match="read-only"):
        store.write(X, Y, "new-run")


def test_store_bf16_round_trip(make_run_store):
    """bf16 shards survive .npy storage (stored as u16 bit patterns)."""
    X, Y = _make_problem(7, 24, 4, 3, dtype=jnp.bfloat16)
    store = make_run_store(np.asarray(X), np.asarray(Y))
    X_c, Y_c = next(store.iter_chunks(24))
    assert jnp.asarray(X_c).dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(X_c, np.float32),
                                  np.asarray(X, np.float32))


def test_store_write_validation(tmp_path):
    X, Y = _make_problem(8, 20, 4, 3)
    store = RunStore.create(str(tmp_path / "s"))
    store.write(X, Y, "r1")
    with pytest.raises(StoreError, match="already written"):
        store.write(X, Y, "r1")
    with pytest.raises(StoreError, match="columns"):
        store.write(X[:, :2], Y, "r2")
    with pytest.raises(StoreError, match="matching 2-D"):
        store.write(X[:10], Y, "r3")
    with pytest.raises(StoreError, match="already exists"):
        RunStore.create(str(tmp_path / "s"))
    with pytest.raises(StoreError, match="no manifest"):
        RunStore.open(str(tmp_path / "nowhere"))


def test_store_manifest_validation(tmp_path, make_run_store):
    X, Y = _make_problem(9, 30, 4, 3)

    def tamper(mutate):
        store = make_run_store(X, Y, n_runs=2)
        path = os.path.join(store.root, "manifest.json")
        with open(path) as f:
            m = json.load(f)
        mutate(m, store.root)
        with open(path, "w") as f:
            json.dump(m, f)
        return store.root

    # Overlapping row ranges.
    root = tamper(lambda m, r: m["runs"][1].update(row_offset=5))
    with pytest.raises(StoreError, match="overlaps or gaps"):
        RunStore.open(root)
    # Shape mismatch (manifest lies about the row count).
    root = tamper(lambda m, r: m["runs"][0].update(n_rows=7, row_offset=0)
                  or m["runs"][1].update(row_offset=7))
    with pytest.raises(StoreError, match="shape"):
        RunStore.open(root)
    # Dtype mismatch.
    root = tamper(lambda m, r: m.update(dtype_x="float64"))
    with pytest.raises(StoreError, match="dtype"):
        RunStore.open(root)
    # Missing shard.
    root = tamper(lambda m, r: os.remove(os.path.join(r, "run-000.X.npy")))
    with pytest.raises(StoreError, match="missing X shard"):
        RunStore.open(root)
    # Unsupported manifest version.
    root = tamper(lambda m, r: m.update(version=99))
    with pytest.raises(StoreError, match="version"):
        RunStore.open(root)


def test_store_materialize_synthetic(tmp_path):
    from repro.data import fmri
    spec = fmri.SubjectSpec(n=100, p=8, t=6)
    store = RunStore.create(str(tmp_path / "syn"))
    store.materialize_synthetic(spec, rows_per_run=32)
    store = RunStore.open(str(tmp_path / "syn"))
    assert store.shape == (100, 8, 6)
    assert len(store.runs) == 4                   # 32+32+32+4 rows
    assert store.runs[-1].n_rows == 4


# ---------------------------------------------------------------------------
# Dispatch: memory-budgeted routing
# ---------------------------------------------------------------------------

def test_dispatch_memory_budget_pins_chunked():
    n, p, t = 10_000, 64, 128
    need = estimated_resident_bytes(n, p, t)
    assert need == n * (p + t) * 4
    d = resolve(EncoderConfig(device_memory_budget=need - 1), n, p, t, 1)
    assert (d.solver, d.method) == ("ridge", "chunked")
    assert "device_memory_budget" in d.rationale
    d = resolve(EncoderConfig(device_memory_budget=need + 1), n, p, t, 1)
    assert d.method != "chunked"
    # No budget → never chunked.
    d = resolve(EncoderConfig(), n, p, t, 1)
    assert d.method != "chunked"
    # Pinned incompatible method cannot stream.
    with pytest.raises(ValueError, match="primal/eigh only"):
        resolve(EncoderConfig(device_memory_budget=1, method="dual"),
                n, p, t, 1)
    # Pinned non-ridge solvers keep their own dispatch (budget ignored).
    d = resolve(EncoderConfig(device_memory_budget=1, solver="mor"),
                n, p, t, 1)
    assert d.solver == "mor"


def test_dispatch_budget_shards_over_devices():
    d = resolve(EncoderConfig(device_memory_budget=1), 1000, 8, 4, 4)
    assert d.method == "chunked" and d.data_shards == 4
    d = resolve(EncoderConfig(device_memory_budget=1, data_shards=2),
                1000, 8, 4, 4)
    assert d.data_shards == 2


# ---------------------------------------------------------------------------
# Store-backed fits: λ bit-identical to in-memory
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("y_offset", [0.0, 3.0])
def test_fit_store_matches_fit_in_memory(make_run_store, y_offset):
    """Streamed fit(store=) vs materialised fit(X, Y): λ bit-identical,
    weights to f32 tolerance — standardized and offset targets."""
    X, Y = _make_problem(10, 310, 24, 12, y_offset=y_offset)
    store = make_run_store(X, Y, n_runs=3, n_folds=4)
    ref = BrainEncoder(n_folds=4).fit(jnp.asarray(X), jnp.asarray(Y))
    enc = BrainEncoder(n_folds=4, device_memory_budget=1,
                       chunk_rows=37).fit(store=store)
    assert enc.report_.decision.method == "chunked"
    assert enc.report_.best_lambda[0] == ref.report_.best_lambda[0]
    np.testing.assert_allclose(np.asarray(enc.weights_),
                               np.asarray(ref.weights_), rtol=1e-4,
                               atol=1e-4)


def test_fit_store_bf16(make_run_store):
    X, Y = _make_problem(11, 200, 16, 8, noise=0.5)
    Xb, Yb = (np.asarray(jnp.asarray(a, jnp.bfloat16)) for a in (X, Y))
    store = make_run_store(Xb, Yb, n_runs=2, n_folds=3)
    ref = BrainEncoder(n_folds=3).fit(jnp.asarray(Xb), jnp.asarray(Yb))
    enc = BrainEncoder(n_folds=3, device_memory_budget=1,
                       chunk_rows=64).fit(store=store)
    assert enc.report_.best_lambda[0] == ref.report_.best_lambda[0]
    np.testing.assert_allclose(np.asarray(enc.weights_),
                               np.asarray(ref.weights_), rtol=5e-2,
                               atol=5e-2)


def test_fit_store_rejects_fold_split_mismatch(make_run_store):
    """The manifest's fold split is a data contract: a config that
    disagrees raises instead of silently running a different CV."""
    X, Y = _make_problem(16, 60, 6, 4)
    store = make_run_store(X, Y, n_folds=3)
    with pytest.raises(ValueError, match="n_folds=3"):
        BrainEncoder(n_folds=5, device_memory_budget=1).fit(store=store)
    with pytest.raises(ValueError, match="n_folds=3"):
        BrainEncoder(n_folds=5).fit_chunks(store)
    with pytest.raises(ValueError, match="n_folds=3"):
        pipeline.run_store(store, EncoderConfig(n_folds=5))


def test_fit_store_transparent_when_budget_fits(make_run_store):
    """A store that fits the budget routes through ordinary dispatch."""
    X, Y = _make_problem(12, 120, 8, 6)
    store = make_run_store(X, Y, n_folds=3)
    enc = BrainEncoder(n_folds=3, device_memory_budget=10**9).fit(store=store)
    assert enc.report_.decision.method != "chunked"
    ref = BrainEncoder(n_folds=3).fit(jnp.asarray(X), jnp.asarray(Y))
    np.testing.assert_allclose(np.asarray(enc.weights_),
                               np.asarray(ref.weights_), rtol=1e-5,
                               atol=1e-5)
    with pytest.raises(ValueError, match="not both"):
        BrainEncoder().fit(jnp.asarray(X), jnp.asarray(Y), store=store)
    with pytest.raises(ValueError, match="needs n_total"):
        BrainEncoder().fit_chunks(iter([(X, Y)]))


# ---------------------------------------------------------------------------
# Streaming pipeline: two-pass standardize + fit without residency
# ---------------------------------------------------------------------------

def test_pipeline_run_store_standardizes_from_moments(make_run_store):
    """run_store ≡ standardize() → fit() on materialised rows."""
    X, Y = _make_problem(13, 260, 12, 8, y_offset=5.0)
    store = make_run_store(X, Y, n_runs=2, n_folds=4)
    state = pipeline.run_store(store, EncoderConfig(n_folds=4),
                               chunk_rows=49)
    mu_x, sd_x = X.mean(0), X.std(0) + 1e-6
    mu_y, sd_y = Y.mean(0), Y.std(0) + 1e-6
    ref = BrainEncoder(n_folds=4).fit(jnp.asarray((X - mu_x) / sd_x),
                                      jnp.asarray((Y - mu_y) / sd_y))
    assert state.report.best_lambda[0] == ref.report_.best_lambda[0]
    np.testing.assert_allclose(np.asarray(state.encoder.weights_),
                               np.asarray(ref.weights_), rtol=5e-4,
                               atol=5e-4)


def test_pipeline_fit_chunked_requires_source():
    with pytest.raises(ValueError, match="store or state.X"):
        pipeline.fit_chunked()(pipeline.PipelineState(X=None, Y=None))


def test_column_moments_matches_numpy():
    rng = np.random.default_rng(14)
    A = rng.normal(size=(123, 7)) * 3 + 11
    cm = foldstats.ColumnMoments()
    for lo in range(0, 123, 17):
        cm.update(A[lo:lo + 17])
    np.testing.assert_allclose(cm.mean, A.mean(0), rtol=1e-9)
    np.testing.assert_allclose(cm.std(0.0), A.std(0), rtol=1e-9)


# ---------------------------------------------------------------------------
# ridge_cv_from_stats on sharded-chunked stats: λ parity end to end
# ---------------------------------------------------------------------------

def test_ridge_cv_from_sharded_stats_lambda_parity():
    X, Y = _make_problem(15, 190, 20, 10)
    cfg = RidgeCVConfig(n_folds=5)
    ref = ridge.ridge_cv(jnp.asarray(X), jnp.asarray(Y), cfg)
    for S, chunk in ((2, 31), (8, 1), (3, 190)):
        streams = [_chunk_stream(X, Y, lo, hi, chunk)
                   for lo, hi in foldstats.shard_row_ranges(190, S)]
        stats = foldstats.compute_sharded_chunked(streams, 190, 5)
        res = ridge.ridge_cv_from_stats(stats, cfg)
        assert float(res.best_lambda) == float(ref.best_lambda), (S, chunk)
        np.testing.assert_allclose(np.asarray(res.weights),
                                   np.asarray(ref.weights), rtol=1e-4,
                                   atol=1e-4)
