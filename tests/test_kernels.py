"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode).

Sweeps shapes (aligned and ragged) and dtypes per the kernel test policy.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import gram as gram_k
from repro.kernels import pearsonr as pearson_k
from repro.kernels import ref
from repro.kernels import ridge_solve as solve_k


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    # f32: blocked reduction order differs from the one-shot oracle matmul.
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-4, atol=2e-4)


SHAPES_XTY = [
    (64, 32, 48),      # ragged, smaller than one tile
    (300, 129, 70),    # non-multiples of every block dim
    (1024, 256, 256),  # exact tile multiples
]


@pytest.mark.parametrize("n,p,q", SHAPES_XTY)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_xty_matches_oracle(n, p, q, dtype):
    kx, ky = jax.random.split(jax.random.PRNGKey(n + p + q))
    x = _rand(kx, (n, p), dtype)
    y = _rand(ky, (n, q), dtype)
    got = gram_k.xty(x, y, block_n=128, block_p=128, interpret=True)
    want = ref.xty(x, y)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(dtype))


def _primitive_names(jaxpr):
    """All primitive names in a (closed) jaxpr, recursing through pjit/call
    sub-jaxprs — the view that exposes hidden pad/slice copies."""
    names = set()
    for eqn in jaxpr.jaxpr.eqns:
        names.add(eqn.primitive.name)
        for v in eqn.params.values():
            if hasattr(v, "jaxpr"):
                names |= _primitive_names(v)
    return names


def test_xty_aligned_traces_no_pad_or_slice():
    # Tile-aligned inputs must take the zero-copy fast path: no jnp.pad
    # round-trip in, no slice back out — the arrays feed pallas_call as-is.
    x = _rand(jax.random.PRNGKey(0), (1024, 256), jnp.float32)
    y = _rand(jax.random.PRNGKey(1), (1024, 256), jnp.float32)
    prims = _primitive_names(jax.make_jaxpr(
        lambda a, b: gram_k.xty(a, b, block_n=128, block_p=128,
                                interpret=True))(x, y))
    assert "pad" not in prims and "slice" not in prims
    # Ragged inputs still pad in and slice out (the correctness path).
    xr = _rand(jax.random.PRNGKey(2), (300, 129), jnp.float32)
    yr = _rand(jax.random.PRNGKey(3), (300, 70), jnp.float32)
    prims = _primitive_names(jax.make_jaxpr(
        lambda a, b: gram_k.xty(a, b, block_n=128, block_p=128,
                                interpret=True))(xr, yr))
    assert "pad" in prims and "slice" in prims


@pytest.mark.parametrize("m,p,q,s", [(24, 16, 8, 3), (37, 5, 12, 4),
                                     (64, 32, 32, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_xty_folds_masked_matches_oracle(m, p, q, s, dtype):
    kx, kz = jax.random.split(jax.random.PRNGKey(m + p + q + s))
    x = _rand(kx, (m, p), dtype)
    z = _rand(kz, (m, q), dtype)
    slots = np.random.default_rng(s).integers(0, s, size=m)
    onehot = jnp.asarray(np.eye(s, dtype=np.float32)[slots])
    got = gram_k.xty_folds_masked(x, z, onehot, block_n=8, block_p=128,
                                  interpret=True)
    want = jnp.einsum("ms,mp,mq->spq", onehot,
                      x.astype(jnp.float32), z.astype(jnp.float32))
    assert got.shape == (s, p, q) and got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               **_tol(dtype))


@pytest.mark.parametrize("n,p", [(200, 64), (64, 200), (257, 128)])
def test_gram_symmetric_and_correct(n, p):
    x = _rand(jax.random.PRNGKey(0), (n, p), jnp.float32)
    got = np.asarray(gram_k.gram(x, block_n=128, block_p=128, interpret=True))
    np.testing.assert_allclose(got, np.asarray(ref.gram(x)), rtol=2e-5,
                               atol=2e-5)
    np.testing.assert_allclose(got, got.T, rtol=1e-5, atol=1e-5)


def test_gram_f32_accumulation_beats_naive_bf16():
    """The kernel's f32 accumulator must track the float64 answer much more
    closely than a pure-bf16 matmul does (DESIGN §2 f64→f32 adaptation)."""
    x64 = np.random.default_rng(0).normal(size=(2048, 64)) * 10.0
    x = jnp.asarray(x64, jnp.bfloat16)
    exact = x64.T.astype(np.float64) @ x64.astype(np.float64)
    kernel = np.asarray(gram_k.gram(x, interpret=True), np.float64)
    kern_err = np.abs(kernel - exact).mean()
    # bf16 inputs: error dominated by input rounding, but accumulation must
    # not blow up with n.
    assert kern_err / np.abs(exact).mean() < 2e-2


SHAPES_SOLVE = [
    (32, 24, 3),       # tiny ragged
    (130, 70, 11),     # paper's grid size, ragged dims
    (256, 128, 4),     # aligned
]


@pytest.mark.parametrize("p,t,r", SHAPES_SOLVE)
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_solve_lambda_grid_matches_oracle(p, t, r, dtype):
    key = jax.random.PRNGKey(p * t + r)
    k1, k2, k3 = jax.random.split(key, 3)
    # Realistic inputs: orthonormal Q and positive eigenvalues.
    m = jax.random.normal(k1, (p, p), jnp.float32)
    q, _ = jnp.linalg.qr(m)
    evals = jnp.abs(jax.random.normal(k2, (p,))) * 10 + 0.1
    a = _rand(k3, (p, t), dtype)
    lams = jnp.asarray(np.logspace(-1, 3, r), jnp.float32)
    got = solve_k.solve_lambda_grid(q.astype(dtype), evals, a, lams,
                                    block_i=128, block_j=128, block_k=128,
                                    interpret=True)
    want = ref.solve_lambda_grid(q, evals, a, lams)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(dtype))


def test_solve_lambda_grid_equals_core_ridge_path():
    """Kernel output must equal the core library's solve_lambda_grid."""
    from repro.core import ridge
    key = jax.random.PRNGKey(7)
    X = jax.random.normal(key, (100, 32), jnp.float32)
    Y = jax.random.normal(jax.random.PRNGKey(8), (100, 16), jnp.float32)
    cfg = ridge.RidgeCVConfig(method="eigh", jitter=0.0,
                              lambdas=(0.1, 1.0, 100.0))
    f = ridge.factorize(X, cfg)
    rhs = ridge.gram_xty(X, Y)
    core = ridge.solve_lambda_grid(f, rhs, cfg.lambdas)
    a = jnp.matmul(f.basis.T, rhs)
    kern = solve_k.solve_lambda_grid(f.basis, f.evals, a,
                                     jnp.asarray(cfg.lambdas), interpret=True)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(core),
                               rtol=3e-4, atol=3e-4)


SHAPES_PEARSON = [(50, 17), (1000, 128), (333, 257)]


@pytest.mark.parametrize("n,t", SHAPES_PEARSON)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pearson_matches_oracle(n, t, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(n * t))
    yt = _rand(k1, (n, t), dtype)
    yp = 0.5 * yt + 0.5 * _rand(k2, (n, t), dtype)
    got = pearson_k.pearson_r(yt, yp, block_n=128, block_t=128,
                              interpret=True)
    want = ref.pearson_r(yt, yp)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **tol)
    assert bool(jnp.all(jnp.abs(got) <= 1.0 + 1e-4))


def test_pearson_perfect_correlation():
    y = _rand(jax.random.PRNGKey(0), (200, 64), jnp.float32)
    r = pearson_k.pearson_r(y, 2.0 * y + 1.0, interpret=True)
    np.testing.assert_allclose(np.asarray(r), 1.0, atol=1e-4)
    r_neg = pearson_k.pearson_r(y, -y, interpret=True)
    np.testing.assert_allclose(np.asarray(r_neg), -1.0, atol=1e-4)
