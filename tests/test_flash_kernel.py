"""Pallas flash-attention kernel vs dense oracle (interpret mode)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as fa
from repro.kernels import ref


def _inputs(bh, s, t, kd, dtype=jnp.float32, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k1, (bh, s, kd), jnp.float32) * kd ** -0.5
    k = jax.random.normal(k2, (bh, t, kd), jnp.float32)
    v = jax.random.normal(k3, (bh, t, kd), jnp.float32)
    return q.astype(dtype), k.astype(dtype), v.astype(dtype)


CASES = [
    # (s, t, kd, causal, window, softcap)
    (128, 128, 32, True, None, None),
    (128, 128, 32, True, 48, None),       # window smaller than block
    (128, 128, 32, True, None, 30.0),     # softcap
    (96, 96, 64, True, 40, 50.0),         # ragged + window + cap
    (64, 64, 32, False, None, None),      # non-causal (encoder)
    (256, 256, 128, True, 128, None),     # multi-block window
]


@pytest.mark.parametrize("s,t,kd,causal,window,softcap", CASES)
def test_flash_matches_dense_oracle(s, t, kd, causal, window, softcap):
    q, k, v = _inputs(3, s, t, kd)
    got = fa.flash_attention(q, k, v, causal=causal, window=window,
                             softcap=softcap, block_q=32, block_k=32,
                             interpret=True)
    want = ref.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.bfloat16])
def test_flash_bf16(dtype):
    q, k, v = _inputs(2, 128, 128, 64, dtype=dtype)
    got = fa.flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_flash_ragged_causal_padding():
    """S not a multiple of the block: causal masking must neutralise pad."""
    q, k, v = _inputs(2, 100, 100, 32, seed=5)
    got = fa.flash_attention(q, k, v, block_q=32, block_k=32, interpret=True)
    want = ref.flash_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_mha_flash_gqa_layout():
    """Model layout + GQA expansion matches the model's dense attention."""
    b, s, h, n_kv, kd = 2, 64, 8, 2, 32
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(k1, (b, s, h, kd)) * kd ** -0.5
    k = jax.random.normal(k2, (b, s, n_kv, kd))
    v = jax.random.normal(k3, (b, s, n_kv, kd))
    got = fa.mha_flash(q, k, v, n_kv, interpret=True, block_q=32, block_k=32)

    # dense GQA reference via the model's attention math
    g = h // n_kv
    qg = q.reshape(b, s, n_kv, g, kd)
    scores = jnp.einsum("bsngk,btnk->bngst", qg, k)
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngst,btnk->bsngk", p, v).reshape(b, s, h, kd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(out),
                               rtol=2e-4, atol=2e-4)
