"""Fused streamed kernel tier — parity, λ bit-identity, dispatch default.

The fused ``kernels.gram.xty_folds_masked`` path must be a drop-in for the
XLA einsum inside the fixed-shape masked chunk update: same statistics (to
f32 reduction-order tolerance) against a float64 oracle across the chunk
shapes that historically caused trouble (single-row, fold-misaligned,
ragged tails) for both stored dtypes and shard counts, BIT-identical λ
selection at f32, and the one-trace-per-stream compile contract intact.
The dispatch tests (quick lane) pin the tri-state auto default: on under
``REPRO_PALLAS_FORCE_INTERPRET``/TPU, off on plain CPU, explicit
True/False always wins, and the rationale names the tier.
"""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp
import ml_dtypes

from repro.core import foldstats, ridge
from repro.encoding import dispatch
from repro.encoding.config import EncoderConfig

N, P, T, K = 67, 5, 7, 4


def _make_problem(seed: int, dtype=np.float32):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(N, P)).astype(dtype)
    Y = rng.normal(size=(N, T)).astype(dtype)
    return X, Y


def _oracle_stats(X: np.ndarray, Y: np.ndarray, k: int):
    """Float64 per-fold G/C from the raw rows (what the kernel sees after
    input rounding — bf16 inputs are widened bf16 values, exactly)."""
    X64 = np.asarray(X, np.float64)
    Y64 = np.asarray(Y, np.float64)
    bounds = foldstats.fold_bounds(X.shape[0], k)
    G = np.stack([X64[lo:hi].T @ X64[lo:hi] for lo, hi in bounds])
    C = np.stack([X64[lo:hi].T @ Y64[lo:hi] for lo, hi in bounds])
    return G, C


def _shard_streams(store, n_shards: int, chunk: int):
    return [store.iter_chunks(chunk, row_range=(lo, hi))
            for lo, hi in foldstats.shard_row_ranges(N, n_shards)]


# chunk shapes: single-row, fold-misaligned (fold sizes are 17/16), ragged
CHUNKS = [1, 13, 29]


@pytest.mark.slow
@pytest.mark.parametrize("n_shards", [1, 8])
@pytest.mark.parametrize("dtype", [np.float32, ml_dtypes.bfloat16])
@pytest.mark.parametrize("chunk", CHUNKS)
def test_fused_stream_matches_f64_oracle(make_run_store, chunk, dtype,
                                         n_shards):
    X, Y = _make_problem(chunk * 100 + n_shards, dtype=dtype)
    store = make_run_store(X, Y, n_runs=2, n_folds=K)
    stats = foldstats.compute_sharded_chunked(
        _shard_streams(store, n_shards, chunk), N, K,
        chunk_rows=chunk, use_pallas=True)
    G64, C64 = _oracle_stats(X, Y, K)
    tol = (dict(rtol=2e-2, atol=2e-2) if dtype == ml_dtypes.bfloat16
           else dict(rtol=1e-4, atol=2e-4))
    np.testing.assert_allclose(np.asarray(stats.G), G64, **tol)
    np.testing.assert_allclose(np.asarray(stats.C), C64, **tol)
    np.testing.assert_allclose(np.asarray(stats.count),
                               [hi - lo for lo, hi in
                                foldstats.fold_bounds(N, K)])


@pytest.mark.slow
@pytest.mark.parametrize("chunk", CHUNKS)
def test_fused_lambda_selection_bit_identical_to_unfused(make_run_store,
                                                         chunk):
    X, Y = _make_problem(7)
    store = make_run_store(X, Y, n_runs=2, n_folds=K)
    cfg = ridge.RidgeCVConfig(n_folds=K)

    def fit(use_pallas: bool):
        stats = foldstats.compute_chunked(
            store.iter_chunks(chunk), N, K, chunk_rows=chunk,
            use_pallas=use_pallas)
        return ridge.ridge_cv_from_stats(stats, cfg)

    base, fused = fit(False), fit(True)
    assert float(base.best_lambda) == float(fused.best_lambda)
    np.testing.assert_allclose(np.asarray(fused.weights),
                               np.asarray(base.weights), rtol=1e-4,
                               atol=1e-4)


@pytest.mark.slow
def test_fused_stream_compiles_once():
    """The kernel tier rides INSIDE the one jitted masked update — a fused
    stream still traces exactly once however chunks meet fold bounds."""
    X, Y = _make_problem(3)
    # Distinctive (chunk, p, t, k) signature so the module-level jit cache
    # cannot already hold it.
    Xs, Ys = X[:, :4], Y[:, :6]
    before = foldstats.chunk_update_compile_count()
    foldstats.compute_chunked(
        [(Xs[i:i + 11], Ys[i:i + 11]) for i in range(0, N, 11)], N, 3,
        chunk_rows=11, use_pallas=True)
    assert foldstats.chunk_update_compile_count() - before == 1
    # A second fused stream over the same signature is a cache hit.
    foldstats.compute_chunked(
        [(Xs[i:i + 11], Ys[i:i + 11]) for i in range(0, N, 11)], N, 3,
        chunk_rows=11, use_pallas=True)
    assert foldstats.chunk_update_compile_count() - before == 1


@pytest.mark.slow
def test_colblock_fused_matches_unfused(make_run_store):
    from repro.wholebrain.solver import fit_wholebrain

    X, Y = _make_problem(11)
    store = make_run_store(X, Y, n_runs=2, n_folds=K)
    base = fit_wholebrain(store, EncoderConfig(n_folds=K, use_pallas=False),
                          t_block=3, chunk_rows=13)
    fused = fit_wholebrain(store, EncoderConfig(n_folds=K, use_pallas=True),
                           t_block=3, chunk_rows=13)
    assert float(base.best_lambda[0]) == float(fused.best_lambda[0])
    np.testing.assert_allclose(fused.weights, base.weights, rtol=1e-4,
                               atol=1e-4)
    assert fused.telemetry["use_pallas"] is True
    assert fused.telemetry["row_passes_x"] == 1
    assert fused.telemetry["colblock_compile_delta"] == 1


# ---------------------------------------------------------------------------
# Dispatch tri-state (quick lane — no kernels run)
# ---------------------------------------------------------------------------

def test_auto_defaults_off_on_plain_cpu(monkeypatch):
    monkeypatch.delenv("REPRO_PALLAS_FORCE_INTERPRET", raising=False)
    cfg = EncoderConfig()
    assert cfg.use_pallas is None
    assert cfg.resolve_use_pallas() is False
    d = dispatch.resolve(cfg, 100, 8, 16, 1)
    assert d.use_pallas is False
    assert "kernel tier: pallas OFF" in d.rationale


def test_auto_turns_on_under_forced_interpret(monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS_FORCE_INTERPRET", "1")
    cfg = EncoderConfig()
    assert cfg.resolve_use_pallas() is True
    d = dispatch.resolve(cfg, 100, 8, 16, 1)
    assert d.use_pallas is True
    assert "kernel tier: pallas ON" in d.rationale
    # The resolved flag feeds the low-level solver config too.
    assert cfg.ridge_cv_config("eigh").use_pallas is True


def test_explicit_pin_beats_auto(monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS_FORCE_INTERPRET", "1")
    off = dispatch.resolve(EncoderConfig(use_pallas=False), 100, 8, 16, 1)
    assert off.use_pallas is False and "pinned off" in off.rationale
    monkeypatch.delenv("REPRO_PALLAS_FORCE_INTERPRET")
    on = dispatch.resolve(EncoderConfig(use_pallas=True), 100, 8, 16, 1)
    assert on.use_pallas is True and "pinned on" in on.rationale


def test_decision_round_trips_with_kernel_tier(monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS_FORCE_INTERPRET", "1")
    d = dispatch.resolve(EncoderConfig(), 100, 8, 16, 1)
    again = dispatch.DispatchDecision(**dataclasses.asdict(d))
    assert again == d
    # Pre-existing serialized decisions (no use_pallas key) still load.
    legacy = dataclasses.asdict(d)
    del legacy["use_pallas"]
    assert dispatch.DispatchDecision(**legacy).use_pallas is False
