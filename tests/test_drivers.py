"""CLI driver integration tests (train/serve/encode/dryrun-help)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=600, env_extra=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.update(env_extra or {})
    return subprocess.run([sys.executable, "-m", *args],
                          capture_output=True, text=True, env=env,
                          timeout=timeout, cwd=REPO)


@pytest.mark.timeout(600)
def test_train_driver_smoke_with_checkpoint(tmp_path):
    ckpt = str(tmp_path / "ck")
    p = _run(["repro.launch.train", "--arch", "gemma2-2b", "--smoke",
              "--steps", "6", "--batch", "2", "--seq", "16",
              "--ckpt-dir", ckpt, "--ckpt-every", "3"])
    assert p.returncode == 0, p.stdout + p.stderr
    assert "done" in p.stdout
    steps = sorted(os.listdir(ckpt))
    assert "step_3" in steps and "step_6" in steps
    # loss decreased over the run
    losses = [float(l.split("loss=")[1].split()[0])
              for l in p.stdout.splitlines() if "loss=" in l]
    assert losses[-1] < losses[0], losses


@pytest.mark.timeout(600)
def test_serve_driver_smoke():
    p = _run(["repro.launch.serve", "--arch", "mamba2-130m", "--smoke",
              "--batch", "2", "--prompt-len", "8", "--gen", "6"])
    assert p.returncode == 0, p.stdout + p.stderr
    assert "decoded 6 tokens" in p.stdout


@pytest.mark.timeout(600)
def test_serve_driver_encoder_mode(tmp_path):
    """materialise → fit → save → serve loop: bundles land on disk, the
    service reports exactly one compiled predict for the single wave
    shape, and a second run reuses the saved bundles."""
    bundles = str(tmp_path / "bundles")
    argv = ["repro.launch.serve", "--encoders", "2", "--bundle-dir", bundles,
            "--n", "192", "--targets", "32", "--serve-steps", "3",
            "--wave-rows", "32", "--requests-per-step", "4"]
    p = _run(argv)
    assert p.returncode == 0, p.stdout + p.stderr
    assert "saved bundle" in p.stdout
    assert "compiled_predicts=1 (1 per wave shape)" in p.stdout
    assert sorted(os.listdir(bundles)) == ["sub-01", "sub-02"]
    p2 = _run(argv)
    assert p2.returncode == 0, p2.stdout + p2.stderr
    assert "reusing bundle" in p2.stdout


@pytest.mark.timeout(600)
def test_encode_driver_backbone(tmp_path):
    bundle = str(tmp_path / "bundle")
    p = _run(["repro.launch.encode", "--backbone", "vgg16", "--n", "400",
              "--targets", "64", "--save-bundle", bundle],
             env_extra={"XLA_FLAGS":
                        "--xla_force_host_platform_device_count=4"})
    assert p.returncode == 0, p.stdout + p.stderr
    assert "B-MOR fit" in p.stdout
    # --save-bundle drops the EncoderBundle + report.json provenance.
    assert os.path.exists(os.path.join(bundle, "bundle.json"))
    assert os.path.exists(os.path.join(bundle, "report.json"))
    assert "significant" in p.stdout
