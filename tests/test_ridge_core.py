"""Unit tests for the mutualised RidgeCV core (paper §2.3.1, §3)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import ridge
from repro.core.ridge import RidgeCVConfig


def _make_problem(key, n=120, p=24, t=16, noise=0.05, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    X = jax.random.normal(k1, (n, p), dtype)
    W = jax.random.normal(k2, (p, t), dtype) / np.sqrt(p)
    Y = X @ W + noise * jax.random.normal(k3, (n, t), dtype)
    return X, Y, W


def _ridge_closed_form(X, Y, lam):
    """float64 numpy oracle: W = (XᵀX+λI)⁻¹XᵀY."""
    X = np.asarray(X, np.float64)
    Y = np.asarray(Y, np.float64)
    p = X.shape[1]
    return np.linalg.solve(X.T @ X + lam * np.eye(p), X.T @ Y)


@pytest.mark.parametrize("method,n,p", [("eigh", 120, 24), ("dual", 24, 64)])
def test_solve_matches_closed_form(method, n, p):
    X, Y, _ = _make_problem(jax.random.PRNGKey(0), n=n, p=p, t=8)
    cfg = RidgeCVConfig(method=method, jitter=0.0)
    lam = 10.0
    factors = ridge.factorize(X, cfg)
    rhs = ridge.gram_xty(X, Y) if factors.primal else Y
    W = ridge.solve(factors, rhs, jnp.float32(lam),
                    X=None if factors.primal else X)
    W_ref = _ridge_closed_form(X, Y, lam)
    np.testing.assert_allclose(np.asarray(W), W_ref, rtol=2e-3, atol=2e-3)


def test_primal_and_dual_agree():
    X, Y, _ = _make_problem(jax.random.PRNGKey(1), n=60, p=40, t=4)
    lam = jnp.float32(50.0)
    fp = ridge.factorize(X, RidgeCVConfig(method="eigh", jitter=0.0))
    fd = ridge.factorize(X, RidgeCVConfig(method="dual", jitter=0.0))
    Wp = ridge.solve(fp, ridge.gram_xty(X, Y), lam)
    Wd = ridge.solve(fd, Y, lam, X=X)
    np.testing.assert_allclose(np.asarray(Wp), np.asarray(Wd),
                               rtol=2e-3, atol=2e-3)


def test_lambda_grid_matches_individual_solves():
    X, Y, _ = _make_problem(jax.random.PRNGKey(2), n=100, p=16, t=8)
    cfg = RidgeCVConfig(method="eigh", jitter=0.0)
    factors = ridge.factorize(X, cfg)
    rhs = ridge.gram_xty(X, Y)
    grid = (0.1, 1.0, 100.0)
    Ws = ridge.solve_lambda_grid(factors, rhs, grid)
    for i, lam in enumerate(grid):
        Wi = ridge.solve(factors, rhs, jnp.float32(lam))
        np.testing.assert_allclose(np.asarray(Ws[i]), np.asarray(Wi),
                                   rtol=1e-5, atol=1e-5)


def test_ridge_cv_selects_reasonable_lambda_and_recovers_weights():
    X, Y, W_true = _make_problem(jax.random.PRNGKey(3), n=300, p=24, t=12,
                                 noise=0.01)
    res = ridge.ridge_cv(X, Y, RidgeCVConfig(n_folds=4))
    # Low-noise, well-conditioned → small λ must win and weights ≈ truth.
    assert float(res.best_lambda) <= 1.0
    np.testing.assert_allclose(np.asarray(res.weights), np.asarray(W_true),
                               rtol=0.1, atol=0.05)
    assert res.cv_scores.shape == (len(ridge.PAPER_LAMBDA_GRID),)
    assert bool(jnp.all(jnp.isfinite(res.cv_scores)))


def test_high_noise_prefers_larger_lambda():
    X, Y, _ = _make_problem(jax.random.PRNGKey(4), n=40, p=32, t=8, noise=3.0)
    res = ridge.ridge_cv(X, Y, RidgeCVConfig(n_folds=4))
    assert float(res.best_lambda) >= 100.0
