"""Whole-brain target-streaming subsystem: block invariance, artifacts.

The lockdown contract: the column-blocked CV driver (``"global"`` λ mode)
is BIT-IDENTICAL to the unblocked ``ridge.ridge_cv_from_stats`` — same λ,
``np.testing.assert_array_equal`` on W — across block widths {one block,
ragged tail, many blocks}, f32 and bf16-as-u16 stores, chunk sizes, and
fold counts.  Property-based (hypothesis) where available, with a
fixed-seed grid that always runs (the ``test_oocore`` pattern).  Plus:
the ``BundleWriter`` streaming artifact path, lazy per-shard bundle
reads, the registry's shard-granular residency, windowed serving, and
the ``colblocked`` dispatch escalation.
"""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import foldstats, ridge
from repro.core.ridge import RidgeCVConfig
from repro.encoding import BrainEncoder, EncoderConfig, resolve
from repro.encoding.dispatch import chunked_stats_bytes, pick_target_block
from repro.encoding.estimator import EncodingReport
from repro.serving_encoders.bundle import BundleError, EncoderBundle
from repro.serving_encoders.registry import EncoderRegistry
from repro.serving_encoders.service import EncoderService, ServiceError
from repro.wholebrain import (
    BundleWriter, ColumnBlockAccumulator, colblock_update_compile_count,
    column_blocks, fit_wholebrain,
)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                # fixed-seed grid only
    HAVE_HYPOTHESIS = False


def _make_problem(seed, n, p, t, dtype=np.float32):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, p)).astype(np.float32)
    W = rng.normal(size=(p, t)).astype(np.float32) / np.sqrt(p)
    Y = (X @ W + 0.05 * rng.normal(size=(n, t))).astype(np.float32)
    if dtype == "bfloat16":
        X = np.asarray(jnp.asarray(X, jnp.bfloat16))
        Y = np.asarray(jnp.asarray(Y, jnp.bfloat16))
    return X, Y


def _reference(store, cfg):
    """The unblocked statistics solve on the same store."""
    stats = foldstats.compute_chunked(
        store.iter_chunks(cfg.chunk_rows), store.shape[0], cfg.n_folds,
        chunk_rows=cfg.chunk_rows)
    rcfg = RidgeCVConfig(lambdas=cfg.lambdas, n_folds=cfg.n_folds,
                         jitter=cfg.jitter, scoring=cfg.scoring,
                         method="eigh")
    return stats, ridge.ridge_cv_from_stats(stats, rcfg)


def _check_block_invariance(make_run_store, seed, n, p, t, t_block, k,
                            chunk, dtype=np.float32):
    """Core harness: blocked λ and W bitwise-equal the unblocked solve."""
    X, Y = _make_problem(seed, n, p, t, dtype=dtype)
    store = make_run_store(X, Y, n_folds=k)
    cfg = EncoderConfig(n_folds=k, chunk_rows=chunk)
    _, ref = _reference(store, cfg)
    res = fit_wholebrain(store, cfg, t_block=t_block)
    assert float(res.best_lambda[0]) == float(np.asarray(ref.best_lambda)), \
        f"λ diverged at t_block={t_block}"
    np.testing.assert_array_equal(
        res.weights, np.asarray(ref.weights),
        err_msg=f"W not bitwise at t_block={t_block} ({dtype})")
    return res


# ---------------------------------------------------------------------------
# Column blocking
# ---------------------------------------------------------------------------

def test_column_blocks_shapes():
    assert column_blocks(10, 4) == [(0, 4), (4, 8), (8, 10)]
    assert column_blocks(8, 4) == [(0, 4), (4, 8)]
    assert column_blocks(5, 99) == [(0, 5)]        # one covering block
    assert column_blocks(1, 1) == [(0, 1)]         # t_block >= t is exempt
    with pytest.raises(ValueError, match="t_block"):
        column_blocks(10, 1)                       # width-1 gemv hazard
    with pytest.raises(ValueError, match="t >= 1"):
        column_blocks(0, 4)


# ---------------------------------------------------------------------------
# Target-block invariance: fixed-seed lockdown grid (always runs)
# ---------------------------------------------------------------------------

# t=23: t_block 23 → one block; 8 → ragged tail (8, 8, 7); 4 → many
# blocks; 2 → the minimum legal width.
@pytest.mark.parametrize("t_block", [23, 8, 4, 2])
@pytest.mark.parametrize("dtype", [np.float32, "bfloat16"])
def test_target_block_invariance_fixed(make_run_store, t_block, dtype):
    _check_block_invariance(make_run_store, seed=0, n=96, p=7, t=23,
                            t_block=t_block, k=5, chunk=17, dtype=dtype)


def test_target_block_invariance_fold_misaligned(make_run_store):
    """Chunk straddles folds AND the tail block is ragged: n=97 (folds of
    20/20/19/19/19), chunks of 13, blocks of 9 over t=21."""
    _check_block_invariance(make_run_store, seed=1, n=97, p=6, t=21,
                            t_block=9, k=5, chunk=13)


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**16), t=st.integers(3, 25),
           t_block=st.integers(2, 30), k=st.integers(2, 6),
           chunk=st.integers(1, 40))
    def test_target_block_invariance_property(tmp_path_factory, seed, t,
                                              t_block, k, chunk):
        from repro.data.store import RunStore

        X, Y = _make_problem(seed, 64, 5, t)
        root = tmp_path_factory.mktemp("wb") / "store"
        store = RunStore.create(str(root), n_folds=k)
        store.write(X[:40], Y[:40], "r0")
        store.write(X[40:], Y[40:], "r1")
        store = RunStore.open(str(root))
        cfg = EncoderConfig(n_folds=k, chunk_rows=chunk)
        _, ref = _reference(store, cfg)
        res = fit_wholebrain(store, cfg, t_block=max(2, min(t_block, t)))
        assert float(res.best_lambda[0]) == float(
            np.asarray(ref.best_lambda))
        np.testing.assert_array_equal(res.weights, np.asarray(ref.weights))


# ---------------------------------------------------------------------------
# per_block λ mode + solver contracts
# ---------------------------------------------------------------------------

def test_per_block_matches_restricted_stats(make_run_store):
    """Each block's λ/W equals ridge_cv_from_stats on the column-restricted
    statistics — the B-MOR per-batch-λ semantics, streamed."""
    X, Y = _make_problem(2, 96, 6, 13)
    store = make_run_store(X, Y, n_folds=4)
    cfg = EncoderConfig(n_folds=4, chunk_rows=32)
    stats, _ = _reference(store, cfg)
    res = fit_wholebrain(store, cfg, t_block=5, lambda_mode="per_block")
    assert res.best_lambda.shape == (3,)
    assert res.cv_scores.shape == (3, len(cfg.lambdas))
    rcfg = RidgeCVConfig(lambdas=cfg.lambdas, n_folds=4, jitter=cfg.jitter,
                         scoring=cfg.scoring, method="eigh")
    for b, (lo, hi) in enumerate(res.block_bounds):
        sub = foldstats.FoldStats(
            G=stats.G, C=stats.C[:, :, lo:hi], xsum=stats.xsum,
            ysum=stats.ysum[:, lo:hi], ysq=stats.ysq[:, lo:hi],
            count=stats.count)
        rr = ridge.ridge_cv_from_stats(sub, rcfg)
        assert res.best_lambda[b] == float(np.asarray(rr.best_lambda))
        np.testing.assert_allclose(res.weights[:, lo:hi],
                                   np.asarray(rr.weights),
                                   rtol=2e-5, atol=2e-4)
        # λ-by-target expansion uses the REAL (ragged) bounds.
        assert (res.lambda_by_target[lo:hi] == res.best_lambda[b]).all()


def test_one_compile_across_blocks(make_run_store):
    """However many blocks stream, the column-block update traces at most
    once per (chunk, p, t_pad, k) signature — and zero on a repeat fit."""
    X, Y = _make_problem(3, 64, 5, 24)
    store = make_run_store(X, Y, n_folds=4)
    cfg = EncoderConfig(n_folds=4, chunk_rows=16)
    res = fit_wholebrain(store, cfg, t_block=6)            # 4 blocks
    assert res.telemetry["n_blocks"] == 4
    assert res.telemetry["colblock_compile_delta"] <= 1
    res2 = fit_wholebrain(store, cfg, t_block=6)           # warm cache
    assert res2.telemetry["colblock_compile_delta"] == 0


def test_fit_wholebrain_validation(make_run_store):
    X, Y = _make_problem(4, 40, 4, 6)
    store = make_run_store(X, Y, n_folds=3)
    cfg = EncoderConfig(n_folds=3)
    with pytest.raises(ValueError, match="t_block"):
        fit_wholebrain(store, cfg)                         # no block width
    with pytest.raises(ValueError, match="lambda_mode"):
        fit_wholebrain(store, cfg, t_block=3, lambda_mode="nope")
    with pytest.raises(ValueError, match="n_folds"):
        fit_wholebrain(store, EncoderConfig(n_folds=5), t_block=3)
    with pytest.raises(ValueError, match="ridge solver"):
        fit_wholebrain(store, EncoderConfig(n_folds=3, solver="bmor"),
                       t_block=3)
    # The row tier's un-standardized-target refusal, per block.
    Yoff = Y + 500.0
    store2 = make_run_store(X, Yoff, n_folds=3)
    with pytest.raises(ValueError, match="mean/std"):
        fit_wholebrain(store2, cfg, t_block=3)


def test_colblock_accumulator_grafts_bitwise(make_run_store):
    """ColumnBlockStats + the shared X-only pass == the fused full-width
    accumulation, bitwise, on the block's columns."""
    X, Y = _make_problem(5, 48, 5, 11)
    store = make_run_store(X, Y, n_folds=3)
    full = foldstats.compute_chunked(store.iter_chunks(16), 48, 3,
                                     chunk_rows=16)
    lo, hi = 4, 9
    acc = ColumnBlockAccumulator(48, 3, t_pad=5, chunk_rows=16)
    for Xc, Yc in store.iter_chunks(16, col_range=(lo, hi)):
        acc.update(Xc, Yc)
    b = acc.finalize()
    np.testing.assert_array_equal(np.asarray(b.C),
                                  np.asarray(full.C[:, :, lo:hi]))
    np.testing.assert_array_equal(np.asarray(b.ysum),
                                  np.asarray(full.ysum[:, lo:hi]))
    np.testing.assert_array_equal(np.asarray(b.ysq),
                                  np.asarray(full.ysq[:, lo:hi]))
    np.testing.assert_array_equal(np.asarray(b.count),
                                  np.asarray(full.count))


# ---------------------------------------------------------------------------
# Dispatch escalation
# ---------------------------------------------------------------------------

def test_dispatch_colblocked_escalation():
    n, p, t = 10_000, 64, 4_096
    # Budget below even the chunked tier's statistics → colblocked, with a
    # budget-derived block width.
    small = chunked_stats_bytes(5, p, t) // 2
    cfg = EncoderConfig(device_memory_budget=small)
    d = resolve(cfg, n, p, t, 1)
    assert d.method == "colblocked" and d.solver == "ridge"
    assert 2 <= d.target_block < t
    assert d.target_block == pick_target_block(small, 5, p, t)
    assert "colblocked" not in d.rationale  # rationale is prose
    assert "t_block" in d.rationale
    # Budget that fits the statistics but not the arrays → chunked.
    d2 = resolve(EncoderConfig(
        device_memory_budget=chunked_stats_bytes(5, p, t) * 2), n, p, t, 1)
    assert d2.method == "chunked" and d2.target_block is None
    # An explicit target_block opts in even when chunked would fit.
    d3 = resolve(EncoderConfig(
        device_memory_budget=chunked_stats_bytes(5, p, t) * 2,
        target_block=512), n, p, t, 1)
    assert d3.method == "colblocked" and d3.target_block == 512
    # Serialized decisions from before the field existed still round-trip.
    import dataclasses
    old = dataclasses.asdict(d2)
    old.pop("target_block")
    from repro.encoding.dispatch import DispatchDecision
    assert DispatchDecision(**old).target_block is None


def test_estimator_routes_colblocked(make_run_store):
    """fit(store=) under a colblocked decision matches the chunked path's
    report bitwise (same λ, same W)."""
    X, Y = _make_problem(6, 80, 6, 18)
    store = make_run_store(X, Y, n_folds=5)
    enc = BrainEncoder(EncoderConfig(n_folds=5, chunk_rows=32,
                                     device_memory_budget=1,
                                     target_block=7)).fit(store=store)
    assert enc.report_.decision.method == "colblocked"
    assert enc.stream_stats_["compile_count"] <= 1
    assert enc.stream_stats_["n_blocks"] == 3
    ref = BrainEncoder(EncoderConfig(
        n_folds=5, chunk_rows=32,
        device_memory_budget=chunked_stats_bytes(5, 6, 18) * 2)
        ).fit(store=store)
    assert ref.report_.decision.method == "chunked"
    np.testing.assert_array_equal(np.asarray(enc.report_.weights),
                                  np.asarray(ref.report_.weights))
    assert enc.report_.best_lambda == ref.report_.best_lambda


# ---------------------------------------------------------------------------
# Streaming artifact: BundleWriter
# ---------------------------------------------------------------------------

def _write_bundle(tmp_path, res, cfg, decision, name="bundle", **commit_kw):
    path = str(tmp_path / name)
    with BundleWriter(path, p=res.weights.shape[0],
                      t=res.weights.shape[1]) as w:
        for lo, hi in res.block_bounds:
            w.append(res.weights[:, lo:hi])
        report = EncodingReport(weights=None, best_lambda=res.best_lambda,
                                cv_scores=res.cv_scores, lambdas=cfg.lambdas,
                                decision=decision)
        w.commit(config=cfg, report=report,
                 lambda_by_target=res.lambda_by_target, **commit_kw)
    return path


def test_bundle_writer_round_trip(make_run_store, tmp_path):
    X, Y = _make_problem(7, 64, 5, 13)
    store = make_run_store(X, Y, n_folds=3)
    cfg = EncoderConfig(n_folds=3, chunk_rows=16, device_memory_budget=1,
                        target_block=6)
    decision = resolve(cfg, *store.shape, 1)
    res = fit_wholebrain(store, cfg, t_block=6)
    path = _write_bundle(tmp_path, res, cfg, decision)
    b = EncoderBundle.open(path)                     # full eager validation
    assert b.shape == (5, 13)
    assert b.weight_shard_bounds() == res.block_bounds
    assert b.decision().target_block == decision.target_block
    W = np.concatenate([b.load_weight_shard(i) for i in range(3)], axis=1)
    np.testing.assert_array_equal(W, res.weights)
    # Round-trip through the ordinary loader: predict parity.
    enc = b.load_encoder()
    np.testing.assert_array_equal(np.asarray(enc.weights_), res.weights)
    arrays = b.load_arrays(["lambda_by_target"])
    np.testing.assert_array_equal(arrays["lambda_by_target"],
                                  res.lambda_by_target)


def test_bundle_writer_bf16_and_errors(make_run_store, tmp_path):
    X, Y = _make_problem(8, 48, 4, 9)
    store = make_run_store(X, Y, n_folds=3)
    cfg = EncoderConfig(n_folds=3, chunk_rows=16, target_block=4)
    decision = resolve(EncoderConfig(n_folds=3, device_memory_budget=1,
                                     target_block=4), *store.shape, 1)
    res = fit_wholebrain(store, cfg, t_block=4)

    path = str(tmp_path / "bf16")
    with BundleWriter(path, p=4, t=9, weight_dtype="bfloat16") as w:
        for lo, hi in res.block_bounds:
            w.append(res.weights[:, lo:hi])
        w.commit(config=cfg, report=EncodingReport(
            weights=None, best_lambda=res.best_lambda,
            cv_scores=res.cv_scores, lambdas=cfg.lambdas,
            decision=decision))
    b = EncoderBundle.open(path)
    assert b.weight_dtype == jnp.bfloat16
    shard = b.load_weight_shard(0)
    assert jnp.asarray(shard).dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(shard),
        np.asarray(jnp.asarray(res.weights[:, :4]).astype(jnp.bfloat16)))

    # Incomplete coverage refuses to commit.
    with BundleWriter(str(tmp_path / "short"), p=4, t=9) as w:
        w.append(res.weights[:, :4])
        with pytest.raises(BundleError, match="cover"):
            w.commit(config=cfg, report=EncodingReport(
                weights=None, best_lambda=res.best_lambda,
                cv_scores=res.cv_scores, lambdas=cfg.lambdas,
                decision=decision))
    assert not os.path.exists(str(tmp_path / "short"))    # abort cleaned up
    # Wrong shard shape / overflow refuse at append.
    with BundleWriter(str(tmp_path / "bad"), p=4, t=9) as w:
        with pytest.raises(BundleError, match="p=4"):
            w.append(np.zeros((5, 3), np.float32))
        with pytest.raises(BundleError, match="overflow"):
            w.append(np.zeros((4, 10), np.float32))
    # Existing bundle refuses without overwrite=True.
    with pytest.raises(BundleError, match="overwrite"):
        BundleWriter(path, p=4, t=9)
    # No stray staging dirs left behind anywhere.
    assert not [d for d in os.listdir(tmp_path)
                if d.startswith(".tmpbundle_")]


def test_writer_solver_streaming_save(make_run_store, tmp_path):
    """writer= streams shards during the fit itself (collect=False →
    weights never assembled in memory) and the bundle round-trips."""
    X, Y = _make_problem(9, 64, 5, 14)
    store = make_run_store(X, Y, n_folds=3)
    cfg = EncoderConfig(n_folds=3, chunk_rows=16, target_block=6)
    decision = resolve(EncoderConfig(n_folds=3, device_memory_budget=1,
                                     target_block=6), *store.shape, 1)
    ref = fit_wholebrain(store, cfg, t_block=6)
    path = str(tmp_path / "streamed")
    with BundleWriter(path, p=5, t=14) as w:
        res = fit_wholebrain(store, cfg, t_block=6, writer=w,
                             collect=False)
        assert res.weights is None
        w.commit(config=cfg, report=EncodingReport(
            weights=None, best_lambda=res.best_lambda,
            cv_scores=res.cv_scores, lambdas=cfg.lambdas,
            decision=decision), lambda_by_target=res.lambda_by_target)
    b = EncoderBundle.open(path)
    W = np.concatenate([b.load_weight_shard(i, mmap=True)
                        for i in range(len(res.block_bounds))], axis=1)
    np.testing.assert_array_equal(W, ref.weights)


# ---------------------------------------------------------------------------
# Lazy shard reads + registry shard residency + windowed serving
# ---------------------------------------------------------------------------

@pytest.fixture
def wb_bundle(make_run_store, tmp_path):
    X, Y = _make_problem(10, 64, 5, 20)
    store = make_run_store(X, Y, n_folds=3)
    cfg = EncoderConfig(n_folds=3, chunk_rows=16, target_block=6)
    decision = resolve(EncoderConfig(n_folds=3, device_memory_budget=1,
                                     target_block=6), *store.shape, 1)
    res = fit_wholebrain(store, cfg, t_block=6)    # bounds 6/6/6/2
    path = _write_bundle(tmp_path, res, cfg, decision, name="wb")
    return path, res


def test_lazy_shard_access(wb_bundle):
    path, res = wb_bundle
    b = EncoderBundle.open(path)
    assert b.shards_for_columns(0, 6) == [0]
    assert b.shards_for_columns(5, 7) == [0, 1]
    assert b.shards_for_columns(18, 20) == [3]
    with pytest.raises(BundleError, match="window"):
        b.shards_for_columns(5, 25)
    with pytest.raises(BundleError, match="range"):
        b.load_weight_shard(4)
    mm = b.load_weight_shard(1, mmap=True)
    assert isinstance(mm, np.memmap)               # lazy: pages on touch
    np.testing.assert_array_equal(np.asarray(mm), res.weights[:, 6:12])
    with pytest.raises(BundleError, match="not in the checkpoint"):
        b.load_arrays(["nope"])


def test_registry_shard_granular_lru(wb_bundle):
    path, res = wb_bundle
    reg = EncoderRegistry(wave_rows=8)
    reg.add("m", path)
    got = reg.get_columns("m", (5, 13))            # shards 0, 1, 2
    assert [e.shard for e in got] == [0, 1, 2]
    assert reg.stats()["shard_loads"] == 3 and reg.stats()["loaded"] == 0
    reg.get_columns("m", (6, 12))                  # pure hit
    assert reg.stats()["shard_hits"] == 1 and reg.stats()["shard_loads"] == 3
    np.testing.assert_array_equal(np.asarray(got[1].W),
                                  res.weights[:, 6:12])
    # Shard-granular eviction: budget for ~2 shards drops LRU shards only.
    from repro.serving_encoders.registry import shard_resident_bytes
    per = shard_resident_bytes(reg.bundle("m"), 6, 8)
    small = EncoderRegistry(wave_rows=8, device_memory_budget=2 * per + 16)
    small.add("m", path)
    small.get_columns("m", (0, 12))                # shards 0, 1 resident
    small.get_columns("m", (12, 18))               # shard 2 evicts shard 0
    assert ("m", 0) not in small.loaded_shards
    assert ("m", 2) in small.loaded_shards
    assert small.evictions >= 1
    # evict(name) clears the model's shards too.
    assert small.evict("m")
    assert not small.loaded_shards


def test_service_predict_columns(wb_bundle):
    path, res = wb_bundle
    reg = EncoderRegistry(wave_rows=8)
    reg.add("m", path)
    svc = EncoderService(reg, wave_rows=8)
    rng = np.random.default_rng(0)
    X = rng.normal(size=(13, 5)).astype(np.float32)   # ragged final wave
    P = svc.predict_columns("m", X, (5, 13))
    assert P.shape == (13, 8)
    np.testing.assert_allclose(P, X @ res.weights[:, 5:13],
                               rtol=1e-5, atol=1e-5)
    # Only the overlapping shards were paged in.
    assert set(reg.loaded_shards) == {("m", 0), ("m", 1), ("m", 2)}
    # Fixed-shape waves: repeat with same shapes compiles nothing new.
    before = svc.compile_count
    svc.predict_columns("m", X, (5, 13))
    assert svc.compile_count == before
    with pytest.raises(ServiceError, match="window"):
        svc.predict_columns("m", X, (13, 5))
    with pytest.raises(ServiceError, match="features"):
        svc.predict_columns("m", X[:, :3], (5, 13))


def test_service_predict_columns_standardized(make_run_store, tmp_path):
    """μ/σ are applied per shard slice exactly as the full path does."""
    from repro.encoding.pipeline import Standardizer

    X, Y = _make_problem(11, 64, 4, 10)
    store = make_run_store(X, Y, n_folds=3)
    cfg = EncoderConfig(n_folds=3, chunk_rows=16, target_block=4)
    decision = resolve(EncoderConfig(n_folds=3, device_memory_budget=1,
                                     target_block=4), *store.shape, 1)
    res = fit_wholebrain(store, cfg, t_block=4)
    rng = np.random.default_rng(1)
    std = Standardizer()
    std.mu_x = rng.normal(size=(4,)).astype(np.float32)
    std.sd_x = (1 + rng.random(size=(4,))).astype(np.float32)
    std.mu_y = rng.normal(size=(10,)).astype(np.float32)
    std.sd_y = (1 + rng.random(size=(10,))).astype(np.float32)
    path = _write_bundle(tmp_path, res, cfg, decision, name="std",
                         standardizer=std)
    reg = EncoderRegistry(wave_rows=8)
    reg.add("m", path)
    svc = EncoderService(reg, wave_rows=8)
    Xq = rng.normal(size=(6, 4)).astype(np.float32)
    P = svc.predict_columns("m", Xq, (3, 9))
    # Same per-shard compiled wave → any window is a bitwise slice of the
    # full-width window.
    full = svc.predict_columns("m", Xq, (0, 10))
    np.testing.assert_array_equal(P, full[:, 3:9])
    manual = ((Xq - std.mu_x) / std.sd_x) @ res.weights * std.sd_y + std.mu_y
    np.testing.assert_allclose(P, manual[:, 3:9], rtol=1e-5, atol=1e-5)
