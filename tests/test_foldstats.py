"""Fold-statistics subsystem: downdating exactness, kernel, and CV parity.

Property-style float64-oracle checks that the single-pass per-fold
statistics and their downdated training splits equal directly-computed
statistics (primal, dual, sharded-masked; f32 and bf16 inputs), plus parity
of the rewritten ``ridge.ridge_cv`` against the seed per-fold
implementation (``ridge.ridge_cv_reference``) on every solver path.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import complexity, foldstats, ridge
from repro.core.ridge import RidgeCVConfig


def _make_problem(key, n, p, t, noise=0.05, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    X = jax.random.normal(k1, (n, p), dtype)
    W = jax.random.normal(k2, (p, t), dtype) / np.sqrt(p)
    Y = (X @ W + noise * jax.random.normal(k3, (n, t), dtype)).astype(dtype)
    return X, Y


def _tol(dtype):
    # bf16 inputs accumulate in f32 but quantise the operands first.
    return dict(rtol=2e-2, atol=2e-1) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-4)


# ---------------------------------------------------------------------------
# Downdated statistics vs float64 oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_downdated_primal_stats_match_f64_oracle(seed, dtype):
    n, p, t, k = 157, 12, 7, 5
    X, Y = _make_problem(jax.random.PRNGKey(seed), n, p, t, dtype=dtype)
    X64 = np.asarray(X, np.float64)
    Y64 = np.asarray(Y, np.float64)
    stats = foldstats.compute(X, Y, k)
    bounds = foldstats.fold_bounds(n, k)
    for f, (lo, hi) in enumerate(bounds):
        tr = np.r_[0:lo, hi:n]
        G_tr, C_tr = stats.train(f)
        np.testing.assert_allclose(np.asarray(G_tr),
                                   X64[tr].T @ X64[tr], **_tol(dtype))
        np.testing.assert_allclose(np.asarray(C_tr),
                                   X64[tr].T @ Y64[tr], **_tol(dtype))
        # Per-fold partials themselves.
        np.testing.assert_allclose(np.asarray(stats.G[f]),
                                   X64[lo:hi].T @ X64[lo:hi], **_tol(dtype))
    # Totals are the full-data refit statistics.
    np.testing.assert_allclose(np.asarray(stats.G_total), X64.T @ X64,
                               **_tol(dtype))
    np.testing.assert_allclose(np.asarray(stats.C_total), X64.T @ Y64,
                               **_tol(dtype))


@pytest.mark.parametrize("seed", [0, 1])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dual_kernel_blocks_match_f64_oracle(seed, dtype):
    """The dual mirror: per-fold K[tr, tr] blocks of one XXᵀ."""
    n, p, k = 37, 64, 4
    X, _ = _make_problem(jax.random.PRNGKey(seed + 10), n, p, 3, dtype=dtype)
    X64 = np.asarray(X, np.float64)
    K = ridge.xxt(X)
    for lo, hi in foldstats.fold_bounds(n, k):
        tr = np.r_[0:lo, hi:n]
        np.testing.assert_allclose(np.asarray(K[tr][:, tr]),
                                   X64[tr] @ X64[tr].T, **_tol(dtype))
        np.testing.assert_allclose(np.asarray(K[lo:hi][:, tr]),
                                   X64[lo:hi] @ X64[tr].T, **_tol(dtype))


@pytest.mark.parametrize("n,k", [(100, 5), (101, 5), (64, 3)])
def test_sharded_masked_partials_match_slice_partials(n, k):
    """The masked (traced-membership) accumulation used inside B-MOR's
    shard_map equals the static-slice accumulation, fold by fold."""
    X, Y = _make_problem(jax.random.PRNGKey(3), n, 10, 6)
    fold_ids = foldstats.fold_of_rows(jnp.arange(n), n, k)
    G_m, C_m = foldstats.partial_fold_stats(X, Y, fold_ids, k)
    stats = foldstats.compute(X, Y, k)
    np.testing.assert_allclose(np.asarray(G_m), np.asarray(stats.G),
                               rtol=2e-5, atol=2e-4)
    np.testing.assert_allclose(np.asarray(C_m), np.asarray(stats.C),
                               rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("n,k", [(10, 3), (100, 5), (101, 5), (7, 7)])
def test_fold_of_rows_matches_fold_bounds(n, k):
    ids = np.asarray(foldstats.fold_of_rows(jnp.arange(n), n, k))
    want = np.empty(n, np.int32)
    for f, (lo, hi) in enumerate(foldstats.fold_bounds(n, k)):
        want[lo:hi] = f
    np.testing.assert_array_equal(ids, want)


def test_chunked_accumulator_matches_single_pass():
    n, k = 203, 5
    X, Y = _make_problem(jax.random.PRNGKey(4), n, 12, 8)
    whole = foldstats.compute(X, Y, k)
    for chunk in (37, 64, 203):
        acc = foldstats.FoldStatsAccumulator(n, k)
        for lo in range(0, n, chunk):
            acc.update(X[lo:lo + chunk], Y[lo:lo + chunk])
        got = acc.finalize()
        for name in ("G", "C", "xsum", "ysum", "ysq", "count"):
            np.testing.assert_allclose(np.asarray(getattr(got, name)),
                                       np.asarray(getattr(whole, name)),
                                       rtol=2e-5, atol=2e-4)


def test_accumulator_rejects_bad_row_counts():
    acc = foldstats.FoldStatsAccumulator(10, 2)
    X, Y = _make_problem(jax.random.PRNGKey(5), 10, 4, 2)
    with pytest.raises(ValueError, match="overruns"):
        acc.update(X[:6], Y[:6]), acc.update(X, Y)
    with pytest.raises(ValueError, match="expected the full window"):
        foldstats.FoldStatsAccumulator(10, 2).finalize()


# ---------------------------------------------------------------------------
# ridge_cv (downdating) vs ridge_cv_reference (seed per-fold path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scoring", ["r2", "r"])
@pytest.mark.parametrize("shape", [(160, 24, 12), (30, 64, 6)])
def test_ridge_cv_parity_with_reference(shape, scoring):
    """λ selection identical, weights/scores equal to f32 tolerance —
    primal (n ≥ p) and dual (n < p), both scoring modes."""
    n, p, t = shape
    X, Y = _make_problem(jax.random.PRNGKey(6), n, p, t)
    cfg = RidgeCVConfig(n_folds=4, scoring=scoring)
    new = ridge.ridge_cv(X, Y, cfg)
    ref = ridge.ridge_cv_reference(X, Y, cfg)
    assert float(new.best_lambda) == float(ref.best_lambda)
    np.testing.assert_allclose(np.asarray(new.cv_scores),
                               np.asarray(ref.cv_scores), rtol=1e-3,
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(new.weights),
                               np.asarray(ref.weights), rtol=2e-3, atol=2e-3)


def test_ridge_cv_parity_bf16():
    X, Y = _make_problem(jax.random.PRNGKey(7), 150, 16, 8, noise=0.5)
    cfg = RidgeCVConfig(n_folds=3)
    new = ridge.ridge_cv(X.astype(jnp.bfloat16), Y.astype(jnp.bfloat16), cfg)
    ref = ridge.ridge_cv_reference(X.astype(jnp.bfloat16),
                                   Y.astype(jnp.bfloat16), cfg)
    assert float(new.best_lambda) == float(ref.best_lambda)
    np.testing.assert_allclose(np.asarray(new.weights),
                               np.asarray(ref.weights), rtol=5e-2, atol=5e-2)


def test_ridge_cv_parity_unstandardized_large_mean_targets():
    """Un-standardized targets with an intercept-bearing X: the centred
    trace-identity scoring must not cancel catastrophically (raw-moment
    expansions drift quadratically in the target mean here)."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(20), 3)
    X = jax.random.normal(k1, (310, 24), jnp.float32).at[:, 0].set(1.0)
    W = jax.random.normal(k2, (24, 12), jnp.float32) / 5
    base = X @ W + 0.05 * jax.random.normal(k3, (310, 12), jnp.float32)
    cfg = RidgeCVConfig(n_folds=5)
    for offset, score_atol in ((100.0, 5e-3), (1e4, None)):
        Y = base + offset
        new = ridge.ridge_cv(X, Y, cfg)
        ref = ridge.ridge_cv_reference(X, Y, cfg)
        assert float(new.best_lambda) == float(ref.best_lambda), offset
        # The out-of-core stats path (fit_chunks) scores from centred
        # sufficient statistics and must stay λ-stable here too.
        stats = foldstats.compute(X, Y, cfg.n_folds)
        from_stats = ridge.ridge_cv_from_stats(stats, cfg)
        assert float(from_stats.best_lambda) == float(ref.best_lambda), offset
        if score_atol is not None:
            np.testing.assert_allclose(np.asarray(new.cv_scores),
                                       np.asarray(ref.cv_scores),
                                       atol=score_atol)
            np.testing.assert_allclose(np.asarray(from_stats.cv_scores),
                                       np.asarray(ref.cv_scores),
                                       atol=score_atol)


def test_ridge_cv_high_noise_parity():
    """Ill-conditioned regime (n_train < p within folds): downdated path
    still selects the reference λ."""
    X, Y = _make_problem(jax.random.PRNGKey(8), 40, 32, 8, noise=3.0)
    cfg = RidgeCVConfig(n_folds=4)
    new = ridge.ridge_cv(X, Y, cfg)
    ref = ridge.ridge_cv_reference(X, Y, cfg)
    assert float(new.best_lambda) == float(ref.best_lambda)


def test_ridge_cv_from_stats_matches_ridge_cv():
    n, p, t = 190, 20, 10
    X, Y = _make_problem(jax.random.PRNGKey(9), n, p, t)
    for scoring in ("r2", "r"):
        cfg = RidgeCVConfig(n_folds=5, scoring=scoring)
        stats = foldstats.compute(X, Y, cfg.n_folds)
        a = ridge.ridge_cv_from_stats(stats, cfg)
        b = ridge.ridge_cv(X, Y, cfg)
        assert float(a.best_lambda) == float(b.best_lambda)
        np.testing.assert_allclose(np.asarray(a.cv_scores),
                                   np.asarray(b.cv_scores), rtol=1e-3,
                                   atol=1e-3)
        np.testing.assert_allclose(np.asarray(a.weights),
                                   np.asarray(b.weights), rtol=1e-4,
                                   atol=1e-4)
    with pytest.raises(ValueError, match="primal-only"):
        ridge.ridge_cv_from_stats(stats, RidgeCVConfig(method="dual"))


def test_bmor_single_shard_matches_reference_weights():
    """B-MOR (downdating via foldstats) on a 1-device mesh reproduces the
    seed single-shard refit weights at f32 tolerance."""
    from repro.core import bmor
    from repro.core.compat import make_mesh

    X, Y = _make_problem(jax.random.PRNGKey(10), 120, 16, 8, noise=0.01)
    cfg = RidgeCVConfig(n_folds=3)
    mesh = make_mesh((1, 1), ("data", "model"))
    res = bmor.bmor_fit(X, Y, mesh, cfg=cfg)
    ref = ridge.ridge_cv_reference(X, Y, cfg)
    assert float(res.best_lambda[0]) == float(ref.best_lambda)
    np.testing.assert_allclose(np.asarray(res.weights),
                               np.asarray(ref.weights), rtol=2e-3, atol=2e-3)


def test_encoder_fit_chunks_matches_fit():
    from repro.encoding import BrainEncoder

    X, Y = _make_problem(jax.random.PRNGKey(11), 310, 24, 12)
    enc = BrainEncoder(n_folds=4).fit(X, Y)
    chunks = ((X[i:i + 64], Y[i:i + 64]) for i in range(0, 310, 64))
    enc2 = BrainEncoder(n_folds=4).fit_chunks(chunks, n_total=310)
    assert enc2.report_.best_lambda[0] == enc.report_.best_lambda[0]
    np.testing.assert_allclose(np.asarray(enc2.weights_),
                               np.asarray(enc.weights_), rtol=1e-4,
                               atol=1e-4)
    assert enc2.report_.decision.solver == "ridge"
    # Pinned non-ridge solvers and pathological un-standardized targets are
    # rejected, not silently mis-fit.
    with pytest.raises(ValueError, match="single-shard ridge"):
        BrainEncoder(solver="bmor").fit_chunks([(X, Y)], n_total=310)
    with pytest.raises(ValueError, match="primal/eigh only"):
        BrainEncoder(bands=(12, 12)).fit_chunks([(X, Y)], n_total=310)
    with pytest.raises(ValueError, match="standardize"):
        BrainEncoder(n_folds=4).fit_chunks([(X, 1e5 + 0.01 * Y)],
                                           n_total=310)


# ---------------------------------------------------------------------------
# Pallas fold kernel (interpret mode on CPU → slow lane)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,k", [(203, 5), (64, 4)])
def test_xty_folds_kernel_matches_f64_oracle(n, k, dtype):
    from repro.kernels import ops

    X, Y = _make_problem(jax.random.PRNGKey(12), n, 24, 17, dtype=dtype)
    bounds = tuple(foldstats.fold_bounds(n, k))
    got = ops.xty_folds(X, Y, bounds)
    assert got.dtype == jnp.float32 and got.shape == (k, 24, 17)
    X64, Y64 = np.asarray(X, np.float64), np.asarray(Y, np.float64)
    want = np.stack([X64[lo:hi].T @ Y64[lo:hi] for lo, hi in bounds])
    np.testing.assert_allclose(np.asarray(got), want, **_tol(dtype))


@pytest.mark.slow
def test_foldstats_compute_pallas_path_matches():
    X, Y = _make_problem(jax.random.PRNGKey(13), 120, 16, 8)
    base = foldstats.compute(X, Y, 4)
    pall = foldstats.compute(X, Y, 4, use_pallas=True)
    np.testing.assert_allclose(np.asarray(pall.G), np.asarray(base.G),
                               rtol=2e-5, atol=2e-4)
    np.testing.assert_allclose(np.asarray(pall.C), np.asarray(base.C),
                               rtol=2e-5, atol=2e-4)


@pytest.mark.slow
def test_ridge_cv_dual_pallas_path_matches_xla():
    """use_pallas now covers the dual path too: XXᵀ and Xᵀα."""
    X, Y = _make_problem(jax.random.PRNGKey(14), 30, 64, 6)
    cfg = RidgeCVConfig(n_folds=3)
    base = ridge.ridge_cv(X, Y, cfg)
    pall = ridge.ridge_cv(X, Y, RidgeCVConfig(n_folds=3, use_pallas=True))
    assert float(base.best_lambda) == float(pall.best_lambda)
    np.testing.assert_allclose(np.asarray(pall.weights),
                               np.asarray(base.weights), rtol=2e-3,
                               atol=2e-3)


# ---------------------------------------------------------------------------
# Complexity model: the folded T_W term
# ---------------------------------------------------------------------------

def test_t_w_folded_is_k_independent_and_k_times_cheaper():
    for n, p, k in [(1000, 64, 5), (69_202, 16_384, 5), (512, 128, 10)]:
        w = complexity.RidgeWorkload(n=n, p=p, t=100, n_folds=k)
        assert complexity.t_w_folded(w) == float(n) * p * p
        np.testing.assert_allclose(complexity.fold_redundancy_factor(w), k)
        assert complexity.t_w_per_fold(w) == k * complexity.t_w_folded(w)


def test_dispatch_ridge_rationale_mentions_fold_savings():
    from repro.encoding import EncoderConfig, resolve
    d = resolve(EncoderConfig(), n=1000, p=100, t=500, device_count=1)
    assert "single-pass fold stats" in d.rationale
    assert d.predicted_cost > 0
