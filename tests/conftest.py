import numpy as np
import pytest


@pytest.fixture
def make_run_store(tmp_path):
    """Tiny ``RunStore`` factory: write (X, Y) into a tmp_path-backed store
    split into ``n_runs`` row shards, reopen read-only, return the store.

    ``factory(X, Y, n_runs=3)`` → validated, memory-mapped ``RunStore``.
    """
    from repro.data.store import RunStore

    counter = {"n": 0}

    def factory(X, Y, *, n_runs: int = 2, n_folds: int = 5):
        X, Y = np.asarray(X), np.asarray(Y)
        counter["n"] += 1
        root = tmp_path / f"run_store_{counter['n']}"
        store = RunStore.create(str(root), n_folds=n_folds, dtype=X.dtype)
        n = X.shape[0]
        bounds = [(i * n // n_runs, (i + 1) * n // n_runs)
                  for i in range(n_runs)]
        for i, (lo, hi) in enumerate(bounds):
            store.write(X[lo:hi], Y[lo:hi], f"run-{i:03d}")
        return RunStore.open(str(root))

    return factory


def pytest_configure(config):
    # pytest-timeout provides this marker when installed; register it so the
    # suite runs warning-free (and without the plugin, e.g. in this container).
    config.addinivalue_line(
        "markers", "timeout(seconds): per-test timeout (pytest-timeout)")
    # Slow lane: interpret-mode Pallas kernel tests (correct but orders of
    # magnitude slower than compiled).  CI's quick lane runs
    # ``pytest -m "not slow"``; the tier-1 gate still runs everything.
    config.addinivalue_line(
        "markers", "slow: interpret-mode Pallas / long-running tests "
                   "(excluded from the CI quick lane)")
