import pytest


def pytest_configure(config):
    # pytest-timeout provides this marker when installed; register it so the
    # suite runs warning-free (and without the plugin, e.g. in this container).
    config.addinivalue_line(
        "markers", "timeout(seconds): per-test timeout (pytest-timeout)")
    # Slow lane: interpret-mode Pallas kernel tests (correct but orders of
    # magnitude slower than compiled).  CI's quick lane runs
    # ``pytest -m "not slow"``; the tier-1 gate still runs everything.
    config.addinivalue_line(
        "markers", "slow: interpret-mode Pallas / long-running tests "
                   "(excluded from the CI quick lane)")
