import pytest


def pytest_configure(config):
    # pytest-timeout provides this marker when installed; register it so the
    # suite runs warning-free (and without the plugin, e.g. in this container).
    config.addinivalue_line(
        "markers", "timeout(seconds): per-test timeout (pytest-timeout)")
