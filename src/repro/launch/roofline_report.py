"""Roofline report generator: dry-run JSONL → EXPERIMENTS.md §Roofline table.

Per (arch × shape): the three roofline terms (seconds, per device), the
dominant bottleneck, MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference) with
N = active parameters (MoE experts scaled by top-k/E), and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs.

Usage: PYTHONPATH=src python -m repro.launch.roofline_report \
           results/dryrun.jsonl [--md]
"""
from __future__ import annotations

import argparse
import json
import math

from repro.launch.hlo_analysis import roofline_terms

_HINTS = {
    "compute": "raise MXU utilisation: bigger per-device batch, bf16 "
               "matmul fusion",
    "memory": "cut HBM traffic: fused/blockwise attention, avoid f32 "
              "intermediates, better remat policy",
    "collective": "overlap collectives with compute; reduce-scatter grads "
                  "(FSDP) instead of all-reduce; fewer µbatch reductions",
}


# Conservative single-socket CPU envelope for the out-of-core ridge bench
# (one core, f32 FMA): ~50 GFLOP/s compute, ~20 GB/s sustained DRAM/disk
# staging bandwidth.  Override from the bench CLI when the host is known.
CPU_PEAK_FLOPS = 50e9
CPU_MEM_BW = 20e9


def encoding_roofline(n: int, p: int, t: int, *, r: int = 11,
                      n_folds: int = 5, wall_s: float | None = None,
                      bytes_staged: int | None = None,
                      peak_flops: float = CPU_PEAK_FLOPS,
                      mem_bw: float = CPU_MEM_BW) -> dict:
    """Roofline placement of one out-of-core ridge-CV fit (paper §3 terms).

    Model FLOPs come from the analytic complexity model: the single-pass
    fold statistics (``n·p²`` Gram + ``n·p·t`` cross-moments,
    ``t_w_folded``), the mutualised factorisation ``T_M``, and the
    target application ``T_W`` — ×2 for multiply+add.  Bytes default to
    the streamed tier's actual staged traffic (``bytes_staged`` from the
    chunk prefetcher) so the reported arithmetic intensity is
    *achieved*, not nominal; pass ``wall_s`` to also get the achieved
    FLOP/s as a fraction of ``peak_flops``.  Purely informational — the
    benches report these numbers but never gate on them.
    """
    from repro.core.complexity import (RidgeWorkload, t_m, t_w, t_w_folded)

    w = RidgeWorkload(n=n, p=p, t=t, r=r, n_folds=n_folds)
    mults = t_w_folded(w) + float(n) * p * t + t_m(w) + t_w(w)
    flops = 2.0 * mults
    nbytes = int(bytes_staged) if bytes_staged else n * (p + t) * 4
    terms = roofline_terms(flops, nbytes, 0.0, peak_flops=peak_flops,
                           hbm_bw=mem_bw)
    out = {
        "model_flops": flops,
        "bytes": nbytes,
        "flop_per_byte": flops / nbytes if nbytes else float("nan"),
        "peak_flop_per_byte": peak_flops / mem_bw,
        "t_compute_s": terms["t_compute_s"],
        "t_memory_s": terms["t_memory_s"],
        "bottleneck": ("compute" if terms["t_compute_s"]
                       >= terms["t_memory_s"] else "memory"),
    }
    if wall_s:
        out["achieved_flops"] = flops / wall_s
        out["peak_fraction"] = flops / wall_s / peak_flops
    return out


def predict_roofline(rows: int, p: int, t: int, *,
                     wall_s: float | None = None,
                     bytes_staged: int | None = None,
                     peak_flops: float = CPU_PEAK_FLOPS,
                     mem_bw: float = CPU_MEM_BW) -> dict:
    """Roofline placement of one serving prediction pass (Ŷ = X·W).

    FLOPs are the ``2·rows·p·t`` matmul; bytes default to the nominal
    traffic — stream ``rows·(p+t)`` in/out plus one read of the ``p·t``
    weight shard — unless the serving loop reports its achieved
    ``bytes_staged``.  Same informational-only contract as
    ``encoding_roofline``.
    """
    flops = 2.0 * rows * p * t
    nbytes = (int(bytes_staged) if bytes_staged
              else rows * (p + t) * 4 + p * t * 4)
    terms = roofline_terms(flops, nbytes, 0.0, peak_flops=peak_flops,
                           hbm_bw=mem_bw)
    out = {
        "model_flops": flops,
        "bytes": nbytes,
        "flop_per_byte": flops / nbytes if nbytes else float("nan"),
        "peak_flop_per_byte": peak_flops / mem_bw,
        "t_compute_s": terms["t_compute_s"],
        "t_memory_s": terms["t_memory_s"],
        "bottleneck": ("compute" if terms["t_compute_s"]
                       >= terms["t_memory_s"] else "memory"),
    }
    if wall_s:
        out["achieved_flops"] = flops / wall_s
        out["peak_fraction"] = flops / wall_s / peak_flops
    return out


def active_params(arch: str) -> tuple[int, int]:
    """(total, active) parameter counts from the config tree."""
    from repro import configs
    from repro.models import build_model
    from repro.models.params import ParamDef, is_def
    import jax

    cfg = configs.get_config(arch)
    model = build_model(cfg)
    defs = model.param_defs()
    total = active = 0
    scale = (cfg.moe.top_k / cfg.moe.n_experts) if cfg.moe else 1.0
    for d in jax.tree_util.tree_leaves(defs, is_leaf=is_def):
        n = math.prod(d.shape)
        total += n
        active += int(n * scale) if "expert" in d.axes else n
    return total, active


def model_flops_per_device(arch: str, shape_name: str,
                           n_devices: int = 256) -> float:
    from repro.models.config import INPUT_SHAPES
    shape = INPUT_SHAPES[shape_name]
    _, n_active = active_params(arch)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens / n_devices
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens / n_devices
    # decode: one token per request
    return 2.0 * n_active * shape.global_batch / n_devices


def report(jsonl_path: str, md: bool = True) -> str:
    rows = []
    seen = set()
    for line in open(jsonl_path):
        r = json.loads(line)
        key = (r["arch"], r["shape"], r.get("mesh"), r.get("rules", "tp"))
        if key in seen:
            continue
        seen.add(key)
        if r.get("mesh") != "16x16":
            continue
        coll = sum(r["collective_bytes"].values())
        terms = roofline_terms(r["flops"], r["hlo_bytes"], coll)
        mf = model_flops_per_device(r["arch"], r["shape"])
        ratio = mf / r["flops"] if r["flops"] else float("nan")
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "rules": r.get("rules", "tp"),
            "tc": terms["t_compute_s"], "tm": terms["t_memory_s"],
            "tx": terms["t_collective_s"],
            "bottleneck": terms["bottleneck"],
            "model_flops": mf, "hlo_flops": r["flops"], "ratio": ratio,
            "temp_gb": r.get("memory", {}).get("temp_size_in_bytes", 0) / 1e9,
        })
    if not md:
        return json.dumps(rows, indent=1)
    out = ["| arch | shape | t_compute | t_memory | t_collective | "
           "bottleneck | 6ND/HLO | temp GB | next lever |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        out.append(
            f"| {r['arch']} | {r['shape']} ({r['rules']}) "
            f"| {r['tc']:.3e} | {r['tm']:.3e} | {r['tx']:.3e} "
            f"| **{r['bottleneck']}** | {r['ratio']:.2f} "
            f"| {r['temp_gb']:.1f} | {_HINTS[r['bottleneck']]} |")
    return "\n".join(out)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("jsonl")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    print(report(args.jsonl, md=not args.json))
