"""Serving driver: batched LLM decode, or the brain-encoder serving loop.

LLM mode (prefill + greedy decode)::

    python -m repro.launch.serve --arch <id> --smoke --batch 2 \
        --prompt-len 16 --gen 16

Encoder mode (materialise → fit → save → serve loop)::

    python -m repro.launch.serve --encoders 3 --bundle-dir /tmp/bundles \
        --serve-steps 5 --wave-rows 64

fits one ``BrainEncoder`` per synthetic subject, persists each as an
``EncoderBundle``, then serves wave-batched prediction traffic against the
bundle fleet through ``EncoderRegistry`` + ``EncoderService`` — the
"fit once, serve many" workflow end to end.
"""
from __future__ import annotations

import argparse
import time


def _run_encoder_mode(args) -> None:
    import numpy as np
    from repro.serving_encoders import EncoderRegistry, EncoderService
    from repro.serving_encoders.traffic import (build_synthetic_fleet,
                                                ragged_requests)

    p = 128
    fleet = build_synthetic_fleet(args.bundle_dir, args.encoders,
                                  n=args.n, p=p, t=args.targets)

    registry = EncoderRegistry(
        device_memory_budget=int(args.budget_mb * 2**20),
        wave_rows=args.wave_rows)
    for name, path in fleet:
        registry.add(name, path)
    service = EncoderService(registry, wave_rows=args.wave_rows)

    names = [name for name, _ in fleet]
    rng = np.random.default_rng(0)
    step_ms = []
    for step in range(args.serve_steps):
        reqs = ragged_requests(rng, names, p, args.wave_rows,
                               args.requests_per_step)
        t0 = time.perf_counter()
        service.serve(reqs)
        step_ms.append((time.perf_counter() - t0) * 1e3)
    warm = step_ms[1:] or step_ms              # first step pays the compile
    print(f"served {args.serve_steps} steps × {args.requests_per_step} "
          f"requests: p50={np.percentile(warm, 50):.1f} ms "
          f"p99={np.percentile(warm, 99):.1f} ms per step "
          f"(first/cold {step_ms[0]:.1f} ms)")
    s = service.stats
    print(f"waves={s.waves} rows={s.rows} pad_rows={s.pad_rows} "
          f"compiled_predicts={service.compile_count} (1 per wave shape)")
    print(f"registry: {registry.stats()}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="LLM mode: model architecture id")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    # -- encoder serving mode ------------------------------------------------
    ap.add_argument("--encoders", type=int, default=None,
                    help="encoder mode: number of synthetic subjects to "
                         "materialise → fit → save → serve")
    ap.add_argument("--bundle-dir", default="encoder_bundles",
                    help="where EncoderBundles are saved/reused")
    ap.add_argument("--n", type=int, default=512,
                    help="encoder mode: time samples per subject")
    ap.add_argument("--targets", type=int, default=256)
    ap.add_argument("--wave-rows", type=int, default=64,
                    help="fixed wave shape (rows) of the compiled predict")
    ap.add_argument("--serve-steps", type=int, default=5)
    ap.add_argument("--requests-per-step", type=int, default=8)
    ap.add_argument("--budget-mb", type=float, default=256.0,
                    help="registry device-memory budget (LRU eviction)")
    args = ap.parse_args()

    if args.encoders is not None:
        _run_encoder_mode(args)
        return
    if args.arch is None:
        ap.error("--arch is required in LLM mode (or pass --encoders N)")

    import jax
    import jax.numpy as jnp
    from repro import configs
    from repro.data.synthetic import make_batch
    from repro.models import build_model

    cfg = configs.get_config(args.arch)
    if args.smoke:
        cfg = configs.smoke(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    batch = make_batch(jax.random.PRNGKey(1), cfg, args.batch,
                       args.prompt_len, kind="prefill")
    t0 = time.time()
    logits, cache = jax.jit(model.prefill)(params, batch)
    print(f"prefill: {time.time()-t0:.2f}s  logits {logits.shape}")

    decode = jax.jit(model.decode_step)
    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    start_pos = args.prompt_len if cfg.family != "audio" else 1
    out_tokens = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, tok, jnp.int32(start_pos + i))
        tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        out_tokens.append(tok)
    dt = time.time() - t0
    toks = jnp.concatenate(out_tokens, axis=1)
    print(f"decoded {args.gen} tokens × batch {args.batch} in {dt:.2f}s "
          f"({args.gen*args.batch/max(dt,1e-9):.1f} tok/s)")
    print("sample tokens:", toks[0, :12].tolist())


if __name__ == "__main__":
    main()
