"""Serving driver: batched LLM decode, or the brain-encoder serving loop.

LLM mode (prefill + greedy decode)::

    python -m repro.launch.serve --arch <id> --smoke --batch 2 \
        --prompt-len 16 --gen 16

Encoder mode (materialise → fit → save → serve loop)::

    python -m repro.launch.serve --encoders 3 --bundle-dir /tmp/bundles \
        --serve-steps 5 --wave-rows 64

fits one ``BrainEncoder`` per synthetic subject, persists each as an
``EncoderBundle``, then serves wave-batched prediction traffic against the
bundle fleet through ``EncoderRegistry`` + ``EncoderService`` — the
"fit once, serve many" workflow end to end.

Fleet mode — N workers, ONE artifact dir, shared page cache::

    python -m repro.launch.serve --encoders 6 --bundle-dir /tmp/bundles \
        --workers 4 --serve-steps 5

``--workers N`` fits the fleet once in the parent, then launches N worker
*processes* against the same bundle directory.  Each worker runs its own
``FleetRegistry`` (mmap'd read-only weight reads → the bytes are faulted
from disk once between co-located workers via the OS page cache) and
publishes its loads/evictions to the shared file-locked
``residency.json``; the parent prints the fleet residency view when the
workers drain.  Per-worker knobs: ``--worker-id`` (set by the parent; set
it manually to join an existing fleet), ``--max-pending-rows`` (bounded
admission — overflow is a typed rejection, not a stall), and
``--replay-trace PATH`` to serve the checked-in deterministic
mixed-traffic trace instead of random ragged traffic.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time


def _run_fleet_parent(args) -> None:
    """Fit the fleet once, launch ``--workers`` child processes against
    the shared bundle dir, then print the fleet residency view."""
    import json

    from repro.serving_encoders import RESIDENCY_MAP, ResidencyMap
    from repro.serving_encoders.traffic import (build_synthetic_fleet,
                                                load_trace)

    # Fit ONCE in the parent so the workers never race on bundle writes —
    # they open the finished artifacts read-only.
    if args.replay_trace is None:
        build_synthetic_fleet(args.bundle_dir, args.encoders,
                              n=args.n, p=128, t=args.targets)
    else:
        spec = load_trace(args.replay_trace)
        build_synthetic_fleet(args.bundle_dir, spec.n_models,
                              n=args.n, p=spec.p, t=spec.t)
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__)))))
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    base = [sys.executable, "-m", "repro.launch.serve",
            "--bundle-dir", args.bundle_dir,
            "--n", str(args.n), "--targets", str(args.targets),
            "--wave-rows", str(args.wave_rows),
            "--serve-steps", str(args.serve_steps),
            "--requests-per-step", str(args.requests_per_step),
            "--budget-mb", str(args.budget_mb),
            "--max-pending-rows", str(args.max_pending_rows)]
    if args.encoders is not None:
        base += ["--encoders", str(args.encoders)]
    if args.replay_trace is not None:
        base += ["--replay-trace", args.replay_trace]

    def worker_argv(wid: str) -> list[str]:
        # Observability flags fan out per worker: each process owns its
        # tracer/registry, so each gets a worker-suffixed output path.
        argv = base + ["--worker-id", wid]
        for flag, path in (("--trace-out", args.trace_out),
                           ("--metrics-out", args.metrics_out)):
            if path is not None:
                root, ext = os.path.splitext(path)
                argv += [flag, f"{root}.{wid}{ext}"]
        return argv

    kill_idx = args.kill_worker
    if kill_idx >= args.workers:
        raise SystemExit(f"--kill-worker {kill_idx} but only "
                         f"{args.workers} workers")
    procs = []
    for i in range(args.workers):
        argv = worker_argv(f"w{i}")
        if i == kill_idx:
            argv += ["--self-kill-after-flush", "1"]
        procs.append(subprocess.Popen(argv, env=env))
    codes = [proc.wait() for proc in procs]
    rmap = ResidencyMap(os.path.join(args.bundle_dir, RESIDENCY_MAP))

    killed_id = None
    if kill_idx >= 0:
        # The liveness gate: one worker SIGKILLs itself mid-trace.  Its
        # lease (residency row) survives it; a replacement under a FRESH
        # id re-runs the victim's workload so the drain still completes;
        # expire_dead must then reap exactly the dead id's stale claim.
        import signal
        if codes[kill_idx] != -signal.SIGKILL:
            raise SystemExit(f"worker w{kill_idx} should have died by "
                             f"SIGKILL mid-trace, exited {codes[kill_idx]}")
        codes[kill_idx] = 0
        killed_id = f"w{kill_idx}"
        restart = subprocess.Popen(worker_argv(f"w{kill_idx}r"), env=env)
        rc = restart.wait()
        if rc:
            raise SystemExit(f"restarted worker w{kill_idx}r exited {rc}")

    print(f"fleet residency after drain: "
          f"{json.dumps(rmap.snapshot(), sort_keys=True)}")
    if any(codes):
        raise SystemExit(f"worker exit codes {codes}")

    if killed_id is not None:
        rows = rmap.snapshot()["workers"]
        if killed_id not in rows:
            raise SystemExit(f"{killed_id} died without leaving a lease — "
                             f"nothing proves expiry works")
        survivors = sorted(w for w in rows if w != killed_id)
        if survivors:
            raise SystemExit(f"cleanly-drained workers left rows behind: "
                             f"{survivors}")
        # Deterministic TTL: the parent observes the dead stamp strictly
        # in its past, so half the observed age expires exactly that row.
        now = time.time()
        age = now - rows[killed_id]["heartbeat"]
        dead = rmap.expire_dead(age / 2, now=now)
        if dead != [killed_id]:
            raise SystemExit(f"expire_dead reaped {dead}, "
                             f"expected [{killed_id!r}]")
        if rmap.snapshot()["workers"]:
            raise SystemExit("stale lease survived expire_dead")
        print(f"lease gate: {killed_id} SIGKILLed after 1 flush, "
              f"w{kill_idx}r re-ran its trace, stale lease "
              f"(age {age:.2f}s) expired ✓")
    print(f"{args.workers} workers drained cleanly ✓")


def _run_encoder_mode(args) -> None:
    import numpy as np
    from repro.serving_encoders import (RESIDENCY_MAP, EncoderRegistry,
                                        EncoderService, FleetFrontend,
                                        FleetRegistry, ResidencyMap)
    from repro.serving_encoders.fleet import replay
    from repro.serving_encoders.traffic import (build_synthetic_fleet,
                                                load_trace, ragged_requests,
                                                replay_requests)

    if args.workers > 1 and args.worker_id is None:
        _run_fleet_parent(args)
        return

    spec = None
    if args.replay_trace is not None:
        # The trace pins the fleet's shapes and size — serve exactly the
        # workload the benchmarks replay.
        spec = load_trace(args.replay_trace)
        p, t, n_models = spec.p, spec.t, spec.n_models
    else:
        p, t, n_models = 128, args.targets, args.encoders
    fleet = build_synthetic_fleet(args.bundle_dir, n_models,
                                  n=args.n, p=p, t=t)

    reg_kw = dict(device_memory_budget=int(args.budget_mb * 2**20),
                  wave_rows=args.wave_rows)
    if args.worker_id is not None:
        rmap = ResidencyMap(os.path.join(args.bundle_dir, RESIDENCY_MAP))
        registry = FleetRegistry(worker_id=args.worker_id,
                                 residency_map=rmap, **reg_kw)
    else:
        registry = EncoderRegistry(**reg_kw)
    for name, path in fleet:
        registry.add(name, path)
    service = EncoderService(registry, wave_rows=args.wave_rows,
                             prefetch_next=True)
    frontend = FleetFrontend(service,
                             max_pending_rows=args.max_pending_rows)
    tag = f"[{args.worker_id}] " if args.worker_id else ""
    names = [name for name, _ in fleet]

    if args.self_kill_after_flush > 0:
        # Fault-injection hook for the fleet liveness gate: die by real
        # SIGKILL right after the Nth flush lands — the residency row
        # (lease) published during that flush is left stale on disk.
        import signal
        inner_flush = frontend.flush
        flushes = [0]

        def _flush_then_die(**kw):
            out = inner_flush(**kw)
            flushes[0] += 1
            if flushes[0] >= args.self_kill_after_flush:
                os.kill(os.getpid(), signal.SIGKILL)
            return out

        frontend.flush = _flush_then_die

    if spec is not None:
        reqs = replay_requests(spec, names)
        t0 = time.perf_counter()
        results, rejections = replay(frontend, reqs)
        wall = (time.perf_counter() - t0) * 1e3
        faults = sum(1 for r in results if r is not None and r.error)
        print(f"{tag}replayed {len(reqs)} trace requests in {wall:.1f} ms "
              f"({len(rejections)} backpressure rejections, "
              f"{faults} faults)")
    else:
        # Per-worker seed: distinct traffic per worker, deterministic per
        # worker id.
        seed = 0 if args.worker_id is None else \
            abs(hash(args.worker_id)) % 2**31
        rng = np.random.default_rng(seed)
        step_ms = []
        for step in range(args.serve_steps):
            for req in ragged_requests(rng, names, p, args.wave_rows,
                                       args.requests_per_step):
                try:
                    frontend.submit(req)
                except Exception:
                    frontend.flush()
                    frontend.submit(req)
            t0 = time.perf_counter()
            frontend.flush()
            step_ms.append((time.perf_counter() - t0) * 1e3)
            if args.worker_id is not None:
                # Explicit lease refresh between serving windows — a
                # steady-state worker whose residency stops changing
                # would otherwise look dead to expire_dead.
                registry.heartbeat()
        warm = step_ms[1:] or step_ms          # first step pays the compile
        print(f"{tag}served {args.serve_steps} steps × "
              f"{args.requests_per_step} requests: "
              f"p50={np.percentile(warm, 50):.1f} ms "
              f"p99={np.percentile(warm, 99):.1f} ms per step "
              f"(first/cold {step_ms[0]:.1f} ms)")
    import json as _json
    s = service.stats
    print(f"{tag}waves={s.waves} rows={s.rows} pad_rows={s.pad_rows} "
          f"compiled_predicts={service.compile_count} (1 per wave shape) "
          f"tenants={len(s.per_tenant)}")
    print(f"{tag}service: {_json.dumps(s.to_dict(), sort_keys=True)}")
    print(f"{tag}registry: {registry.stats()}")
    if args.worker_id is not None:
        registry.close()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="LLM mode: model architecture id")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    # -- encoder serving mode ------------------------------------------------
    ap.add_argument("--encoders", type=int, default=None,
                    help="encoder mode: number of synthetic subjects to "
                         "materialise → fit → save → serve")
    ap.add_argument("--bundle-dir", default="encoder_bundles",
                    help="where EncoderBundles are saved/reused")
    ap.add_argument("--n", type=int, default=512,
                    help="encoder mode: time samples per subject")
    ap.add_argument("--targets", type=int, default=256)
    ap.add_argument("--wave-rows", type=int, default=64,
                    help="fixed wave shape (rows) of the compiled predict")
    ap.add_argument("--serve-steps", type=int, default=5)
    ap.add_argument("--requests-per-step", type=int, default=8)
    ap.add_argument("--budget-mb", type=float, default=256.0,
                    help="registry device-memory budget (LRU eviction)")
    # -- fleet mode ----------------------------------------------------------
    ap.add_argument("--workers", type=int, default=1,
                    help="fleet mode: launch N worker processes against "
                         "one bundle dir (shared page cache via mmap'd "
                         "weights + file-locked residency.json)")
    ap.add_argument("--worker-id", default=None,
                    help="run as ONE fleet worker under this id "
                         "(normally set by the --workers parent)")
    ap.add_argument("--max-pending-rows", type=int, default=4096,
                    help="bounded-admission queue depth in rows; overflow "
                         "is a typed ServiceError rejection (backpressure)")
    ap.add_argument("--replay-trace", default=None,
                    help="encoder mode: serve this checked-in mixed-traffic "
                         "trace (e.g. benchmarks/traces/mixed_v1.json) "
                         "instead of random ragged traffic")
    ap.add_argument("--kill-worker", type=int, default=-1,
                    help="fleet liveness gate: SIGKILL this worker index "
                         "after its first flush, restart it under a fresh "
                         "id, and assert expire_dead reaps the stale lease")
    ap.add_argument("--self-kill-after-flush", type=int, default=0,
                    help="(internal worker hook) raise SIGKILL on self "
                         "right after the Nth flush")
    from repro.launch.obscli import add_obs_args, obs_session
    add_obs_args(ap)
    args = ap.parse_args()

    if args.encoders is not None or args.replay_trace is not None:
        if args.workers > 1 and args.worker_id is None:
            # The fleet parent does no device work itself — the obs flags
            # fan out to the workers (suffixed paths), not to the parent.
            _run_encoder_mode(args)
        else:
            with obs_session(args):
                _run_encoder_mode(args)
        return
    if args.arch is None:
        ap.error("--arch is required in LLM mode (or pass --encoders N)")

    import jax
    import jax.numpy as jnp
    from repro import configs
    from repro.data.synthetic import make_batch
    from repro.models import build_model

    cfg = configs.get_config(args.arch)
    if args.smoke:
        cfg = configs.smoke(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    batch = make_batch(jax.random.PRNGKey(1), cfg, args.batch,
                       args.prompt_len, kind="prefill")
    t0 = time.time()
    logits, cache = jax.jit(model.prefill)(params, batch)
    print(f"prefill: {time.time()-t0:.2f}s  logits {logits.shape}")

    decode = jax.jit(model.decode_step)
    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    start_pos = args.prompt_len if cfg.family != "audio" else 1
    out_tokens = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, tok, jnp.int32(start_pos + i))
        tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        out_tokens.append(tok)
    dt = time.time() - t0
    toks = jnp.concatenate(out_tokens, axis=1)
    print(f"decoded {args.gen} tokens × batch {args.batch} in {dt:.2f}s "
          f"({args.gen*args.batch/max(dt,1e-9):.1f} tok/s)")
    print("sample tokens:", toks[0, :12].tolist())


if __name__ == "__main__":
    main()
