"""Serving driver: batched prefill + greedy decode loop.

``python -m repro.launch.serve --arch <id> --smoke --batch 2 --prompt-len 16
--gen 16``
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro import configs
    from repro.data.synthetic import make_batch
    from repro.models import build_model

    cfg = configs.get_config(args.arch)
    if args.smoke:
        cfg = configs.smoke(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    batch = make_batch(jax.random.PRNGKey(1), cfg, args.batch,
                       args.prompt_len, kind="prefill")
    t0 = time.time()
    logits, cache = jax.jit(model.prefill)(params, batch)
    print(f"prefill: {time.time()-t0:.2f}s  logits {logits.shape}")

    decode = jax.jit(model.decode_step)
    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    start_pos = args.prompt_len if cfg.family != "audio" else 1
    out_tokens = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, tok, jnp.int32(start_pos + i))
        tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        out_tokens.append(tok)
    dt = time.time() - t0
    toks = jnp.concatenate(out_tokens, axis=1)
    print(f"decoded {args.gen} tokens × batch {args.batch} in {dt:.2f}s "
          f"({args.gen*args.batch/max(dt,1e-9):.1f} tok/s)")
    print("sample tokens:", toks[0, :12].tolist())


if __name__ == "__main__":
    main()
