"""Compiled-HLO analysis: collective byte counts + roofline terms.

``cost_analysis()`` gives FLOPs and HBM bytes but not collective traffic, so
we parse the compiled module text and sum operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
Byte counts are the *per-shard* operand sizes as written in the HLO (shapes
in a compiled SPMD module are already per-device).
"""
from __future__ import annotations

import re
from typing import Any

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g. "bf16[2,4096,128]{2,1,0}" — capture dtype + dims.
_SHAPE_RE = re.compile(r"\b(pred|[suf]\d+|bf16|f16|c64|c128)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum output-shape bytes of collective ops, keyed by op kind."""
    totals: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # Instruction lines look like: "%name = TYPE[dims] op-name(...)".
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+)$", stripped)
        if not m:
            continue
        rhs = m.group(1)
        op = None
        for kind in _COLLECTIVES:
            if re.search(rf"\b{kind}(?:-start|-done)?\(", rhs):
                op = kind
                break
        if op is None or f"{op}-done(" in rhs:
            continue  # count -start, skip -done (same transfer)
        # Output shape(s) precede the op name on the rhs; sum all shapes in
        # the result type (tuples for grouped collectives).
        type_part = rhs.split(f" {op}", 1)[0] if f" {op}" in rhs else \
            rhs.split("(", 1)[0]
        total = sum(_shape_bytes(d, dims)
                    for d, dims in _SHAPE_RE.findall(type_part))
        totals[op] += float(total)
    return {k: v for k, v in totals.items()}


def total_collective_bytes(hlo_text: str) -> float:
    return sum(collective_bytes(hlo_text).values())


def memory_dict(mem: Any) -> dict:
    """Normalise compiled.memory_analysis() across backends."""
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        if hasattr(mem, attr):
            out[attr] = int(getattr(mem, attr))
    return out


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float, *,
                   peak_flops: float = 197e12, hbm_bw: float = 819e9,
                   ici_bw: float = 50e9, ici_links: int = 4) -> dict:
    """Three-term roofline (seconds).

    All inputs are PER-DEVICE quantities: ``compiled.cost_analysis()`` on a
    jitted SPMD module reports the per-device partitioned program (verified
    empirically: an 8-way-sharded matmul reports 1/8 the FLOPs), and the
    collective operand shapes in the partitioned HLO are per-shard too.
    """
    t_compute = flops / peak_flops
    t_memory = hbm_bytes / hbm_bw
    t_collective = coll_bytes / (ici_bw * ici_links)
    dom = max(("compute", t_compute), ("memory", t_memory),
              ("collective", t_collective), key=lambda kv: kv[1])
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "bottleneck": dom[0],
    }
