"""Span-trace report — per-phase time/bytes breakdown of a JSONL trace.

Reads the JSONL span stream the launchers emit under ``--trace-out``
(one ``repro.obs`` event per line) and prints, per span name: call
count, total seconds, mean milliseconds, share of the trace wall, and
the bytes the spans carried (``bytes``/``bytes_staged`` attrs).

The report also computes **root coverage**: the fraction of the longest
root (depth-0) span's wall time attributed to its direct (depth-1)
children.  A healthy instrumented fit attributes ≥95% — anything less
means an uninstrumented phase is hiding inside the root.
``--assert-coverage 0.95`` turns that into an exit-code gate (the obs CI
lane runs it against the smoke fit's trace).

::

    python -m repro.launch.obs_report trace.jsonl
    python -m repro.launch.obs_report trace.jsonl --assert-coverage 0.95
"""
from __future__ import annotations

import argparse
import json


def load_events(path: str) -> list[dict]:
    """Parse a JSONL span trace (skips blank lines)."""
    events = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            ev = json.loads(line)
            if "name" not in ev or "ts_us" not in ev:
                raise ValueError(f"{path}: not a repro.obs JSONL trace "
                                 f"(event missing name/ts_us: {ev})")
            events.append(ev)
    return events


def _span_bytes(ev: dict) -> int:
    attrs = ev.get("attrs") or {}
    return int(attrs.get("bytes", 0) or 0) \
        + int(attrs.get("bytes_staged", 0) or 0)


def summarize(events: list[dict]) -> dict:
    """Aggregate per span name: ``{name: {count, total_us, bytes}}``."""
    agg: dict[str, dict] = {}
    for ev in events:
        if ev.get("instant"):
            continue
        row = agg.setdefault(ev["name"],
                             {"count": 0, "total_us": 0.0, "bytes": 0})
        row["count"] += 1
        row["total_us"] += ev["dur_us"]
        row["bytes"] += _span_bytes(ev)
    return agg


def root_coverage(events: list[dict]) -> tuple[dict | None, float]:
    """(longest depth-0 span, fraction of it covered by its depth-1
    children).  ``(None, 0.0)`` when the trace has no root span."""
    roots = [e for e in events if e.get("depth") == 0
             and not e.get("instant")]
    if not roots:
        return None, 0.0
    root = max(roots, key=lambda e: e["dur_us"])
    if root["dur_us"] <= 0:
        return root, 0.0
    lo, hi = root["ts_us"], root["ts_us"] + root["dur_us"]
    kids = [e for e in events
            if e.get("depth") == 1 and not e.get("instant")
            and e.get("parent") == root["name"]
            and lo <= e["ts_us"] and e["ts_us"] + e["dur_us"] <= hi + 1.0]
    return root, sum(k["dur_us"] for k in kids) / root["dur_us"]


def render(events: list[dict]) -> str:
    agg = summarize(events)
    if not agg:
        return "(empty trace)"
    wall_us = (max(e["ts_us"] + e.get("dur_us", 0.0) for e in events)
               - min(e["ts_us"] for e in events)) or 1.0
    name_w = max(len(n) for n in agg) + 2
    lines = [f"{'span':<{name_w}}{'count':>7}{'total_s':>10}"
             f"{'mean_ms':>10}{'%wall':>8}{'MB':>10}"]
    for name, row in sorted(agg.items(),
                            key=lambda kv: -kv[1]["total_us"]):
        total_s = row["total_us"] / 1e6
        mean_ms = row["total_us"] / row["count"] / 1e3
        lines.append(
            f"{name:<{name_w}}{row['count']:>7}{total_s:>10.3f}"
            f"{mean_ms:>10.2f}{100 * row['total_us'] / wall_us:>7.1f}%"
            f"{row['bytes'] / 2**20:>10.2f}")
    n_instants = sum(1 for e in events if e.get("instant"))
    if n_instants:
        lines.append(f"(+ {n_instants} instant events)")
    root, cov = root_coverage(events)
    if root is not None:
        lines.append(f"root {root['name']!r}: "
                     f"{root['dur_us'] / 1e6:.3f}s wall, "
                     f"{100 * cov:.1f}% attributed to direct children")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="JSONL span trace (--trace-out output)")
    ap.add_argument("--assert-coverage", type=float, default=None,
                    metavar="FRAC",
                    help="exit non-zero unless the longest root span "
                         "attributes at least FRAC of its wall time to "
                         "its direct children")
    args = ap.parse_args()

    events = load_events(args.trace)
    print(render(events))
    if args.assert_coverage is not None:
        root, cov = root_coverage(events)
        if root is None:
            raise SystemExit("coverage assertion failed: trace has no "
                             "root (depth-0) span")
        if cov < args.assert_coverage:
            raise SystemExit(
                f"coverage assertion failed: {100 * cov:.1f}% of root "
                f"{root['name']!r} attributed, need "
                f"{100 * args.assert_coverage:.1f}%")
        print(f"coverage ≥ {100 * args.assert_coverage:.0f}% ✓")


if __name__ == "__main__":
    main()
