"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

Runs the full substrate end-to-end on whatever mesh fits the local devices:
synthetic CNeuroMod-shaped data pipeline → sharded train_step (pjit) →
AdamW → periodic checkpointing.  On a real TPU pod the same driver runs with
``--production-mesh`` (16×16 or 2×16×16).
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--rules", default="tp")
    args = ap.parse_args()

    import jax
    from repro import checkpoint, configs
    from repro.data.synthetic import TokenStream, make_batch
    from repro.launch import mesh as mesh_lib
    from repro.launch.steps import build_train_step
    from repro.models.config import InputShape
    from repro.optim import AdamWConfig, adamw_init

    cfg = configs.get_config(args.arch)
    if args.smoke:
        cfg = configs.smoke(cfg)
    if args.production_mesh:
        mesh = mesh_lib.make_production_mesh(multi_pod=args.multi_pod)
    else:
        n = jax.device_count()
        model_par = 2 if n % 2 == 0 and n > 1 else 1
        mesh = mesh_lib.make_host_mesh(model=model_par)
    shape = InputShape("cli", args.seq, args.batch, "train")

    bundle = build_train_step(cfg, mesh, shape, rules=args.rules,
                              opt=AdamWConfig(lr=args.lr))
    from repro.models import build_model
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = adamw_init(params)

    with mesh:
        step_fn = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                          out_shardings=bundle.out_shardings,
                          donate_argnums=bundle.donate_argnums)
        stream = TokenStream(cfg, args.batch, args.seq)
        t0 = time.time()
        for step in range(args.steps):
            batch = stream.batch_at(step)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if step % max(1, args.steps // 10) == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"({time.time()-t0:.1f}s)")
            if args.ckpt_every and args.ckpt_dir and \
                    (step + 1) % args.ckpt_every == 0:
                checkpoint.save(args.ckpt_dir, step + 1,
                                {"params": params, "opt": opt_state})
    print("done")


if __name__ == "__main__":
    main()
