"""Brain-encoding driver — the paper's full pipeline, end to end.

stimulus features (backbone hidden states or synthetic VGG16-shaped
features) → distributed B-MOR RidgeCV → Pearson-r encoding map + null
permutation control.

``python -m repro.launch.encode --backbone qwen3-1.7b --smoke`` runs the
whole thing on CPU; ``--features vgg16`` uses the paper-faithful synthetic
feature pipeline instead of a transformer backbone.
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--backbone", default="vgg16",
                    help="arch id or 'vgg16' for the paper's feature shape")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--n", type=int, default=512, help="time samples")
    ap.add_argument("--targets", type=int, default=256)
    ap.add_argument("--model-shards", type=int, default=2)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import configs
    from repro.core import bmor, ridge, scoring
    from repro.data import fmri, synthetic
    from repro.launch import mesh as mesh_lib
    from repro.models import build_model

    n, t = args.n, args.targets
    key = jax.random.PRNGKey(0)

    # 1. Stimulus features X.
    if args.backbone == "vgg16":
        spec = fmri.SubjectSpec(n=n, p=128, t=t)
        X, Y, mask = fmri.generate(key, spec)
        print(f"synthetic VGG16-shaped features: X{X.shape} Y{Y.shape}")
    else:
        cfg = configs.get_config(args.backbone)
        if args.smoke:
            cfg = configs.smoke(cfg)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(1))
        seq = 16
        batch = synthetic.make_batch(jax.random.PRNGKey(2), cfg,
                                     n // seq, seq)
        h = jax.jit(model.hidden_states)(params, batch)   # (B, S, d)
        X = h.reshape(-1, h.shape[-1]).astype(jnp.float32)
        X = (X - X.mean(0)) / (X.std(0) + 1e-6)
        spec = fmri.SubjectSpec(n=X.shape[0], p=X.shape[1], t=t)
        _, Y, mask = fmri.generate(key, spec)
        # Plant signal from THESE features so encoding is learnable.
        W_true = jax.random.normal(jax.random.PRNGKey(3),
                                   (X.shape[1], t)) / np.sqrt(X.shape[1])
        W_true = W_true * jnp.where(mask, 1.0, 0.0)[None, :]
        Y = X @ W_true * 2.0 + jax.random.normal(jax.random.PRNGKey(4),
                                                 Y.shape)
        Y = (Y - Y.mean(0)) / (Y.std(0) + 1e-6)
        print(f"backbone features from {cfg.name}: X{X.shape} Y{Y.shape}")

    # 2. Train/test split (paper: 90/10 random).
    tr, te = scoring.train_test_split_indices(jax.random.PRNGKey(5),
                                              X.shape[0])
    X_tr, Y_tr, X_te, Y_te = X[tr], Y[tr], X[te], Y[te]

    # 3. Distributed B-MOR fit.
    n_dev = jax.device_count()
    model_shards = min(args.model_shards, n_dev)
    mesh = mesh_lib.make_host_mesh(model=model_shards)
    n_data = mesh.shape["data"]
    keep = (X_tr.shape[0] // n_data) * n_data
    X_tr, Y_tr = X_tr[:keep], Y_tr[:keep]
    Xs = jax.device_put(X_tr, NamedSharding(mesh, P("data", None)))
    Ys = jax.device_put(Y_tr, NamedSharding(mesh, P("data", "model")))
    res = bmor.bmor_fit(Xs, Ys, mesh)
    print(f"B-MOR fit: per-batch λ = {np.asarray(res.best_lambda)}")

    # 4. Evaluate (paper §4.1-4.2).
    preds = ridge.predict(X_te, res.weights)
    r = scoring.pearson_r(Y_te, preds)
    null = scoring.null_permutation_scores(jax.random.PRNGKey(6), X_te, Y_te,
                                           res.weights, n_perms=5)
    r_np = np.asarray(r)
    m = np.asarray(mask)
    print(f"test Pearson r: responsive targets mean={r_np[m].mean():.3f}  "
          f"non-responsive mean={r_np[~m].mean():.3f}")
    print(f"null permutation |r|: mean={float(jnp.mean(jnp.abs(null))):.4f} "
          f"(aligned encoding is significant, paper §4.2)")


if __name__ == "__main__":
    main()
