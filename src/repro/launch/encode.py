"""Brain-encoding driver — the paper's full pipeline, end to end.

stimulus features (backbone hidden states or synthetic VGG16-shaped
features) → ``BrainEncoder`` (solver picked by complexity-driven dispatch:
distributed B-MOR on a multi-device mesh, mutualised RidgeCV otherwise) →
Pearson-r encoding map + null permutation control.

``python -m repro.launch.encode --backbone qwen3-1.7b --smoke`` runs the
whole thing on CPU; ``--features vgg16`` uses the paper-faithful synthetic
feature pipeline instead of a transformer backbone.
"""
from __future__ import annotations

import argparse


def _run_store_mode(args) -> None:
    """Out-of-core path: materialise a synthetic subject once, stream it.

    ``--store DIR`` either opens an existing ``RunStore`` or writes one
    (CNeuroMod-shaped synthetic runs via ``materialize_synthetic``), then
    fits through ``BrainEncoder.fit(store=...)`` under ``--budget-mb`` —
    dispatch pins the streamed fold-statistics path whenever the resident
    estimate exceeds the budget, sharding the accumulation over the local
    devices.
    """
    import os

    import jax
    from repro.data import fmri
    from repro.data.store import MANIFEST_NAME, RunStore
    from repro.encoding import BrainEncoder, EncoderConfig
    from repro.encoding.dispatch import estimated_resident_bytes

    if os.path.exists(os.path.join(args.store, MANIFEST_NAME)):
        store = RunStore.open(args.store)
        print(f"opened store {args.store}: shape {store.shape}")
    else:
        spec = fmri.SubjectSpec(n=args.n, p=128, t=args.targets)
        store = RunStore.create(args.store)
        store.materialize_synthetic(
            spec, rows_per_run=max(1, min(spec.n, 4 * args.chunk_rows)))
        store = RunStore.open(args.store)
        print(f"materialised synthetic subject into {args.store}: "
              f"shape {store.shape}")

    n, p, t = store.shape
    budget = int(args.budget_mb * 2**20)
    enc = BrainEncoder(EncoderConfig(device_memory_budget=budget,
                                     chunk_rows=args.chunk_rows,
                                     prefetch=args.prefetch))
    enc.fit(store=store)
    d = enc.report_.decision
    resident = estimated_resident_bytes(n, p, t, jax.device_count())
    print(f"resident estimate {resident / 2**20:.1f} MB vs budget "
          f"{args.budget_mb:.1f} MB on {jax.device_count()} device(s)")
    print(f"dispatch: solver={d.solver} method={d.method} "
          f"data_shards={d.data_shards} ({d.rationale})")
    if enc.stream_stats_ is not None:
        ss = enc.stream_stats_
        print(f"stream: prefetch={'on' if ss['prefetch'] else 'off'} "
              f"chunks={ss['chunks']} "
              f"staged={ss['bytes_staged'] / 2**20:.1f} MB "
              f"read_stall={ss['read_stall_s']:.2f}s "
              f"compute_stall={ss['compute_stall_s']:.2f}s "
              f"accumulation compiles={ss['compile_count']} "
              f"[{ss['schema']}]")
    print(f"{enc.report_.solver_label} fit: λ = {enc.report_.best_lambda}, "
          f"CV scores {enc.report_.cv_scores.round(4)}")
    if args.save_bundle:
        _save_bundle_with_report(enc, args.save_bundle,
                                 provenance={"source": "run_store",
                                             "store": args.store,
                                             "shape": list(store.shape)})


def _save_bundle_with_report(encoder, bundle_dir: str,
                             provenance: dict | None = None) -> None:
    """Persist the fitted encoder + machine-readable run provenance.

    The bundle directory gets the ``EncoderBundle`` payload; ``report.json``
    (``EncodingReport.to_json``) rides next to it so downstream tooling can
    read solver/λ/CV provenance without touching the arrays.
    """
    import os

    path = encoder.save(bundle_dir, overwrite=True, provenance=provenance)
    with open(os.path.join(path, "report.json"), "w") as f:
        f.write(encoder.report_.to_json())
    print(f"bundle saved → {path} (report.json alongside)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--backbone", default="vgg16",
                    help="arch id or 'vgg16' for the paper's feature shape")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--n", type=int, default=512, help="time samples")
    ap.add_argument("--targets", type=int, default=256)
    ap.add_argument("--solver", default="auto",
                    help="auto|ridge|mor|bmor|bmor_dual|banded")
    ap.add_argument("--target-shards", type=int, default=None,
                    help="pin the target-batch shard count (default: dispatch)")
    ap.add_argument("--store", default=None,
                    help="out-of-core mode: RunStore directory (materialised "
                         "with synthetic runs on first use, then streamed)")
    ap.add_argument("--chunk-rows", type=int, default=8192,
                    help="row-batch size of the streaming accumulation")
    ap.add_argument("--prefetch", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="overlap the next chunk's disk read with the "
                         "current accumulation (--no-prefetch for the "
                         "serial A/B; results are bit-identical)")
    ap.add_argument("--budget-mb", type=float, default=64.0,
                    help="device-memory budget (MB) for --store dispatch")
    ap.add_argument("--save-bundle", default=None,
                    help="persist the fitted encoder as an EncoderBundle "
                         "directory (+ report.json run provenance) for the "
                         "serving subsystem")
    from repro.launch.obscli import add_obs_args, obs_session
    add_obs_args(ap)
    args = ap.parse_args()

    with obs_session(args):
        _run(args)


def _run(args) -> None:
    if args.store is not None:
        _run_store_mode(args)
        return

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro import configs
    from repro.data import fmri, synthetic
    from repro.encoding import EncoderConfig, pipeline
    from repro.models import build_model

    n, t = args.n, args.targets
    key = jax.random.PRNGKey(0)

    # 1. Stimulus features X.
    if args.backbone == "vgg16":
        spec = fmri.SubjectSpec(n=n, p=128, t=t)
        X, Y, mask = fmri.generate(key, spec)
        print(f"synthetic VGG16-shaped features: X{X.shape} Y{Y.shape}")
    else:
        cfg = configs.get_config(args.backbone)
        if args.smoke:
            cfg = configs.smoke(cfg)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(1))
        seq = 16
        batch = synthetic.make_batch(jax.random.PRNGKey(2), cfg,
                                     n // seq, seq)
        h = jax.jit(model.hidden_states)(params, batch)   # (B, S, d)
        X = h.reshape(-1, h.shape[-1]).astype(jnp.float32)
        X = (X - X.mean(0)) / (X.std(0) + 1e-6)
        spec = fmri.SubjectSpec(n=X.shape[0], p=X.shape[1], t=t)
        _, Y, mask = fmri.generate(key, spec)
        # Plant signal from THESE features so encoding is learnable.
        W_true = jax.random.normal(jax.random.PRNGKey(3),
                                   (X.shape[1], t)) / np.sqrt(X.shape[1])
        W_true = W_true * jnp.where(mask, 1.0, 0.0)[None, :]
        Y = X @ W_true * 2.0 + jax.random.normal(jax.random.PRNGKey(4),
                                                 Y.shape)
        print(f"backbone features from {cfg.name}: X{X.shape} Y{Y.shape}")

    # 2-4. 90/10 split → standardize (train-fitted) → fit → evaluate, through
    # the unified estimator API: no mesh/device_put boilerplate here, the
    # dispatch layer picks ridge vs (dual) B-MOR from the problem shape and
    # jax.device_count() (§3 cost model).
    enc_cfg = EncoderConfig(solver=args.solver,
                            target_shards=args.target_shards)
    state = pipeline.run(X, Y, enc_cfg, detrend_targets=False, n_perms=5)
    report, ev = state.report, state.evaluation

    d = report.decision
    print(f"dispatch: solver={d.solver} mesh={d.data_shards}x"
          f"{d.target_shards} ({d.rationale})")
    print(f"{report.solver_label} fit: per-batch λ = {report.best_lambda}")

    if args.save_bundle:
        _save_bundle_with_report(
            state.encoder, args.save_bundle,
            provenance={"source": "pipeline", "backbone": args.backbone,
                        "n": args.n, "targets": args.targets})

    r_np = ev.pearson_r
    m = np.asarray(mask)
    print(f"test Pearson r: responsive targets mean={r_np[m].mean():.3f}  "
          f"non-responsive mean={r_np[~m].mean():.3f}")
    ok = r_np[m].mean() > 5 * ev.null_abs_r
    print(f"null permutation |r|: mean={ev.null_abs_r:.4f} "
          + ("(aligned encoding is significant, paper §4.2)" if ok else
             "(WARNING: responsive targets do not clear the null floor)"))


if __name__ == "__main__":
    main()
