"""Step functions + shardings for every (architecture × input shape).

``build_step`` returns the jit-able step function, abstract example inputs
(ShapeDtypeStructs), and the matching in/out shardings for a given mesh —
consumed identically by the dry-run launcher (``.lower().compile()``) and
the real training/serving drivers.

Sharding policy (DESIGN §5):
* train/prefill: batch over ("pod","data"); params per the logical-axis rule
  table (default "tp": heads/mlp/vocab/experts over "model").
* decode: batch over data axes when divisible; otherwise (long_500k, B=1)
  the KV-cache *sequence* dimension is sharded over the data axes instead
  (distributed flash-decode: XLA inserts the softmax-stat combine).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.data.synthetic import batch_spec
from repro.models import build_model
from repro.models.config import InputShape, ModelConfig
from repro.models.params import RULES, ParamDef, abstract, is_def, specs
from repro.optim import AdamWConfig, adamw_init, adamw_update


def _mesh_data_axes(mesh: Mesh) -> tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _data_size(mesh: Mesh) -> int:
    return math.prod(mesh.shape[a] for a in _mesh_data_axes(mesh))


def rule_table(mesh: Mesh, batch: int, rules: str = "tp") -> dict:
    """Resolve the logical-axis table for this mesh + batch size."""
    t = dict(RULES[rules])
    daxes = _mesh_data_axes(mesh)
    shardable = batch % _data_size(mesh) == 0
    t["batch"] = daxes if shardable else None
    if t.get("cache_seq") is None:          # rule tables may pin it (§Perf)
        t["cache_seq"] = None if shardable else daxes
    # FSDP rules reference a bare "data" axis; with a pod axis the weight
    # shards span both.
    if t.get("embed") == "data":
        t["embed"] = daxes
    return t


def named(mesh: Mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def batch_shardings(mesh: Mesh, spec: dict, batch: int) -> dict:
    daxes = _mesh_data_axes(mesh)
    shardable = batch % _data_size(mesh) == 0
    bspec = daxes if shardable else None

    def one(s: jax.ShapeDtypeStruct):
        return NamedSharding(mesh, P(bspec, *([None] * (len(s.shape) - 1))))

    return jax.tree_util.tree_map(one, spec)


@dataclasses.dataclass
class StepBundle:
    """Everything needed to lower/compile/run one step."""
    fn: Callable                    # jit-able python callable
    abstract_inputs: tuple          # ShapeDtypeStructs, positional
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple = ()


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------

def build_train_step(cfg: ModelConfig, mesh: Mesh, shape: InputShape,
                     rules: str = "tp",
                     opt: AdamWConfig = AdamWConfig(),
                     remat: bool = True,
                     microbatch: int = 1,
                     microbatch_unroll: bool = False,
                     unroll: bool = False) -> StepBundle:
    model = build_model(cfg)
    model.unroll = unroll
    defs = model.param_defs()
    table = rule_table(mesh, shape.global_batch, rules)
    pspecs = specs(defs, table, dict(mesh.shape))
    psh = named(mesh, pspecs)
    abs_params = abstract(defs)

    opt_sh = {"mu": psh, "nu": psh, "step": NamedSharding(mesh, P())}
    abs_opt = {
        "mu": jax.tree_util.tree_map(
            lambda d: jax.ShapeDtypeStruct(d.shape, jnp.float32), defs,
            is_leaf=is_def),
        "nu": jax.tree_util.tree_map(
            lambda d: jax.ShapeDtypeStruct(d.shape, jnp.float32), defs,
            is_leaf=is_def),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }

    bspec = batch_spec(cfg, shape.global_batch, shape.seq_len, "train")
    bsh = batch_shardings(mesh, bspec, shape.global_batch)

    # Remat lives inside the models (per scanned layer group): wrapping the
    # whole loss in jax.checkpoint does nothing for scan-saved residuals.
    model.remat = remat
    loss_fn = model.loss
    daxes = _mesh_data_axes(mesh)
    shardable = shape.global_batch % (_data_size(mesh) * microbatch) == 0
    M = microbatch if (microbatch > 1 and shardable) else 1

    def train_step(params, opt_state, batch):
        if M == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            # Gradient accumulation over M microbatches: activation temp
            # memory scales 1/M while arithmetic is unchanged (§Perf iter 2).
            def split(x):
                mb = x.reshape(M, x.shape[0] // M, *x.shape[1:])
                return jax.lax.with_sharding_constraint(
                    mb, NamedSharding(mesh,
                                      P(None, daxes,
                                        *([None] * (x.ndim - 1)))))
            mbatch = jax.tree_util.tree_map(split, batch)
            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(acc, mb):
                loss, g = jax.value_and_grad(loss_fn)(params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, x: a + x.astype(jnp.float32), acc, g)
                return acc, loss

            if microbatch_unroll:
                # Unrolled accumulation exposes the M per-µbatch gradient
                # all-reduces to XLA's reassociation pass, which merges them
                # into ONE all-reduce of the local sums (§Perf hillclimb:
                # collective term ÷ M).  Scan hides this behind the loop.
                grads, losses = zero, []
                for i in range(M):
                    mb = jax.tree_util.tree_map(lambda x: x[i], mbatch)
                    grads, loss_i = body(grads, mb)
                    losses.append(loss_i)
                losses = jnp.stack(losses)
            else:
                grads, losses = jax.lax.scan(body, zero, mbatch)
            grads = jax.tree_util.tree_map(lambda g: g / M, grads)
            loss = jnp.mean(losses)
        params, opt_state, metrics = adamw_update(opt, params, grads,
                                                  opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    metrics_sh = {k: NamedSharding(mesh, P())
                  for k in ("grad_norm", "lr", "loss")}
    return StepBundle(
        fn=train_step,
        abstract_inputs=(abs_params, abs_opt, bspec),
        in_shardings=(psh, opt_sh, bsh),
        out_shardings=(psh, opt_sh, metrics_sh),
        donate_argnums=(0, 1),
    )


# ---------------------------------------------------------------------------
# Prefill step
# ---------------------------------------------------------------------------

def build_prefill_step(cfg: ModelConfig, mesh: Mesh, shape: InputShape,
                       rules: str = "tp", unroll: bool = False) -> StepBundle:
    model = build_model(cfg)
    model.unroll = unroll
    defs = model.param_defs()
    table = rule_table(mesh, shape.global_batch, rules)
    psh = named(mesh, specs(defs, table, dict(mesh.shape)))
    abs_params = abstract(defs)

    bspec = batch_spec(cfg, shape.global_batch, shape.seq_len, "prefill")
    bsh = batch_shardings(mesh, bspec, shape.global_batch)

    cache_defs = _cache_defs(cfg, model, shape)
    cache_sh = named(mesh, specs(cache_defs, table, dict(mesh.shape)))

    def prefill_step(params, batch):
        if cfg.family == "audio":
            batch = dict(batch)
            batch["decode_len"] = shape.seq_len
        return model.prefill(params, batch)

    logits_sh = _logits_sharding(cfg, mesh, shape)
    return StepBundle(
        fn=prefill_step,
        abstract_inputs=(abs_params, bspec),
        in_shardings=(psh, bsh),
        out_shardings=(logits_sh, cache_sh),
    )


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------

def _logits_sharding(cfg: ModelConfig, mesh: Mesh,
                     shape: InputShape) -> NamedSharding:
    daxes = _mesh_data_axes(mesh)
    shardable = shape.global_batch % _data_size(mesh) == 0
    vocab_ok = cfg.vocab % mesh.shape["model"] == 0
    return NamedSharding(mesh, P(daxes if shardable else None, None,
                                 "model" if vocab_ok else None))


def _cache_defs(cfg: ModelConfig, model, shape: InputShape):
    if cfg.family == "audio":
        return model.cache_defs(shape.global_batch, shape.seq_len)
    return model.cache_defs(shape.global_batch, shape.seq_len)


def build_decode_step(cfg: ModelConfig, mesh: Mesh, shape: InputShape,
                      rules: str = "tp", unroll: bool = False) -> StepBundle:
    model = build_model(cfg)
    model.unroll = unroll
    defs = model.param_defs()
    table = rule_table(mesh, shape.global_batch, rules)
    psh = named(mesh, specs(defs, table, dict(mesh.shape)))
    abs_params = abstract(defs)

    cache_defs = _cache_defs(cfg, model, shape)
    cache_sh = named(mesh, specs(cache_defs, table, dict(mesh.shape)))
    abs_cache = abstract(cache_defs)

    daxes = _mesh_data_axes(mesh)
    shardable = shape.global_batch % _data_size(mesh) == 0
    tok_sh = NamedSharding(mesh, P(daxes if shardable else None, None))
    abs_tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    abs_pos = jax.ShapeDtypeStruct((), jnp.int32)
    pos_sh = NamedSharding(mesh, P())

    def decode_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)

    logits_sh = _logits_sharding(cfg, mesh, shape)
    return StepBundle(
        fn=decode_step,
        abstract_inputs=(abs_params, abs_cache, abs_tok, abs_pos),
        in_shardings=(psh, cache_sh, tok_sh, pos_sh),
        out_shardings=(logits_sh, cache_sh),
        donate_argnums=(1,),
    )


def build_step(cfg: ModelConfig, mesh: Mesh, shape: InputShape,
               rules: str = "tp", **kw) -> StepBundle:
    """Dispatch on the input-shape kind; applies the long_500k window
    override (DESIGN §4) automatically."""
    if shape.name == "long_500k":
        cfg = cfg.with_sliding_windows()
    if shape.kind == "train":
        # Production default: 4 microbatches (grad accumulation) keeps the
        # per-device activation footprint inside v5e HBM (EXPERIMENTS §Perf).
        if mesh.devices.size >= 64:
            kw.setdefault("microbatch", 4)
        return build_train_step(cfg, mesh, shape, rules, **kw)
    kw.pop("microbatch", None)
    kw.pop("microbatch_unroll", None)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, mesh, shape, rules, **kw)
    return build_decode_step(cfg, mesh, shape, rules, **kw)
