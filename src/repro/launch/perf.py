import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb runner: dry-run one (arch × shape) with optimisation
levers applied, so before/after roofline terms are comparable.

Levers (combinable):
  --flash N              enable blockwise attention above seq N
  --pad-heads N          pad query-head count (zero wo rows) to divide TP
  --mb-unroll            unrolled grad accumulation (all-reduce reassoc.)
  --microbatch M         grad-accumulation factor (train shapes)
  --rules tp|tp_fsdp     weight sharding rule table

Example:
  PYTHONPATH=src python -m repro.launch.perf --arch llava-next-34b \
      --shape prefill_32k --flash 8192 --pad-heads 64 --json results/perf.jsonl
"""
import argparse
import dataclasses
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--flash", type=int, default=None)
    ap.add_argument("--flash-block", type=int, default=512)
    ap.add_argument("--pad-heads", type=int, default=None)
    ap.add_argument("--chunk", type=int, default=None,
                    help="override SSD chunk length (ssm archs)")
    ap.add_argument("--pad-vocab", type=int, default=None,
                    help="pad vocab to divide the tensor axis (zero rows)")
    ap.add_argument("--ce-chunks", type=int, default=None,
                    help="chunked-vocab logsumexp CE (train shapes)")
    ap.add_argument("--mb-unroll", action="store_true")
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--rules", default="tp")
    ap.add_argument("--batch", type=int, default=None,
                    help="override global batch (serving wave size)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--label", default="")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()

    import jax
    from repro import configs
    from repro.launch import dryrun, hlo_analysis, mesh as mesh_lib
    from repro.launch.steps import build_step
    from repro.models.config import INPUT_SHAPES

    cfg = configs.get_config(args.arch)
    changes = {}
    if args.flash is not None:
        changes.update(flash_threshold=args.flash,
                       flash_block=args.flash_block)
    if args.pad_heads is not None:
        assert args.pad_heads >= cfg.n_heads
        changes.update(n_heads=args.pad_heads)
    if args.chunk is not None:
        assert cfg.ssm is not None
        changes.update(ssm=dataclasses.replace(cfg.ssm, chunk=args.chunk))
    if args.pad_vocab is not None:
        assert args.pad_vocab >= cfg.vocab
        changes.update(vocab=args.pad_vocab)
    if args.ce_chunks is not None:
        changes.update(ce_vocab_chunks=args.ce_chunks)
    if changes:
        cfg = dataclasses.replace(cfg, **changes)

    shape = INPUT_SHAPES[args.shape]
    if args.batch is not None:
        shape = dataclasses.replace(shape, global_batch=args.batch)
    mesh = mesh_lib.make_production_mesh(multi_pod=args.multi_pod)
    step_kw = {}
    if shape.kind == "train":
        if args.microbatch is not None:
            step_kw["microbatch"] = args.microbatch
        if args.mb_unroll:
            step_kw["microbatch_unroll"] = True

    # Memory run (production program).
    t0 = time.time()
    bundle = build_step(cfg, mesh, shape, rules=args.rules, **step_kw)
    compiled = dryrun._compile(bundle, mesh)
    mem = compiled.memory_analysis()
    scan_cost = dryrun._costs(compiled)
    rec = {
        "arch": args.arch, "shape": args.shape,
        "mesh": "2x16x16" if args.multi_pod else "16x16",
        "rules": args.rules,
        "label": args.label or "+".join(
            k for k, v in [("flash", args.flash),
                           ("padheads", args.pad_heads),
                           ("padvocab", args.pad_vocab),
                           ("mbunroll", args.mb_unroll or None),
                           (f"mb{args.microbatch}", args.microbatch)] if v),
        "compile_s": round(time.time() - t0, 1),
        "memory": hlo_analysis.memory_dict(mem),
        "scan_counted": scan_cost,
    }

    if not args.no_probes:
        probe = {}
        pk = dict(step_kw)
        pk["microbatch"] = 1
        pk.pop("microbatch_unroll", None)
        if shape.kind != "train":
            pk = {}
        for k in (1, 2):
            cfg_k = dryrun._shrink_depth(cfg, k)
            b_k = build_step(cfg_k, mesh, shape, rules=args.rules,
                             unroll=True, **pk)
            probe[k] = dryrun._costs(dryrun._compile(b_k, mesh))
        R = cfg.n_layers // len(cfg.pattern)
        for key in ("flops", "hlo_bytes"):
            rec[key] = probe[1][key] + (R - 1) * (probe[2][key] -
                                                  probe[1][key])
        rec["collective_bytes"] = {
            op: probe[1]["collective_bytes"][op] + (R - 1) * (
                probe[2]["collective_bytes"][op] -
                probe[1]["collective_bytes"][op])
            for op in probe[1]["collective_bytes"]}
        terms = hlo_analysis.roofline_terms(
            rec["flops"], rec["hlo_bytes"],
            sum(rec["collective_bytes"].values()))
        rec.update(terms)

    print(json.dumps(rec, indent=1))
    if args.json:
        with open(args.json, "a") as f:
            f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
