"""Production mesh construction (TPU v5e pods).

Single pod: 16×16 = 256 chips, axes (data, model).
Multi-pod:  2×16×16 = 512 chips, axes (pod, data, model) — the "pod" axis is
the slow inter-pod (DCN-ish) dimension; only data-parallel collectives cross
it.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run launcher must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

from repro.core.compat import auto_axis_types, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=auto_axis_types(len(axes)))


def make_host_mesh(model: int = 2, data: int | None = None, pod: int = 1):
    """Small mesh over whatever local devices exist (tests/examples)."""
    n = jax.device_count()
    if data is None:
        data = n // (model * pod)
    assert pod * data * model == n, (pod, data, model, n)
    if pod > 1:
        return make_mesh((pod, data, model), ("pod", "data", "model"),
                         axis_types=auto_axis_types(3))
    return make_mesh((data, model), ("data", "model"),
                     axis_types=auto_axis_types(2))


# TPU v5e hardware constants for the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW_PER_LINK = 50e9          # bytes/s per link


def data_axes(mesh) -> tuple[str, ...]:
    """Axes that shard the batch/time dimension."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
