"""Whole-brain demo — materialise → fit → save → serve on commodity RAM.

The paper's Table 1 whole-brain subject (t≈264k targets) is the shape
where even the row-streamed tier dies: its accumulated ``(k, p, t)`` fold
statistics alone are ~1 GB (8-fold CV at p=128) and the unblocked
statistics solve tops 1.4 GB resident.  This driver runs the full loop on a synthetic subject of
exactly that target width (downscaled ``n`` — the target axis is what is
being proven) with every phase in its OWN subprocess, so each peak RSS
(``getrusage(RUSAGE_SELF).ru_maxrss``) is an honest per-phase high-water
mark:

* **materialise** — ``RunStore.materialize_synthetic`` writes the
  CNeuroMod-shaped subject run by run (never holding (n, t)).
* **fit** (once per ``--t-block`` value) — ``wholebrain.fit_wholebrain``
  under a memory budget that dispatch resolves to ``method="colblocked"``;
  the child HARD-ASSERTS the column-block update compiled exactly once
  across all blocks AND that its peak RSS stays under a cap the unblocked
  path provably could not survive (the cap binds: the child refuses to
  run if the unblocked estimate fits it).  The first fit streams its
  weight shards through ``BundleWriter`` into an ``EncoderBundle``.
* **fit** also gates the single-X-pass composition: the X-statistics
  pass rides the first target block's stream and an in-budget chunk
  cache replays X for later blocks, so telemetry must show at most 2
  row passes over X — never one per block.
* **ab** — fused-vs-unfused kernel-tier A/B of the same composition at
  a downscaled t (interpret mode on CPU), asserting bitwise λ parity
  and recording the roofline placement.
* **serve** — opens the bundle in an ``EncoderRegistry`` and serves
  column-windowed predictions (``EncoderService.predict_columns``),
  asserting only the touched weight shards were paged in.

Writes ``BENCH_wholebrain.json``: wall / peak RSS / bytes staged /
compile counts per fit, keyed by ``t_block``, plus the serve paging
stats.  ``--smoke`` shrinks ``n`` and the fold count (CI lane shape) —
the target axis stays FULL SCALE, so the cap proof is unchanged.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))
_T_FULL = 262_144                       # paper Table 1 whole-brain order
_P = 128

# (n, n_folds, rows_per_run, chunk_rows, t_blocks)
_FULL = (1024, 8, 64, 256, (16_384, 20_480))   # 20_480: ragged 16k tail
_SMOKE = (256, 6, 64, 128, (16_384, 20_480))   # 20_480: ragged 16k tail


def _result(payload: dict) -> None:
    print("WHOLEBRAIN_RESULT " + json.dumps(payload), flush=True)


def _peak_rss_mb() -> float:
    import resource
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024


def phase_materialise(args) -> None:
    from repro.data import fmri
    from repro.data.store import MANIFEST_NAME, RunStore

    t0 = time.time()
    if not os.path.exists(os.path.join(args.store, MANIFEST_NAME)):
        spec = fmri.SubjectSpec(n=args.n, p=_P, t=args.t)
        RunStore.create(args.store, n_folds=args.n_folds)\
            .materialize_synthetic(spec, rows_per_run=args.rows_per_run)
    store = RunStore.open(args.store)
    _result({"phase": "materialise", "wall_s": round(time.time() - t0, 2),
             "peak_rss_mb": round(_peak_rss_mb(), 1),
             "shape": list(store.shape),
             "store_gb": round(store.nbytes_resident() / 2**30, 2)})


def phase_fit(args) -> None:
    import jax
    import numpy as np

    from repro.data.store import RunStore
    from repro.encoding.config import EncoderConfig
    from repro.encoding.dispatch import chunked_stats_bytes, resolve
    from repro.encoding.estimator import EncodingReport
    from repro.wholebrain import BundleWriter, fit_wholebrain

    store = RunStore.open(args.store)
    n, p, t = store.shape
    cap_bytes = int(args.cap_mb * 2**20)
    # The cap must BIND: the unblocked statistics solve holds the
    # (k, p, p+t) fold statistics plus C_total/Â/W working arrays.  If
    # that estimate fits the cap, this run would prove nothing — refuse.
    unblocked_mb = (chunked_stats_bytes(args.n_folds, p, t)
                    + 3 * p * t * 4) / 2**20
    if unblocked_mb <= args.cap_mb:
        raise SystemExit(
            f"cap {args.cap_mb} MB does not bind: the unblocked path needs "
            f"only ~{unblocked_mb:.0f} MB — raise t or lower the cap")

    cfg = EncoderConfig(n_folds=args.n_folds, chunk_rows=args.chunk_rows,
                        device_memory_budget=cap_bytes,
                        target_block=args.t_block)
    decision = resolve(cfg, n, p, t, jax.device_count())
    assert decision.method == "colblocked", decision
    t0 = time.time()
    if args.bundle:
        with BundleWriter(args.bundle, p=p, t=t, overwrite=True) as w:
            res = fit_wholebrain(store, cfg, t_block=decision.target_block,
                                 writer=w, collect=False)
            report = EncodingReport(
                weights=None, best_lambda=res.best_lambda,
                cv_scores=res.cv_scores, lambdas=cfg.lambdas,
                decision=decision)
            w.commit(config=cfg, report=report,
                     lambda_by_target=res.lambda_by_target,
                     provenance={"source": "launch.wholebrain",
                                 "store": args.store,
                                 "t_block": decision.target_block})
    else:
        res = fit_wholebrain(store, cfg, t_block=decision.target_block,
                             collect=False)
    wall = time.time() - t0
    tel = res.telemetry
    # THE deterministic gates (fresh process, so counts are absolute):
    # one trace for the X-only Gram accumulation, one for the column-block
    # update across ALL blocks — the fixed-shape contract on both axes.
    if tel["gram_compile_delta"] != 1 or tel["colblock_compile_delta"] != 1:
        raise SystemExit(f"fixed-shape contract broken: gram compiled "
                         f"{tel['gram_compile_delta']}×, column-block "
                         f"update {tel['colblock_compile_delta']}×")
    # Single-X-pass composition gate: the stats pass rides block 0's
    # stream and the chunk cache replays X for blocks 1+, so X is read
    # at most twice (once + at worst a full re-stream when the cache
    # exceeds the budget) — never once per block.
    if tel["row_passes_x"] > 2:
        raise SystemExit(f"single-X-pass composition broken: "
                         f"{tel['row_passes_x']} row passes over X for "
                         f"{tel['n_blocks']} blocks (expected <= 2)")
    peak = _peak_rss_mb()
    if peak >= args.cap_mb:
        raise SystemExit(f"blocked fit peaked at {peak:.0f} MB RSS — over "
                         f"the {args.cap_mb} MB cap the unblocked path "
                         f"(~{unblocked_mb:.0f} MB) was excluded by")
    _result({"phase": "fit", "t_block": decision.target_block,
             "wall_s": round(wall, 2), "peak_rss_mb": round(peak, 1),
             "unblocked_stats_mb": round(unblocked_mb, 1),
             "n_blocks": tel["n_blocks"],
             "bytes_staged_mb": round(tel["bytes_staged"] / 2**20, 1),
             "read_stall_s": round(tel["read_stall_s"], 2),
             "gram_compiles": tel["gram_compile_delta"],
             "colblock_compiles": tel["colblock_compile_delta"],
             "row_passes_x": tel["row_passes_x"],
             "x_cache_mb": round(tel["x_cache_bytes"] / 2**20, 2),
             "use_pallas": tel["use_pallas"],
             "best_lambda": float(np.asarray(res.best_lambda)[0]),
             "saved_bundle": bool(args.bundle)})


def phase_ab(args) -> None:
    """Fused-vs-unfused A/B of the column-blocked fit (downscaled t).

    On CPU the fused tier runs in interpret mode — a correctness harness,
    orders of magnitude slower than XLA — so at full-scale t the A/B
    would take hours.  It therefore runs the SAME composition (blocked
    CV, single-X-pass, chunk cache) at a small target width, asserts λ
    matches bitwise between the tiers, and anchors the comparison in
    roofline terms (FLOP/byte), which transfer to the compiled tier.
    """
    import numpy as np

    from repro.data import fmri
    from repro.data.store import MANIFEST_NAME, RunStore
    from repro.encoding.config import EncoderConfig
    from repro.kernels.ops import _interpret
    from repro.launch.roofline_report import encoding_roofline
    from repro.wholebrain import fit_wholebrain

    if not os.path.exists(os.path.join(args.store, MANIFEST_NAME)):
        spec = fmri.SubjectSpec(n=args.n, p=_P, t=args.t)
        RunStore.create(args.store, n_folds=args.n_folds)\
            .materialize_synthetic(spec, rows_per_run=args.rows_per_run)
    store = RunStore.open(args.store)
    n, p, t = store.shape

    def run(up: bool):
        cfg = EncoderConfig(n_folds=args.n_folds,
                            chunk_rows=args.chunk_rows, use_pallas=up)
        t0 = time.time()
        res = fit_wholebrain(store, cfg, t_block=args.t_block,
                             collect=False)
        return time.time() - t0, res

    unfused_s, base = run(False)
    fused_s, fused = run(True)
    if (float(np.asarray(base.best_lambda)[0])
            != float(np.asarray(fused.best_lambda)[0])):
        raise SystemExit(f"λ diverged fused-vs-unfused: "
                         f"{base.best_lambda} vs {fused.best_lambda}")
    tier = "interpret" if _interpret() else "compiled"
    roof = encoding_roofline(n, p, t, n_folds=args.n_folds,
                             wall_s=min(unfused_s, fused_s))
    _result({"phase": "ab", "n": n, "p": p, "t": t,
             "t_block": args.t_block, "chunk_rows": args.chunk_rows,
             "unfused_s": round(unfused_s, 2),
             "fused_s": round(fused_s, 2),
             "kernel_tier": tier, "lambda_match": True,
             "row_passes_x": fused.telemetry["row_passes_x"],
             "roofline": roof})


def phase_serve(args) -> None:
    import numpy as np

    from repro.serving_encoders.bundle import EncoderBundle
    from repro.serving_encoders.registry import EncoderRegistry
    from repro.serving_encoders.service import EncoderService

    t0 = time.time()
    bundle = EncoderBundle.open(args.bundle)
    p, t = bundle.shape
    reg = EncoderRegistry(device_memory_budget=64 * 2**20, wave_rows=64)
    reg.add("wholebrain", args.bundle)
    svc = EncoderService(reg, wave_rows=64)
    rng = np.random.default_rng(0)
    X = rng.standard_normal((100, p)).astype(np.float32)
    # Three windowed requests: two distinct windows, then a repeat (cache
    # hit).  Each must page in ONLY its own shards.
    windows = [(1_000, 3_000), (t // 2 + 100, t // 2 + 2_100),
               (1_000, 3_000)]
    expect = set()
    for lo, hi in windows:
        P = svc.predict_columns("wholebrain", X, (lo, hi))
        assert P.shape == (100, hi - lo), P.shape
        # Reference straight off the mmap'd shards.
        idxs = bundle.shards_for_columns(lo, hi)
        expect |= {("wholebrain", i) for i in idxs}
        cols = np.concatenate(
            [np.asarray(bundle.load_weight_shard(i, mmap=True),
                        np.float32) for i in idxs], axis=1)
        first = bundle.weight_shard_bounds()[idxs[0]][0]
        ref = X @ cols[:, lo - first:hi - first]
        assert np.allclose(P, ref, atol=1e-4), "windowed serve mismatch"
    st = reg.stats()
    # The acceptance criterion: only the shards the windows touched are
    # resident — never the full bundle, never an untouched shard.
    assert st["loaded"] == 0, st
    assert set(reg.loaded_shards) == expect, (reg.loaded_shards, expect)
    assert st["shard_loads"] == len(expect), st
    assert st["shard_hits"] > 0, st           # the repeated window hit
    peak = _peak_rss_mb()
    if peak >= args.cap_mb:
        raise SystemExit(f"serve peaked at {peak:.0f} MB RSS — over the "
                         f"{args.cap_mb} MB cap")
    _result({"phase": "serve", "wall_s": round(time.time() - t0, 2),
             "peak_rss_mb": round(peak, 1),
             "weight_shards": bundle.manifest["weight_shards"],
             "shards_paged": st["shard_loads"],
             "shard_hits": st["shard_hits"],
             "resident_mb": round(st["resident_bytes"] / 2**20, 2),
             "compile_count": svc.compile_count})


def _spawn(phase: str, extra: list[str]) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.wholebrain",
         "--phase", phase] + extra,
        capture_output=True, text=True, env=env)
    if proc.returncode != 0:
        raise SystemExit(f"{phase} child failed:\n{proc.stdout}\n"
                         f"{proc.stderr}")
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("WHOLEBRAIN_RESULT ")][-1]
    return json.loads(line[len("WHOLEBRAIN_RESULT "):])


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--phase", default=None,
                    help="(internal) child mode: materialise|fit|serve")
    ap.add_argument("--store", default=None)
    ap.add_argument("--bundle", default=None)
    ap.add_argument("--n", type=int, default=0)
    ap.add_argument("--t", type=int, default=_T_FULL,
                    help="target count (full whole-brain scale by default)")
    ap.add_argument("--n-folds", type=int, default=0)
    ap.add_argument("--rows-per-run", type=int, default=64)
    ap.add_argument("--chunk-rows", type=int, default=0)
    ap.add_argument("--t-block", type=int, default=0)
    ap.add_argument("--cap-mb", type=float, default=1024.0,
                    help="per-phase RSS ceiling; must be fatal to the "
                         "unblocked path (the fit child checks it binds)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI shape: downscaled n/folds, FULL-SCALE t")
    ap.add_argument("--workdir", default=None)
    ap.add_argument("--out", default=None)
    from repro.launch.obscli import add_obs_args, obs_session
    add_obs_args(ap)
    args = ap.parse_args()

    if args.phase:                                 # child mode
        with obs_session(args):
            {"materialise": phase_materialise, "fit": phase_fit,
             "ab": phase_ab, "serve": phase_serve}[args.phase](args)
        return

    import tempfile

    n, n_folds, rows_per_run, chunk_rows, t_blocks = (
        _SMOKE if args.smoke else _FULL)
    n = args.n or n
    n_folds = args.n_folds or n_folds
    chunk_rows = args.chunk_rows or chunk_rows
    workdir = args.workdir or tempfile.mkdtemp(prefix="wholebrain_")
    store = os.path.join(workdir, f"subject_{n}x{_P}x{args.t}")
    bundle = os.path.join(workdir, "bundle")
    if args.out is None:
        args.out = os.path.join(
            REPO, "BENCH_wholebrain_smoke.json" if args.smoke
            else "BENCH_wholebrain.json")

    def obs_extra(tag: str) -> list[str]:
        # Phase children own the tracer: fan the parent's obs flags out
        # with a phase-suffixed path per subprocess.
        extra = []
        for flag, path in (("--trace-out", args.trace_out),
                           ("--metrics-out", args.metrics_out)):
            if path is not None:
                root, ext = os.path.splitext(path)
                extra += [flag, f"{root}.{tag}{ext}"]
        return extra

    print(f"[wholebrain] materialising {n}x{_P}x{args.t} subject ...",
          flush=True)
    mat = _spawn("materialise", [
        "--store", store, "--n", str(n), "--t", str(args.t),
        "--n-folds", str(n_folds), "--rows-per-run", str(rows_per_run)]
        + obs_extra("materialise"))
    print(f"[wholebrain] materialise: {mat['wall_s']}s "
          f"rss={mat['peak_rss_mb']}MB store={mat['store_gb']}GB",
          flush=True)

    fits = []
    for i, t_block in enumerate(t_blocks):
        extra = ["--store", store, "--t-block", str(t_block),
                 "--n-folds", str(n_folds), "--chunk-rows", str(chunk_rows),
                 "--cap-mb", str(args.cap_mb)] \
            + obs_extra(f"fit{t_block}")
        if i == 0:
            extra += ["--bundle", bundle]
        fit = _spawn("fit", extra)
        fits.append(fit)
        print(f"[wholebrain] fit t_block={t_block}: {fit['wall_s']}s "
              f"rss={fit['peak_rss_mb']}MB (unblocked would need "
              f"{fit['unblocked_stats_mb']}MB) blocks={fit['n_blocks']} "
              f"staged={fit['bytes_staged_mb']}MB "
              f"compiles={fit['gram_compiles']}+{fit['colblock_compiles']} "
              f"λ={fit['best_lambda']}", flush=True)
    lams = {f["best_lambda"] for f in fits}
    if len(lams) != 1:
        raise SystemExit(f"λ selection diverged across t_block values: "
                         f"{lams}")

    # Fused-vs-unfused kernel-tier A/B at a downscaled t (interpret mode
    # on CPU is a correctness harness — full-scale fused would take
    # hours); λ parity is asserted in the child, roofline anchors it.
    ab_n, ab_t, ab_tb, ab_chunk = ((128, 512, 128, 64) if args.smoke
                                   else (512, 2048, 512, 128))
    ab_store = os.path.join(workdir, f"ab_subject_{ab_n}x{_P}x{ab_t}")
    ab = _spawn("ab", ["--store", ab_store, "--n", str(ab_n),
                       "--t", str(ab_t), "--t-block", str(ab_tb),
                       "--n-folds", str(n_folds),
                       "--chunk-rows", str(ab_chunk),
                       "--rows-per-run", str(rows_per_run)]
                + obs_extra("ab"))
    print(f"[wholebrain] fused A/B ({ab_n}x{_P}x{ab_t}, "
          f"{ab['kernel_tier']}): unfused {ab['unfused_s']}s vs fused "
          f"{ab['fused_s']}s, λ match, x passes={ab['row_passes_x']}",
          flush=True)

    serve = _spawn("serve", ["--bundle", bundle,
                             "--cap-mb", str(args.cap_mb)]
                   + obs_extra("serve"))
    print(f"[wholebrain] serve: {serve['wall_s']}s "
          f"rss={serve['peak_rss_mb']}MB paged "
          f"{serve['shards_paged']}/{serve['weight_shards']} shards "
          f"({serve['resident_mb']}MB resident)", flush=True)

    payload = {"n": n, "p": _P, "t": args.t, "n_folds": n_folds,
               "chunk_rows": chunk_rows, "rss_cap_mb": args.cap_mb,
               "smoke": args.smoke, "materialise": mat,
               "fit_vs_t_block": fits, "fused_ab": ab, "serve": serve}
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
