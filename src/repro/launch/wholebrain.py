"""Whole-brain demo — materialise → fit → save → serve on commodity RAM.

The paper's Table 1 whole-brain subject (t≈264k targets) is the shape
where even the row-streamed tier dies: its accumulated ``(k, p, t)`` fold
statistics alone are ~1 GB (8-fold CV at p=128) and the unblocked
statistics solve tops 1.4 GB resident.  This driver runs the full loop on a synthetic subject of
exactly that target width (downscaled ``n`` — the target axis is what is
being proven) with every phase in its OWN subprocess, so each peak RSS
(``getrusage(RUSAGE_SELF).ru_maxrss``) is an honest per-phase high-water
mark:

* **materialise** — ``RunStore.materialize_synthetic`` writes the
  CNeuroMod-shaped subject run by run (never holding (n, t)).
* **fit** (once per ``--t-block`` value) — ``wholebrain.fit_wholebrain``
  under a memory budget that dispatch resolves to ``method="colblocked"``;
  the child HARD-ASSERTS the column-block update compiled exactly once
  across all blocks AND that its peak RSS stays under a cap the unblocked
  path provably could not survive (the cap binds: the child refuses to
  run if the unblocked estimate fits it).  The first fit streams its
  weight shards through ``BundleWriter`` into an ``EncoderBundle``.
* **fit** also gates the single-X-pass composition: the X-statistics
  pass rides the first target block's stream and an in-budget chunk
  cache replays X for later blocks, so telemetry must show at most 2
  row passes over X — never one per block.
* **ab** — fused-vs-unfused kernel-tier A/B of the same composition at
  a downscaled t (interpret mode on CPU), asserting bitwise λ parity
  and recording the roofline placement.
* **serve** — opens the bundle in an ``EncoderRegistry`` and serves
  column-windowed predictions (``EncoderService.predict_columns``),
  asserting only the touched weight shards were paged in.

Writes ``BENCH_wholebrain.json``: wall / peak RSS / bytes staged /
compile counts per fit, keyed by ``t_block``, plus the serve paging
stats.  ``--smoke`` shrinks ``n`` and the fold count (CI lane shape) —
the target axis stays FULL SCALE, so the cap proof is unchanged.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import subprocess
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))
_T_FULL = 262_144                       # paper Table 1 whole-brain order
_P = 128

# (n, n_folds, rows_per_run, chunk_rows, t_blocks)
_FULL = (1024, 8, 64, 256, (16_384, 20_480))   # 20_480: ragged 16k tail
_SMOKE = (256, 6, 64, 128, (16_384, 20_480))   # 20_480: ragged 16k tail


def _result(payload: dict) -> None:
    print("WHOLEBRAIN_RESULT " + json.dumps(payload), flush=True)


def _peak_rss_mb() -> float:
    import resource
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024


def phase_materialise(args) -> None:
    from repro.data import fmri
    from repro.data.store import MANIFEST_NAME, RunStore

    t0 = time.time()
    if not os.path.exists(os.path.join(args.store, MANIFEST_NAME)):
        spec = fmri.SubjectSpec(n=args.n, p=_P, t=args.t)
        RunStore.create(args.store, n_folds=args.n_folds)\
            .materialize_synthetic(spec, rows_per_run=args.rows_per_run)
    store = RunStore.open(args.store)
    _result({"phase": "materialise", "wall_s": round(time.time() - t0, 2),
             "peak_rss_mb": round(_peak_rss_mb(), 1),
             "shape": list(store.shape),
             "store_gb": round(store.nbytes_resident() / 2**30, 2)})


def phase_fit(args) -> None:
    import jax
    import numpy as np

    from repro.data.store import RunStore
    from repro.encoding.config import EncoderConfig
    from repro.encoding.dispatch import chunked_stats_bytes, resolve
    from repro.encoding.estimator import EncodingReport
    from repro.wholebrain import BundleWriter, fit_wholebrain

    store = RunStore.open(args.store)
    n, p, t = store.shape
    cap_bytes = int(args.cap_mb * 2**20)
    # The cap must BIND: the unblocked statistics solve holds the
    # (k, p, p+t) fold statistics plus C_total/Â/W working arrays.  If
    # that estimate fits the cap, this run would prove nothing — refuse.
    unblocked_mb = (chunked_stats_bytes(args.n_folds, p, t)
                    + 3 * p * t * 4) / 2**20
    if unblocked_mb <= args.cap_mb:
        raise SystemExit(
            f"cap {args.cap_mb} MB does not bind: the unblocked path needs "
            f"only ~{unblocked_mb:.0f} MB — raise t or lower the cap")

    cfg = EncoderConfig(n_folds=args.n_folds, chunk_rows=args.chunk_rows,
                        device_memory_budget=cap_bytes,
                        target_block=args.t_block)
    decision = resolve(cfg, n, p, t, jax.device_count())
    assert decision.method == "colblocked", decision
    t0 = time.time()
    if args.bundle:
        with BundleWriter(args.bundle, p=p, t=t, overwrite=True) as w:
            res = fit_wholebrain(store, cfg, t_block=decision.target_block,
                                 writer=w, collect=False,
                                 journal=args.journal or None)
            report = EncodingReport(
                weights=None, best_lambda=res.best_lambda,
                cv_scores=res.cv_scores, lambdas=cfg.lambdas,
                decision=decision)
            w.commit(config=cfg, report=report,
                     lambda_by_target=res.lambda_by_target,
                     provenance={"source": "launch.wholebrain",
                                 "store": args.store,
                                 "t_block": decision.target_block})
    else:
        res = fit_wholebrain(store, cfg, t_block=decision.target_block,
                             collect=False)
    wall = time.time() - t0
    tel = res.telemetry
    # THE deterministic gates (fresh process, so counts are absolute):
    # one trace for the X-only Gram accumulation, one for the column-block
    # update across ALL blocks — the fixed-shape contract on both axes.
    if tel["gram_compile_delta"] != 1 or tel["colblock_compile_delta"] != 1:
        raise SystemExit(f"fixed-shape contract broken: gram compiled "
                         f"{tel['gram_compile_delta']}×, column-block "
                         f"update {tel['colblock_compile_delta']}×")
    # Single-X-pass composition gate: the stats pass rides block 0's
    # stream and the chunk cache replays X for blocks 1+, so X is read
    # at most twice (once + at worst a full re-stream when the cache
    # exceeds the budget) — never once per block.
    if tel["row_passes_x"] > 2:
        raise SystemExit(f"single-X-pass composition broken: "
                         f"{tel['row_passes_x']} row passes over X for "
                         f"{tel['n_blocks']} blocks (expected <= 2)")
    peak = _peak_rss_mb()
    if peak >= args.cap_mb:
        raise SystemExit(f"blocked fit peaked at {peak:.0f} MB RSS — over "
                         f"the {args.cap_mb} MB cap the unblocked path "
                         f"(~{unblocked_mb:.0f} MB) was excluded by")
    _result({"phase": "fit", "t_block": decision.target_block,
             "wall_s": round(wall, 2), "peak_rss_mb": round(peak, 1),
             "unblocked_stats_mb": round(unblocked_mb, 1),
             "n_blocks": tel["n_blocks"],
             "bytes_staged_mb": round(tel["bytes_staged"] / 2**20, 1),
             "read_stall_s": round(tel["read_stall_s"], 2),
             "gram_compiles": tel["gram_compile_delta"],
             "colblock_compiles": tel["colblock_compile_delta"],
             "row_passes_x": tel["row_passes_x"],
             "x_cache_mb": round(tel["x_cache_bytes"] / 2**20, 2),
             "use_pallas": tel["use_pallas"],
             "best_lambda": float(np.asarray(res.best_lambda)[0]),
             "saved_bundle": bool(args.bundle)})


def phase_ab(args) -> None:
    """Fused-vs-unfused A/B of the column-blocked fit (downscaled t).

    On CPU the fused tier runs in interpret mode — a correctness harness,
    orders of magnitude slower than XLA — so at full-scale t the A/B
    would take hours.  It therefore runs the SAME composition (blocked
    CV, single-X-pass, chunk cache) at a small target width, asserts λ
    matches bitwise between the tiers, and anchors the comparison in
    roofline terms (FLOP/byte), which transfer to the compiled tier.
    """
    import numpy as np

    from repro.data import fmri
    from repro.data.store import MANIFEST_NAME, RunStore
    from repro.encoding.config import EncoderConfig
    from repro.kernels.ops import _interpret
    from repro.launch.roofline_report import encoding_roofline
    from repro.wholebrain import fit_wholebrain

    if not os.path.exists(os.path.join(args.store, MANIFEST_NAME)):
        spec = fmri.SubjectSpec(n=args.n, p=_P, t=args.t)
        RunStore.create(args.store, n_folds=args.n_folds)\
            .materialize_synthetic(spec, rows_per_run=args.rows_per_run)
    store = RunStore.open(args.store)
    n, p, t = store.shape

    def run(up: bool):
        cfg = EncoderConfig(n_folds=args.n_folds,
                            chunk_rows=args.chunk_rows, use_pallas=up)
        t0 = time.time()
        res = fit_wholebrain(store, cfg, t_block=args.t_block,
                             collect=False)
        return time.time() - t0, res

    unfused_s, base = run(False)
    fused_s, fused = run(True)
    if (float(np.asarray(base.best_lambda)[0])
            != float(np.asarray(fused.best_lambda)[0])):
        raise SystemExit(f"λ diverged fused-vs-unfused: "
                         f"{base.best_lambda} vs {fused.best_lambda}")
    tier = "interpret" if _interpret() else "compiled"
    roof = encoding_roofline(n, p, t, n_folds=args.n_folds,
                             wall_s=min(unfused_s, fused_s))
    _result({"phase": "ab", "n": n, "p": p, "t": t,
             "t_block": args.t_block, "chunk_rows": args.chunk_rows,
             "unfused_s": round(unfused_s, 2),
             "fused_s": round(fused_s, 2),
             "kernel_tier": tier, "lambda_match": True,
             "row_passes_x": fused.telemetry["row_passes_x"],
             "roofline": roof})


def phase_crashfit(args) -> None:
    """One blocked fit at crash-gate scale, journalled and optionally
    killed (``--kill-after-block``) or fed injected transient read
    faults (``--inject-read-faults``).

    Three invocations compose the parent's crash-resume gate: an
    uninterrupted reference, a child that ``os._exit``\\ s right after
    journalling block N (modelling SIGKILL — no cleanup handlers run),
    and a resume against the same journal that must replay blocks
    0..N and re-stream only the rest.  The child reports λ plus the
    resume/retry telemetry; bit-identity of the weight shards is the
    PARENT's check (raw ``.npy`` bytes across the two bundles).
    """
    import jax
    import numpy as np

    from repro import obs
    from repro.data import fmri
    from repro.data.store import MANIFEST_NAME, RunStore
    from repro.encoding.config import EncoderConfig
    from repro.encoding.dispatch import resolve
    from repro.encoding.estimator import EncodingReport
    from repro.wholebrain import BundleWriter, fit_wholebrain
    from repro.wholebrain.solver import journal_signature

    if not os.path.exists(os.path.join(args.store, MANIFEST_NAME)):
        spec = fmri.SubjectSpec(n=args.n, p=_P, t=args.t)
        RunStore.create(args.store, n_folds=args.n_folds)\
            .materialize_synthetic(spec, rows_per_run=args.rows_per_run)

    fault_policy = None
    injector = None
    if args.inject_read_faults:
        from repro.resilience import faultsim
        from repro.resilience.policy import FaultPolicy
        # Virtual time: retries are deterministic and the child never
        # actually sleeps — backoff delays only accumulate in a counter.
        fault_policy = FaultPolicy(max_attempts=3, seed=7).with_virtual_time()
        injector = faultsim.FaultInjector(seed=7)
        injector.plan("store.mmap", 1)        # first fold-matrix mmap
        injector.plan("store.chunk", 2)       # mid block 0's stream
        injector.plan("store.chunk", 7)       # a later block's re-stream
    store = RunStore.open(args.store, fault_policy=fault_policy)
    if injector is not None:
        from repro.resilience import faultsim
        store = faultsim.wrap_store(store, injector)

    cfg = EncoderConfig(n_folds=args.n_folds, chunk_rows=args.chunk_rows,
                        target_block=args.t_block)
    journal = args.journal or None
    if journal is not None and args.kill_after_block >= 0:
        from repro.resilience import faultsim
        from repro.resilience.journal import FitJournal
        sig = journal_signature(store, cfg, t_block=args.t_block)
        journal = faultsim.KillAfterBlock(
            FitJournal.attach(journal, sig), args.kill_after_block)

    n, p, t = store.shape
    decision = resolve(cfg, n, p, t, jax.device_count())
    t0 = time.time()
    with BundleWriter(args.bundle, p=p, t=t, overwrite=True) as w:
        res = fit_wholebrain(store, cfg, t_block=args.t_block,
                             writer=w, collect=False, journal=journal)
        report = EncodingReport(
            weights=None, best_lambda=res.best_lambda,
            cv_scores=res.cv_scores, lambdas=cfg.lambdas,
            decision=decision)
        w.commit(config=cfg, report=report,
                 lambda_by_target=res.lambda_by_target,
                 provenance={"source": "launch.wholebrain:crashfit"})
    tel = res.telemetry
    # Fixed-shape contract survives both resume and injected faults: the
    # column-block update compiles exactly once; the Gram accumulation
    # compiles once on a fresh fit and ZERO times on resume (the X-stats
    # pass is replayed from the journal, never re-run).
    want_gram = 0 if tel["resumed"] else 1
    if (tel["gram_compile_delta"] != want_gram
            or tel["colblock_compile_delta"] != 1):
        raise SystemExit(
            f"fixed-shape contract broken under "
            f"{'resume' if tel['resumed'] else 'faults/clean run'}: gram "
            f"compiled {tel['gram_compile_delta']}× (want {want_gram}), "
            f"column-block update {tel['colblock_compile_delta']}×")
    counters = obs.snapshot().get("counters", {})
    retries = int(sum(v for k, v in counters.items()
                      if k.startswith("io_retries")))
    giveups = int(sum(v for k, v in counters.items()
                      if k.startswith("io_giveups")))
    if args.inject_read_faults and giveups:
        raise SystemExit(f"injected transient faults escalated to "
                         f"{giveups} give-ups")
    _result({"phase": "crashfit", "wall_s": round(time.time() - t0, 2),
             "n_blocks": tel["n_blocks"],
             "resumed": tel["resumed"],
             "blocks_replayed": tel["blocks_replayed"],
             "blocks_streamed": tel["blocks_streamed"],
             "row_passes_x": tel["row_passes_x"],
             "bytes_staged": tel["bytes_staged"],
             "gram_compiles": tel["gram_compile_delta"],
             "colblock_compiles": tel["colblock_compile_delta"],
             "io_retries": retries, "io_giveups": giveups,
             "best_lambda": float(np.asarray(res.best_lambda)[0])})


def phase_serve(args) -> None:
    import numpy as np

    from repro.serving_encoders.bundle import EncoderBundle
    from repro.serving_encoders.registry import EncoderRegistry
    from repro.serving_encoders.service import EncoderService

    t0 = time.time()
    bundle = EncoderBundle.open(args.bundle)
    p, t = bundle.shape
    reg = EncoderRegistry(device_memory_budget=64 * 2**20, wave_rows=64)
    reg.add("wholebrain", args.bundle)
    svc = EncoderService(reg, wave_rows=64)
    rng = np.random.default_rng(0)
    X = rng.standard_normal((100, p)).astype(np.float32)
    # Three windowed requests: two distinct windows, then a repeat (cache
    # hit).  Each must page in ONLY its own shards.
    windows = [(1_000, 3_000), (t // 2 + 100, t // 2 + 2_100),
               (1_000, 3_000)]
    expect = set()
    for lo, hi in windows:
        P = svc.predict_columns("wholebrain", X, (lo, hi))
        assert P.shape == (100, hi - lo), P.shape
        # Reference straight off the mmap'd shards.
        idxs = bundle.shards_for_columns(lo, hi)
        expect |= {("wholebrain", i) for i in idxs}
        cols = np.concatenate(
            [np.asarray(bundle.load_weight_shard(i, mmap=True),
                        np.float32) for i in idxs], axis=1)
        first = bundle.weight_shard_bounds()[idxs[0]][0]
        ref = X @ cols[:, lo - first:hi - first]
        assert np.allclose(P, ref, atol=1e-4), "windowed serve mismatch"
    st = reg.stats()
    # The acceptance criterion: only the shards the windows touched are
    # resident — never the full bundle, never an untouched shard.
    assert st["loaded"] == 0, st
    assert set(reg.loaded_shards) == expect, (reg.loaded_shards, expect)
    assert st["shard_loads"] == len(expect), st
    assert st["shard_hits"] > 0, st           # the repeated window hit
    peak = _peak_rss_mb()
    if peak >= args.cap_mb:
        raise SystemExit(f"serve peaked at {peak:.0f} MB RSS — over the "
                         f"{args.cap_mb} MB cap")
    _result({"phase": "serve", "wall_s": round(time.time() - t0, 2),
             "peak_rss_mb": round(peak, 1),
             "weight_shards": bundle.manifest["weight_shards"],
             "shards_paged": st["shard_loads"],
             "shard_hits": st["shard_hits"],
             "resident_mb": round(st["resident_bytes"] / 2**20, 2),
             "compile_count": svc.compile_count})


def _spawn(phase: str, extra: list[str], *, expect_code: int = 0) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.wholebrain",
         "--phase", phase] + extra,
        capture_output=True, text=True, env=env)
    if proc.returncode != expect_code:
        raise SystemExit(f"{phase} child exited {proc.returncode} "
                         f"(expected {expect_code}):\n{proc.stdout}\n"
                         f"{proc.stderr}")
    if expect_code != 0:
        return {}            # a killed child never prints a result line
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("WHOLEBRAIN_RESULT ")][-1]
    return json.loads(line[len("WHOLEBRAIN_RESULT "):])


_CRASH_EXIT = 42             # KillAfterBlock's os._exit code


def run_crash_gate(workdir, *, n_folds: int, rows_per_run: int,
                   smoke: bool, kill_after_block: int, obs_extra) -> dict:
    """The crash-resume gate: reference fit → killed fit → resumed fit.

    Asserts the resume replayed exactly the journalled blocks, streamed
    only the remainder (strictly fewer bytes staged than the reference),
    selected a bit-equal λ, and wrote weight shards whose raw ``.npy``
    bytes match the uninterrupted bundle's.  A fourth fit with injected
    transient read faults must retry through them with identical λ and
    unchanged compile counts.
    """
    import filecmp

    cg_n, cg_t, cg_tb, cg_chunk = ((128, 512, 128, 64) if smoke
                                   else (256, 1024, 256, 64))
    store = os.path.join(workdir, f"crash_subject_{cg_n}x{_P}x{cg_t}")
    base = ["--store", store, "--n", str(cg_n), "--t", str(cg_t),
            "--t-block", str(cg_tb), "--n-folds", str(n_folds),
            "--chunk-rows", str(cg_chunk),
            "--rows-per-run", str(rows_per_run)]
    bundle_ref = os.path.join(workdir, "crash_bundle_ref")
    bundle_res = os.path.join(workdir, "crash_bundle_resumed")
    jdir = os.path.join(workdir, "crash_journal")
    # Idempotent on a reused workdir: a previous run's artifacts would
    # otherwise spoof the "killed child published nothing" assertion.
    for stale in (bundle_ref, bundle_res, jdir):
        if os.path.isdir(stale):
            shutil.rmtree(stale)

    ref = _spawn("crashfit", base + ["--bundle", bundle_ref]
                 + obs_extra("crashref"))
    n_blocks = ref["n_blocks"]
    if not 0 <= kill_after_block < n_blocks - 1:
        raise SystemExit(f"--kill-after-block {kill_after_block} leaves "
                         f"nothing to resume ({n_blocks} blocks)")
    _spawn("crashfit", base + [
        "--bundle", bundle_res, "--journal", jdir,
        "--kill-after-block", str(kill_after_block)],
        expect_code=_CRASH_EXIT)
    if not os.path.isdir(jdir):
        raise SystemExit("killed child left no journal to resume from")
    if os.path.isdir(bundle_res):
        raise SystemExit("killed child published a bundle — the atomic "
                         "commit boundary leaked")
    res = _spawn("crashfit", base + ["--bundle", bundle_res,
                                     "--journal", jdir]
                 + obs_extra("crashresume"))

    want_replayed = kill_after_block + 1
    if (not res["resumed"] or res["blocks_replayed"] != want_replayed
            or res["blocks_streamed"] != n_blocks - want_replayed):
        raise SystemExit(f"resume accounting wrong: {res} (expected "
                         f"{want_replayed} replayed of {n_blocks})")
    if res["bytes_staged"] >= ref["bytes_staged"]:
        raise SystemExit(f"resume re-streamed as much as a fresh fit "
                         f"({res['bytes_staged']} vs "
                         f"{ref['bytes_staged']} bytes)")
    if res["best_lambda"] != ref["best_lambda"]:
        raise SystemExit(f"λ diverged across crash-resume: "
                         f"{res['best_lambda']} vs {ref['best_lambda']}")
    step_ref = os.path.join(bundle_ref, "step_0")
    step_res = os.path.join(bundle_res, "step_0")
    shards = sorted(f for f in os.listdir(step_ref) if f.startswith("W__"))
    if not shards or shards != sorted(
            f for f in os.listdir(step_res) if f.startswith("W__")):
        raise SystemExit("resumed bundle's weight shard set differs")
    for fname in shards:
        if not filecmp.cmp(os.path.join(step_ref, fname),
                           os.path.join(step_res, fname), shallow=False):
            raise SystemExit(f"weight shard {fname} not bit-identical "
                             f"after crash-resume")
    if os.path.isdir(jdir):
        raise SystemExit("journal survived a successful resume")

    faulty = _spawn("crashfit", base + [
        "--bundle", os.path.join(workdir, "crash_bundle_faulty"),
        "--inject-read-faults"] + obs_extra("crashfaulty"))
    if faulty["best_lambda"] != ref["best_lambda"]:
        raise SystemExit(f"λ diverged under injected read faults: "
                         f"{faulty['best_lambda']} vs "
                         f"{ref['best_lambda']}")
    if faulty["io_retries"] < 3 or faulty["io_giveups"]:
        raise SystemExit(f"fault injection did not exercise the retry "
                         f"path: {faulty}")
    return {"kill_after_block": kill_after_block, "n_blocks": n_blocks,
            "w_shards_bitwise": len(shards), "ref": ref, "resumed": res,
            "faulty": faulty}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--phase", default=None,
                    help="(internal) child mode: materialise|fit|serve")
    ap.add_argument("--store", default=None)
    ap.add_argument("--bundle", default=None)
    ap.add_argument("--n", type=int, default=0)
    ap.add_argument("--t", type=int, default=_T_FULL,
                    help="target count (full whole-brain scale by default)")
    ap.add_argument("--n-folds", type=int, default=0)
    ap.add_argument("--rows-per-run", type=int, default=64)
    ap.add_argument("--chunk-rows", type=int, default=0)
    ap.add_argument("--t-block", type=int, default=0)
    ap.add_argument("--cap-mb", type=float, default=1024.0,
                    help="per-phase RSS ceiling; must be fatal to the "
                         "unblocked path (the fit child checks it binds)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI shape: downscaled n/folds, FULL-SCALE t")
    ap.add_argument("--workdir", default=None)
    ap.add_argument("--out", default=None)
    ap.add_argument("--journal", default=None,
                    help="progress-journal dir: makes the fit resumable "
                         "(repro.resilience.FitJournal)")
    ap.add_argument("--kill-after-block", type=int, default=-1,
                    help="crash gate: the killed child os._exits right "
                         "after journalling this block index (parent "
                         "default: block 1)")
    ap.add_argument("--inject-read-faults", action="store_true",
                    help="(crashfit child) seeded transient faults on "
                         "chunk reads + fold-matrix mmaps")
    ap.add_argument("--crash-only", action="store_true",
                    help="run ONLY the crash-resume gate (CI faults lane)")
    from repro.launch.obscli import add_obs_args, obs_session
    add_obs_args(ap)
    args = ap.parse_args()

    if args.phase:                                 # child mode
        with obs_session(args):
            {"materialise": phase_materialise, "fit": phase_fit,
             "ab": phase_ab, "serve": phase_serve,
             "crashfit": phase_crashfit}[args.phase](args)
        return

    import tempfile

    n, n_folds, rows_per_run, chunk_rows, t_blocks = (
        _SMOKE if args.smoke else _FULL)
    n = args.n or n
    n_folds = args.n_folds or n_folds
    chunk_rows = args.chunk_rows or chunk_rows
    workdir = args.workdir or tempfile.mkdtemp(prefix="wholebrain_")
    store = os.path.join(workdir, f"subject_{n}x{_P}x{args.t}")
    bundle = os.path.join(workdir, "bundle")
    if args.out is None:
        # Smoke runs with an explicit workdir keep their artifact there
        # (CI lanes read it from $RUNNER_TEMP); real runs land at the root.
        out_root = workdir if args.smoke and args.workdir else REPO
        args.out = os.path.join(
            out_root, "BENCH_wholebrain_crash.json" if args.crash_only
            else "BENCH_wholebrain_smoke.json" if args.smoke
            else "BENCH_wholebrain.json")

    kab = args.kill_after_block if args.kill_after_block >= 0 else 1

    def obs_extra(tag: str) -> list[str]:
        # Phase children own the tracer: fan the parent's obs flags out
        # with a phase-suffixed path per subprocess.
        extra = []
        for flag, path in (("--trace-out", args.trace_out),
                           ("--metrics-out", args.metrics_out)):
            if path is not None:
                root, ext = os.path.splitext(path)
                extra += [flag, f"{root}.{tag}{ext}"]
        return extra

    if args.crash_only:
        crash = run_crash_gate(workdir, n_folds=n_folds,
                               rows_per_run=rows_per_run, smoke=args.smoke,
                               kill_after_block=kab, obs_extra=obs_extra)
        print(f"[wholebrain] crash-resume: killed after block "
              f"{crash['kill_after_block']}, resumed "
              f"{crash['resumed']['blocks_replayed']} replayed + "
              f"{crash['resumed']['blocks_streamed']} streamed of "
              f"{crash['n_blocks']}, {crash['w_shards_bitwise']} W shards "
              f"bit-identical; faulty run retried "
              f"{crash['faulty']['io_retries']}× with λ parity", flush=True)
        with open(args.out, "w") as f:
            json.dump({"smoke": args.smoke, "crash_resume": crash}, f,
                      indent=2)
            f.write("\n")
        print(f"# wrote {args.out}")
        return

    print(f"[wholebrain] materialising {n}x{_P}x{args.t} subject ...",
          flush=True)
    mat = _spawn("materialise", [
        "--store", store, "--n", str(n), "--t", str(args.t),
        "--n-folds", str(n_folds), "--rows-per-run", str(rows_per_run)]
        + obs_extra("materialise"))
    print(f"[wholebrain] materialise: {mat['wall_s']}s "
          f"rss={mat['peak_rss_mb']}MB store={mat['store_gb']}GB",
          flush=True)

    fits = []
    for i, t_block in enumerate(t_blocks):
        extra = ["--store", store, "--t-block", str(t_block),
                 "--n-folds", str(n_folds), "--chunk-rows", str(chunk_rows),
                 "--cap-mb", str(args.cap_mb)] \
            + obs_extra(f"fit{t_block}")
        if i == 0:
            extra += ["--bundle", bundle]
        fit = _spawn("fit", extra)
        fits.append(fit)
        print(f"[wholebrain] fit t_block={t_block}: {fit['wall_s']}s "
              f"rss={fit['peak_rss_mb']}MB (unblocked would need "
              f"{fit['unblocked_stats_mb']}MB) blocks={fit['n_blocks']} "
              f"staged={fit['bytes_staged_mb']}MB "
              f"compiles={fit['gram_compiles']}+{fit['colblock_compiles']} "
              f"λ={fit['best_lambda']}", flush=True)
    lams = {f["best_lambda"] for f in fits}
    if len(lams) != 1:
        raise SystemExit(f"λ selection diverged across t_block values: "
                         f"{lams}")

    # Fused-vs-unfused kernel-tier A/B at a downscaled t (interpret mode
    # on CPU is a correctness harness — full-scale fused would take
    # hours); λ parity is asserted in the child, roofline anchors it.
    ab_n, ab_t, ab_tb, ab_chunk = ((128, 512, 128, 64) if args.smoke
                                   else (512, 2048, 512, 128))
    ab_store = os.path.join(workdir, f"ab_subject_{ab_n}x{_P}x{ab_t}")
    ab = _spawn("ab", ["--store", ab_store, "--n", str(ab_n),
                       "--t", str(ab_t), "--t-block", str(ab_tb),
                       "--n-folds", str(n_folds),
                       "--chunk-rows", str(ab_chunk),
                       "--rows-per-run", str(rows_per_run)]
                + obs_extra("ab"))
    print(f"[wholebrain] fused A/B ({ab_n}x{_P}x{ab_t}, "
          f"{ab['kernel_tier']}): unfused {ab['unfused_s']}s vs fused "
          f"{ab['fused_s']}s, λ match, x passes={ab['row_passes_x']}",
          flush=True)

    crash = run_crash_gate(workdir, n_folds=n_folds,
                           rows_per_run=rows_per_run, smoke=args.smoke,
                           kill_after_block=kab, obs_extra=obs_extra)
    print(f"[wholebrain] crash-resume: killed after block "
          f"{crash['kill_after_block']}, resumed "
          f"{crash['resumed']['blocks_replayed']} replayed + "
          f"{crash['resumed']['blocks_streamed']} streamed of "
          f"{crash['n_blocks']}, {crash['w_shards_bitwise']} W shards "
          f"bit-identical; faulty run retried "
          f"{crash['faulty']['io_retries']}× with λ parity", flush=True)

    serve = _spawn("serve", ["--bundle", bundle,
                             "--cap-mb", str(args.cap_mb)]
                   + obs_extra("serve"))
    print(f"[wholebrain] serve: {serve['wall_s']}s "
          f"rss={serve['peak_rss_mb']}MB paged "
          f"{serve['shards_paged']}/{serve['weight_shards']} shards "
          f"({serve['resident_mb']}MB resident)", flush=True)

    payload = {"n": n, "p": _P, "t": args.t, "n_folds": n_folds,
               "chunk_rows": chunk_rows, "rss_cap_mb": args.cap_mb,
               "smoke": args.smoke, "materialise": mat,
               "fit_vs_t_block": fits, "fused_ab": ab,
               "crash_resume": crash, "serve": serve}
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
