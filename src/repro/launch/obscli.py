"""Shared ``--trace-out`` / ``--metrics-out`` wiring for launch drivers.

Every launcher takes the same two flags:

* ``--trace-out PATH`` — install the process-global ``repro.obs`` tracer
  for the run and write the span trace on exit: Chrome/Perfetto
  ``trace_event`` JSON when ``PATH`` ends in ``.json`` (loadable directly
  at https://ui.perfetto.dev), JSONL otherwise (the format
  ``launch/obs_report.py`` and ``benchmarks/parse_sweep_log.py`` read).
* ``--metrics-out PATH`` — start the background RSS gauge poller and
  write the ``MetricsRegistry`` snapshot JSON on exit.

``obs_session(args)`` is the one context manager a driver wraps its work
in; with neither flag given it is a no-op (the tracer stays uninstalled,
so the instrumented hot paths keep their disabled-cost contract).
"""
from __future__ import annotations

import argparse
import contextlib

from repro import obs


def add_obs_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--trace-out", default=None,
                    help="write a span trace here on exit (.json = "
                         "Perfetto trace_event, else JSONL for "
                         "launch/obs_report.py)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the obs metrics snapshot JSON here on "
                         "exit (counters/gauges/histograms incl. the "
                         "RSS high-water gauge)")


@contextlib.contextmanager
def obs_session(args):
    """Install tracing/metrics per the parsed flags; flush on exit.

    Yields the installed :class:`repro.obs.Tracer` (or ``None``).  The
    trace and snapshot are written even when the wrapped driver raises —
    a crashed run's partial trace is exactly when you want one.
    """
    tracer = obs.install() if args.trace_out else None
    poller = obs.start_rss_poller() if args.metrics_out else None
    try:
        yield tracer
    finally:
        if poller is not None:
            poller.stop()
        if tracer is not None:
            fmt = obs.write_trace(tracer, args.trace_out)
            obs.uninstall()
            print(f"trace written → {args.trace_out} ({fmt})")
        if args.metrics_out:
            obs.get_metrics().write_json(args.metrics_out)
            print(f"metrics snapshot → {args.metrics_out}")


__all__ = ["add_obs_args", "obs_session"]
