import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture × input shape × mesh)
combination lowers and compiles on the production mesh, with no allocation.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
      --shape train_4k [--multi-pod] [--rules tp] [--json out.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Outputs per combination: compiled memory analysis (bytes/device),
cost analysis (FLOPs, bytes), and collective-bytes parsed from the HLO —
the §Roofline inputs.
"""
import argparse
import json
import sys
import time


def _shrink_depth(cfg, k: int):
    """Config with k pattern repeats (for the unrolled cost probes)."""
    import dataclasses
    kw = {"n_layers": k * len(cfg.pattern)}
    if cfg.n_encoder_layers:
        kw["n_encoder_layers"] = k
    return dataclasses.replace(cfg, **kw)


def _compile(bundle, mesh):
    import jax
    with mesh:
        jitted = jax.jit(bundle.fn,
                         in_shardings=bundle.in_shardings,
                         out_shardings=bundle.out_shardings,
                         donate_argnums=bundle.donate_argnums)
        lowered = jitted.lower(*bundle.abstract_inputs)
        return lowered.compile()


def _costs(compiled) -> dict:
    from repro.launch import hlo_analysis
    cost = compiled.cost_analysis()
    return {
        "flops": cost.get("flops", 0.0),
        "hlo_bytes": cost.get("bytes accessed", 0.0),
        "collective_bytes": hlo_analysis.collective_bytes(compiled.as_text()),
    }


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            rules: str = "tp", verbose: bool = True,
            probes: bool = True) -> dict:
    from repro import configs
    from repro.launch import hlo_analysis, mesh as mesh_lib
    from repro.launch.steps import build_step
    from repro.models.config import INPUT_SHAPES

    cfg = configs.get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)

    # Main lower+compile: production settings (scan over layers, grad-accum
    # microbatching).  Proves the combination lowers and fits.
    t0 = time.time()
    bundle = build_step(cfg, mesh, shape, rules=rules)
    compiled = _compile(bundle, mesh)
    t_main = time.time() - t0
    mem = compiled.memory_analysis()
    scan_cost = _costs(compiled)

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "rules": rules,
        "n_devices": int(mesh.devices.size),
        "compile_s": round(t_main, 1),
        "memory": hlo_analysis.memory_dict(mem),
        # Raw scanned-program counters (scan bodies counted ONCE by XLA —
        # see models/scanning.py; use the probe-extrapolated numbers below
        # for the roofline).
        "scan_counted": scan_cost,
    }

    if probes:
        # Two tiny unrolled variants (1 and 2 pattern repeats, microbatch=1)
        # → per-repeat slope → true totals: f(R) = f1 + (R-1)·(f2-f1).
        t0 = time.time()
        probe = {}
        for k in (1, 2):
            cfg_k = _shrink_depth(cfg, k)
            kw = {"microbatch": 1} if shape.kind == "train" else {}
            b_k = build_step(cfg_k, mesh, shape, rules=rules, unroll=True,
                             **kw)
            probe[k] = _costs(_compile(b_k, mesh))
        R = cfg.n_layers // len(cfg.pattern)
        rec["probe_s"] = round(time.time() - t0, 1)
        rec["flops"] = probe[1]["flops"] + (R - 1) * (
            probe[2]["flops"] - probe[1]["flops"])
        rec["hlo_bytes"] = probe[1]["hlo_bytes"] + (R - 1) * (
            probe[2]["hlo_bytes"] - probe[1]["hlo_bytes"])
        rec["collective_bytes"] = {
            op: probe[1]["collective_bytes"][op] + (R - 1) * (
                probe[2]["collective_bytes"][op] -
                probe[1]["collective_bytes"][op])
            for op in probe[1]["collective_bytes"]}
    else:
        rec["flops"] = scan_cost["flops"]
        rec["hlo_bytes"] = scan_cost["hlo_bytes"]
        rec["collective_bytes"] = scan_cost["collective_bytes"]

    if verbose:
        print(f"== {arch} × {shape_name} × {rec['mesh']} (rules={rules}) ==")
        print("memory_analysis:", mem)
        print("cost_analysis (probe-extrapolated): "
              f"flops={rec['flops']:.3e} bytes={rec['hlo_bytes']:.3e}")
        print("collective_bytes:",
              {k: f"{v:.3e}" for k, v in rec["collective_bytes"].items()})
        sys.stdout.flush()
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--rules", default="tp")
    ap.add_argument("--no-probes", action="store_true",
                    help="skip the unrolled cost probes (memory-only run)")
    ap.add_argument("--json", default=None, help="append JSONL records here")
    args = ap.parse_args()

    from repro import configs
    from repro.models.config import INPUT_SHAPES

    if args.all:
        combos = [(a, s) for a in configs.ARCH_IDS for s in INPUT_SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]

    records, failures = [], []
    for arch, shape in combos:
        try:
            rec = run_one(arch, shape, multi_pod=args.multi_pod,
                          rules=args.rules, probes=not args.no_probes)
            records.append(rec)
            if args.json:  # append incrementally — crash-safe
                with open(args.json, "a") as f:
                    f.write(json.dumps(rec) + "\n")
        except Exception as e:  # noqa: BLE001 — report every combo
            failures.append((arch, shape, repr(e)))
            print(f"FAILED {arch} × {shape}: {e!r}", file=sys.stderr)
    print(f"\n{len(records)} passed, {len(failures)} failed")
    for a, s, e in failures:
        print(f"  FAIL {a} × {s}: {e}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
