"""``BrainEncoder`` — the scikit-learn-style facade over every ridge solver.

One estimator, one result type.  ``fit`` resolves the solver through
``encoding.dispatch`` (unless pinned), owns all mesh/sharding boilerplate via
``encoding.sharding.ShardingPlan``, and normalises the four historical result
types (``RidgeCVResult``, ``BMORResult``, ``BandedResult``, bare MOR weight
matrices) into a single ``EncodingReport``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import banded, bmor, foldstats, mor, ridge, scoring
from repro.encoding.config import EncoderConfig
from repro.encoding.dispatch import DispatchDecision, resolve
from repro.encoding.sharding import ShardingPlan

_SOLVER_LABELS = {
    "ridge": "RidgeCV", "mor": "MOR", "bmor": "B-MOR",
    "bmor_dual": "dual B-MOR", "banded": "banded RidgeCV",
}


@dataclasses.dataclass
class EncodingReport:
    """Unified fit result across all solvers.

    ``best_lambda`` always has one entry per target batch: shape ``(1,)`` for
    single-shard solvers, ``(target_shards,)`` for B-MOR (per-batch λ,
    Alg. 1 line 13), ``(t,)`` conceptually for MOR (not materialised — MOR
    selects per target inside the fused program; the array is empty there).
    """

    weights: jax.Array                 # (p, t)
    best_lambda: np.ndarray            # (n_batches,) — see docstring
    cv_scores: np.ndarray              # (n_batches, r) CV curve per batch
    lambdas: tuple[float, ...]         # the swept grid (banded: empty)
    decision: DispatchDecision
    band_lambdas: np.ndarray | None = None   # (n_bands,), banded solver only

    @property
    def solver_label(self) -> str:
        return _SOLVER_LABELS[self.decision.solver]

    # -- machine-readable provenance ----------------------------------------
    def to_dict(self) -> dict:
        """Everything but the weight matrix, JSON-serialisable.

        The weights belong in an encoder *bundle* (they can be GBs); the
        report dict is the run provenance that rides next to it — solver
        decision, selected λ, CV curve, swept grid, weight shape/dtype.
        """
        return {
            "decision": dataclasses.asdict(self.decision),
            "best_lambda": np.asarray(self.best_lambda).tolist(),
            "cv_scores": np.asarray(self.cv_scores).tolist(),
            "lambdas": list(self.lambdas),
            "band_lambdas": (None if self.band_lambdas is None
                             else np.asarray(self.band_lambdas).tolist()),
            # None for a provenance-only report rebuilt via from_json.
            "weights_shape": (None if self.weights is None
                              else list(np.shape(self.weights))),
            "weights_dtype": (None if self.weights is None
                              else str(jnp.asarray(self.weights).dtype)),
            "solver_label": self.solver_label,
        }

    def to_json(self) -> str:
        import json
        return json.dumps(self.to_dict(), indent=2) + "\n"

    @classmethod
    def from_dict(cls, d: dict) -> "EncodingReport":
        """Rebuild the provenance half of a report (``weights`` is ``None``
        — load the bundle for the matrix itself)."""
        band = d.get("band_lambdas")
        return cls(
            weights=None,
            best_lambda=np.asarray(d["best_lambda"], np.float64),
            cv_scores=np.asarray(d["cv_scores"], np.float64),
            lambdas=tuple(d["lambdas"]),
            decision=DispatchDecision(**d["decision"]),
            band_lambdas=None if band is None else np.asarray(band))

    @classmethod
    def from_json(cls, s: str) -> "EncodingReport":
        import json
        return cls.from_dict(json.loads(s))


@dataclasses.dataclass
class EvaluationReport:
    """Held-out evaluation in the paper's metrics (§4.1–4.2)."""

    pearson_r: np.ndarray              # (t,) per-target test correlation
    r2: np.ndarray                     # (t,)
    null_r: np.ndarray                 # (n_perms, t) shuffled-stimulus control
    mean_r: float
    null_abs_r: float

    @property
    def significant(self) -> bool:
        """Aligned encoding clears the null floor (paper §4.2 criterion)."""
        return self.mean_r > 5.0 * self.null_abs_r


class BrainEncoder:
    """Multi-target brain-encoding ridge with automatic solver dispatch.

    >>> enc = BrainEncoder()                      # solver="auto"
    >>> enc.fit(X_train, Y_train)
    >>> r = enc.score(X_test, Y_test)             # per-target Pearson r
    >>> enc.report_.decision.solver               # what dispatch picked

    Keyword overrides are ``EncoderConfig`` fields:

    >>> BrainEncoder(solver="bmor", target_shards=8, n_folds=3)
    >>> BrainEncoder(bands=(4096, 4096))          # banded → per-band λ

    Attributes set by ``fit``: ``report_`` (an ``EncodingReport``),
    ``weights_`` (alias of ``report_.weights``).
    """

    def __init__(self, config: EncoderConfig | None = None, **overrides: Any):
        base = config or EncoderConfig()
        self.config = (dataclasses.replace(base, **overrides)
                       if overrides else base)
        self.report_: EncodingReport | None = None
        # Set by pipeline.standardize/fit (or by load()): the fitted
        # per-column μ/σ transform, persisted with save() so serving can
        # replay it on raw features.
        self.standardizer_ = None
        # Set by the streamed fit paths: overlap telemetry of the chunk
        # pipeline (reader-stall vs compute-stall seconds, chunks, bytes
        # staged, accumulation compile count).  None for in-memory fits.
        self.stream_stats_: dict | None = None

    # -- sklearn-ish surface -------------------------------------------------
    def fit(self, X: jax.Array | None = None, Y: jax.Array | None = None,
            *, store=None, chunk_rows: int | None = None) -> "BrainEncoder":
        """Fit from in-memory arrays, or out-of-core from a ``RunStore``.

        ``fit(X, Y)`` is the classic in-memory path.  ``fit(store=run_store)``
        resolves dispatch on the store's ``(n, p, t)`` shape: when the
        resident-set estimate exceeds ``config.device_memory_budget`` the
        decision pins ``method="chunked"`` and the rows are STREAMED from
        the memory-mapped shards (sharded over the local devices when
        ``data_shards > 1``) — ``(n, p)`` is never materialised; otherwise
        the store is loaded once and routed through the ordinary solver
        dispatch, so small stores transparently get B-MOR/dual/banded
        semantics.
        """
        with obs.span("fit", mode="store" if store is not None
                      else "arrays"):
            if store is not None:
                if X is not None or Y is not None:
                    raise ValueError("pass either (X, Y) or store=, not both")
                self._check_store_folds(store)
                n, p, t = store.shape
                with obs.span("fit.dispatch", n=n, p=p, t=t):
                    decision = resolve(self.config, n, p, t,
                                       jax.device_count())
                if decision.method == "colblocked":
                    return self._fit_store_colblocked(store, decision,
                                                      chunk_rows)
                if decision.method == "chunked":
                    return self._fit_store_chunked(store, decision,
                                                   chunk_rows)
                X, Y = store.load()
                X, Y = jnp.asarray(X), jnp.asarray(Y)
            if X is None or Y is None:
                raise ValueError("fit() needs (X, Y) arrays or store=")
            n, p = X.shape
            t = Y.shape[1]
            with obs.span("fit.dispatch", n=n, p=p, t=t):
                decision = resolve(self.config, n, p, t, jax.device_count())
            fitter = getattr(self, f"_fit_{decision.solver}")
            with obs.span("fit.solve", solver=decision.solver):
                self.report_ = fitter(X, Y, decision)
            return self

    def fit_chunks(self, chunks, n_total: int | None = None,
                   chunk_rows: int | None = None) -> "BrainEncoder":
        """Out-of-core fit from ordered ``(X_chunk, Y_chunk)`` row batches.

        The chunks are streamed through a ``foldstats.FoldStatsAccumulator``
        — only the ``(k, p, p+t)`` sufficient statistics ever live on the
        device, so ``X`` may be arbitrarily taller than device memory — and
        the CV'd solve runs entirely on the accumulated statistics
        (``ridge.ridge_cv_from_stats``).  Every chunk goes through ONE
        fixed-shape compiled masked update (padded to the chunk size, fold
        membership as a mask), so the whole stream costs a single trace
        regardless of fold alignment.  Primal/eigh single-shard only:
        the streaming regime is tall-``n``, exactly where the Gram form
        (p×p) is the small object.  Chunks must arrive in global row order;
        the fold split matches ``fit`` on the concatenated rows.

        ``chunks`` may also be a ``repro.data.store.RunStore`` — it is
        streamed with ``config.chunk_rows`` (background-prefetched when
        ``config.prefetch``) and ``n_total`` is taken from its manifest.
        """
        self._check_chunkable()
        # A source that exposes PrefetchStats (a ChunkPrefetcher handed in
        # directly) contributes its overlap telemetry to stream_stats_.
        stream = chunks if hasattr(chunks, "stats") else None
        if hasattr(chunks, "iter_chunks"):            # RunStore duck-type
            self._check_store_folds(chunks)
            n_total = chunks.shape[0]
            chunk_rows = chunk_rows or self.config.chunk_rows
            chunks = stream = chunks.iter_chunks(
                chunk_rows, prefetch=self.config.prefetch,
                prefetch_depth=self.config.prefetch_depth)
        if n_total is None:
            raise ValueError("fit_chunks needs n_total for iterator sources")
        with obs.span("fit", mode="chunks"):
            compiles0 = foldstats.chunk_update_compile_count()
            with obs.span("fit.stats", n=n_total):
                stats = foldstats.compute_chunked(
                    chunks, n_total, self.config.n_folds,
                    chunk_rows=chunk_rows,
                    use_pallas=self.config.resolve_use_pallas())
            self._record_stream_stats([stream] if stream is not None else [],
                                      compiles0)
            return self._fit_from_stats(stats, n_total)

    def _check_store_folds(self, store) -> None:
        """The manifest's fold split is part of the store's data contract:
        every consumer must derive the identical k-fold assignment, so a
        config that disagrees with the manifest is an error, not a
        silently different CV."""
        k = getattr(store, "n_folds", None)
        if k is not None and k != self.config.n_folds:
            raise ValueError(
                f"store manifest records n_folds={k} but the encoder is "
                f"configured with n_folds={self.config.n_folds} — match "
                f"EncoderConfig.n_folds to the store (or re-create the "
                f"store with the intended split)")

    def _check_chunkable(self) -> None:
        if self.config.solver not in ("auto", "ridge"):
            raise ValueError(
                f"fit_chunks supports only the single-shard ridge solver; "
                f"solver={self.config.solver!r} is pinned — use fit() for "
                f"B-MOR/MOR/banded semantics")
        if self.config.method == "dual" or self.config.bands is not None:
            raise ValueError(
                "fit_chunks is primal/eigh only (streamed row statistics "
                "cannot build the dual kernel or per-band refits)")

    def _fit_from_stats(self, stats: foldstats.FoldStats, n_total: int,
                        decision: DispatchDecision | None = None
                        ) -> "BrainEncoder":
        """CV'd solve from accumulated fold statistics alone."""
        p, t = stats.G.shape[1], stats.C.shape[2]
        # Statistics-based CV scores lose f32 precision roughly
        # quadratically in |ȳ|/σ_y (see foldstats.validation_scores_from
        # _stats); refuse clearly pathological un-standardized targets
        # instead of returning silently corrupted scores.
        # The host pulls below block on the accumulation's async tail, so
        # under tracing this span is where the streamed compute drains.
        with obs.span("fit.finalize", n=n_total, t=t):
            mu = np.asarray(jnp.sum(stats.ysum, axis=0)) / n_total
            var = np.asarray(jnp.sum(stats.ysq, axis=0)) / max(n_total - 1, 1)
            ratio = float(np.max(np.abs(mu) / np.sqrt(var + 1e-12)))
        if ratio > 1e3:
            raise ValueError(
                f"fit_chunks: target mean/std ratio {ratio:.0f} is too "
                f"large for statistics-based CV scoring in float32 — "
                f"standardize the targets first (pipeline.standardize)")
        cfg = dataclasses.replace(self.config, solver="ridge", method="eigh")
        if decision is None:
            decision = resolve(cfg, n_total, p, t, jax.device_count())
        res = ridge.ridge_cv_from_stats(stats,
                                        cfg.ridge_cv_config("eigh"))
        self.report_ = EncodingReport(
            weights=res.weights,
            best_lambda=np.asarray(res.best_lambda)[None],
            cv_scores=np.asarray(res.cv_scores)[None, :],
            lambdas=self.config.lambdas, decision=decision)
        return self

    def _fit_store_chunked(self, store, decision: DispatchDecision,
                           chunk_rows: int | None) -> "BrainEncoder":
        """Streamed fit: shard the row windows over the local devices, each
        shard accumulating its own chunks; one psum combines the stacks.

        Each shard's stream is background-prefetched (``config.prefetch``;
        reader threads and staging buffers start lazily, so the sequential
        shard consumption only ever holds one prefetcher's buffers), and
        all shards share the one fixed-shape compiled update.  After the
        fit, ``stream_stats_`` records the overlap telemetry: reader-stall
        vs compute-stall seconds, chunks, bytes staged, and the trace-time
        compile count of the accumulation.
        """
        self._check_chunkable()
        n_total = store.shape[0]
        chunk_rows = chunk_rows or self.config.chunk_rows
        n_shards = max(1, min(decision.data_shards, jax.device_count(),
                              n_total))
        mesh = None
        if n_shards > 1:
            from repro.core.compat import make_mesh
            mesh = make_mesh((n_shards,), (self.config.data_axis,))
        streams = [
            store.iter_chunks(chunk_rows, row_range=(lo, hi),
                              prefetch=self.config.prefetch,
                              prefetch_depth=self.config.prefetch_depth)
            for lo, hi in foldstats.shard_row_ranges(n_total, n_shards)]
        compiles0 = foldstats.chunk_update_compile_count()
        with obs.span("fit.stats", n=n_total, shards=n_shards,
                      chunk_rows=chunk_rows):
            stats = foldstats.compute_sharded_chunked(
                streams, n_total, self.config.n_folds, mesh=mesh,
                data_axis=self.config.data_axis, chunk_rows=chunk_rows,
                use_pallas=decision.use_pallas)
        self._record_stream_stats(streams, compiles0)
        return self._fit_from_stats(stats, n_total, decision)

    def _fit_store_colblocked(self, store, decision: DispatchDecision,
                              chunk_rows: int | None) -> "BrainEncoder":
        """Target-axis streamed fit (``repro.wholebrain``): shared Gram
        pass + per-block ``(k, p, t_block)`` statistics, eigendecompositions
        reused across blocks.  λ and ``W`` are bit-identical to the
        unblocked statistics solve (global-λ mode).

        This transparent route still assembles the host ``(p, t)`` weight
        matrix for ``report_`` — at true whole-brain scale drive
        ``wholebrain.fit_wholebrain`` directly with a ``BundleWriter`` so
        the shards stream to disk instead (``launch/wholebrain.py``).
        """
        self._check_chunkable()
        from repro.wholebrain.solver import fit_wholebrain

        res = fit_wholebrain(store, self.config,
                             t_block=decision.target_block,
                             chunk_rows=chunk_rows)
        self.report_ = EncodingReport(
            weights=jnp.asarray(res.weights),
            best_lambda=res.best_lambda,
            cv_scores=res.cv_scores,
            lambdas=self.config.lambdas, decision=decision)
        self.stream_stats_ = {"schema": obs.SCHEMA_VERSION, "kind": "stream",
                              "prefetch": bool(self.config.prefetch),
                              **res.telemetry,
                              "compile_count":
                                  res.telemetry["colblock_compile_delta"]}
        return self

    def _record_stream_stats(self, streams, compiles_before: int) -> None:
        """Aggregate per-stream prefetch telemetry into ``stream_stats_``
        (the shared ``repro.obs`` snapshot schema: flat snake_case keys
        plus ``schema``/``kind`` markers)."""
        agg = {"schema": obs.SCHEMA_VERSION, "kind": "stream",
               "prefetch": bool(self.config.prefetch), "chunks": 0,
               "bytes_staged": 0, "read_stall_s": 0.0,
               "compute_stall_s": 0.0,
               "use_pallas": self.config.resolve_use_pallas(),
               "compile_count": (foldstats.chunk_update_compile_count()
                                 - compiles_before)}
        for stream in streams:
            s = getattr(stream, "stats", None)
            if s is None:
                continue
            d = s.to_dict()
            agg["chunks"] += d["chunks"]
            agg["bytes_staged"] += d["bytes_staged"]
            agg["read_stall_s"] += d["read_stall_s"]
            agg["compute_stall_s"] += d["compute_stall_s"]
        self.stream_stats_ = agg

    @property
    def weights_(self) -> jax.Array:
        assert self.report_ is not None, "call fit() first"
        return self.report_.weights

    # -- persistence (fit once, serve many) ----------------------------------
    def save(self, bundle_dir: str, *, overwrite: bool = False,
             weight_shards: int | None = None,
             weight_dtype: str | None = None,
             provenance: dict | None = None) -> str:
        """Persist the fitted encoder as an ``EncoderBundle`` directory.

        Everything needed to ``predict`` without refitting lands on disk:
        the weight matrix (column-sharded ``.npy`` leaves, bf16 stored as
        u16 bit patterns), the selected λ / CV provenance, the
        ``EncoderConfig``, the dispatch decision, and the fitted
        ``Standardizer`` when the pipeline attached one.  The write is
        atomic (tmp dir + rename).  Round-trip contract:
        ``BrainEncoder.load(d).predict(X)`` is bit-identical to
        ``self.predict(X)``.
        """
        from repro.serving_encoders import bundle as _bundle
        return _bundle.save_bundle(bundle_dir, self, overwrite=overwrite,
                                   weight_shards=weight_shards,
                                   weight_dtype=weight_dtype,
                                   provenance=provenance)

    @classmethod
    def load(cls, bundle_dir: str, *,
             target_shards: int | None = None) -> "BrainEncoder":
        """Rebuild a fitted encoder from a saved bundle (no refit).

        ``target_shards`` > 1 places the weight matrix column-sharded over
        a fresh ``(1, target_shards)`` mesh at load time (the serving
        layout); default is a single replicated device array.
        """
        from repro.serving_encoders import bundle as _bundle
        return _bundle.EncoderBundle.open(bundle_dir).load_encoder(
            target_shards=target_shards)

    def predict(self, X: jax.Array) -> jax.Array:
        return ridge.predict(X, self.weights_)

    def score(self, X: jax.Array, Y: jax.Array) -> np.ndarray:
        """Per-target Pearson r on held-out data (the paper's metric)."""
        return np.asarray(scoring.pearson_r(Y, self.predict(X)))

    def evaluate(self, X: jax.Array, Y: jax.Array, *, n_perms: int = 10,
                 key: jax.Array | None = None) -> EvaluationReport:
        """Pearson r + R² + the §4.2 null-permutation control."""
        preds = self.predict(X)
        r = np.asarray(scoring.pearson_r(Y, preds))
        r2 = np.asarray(scoring.r2_score(Y, preds))
        if key is None:
            key = jax.random.PRNGKey(self.config.seed + 1)
        null = np.asarray(scoring.null_permutation_scores(
            key, X, Y, self.weights_, n_perms=n_perms))
        return EvaluationReport(
            pearson_r=r, r2=r2, null_r=null, mean_r=float(r.mean()),
            null_abs_r=float(np.abs(null).mean()))

    # -- per-solver fit paths ------------------------------------------------
    def _fit_ridge(self, X, Y, decision: DispatchDecision) -> EncodingReport:
        res = ridge.ridge_cv(X, Y, self.config.ridge_cv_config(decision.method))
        return EncodingReport(
            weights=res.weights,
            best_lambda=np.asarray(res.best_lambda)[None],
            cv_scores=np.asarray(res.cv_scores)[None, :],
            lambdas=self.config.lambdas, decision=decision)

    def _fit_mor(self, X, Y, decision: DispatchDecision) -> EncodingReport:
        cfg = self.config.ridge_cv_config(decision.method)
        if self.config.mor_taskwise and decision.target_shards > 1:
            # Distributed MOR is one fused XLA program per shard, which hoists
            # the per-target refactorisation (see mor.mor_fit's NOTE) — the
            # opposite of what the taskwise flag exists to measure.
            raise ValueError("mor_taskwise=True is incompatible with "
                             "target_shards > 1: taskwise MOR is a host-level "
                             "per-target loop (paper Fig. 8 cost semantics)")
        if decision.target_shards > 1:
            plan = ShardingPlan(data_shards=1,
                                target_shards=decision.target_shards,
                                data_axis=self.config.data_axis,
                                target_axis=self.config.target_axis)
            X, Y, t = plan.prepare(X, Y)
            W = mor.mor_fit_distributed(X, Y, plan.build_mesh(),
                                        axis=plan.target_axis, cfg=cfg)
            W = W[:, :t]
        elif self.config.mor_taskwise:
            W = mor.mor_fit_taskwise(X, Y, cfg)
        else:
            W = mor.mor_fit(X, Y, cfg)
        return EncodingReport(
            weights=W,
            best_lambda=np.empty((0,)),          # per-target λ stays internal
            cv_scores=np.empty((0, len(self.config.lambdas))),
            lambdas=self.config.lambdas, decision=decision)

    def _fit_bmor(self, X, Y, decision: DispatchDecision) -> EncodingReport:
        plan = ShardingPlan(data_shards=decision.data_shards,
                            target_shards=decision.target_shards,
                            data_axis=self.config.data_axis,
                            target_axis=self.config.target_axis)
        X, Y, t = plan.prepare(X, Y)
        mesh = plan.build_mesh()
        Xs, Ys = plan.place(mesh, X, Y)
        res = bmor.bmor_fit(Xs, Ys, mesh, data_axis=plan.data_axis,
                            target_axis=plan.target_axis,
                            cfg=self.config.ridge_cv_config("eigh"))
        return EncodingReport(
            weights=res.weights[:, :t],
            best_lambda=np.asarray(res.best_lambda),
            cv_scores=np.asarray(res.cv_scores),
            lambdas=self.config.lambdas, decision=decision)

    def _fit_bmor_dual(self, X, Y, decision: DispatchDecision
                       ) -> EncodingReport:
        plan = ShardingPlan(data_shards=1,
                            target_shards=decision.target_shards,
                            data_axis=self.config.data_axis,
                            target_axis=self.config.target_axis,
                            replicate_rows=True)
        X, Y, t = plan.prepare(X, Y)
        mesh = plan.build_mesh()
        Xs, Ys = plan.place(mesh, X, Y)
        res = bmor.bmor_fit_dual(Xs, Ys, mesh, target_axis=plan.target_axis,
                                 cfg=self.config.ridge_cv_config("dual"))
        return EncodingReport(
            weights=res.weights[:, :t],
            best_lambda=np.asarray(res.best_lambda),
            cv_scores=np.asarray(res.cv_scores),
            lambdas=self.config.lambdas, decision=decision)

    def _fit_banded(self, X, Y, decision: DispatchDecision) -> EncodingReport:
        bands = self.config.bands
        if sum(bands) != X.shape[1]:
            raise ValueError(f"bands {bands} sum to {sum(bands)} but X has "
                             f"{X.shape[1]} features")
        res = banded.banded_ridge_cv(jax.random.PRNGKey(self.config.seed),
                                     X, Y, self.config.banded_config())
        return EncodingReport(
            weights=res.weights,
            best_lambda=np.empty((0,)),          # per-band, not per-grid-λ
            cv_scores=np.asarray(res.cv_scores)[None, :],
            lambdas=(), decision=decision,
            band_lambdas=np.asarray(res.band_lambdas))
