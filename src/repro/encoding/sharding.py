"""ShardingPlan — mesh construction and data placement, in one object.

Before this module, every distributed call site (``launch/encode.py``,
``examples/distributed_ridge.py``, ``examples/brain_encoding_e2e.py``,
``benchmarks/distributed_bench.py``) hand-rolled the same four steps: build a
``(data, model)`` mesh, round the row count to a multiple of the data-shard
count, ``device_put`` X over rows, ``device_put`` Y over rows × targets.
``ShardingPlan`` owns those steps — plus target-count padding, which the
hand-rolled versions silently could not handle (``shard_map`` needs the
target dimension divisible by the target-shard count).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.compat import make_mesh


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    """How a ``(n, p) × (n, t)`` ridge problem maps onto the device mesh.

    ``data_shards`` splits rows (time samples) — the Gram/psum axis of
    B-MOR's TPU adaptation; ``target_shards`` splits columns of Y — the
    paper's batch axis (c in Eq. 7).  ``replicate_rows=True`` is the dual
    regime, where the kernel is small and rows live on every shard.
    """

    data_shards: int = 1
    target_shards: int = 1
    data_axis: str = "data"
    target_axis: str = "model"
    replicate_rows: bool = False

    @property
    def device_count(self) -> int:
        return self.data_shards * self.target_shards

    def build_mesh(self) -> Mesh:
        assert self.device_count <= jax.device_count(), (
            f"plan wants {self.device_count} devices, "
            f"have {jax.device_count()}")
        return make_mesh((self.data_shards, self.target_shards),
                         (self.data_axis, self.target_axis))

    # -- shape rounding ------------------------------------------------------
    def round_rows(self, n: int) -> int:
        """Largest row count ≤ n divisible by the data-shard count."""
        if self.replicate_rows:
            return n
        return (n // self.data_shards) * self.data_shards

    def padded_targets(self, t: int) -> int:
        """Smallest target count ≥ t divisible by the target-shard count."""
        c = self.target_shards
        return ((t + c - 1) // c) * c

    def prepare(self, X: jax.Array, Y: jax.Array
                ) -> tuple[jax.Array, jax.Array, int]:
        """Round rows / zero-pad targets so shapes divide the mesh.

        Returns ``(X', Y', t_original)``; padded weight columns are sliced
        off again by the caller (see ``BrainEncoder.fit``).
        """
        t = Y.shape[1]
        keep = self.round_rows(X.shape[0])
        X, Y = X[:keep], Y[:keep]
        t_pad = self.padded_targets(t)
        if t_pad != t:
            Y = jnp.concatenate(
                [Y, jnp.zeros((Y.shape[0], t_pad - t), Y.dtype)], axis=1)
        return X, Y, t

    # -- placement -----------------------------------------------------------
    def x_spec(self) -> P:
        return P() if self.replicate_rows else P(self.data_axis, None)

    def y_spec(self) -> P:
        row = None if self.replicate_rows else self.data_axis
        return P(row, self.target_axis)

    def place(self, mesh: Mesh, X: jax.Array, Y: jax.Array
              ) -> tuple[jax.Array, jax.Array]:
        Xs = jax.device_put(X, NamedSharding(mesh, self.x_spec()))
        Ys = jax.device_put(Y, NamedSharding(mesh, self.y_spec()))
        return Xs, Ys
