"""repro.encoding — the unified brain-encoding estimator API.

This package is the front door to every ridge solver in the repo.  The
low-level solvers (``repro.core.ridge``/``mor``/``bmor``/``banded``) stay
available as the documented low-level layer, but call sites should not need
them: ``BrainEncoder`` picks the solver and mesh layout from the problem
shape using the paper's §3 analytic cost model (Eq. 6–7), and owns all
sharding boilerplate.

Quickstart::

    import jax
    from repro.encoding import BrainEncoder, pipeline
    from repro.data import fmri

    X, Y, mask = fmri.generate(jax.random.PRNGKey(0),
                               fmri.SubjectSpec(n=1200, p=128, t=512))
    state = pipeline.run(X, Y)            # detrend → split → fit → evaluate
    print(state.report.decision.solver)   # e.g. "ridge" (1 device) / "bmor"
    print(state.evaluation.mean_r, state.evaluation.significant)

Or, scikit-learn style, with explicit control::

    enc = BrainEncoder(solver="bmor", target_shards=8, n_folds=3)
    enc.fit(X_train, Y_train)
    r_per_target = enc.score(X_test, Y_test)      # Pearson r (paper §4.1)

Modules:
  config    — ``EncoderConfig``: one config subsuming ridge/banded/sharding
  dispatch  — complexity-driven solver + mesh-layout resolution
  sharding  — ``ShardingPlan``: mesh build, row rounding, device_put specs
  estimator — ``BrainEncoder`` / ``EncodingReport`` / ``EvaluationReport``
  pipeline  — composable detrend → split → standardize → fit → evaluate
"""
from repro.encoding import pipeline  # noqa: F401
from repro.encoding.config import EncoderConfig  # noqa: F401
from repro.encoding.dispatch import DispatchDecision, resolve  # noqa: F401
from repro.encoding.estimator import (  # noqa: F401
    BrainEncoder, EncodingReport, EvaluationReport,
)
from repro.encoding.sharding import ShardingPlan  # noqa: F401
