"""repro.encoding — the unified brain-encoding estimator API.

This package is the front door to every ridge solver in the repo.  The
low-level solvers (``repro.core.ridge``/``mor``/``bmor``/``banded``) stay
available as the documented low-level layer, but call sites should not need
them: ``BrainEncoder`` picks the solver and mesh layout from the problem
shape using the paper's §3 analytic cost model (Eq. 6–7), and owns all
sharding boilerplate.

Quickstart::

    import jax
    from repro.encoding import BrainEncoder, pipeline
    from repro.data import fmri

    X, Y, mask = fmri.generate(jax.random.PRNGKey(0),
                               fmri.SubjectSpec(n=1200, p=128, t=512))
    state = pipeline.run(X, Y)            # detrend → split → fit → evaluate
    print(state.report.decision.solver)   # e.g. "ridge" (1 device) / "bmor"
    print(state.evaluation.mean_r, state.evaluation.significant)

Or, scikit-learn style, with explicit control::

    enc = BrainEncoder(solver="bmor", target_shards=8, n_folds=3)
    enc.fit(X_train, Y_train)
    r_per_target = enc.score(X_test, Y_test)      # Pearson r (paper §4.1)

Streaming large subjects (out-of-core)
--------------------------------------
The paper's whole-brain subjects (Table 1: n≈60k TRs × t≈264k targets)
cannot be materialised.  Write each run ONCE into an on-disk
``repro.data.store.RunStore`` (memory-mapped ``.npy`` shards + manifest),
then stream it::

    from repro.data.store import RunStore
    from repro.data import fmri

    store = RunStore.create("subj01_store")
    store.materialize_synthetic(fmri.SubjectSpec(n=500_000), seed=0)
    store = RunStore.open("subj01_store")          # read-only memmaps

    # 1. Transparent: give fit() a budget and the store — dispatch pins
    #    the streamed fold-statistics path (method="chunked") whenever
    #    the resident estimate n·p + n·t_shard exceeds the budget, and
    #    shards the accumulation over the local devices (one psum of the
    #    stacked (k, p, p+t) partials at finalize).
    enc = BrainEncoder(device_memory_budget=2 * 2**30, chunk_rows=65536)
    enc.fit(store=store)                           # (n, p) never resident
    print(enc.report_.decision.rationale)

    # 2. Pipeline: two-pass streaming standardize (column μ/σ from one
    #    ColumnMoments pass) + fold-stats fit, no materialisation.
    state = pipeline.run_store(store, chunk_rows=65536)

    # 3. Explicit: BrainEncoder.fit_chunks accepts the store (or any
    #    ordered (X_chunk, Y_chunk) iterator) directly.
    enc = BrainEncoder(chunk_rows=65536).fit_chunks(store)

Evaluation needs rows that fit in memory — score against a separate held
-out store/array (``enc.evaluate(X_test, Y_test)``).  CV λ selection on
the streamed path is bit-identical to the in-memory fit (the chunk
-invariance harness in ``tests/test_oocore.py`` and the memory-capped CI
lane lock this down; ``BENCH_oocore.json`` tracks wall time / peak RSS).

The streaming tier is *overlapped*: by default (``EncoderConfig.prefetch``)
a background reader stages the NEXT chunk into a reusable host buffer —
bounded queue of ``prefetch_depth``, ``depth + 2`` staging buffers — while
the device accumulates the current one, so the disk→host→device→accumulate
pipeline runs at the speed of the slower side, not their sum.  Two
invariants make that free of semantic cost:

* **Prefetch is bit-identical.**  Staging is a straight copy; prefetch
  on/off select the same λ and produce the same weights bit for bit
  (``--no-prefetch`` on ``launch/encode.py`` is purely a wall-time A/B).
* **Fixed-shape masked updates compile ONCE.**  Every chunk — whatever
  its fold alignment, shard window, or ragged tail — is padded to
  ``chunk_rows`` and applied through one jitted masked einsum (fold
  membership is a per-row one-hot, pad rows an all-zero mask), so the
  accumulation's trace-time compile count is 1 instead of one per
  distinct fold-segment length.  ``foldstats.chunk_update_compile_count``
  exposes the counter; tests and the oocore bench gate on it.

After a streamed fit, ``enc.stream_stats_`` reports the overlap telemetry
(reader-stall vs compute-stall seconds, chunks, bytes staged, compiles).

Whole-brain target streaming
----------------------------
Row streaming bounds the ``n`` terms but still accumulates the full
``(k, p, t)`` fold statistics and solves all ``t`` targets at once — at
the paper's whole-brain ``t≈264k`` those target-axis arrays are what no
longer fit.  The third tier (``repro.wholebrain``) streams the TARGET
axis on top of the row tier: one shared pass accumulates the X-only
statistics (``G``, ``xsum``, ``count``), then each column block streams
its own ``(k, p, t_block)`` cross-moments through ONE fixed-shape
compiled update (ragged tail zero-padded to ``t_pad``), and the CV solve
reuses the per-fold eigendecompositions of the downdated Grams across
every block (the paper's Eq. 5 mutualisation, paid ``k+1`` times total,
not per block).  Peak memory is ``O(p² + p·t_block)`` — independent of
``t`` — and λ selection + weights stay BIT-identical to the unblocked
solve (``tests/test_wholebrain.py`` gates this across block widths,
f32 and bf16)::

    # Transparent: same budget knob — when even the row tier's t-axis
    # working set (k·p·(p+t) stats + (p, t) solve arrays) breaks the
    # budget, dispatch escalates to method="colblocked" and picks a
    # t_block that fits half the budget.  target_block= pins it.
    enc = BrainEncoder(device_memory_budget=2**30).fit(store=store)
    print(enc.report_.decision.method)          # "colblocked"

    # Explicit, with streaming artifact writes: weight shards land on
    # disk as blocks finish — W is NEVER resident all at once.
    from repro.wholebrain import BundleWriter, fit_wholebrain
    with BundleWriter("bundles/sub-01_wb", p=p, t=t) as w:
        res = fit_wholebrain(store, enc.config, t_block=16_384,
                             writer=w, collect=False)
        w.commit(config=enc.config, report=report,
                 lambda_by_target=res.lambda_by_target)

Serving reads the result lazily: ``EncoderBundle`` memory-maps weight
shards per column window (``load_weight_shard(i, mmap=True)``), and the
serving registry charges + pages in ONLY the shards a request window
touches, with LRU eviction at shard granularity.
``python -m repro.launch.wholebrain`` runs the whole loop on a
whole-brain-shaped synthetic subject under an RSS cap the unblocked
path cannot survive (``BENCH_wholebrain.json``).

The kernel tier (Pallas) is the default hot path
------------------------------------------------
The streamed masked chunk update — the inner loop of every tier above —
routes its heavy ``[G|C]`` contribution through the fused Pallas kernel
``kernels.gram.xty_folds_masked`` (one HBM pass: chunk in, per-fold
scatter out; the ``(k, m, p)`` masked intermediate never materialises).
``EncoderConfig.use_pallas`` is tri-state:

* ``None`` (default) — auto.  On where the backend compiles the kernels
  natively (TPU: they ARE the fast path), and on CPU only when
  ``REPRO_PALLAS_FORCE_INTERPRET=1`` is set — interpret mode runs the
  same code path as a correctness harness (the CI pallas lane), but is
  orders of magnitude slower than XLA, so plain CPU sessions stay on the
  einsum tier.
* ``True`` / ``False`` — pin it either way; explicit always wins.

``dispatch.resolve`` collapses the tri-state to a concrete
``DispatchDecision.use_pallas`` and names the choice in the rationale.
Both tiers present every chunk to the same fixed-shape jitted update
(``use_pallas`` is a static argument — each tier traces once), and λ
selection is bit-identical between them at f32
(``tests/test_fused_foldstats.py``; ``BENCH_foldstats.json`` carries the
fused-vs-unfused A/B with roofline placement)::

    enc = BrainEncoder()                      # auto: kernel tier on TPU
    enc = BrainEncoder(use_pallas=False)      # pin the einsum tier
    print(enc.report_.decision.use_pallas, enc.report_.decision.rationale)

Fit once, serve many
--------------------
A fitted encoder no longer dies with the process: ``save`` persists an
``EncoderBundle`` (sharded weights with bf16-as-u16 storage, the
pipeline's fitted μ/σ, selected λ per target, config + dispatch
provenance; atomic write, eagerly validated ``open``) and ``load``
rebuilds a predicting encoder bit-identically — no refit::

    enc = BrainEncoder().fit(X_train, Y_train)
    enc.save("bundles/sub-01_L12")
    enc2 = BrainEncoder.load("bundles/sub-01_L12")      # predicts ==
    enc_sh = BrainEncoder.load("bundles/sub-01_L12",    # serving layout:
                               target_shards=8)         # column-sharded W

Serving traffic against a fleet of bundles goes through
``repro.serving_encoders``: an ``EncoderRegistry`` lazy-loads bundles
under a ``device_memory_budget`` (LRU eviction), and an
``EncoderService`` micro-batches concurrent requests into fixed-shape
padded waves — one compiled ``standardize → X @ W → de-standardize``
program per wave shape, reused forever::

    from repro.serving_encoders import (EncoderRegistry, EncoderService,
                                        PredictRequest)
    reg = EncoderRegistry(device_memory_budget=512 * 2**20)
    reg.add("sub-01/L12", "bundles/sub-01_L12")
    service = EncoderService(reg, wave_rows=128)
    out = service.serve([PredictRequest("sub-01/L12", X_new,
                                        targets=Y_new)])   # + Pearson r

``python -m repro.launch.serve --encoders 3`` runs the whole loop
(materialise → fit → save → serve); ``BENCH_serving.json`` tracks
latency/throughput vs wave size.

The fleet tier scales that to N workers, one artifact dir, shared page
cache: each worker process runs a ``FleetRegistry`` (weight shards read
through read-only mmap, so co-located workers fault each shard from disk
once between them; per-process residency published into one file-locked
``residency.json``), the service packs scored AND unscored requests from
any tenants into the SAME mixed waves (per-row request one-hot → per-slot
Pearson sums, bit-identical to serving each request alone), and a
``FleetFrontend`` bounds admission in rows — overflow is a typed
``ServiceError`` rejection, never an OOM or a stall.  A bundle that
faults mid-serve (truncated shard, flipped manifest) degrades only its
own tenants: typed ``BundleError`` per affected request, eviction, and
the rest of the batch serves on.  ``python -m repro.launch.serve
--encoders 6 --workers 4`` drives the whole fleet;
``benchmarks/serving_bench.py --replay-trace`` gates p50/p99 and
bit-identity under the checked-in deterministic mixed-traffic trace.

Observing a fit and a fleet
---------------------------
Every tier above is permanently instrumented through ``repro.obs`` —
spans, metrics, and recompile sentinels — at zero cost until you opt in
(with no tracer installed a span site is one module attribute load).
Three switches:

* **Tracing**: install the process-global tracer around any code, or
  pass ``--trace-out PATH`` to ``launch/encode.py`` /
  ``launch/wholebrain.py`` / ``launch/serve.py`` (fleet parents and the
  wholebrain driver fan the flag out per worker/phase child)::

      from repro import obs
      tracer = obs.install()
      enc = BrainEncoder(device_memory_budget=1, chunk_rows=4096)
      enc.fit(store=store)            # fit.dispatch/stats/eigh/solve spans
      obs.write_trace(tracer, "fit.json")     # .json → open in Perfetto
      obs.uninstall()

  ``python -m repro.launch.obs_report fit.jsonl`` renders the per-phase
  time/bytes table and the root-coverage figure (the obs CI lane gates
  ≥95% of the fit root attributed to its phase children).
* **Metrics**: ``obs.snapshot()`` renders the process-global counters
  (``compiles{tier=...}``, ``bytes_staged``, ``waves``,
  ``tenant_rows{tenant=...}``, ``registry_hits``/``loads``/
  ``evictions``, fleet admission outcomes) plus the RSS high-water gauge
  into one schema'd dict (``repro.obs/v1``); ``--metrics-out PATH``
  writes it on launcher exit.  ``stream_stats_``,
  ``ServiceStats.to_dict()`` and ``PrefetchStats.to_dict()`` carry the
  same schema marker, and the ``BENCH_*.json`` rows embed them.
* **Sentinels**: under ``REPRO_OBS_STRICT=1`` every fixed-shape contract
  (the chunked fold update, the whole-brain column-block update, the
  serving wave programs) raises ``obs.RecompileError`` AT TRACE TIME if
  it retraces beyond its expectation window — the CI oocore, wholebrain,
  fleet, and obs lanes all run armed.

Surviving failures
------------------
A whole-brain fit is hours of streaming and a fleet runs unattended, so
the crash-safe tier (``repro.resilience``) assumes the process WILL die
and the disk WILL hiccup — and makes both survivable without changing a
single result bit:

* **Checkpoint/resume**: pass ``journal=`` to ``fit_wholebrain`` (or
  ``--journal`` to ``launch/wholebrain.py``) and every completed column
  block — plus the shared X-statistics pass — is committed to an
  atomic-rename ledger (payload → fsync → rename, ``ledger.json``
  rewritten last, torn ``*.tmp-*`` leftovers reaped on attach).  A
  killed fit re-attached to the same journal replays the committed
  blocks from disk (exact f32 stats, f64 score contributions added in
  block order) and streams only the remainder, so λ AND W come out
  BIT-identical to an uninterrupted run; a finished fit deletes its
  journal.  ``journal_signature`` pins the problem shape — a journal
  from a different fit raises ``JournalError`` instead of corrupting::

      from repro.wholebrain import fit_wholebrain
      res = fit_wholebrain(store, cfg, t_block=16_384,
                           journal="runs/sub-01.journal")
      res.telemetry["resumed"], res.telemetry["blocks_replayed"]

* **Transient-I/O retry**: ``RunStore.open(root, fault_policy=...)``
  arms every shard mmap, chunk read, and prefetcher stage with
  ``FaultPolicy`` retries — bounded attempts, exponential backoff with
  deterministic seeded jitter, optional per-op deadline, and a typed
  transient/permanent classifier (a permanent fault raises first time).
  The prefetcher's reader restarts its stream at the next unconsumed
  chunk, so a retried read is invisible downstream: λ, W, and the
  compile counts are unchanged (``tests/test_resilience.py`` injects
  mid-fit faults and gates exactly that).  ``EncoderRegistry`` takes the
  same ``fault_policy=`` for bundle/shard loads; exhausted retries
  surface as the usual typed ``StoreError``/``BundleError``.  Retries
  and give-ups are ``io_retries{op=...}`` / ``io_giveups{op=...}``
  counters with ``retry.backoff`` spans.

* **Fleet liveness**: every residency publish stamps a heartbeat lease
  (``ResidencyMap.heartbeat`` refreshes it between loads);
  ``expire_dead(ttl_s)`` reaps workers whose stamp went stale, so a
  SIGKILLed worker's claims vanish instead of pinning phantom residency
  forever.  ``holders(model, ttl_s=...)`` filters the stale rows on
  read.  A batch that dies with its worker is re-admitted by the
  frontend (``WorkerLost`` → pending restored in admission order,
  ``requests_replayed`` counted) and ``fleet.replay`` drains through the
  loss.  The map's file lock acquire is bounded too — a wedged peer
  yields a typed ``FleetError`` after ``lock_timeout_s``, never a hang.

* **Crashed-writer hygiene**: ``BundleWriter`` and store
  materialisation sweep stale staging leftovers (``.tmpbundle_*``,
  ``*.tmp-*``, …) past an age gate before writing
  (``resilience.reap_stale_staging``, ``staging_reaped`` counter).

All of it is driven by the seeded deterministic harness in
``repro.resilience.faultsim`` (fail the Nth read, truncate a payload,
kill after block N) — the CI ``faults`` lane runs the injection matrix,
a real ``--kill-after-block`` crash-resume smoke gating W shard bytes,
and a 2-worker drain with one worker SIGKILLed mid-trace.

Modules:
  config    — ``EncoderConfig``: one config subsuming ridge/banded/sharding
  dispatch  — complexity-driven solver + mesh-layout resolution
  sharding  — ``ShardingPlan``: mesh build, row rounding, device_put specs
  estimator — ``BrainEncoder`` / ``EncodingReport`` / ``EvaluationReport``
  pipeline  — composable detrend → split → standardize → fit → evaluate

(The target-axis tier itself lives in ``repro.wholebrain``: blocked
fold statistics, the mutualised column-blocked CV driver, and the
streaming ``BundleWriter``.)
"""
from repro.encoding import pipeline  # noqa: F401
from repro.encoding.config import EncoderConfig  # noqa: F401
from repro.encoding.dispatch import DispatchDecision, resolve  # noqa: F401
from repro.encoding.estimator import (  # noqa: F401
    BrainEncoder, EncodingReport, EvaluationReport,
)
from repro.encoding.sharding import ShardingPlan  # noqa: F401
