"""repro.encoding — the unified brain-encoding estimator API.

This package is the front door to every ridge solver in the repo.  The
low-level solvers (``repro.core.ridge``/``mor``/``bmor``/``banded``) stay
available as the documented low-level layer, but call sites should not need
them: ``BrainEncoder`` picks the solver and mesh layout from the problem
shape using the paper's §3 analytic cost model (Eq. 6–7), and owns all
sharding boilerplate.

Quickstart::

    import jax
    from repro.encoding import BrainEncoder, pipeline
    from repro.data import fmri

    X, Y, mask = fmri.generate(jax.random.PRNGKey(0),
                               fmri.SubjectSpec(n=1200, p=128, t=512))
    state = pipeline.run(X, Y)            # detrend → split → fit → evaluate
    print(state.report.decision.solver)   # e.g. "ridge" (1 device) / "bmor"
    print(state.evaluation.mean_r, state.evaluation.significant)

Or, scikit-learn style, with explicit control::

    enc = BrainEncoder(solver="bmor", target_shards=8, n_folds=3)
    enc.fit(X_train, Y_train)
    r_per_target = enc.score(X_test, Y_test)      # Pearson r (paper §4.1)

Streaming large subjects (out-of-core)
--------------------------------------
The paper's whole-brain subjects (Table 1: n≈60k TRs × t≈264k targets)
cannot be materialised.  Write each run ONCE into an on-disk
``repro.data.store.RunStore`` (memory-mapped ``.npy`` shards + manifest),
then stream it::

    from repro.data.store import RunStore
    from repro.data import fmri

    store = RunStore.create("subj01_store")
    store.materialize_synthetic(fmri.SubjectSpec(n=500_000), seed=0)
    store = RunStore.open("subj01_store")          # read-only memmaps

    # 1. Transparent: give fit() a budget and the store — dispatch pins
    #    the streamed fold-statistics path (method="chunked") whenever
    #    the resident estimate n·p + n·t_shard exceeds the budget, and
    #    shards the accumulation over the local devices (one psum of the
    #    stacked (k, p, p+t) partials at finalize).
    enc = BrainEncoder(device_memory_budget=2 * 2**30, chunk_rows=65536)
    enc.fit(store=store)                           # (n, p) never resident
    print(enc.report_.decision.rationale)

    # 2. Pipeline: two-pass streaming standardize (column μ/σ from one
    #    ColumnMoments pass) + fold-stats fit, no materialisation.
    state = pipeline.run_store(store, chunk_rows=65536)

    # 3. Explicit: BrainEncoder.fit_chunks accepts the store (or any
    #    ordered (X_chunk, Y_chunk) iterator) directly.
    enc = BrainEncoder(chunk_rows=65536).fit_chunks(store)

Evaluation needs rows that fit in memory — score against a separate held
-out store/array (``enc.evaluate(X_test, Y_test)``).  CV λ selection on
the streamed path is bit-identical to the in-memory fit (the chunk
-invariance harness in ``tests/test_oocore.py`` and the memory-capped CI
lane lock this down; ``BENCH_oocore.json`` tracks wall time / peak RSS).

Modules:
  config    — ``EncoderConfig``: one config subsuming ridge/banded/sharding
  dispatch  — complexity-driven solver + mesh-layout resolution
  sharding  — ``ShardingPlan``: mesh build, row rounding, device_put specs
  estimator — ``BrainEncoder`` / ``EncodingReport`` / ``EvaluationReport``
  pipeline  — composable detrend → split → standardize → fit → evaluate
"""
from repro.encoding import pipeline  # noqa: F401
from repro.encoding.config import EncoderConfig  # noqa: F401
from repro.encoding.dispatch import DispatchDecision, resolve  # noqa: F401
from repro.encoding.estimator import (  # noqa: F401
    BrainEncoder, EncodingReport, EvaluationReport,
)
from repro.encoding.sharding import ShardingPlan  # noqa: F401
