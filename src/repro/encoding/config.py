"""One configuration object for the whole encoding stack.

``EncoderConfig`` subsumes the per-solver configs that used to live at every
call site (``ridge.RidgeCVConfig``, ``banded.BandedConfig``) plus the solver
and sharding choices that previously required hand-written mesh boilerplate.
It is frozen/hashable so it can ride through ``jax.jit`` static arguments.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

from repro.core.banded import BandedConfig
from repro.core.ridge import PAPER_LAMBDA_GRID, RidgeCVConfig

# Solver identifiers, in the paper's vocabulary:
#   ridge     — single-shard SVD/eigh-mutualised RidgeCV (§2.3.1)
#   mor       — MultiOutput ridge baseline, per-target recompute (§2.3.4)
#   bmor      — Batch Multi-Output ridge, targets batched over shards (Alg. 1)
#   bmor_dual — B-MOR on the kernel (n < p regime; rows replicated)
#   banded    — per-feature-space λ (la Tour et al. 2022, paper ref [13])
Solver = Literal["auto", "ridge", "mor", "bmor", "bmor_dual", "banded"]


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Everything a ``BrainEncoder`` needs, in one place.

    ``solver="auto"`` (the default) lets ``encoding.dispatch`` pick the
    implementation from the problem shape and device count using the §3
    analytic cost model; every field below can still be pinned explicitly.
    """

    # --- ridge CV (paper §2.2.4) ------------------------------------------
    lambdas: tuple[float, ...] = PAPER_LAMBDA_GRID
    n_folds: int = 5
    jitter: float = 1e-6
    scoring: Literal["r", "r2"] = "r2"
    # Kernel tier (Pallas fused statistics/solve kernels).  Tri-state:
    # None (default) = auto — on where the backend compiles them natively
    # (TPU), and on CPU only when REPRO_PALLAS_FORCE_INTERPRET is set (the
    # CI pallas lane: interpret mode exercises the same code path but is a
    # correctness harness, not a fast path).  True/False pin it.
    use_pallas: bool | None = None

    # --- solver selection --------------------------------------------------
    solver: Solver = "auto"
    # Factorisation side for the ridge path ("auto" → primal iff n >= p).
    method: Literal["auto", "eigh", "dual"] = "auto"
    # MOR only: pay the per-target dispatch cost for real (paper Fig. 8
    # semantics) instead of one fused XLA program.
    mor_taskwise: bool = False

    # --- banded ridge (set ``bands`` to enable) ----------------------------
    bands: tuple[int, ...] | None = None
    n_band_candidates: int = 16
    band_log_lambda_range: tuple[float, float] = (-2.0, 4.0)

    # --- sharding (None → chosen by dispatch from jax.device_count()) ------
    data_shards: int | None = None
    target_shards: int | None = None
    data_axis: str = "data"
    target_axis: str = "model"

    # --- out-of-core streaming (paper Table 1 whole-brain regime) ----------
    # Device-memory budget in BYTES for the resident working set
    # n·p + n·t_shard (f32).  When the estimate exceeds it, dispatch pins
    # the streamed fold-statistics path (method="chunked") and
    # ``BrainEncoder.fit(store=...)`` never materialises (n, p).  None →
    # unlimited (always materialise).
    device_memory_budget: int | None = None
    # Row-batch size of the streaming accumulation (per shard).
    chunk_rows: int = 8192
    # Overlapped streaming: a background reader stages the NEXT chunk into
    # a reusable host buffer while the device accumulates the current one
    # (RunStore.iter_chunks(prefetch=True)).  Results are bit-identical to
    # the non-prefetched stream — both present every chunk to the same
    # fixed-shape compiled update — so this is purely a wall-time knob;
    # turn it off to A/B the overlap (launch/encode.py --no-prefetch).
    prefetch: bool = True
    # Bounded hand-over queue depth; the reader owns depth + 2 staging
    # buffers of chunk_rows rows each.
    prefetch_depth: int = 2
    # Target-axis streaming (repro.wholebrain): column-block width of the
    # blocked CV fit.  None → chosen by dispatch from the memory budget
    # when even the chunked path's (k, p, p+t) statistics cannot fit
    # (method="colblocked"); set explicitly to pin the block width.
    target_block: int | None = None

    # --- determinism -------------------------------------------------------
    seed: int = 0

    def resolve_use_pallas(self) -> bool:
        """The kernel-tier decision as a concrete bool.

        ``None`` resolves through ``kernels.ops.kernel_tier_auto()`` (TPU →
        on; CPU → on only under ``REPRO_PALLAS_FORCE_INTERPRET``); an
        explicit ``True``/``False`` always wins.
        """
        if self.use_pallas is not None:
            return self.use_pallas
        from repro.kernels import ops
        return ops.kernel_tier_auto()

    def ridge_cv_config(self, method: str | None = None) -> RidgeCVConfig:
        """Project onto the low-level ``RidgeCVConfig``."""
        return RidgeCVConfig(
            lambdas=self.lambdas, n_folds=self.n_folds,
            method=method or self.method, jitter=self.jitter,
            scoring=self.scoring, use_pallas=self.resolve_use_pallas())

    def banded_config(self) -> BandedConfig:
        """Project onto the low-level ``BandedConfig`` (requires ``bands``)."""
        if self.bands is None:
            raise ValueError("EncoderConfig.bands must be set for the banded "
                             "solver (one feature count per band)")
        return BandedConfig(
            bands=self.bands, n_candidates=self.n_band_candidates,
            log_lambda_range=self.band_log_lambda_range,
            n_folds=self.n_folds, jitter=self.jitter)
