"""Complexity-driven solver dispatch (paper §3, Eq. 6–7).

The paper's core finding is that the right ridge parallelisation depends on
the problem shape: MOR's per-target refactorisation (Eq. 6, ``c⁻¹(T_W +
t·T_M)``) is impractical at scale, while B-MOR (Eq. 7, ``c⁻¹·T_W + T_M``)
scales to 33×.  This module turns that analysis into code: given ``(n, p, t,
device_count)`` and an ``EncoderConfig``, ``resolve`` picks

* the solver — single-shard mutualised ridge, B-MOR, dual B-MOR, or banded —
* the factorisation side (primal eigh when n ≥ p, dual kernel otherwise),
* and the mesh layout ``(data_shards, target_shards)`` minimising the
  analytic critical-path cost ``T_W/c_t + T_M/c_d``.

MOR is never auto-selected (that is the paper's point); it stays available
as an explicit override for baselines and benchmarks.
"""
from __future__ import annotations

import dataclasses

from repro.core import complexity
from repro.core.complexity import RidgeWorkload
from repro.encoding.config import EncoderConfig


@dataclasses.dataclass(frozen=True)
class DispatchDecision:
    """The resolved execution plan, with the model cost that justified it."""

    solver: str              # "ridge" | "mor" | "bmor" | "bmor_dual" | "banded"
    # Factorisation side "eigh" | "dual", or one of the streaming tiers:
    # "chunked" — out-of-core row streaming (fold statistics accumulated
    # chunk-wise; the regime is tall-n, where (k, p, p+t) is the small
    # object) — or "colblocked" — row AND target streaming
    # (repro.wholebrain; the regime is tall-n × wide-t, where even the
    # (k, p, t) statistics break the budget).
    method: str
    data_shards: int
    target_shards: int
    predicted_cost: float    # §3 fp-mult count on the critical path
    rationale: str
    # Column-block width of the "colblocked" tier; None for every other
    # method (kept defaulted so decisions serialized before this field
    # existed still round-trip through DispatchDecision(**d)).
    target_block: int | None = None
    # Kernel-tier resolution of EncoderConfig.use_pallas (tri-state None =
    # auto → this concrete bool).  Defaulted for the same serialized
    # round-trip reason as target_block.
    use_pallas: bool = False

    @property
    def device_count(self) -> int:
        return self.data_shards * self.target_shards


def _divisor_layouts(c: int) -> list[tuple[int, int]]:
    """All (data_shards, target_shards) with data·target == c."""
    return [(d, c // d) for d in range(1, c + 1) if c % d == 0]


def _best_bmor_layout(w: RidgeWorkload, device_count: int,
                      data_shards: int | None, target_shards: int | None
                      ) -> tuple[int, int, float]:
    """Minimise T_W/c_t + T_M/c_d over divisor splits of the device count.

    Pinned shard counts are honoured directly (a mesh may occupy a device
    subset — benchmark sweeps pin c=1,2,4 on an 8-device host); with one
    side pinned the other takes the remaining devices; with neither pinned
    the search covers divisor pairs of the full device count, ties
    preferring more target shards (the paper's batch axis — per-batch λ,
    Alg. 1 line 13).
    """
    if data_shards is not None and target_shards is not None:
        if data_shards * target_shards > device_count:
            raise ValueError(
                f"pinned layout {data_shards}x{target_shards} needs more "
                f"than the {device_count} available devices")
        return (data_shards, target_shards,
                complexity.t_bmor_sharded(w, data_shards, target_shards))
    if data_shards is not None or target_shards is not None:
        pinned = data_shards if data_shards is not None else target_shards
        if not 1 <= pinned <= device_count:
            raise ValueError(f"pinned shard count {pinned} exceeds the "
                             f"{device_count} available devices")
        other = device_count // pinned
        c_d, c_t = ((pinned, other) if data_shards is not None
                    else (other, pinned))
        return c_d, c_t, complexity.t_bmor_sharded(w, c_d, c_t)
    best_key: tuple[float, int] | None = None
    best_layout: tuple[int, int, float] | None = None
    for c_d, c_t in _divisor_layouts(device_count):
        if c_d > max(w.n, 1):
            continue
        cost = complexity.t_bmor_sharded(w, c_d, c_t)
        key = (cost, -c_t)
        if best_key is None or key < best_key:
            best_key, best_layout = key, (c_d, c_t, cost)
    assert best_layout is not None
    return best_layout


def estimated_resident_bytes(n: int, p: int, t: int,
                             target_shards: int = 1,
                             itemsize: int = 4) -> int:
    """Per-device resident working set of a materialised fit: the row block
    ``n·p`` plus this device's target slice ``n·t_shard`` (f32 by default).

    This is the quantity the paper's Table 1 makes hopeless for the
    whole-brain subject (n≈60k × t≈264k → hundreds of GB): the term
    dispatch compares against ``EncoderConfig.device_memory_budget``.
    """
    t_shard = -(-t // max(target_shards, 1))
    return n * (p + t_shard) * itemsize


def mixed_wave_scoring_bytes(wave_rows: int, t: int, score_slots: int,
                             itemsize: int = 4) -> int:
    """Extra resident bytes the MIXED serving wave pins beyond the plain
    predict's activation set: the padded target block (``wave_rows·t``),
    the per-row request one-hot (``wave_rows·score_slots``), and the
    in/out per-slot Pearson-sum carries (``2·score_slots·5·t``).

    This is the fleet tier's half of the residency account: the serving
    registry charges it next to ``estimated_resident_bytes`` so a budget
    bounds the waves actually flown — scored and unscored alike — not
    just the weight matrices.
    """
    if score_slots <= 0:
        return 0
    return (wave_rows * t + wave_rows * score_slots
            + 2 * 5 * score_slots * t) * itemsize


def _chunked_decision(cfg: EncoderConfig, w: RidgeWorkload, resident: int,
                      device_count: int) -> DispatchDecision:
    """Pin the streamed fold-statistics path (out-of-core regime)."""
    c_d = cfg.data_shards or device_count
    cost = (complexity.t_w(w) +
            complexity.t_m(w) + complexity.t_w_folded(w) / max(c_d, 1))
    overlap = (f"double-buffered chunk prefetch (depth "
               f"{cfg.prefetch_depth})" if cfg.prefetch
               else "prefetch off (serial read→accumulate)")
    return DispatchDecision(
        solver="ridge", method="chunked", data_shards=c_d, target_shards=1,
        predicted_cost=cost,
        rationale=f"resident set n·p + n·t_shard = {resident / 2**20:.1f} MB "
                  f"exceeds device_memory_budget = "
                  f"{cfg.device_memory_budget / 2**20:.1f} MB → streamed "
                  f"fold-statistics accumulation over {c_d} row shard(s), "
                  f"chunk_rows={cfg.chunk_rows}, {overlap} (only the "
                  f"(k, p, p+t) sufficient statistics and the staging "
                  f"buffers stay resident)")


def chunked_stats_bytes(n_folds: int, p: int, t: int,
                        itemsize: int = 4) -> int:
    """Resident footprint of the row-streamed tier's accumulated fold
    statistics: ``G (k, p, p) + C (k, p, t)`` (the ``ysum``/``ysq``
    vectors are noise next to these).  THIS is what breaks at whole-brain
    ``t`` even though row streaming already bounded the ``n`` terms."""
    return n_folds * p * (p + t) * itemsize


def pick_target_block(budget: int, n_folds: int, p: int, t: int,
                      itemsize: int = 4) -> int:
    """Largest column-block width whose blocked statistics
    ``k·p·(p + t_block)`` fit in HALF the budget (the other half covers
    staging buffers, the hoisted eigenbases, and solve temporaries),
    clamped to ``[2, t]`` — width 1 would break the tier's bitwise
    column-slice contract (see ``wholebrain.stats.column_blocks``)."""
    per_col = n_folds * p * itemsize
    spare = budget // 2 - n_folds * p * p * itemsize
    return max(2, min(t, spare // max(per_col, 1)))


def _colblocked_decision(cfg: EncoderConfig, w: RidgeWorkload, resident: int,
                         t_axis_bytes: int, t: int) -> DispatchDecision:
    """Pin the target-axis streaming tier (whole-brain regime)."""
    t_block = cfg.target_block or pick_target_block(
        cfg.device_memory_budget, cfg.n_folds, w.p, t)
    n_blocks = -(-t // t_block)
    # Same FLOPs as the chunked tier — the Gram is still accumulated once
    # and the C einsum totals n·p·t across blocks; the per-block cost is
    # the re-streamed X I/O, which the FLOP model does not price.
    cost = (complexity.t_w(w) +
            complexity.t_m(w) + complexity.t_w_folded(w))
    return DispatchDecision(
        solver="ridge", method="colblocked", data_shards=1, target_shards=1,
        predicted_cost=cost, target_block=t_block,
        rationale=f"the target-axis working set (k·p·(p+t) fold statistics "
                  f"+ (p, t) solve arrays) = {t_axis_bytes / 2**20:.1f} MB "
                  f"breaks device_memory_budget = "
                  f"{cfg.device_memory_budget / 2**20:.1f} MB regardless of "
                  f"row streaming → column-blocked target streaming: "
                  f"{n_blocks} block(s) of t_block={t_block} targets, "
                  f"shared Gram pass + per-block (k, p, t_block) "
                  f"statistics, eigendecompositions mutualised across "
                  f"blocks (resident set O(p² + p·t_block), independent "
                  f"of t={t})")


def _kernel_tier(cfg: EncoderConfig) -> tuple[bool, str]:
    """Resolve the kernel tier to a concrete bool plus a rationale clause."""
    import jax

    up = cfg.resolve_use_pallas()
    if up:
        if cfg.use_pallas is True:
            why = "pinned on by config"
        elif jax.default_backend() == "tpu":
            why = "auto: TPU backend compiles the kernels to Mosaic"
        else:
            why = ("auto: REPRO_PALLAS_FORCE_INTERPRET set — interpret "
                   "mode on this backend (same code path, correctness "
                   "harness not a fast path)")
        return True, (f"kernel tier: pallas ON ({why}; fused "
                      f"xty_folds_masked chunk updates)")
    if cfg.use_pallas is False:
        why = "pinned off by config"
    else:
        why = (f"auto: backend {jax.default_backend()!r} would interpret "
               f"the kernels (set REPRO_PALLAS_FORCE_INTERPRET=1 to opt in)")
    return False, f"kernel tier: pallas OFF ({why}; XLA einsum updates)"


def resolve(cfg: EncoderConfig, n: int, p: int, t: int,
            device_count: int) -> DispatchDecision:
    """Resolve ``cfg.solver`` ("auto" or explicit) into a concrete plan.

    Every decision also carries the kernel-tier resolution
    (``use_pallas``): the tri-state ``EncoderConfig.use_pallas`` collapsed
    to a concrete bool, named in the rationale string.
    """
    decision = _resolve_plan(cfg, n, p, t, device_count)
    up, tier = _kernel_tier(cfg)
    return dataclasses.replace(decision, use_pallas=up,
                               rationale=f"{decision.rationale}; {tier}")


def _resolve_plan(cfg: EncoderConfig, n: int, p: int, t: int,
                  device_count: int) -> DispatchDecision:
    valid = ("auto", "ridge", "mor", "bmor", "bmor_dual", "banded")
    if cfg.solver not in valid:
        raise ValueError(f"unknown solver {cfg.solver!r}; expected one of "
                         f"{valid}")
    for name, pinned in (("data_shards", cfg.data_shards),
                         ("target_shards", cfg.target_shards)):
        if pinned is not None and not 1 <= pinned <= device_count:
            raise ValueError(f"{name}={pinned} is outside the valid range "
                             f"[1, {device_count}] (available devices)")
    w = RidgeWorkload(n=n, p=p, t=t, r=len(cfg.lambdas), n_folds=cfg.n_folds)
    method = cfg.method if cfg.method != "auto" else (
        "eigh" if n >= p else "dual")
    solver = cfg.solver

    # Memory-budgeted dispatch: when the materialised working set cannot
    # fit, the ONLY viable plan is the streamed accumulation — it overrides
    # the FLOP-model choice below (which assumes the rows are resident).
    if cfg.device_memory_budget is not None and solver in ("auto", "ridge"):
        # Conservative estimate: unless the caller PINNED a target-shard
        # count, assume t_shard = t — the ridge path this guard protects
        # is single-shard, so dividing by device_count here would
        # under-estimate by device_count× and let fit(store=...)
        # materialise exactly the arrays the budget was set to prevent.
        resident = estimated_resident_bytes(n, p, t, cfg.target_shards or 1)
        stats_bytes = chunked_stats_bytes(cfg.n_folds, p, t)
        # Any fit — in-memory or row-streamed — holds the (k, p, t) fold
        # statistics plus the (p, t)-sized solve arrays (W, the projected
        # cross-moments, per-target scores).  At whole-brain t these
        # t-axis terms break the budget even when the (possibly
        # downscaled) rows fit, and only column blocking removes them.
        t_axis_bytes = stats_bytes + 3 * p * t * 4
        # Blocking only helps when the blocked statistics can actually fit
        # the half-budget pick_target_block reserves for them; under an
        # absurdly small budget nothing fits and the sharded row-streamed
        # tier stays the best-effort plan.
        colblock_viable = (chunked_stats_bytes(cfg.n_folds, p, 2)
                           <= cfg.device_memory_budget // 2)
        streamable = cfg.method != "dual" and cfg.bands is None
        if resident > cfg.device_memory_budget:
            if not streamable:
                raise ValueError(
                    f"resident set {resident} B exceeds device_memory_budget="
                    f"{cfg.device_memory_budget} B but the pinned "
                    f"method/bands ({cfg.method!r}/{cfg.bands}) cannot "
                    f"stream — the streaming paths are primal/eigh only")
            # Second-tier escalation: row streaming bounds the n terms but
            # still accumulates (k, p, t) statistics — at whole-brain t
            # those alone break the budget and the target axis must be
            # blocked too.  An explicit target_block also opts in.
            if cfg.target_block is not None or (
                    t_axis_bytes > cfg.device_memory_budget
                    and colblock_viable):
                return _colblocked_decision(cfg, w, resident, t_axis_bytes, t)
            return _chunked_decision(cfg, w, resident, device_count)
        if streamable and (cfg.target_block is not None or (
                t_axis_bytes > cfg.device_memory_budget and colblock_viable)):
            return _colblocked_decision(cfg, w, resident, t_axis_bytes, t)

    if solver == "auto":
        if cfg.bands is not None:
            solver = "banded"
        elif device_count <= 1:
            solver = "ridge"
        elif n < p:
            solver = "bmor_dual"
        else:
            solver = "bmor"

    if solver == "banded":
        if cfg.bands is None:
            raise ValueError("banded solver requires EncoderConfig.bands")
        return DispatchDecision(
            solver="banded", method="eigh", data_shards=1, target_shards=1,
            predicted_cost=cfg.n_band_candidates * complexity.t_m(w),
            rationale=f"{len(cfg.bands)} feature bands → per-band λ "
                      f"(Tikhonov substitution), one T_M per candidate")

    if solver == "ridge":
        # The CV Gram statistics are single-pass (t_w_folded = np², not the
        # per-fold k·np² of the seed path) — foldstats downdating keeps the
        # k-fold redundancy off the critical path.
        cost = (complexity.t_w(w) +
                (complexity.t_m(w) + complexity.t_w_folded(w)
                 if method == "eigh"
                 else complexity.t_m_dual(w) + complexity.t_w_folded_dual(w)))
        return DispatchDecision(
            solver="ridge", method=method, data_shards=1, target_shards=1,
            predicted_cost=cost,
            rationale=f"single shard, {method} factorisation mutualised "
                      f"across t={t} targets and r={w.r} λ (T_M + T_W); "
                      f"single-pass fold stats save "
                      f"{complexity.fold_redundancy_factor(w):.0f}× on the "
                      f"np² Gram term")

    if solver == "mor":
        c_t = cfg.target_shards or 1
        cost = complexity.t_mor(w, c_t)
        return DispatchDecision(
            solver="mor", method=method, data_shards=1, target_shards=c_t,
            predicted_cost=cost,
            rationale=f"explicit MOR baseline: t·T_M recompute, Eq. 6 — "
                      f"{complexity.mor_overhead_factor(w, max(c_t, 1)):.0f}×"
                      f" the B-MOR work at c={c_t} (never auto-selected)")

    if solver == "bmor_dual":
        c_t = cfg.target_shards or device_count
        if cfg.data_shards not in (None, 1):
            raise ValueError("bmor_dual replicates rows; data_shards must "
                             "be 1 (the n×n kernel is small when n < p)")
        cost = (complexity.t_w(w) / c_t + complexity.t_m_dual(w) +
                complexity.t_w_folded_dual(w))
        return DispatchDecision(
            solver="bmor_dual", method="dual", data_shards=1,
            target_shards=c_t, predicted_cost=cost,
            rationale=f"n={n} < p={p}: kernel (n×n) factorisation replicated,"
                      f" targets batched over c={c_t} shards (Eq. 7 dual)")

    assert solver == "bmor", solver
    c_d, c_t, cost = _best_bmor_layout(w, device_count, cfg.data_shards,
                                       cfg.target_shards)
    return DispatchDecision(
        solver="bmor", method="eigh", data_shards=c_d, target_shards=c_t,
        predicted_cost=cost,
        rationale=f"B-MOR Eq. 7: T_W/{c_t} + T_M/{c_d} minimal over divisor "
                  f"layouts of {device_count} devices "
                  f"(vs MOR {complexity.mor_overhead_factor(w, c_t):.0f}× "
                  f"work at equal parallelism)")
