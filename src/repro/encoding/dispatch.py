"""Complexity-driven solver dispatch (paper §3, Eq. 6–7).

The paper's core finding is that the right ridge parallelisation depends on
the problem shape: MOR's per-target refactorisation (Eq. 6, ``c⁻¹(T_W +
t·T_M)``) is impractical at scale, while B-MOR (Eq. 7, ``c⁻¹·T_W + T_M``)
scales to 33×.  This module turns that analysis into code: given ``(n, p, t,
device_count)`` and an ``EncoderConfig``, ``resolve`` picks

* the solver — single-shard mutualised ridge, B-MOR, dual B-MOR, or banded —
* the factorisation side (primal eigh when n ≥ p, dual kernel otherwise),
* and the mesh layout ``(data_shards, target_shards)`` minimising the
  analytic critical-path cost ``T_W/c_t + T_M/c_d``.

MOR is never auto-selected (that is the paper's point); it stays available
as an explicit override for baselines and benchmarks.
"""
from __future__ import annotations

import dataclasses

from repro.core import complexity
from repro.core.complexity import RidgeWorkload
from repro.encoding.config import EncoderConfig


@dataclasses.dataclass(frozen=True)
class DispatchDecision:
    """The resolved execution plan, with the model cost that justified it."""

    solver: str              # "ridge" | "mor" | "bmor" | "bmor_dual" | "banded"
    # Factorisation side "eigh" | "dual", or "chunked": the out-of-core
    # streamed fold-statistics path (always primal/eigh on the accumulated
    # Gram — the regime is tall-n, where (p, p) is the small object).
    method: str
    data_shards: int
    target_shards: int
    predicted_cost: float    # §3 fp-mult count on the critical path
    rationale: str

    @property
    def device_count(self) -> int:
        return self.data_shards * self.target_shards


def _divisor_layouts(c: int) -> list[tuple[int, int]]:
    """All (data_shards, target_shards) with data·target == c."""
    return [(d, c // d) for d in range(1, c + 1) if c % d == 0]


def _best_bmor_layout(w: RidgeWorkload, device_count: int,
                      data_shards: int | None, target_shards: int | None
                      ) -> tuple[int, int, float]:
    """Minimise T_W/c_t + T_M/c_d over divisor splits of the device count.

    Pinned shard counts are honoured directly (a mesh may occupy a device
    subset — benchmark sweeps pin c=1,2,4 on an 8-device host); with one
    side pinned the other takes the remaining devices; with neither pinned
    the search covers divisor pairs of the full device count, ties
    preferring more target shards (the paper's batch axis — per-batch λ,
    Alg. 1 line 13).
    """
    if data_shards is not None and target_shards is not None:
        if data_shards * target_shards > device_count:
            raise ValueError(
                f"pinned layout {data_shards}x{target_shards} needs more "
                f"than the {device_count} available devices")
        return (data_shards, target_shards,
                complexity.t_bmor_sharded(w, data_shards, target_shards))
    if data_shards is not None or target_shards is not None:
        pinned = data_shards if data_shards is not None else target_shards
        if not 1 <= pinned <= device_count:
            raise ValueError(f"pinned shard count {pinned} exceeds the "
                             f"{device_count} available devices")
        other = device_count // pinned
        c_d, c_t = ((pinned, other) if data_shards is not None
                    else (other, pinned))
        return c_d, c_t, complexity.t_bmor_sharded(w, c_d, c_t)
    best_key: tuple[float, int] | None = None
    best_layout: tuple[int, int, float] | None = None
    for c_d, c_t in _divisor_layouts(device_count):
        if c_d > max(w.n, 1):
            continue
        cost = complexity.t_bmor_sharded(w, c_d, c_t)
        key = (cost, -c_t)
        if best_key is None or key < best_key:
            best_key, best_layout = key, (c_d, c_t, cost)
    assert best_layout is not None
    return best_layout


def estimated_resident_bytes(n: int, p: int, t: int,
                             target_shards: int = 1,
                             itemsize: int = 4) -> int:
    """Per-device resident working set of a materialised fit: the row block
    ``n·p`` plus this device's target slice ``n·t_shard`` (f32 by default).

    This is the quantity the paper's Table 1 makes hopeless for the
    whole-brain subject (n≈60k × t≈264k → hundreds of GB): the term
    dispatch compares against ``EncoderConfig.device_memory_budget``.
    """
    t_shard = -(-t // max(target_shards, 1))
    return n * (p + t_shard) * itemsize


def _chunked_decision(cfg: EncoderConfig, w: RidgeWorkload, resident: int,
                      device_count: int) -> DispatchDecision:
    """Pin the streamed fold-statistics path (out-of-core regime)."""
    c_d = cfg.data_shards or device_count
    cost = (complexity.t_w(w) +
            complexity.t_m(w) + complexity.t_w_folded(w) / max(c_d, 1))
    overlap = (f"double-buffered chunk prefetch (depth "
               f"{cfg.prefetch_depth})" if cfg.prefetch
               else "prefetch off (serial read→accumulate)")
    return DispatchDecision(
        solver="ridge", method="chunked", data_shards=c_d, target_shards=1,
        predicted_cost=cost,
        rationale=f"resident set n·p + n·t_shard = {resident / 2**20:.1f} MB "
                  f"exceeds device_memory_budget = "
                  f"{cfg.device_memory_budget / 2**20:.1f} MB → streamed "
                  f"fold-statistics accumulation over {c_d} row shard(s), "
                  f"chunk_rows={cfg.chunk_rows}, {overlap} (only the "
                  f"(k, p, p+t) sufficient statistics and the staging "
                  f"buffers stay resident)")


def resolve(cfg: EncoderConfig, n: int, p: int, t: int,
            device_count: int) -> DispatchDecision:
    """Resolve ``cfg.solver`` ("auto" or explicit) into a concrete plan."""
    valid = ("auto", "ridge", "mor", "bmor", "bmor_dual", "banded")
    if cfg.solver not in valid:
        raise ValueError(f"unknown solver {cfg.solver!r}; expected one of "
                         f"{valid}")
    for name, pinned in (("data_shards", cfg.data_shards),
                         ("target_shards", cfg.target_shards)):
        if pinned is not None and not 1 <= pinned <= device_count:
            raise ValueError(f"{name}={pinned} is outside the valid range "
                             f"[1, {device_count}] (available devices)")
    w = RidgeWorkload(n=n, p=p, t=t, r=len(cfg.lambdas), n_folds=cfg.n_folds)
    method = cfg.method if cfg.method != "auto" else (
        "eigh" if n >= p else "dual")
    solver = cfg.solver

    # Memory-budgeted dispatch: when the materialised working set cannot
    # fit, the ONLY viable plan is the streamed accumulation — it overrides
    # the FLOP-model choice below (which assumes the rows are resident).
    if cfg.device_memory_budget is not None and solver in ("auto", "ridge"):
        # Conservative estimate: unless the caller PINNED a target-shard
        # count, assume t_shard = t — the ridge path this guard protects
        # is single-shard, so dividing by device_count here would
        # under-estimate by device_count× and let fit(store=...)
        # materialise exactly the arrays the budget was set to prevent.
        resident = estimated_resident_bytes(n, p, t, cfg.target_shards or 1)
        if resident > cfg.device_memory_budget:
            if cfg.method == "dual" or cfg.bands is not None:
                raise ValueError(
                    f"resident set {resident} B exceeds device_memory_budget="
                    f"{cfg.device_memory_budget} B but the pinned "
                    f"method/bands ({cfg.method!r}/{cfg.bands}) cannot "
                    f"stream — the chunked path is primal/eigh only")
            return _chunked_decision(cfg, w, resident, device_count)

    if solver == "auto":
        if cfg.bands is not None:
            solver = "banded"
        elif device_count <= 1:
            solver = "ridge"
        elif n < p:
            solver = "bmor_dual"
        else:
            solver = "bmor"

    if solver == "banded":
        if cfg.bands is None:
            raise ValueError("banded solver requires EncoderConfig.bands")
        return DispatchDecision(
            solver="banded", method="eigh", data_shards=1, target_shards=1,
            predicted_cost=cfg.n_band_candidates * complexity.t_m(w),
            rationale=f"{len(cfg.bands)} feature bands → per-band λ "
                      f"(Tikhonov substitution), one T_M per candidate")

    if solver == "ridge":
        # The CV Gram statistics are single-pass (t_w_folded = np², not the
        # per-fold k·np² of the seed path) — foldstats downdating keeps the
        # k-fold redundancy off the critical path.
        cost = (complexity.t_w(w) +
                (complexity.t_m(w) + complexity.t_w_folded(w)
                 if method == "eigh"
                 else complexity.t_m_dual(w) + complexity.t_w_folded_dual(w)))
        return DispatchDecision(
            solver="ridge", method=method, data_shards=1, target_shards=1,
            predicted_cost=cost,
            rationale=f"single shard, {method} factorisation mutualised "
                      f"across t={t} targets and r={w.r} λ (T_M + T_W); "
                      f"single-pass fold stats save "
                      f"{complexity.fold_redundancy_factor(w):.0f}× on the "
                      f"np² Gram term")

    if solver == "mor":
        c_t = cfg.target_shards or 1
        cost = complexity.t_mor(w, c_t)
        return DispatchDecision(
            solver="mor", method=method, data_shards=1, target_shards=c_t,
            predicted_cost=cost,
            rationale=f"explicit MOR baseline: t·T_M recompute, Eq. 6 — "
                      f"{complexity.mor_overhead_factor(w, max(c_t, 1)):.0f}×"
                      f" the B-MOR work at c={c_t} (never auto-selected)")

    if solver == "bmor_dual":
        c_t = cfg.target_shards or device_count
        if cfg.data_shards not in (None, 1):
            raise ValueError("bmor_dual replicates rows; data_shards must "
                             "be 1 (the n×n kernel is small when n < p)")
        cost = (complexity.t_w(w) / c_t + complexity.t_m_dual(w) +
                complexity.t_w_folded_dual(w))
        return DispatchDecision(
            solver="bmor_dual", method="dual", data_shards=1,
            target_shards=c_t, predicted_cost=cost,
            rationale=f"n={n} < p={p}: kernel (n×n) factorisation replicated,"
                      f" targets batched over c={c_t} shards (Eq. 7 dual)")

    assert solver == "bmor", solver
    c_d, c_t, cost = _best_bmor_layout(w, device_count, cfg.data_shards,
                                       cfg.target_shards)
    return DispatchDecision(
        solver="bmor", method="eigh", data_shards=c_d, target_shards=c_t,
        predicted_cost=cost,
        rationale=f"B-MOR Eq. 7: T_W/{c_t} + T_M/{c_d} minimal over divisor "
                  f"layouts of {device_count} devices "
                  f"(vs MOR {complexity.mor_overhead_factor(w, c_t):.0f}× "
                  f"work at equal parallelism)")
