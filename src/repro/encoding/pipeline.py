"""Composable encoding pipeline: detrend → split → standardize → fit → eval.

Each stage is a plain ``PipelineState → PipelineState`` callable, so drivers
can insert, drop, or reorder steps (e.g. skip ``detrend`` for backbone
features that were never polluted with scanner drift) while the default
``run(X, Y, config)`` reproduces the paper's §2 preprocessing + §4 evaluation
end to end.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core import scoring
from repro.data import fmri
from repro.encoding.config import EncoderConfig
from repro.encoding.estimator import (BrainEncoder, EncodingReport,
                                      EvaluationReport)


@dataclasses.dataclass
class Standardizer:
    """Fitted per-column standardization (μ/σ of the *training* rows).

    The ``standardize`` stage records one of these so the transform it
    applied during fitting survives the process: ``BrainEncoder.save``
    persists it inside the encoder bundle, and the serving subsystem
    (``repro.serving_encoders``) replays the same affine maps —
    ``apply_x`` on incoming raw features, ``unapply_y`` on predictions —
    fused into its compiled wave (with identity μ/σ filled in for absent
    halves, so every bundle shares one program signature).  ``None``
    halves mean that side was never standardized (identity transform).
    """

    mu_x: np.ndarray | None = None          # (p,)
    sd_x: np.ndarray | None = None          # (p,)
    mu_y: np.ndarray | None = None          # (t,)
    sd_y: np.ndarray | None = None          # (t,)

    def apply_x(self, X):
        return X if self.mu_x is None else (X - self.mu_x) / self.sd_x

    def apply_y(self, Y):
        return Y if self.mu_y is None else (Y - self.mu_y) / self.sd_y

    def unapply_y(self, Y_pred):
        """Map standardized-space predictions back to raw target units."""
        return Y_pred if self.mu_y is None else Y_pred * self.sd_y + self.mu_y


@dataclasses.dataclass
class PipelineState:
    """Everything flowing between stages.

    Out-of-core states carry a ``store`` (``repro.data.store.RunStore``)
    instead of materialised ``X``/``Y`` — stages that need the rows stream
    them chunk by chunk and never hold ``(n, p)`` resident.
    """

    X: jax.Array | None
    Y: jax.Array | None
    X_test: jax.Array | None = None
    Y_test: jax.Array | None = None
    store: "object | None" = None           # RunStore-shaped source
    standardizer: Standardizer | None = None
    encoder: BrainEncoder | None = None
    report: EncodingReport | None = None
    evaluation: EvaluationReport | None = None


Stage = Callable[[PipelineState], PipelineState]


def detrend(tr_seconds: float = 1.49, cutoff_hz: float = 0.01) -> Stage:
    """Regress slow scanner drifts out of Y (paper §2.1.4)."""
    def stage(s: PipelineState) -> PipelineState:
        s.Y = fmri.detrend(s.Y, tr_seconds=tr_seconds, cutoff_hz=cutoff_hz)
        return s
    return stage


def standardize(features: bool = True, targets: bool = True) -> Stage:
    """Column-wise zero-mean / unit-variance (paper §2.1.4 preprocessing).

    Statistics are computed on the rows currently in ``state.X``/``state.Y``
    — i.e. the *training* rows when a ``split`` stage ran first — and the
    same transform is applied to the held-out rows, so no test-set
    statistics leak into the fit or the evaluation.
    """
    def stage(s: PipelineState) -> PipelineState:
        import numpy as np

        std = Standardizer()
        if features:
            mu, sd = s.X.mean(0), s.X.std(0) + 1e-6
            std.mu_x, std.sd_x = np.asarray(mu), np.asarray(sd)
            s.X = std.apply_x(s.X)
            if s.X_test is not None:
                s.X_test = std.apply_x(s.X_test)
        if targets:
            mu, sd = s.Y.mean(0), s.Y.std(0) + 1e-6
            std.mu_y, std.sd_y = np.asarray(mu), np.asarray(sd)
            s.Y = std.apply_y(s.Y)
            if s.Y_test is not None:
                s.Y_test = std.apply_y(s.Y_test)
        s.standardizer = std
        return s
    return stage


def split(test_frac: float = 0.1, seed: int = 0) -> Stage:
    """Paper §2.2.4: random 90/10 train/test split."""
    def stage(s: PipelineState) -> PipelineState:
        tr, te = scoring.train_test_split_indices(
            jax.random.PRNGKey(seed), s.X.shape[0], test_frac)
        s.X_test, s.Y_test = s.X[te], s.Y[te]
        s.X, s.Y = s.X[tr], s.Y[tr]
        return s
    return stage


def fit(config: EncoderConfig | None = None, **overrides) -> Stage:
    """Fit a ``BrainEncoder`` on the (training) X/Y in the state."""
    def stage(s: PipelineState) -> PipelineState:
        s.encoder = BrainEncoder(config, **overrides).fit(s.X, s.Y)
        s.encoder.standardizer_ = s.standardizer
        s.report = s.encoder.report_
        return s
    return stage


def streaming_moments(chunks) -> tuple:
    """First streaming pass: per-column μ/σ of X and Y over the chunks.

    Returns ``(mu_x, sd_x, mu_y, sd_y)`` as float32 numpy arrays — the
    standardization statistics the second pass applies chunk by chunk, so
    the streamed fit standardizes exactly like ``pipeline.standardize``
    does on materialised rows (μ/σ from the training rows it streams)
    without ever holding them.
    """
    import numpy as np

    from repro.core import foldstats as fs
    mx, my = fs.ColumnMoments(), fs.ColumnMoments()
    for X_c, Y_c in chunks:
        mx.update(X_c)
        my.update(Y_c)
    return (np.float32(mx.mean), np.float32(mx.std()),
            np.float32(my.mean), np.float32(my.std()))


def fit_chunked(config: EncoderConfig | None = None, *,
                chunk_rows: int = 1024, standardize: bool | None = None,
                **overrides) -> Stage:
    """Out-of-core fit stage: stream the training rows in ``chunk_rows``
    batches through ``BrainEncoder.fit_chunks``.

    Sources, in priority order: ``state.store`` (a ``RunStore`` — rows are
    memory-mapped and streamed, ``(n, p)`` is NEVER materialised) or the
    in-memory ``state.X``/``state.Y`` (sliced lazily; useful for parity
    tests of the chunked path, and standardize-free by default so it
    matches a plain ``fit()`` on the same rows).

    ``standardize`` defaults to True for a store source and False for the
    in-memory source.  When on, the stage makes two streaming passes: one
    ``ColumnMoments`` pass for the per-column μ/σ of X and Y on the rows
    it will train on, then the fold-statistics pass over the standardized
    chunks — the streaming equivalent of the ``standardize() → fit()``
    stage pair, at one extra read of the rows and O(p + t) extra
    residency.  Both passes over a store source are background-prefetched
    when the encoder's ``config.prefetch`` is on (the default): the reader
    stages the next chunk while the current one is standardized and
    accumulated, and the fold update is the single fixed-shape compiled
    program, so fold misalignment never recompiles.
    """
    def stage(s: PipelineState) -> PipelineState:
        import numpy as np
        encoder = BrainEncoder(config, **overrides)
        if s.store is not None:
            encoder._check_store_folds(s.store)
            n = s.store.shape[0]
            cfg = encoder.config
            make_chunks = lambda: s.store.iter_chunks(       # noqa: E731
                chunk_rows, prefetch=cfg.prefetch,
                prefetch_depth=cfg.prefetch_depth)
        else:
            if s.X is None:
                raise ValueError("fit_chunked needs state.store or state.X")
            n = s.X.shape[0]
            make_chunks = lambda: (                                # noqa: E731
                (s.X[lo:lo + chunk_rows], s.Y[lo:lo + chunk_rows])
                for lo in range(0, n, chunk_rows))
        chunks = source = make_chunks()
        do_std = standardize if standardize is not None \
            else s.store is not None
        if do_std:
            mu_x, sd_x, mu_y, sd_y = streaming_moments(make_chunks())

            def std_chunks(src):
                # Close a prefetching source on every exit path so an
                # aborted fit never leaves a reader thread behind.
                try:
                    for X_c, Y_c in src:
                        yield ((np.asarray(X_c, np.float32) - mu_x) / sd_x,
                               (np.asarray(Y_c, np.float32) - mu_y) / sd_y)
                finally:
                    if hasattr(src, "close"):
                        src.close()

            chunks = std_chunks(chunks)
            s.standardizer = Standardizer(mu_x=mu_x, sd_x=sd_x,
                                          mu_y=mu_y, sd_y=sd_y)
        s.encoder = encoder.fit_chunks(chunks, n_total=n,
                                       chunk_rows=chunk_rows)
        # The standardizing generator hides the prefetcher from fit_chunks;
        # fold the fit pass's overlap telemetry back into stream_stats_ so
        # the pipeline path reports honestly too.
        src_stats = getattr(source, "stats", None)
        ss = s.encoder.stream_stats_
        if src_stats is not None and ss is not None and not ss["chunks"]:
            ss.update(chunks=src_stats.chunks,
                      bytes_staged=src_stats.bytes_staged,
                      read_stall_s=src_stats.read_stall_s,
                      compute_stall_s=src_stats.compute_stall_s)
        s.encoder.standardizer_ = s.standardizer
        s.report = s.encoder.report_
        return s
    return stage


def evaluate(n_perms: int = 10, seed: int = 1,
             on_train: bool = False) -> Stage:
    """Held-out Pearson r / R² + null-permutation control (§4.1–4.2).

    Refuses to silently report in-sample numbers: if no ``split`` stage ran,
    pass ``on_train=True`` to explicitly evaluate on the training rows.
    """
    def stage(s: PipelineState) -> PipelineState:
        assert s.encoder is not None, "evaluate() needs a fit() stage first"
        if s.X_test is None and not on_train:
            raise ValueError(
                "evaluate(): no split stage ran, so only training rows are "
                "available; add pipeline.split(...) or opt in to in-sample "
                "metrics with evaluate(on_train=True)")
        X_ev = s.X_test if s.X_test is not None else s.X
        Y_ev = s.Y_test if s.Y_test is not None else s.Y
        s.evaluation = s.encoder.evaluate(
            X_ev, Y_ev, n_perms=n_perms, key=jax.random.PRNGKey(seed))
        return s
    return stage


def run_stages(X: jax.Array, Y: jax.Array,
               stages: Sequence[Stage]) -> PipelineState:
    state = PipelineState(X=jnp.asarray(X), Y=jnp.asarray(Y))
    for stage in stages:
        state = stage(state)
    return state


def default_stages(config: EncoderConfig | None = None, *,
                   detrend_targets: bool = True, test_frac: float = 0.1,
                   n_perms: int = 10, seed: int = 0) -> list[Stage]:
    """The paper's end-to-end recipe as a stage list (editable by callers)."""
    stages: list[Stage] = []
    if detrend_targets:
        stages.append(detrend())
    # split BEFORE standardize: μ/σ come from training rows only and are
    # applied to the held-out rows, so the §4 evaluation stays leak-free.
    stages += [split(test_frac=test_frac, seed=seed), standardize(),
               fit(config), evaluate(n_perms=n_perms, seed=seed + 1)]
    return stages


def run(X: jax.Array, Y: jax.Array, config: EncoderConfig | None = None,
        **kwargs) -> PipelineState:
    """One-call pipeline: ``run(X, Y, EncoderConfig(...))``."""
    return run_stages(X, Y, default_stages(config, **kwargs))


def run_store(store, config: EncoderConfig | None = None, *,
              chunk_rows: int = 8192, standardize: bool = True,
              **overrides) -> PipelineState:
    """One-call out-of-core pipeline: stream a ``RunStore`` through the
    two-pass standardize + fold-statistics fit without materialising rows.

    Held-out evaluation needs rows that fit in memory — evaluate against a
    separate (small) test store/array with ``state.encoder.evaluate``.
    """
    state = PipelineState(X=None, Y=None, store=store)
    return fit_chunked(config, chunk_rows=chunk_rows,
                       standardize=standardize, **overrides)(state)
