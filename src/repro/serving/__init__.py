from repro.serving.engine import ServeEngine, ServeRequest  # noqa: F401
from repro.serving.sampler import SamplerConfig, sample  # noqa: F401
