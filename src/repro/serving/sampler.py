"""Token samplers for the serving engine."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0      # 0 → greedy
    top_k: int | None = None      # restrict to k highest logits
    top_p: float | None = None    # nucleus sampling


def sample(key: jax.Array, logits: jax.Array, cfg: SamplerConfig
           ) -> jax.Array:
    """logits: (B, V) → token ids (B,) int32."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / cfg.temperature
    if cfg.top_k is not None:
        kth = jnp.sort(logits, axis=-1)[:, -cfg.top_k][:, None]
        logits = jnp.where(logits >= kth, logits, -jnp.inf)
    if cfg.top_p is not None:
        sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # Smallest prefix with mass ≥ top_p; threshold logit of that prefix.
        cutoff_idx = jnp.sum(cum < cfg.top_p, axis=-1)
        thresh = jnp.take_along_axis(sorted_logits, cutoff_idx[:, None],
                                     axis=-1)
        logits = jnp.where(logits >= thresh, logits, -jnp.inf)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
