"""Wave-batched serving engine.

Requests are queued and served in fixed-shape *waves* (the production
decode shapes are fixed-batch: decode_32k = 128 concurrent slots).  Each
wave: pad/stack prompts → one prefill → greedy/sampled decode loop on the
shared KV cache.  Fixed shapes mean two compilations total (prefill +
decode), reused across waves — the deployment pattern the decode_32k /
long_500k dry-runs prove out at pod scale.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.serving.sampler import SamplerConfig, sample


@dataclasses.dataclass
class ServeRequest:
    prompt: list[int]
    max_new_tokens: int = 16
    eos_id: int | None = None


@dataclasses.dataclass
class ServeResult:
    tokens: list[int]


class ServeEngine:
    def __init__(self, model, params, cfg: ModelConfig, *, wave_size: int = 4,
                 prompt_len: int = 16,
                 sampler: SamplerConfig | None = None, seed: int = 0):
        self.model, self.params, self.cfg = model, params, cfg
        self.wave_size, self.prompt_len = wave_size, prompt_len
        self.sampler = sampler if sampler is not None else SamplerConfig()
        self._key = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)

    # -- queue -----------------------------------------------------------
    def serve(self, requests: Sequence[ServeRequest]) -> list[ServeResult]:
        out: list[ServeResult] = []
        for start in range(0, len(requests), self.wave_size):
            wave = list(requests[start:start + self.wave_size])
            n_real = len(wave)
            while len(wave) < self.wave_size:       # pad the last wave
                wave.append(ServeRequest(prompt=[0], max_new_tokens=1))
            out.extend(self._serve_wave(wave)[:n_real])
        return out

    def _pad_prompt(self, p: list[int]) -> list[int]:
        p = p[-self.prompt_len:]
        return [0] * (self.prompt_len - len(p)) + p

    def _serve_wave(self, wave: list[ServeRequest]) -> list[ServeResult]:
        tokens = jnp.asarray([self._pad_prompt(r.prompt) for r in wave],
                             jnp.int32)
        batch = {"tokens": tokens}
        if self.cfg.family == "audio":
            batch["src_embeds"] = jnp.zeros(
                (len(wave), self.prompt_len, self.cfg.d_model), jnp.bfloat16)
        logits, cache = self._prefill(self.params, batch)

        max_new = max(r.max_new_tokens for r in wave)
        start_pos = self.prompt_len if self.cfg.family != "audio" else 1
        results = [[] for _ in wave]
        done = np.zeros(len(wave), bool)
        tok = None
        for i in range(max_new):
            self._key, sub = jax.random.split(self._key)
            tok = sample(sub, logits[:, -1, :], self.sampler)[:, None]
            step_tokens = np.asarray(tok[:, 0])
            for b, r in enumerate(wave):
                if done[b] or i >= r.max_new_tokens:
                    continue
                t = int(step_tokens[b])
                results[b].append(t)
                if r.eos_id is not None and t == r.eos_id:
                    done[b] = True
            if done.all() or i == max_new - 1:
                break
            logits, cache = self._decode(self.params, cache, tok,
                                         jnp.int32(start_pos + i))
        return [ServeResult(tokens=r) for r in results]
