"""Orphaned-staging reaper: sweep up what crashed writers left behind.

Every atomic-rename writer in the repo stages under a well-known
temporary name next to its target (``.tmpbundle_*`` for
``BundleWriter``, ``*.tmp-<pid>`` for shard/array writes,
``manifest.json.tmp`` for the store manifest, ``.old_*`` for replaced
bundles).  A process killed mid-write leaves that staging entry behind;
it is never referenced by any manifest, so it is garbage — but silently
accumulating garbage fills disks and masks real corruption.

:func:`reap_stale_staging` deletes such entries **age-gated**: only
entries whose mtime is older than ``max_age_s`` go (a *live* concurrent
writer's staging dir is younger than that), and every reaped entry is
counted in the ``staging_reaped`` obs counter plus an
``obs.instant("cleanup.reap")`` marker, so a bench or CI run can assert
how much the sweep collected.
"""
from __future__ import annotations

import fnmatch
import os
import shutil
import time

from repro import obs

__all__ = ["STAGING_PATTERNS", "reap_stale_staging"]

#: glob patterns every atomic-rename writer in the repo stages under.
STAGING_PATTERNS = (
    ".tmpbundle_*",        # BundleWriter staging dirs
    ".tmpresidency_*",     # ResidencyMap atomic-JSON staging
    "*.tmp-*",             # shard/array tmp-then-rename files
    "manifest.json.tmp",   # RunStore manifest staging
    ".old_*",              # replaced-bundle graveyard dirs
)


def reap_stale_staging(root: str, *, max_age_s: float = 3600.0,
                       patterns: tuple[str, ...] = STAGING_PATTERNS,
                       now: float | None = None) -> list[str]:
    """Delete stale staging entries directly under ``root``.

    Returns the (possibly empty) list of reaped entry names.  Missing
    ``root`` is a no-op; entries that vanish mid-sweep (a concurrent
    reaper) are skipped silently — the sweep is best-effort and never
    raises for reapable garbage.
    """
    if not os.path.isdir(root):
        return []
    if now is None:
        now = time.time()
    reaped: list[str] = []
    for name in sorted(os.listdir(root)):
        if not any(fnmatch.fnmatch(name, pat) for pat in patterns):
            continue
        path = os.path.join(root, name)
        try:
            age = now - os.lstat(path).st_mtime
        except OSError:
            continue                        # vanished mid-sweep
        if age < max_age_s:
            continue                        # possibly a live writer
        try:
            if os.path.isdir(path) and not os.path.islink(path):
                shutil.rmtree(path, ignore_errors=True)
            else:
                os.unlink(path)
        except OSError:
            continue
        reaped.append(name)
        obs.instant("cleanup.reap", path=name, age_s=round(age, 1))
    if reaped:
        obs.get_metrics().counter("staging_reaped").inc(len(reaped))
    return reaped
