"""Transient-fault retry policy for the streaming and serving tiers.

A :class:`FaultPolicy` describes *how* to retry an I/O operation that
failed transiently: how many attempts, how the backoff grows, how much
deterministic jitter to add, and an optional per-op wall-clock deadline.
:func:`retry_call` executes a callable under a policy, classifying each
exception as transient (retry) or permanent (raise immediately), and
publishes every retry and give-up through ``repro.obs``:

* counter ``io_retries{op=...}`` — one per retried attempt
* counter ``io_giveups{op=...}`` — one per exhausted/permanent failure
* span ``retry.backoff`` — wraps each backoff sleep (attrs: op, attempt)

Determinism: the jitter is a pure function of ``(seed, op, attempt)``
(CRC32-derived), never ``random``/wall clock, so two processes with the
same policy back off identically and tests can assert exact delays.
``sleep`` and ``clock`` are injectable so the fault-injection test
matrix runs with a virtual clock — no real sleeping, no flakes.

Classification: :class:`TransientFault` (and any exception with a
truthy ``transient`` attribute) always retries; plain ``OSError`` with
errno in :data:`TRANSIENT_ERRNOS` and ``TimeoutError`` retry; everything
else is permanent and propagates on the first occurrence.
"""
from __future__ import annotations

import errno
import time
import zlib
from dataclasses import dataclass, field, replace
from typing import Callable

from repro import obs

__all__ = [
    "FaultPolicy", "TransientFault", "RetryGiveUp", "retry_call",
    "classify_default", "TRANSIENT_ERRNOS", "NO_RETRY",
]

#: errno values treated as transient for plain ``OSError``.
TRANSIENT_ERRNOS = frozenset({
    errno.EIO, errno.EAGAIN, errno.EBUSY, errno.EINTR, errno.ETIMEDOUT,
})


class TransientFault(OSError):
    """An error the caller should retry under its :class:`FaultPolicy`.

    Subclasses ``OSError`` deliberately: existing give-up translation
    sites (``except OSError: raise BundleError/StoreError``) keep
    working unchanged when a retry loop exhausts and re-raises.
    """

    transient = True


class RetryGiveUp(RuntimeError):
    """Internal marker — never raised to callers; the original exception
    is always re-raised on give-up so error types stay stable."""


def classify_default(exc: BaseException) -> bool:
    """Return True if ``exc`` should be retried (transient)."""
    t = getattr(exc, "transient", None)
    if t is not None:
        return bool(t)
    if isinstance(exc, TimeoutError):
        return True
    if isinstance(exc, OSError):
        return exc.errno in TRANSIENT_ERRNOS
    return False


@dataclass(frozen=True)
class FaultPolicy:
    """How to retry one class of I/O operation.

    Attributes
    ----------
    max_attempts : total tries including the first (>= 1).
    base_delay_s : backoff before attempt 2 (then grows by ``backoff``).
    backoff      : multiplicative growth per retry.
    max_delay_s  : backoff cap.
    jitter       : fraction of the delay perturbed deterministically
                   from ``(seed, op, attempt)``; 0 disables.
    deadline_s   : optional per-op wall-clock budget measured on
                   ``clock``; exceeded -> give up even with attempts
                   remaining.
    seed         : jitter seed (same seed -> same delays everywhere).
    sleep/clock  : injectable for tests (virtual time, no real sleeps).
    """

    max_attempts: int = 3
    base_delay_s: float = 0.05
    backoff: float = 2.0
    max_delay_s: float = 2.0
    jitter: float = 0.25
    deadline_s: float | None = None
    seed: int = 0
    sleep: Callable[[float], None] = field(default=time.sleep, repr=False)
    clock: Callable[[], float] = field(default=time.monotonic, repr=False)

    def delay_for(self, op: str, attempt: int) -> float:
        """Deterministic backoff before retry number ``attempt`` (1-based)."""
        d = min(self.base_delay_s * (self.backoff ** (attempt - 1)),
                self.max_delay_s)
        if self.jitter:
            h = zlib.crc32(f"{self.seed}:{op}:{attempt}".encode()) / 0xFFFFFFFF
            d *= 1.0 + self.jitter * (2.0 * h - 1.0)
        return max(d, 0.0)

    def with_virtual_time(self) -> "FaultPolicy":
        """Copy with a no-op sleep and a counting clock (for tests)."""
        t = [0.0]

        def _sleep(s: float) -> None:
            t[0] += s

        def _clock() -> float:
            return t[0]

        return replace(self, sleep=_sleep, clock=_clock)


#: Policy that never retries — used to opt a path out without branching.
NO_RETRY = FaultPolicy(max_attempts=1, base_delay_s=0.0, jitter=0.0)


def retry_call(fn: Callable, policy: FaultPolicy | None, op: str,
               classify: Callable[[BaseException], bool] = classify_default):
    """Run ``fn()`` under ``policy``; retry transient failures.

    Raises the LAST exception unchanged on give-up (attempt or deadline
    exhaustion) and the FIRST exception unchanged when permanent, so
    callers' existing ``except`` clauses see the same types as before.
    """
    if policy is None:
        policy = NO_RETRY
    metrics = obs.get_metrics()
    start = policy.clock()
    attempt = 0
    while True:
        attempt += 1
        try:
            return fn()
        except BaseException as exc:  # noqa: BLE001 - reclassified below
            if not classify(exc):
                raise
            out_of_attempts = attempt >= policy.max_attempts
            out_of_time = (policy.deadline_s is not None
                           and policy.clock() - start >= policy.deadline_s)
            if out_of_attempts or out_of_time:
                metrics.counter("io_giveups", op=op).inc()
                obs.instant("retry.giveup", op=op, attempt=attempt)
                raise
            metrics.counter("io_retries", op=op).inc()
            delay = policy.delay_for(op, attempt)
            with obs.span("retry.backoff", op=op, attempt=attempt,
                          delay_s=round(delay, 6)):
                if delay > 0.0:
                    policy.sleep(delay)
