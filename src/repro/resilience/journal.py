"""Crash-consistent progress ledger for the column-blocked whole-brain fit.

A :class:`FitJournal` lives in its own directory next to the
``BundleWriter`` staging dir (``<bundle>.journal`` by convention) and
records, durably, everything ``fit_wholebrain`` would otherwise lose to
a crash:

* the fused X-stats pass (``G``/``xsum``/``count`` of the k-fold
  ``FoldStats`` — the inputs of the hoisted eighs, which are themselves
  recomputed on resume, never persisted), and
* each completed column block: its float64 per-fold validation-score
  contribution, plus the block's ``Â`` projection (global-λ mode) or its
  chosen λ, CV curve, and solved weight shard (per-block mode).

Write protocol (crash-consistent by construction):

1. array payloads land as ``<name>.tmp-<pid>`` then ``os.replace`` —
   a reader never sees a torn ``.npy``;
2. the ``ledger.json`` index is rewritten the same way, LAST — a block
   exists exactly when the ledger lists it.  A crash between (1) and (2)
   leaves an orphaned payload that the next attach sweeps
   (:func:`repro.resilience.cleanup.reap_stale_staging`).

Bit-identity: the journal stores the exact arrays the live fit produced
(f32 statistics, f64 score contributions), and the resuming fit *replays*
them — adds the same f64 addends in the same block order, writes the same
f32 ``Â`` bytes into the scratch — so λ and W of a resumed fit are
bitwise equal to an uninterrupted run's.  The ledger's ``signature``
pins every input that shapes those bytes (shape, folds, blocking, λ
grid, scoring, chunking); attaching with a different signature raises
:class:`JournalError` rather than resuming into silent garbage.
"""
from __future__ import annotations

import json
import os
import tempfile

import numpy as np

from repro import obs
from repro.resilience import cleanup

__all__ = ["FitJournal", "JournalError", "LEDGER_NAME"]

LEDGER_NAME = "ledger.json"
_VERSION = 1


class JournalError(RuntimeError):
    """Unusable journal: signature mismatch or corrupt ledger."""


def _atomic_write_bytes(path: str, data: bytes) -> None:
    tmp = f"{path}.tmp-{os.getpid()}"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def _atomic_save_array(path: str, arr: np.ndarray) -> None:
    tmp = f"{path}.tmp-{os.getpid()}"
    with open(tmp, "wb") as f:
        np.save(f, arr)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


class FitJournal:
    """Progress ledger of one ``fit_wholebrain`` invocation.

    ``attach`` is the one constructor: it creates the directory on first
    use, reap-sweeps stale ``*.tmp-*`` payloads from a previous crash,
    and validates the signature when a ledger already exists.
    """

    def __init__(self, root: str, signature: dict, ledger: dict):
        self.root = root
        self.signature = signature
        self._ledger = ledger

    # -- construction --------------------------------------------------------
    @classmethod
    def attach(cls, root: str, signature: dict) -> "FitJournal":
        os.makedirs(root, exist_ok=True)
        # Torn payloads from a crashed writer are garbage immediately —
        # nothing else writes here, so no age gate.
        cleanup.reap_stale_staging(root, max_age_s=0.0,
                                   patterns=("*.tmp-*",))
        path = os.path.join(root, LEDGER_NAME)
        if os.path.exists(path):
            try:
                with open(path) as f:
                    ledger = json.load(f)
            except (OSError, json.JSONDecodeError) as e:
                raise JournalError(f"corrupt journal ledger {path}: {e}")
            if ledger.get("version") != _VERSION:
                raise JournalError(
                    f"journal version {ledger.get('version')} != {_VERSION}")
            if ledger.get("signature") != signature:
                raise JournalError(
                    f"journal at {root} was written by a different fit "
                    f"configuration; delete it or pass a fresh journal dir "
                    f"(journal {ledger.get('signature')} != fit {signature})")
            obs.instant("journal.resume", root=root,
                        blocks=len(ledger.get("blocks", {})))
        else:
            ledger = {"version": _VERSION, "signature": signature,
                      "xstats": False, "blocks": {}}
        j = cls(root, signature, ledger)
        if not os.path.exists(path):
            j._flush()
        return j

    def _flush(self) -> None:
        data = (json.dumps(self._ledger, indent=1) + "\n").encode()
        _atomic_write_bytes(os.path.join(self.root, LEDGER_NAME), data)

    # -- X statistics --------------------------------------------------------
    @property
    def has_xstats(self) -> bool:
        return bool(self._ledger["xstats"])

    def put_xstats(self, G: np.ndarray, xsum: np.ndarray,
                   count: np.ndarray) -> None:
        for name, arr in (("G", G), ("xsum", xsum), ("count", count)):
            _atomic_save_array(os.path.join(self.root, f"xstats.{name}.npy"),
                               np.asarray(arr))
        self._ledger["xstats"] = True
        self._flush()

    def load_xstats(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        if not self.has_xstats:
            raise JournalError("journal has no X statistics yet")
        return tuple(np.load(os.path.join(self.root, f"xstats.{n}.npy"))
                     for n in ("G", "xsum", "count"))

    # -- column blocks -------------------------------------------------------
    def completed_blocks(self) -> set[int]:
        return {int(b) for b in self._ledger["blocks"]}

    def has_block(self, bi: int) -> bool:
        return str(bi) in self._ledger["blocks"]

    def put_block(self, bi: int, *, scores: np.ndarray | None = None,
                  ahat: np.ndarray | None = None,
                  lam: float | None = None,
                  curve: np.ndarray | None = None,
                  W: np.ndarray | None = None) -> None:
        """Record block ``bi`` as complete; payloads land before the ledger."""
        rec: dict = {}
        for name, arr in (("scores", scores), ("ahat", ahat),
                          ("curve", curve), ("W", W)):
            if arr is not None:
                fname = f"block_{bi:05d}.{name}.npy"
                _atomic_save_array(os.path.join(self.root, fname),
                                   np.asarray(arr))
                rec[name] = fname
        if lam is not None:
            rec["lam"] = float(lam)
        self._ledger["blocks"][str(bi)] = rec
        self._flush()
        obs.instant("journal.block", block=bi)

    def load_block(self, bi: int) -> dict:
        """Block record with array fields loaded (keys as written)."""
        rec = self._ledger["blocks"].get(str(bi))
        if rec is None:
            raise JournalError(f"block {bi} is not journaled")
        out: dict = {}
        for name, val in rec.items():
            if name == "lam":
                out["lam"] = float(val)
            else:
                out[name] = np.load(os.path.join(self.root, val))
        return out

    # -- lifecycle -----------------------------------------------------------
    def finish(self) -> None:
        """Delete the journal after the fit committed its result."""
        import shutil
        shutil.rmtree(self.root, ignore_errors=True)
        obs.instant("journal.finish", root=self.root)

    @staticmethod
    def default_dir(bundle_dir: str | None) -> str:
        """Conventional journal location for a bundle-producing fit."""
        if bundle_dir:
            return os.path.abspath(bundle_dir).rstrip(os.sep) + ".journal"
        return tempfile.mkdtemp(prefix="wholebrain_journal_")
