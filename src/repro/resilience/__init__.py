"""repro.resilience — the crash-safe execution tier.

Three independent mechanisms, one deterministic test harness:

* **Retry** (:mod:`~repro.resilience.policy`): :class:`FaultPolicy`
  (attempts, exponential backoff with deterministic jitter, per-op
  deadline) + :func:`retry_call`, wired into ``RunStore`` shard mmaps,
  the ``ChunkPrefetcher`` reader (which restarts its stream at the next
  unconsumed chunk), and ``EncoderRegistry`` bundle/shard loads.
  Retries and give-ups are ``repro.obs`` counters
  (``io_retries{op=...}`` / ``io_giveups{op=...}``).
* **Checkpoint/resume** (:mod:`~repro.resilience.journal`):
  :class:`FitJournal` — the atomic-rename progress ledger that makes a
  killed ``fit_wholebrain`` resumable with bit-identical λ and W.
* **Cleanup** (:mod:`~repro.resilience.cleanup`):
  :func:`reap_stale_staging` — age-gated sweep of the staging dirs and
  tmp files crashed writers leave behind.

:mod:`~repro.resilience.faultsim` is the seeded fault-injection harness
(fail the Nth read, truncate a payload, kill after block N) that makes
every resilience test — and the CI ``faults`` lane — deterministic.
Fleet liveness (heartbeat leases, ``expire_dead``, request replay)
lives with the fleet itself in ``repro.serving_encoders.fleet``.
"""
from repro.resilience.cleanup import (  # noqa: F401
    STAGING_PATTERNS, reap_stale_staging,
)
from repro.resilience.journal import (  # noqa: F401
    FitJournal, JournalError,
)
from repro.resilience.policy import (  # noqa: F401
    NO_RETRY, FaultPolicy, RetryGiveUp, TransientFault, classify_default,
    retry_call,
)

__all__ = [
    "FaultPolicy", "TransientFault", "RetryGiveUp", "retry_call",
    "classify_default", "NO_RETRY",
    "FitJournal", "JournalError",
    "reap_stale_staging", "STAGING_PATTERNS",
]
