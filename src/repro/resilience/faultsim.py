"""Deterministic fault-injection harness for the resilience tier.

Every retry/lease/resume test in the repo drives failures through ONE
seeded :class:`FaultInjector` instead of monkeypatched randomness or
sleep-and-hope timing: the injector counts invocations per named op and
raises exactly the planned exception on exactly the planned invocation.
Two runs with the same plan fail identically — which is what lets CI
gate "injected transient read faults change neither λ nor
compile_count" as a bitwise assertion.

Wrappers around the real components:

* :func:`wrap_store` — a ``RunStore`` whose shard mmaps (op
  ``store.mmap``) and per-chunk yields (op ``store.chunk``) consult the
  injector; the store's retry policy and the ``ChunkPrefetcher``'s
  stream-restart path are exercised against it unmodified.
* :func:`flaky_proxy` — a generic delegating proxy that interposes the
  injector before named methods; :func:`flaky_bundle` specialises it for
  ``EncoderBundle`` loads (ops ``bundle.load_encoder`` /
  ``bundle.load_shard``).
* :class:`KillAfterBlock` — a ``FitJournal`` wrapper that hard-kills the
  process (``os._exit``) immediately after block N commits to the
  ledger: the crash-resume gate's deterministic "pull the plug here".
* :func:`truncate_file` — torn-write simulation for staging payloads.
"""
from __future__ import annotations

import os
import threading
from typing import Callable

from repro.resilience.policy import TransientFault

__all__ = [
    "InjectedFault", "InjectedPermanentFault", "FaultInjector",
    "wrap_store", "flaky_proxy", "flaky_bundle", "KillAfterBlock",
    "truncate_file",
]


class InjectedFault(TransientFault):
    """A planned transient failure (retryable under any FaultPolicy)."""


class InjectedPermanentFault(OSError):
    """A planned permanent failure — must NOT be retried."""

    transient = False


class FaultInjector:
    """Seeded, counting fault planner.

    ``plan(op, fail_at)`` arms invocation number ``fail_at`` (1-based) of
    ``op``; ``check(op)`` — called by the wrappers on every invocation —
    raises the armed exception when the count matches.  ``times`` arms a
    run of consecutive failures (attempts ``fail_at`` ..
    ``fail_at + times - 1``), which is how a test forces a give-up with
    ``max_attempts`` retries.  Thread-safe: the prefetcher's reader
    thread and the consumer may both consult the injector.
    """

    def __init__(self, seed: int = 0):
        self.seed = seed
        self._lock = threading.Lock()
        self._counts: dict[str, int] = {}
        self._plans: dict[str, list[tuple[int, Callable[[], BaseException]]]] \
            = {}
        self._fired: dict[str, int] = {}

    def plan(self, op: str, fail_at: int, *, times: int = 1,
             exc: Callable[[], BaseException] | None = None) -> None:
        if fail_at < 1 or times < 1:
            raise ValueError("fail_at and times are 1-based and positive")
        if exc is None:
            exc = lambda: InjectedFault(  # noqa: E731
                f"injected fault: op={op} seed={self.seed}")
        with self._lock:
            plans = self._plans.setdefault(op, [])
            plans.extend((fail_at + i, exc) for i in range(times))

    def check(self, op: str) -> None:
        """Count one invocation of ``op``; raise if this one was planned."""
        with self._lock:
            n = self._counts.get(op, 0) + 1
            self._counts[op] = n
            hit = None
            for i, (at, exc) in enumerate(self._plans.get(op, ())):
                if at == n:
                    hit = exc
                    del self._plans[op][i]
                    self._fired[op] = self._fired.get(op, 0) + 1
                    break
        if hit is not None:
            raise hit()

    def count(self, op: str) -> int:
        with self._lock:
            return self._counts.get(op, 0)

    def fired(self, op: str) -> int:
        with self._lock:
            return self._fired.get(op, 0)


def wrap_store(store, injector: FaultInjector):
    """A ``RunStore`` clone whose reads consult ``injector``.

    Ops: ``store.mmap`` (one per shard-pair mapping — the
    ``_mmap_raw`` seam the store-level retry wraps) and ``store.chunk``
    (one per chunk yielded by the synchronous iterator — what the
    prefetcher's restarting reader sees mid-stream).
    """
    base = type(store)

    class _FaultyStore(base):
        def _mmap_raw(self, r):
            injector.check("store.mmap")
            return super()._mmap_raw(r)

        def _iter_chunks_sync(self, *args, **kwargs):
            for item in super()._iter_chunks_sync(*args, **kwargs):
                injector.check("store.chunk")
                yield item

    faulty = object.__new__(_FaultyStore)
    faulty.__dict__.update(store.__dict__)
    return faulty


class _FlakyProxy:
    """Delegating proxy that runs ``injector.check(op)`` before the
    named methods (everything else passes straight through)."""

    def __init__(self, target, injector: FaultInjector, ops: dict):
        object.__setattr__(self, "_target", target)
        object.__setattr__(self, "_injector", injector)
        object.__setattr__(self, "_ops", dict(ops))

    def __getattr__(self, name):
        attr = getattr(self._target, name)
        op = self._ops.get(name)
        if op is None or not callable(attr):
            return attr

        def _guarded(*args, **kwargs):
            self._injector.check(op)
            return attr(*args, **kwargs)

        return _guarded


def flaky_proxy(target, injector: FaultInjector, ops: dict):
    """Wrap ``target`` so each method named in ``ops`` consults the
    injector under its op label before delegating."""
    return _FlakyProxy(target, injector, ops)


def flaky_bundle(bundle, injector: FaultInjector):
    """An ``EncoderBundle`` whose loads consult the injector (ops
    ``bundle.load_encoder`` / ``bundle.load_shard``)."""
    return flaky_proxy(bundle, injector, {
        "load_encoder": "bundle.load_encoder",
        "load_weight_shard": "bundle.load_shard",
    })


class KillAfterBlock:
    """``FitJournal`` wrapper: hard-exit right after block ``n`` commits.

    ``os._exit`` (no atexit, no finally blocks) models a SIGKILL'd fit
    child at the exact crash-consistency boundary: the ledger lists
    blocks 0..n, everything later is lost.  Exit code defaults to 42 so
    the launcher's crash-resume gate can tell a planned kill from a real
    failure.
    """

    def __init__(self, journal, kill_after: int, *, exit_code: int = 42):
        self._journal = journal
        self._kill_after = kill_after
        self._exit_code = exit_code

    def put_block(self, bi: int, **kwargs) -> None:
        self._journal.put_block(bi, **kwargs)
        if bi == self._kill_after:
            os._exit(self._exit_code)

    def __getattr__(self, name):
        return getattr(self._journal, name)


def truncate_file(path: str, keep_bytes: int) -> None:
    """Simulate a torn write: keep only the first ``keep_bytes`` bytes."""
    with open(path, "r+b") as f:
        f.truncate(keep_bytes)
