from repro.checkpoint.io import (  # noqa: F401
    CheckpointError, latest_step, load, restore, save,
)
