"""Minimal, dependency-free pytree checkpointing.

Layout: ``<dir>/step_<n>/`` with one ``.npy`` per leaf (named by the
flattened key path, '/'-joined) plus ``manifest.json`` recording the tree
structure and dtypes.  Atomic via write-to-tmp + rename.  bfloat16 leaves
are stored as uint16 views with the true dtype in the manifest (npy has no
native bf16).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any) -> dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out[key] = leaf
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    flat = _flatten(tree)
    treedef = jax.tree_util.tree_structure(tree)
    target = os.path.join(ckpt_dir, f"step_{step}")
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    manifest = {"treedef": str(treedef), "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        dtype_name = str(arr.dtype)
        if arr.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)
            dtype_name = "bfloat16"
        fname = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {"file": fname, "dtype": dtype_name}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(target):
        shutil.rmtree(target)
    os.rename(tmp, target)
    return target


def restore(ckpt_dir: str, step: int, like: Any) -> Any:
    """Restore into the structure of ``like`` (shapes/dtypes validated)."""
    src = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(src, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like = _flatten(like)
    restored = {}
    for key, ref in flat_like.items():
        meta = manifest["leaves"][key]
        arr = np.load(os.path.join(src, meta["file"]))
        if meta["dtype"] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        want_shape = tuple(ref.shape)
        assert tuple(arr.shape) == want_shape, (key, arr.shape, want_shape)
        restored[key] = jnp.asarray(arr)
    # Rebuild in like's structure.
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = [restored["/".join(_path_str(p) for p in path)]
              for path, _ in flat]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_", 1)[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")]
    return max(steps) if steps else None
