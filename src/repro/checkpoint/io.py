"""Minimal, dependency-free pytree checkpointing.

Layout: ``<dir>/step_<n>/`` with one ``.npy`` per leaf (named by the
flattened key path, '/'-joined) plus ``manifest.json`` recording the tree
structure and dtypes.  Atomic via write-to-tmp + rename.  bfloat16 leaves
are stored as uint16 views with the true dtype in the manifest (npy has no
native bf16).

Errors are typed: a missing/corrupt manifest, a leaf recorded in the
manifest whose ``.npy`` is gone, or a requested leaf the manifest never
recorded all raise ``CheckpointError`` (a ``ValueError``), never a bare
``KeyError`` — consumers like ``serving_encoders.bundle`` turn these into
their own eager-validation failures.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


class CheckpointError(ValueError):
    """Checkpoint inconsistency: missing/corrupt manifest, missing leaf
    file, a leaf absent from the manifest, or a shape mismatch on restore."""


def _flatten(tree: Any) -> dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out[key] = leaf
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def atomic_replace_dir(tmp: str, target: str) -> None:
    """Crash-safely swap a fully-written ``tmp`` directory into ``target``.

    If ``target`` exists it is renamed aside first and deleted only after
    the swap, so a failure at any point leaves one complete directory:
    either the old content (restored on exception) or the new.  On
    failure ``tmp`` is cleaned up and the exception re-raised.
    """
    parent = os.path.dirname(os.path.abspath(target)) or "."
    old = None
    try:
        if os.path.exists(target):
            old = tempfile.mkdtemp(dir=parent, prefix=".old_")
            os.rename(target, os.path.join(old, "d"))
        os.rename(tmp, target)
        if old is not None:
            shutil.rmtree(old, ignore_errors=True)
    except BaseException:
        if old is not None:
            moved = os.path.join(old, "d")
            if not os.path.exists(target) and os.path.exists(moved):
                os.rename(moved, target)                 # restore old
            if not os.path.exists(moved):                # payload safe →
                shutil.rmtree(old, ignore_errors=True)   # drop aside dir
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    flat = _flatten(tree)
    treedef = jax.tree_util.tree_structure(tree)
    target = os.path.join(ckpt_dir, f"step_{step}")
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    manifest = {"treedef": str(treedef), "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        dtype_name = str(arr.dtype)
        if arr.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)
            dtype_name = "bfloat16"
        fname = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {"file": fname, "dtype": dtype_name}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    atomic_replace_dir(tmp, target)
    return target


def _read_manifest(src: str) -> dict:
    path = os.path.join(src, "manifest.json")
    if not os.path.exists(path):
        raise CheckpointError(f"no manifest.json under {src}")
    try:
        with open(path) as f:
            manifest = json.load(f)
    except json.JSONDecodeError as e:
        raise CheckpointError(f"corrupt manifest.json under {src}: {e}")
    if not isinstance(manifest.get("leaves"), dict):
        raise CheckpointError(f"manifest.json under {src} has no 'leaves'")
    return manifest


def _load_leaf(src: str, key: str, meta: dict, *,
               mmap: bool = False) -> np.ndarray:
    path = os.path.join(src, meta["file"])
    if not os.path.exists(path):
        raise CheckpointError(
            f"leaf {key!r}: manifest records {meta['file']} but the file "
            f"is missing under {src}")
    arr = np.load(path, mmap_mode="r" if mmap else None)
    if meta["dtype"] == "bfloat16":
        arr = arr.view(jnp.bfloat16)
    return arr


def load_leaf(ckpt_dir: str, step: int, key: str, *,
              mmap: bool = False) -> np.ndarray:
    """Load ONE leaf by its flattened key path.

    ``mmap=True`` returns a read-only memmap view — nothing is paged in
    until the caller touches it, so a consumer that needs one column
    shard of a whole-brain weight matrix never faults in the rest.
    bfloat16 leaves come back viewed as bf16 either way.
    """
    src = os.path.join(ckpt_dir, f"step_{step}")
    manifest = _read_manifest(src)
    if key not in manifest["leaves"]:
        raise CheckpointError(
            f"leaf {key!r} is not recorded in the manifest under {src}")
    return _load_leaf(src, key, manifest["leaves"][key], mmap=mmap)


def load(ckpt_dir: str, step: int) -> dict[str, np.ndarray]:
    """Load every leaf of a checkpoint as a flat ``{path: array}`` dict.

    No ``like`` tree needed: the manifest alone drives the read, so callers
    that persist their own structure description (``serving_encoders``
    bundles) can restore without pre-building a template pytree.  bfloat16
    leaves come back viewed as bf16 (the uint16 storage is transparent).
    """
    src = os.path.join(ckpt_dir, f"step_{step}")
    manifest = _read_manifest(src)
    return {key: _load_leaf(src, key, meta)
            for key, meta in manifest["leaves"].items()}


def restore(ckpt_dir: str, step: int, like: Any) -> Any:
    """Restore into the structure of ``like`` (shapes/dtypes validated)."""
    src = os.path.join(ckpt_dir, f"step_{step}")
    manifest = _read_manifest(src)
    flat_like = _flatten(like)
    missing = sorted(set(flat_like) - set(manifest["leaves"]))
    if missing:
        raise CheckpointError(
            f"checkpoint {src} is missing {len(missing)} leave(s) that the "
            f"restore template requires: {missing[:5]}"
            + (" ..." if len(missing) > 5 else ""))
    restored = {}
    for key, ref in flat_like.items():
        arr = _load_leaf(src, key, manifest["leaves"][key])
        want_shape = tuple(ref.shape)
        if tuple(arr.shape) != want_shape:
            raise CheckpointError(
                f"leaf {key!r}: stored shape {tuple(arr.shape)} != template "
                f"shape {want_shape}")
        restored[key] = jnp.asarray(arr)
    # Rebuild in like's structure.
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = [restored["/".join(_path_str(p) for p in path)]
              for path, _ in flat]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_", 1)[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")]
    return max(steps) if steps else None
