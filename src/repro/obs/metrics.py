"""Process-global metrics registry: typed counters/gauges/histograms.

One :class:`MetricsRegistry` instance (module-global ``REGISTRY``,
reachable via :func:`get_metrics`) holds every metric in the process.
Instruments are identified by ``(name, sorted(labels))`` — asking twice
returns the SAME object, so hot paths hoist the lookup once
(``ctr = get_metrics().counter("bytes_staged")``) and pay a plain
float-add per event afterwards.

``snapshot()`` renders everything into ONE JSON-serialisable dict (the
shared schema documented in ``repro.obs.__doc__``); it is what
``stream_stats_``, ``ServiceStats.to_dict``, ``PrefetchStats.to_dict``
and every ``BENCH_*.json`` row embed instead of inventing bespoke key
sets.  Label sets flatten Prometheus-style: ``compiles{tier=foldstats}``.

The RSS gauge is fed by :func:`start_rss_poller` — a daemon thread that
samples ``/proc/self/status`` ``VmRSS`` (fallback: ``ru_maxrss``) every
``interval_s`` into ``rss_bytes`` / high-water ``rss_peak_bytes``.
"""
from __future__ import annotations

import json
import threading
import time

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
    "get_metrics", "snapshot", "start_rss_poller", "read_rss_bytes",
    "SCHEMA_VERSION",
]

SCHEMA_VERSION = "repro.obs/v1"


def _key(name: str, labels: dict) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonically increasing float."""

    __slots__ = ("key", "value", "_lock")

    def __init__(self, key: str):
        self.key = key
        self.value = 0.0
        self._lock = threading.Lock()

    def inc(self, v: float = 1.0) -> None:
        with self._lock:
            self.value += v


class Gauge:
    """Last-write-wins instantaneous value (also tracks its own peak)."""

    __slots__ = ("key", "value", "peak", "_lock")

    def __init__(self, key: str):
        self.key = key
        self.value = 0.0
        self.peak = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self.value = v
            if v > self.peak:
                self.peak = v


class Histogram:
    """Streaming summary: count/sum/min/max (no bucket boundaries to
    configure — reports derive mean; percentiles belong to traces)."""

    __slots__ = ("key", "count", "sum", "min", "max", "_lock")

    def __init__(self, key: str):
        self.key = key
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        with self._lock:
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v

    def summary(self) -> dict:
        with self._lock:
            if not self.count:
                return {"count": 0, "sum": 0.0}
            return {"count": self.count, "sum": self.sum,
                    "min": self.min, "max": self.max,
                    "mean": self.sum / self.count}


class MetricsRegistry:
    """Typed, labelled instruments with one JSON snapshot."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def counter(self, name: str, **labels) -> Counter:
        k = _key(name, labels)
        with self._lock:
            c = self._counters.get(k)
            if c is None:
                c = self._counters[k] = Counter(k)
            return c

    def gauge(self, name: str, **labels) -> Gauge:
        k = _key(name, labels)
        with self._lock:
            g = self._gauges.get(k)
            if g is None:
                g = self._gauges[k] = Gauge(k)
            return g

    def histogram(self, name: str, **labels) -> Histogram:
        k = _key(name, labels)
        with self._lock:
            h = self._histograms.get(k)
            if h is None:
                h = self._histograms[k] = Histogram(k)
            return h

    def snapshot(self) -> dict:
        """The shared metrics-snapshot schema (see ``repro.obs``):
        JSON-serialisable, stable key names, round-trips losslessly."""
        with self._lock:
            counters = {k: c.value for k, c in self._counters.items()}
            gauges = {k: {"value": g.value, "peak": g.peak}
                      for k, g in self._gauges.items()}
            hists = {k: h.summary() for k, h in self._histograms.items()}
        return {"schema": SCHEMA_VERSION,
                "counters": dict(sorted(counters.items())),
                "gauges": dict(sorted(gauges.items())),
                "histograms": dict(sorted(hists.items()))}

    def reset(self) -> None:
        """Drop every instrument (tests; fresh bench children)."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()

    def write_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=2, sort_keys=True)
            f.write("\n")


REGISTRY = MetricsRegistry()


def get_metrics() -> MetricsRegistry:
    return REGISTRY


def snapshot() -> dict:
    return REGISTRY.snapshot()


def read_rss_bytes() -> int:
    """Current resident set in bytes (``/proc`` on Linux, ``ru_maxrss``
    high-water fallback elsewhere)."""
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmRSS:"):
                    return int(line.split()[1]) * 1024
    except OSError:
        pass
    import resource
    ru = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    return int(ru) * 1024          # kB on Linux


class _RssPoller:
    def __init__(self, registry: MetricsRegistry, interval_s: float):
        self._stop = threading.Event()
        self._gauge = registry.gauge("rss_bytes")
        self._interval = interval_s
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="obs-rss-poller")

    def _run(self) -> None:
        while not self._stop.is_set():
            self._gauge.set(float(read_rss_bytes()))
            self._stop.wait(self._interval)

    def stop(self) -> None:
        self._stop.set()
        self._thread.join(timeout=2.0)
        self._gauge.set(float(read_rss_bytes()))    # final sample


def start_rss_poller(interval_s: float = 0.25,
                     registry: MetricsRegistry | None = None) -> _RssPoller:
    """Start the lightweight RSS sampler; returns a handle with
    ``stop()``.  The gauge's ``peak`` field is the observed high-water."""
    p = _RssPoller(registry or REGISTRY, interval_s)
    p._gauge.set(float(read_rss_bytes()))
    p._thread.start()
    return p
