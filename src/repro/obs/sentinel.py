"""Compile counting + the recompile sentinel.

Every jitted tier in the repo relies on the same trick: a Python-side
side effect in the traced function body runs once per DISTINCT trace
signature, so ``counter.mark()`` inside the jit counts compilations
exactly.  Three copies of that trick grew independently
(``foldstats._FixedShapeUpdate``, ``wholebrain._ColumnBlockUpdate``,
``EncoderService``); :class:`CompileCounter` is the one shared
primitive they now all route through.

``mark()`` does three things:

1. bumps ``.count`` (the number every existing gate reads — the public
   aliases ``chunk_update_compile_count`` etc. stay bit-compatible);
2. bumps the global metric ``compiles{tier=<tier>}``;
3. enforces the **recompile sentinel**: inside an ``expect(at_most=N)``
   window, a trace that would push the window's compile delta past N
   raises :class:`RecompileError` AT TRACE TIME (the stack points at
   the recompiling call site) when strict mode is on.

Strict mode is ``REPRO_OBS_STRICT=1`` in the environment — the CI
oocore/wholebrain/fleet lanes set it, turning what used to be scattered
post-hoc ``compile_count == 1`` assertions into a guard that fires at
the moment of the violation.  Off by default: an unexpected recompile
in an exploratory session is a perf bug, not a crash.
"""
from __future__ import annotations

import contextlib
import os

from repro.obs.metrics import get_metrics

__all__ = ["CompileCounter", "RecompileError", "strict_enabled"]


class RecompileError(RuntimeError):
    """A jitted tier compiled more times than its expectation window
    allows (raised at trace time under ``REPRO_OBS_STRICT=1``)."""


def strict_enabled() -> bool:
    return os.environ.get("REPRO_OBS_STRICT", "") == "1"


class CompileCounter:
    """Trace-time compile counter for one jitted tier.

    >>> compiles = CompileCounter("foldstats.chunk_update")
    >>> @partial(jax.jit, static_argnums=...)
    ... def _update(...):
    ...     compiles.mark()          # traced once per distinct signature
    ...     ...
    >>> with compiles.expect(at_most=1):     # the fixed-shape contract
    ...     for chunk in stream: update(chunk)

    ``expect`` windows nest (inner windows shadow outer); the window
    limit is evaluated inside ``mark``, so a violating compile raises
    while JAX is still tracing — under strict mode only.
    """

    def __init__(self, tier: str):
        self.tier = tier
        self.count = 0
        self._limit: int | None = None          # absolute ceiling in-window
        self._metric = get_metrics().counter("compiles", tier=tier)

    def mark(self) -> None:
        """Call from INSIDE the traced function body."""
        self.count += 1
        self._metric.inc()
        if (self._limit is not None and self.count > self._limit
                and strict_enabled()):
            raise RecompileError(
                f"{self.tier}: compile #{self.count} exceeds the expectation "
                f"window (allowed {self._limit}) — a fixed-shape tier is "
                f"retracing (REPRO_OBS_STRICT=1)")

    @contextlib.contextmanager
    def expect(self, at_most: int = 1):
        """Bound compiles inside the ``with`` body to ``at_most`` beyond
        the current count (sentinel active only under strict mode)."""
        prev = self._limit
        self._limit = self.count + at_most
        try:
            yield self
        finally:
            self._limit = prev

    def delta(self, before: int) -> int:
        return self.count - before

    def __int__(self) -> int:
        return self.count

    def __repr__(self) -> str:
        return f"CompileCounter({self.tier!r}, count={self.count})"
