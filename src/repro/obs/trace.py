"""Span tracing — nested, thread-safe, exportable to JSONL and Perfetto.

One process-global tracer slot (``install``/``uninstall``): when empty,
``span()`` costs one module-global load plus returning a shared no-op
singleton — the hot paths stay instrumented permanently without paying
for it.  When a :class:`Tracer` is installed every ``span`` context
records a completed event on exit:

* ``name`` — dotted phase name (``fit.stats``, ``prefetch.wait``, …);
  the naming convention is documented in ``repro.obs`` and in the
  ``repro.encoding`` package docstring.
* ``ts_us``/``dur_us`` — microseconds on the tracer's monotonic clock
  (``time.perf_counter`` based; never wall-clock, so spans order
  correctly across NTP slews).
* ``track`` — a small per-thread integer (0 = first thread seen), and
  ``tid`` the OS thread ident, so concurrent threads render as separate
  tracks in Perfetto.
* ``depth``/``parent`` — nesting within the thread (a thread-local span
  stack), so reports can attribute child time to phases.
* ``attrs`` — user key/values (``bytes=...``, ``tenant=...``).

Export formats:

* ``write_jsonl(path)`` — one JSON object per event line (the format
  ``launch/obs_report.py`` and ``benchmarks/parse_sweep_log.py`` read).
* ``write_perfetto(path)`` — Chrome ``trace_event`` JSON
  (``{"traceEvents": [...]}``, ``ph="X"`` complete events), loadable
  directly in https://ui.perfetto.dev.

``timed(name)`` is the variant the streaming tier uses: it ALWAYS
measures the region (two ``perf_counter`` calls) and exposes ``.dur_s``,
emitting the span only when a tracer is installed — so derived stats
(``PrefetchStats`` stall seconds) and the trace are two views of the
SAME measurement instead of parallel bookkeeping.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any

__all__ = [
    "Tracer", "span", "timed", "instant", "install", "uninstall",
    "current", "write_trace",
]

_tracer: "Tracer | None" = None


class _NullSpan:
    """Shared no-op span: returned when no tracer is installed."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("_tracer", "name", "attrs", "_t0")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self._t0 = 0.0

    def set(self, **attrs):
        """Attach/override attributes mid-span."""
        self.attrs.update(attrs)
        return self

    def __enter__(self):
        tls = self._tracer._tls
        stack = getattr(tls, "stack", None)
        if stack is None:
            stack = tls.stack = []
        stack.append(self)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        tr = self._tracer
        stack = tr._tls.stack
        stack.pop()
        parent = stack[-1].name if stack else None
        tr._record(self.name, self._t0, t1 - self._t0, len(stack),
                   parent, self.attrs)
        return False


class _Timed:
    """Always-measured region; span emitted only if a tracer is live.

    The measured ``dur_s`` is the single source both for derived stats
    (e.g. prefetch stall accounting) and — when tracing is on — for the
    recorded span, so they can never drift apart.
    """

    __slots__ = ("name", "attrs", "_t0", "dur_s")

    def __init__(self, name: str, attrs: dict):
        self.name = name
        self.attrs = attrs
        self.dur_s = 0.0

    def set(self, **attrs):
        self.attrs.update(attrs)
        return self

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        self.dur_s = t1 - self._t0
        tr = _tracer
        if tr is not None:
            stack = getattr(tr._tls, "stack", None)
            depth = len(stack) if stack else 0
            parent = stack[-1].name if stack else None
            tr._record(self.name, self._t0, self.dur_s, depth, parent,
                       self.attrs)
        return False


class Tracer:
    """Thread-safe in-memory span collector on a monotonic clock."""

    def __init__(self):
        self._lock = threading.Lock()
        self._events: list[dict] = []
        self._tls = threading.local()
        self._tracks: dict[int, int] = {}
        self._epoch = time.perf_counter()
        self.pid = os.getpid()

    # -- recording ---------------------------------------------------------
    def span(self, name: str, **attrs: Any) -> _Span:
        return _Span(self, name, attrs)

    def _track_id(self, ident: int) -> int:
        tid = self._tracks.get(ident)
        if tid is None:
            tid = self._tracks[ident] = len(self._tracks)
        return tid

    def _record(self, name: str, t0: float, dur_s: float, depth: int,
                parent: str | None, attrs: dict) -> None:
        ident = threading.get_ident()
        with self._lock:
            self._events.append({
                "name": name,
                "ts_us": round((t0 - self._epoch) * 1e6, 3),
                "dur_us": round(dur_s * 1e6, 3),
                "track": self._track_id(ident),
                "tid": ident,
                "depth": depth,
                "parent": parent,
                "attrs": attrs,
            })

    def instant(self, name: str, **attrs: Any) -> None:
        """Record a zero-duration marker event (Perfetto ``ph="i"``)."""
        ident = threading.get_ident()
        stack = getattr(self._tls, "stack", None)
        with self._lock:
            self._events.append({
                "name": name,
                "ts_us": round((time.perf_counter() - self._epoch) * 1e6, 3),
                "dur_us": 0.0,
                "track": self._track_id(ident),
                "tid": ident,
                "depth": len(stack) if stack else 0,
                "parent": stack[-1].name if stack else None,
                "attrs": attrs,
                "instant": True,
            })

    # -- reading / export --------------------------------------------------
    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def write_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for ev in self.events():
                f.write(json.dumps(ev) + "\n")

    def to_perfetto(self) -> dict:
        """Chrome/Perfetto ``trace_event`` document (``ph="X"`` complete
        events, instants as ``ph="i"``)."""
        out = []
        for ev in self.events():
            rec = {"name": ev["name"], "cat": ev["name"].split(".")[0],
                   "ph": "i" if ev.get("instant") else "X",
                   "ts": ev["ts_us"], "pid": self.pid, "tid": ev["track"],
                   "args": dict(ev["attrs"], depth=ev["depth"])}
            if not ev.get("instant"):
                rec["dur"] = ev["dur_us"]
            else:
                rec["s"] = "t"
            out.append(rec)
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def write_perfetto(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_perfetto(), f)
            f.write("\n")


def install(tracer: "Tracer | None" = None) -> Tracer:
    """Install (and return) the process-global tracer."""
    global _tracer
    if tracer is None:
        tracer = Tracer()
    _tracer = tracer
    return tracer


def uninstall() -> None:
    global _tracer
    _tracer = None


def current() -> "Tracer | None":
    return _tracer


def span(name: str, **attrs: Any):
    """Open a (context-manager) span — a shared no-op when no tracer is
    installed, so permanently instrumented hot paths cost one module
    attribute load on the disabled path."""
    t = _tracer
    if t is None:
        return _NULL_SPAN
    return t.span(name, **attrs)


def timed(name: str, **attrs: Any) -> _Timed:
    """Always-measured region (see module docstring): ``.dur_s`` is valid
    whether or not a tracer is installed."""
    return _Timed(name, attrs)


def instant(name: str, **attrs: Any) -> None:
    """Zero-duration marker (admit/reject/hit events)."""
    t = _tracer
    if t is not None:
        t.instant(name, **attrs)


def write_trace(tracer: Tracer, path: str) -> str:
    """Write ``tracer`` to ``path`` — Perfetto ``trace_event`` JSON when
    the suffix is ``.json``, JSONL otherwise.  Returns the format used."""
    if path.endswith(".json"):
        tracer.write_perfetto(path)
        return "perfetto"
    tracer.write_jsonl(path)
    return "jsonl"
