"""repro.obs — the unified observability layer (tracing, metrics, sentinels).

Zero-dependency (stdlib only), disabled by default, and shared by every
tier: the fit path (``BrainEncoder``/``foldstats``), the streaming tier
(``ChunkPrefetcher``), the whole-brain column-blocked driver, and the
serving fleet (``EncoderService``/``EncoderRegistry``/``FleetFrontend``)
all emit through the SAME three primitives instead of bespoke stat dicts:

* **Spans** — ``with obs.span("fit.stats", bytes=n): ...`` nests, is
  thread-safe, stamps a monotonic clock, and exports to JSONL or
  Chrome/Perfetto ``trace_event`` JSON (``obs.write_trace``).  With no
  tracer installed the call returns a shared no-op after one module
  attribute load — hot paths stay permanently instrumented.
  ``obs.timed`` additionally ALWAYS measures (the streaming tier derives
  ``PrefetchStats`` stall seconds from the same measurement the span
  records).  ``obs.instant`` records zero-duration markers
  (admit/reject/hit).
* **Metrics** — ``obs.get_metrics()`` returns the process-global
  :class:`~repro.obs.metrics.MetricsRegistry`; ``obs.snapshot()`` renders
  every counter/gauge/histogram into one JSON dict (schema below) that
  ``stream_stats_``, ``ServiceStats.to_dict``, ``PrefetchStats.to_dict``
  and the ``BENCH_*.json`` rows embed.
* **Compile sentinels** — :class:`~repro.obs.sentinel.CompileCounter` is
  the one trace-time compile counter behind
  ``foldstats.chunk_update_compile_count``,
  ``wholebrain.colblock_update_compile_count`` and
  ``EncoderService.compile_count``; ``counter.expect(at_most=N)`` windows
  raise :class:`~repro.obs.sentinel.RecompileError` at trace time under
  ``REPRO_OBS_STRICT=1`` when a fixed-shape tier retraces.

Span naming convention: dotted ``<tier>.<phase>[.<subphase>]`` —
``fit.dispatch`` / ``fit.stats`` / ``fit.eigh`` / ``fit.solve``,
``prefetch.stage`` / ``prefetch.wait`` / ``prefetch.compute_stall``,
``wholebrain.block`` / ``wholebrain.xstats``, ``serve.wave.build`` /
``serve.wave.execute``, ``registry.load`` / ``registry.evict`` /
``registry.hit``, ``fleet.admit`` / ``fleet.reject`` / ``fleet.flush``.

Metrics-snapshot schema (``obs.snapshot()``; version ``repro.obs/v1``)
----------------------------------------------------------------------

====================================  =========  ==========================================
key                                   type       meaning
====================================  =========  ==========================================
``schema``                            str        ``"repro.obs/v1"``
``counters``                          dict       flat ``name{label=v,...} -> float``
``gauges``                            dict       ``key -> {"value", "peak"}``
``histograms``                        dict       ``key -> {"count","sum","min","max","mean"}``
====================================  =========  ==========================================

Well-known instruments (all optional — present once the producing tier ran):

====================================  =========  ==========================================
instrument                            type       producer
====================================  =========  ==========================================
``compiles{tier=...}``                counter    every ``CompileCounter.mark`` (tiers:
                                                 ``foldstats.chunk_update``,
                                                 ``wholebrain.colblock_update``,
                                                 ``wholebrain.gram``, ``service.predict``)
``bytes_staged``                      counter    prefetcher staging copies (bytes)
``chunks_staged``                     counter    prefetcher chunks staged
``read_stall_s`` / ``compute_stall_s``  counter  prefetcher stall seconds (consumer /
                                                 producer side)
``wave_pad_rows`` / ``wave_rows``     counter    serving pad vs real rows per wave
``waves``                             counter    compiled predict waves executed
``tenant_rows{tenant=...}``           counter    per-tenant served rows
``registry_hits`` / ``registry_loads``  counter  bundle cache hits / cold loads
``registry_evictions``                counter    LRU + fault evictions
``admitted_rows`` / ``rejected_requests``  counter  fleet admission outcomes
``io_retries{op=...}``                counter    transient faults retried by
                                                 ``resilience.retry_call`` (ops:
                                                 ``store.mmap``, ``prefetch.read``,
                                                 ``registry.load_encoder`` /
                                                 ``load_shard`` / ``load_std``)
``io_giveups{op=...}``                counter    retry budget exhausted — the original
                                                 error re-raised (typed at the caller)
``staging_reaped``                    counter    stale staging orphans swept by
                                                 ``resilience.reap_stale_staging``
``lease_expirations``                 counter    dead-worker leases reaped by
                                                 ``ResidencyMap.expire_dead``
``requests_replayed``                 counter    requests re-admitted after a
                                                 ``WorkerLost`` flush
``rss_bytes``                         gauge      resident set (background poller;
                                                 ``peak`` = observed high-water)
====================================  =========  ==========================================

Stats ``to_dict()`` payloads (``PrefetchStats``, ``ServiceStats``, and the
``stream_stats_`` dict) carry ``{"schema": "repro.obs/v1", "kind": ...}``
plus their flat snake_case fields — benches consume those dicts, never
raw attributes.

Surfacing: ``launch/encode.py``, ``launch/wholebrain.py`` and
``launch/serve.py`` accept ``--trace-out PATH`` (``.json`` → Perfetto,
else JSONL) and ``--metrics-out PATH``; ``launch/obs_report.py`` renders
a per-phase time/bytes table from a JSONL trace and can gate span
coverage (``--assert-coverage``).
"""
from repro.obs.metrics import (  # noqa: F401
    REGISTRY, SCHEMA_VERSION, Counter, Gauge, Histogram, MetricsRegistry,
    get_metrics, read_rss_bytes, snapshot, start_rss_poller,
)
from repro.obs.sentinel import (  # noqa: F401
    CompileCounter, RecompileError, strict_enabled,
)
from repro.obs.trace import (  # noqa: F401
    Tracer, current, install, instant, span, timed, uninstall, write_trace,
)

__all__ = [
    "Tracer", "span", "timed", "instant", "install", "uninstall", "current",
    "write_trace",
    "MetricsRegistry", "Counter", "Gauge", "Histogram", "REGISTRY",
    "get_metrics", "snapshot", "start_rss_poller", "read_rss_bytes",
    "SCHEMA_VERSION",
    "CompileCounter", "RecompileError", "strict_enabled",
]
