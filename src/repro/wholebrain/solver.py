"""Column-blocked CV ridge driver — Eq. 5 mutualisation across target blocks.

``ridge.ridge_cv_from_stats`` already mutualises the expensive per-fold
eigendecompositions across all targets and all λ — but it consumes a full
``(k, p, t)`` statistics tensor.  This driver extends the mutualisation
across TARGET BLOCKS: the ``k+1`` eigendecompositions of the downdated
Grams depend only on ``X`` and are computed once (from the shared X-only
pass), then reused for every column block; each block's ``(k, p, t_block)``
statistics stream through ``ColumnBlockAccumulator`` and are scored
against the hoisted eigenbases via ``validation_scores_per_target``.

Two λ-selection modes:

* ``"global"`` (default) — one λ for ALL targets, the unblocked
  ``ridge_cv_from_stats`` contract.  Per-column validation scores are
  aggregated on the host in float64 in global column order (so the
  aggregate is invariant to the blocking), and the final weights are
  produced per block from the block's eigenbasis projection
  ``Â_b = Qᵀ C_total[:, block]`` stashed in an on-disk float32 scratch
  during the single statistics pass — no second pass over the rows.  λ
  and ``W`` are bit-identical to the unblocked path (the invariance
  harness's gate): every per-block contraction runs at one fixed padded
  width and XLA's column-blocked GEMMs are bitwise column slices of the
  full-width ones.
* ``"per_block"`` — one λ per target block, the B-MOR semantics of
  Alg. 1 line 13 carried to the streaming tier: each block's CV curve is
  scored and argmaxed exactly as ``ridge_cv_from_stats`` would on the
  block-restricted statistics, and its weights are solved at the block's
  own λ in the same single pass.

Peak memory: ``O(p² + p·t_block)`` device + the scratch/weight shards on
disk — independent of ``t``.  ``Y`` is streamed exactly once, each block
faulting in only its own column pages; ``X`` is streamed ONCE when the
single-X-pass composition engages (the X-only statistics ride the first
block's stream and a chunk-granular host cache replays the feature rows
for later blocks — ``n·p`` is the SMALL axis in the whole-brain regime),
spilling to a once-per-block prefetcher re-stream only when the cache
breaks the memory budget (telemetry: ``row_passes_x``).
"""
from __future__ import annotations

import dataclasses
import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp

from repro import obs
from repro.core import foldstats
from repro.encoding.config import EncoderConfig
from repro.wholebrain.stats import (
    ColumnBlockAccumulator, colblock_update_compile_count,
    colblock_update_compiles, column_blocks,
)


@dataclasses.dataclass
class WholebrainResult:
    """Fit result of the column-blocked driver.

    ``best_lambda``/``cv_scores`` follow the ``EncodingReport`` batch
    convention: one row per λ-selection batch — shape ``(1,)``/``(1, r)``
    in global mode, ``(n_blocks,)``/``(n_blocks, r)`` per block.
    ``weights`` is the assembled host ``(p, t)`` float32 matrix when the
    fit collected it, ``None`` when every shard went to a writer instead.
    """

    best_lambda: np.ndarray            # (n_batches,) float64
    cv_scores: np.ndarray              # (n_batches, r) float64
    lambdas: tuple[float, ...]
    lambda_mode: str                   # "global" | "per_block"
    t_block: int
    block_bounds: list[tuple[int, int]]
    lambda_by_target: np.ndarray       # (t,) float64, from the REAL bounds
    weights: np.ndarray | None
    telemetry: dict


def _stream_stats(agg: dict, stream) -> None:
    s = getattr(stream, "stats", None)
    if s is None:
        return
    d = s.to_dict()
    agg["chunks"] += d["chunks"]
    agg["bytes_staged"] += d["bytes_staged"]
    agg["read_stall_s"] += d["read_stall_s"]
    agg["compute_stall_s"] += d["compute_stall_s"]


class _XChunkCache:
    """Chunk-granular host cache of the ``X`` rows seen in one stream.

    Filled during the fused first-block pass (the staging buffers of the
    prefetcher recycle, so each chunk is copied out at stream granularity
    into one contiguous ``(n, p)`` host array); subsequent target blocks
    replay the identical chunk partition from it and re-stream only their
    ``Y`` columns (``iter_chunks(col_range_x=(0, 0))``) — zero further
    reads of the feature shards.
    """

    def __init__(self, n: int, p: int, dtype) -> None:
        self._arr = np.empty((n, p), dtype)
        self._fill = 0
        self._chunk_ends: list[int] = []

    @property
    def nbytes(self) -> int:
        return self._arr.nbytes

    def append(self, Xc: np.ndarray) -> None:
        m = Xc.shape[0]
        self._arr[self._fill:self._fill + m] = Xc
        self._fill += m
        self._chunk_ends.append(self._fill)

    def chunks(self):
        """Read-only views replaying the captured chunk partition."""
        lo = 0
        for hi in self._chunk_ends:
            v = self._arr[lo:hi].view()
            v.flags.writeable = False
            yield v
            lo = hi

    @staticmethod
    def fits(n: int, p: int, itemsize: int, budget: int | None) -> bool:
        """Cache policy: the whole-brain regime is p ≪ t, so ``n·p`` is
        the small axis — cache it whenever it takes at most a quarter of
        the device-memory budget (the budget bounds the DEVICE working
        set; the host cache rides in the same envelope so the launch-layer
        RSS caps keep binding), or always when no budget was set."""
        return budget is None or n * p * itemsize <= budget // 4


def journal_signature(store, cfg: EncoderConfig | None = None, *,
                      t_block: int | None = None,
                      lambda_mode: str = "global",
                      chunk_rows: int | None = None) -> dict:
    """The ``FitJournal`` signature ``fit_wholebrain`` would compute for
    these arguments — every input that shapes the bits of λ/W.  Callers
    that attach a journal themselves (e.g. to wrap it with the
    fault-injection harness's ``KillAfterBlock``) MUST build it from
    here so the solver accepts the attached journal."""
    cfg = cfg or EncoderConfig()
    n, p, t = store.shape
    t_block = t_block or getattr(cfg, "target_block", None)
    return {
        "n": int(n), "p": int(p), "t": int(t), "k": int(cfg.n_folds),
        "t_block": int(t_block), "lambda_mode": lambda_mode,
        "chunk_rows": int(min(chunk_rows or cfg.chunk_rows, n)),
        "lambdas": [float(l) for l in cfg.lambdas],
        "scoring": cfg.scoring,
        "use_pallas": bool(cfg.resolve_use_pallas()),
    }


def _check_target_scale(bstats, n_total: int, lo: int, hi: int) -> None:
    """The row tier's un-standardized-target refusal, per block (see
    ``BrainEncoder._fit_from_stats``): statistics-based CV scoring loses
    f32 precision quadratically in |ȳ|/σ_y."""
    w = hi - lo
    mu = np.asarray(jnp.sum(bstats.ysum, axis=0))[:w] / n_total
    var = np.asarray(jnp.sum(bstats.ysq, axis=0))[:w] / max(n_total - 1, 1)
    ratio = float(np.max(np.abs(mu) / np.sqrt(var + 1e-12)))
    if ratio > 1e3:
        raise ValueError(
            f"wholebrain fit: target mean/std ratio {ratio:.0f} in columns "
            f"[{lo}, {hi}) is too large for statistics-based CV scoring in "
            f"float32 — standardize the targets first")


def fit_wholebrain(store, cfg: EncoderConfig | None = None, *,
                   t_block: int | None = None,
                   lambda_mode: str = "global",
                   chunk_rows: int | None = None,
                   writer=None, collect: bool | None = None,
                   scratch_dir: str | None = None,
                   journal=None) -> WholebrainResult:
    """Column-blocked streaming CV ridge over a ``RunStore``.

    ``writer`` (any object with ``append(W_block)``, e.g.
    ``wholebrain.artifact.BundleWriter``) receives the ``(p, w)`` float32
    weight shards in block order as they finish — the streaming-save path
    where the full ``(p, t)`` matrix never exists in memory.  Without a
    writer, ``collect=True`` (the default then) assembles the host
    weight matrix.  ``scratch_dir`` hosts the global-mode ``Â`` scratch
    memmap (default: alongside the writer's staging dir, else a tempdir).

    ``journal`` makes the fit resumable (``repro.resilience``): a
    directory path (or an attached ``FitJournal`` whose signature matches
    :func:`journal_signature`) where the X-stats pass and every completed
    column block are committed as they finish.  A fit killed mid-stream
    and re-run with the same journal replays the committed statistics —
    never re-accumulating them — streams only the remaining blocks, and
    produces λ and W **bit-identical** to an uninterrupted run.  On
    success the journal directory is deleted.

    The whole fit runs under a ``fit.wholebrain`` root span (children:
    ``wholebrain.xstats``, ``wholebrain.block``, ``fit.eigh``,
    ``fit.solve``) with the strict recompile sentinel armed at one trace
    per tier for the full run — every block shares the gram and
    column-block compiled updates.
    """
    n, p, t = store.shape
    with obs.span("fit.wholebrain", n=n, p=p, t=t,
                  lambda_mode=lambda_mode), \
         foldstats.chunk_update_compiles().expect(at_most=1), \
         colblock_update_compiles().expect(at_most=1):
        return _fit_wholebrain(store, cfg, t_block=t_block,
                               lambda_mode=lambda_mode,
                               chunk_rows=chunk_rows, writer=writer,
                               collect=collect, scratch_dir=scratch_dir,
                               journal=journal)


def _fit_wholebrain(store, cfg: EncoderConfig | None = None, *,
                    t_block: int | None = None,
                    lambda_mode: str = "global",
                    chunk_rows: int | None = None,
                    writer=None, collect: bool | None = None,
                    scratch_dir: str | None = None,
                    journal=None) -> WholebrainResult:
    cfg = cfg or EncoderConfig()
    if cfg.solver not in ("auto", "ridge"):
        raise ValueError(f"wholebrain fit supports only the ridge solver; "
                         f"solver={cfg.solver!r} is pinned")
    if cfg.method == "dual" or cfg.bands is not None:
        raise ValueError("wholebrain fit is primal/eigh only (streamed "
                         "statistics cannot build the dual kernel or bands)")
    if lambda_mode not in ("global", "per_block"):
        raise ValueError(f"lambda_mode must be 'global' or 'per_block', "
                         f"got {lambda_mode!r}")
    k_store = getattr(store, "n_folds", None)
    if k_store is not None and k_store != cfg.n_folds:
        raise ValueError(f"store manifest records n_folds={k_store} but the "
                         f"config says n_folds={cfg.n_folds}")
    n, p, t = store.shape
    t_block = t_block or getattr(cfg, "target_block", None)
    if t_block is None:
        raise ValueError("pass t_block= (or set EncoderConfig.target_block)")
    bounds = column_blocks(t, t_block)
    t_pad = bounds[0][1] - bounds[0][0]
    k = cfg.n_folds
    r = len(cfg.lambdas)
    chunk_rows = min(chunk_rows or cfg.chunk_rows, n)
    if collect is None:
        collect = writer is None

    use_pallas = cfg.resolve_use_pallas()
    agg = {"chunks": 0, "bytes_staged": 0, "read_stall_s": 0.0,
           "compute_stall_s": 0.0}
    fixed0 = foldstats.chunk_update_compile_count()
    colblock0 = colblock_update_compile_count()
    dtype_x = getattr(store, "dtype_x", np.dtype(np.float32))

    # -- progress journal (repro.resilience): attach / validate ---------------
    jrn = None
    if journal is not None:
        from repro.resilience.journal import FitJournal, JournalError
        signature = journal_signature(store, cfg, t_block=t_block,
                                      lambda_mode=lambda_mode,
                                      chunk_rows=chunk_rows)
        if isinstance(journal, (str, os.PathLike)):
            jrn = FitJournal.attach(os.fspath(journal), signature)
        else:
            jrn = journal
            if getattr(jrn, "signature", None) != signature:
                raise JournalError(
                    f"attached journal signature {jrn.signature} does not "
                    f"match this fit's {signature}")
    done: set[int] = jrn.completed_blocks() if jrn is not None else set()
    resumed = jrn is not None and jrn.has_xstats
    # Highest block index that will actually STREAM this run — a rebuilt
    # X cache only pays off if more streamed blocks follow.
    last_streamed = max((i for i in range(len(bounds)) if i not in done),
                       default=-1)

    if not resumed:
        # -- fused first pass: the X-only statistics (G/xsum/count,
        # zero-width Y window — same compiled signature as a standalone X
        # pass) ride the FIRST target block's stream, so they cost no row
        # pass of their own.  When the (n, p) feature rows fit the cache
        # policy they are also captured chunk-by-chunk, and every later
        # block re-streams only its own Y columns — row passes over X drop
        # from 1 + ceil(t/t_block) to 1 (cached) or ceil(t/t_block)
        # (spilled to the prefetcher re-stream).
        lo0, hi0 = bounds[0]
        with obs.span("wholebrain.xstats", rows=n, fused_block=0) as xsp:
            gacc = foldstats.FoldStatsAccumulator(n, k, chunk_rows=chunk_rows,
                                                  use_pallas=use_pallas)
            bacc0 = ColumnBlockAccumulator(n, k, t_pad, chunk_rows=chunk_rows,
                                           use_pallas=use_pallas)
            x_cache = None
            if len(bounds) > 1 and _XChunkCache.fits(n, p, dtype_x.itemsize,
                                                     cfg.device_memory_budget):
                x_cache = _XChunkCache(n, p, dtype_x)
            xsp.set(cached=x_cache is not None)
            stream = store.iter_chunks(chunk_rows, col_range=(lo0, hi0),
                                       prefetch=cfg.prefetch,
                                       prefetch_depth=cfg.prefetch_depth)
            try:
                for Xc, Yc in stream:
                    gacc.update(Xc, Yc[:, :0])
                    bacc0.update(Xc, Yc)
                    if x_cache is not None:
                        x_cache.append(np.asarray(Xc))
            finally:
                if hasattr(stream, "close"):
                    stream.close()
            _stream_stats(agg, stream)
            xsp.set(bytes_staged=agg["bytes_staged"])
            gstats = gacc.finalize()
            block0_stats = bacc0.finalize()
        if jrn is not None:
            jrn.put_xstats(np.asarray(gstats.G), np.asarray(gstats.xsum),
                           np.asarray(gstats.count))
    else:
        # -- resume: REPLAY the journaled X statistics (never
        # re-accumulate — the f32 arrays on disk are the exact bytes the
        # killed fit produced, so the recomputed eighs, and everything
        # downstream of them, match bitwise).  The X chunk cache died
        # with the old process; the first streamed block rebuilds it.
        with obs.span("wholebrain.xstats", rows=n, replayed=True):
            G_j, xsum_j, count_j = jrn.load_xstats()
            zero_y = jnp.zeros((k, 0), jnp.float32)
            gstats = foldstats.FoldStats(
                G=jnp.asarray(G_j), C=jnp.zeros((k, p, 0), jnp.float32),
                xsum=jnp.asarray(xsum_j), ysum=zero_y, ysq=zero_y,
                count=jnp.asarray(count_j))
        block0_stats = None
        x_cache = None

    # -- hoisted factorisations: k downdated eighs + the refit, once ---------
    # (the paper's Eq. 5 mutualisation extended across blocks: these depend
    # only on X, so every target block reuses them).
    with obs.span("fit.eigh", folds=k, p=p):
        eye = cfg.jitter * jnp.eye(p, dtype=jnp.float32)
        lams = jnp.asarray(cfg.lambdas, dtype=jnp.float32)
        fold_eigs = []
        for f in range(k):
            G_tr, _ = gstats.train(f)
            evals_f, Q_f = jnp.linalg.eigh(G_tr + eye)
            fold_eigs.append((evals_f, Q_f))
        evals_R, Q_R = jnp.linalg.eigh(gstats.G_total + eye)
        # Forcing only under tracing: honest eigh wall attribution without
        # changing the async dispatch semantics of an untraced fit.
        if obs.current() is not None:
            jax.block_until_ready(Q_R)

    W_full = np.empty((p, t), np.float32) if collect else None
    scratch = None
    scratch_path = None
    tmp_holder = None
    per_block_lams: list[float] = []
    per_block_curves: list[np.ndarray] = []
    score_sum = np.zeros((k, r), np.float64)     # global: Σ_cols per fold

    try:
        if lambda_mode == "global":
            base = scratch_dir or getattr(writer, "scratch_dir", None)
            if base is None:
                tmp_holder = tempfile.mkdtemp(prefix="wholebrain_scratch_")
                base = tmp_holder
            scratch_path = os.path.join(base, "ahat.npy")
            scratch = np.lib.format.open_memmap(
                scratch_path, mode="w+", dtype=np.float32, shape=(p, t))

        # -- per-block pass: stream the block's columns, score every fold ----
        # (block 0 was accumulated in the fused first pass above; later
        # blocks read X from the chunk cache when it was captured, else
        # re-stream the full rows through the prefetcher.  Journaled
        # blocks from a killed fit are REPLAYED — their committed scores/
        # projections are re-applied in block order, bitwise.)
        restreamed_x = 0
        blocks_replayed = 0
        for bi, (lo, hi) in enumerate(bounds):
            if jrn is not None and bi in done:
                with obs.span("wholebrain.block", block=bi, lo=lo, hi=hi,
                              replayed=True):
                    rec = jrn.load_block(bi)
                    blocks_replayed += 1
                    if lambda_mode == "global":
                        # Same f64 addends in the same block order as the
                        # killed fit — the running sum stays bitwise equal.
                        score_sum += rec["scores"]
                        scratch[:, lo:hi] = rec["ahat"]
                    else:
                        per_block_lams.append(rec["lam"])
                        per_block_curves.append(rec["curve"])
                        Wb = rec["W"]
                        if collect:
                            W_full[:, lo:hi] = Wb
                        if writer is not None:
                            writer.append(Wb)
                continue
            with obs.span("wholebrain.block", block=bi, lo=lo, hi=hi) as bsp:
                bytes0 = agg["bytes_staged"]
                w = hi - lo
                if bi == 0 and block0_stats is not None:
                    bstats = block0_stats
                else:
                    bacc = ColumnBlockAccumulator(n, k, t_pad,
                                                  chunk_rows=chunk_rows,
                                                  use_pallas=use_pallas)
                    if x_cache is not None:
                        # Y-only store pass (zero feature-shard bytes) zipped
                        # with the cache's replay of the identical chunk
                        # partition.
                        stream = store.iter_chunks(
                            chunk_rows, col_range=(lo, hi), col_range_x=(0, 0),
                            prefetch=cfg.prefetch,
                            prefetch_depth=cfg.prefetch_depth)
                        try:
                            for Xc, (_, Yc) in zip(x_cache.chunks(), stream):
                                bacc.update(Xc, Yc)
                        finally:
                            if hasattr(stream, "close"):
                                stream.close()
                        _stream_stats(agg, stream)
                        bstats = bacc.finalize()
                    else:
                        restreamed_x += 1
                        # Re-streaming the full rows anyway — capture the
                        # X chunks when more streamed blocks follow and
                        # the cache policy admits them (the resume path's
                        # cache rebuild; a no-op pre-crash, where a
                        # fitting cache was captured in the fused pass).
                        capture = None
                        if bi < last_streamed and _XChunkCache.fits(
                                n, p, dtype_x.itemsize,
                                cfg.device_memory_budget):
                            capture = _XChunkCache(n, p, dtype_x)
                        stream = store.iter_chunks(
                            chunk_rows, col_range=(lo, hi),
                            prefetch=cfg.prefetch,
                            prefetch_depth=cfg.prefetch_depth)
                        try:
                            for Xc, Yc in stream:
                                bacc.update(Xc, Yc)
                                if capture is not None:
                                    capture.append(np.asarray(Xc))
                        finally:
                            if hasattr(stream, "close"):
                                stream.close()
                        _stream_stats(agg, stream)
                        bstats = bacc.finalize()
                        if capture is not None:
                            x_cache = capture
                _check_target_scale(bstats, n, lo, hi)
                # Grafted onto the shared statistics this is a full FoldStats
                # restricted (bitwise) to the block's columns.
                full = foldstats.FoldStats(
                    G=gstats.G, C=bstats.C, xsum=gstats.xsum,
                    ysum=bstats.ysum, ysq=bstats.ysq, count=gstats.count)
                fold_scores = []
                contrib = np.zeros((k, r), np.float64)   # this block's Σ_cols
                for f in range(k):
                    evals_f, Q_f = fold_eigs[f]
                    _, C_tr = full.train(f)
                    s_rt = foldstats.validation_scores_per_target(
                        full, f, Q_f, evals_f, C_tr, lams, cfg.scoring)
                    if lambda_mode == "global":
                        # Host f64 accumulation in global column order — the
                        # aggregate is independent of the blocking.
                        contrib[f] = np.asarray(
                            s_rt[:, :w], np.float64).sum(axis=1)
                        score_sum[f] += contrib[f]
                    else:
                        fold_scores.append(jnp.mean(s_rt[:, :w], axis=1))
                C_total_b = full.C_total                      # (p, t_pad)
                if lambda_mode == "global":
                    # Stash the refit eigenbasis projection of the block — the
                    # only per-block quantity the final solve needs, computed
                    # HERE so λ selection costs no second pass over the rows.
                    Ahat = jnp.matmul(Q_R.T, C_total_b,
                                      preferred_element_type=jnp.float32)
                    Ahat_w = np.asarray(Ahat)[:, :w]
                    scratch[:, lo:hi] = Ahat_w
                    if jrn is not None:
                        jrn.put_block(bi, scores=contrib, ahat=Ahat_w)
                else:
                    # ridge_cv_from_stats on the block-restricted statistics,
                    # with the factorisations hoisted: same ops, same bits.
                    cv_b = jnp.mean(jnp.stack(fold_scores), axis=0)
                    best_b = int(jnp.argmax(cv_b))
                    lam_b = float(np.asarray(lams)[best_b])
                    z = jnp.matmul(Q_R.T, C_total_b,
                                   preferred_element_type=jnp.float32)
                    z = z / (evals_R + lams[best_b])[:, None]
                    Wb = jnp.matmul(Q_R, z,
                                    preferred_element_type=jnp.float32)[:, :w]
                    per_block_lams.append(lam_b)
                    per_block_curves.append(np.asarray(cv_b, np.float64))
                    Wb = np.asarray(Wb)
                    if jrn is not None:
                        jrn.put_block(bi, lam=lam_b,
                                      curve=np.asarray(cv_b, np.float64),
                                      W=Wb)
                    if collect:
                        W_full[:, lo:hi] = Wb
                    if writer is not None:
                        writer.append(Wb)
                bsp.set(bytes_staged=agg["bytes_staged"] - bytes0)

        scratch_bytes = 0
        if lambda_mode == "global":
            cv_scores = (score_sum / t).mean(axis=0)          # (r,) f64
            best = int(np.argmax(cv_scores))
            lam = float(np.asarray(lams)[best])
            # -- weight pass: read each block's Â back, diagonal solve -------
            # (padded back to t_pad so the final GEMM stays a bitwise
            # column slice of the unblocked solve, even on a ragged tail).
            with obs.span("fit.solve", p=p, blocks=len(bounds)):
                scratch.flush()
                for lo, hi in bounds:
                    w = hi - lo
                    Ab = np.zeros((p, t_pad), np.float32)
                    Ab[:, :w] = scratch[:, lo:hi]
                    z = jnp.asarray(Ab) / (evals_R + lams[best])[:, None]
                    Wb = jnp.matmul(Q_R, z,
                                    preferred_element_type=jnp.float32)[:, :w]
                    Wb = np.asarray(Wb)
                    if collect:
                        W_full[:, lo:hi] = Wb
                    if writer is not None:
                        writer.append(Wb)
            scratch_bytes = p * t * 4
            best_lambda = np.asarray([lam], np.float64)
            curves = cv_scores[None, :]
            lam_t = np.full((t,), lam, np.float64)
        else:
            best_lambda = np.asarray(per_block_lams, np.float64)
            curves = np.stack(per_block_curves)
            # λ per target from the REAL block bounds (the ceil-repeat
            # expansion in serving_encoders.bundle assumes equal blocks).
            lam_t = np.empty((t,), np.float64)
            for lam_b, (lo, hi) in zip(per_block_lams, bounds):
                lam_t[lo:hi] = lam_b
    finally:
        if scratch is not None:
            del scratch                          # unmap before unlink
        if scratch_path is not None and os.path.exists(scratch_path):
            os.unlink(scratch_path)
        if tmp_holder is not None:
            import shutil
            shutil.rmtree(tmp_holder, ignore_errors=True)

    telemetry = {
        **agg,
        "n_blocks": len(bounds),
        "t_block": t_block,
        "t_pad": t_pad,
        "eighs": k + 1,
        "gram_compile_delta": foldstats.chunk_update_compile_count() - fixed0,
        "colblock_compile_delta": (colblock_update_compile_count()
                                   - colblock0),
        "scratch_bytes": scratch_bytes if lambda_mode == "global" else 0,
        # 1 fused first pass (absent on resume) + any blocks that had to
        # re-stream the feature shards because the X chunk cache was not
        # captured (or died with the killed fit).
        "row_passes_x": (0 if resumed else 1) + restreamed_x,
        "row_passes_y": 1,
        "x_cache_bytes": 0 if x_cache is None else x_cache.nbytes,
        "use_pallas": use_pallas,
        "resumed": resumed,
        "blocks_replayed": blocks_replayed,
        "blocks_streamed": len(bounds) - blocks_replayed,
    }
    if jrn is not None:
        jrn.finish()
    return WholebrainResult(
        best_lambda=best_lambda, cv_scores=np.asarray(curves, np.float64),
        lambdas=cfg.lambdas, lambda_mode=lambda_mode, t_block=t_block,
        block_bounds=bounds, lambda_by_target=lam_t,
        weights=W_full, telemetry=telemetry)


__all__ = ["WholebrainResult", "fit_wholebrain", "journal_signature"]
