"""Streaming bundle writes — shards land as blocks finish, one atomic commit.

``serving_encoders.bundle.save_bundle`` serialises a fitted encoder whose
full ``(p, t)`` weight matrix is already in memory.  At whole-brain scale
that matrix never exists: the column-blocked solver emits one ``(p, w)``
shard per target block.  ``BundleWriter`` accepts those shards
incrementally — each ``append`` writes one ``.npy`` leaf into a hidden
staging directory — and ``commit`` writes the metadata leaves, the
checkpoint manifest, and ``bundle.json``, then atomically renames the
staging directory into place.  A crash at ANY point before the rename
leaves no bundle (the staging dir is hidden and removed by ``abort``/
``__exit__``); after it, a complete one.

The committed layout is byte-compatible with ``save_bundle``'s: the same
``bundle.json`` schema, the same ``step_0/`` leaf naming, the same bf16-
as-uint16 storage.  ``EncoderBundle.open`` validates it identically and
``load_encoder``/``load_weight_shard`` read it identically — the serving
tier cannot tell which writer produced a bundle.  One deliberate upgrade:
``lambda_by_target`` is expanded from the writer's ACTUAL shard bounds
(the eager path's ceil-repeat expansion assumes equal blocks, which a
ragged-tail blocking violates).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile

import numpy as np

from repro.checkpoint import io as ckpt_io
from repro.resilience import cleanup
from repro.serving_encoders.bundle import (
    BUNDLE_MANIFEST, _BUNDLE_VERSION, BundleError, _shard_key, config_to_dict,
)


class BundleWriter:
    """Incremental, atomic ``EncoderBundle`` writer.

    Usage::

        with BundleWriter(path, p=p, t=t, overwrite=True) as w:
            fit_wholebrain(store, cfg, t_block=tb, writer=w)
            w.commit(config=cfg, report=report, lambda_by_target=lam_t)

    ``append`` may be called from the solver as each block finishes; the
    shard hits disk immediately, so peak memory stays ``O(p·t_block)``.
    Leaving the ``with`` without a ``commit`` aborts (staging removed).
    """

    def __init__(self, bundle_dir: str, *, p: int, t: int,
                 weight_dtype: str | np.dtype = "float32",
                 overwrite: bool = False):
        # Refuse BEFORE staging, like save_bundle (re-checked at commit).
        if os.path.exists(bundle_dir) and not overwrite:
            raise BundleError(f"bundle already exists at {bundle_dir}; "
                              f"pass overwrite=True to replace it")
        self.bundle_dir = bundle_dir
        self.p, self.t = int(p), int(t)
        self.weight_dtype = str(weight_dtype)
        self.overwrite = overwrite
        parent = os.path.dirname(os.path.abspath(bundle_dir)) or "."
        os.makedirs(parent, exist_ok=True)
        # A writer killed before commit leaves its hidden staging dir
        # behind; sweep stale ones (age-gated — a CONCURRENT writer's
        # staging is younger) before adding our own.
        cleanup.reap_stale_staging(parent)
        self._tmp = tempfile.mkdtemp(dir=parent, prefix=".tmpbundle_")
        self._step = os.path.join(self._tmp, "step_0")
        os.makedirs(self._step)
        self.bounds: list[tuple[int, int]] = []
        self._leaves: dict[str, dict] = {}
        self._arrays: dict[str, dict] = {}
        self._committed = False

    @property
    def scratch_dir(self) -> str:
        """Staging dir — solver scratch placed here rides the same
        filesystem as the shards and dies with ``abort``."""
        return self._tmp

    def _write_leaf(self, key: str, arr: np.ndarray) -> None:
        arr = np.asarray(arr)
        dtype_name = str(arr.dtype)
        if dtype_name == "bfloat16":
            store = arr.view(np.uint16)
        else:
            store = arr
        fname = key.replace("/", "__") + ".npy"
        np.save(os.path.join(self._step, fname), store)
        self._leaves[key] = {"file": fname, "dtype": dtype_name}
        self._arrays[key] = {"shape": list(arr.shape), "dtype": dtype_name}

    def append(self, W_block: np.ndarray) -> int:
        """Write the next ``(p, width)`` weight column shard; returns its
        index.  Blocks must arrive in target-column order."""
        if self._committed:
            raise BundleError("BundleWriter already committed")
        W_block = np.asarray(W_block)
        if W_block.ndim != 2 or W_block.shape[0] != self.p:
            raise BundleError(f"weight shard shape {W_block.shape} does not "
                              f"match p={self.p}")
        lo = self.bounds[-1][1] if self.bounds else 0
        hi = lo + W_block.shape[1]
        if hi > self.t:
            raise BundleError(f"weight shards overflow the target axis: "
                              f"[{lo}, {hi}) beyond t={self.t}")
        if self.weight_dtype == "bfloat16":
            import jax.numpy as jnp
            W_block = np.asarray(jnp.asarray(W_block).astype(jnp.bfloat16))
        elif str(W_block.dtype) != self.weight_dtype:
            W_block = W_block.astype(np.dtype(self.weight_dtype))
        i = len(self.bounds)
        self._write_leaf(f"W/{_shard_key(i)}", W_block)
        self.bounds.append((lo, hi))
        return i

    def commit(self, *, config, report, standardizer=None,
               lambda_by_target: np.ndarray | None = None,
               provenance: dict | None = None) -> str:
        """Write metadata + manifests and atomically publish the bundle.

        ``report`` is an ``EncodingReport`` (its ``weights`` may be — and
        at whole-brain scale should be — ``None``; the shards already on
        disk ARE the weights).  ``standardizer`` is an optional fitted
        ``pipeline.Standardizer``.
        """
        if self._committed:
            raise BundleError("BundleWriter already committed")
        if not self.bounds or self.bounds[-1][1] != self.t:
            got = self.bounds[-1][1] if self.bounds else 0
            raise BundleError(f"weight shards cover {got} of t={self.t} "
                              f"target columns — cannot commit")
        try:
            self._write_leaf(
                "best_lambda", np.asarray(report.best_lambda, np.float64))
            self._write_leaf(
                "cv_scores", np.asarray(report.cv_scores, np.float64))
            if lambda_by_target is not None:
                lam_t = np.asarray(lambda_by_target, np.float64)
                if lam_t.shape != (self.t,):
                    raise BundleError(f"lambda_by_target shape {lam_t.shape} "
                                      f"!= (t,)=({self.t},)")
                self._write_leaf("lambda_by_target", lam_t)
            if report.band_lambdas is not None:
                self._write_leaf(
                    "band_lambdas",
                    np.asarray(report.band_lambdas, np.float64))
            std_flags = {"x": False, "y": False}
            if standardizer is not None:
                if standardizer.mu_x is not None:
                    std_flags["x"] = True
                    self._write_leaf("mu_x",
                                     np.asarray(standardizer.mu_x, np.float32))
                    self._write_leaf("sd_x",
                                     np.asarray(standardizer.sd_x, np.float32))
                if standardizer.mu_y is not None:
                    std_flags["y"] = True
                    self._write_leaf("mu_y",
                                     np.asarray(standardizer.mu_y, np.float32))
                    self._write_leaf("sd_y",
                                     np.asarray(standardizer.sd_y, np.float32))

            # The treedef string ckpt_io.save would have recorded for the
            # same logical tree (structure ignores leaf values; load()
            # never parses it — it is provenance for human readers).
            import jax
            placeholder = {"W": {_shard_key(i): 0
                                 for i in range(len(self.bounds))}}
            for key in self._leaves:
                if not key.startswith("W/"):
                    placeholder[key] = 0
            treedef = str(jax.tree_util.tree_structure(placeholder))
            with open(os.path.join(self._step, "manifest.json"), "w") as f:
                json.dump({"treedef": treedef, "leaves": self._leaves},
                          f, indent=1)

            manifest = {
                "version": _BUNDLE_VERSION,
                "kind": "encoder_bundle",
                "p": self.p,
                "t": self.t,
                "weight_dtype": self.weight_dtype,
                "weight_shards": len(self.bounds),
                "weight_shard_bounds": [[lo, hi] for lo, hi in self.bounds],
                "standardizer": std_flags,
                "config": config_to_dict(config),
                "report": report.to_dict(),
                "arrays": self._arrays,
                "provenance": provenance or {},
            }
            with open(os.path.join(self._tmp, BUNDLE_MANIFEST), "w") as f:
                json.dump(manifest, f, indent=2)
                f.write("\n")
            if os.path.exists(self.bundle_dir) and not self.overwrite:
                raise BundleError(f"bundle already exists at "
                                  f"{self.bundle_dir}; pass overwrite=True "
                                  f"to replace it")
            ckpt_io.atomic_replace_dir(self._tmp, self.bundle_dir)
        except BaseException:
            shutil.rmtree(self._tmp, ignore_errors=True)
            raise
        self._committed = True
        return self.bundle_dir

    def abort(self) -> None:
        if not self._committed:
            shutil.rmtree(self._tmp, ignore_errors=True)

    def __enter__(self) -> "BundleWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.abort()


__all__ = ["BundleWriter"]
