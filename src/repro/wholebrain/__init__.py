"""Target-axis streaming tier: whole-brain fits on commodity memory.

Composes with the row-streaming tier (``repro.data.store`` +
``repro.core.foldstats``) along the OTHER axis: rows stream in chunks,
targets stream in column blocks, and peak memory is
``O(p² + p·t_block)`` — independent of both ``n`` and ``t``.

* ``stats`` — ``ColumnBlockAccumulator``: per-block ``(k, p, t_block)``
  statistics from mmap column windows, one compiled update for all blocks.
* ``solver`` — ``fit_wholebrain``: column-blocked CV ridge reusing the
  ``k+1`` eigendecompositions across every block; λ and ``W`` bit-identical
  to the unblocked path in ``"global"`` mode.
* ``artifact`` — ``BundleWriter``: weight shards appended as blocks
  finish, one atomic ``bundle.json`` commit; read back lazily per shard.

``BrainEncoder.fit(store=...)`` routes here automatically when the
dispatch layer decides ``p·t`` breaks the device-memory budget (method
``"colblocked"``); ``launch/wholebrain.py`` drives the full
materialise→fit→save→serve loop under an RSS cap.
"""
from repro.wholebrain.artifact import BundleWriter
from repro.wholebrain.solver import WholebrainResult, fit_wholebrain
from repro.wholebrain.stats import (
    ColumnBlockAccumulator, ColumnBlockStats, colblock_update_compile_count,
    colblock_update_compiles, column_blocks,
)

__all__ = [
    "BundleWriter",
    "ColumnBlockAccumulator",
    "ColumnBlockStats",
    "WholebrainResult",
    "colblock_update_compile_count",
    "colblock_update_compiles",
    "column_blocks",
    "fit_wholebrain",
]
