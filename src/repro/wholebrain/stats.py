"""Column-blocked fold statistics — the target-axis streaming tier.

The row-streaming tier (``foldstats.FoldStatsAccumulator``) bounds memory
in ``n`` but still materialises the full ``(k, p, t)`` cross-covariance
``C`` — at the paper's whole-brain scale (Table 1: t≈264k targets) that
single tensor is the object that no longer fits.  This module blocks the
TARGET axis the same way the row tier blocks rows:

* the shared statistics (``G`` (k, p, p), ``xsum``, ``count``) depend only
  on ``X`` and are accumulated ONCE, by the existing fixed-shape masked
  update fed zero-width ``Y`` chunks (``RunStore.iter_chunks(col_range=
  (0, 0))``);
* the per-target statistics (``C`` (k, p, t_block), ``ysum``, ``ysq``)
  are accumulated per column block by ``ColumnBlockAccumulator`` — one
  streaming pass over the rows per block, touching only that block's
  ``Y`` column window (a strided mmap view, so only its pages fault in).

Peak memory is ``O(p² + p·t_block)`` — independent of ``t``.

Bit-identity contract (what ``tests/test_wholebrain.py`` locks down):
every contraction here is per-target-column independent, and on the CPU
backend XLA's column-blocked GEMMs are bitwise equal to the same columns
of the full-width GEMM for block widths ≥ 2 (width-1 lowers to a gemv
with a different reduction order).  All block computations therefore run
at ONE fixed padded width ``t_pad`` (the ragged last block is zero-padded
and sliced after), which simultaneously keeps the compiled update at a
single trace across every block — the same fixed-shape contract as the
row tier, extended to the target axis.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import foldstats


def column_blocks(t: int, t_block: int) -> list[tuple[int, int]]:
    """Contiguous target-column windows of width ``t_block`` (ragged tail).

    ``t_block >= 2`` unless it covers everything: a width-1 block would
    lower the per-block GEMMs to gemv, whose reduction order breaks the
    bitwise column-slice identity the invariance harness gates (only the
    padded LAST block may be narrower than 2 real columns — its compute
    still runs at the fixed padded width).
    """
    if t < 1:
        raise ValueError(f"need t >= 1, got t={t}")
    if t_block < 2 and t_block < t:
        raise ValueError(
            f"t_block must be >= 2 (width-1 GEMMs are gemv and break the "
            f"bitwise column-slice identity), got t_block={t_block}")
    t_block = min(t_block, t)
    return [(lo, min(lo + t_block, t)) for lo in range(0, t, t_block)]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ColumnBlockStats:
    """Per-fold sufficient statistics of ONE target-column window.

    The target-dependent half of ``foldstats.FoldStats`` — grafted onto
    the shared ``G``/``xsum``/``count`` of the X-only pass, the pair is
    indistinguishable from a full ``FoldStats`` restricted to the block's
    columns (bit-for-bit, see the module docstring).
    """

    C: jax.Array        # (k, p, t_pad)  per-fold XᵀY over the window
    ysum: jax.Array     # (k, t_pad)     per-fold Σ y
    ysq: jax.Array      # (k, t_pad)     per-fold centred Σ (y − ȳ_f)²
    count: jax.Array    # (k,)           per-fold row count

    @property
    def C_total(self) -> jax.Array:
        return jnp.sum(self.C, axis=0)


class _ColumnBlockUpdate:
    """The ONE compiled program of the per-block accumulation.

    The target-block mirror of ``foldstats._FixedShapeUpdate``: same
    masked slot layout, same Chan centred-moment update, but WITHOUT the
    ``G``/``xsum`` terms — those are shared across blocks and recomputing
    the ``O(np²)`` Gram once per block would multiply the dominant cost by
    the block count.  The ``C`` einsum is the exact column sub-problem of
    the fused ``Xᵀ[X | Y]`` update, so its output is bitwise equal to the
    corresponding columns of the full-width accumulation.
    """

    def __init__(self) -> None:
        self.compiles = obs.CompileCounter("wholebrain.colblock_update")
        self._fn = jax.jit(self._update, static_argnames=("use_pallas",))

    @property
    def compile_count(self) -> int:
        return self.compiles.count

    def __call__(self, stats: ColumnBlockStats, X, Y, onehot, slot_fold, *,
                 use_pallas: bool = False) -> ColumnBlockStats:
        return self._fn(stats, X, Y, onehot, slot_fold,
                        use_pallas=use_pallas)

    def _update(self, stats: ColumnBlockStats, X: jax.Array, Y: jax.Array,
                onehot: jax.Array, slot_fold: jax.Array,
                use_pallas: bool = False) -> ColumnBlockStats:
        # Python side effect at TRACE time only — the compile counter the
        # wholebrain CI lane gates at exactly 1 across ALL blocks (shared
        # obs.CompileCounter; expect() windows arm the strict sentinel).
        self.compiles.mark()
        dt = jnp.promote_types(X.dtype, Y.dtype)
        w = onehot                                          # (m, s) f32 0/1
        if use_pallas:
            # Same fused masked kernel as the row tier, with Z = the
            # block's Y columns only (the X half of [G|C] is shared across
            # blocks and accumulated once, in the X-only/first-block pass).
            from repro.kernels import ops
            Cb = ops.xty_folds_masked(X.astype(dt), Y.astype(dt),
                                      w.astype(dt))          # (s, p, t_pad)
        else:
            Xw = (X.astype(dt)[None]
                  * jnp.swapaxes(w, 0, 1)[:, :, None].astype(dt))
            Cb = jnp.einsum("smp,mq->spq", Xw, Y.astype(dt),
                            preferred_element_type=jnp.float32)
        Yf = Y.astype(jnp.float32)
        cnt = jnp.sum(w, axis=0)                             # (s,)
        ysum = jnp.einsum("ms,mt->st", w, Yf,
                          preferred_element_type=jnp.float32)
        # Chan pairwise combination, identical to the row tier's — every
        # term is per-column independent, so the block is a bitwise column
        # slice of the full-width moment statistics.
        mu_b = ysum / jnp.maximum(cnt, 1.0)[:, None]
        d = Yf[None, :, :] - mu_b[:, None, :]                # (s, m, t_pad)
        m2 = jnp.einsum("ms,smt->st", w, d * d,
                        preferred_element_type=jnp.float32)
        n_a = stats.count[slot_fold]                         # (s,)
        mu_a = stats.ysum[slot_fold] / jnp.maximum(n_a, 1.0)[:, None]
        both = ((n_a > 0) & (cnt > 0))[:, None]
        delta2 = jnp.where(both, (mu_a - mu_b) ** 2, 0.0)
        ysq_add = m2 + delta2 * (n_a * cnt
                                 / jnp.maximum(n_a + cnt, 1.0))[:, None]
        return ColumnBlockStats(
            C=stats.C.at[slot_fold].add(Cb),
            ysum=stats.ysum.at[slot_fold].add(ysum),
            ysq=stats.ysq.at[slot_fold].add(ysq_add),
            count=stats.count.at[slot_fold].add(cnt))


# Module-level singleton: every block of every stream shares one jit
# cache, so a whole-brain sweep of hundreds of blocks costs ONE trace.
_COLBLOCK_UPDATE = _ColumnBlockUpdate()


def colblock_update_compile_count() -> int:
    """Trace count of the column-block update (monotonic, process-wide).

    Take a delta around a blocked fit to measure its compiles; the
    contract is ``delta == 1`` for a fresh ``(chunk_rows, p, t_pad, k)``
    signature however many blocks are streamed, and ``0`` for a repeat.

    (Thin alias over ``colblock_update_compiles().count`` — the shared
    ``obs.CompileCounter`` primitive.)
    """
    return _COLBLOCK_UPDATE.compiles.count


def colblock_update_compiles() -> "obs.CompileCounter":
    """The column-block update's :class:`repro.obs.CompileCounter`
    (``expect()`` windows arm the strict recompile sentinel)."""
    return _COLBLOCK_UPDATE.compiles


class ColumnBlockAccumulator(foldstats.FoldStatsAccumulator):
    """Streaming builder of ``ColumnBlockStats`` for one column window.

    Reuses ALL of the row tier's machinery — chunk splitting, zero-row
    padding, slot masks, offset accounting, the finalize contract — and
    replaces only the applied statistic (the ``_apply`` seam): incoming
    ``Y`` chunks carry the block's real columns and are zero-padded on the
    COLUMN axis to the fixed ``t_pad``, so every block of every width
    presents the same shape to the one compiled update.  Padded columns
    accumulate exact zeros and are sliced away by the solver.
    """

    def __init__(self, n_total: int, n_folds: int, t_pad: int, *,
                 row_start: int = 0, row_stop: int | None = None,
                 chunk_rows: int | None = None,
                 use_pallas: bool = False):
        if t_pad < 1:
            raise ValueError(f"t_pad must be >= 1, got {t_pad}")
        super().__init__(n_total, n_folds, row_start=row_start,
                         row_stop=row_stop, chunk_rows=chunk_rows,
                         use_pallas=use_pallas)
        self.t_pad = t_pad

    def _init_stats(self, p: int, t: int) -> ColumnBlockStats:
        if t > self.t_pad:
            raise ValueError(f"chunk has {t} target columns but the fixed "
                             f"block width is t_pad={self.t_pad}")
        k = len(self.bounds)
        z = jnp.zeros
        return ColumnBlockStats(C=z((k, p, self.t_pad), jnp.float32),
                                ysum=z((k, self.t_pad), jnp.float32),
                                ysq=z((k, self.t_pad), jnp.float32),
                                count=z((k,), jnp.float32))

    def _apply(self, Xs, Ys, onehot, slot_fold) -> None:
        import numpy as np
        Ys = np.asarray(Ys)
        if Ys.shape[1] < self.t_pad:       # ragged block: zero-pad columns
            Yp = np.zeros((Ys.shape[0], self.t_pad), Ys.dtype)
            Yp[:, :Ys.shape[1]] = Ys
            Ys = Yp
        self._stats = _COLBLOCK_UPDATE(self._stats, jnp.asarray(Xs),
                                       jnp.asarray(Ys), onehot, slot_fold,
                                       use_pallas=self.use_pallas)


__all__ = ["ColumnBlockAccumulator", "ColumnBlockStats", "column_blocks",
           "colblock_update_compile_count", "colblock_update_compiles"]
