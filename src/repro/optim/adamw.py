"""AdamW with decoupled weight decay and global-norm gradient clipping.

Implemented directly on pytrees (no optax dependency in this environment).
Optimizer moments are kept in float32 regardless of parameter dtype, the
standard mixed-precision training arrangement on TPU.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float | None = 1.0


def adamw_init(params: Any) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(cfg: AdamWConfig, params: Any, grads: Any, state: dict,
                 lr_scale: jax.Array | float = 1.0
                 ) -> tuple[Any, dict, dict]:
    """→ (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    if cfg.grad_clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.grad_clip_norm / (gnorm + 1e-9))
        grads = jax.tree_util.tree_map(
            lambda g: (g.astype(jnp.float32) * scale), grads)
    else:
        grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)

    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                                state["mu"], grads)
    nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                                state["nu"], grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, m, v):
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree_util.tree_map(upd, params, mu, nu)
    new_state = {"mu": mu, "nu": nu, "step": step}
    return new_params, new_state, {"grad_norm": gnorm,
                                   "lr": jnp.asarray(lr, jnp.float32)}
