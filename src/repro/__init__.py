"""repro — scaling up ridge regression for brain encoding (JAX/Pallas).

Public surface:

* ``repro.encoding`` — the estimator API (``BrainEncoder``,
  ``EncoderConfig``, ``ShardingPlan``, ``pipeline``).  Start here.
* ``repro.core`` — documented low-level solver layer (``ridge_cv``,
  ``bmor_fit``, ``banded_ridge_cv``, the §3 ``complexity`` model).
* ``repro.data`` / ``repro.models`` / ``repro.launch`` — data generators,
  feature-extractor backbones, and drivers.
* ``repro.obs`` — span tracing, the metrics registry, and the recompile
  sentinel shared by every tier (disabled-by-default, stdlib only).

Exports are lazy (PEP 562) so that ``import repro`` never initialises JAX
device state — launchers must be able to set ``XLA_FLAGS`` first.
"""
from __future__ import annotations

import importlib

_LAZY = {
    "BrainEncoder": ("repro.encoding.estimator", "BrainEncoder"),
    "EncoderConfig": ("repro.encoding.config", "EncoderConfig"),
    "EncodingReport": ("repro.encoding.estimator", "EncodingReport"),
    "EvaluationReport": ("repro.encoding.estimator", "EvaluationReport"),
    "EncoderBundle": ("repro.serving_encoders.bundle", "EncoderBundle"),
    "EncoderRegistry": ("repro.serving_encoders.registry", "EncoderRegistry"),
    "EncoderService": ("repro.serving_encoders.service", "EncoderService"),
    "RunStore": ("repro.data.store", "RunStore"),
    "ShardingPlan": ("repro.encoding.sharding", "ShardingPlan"),
    "encoding": ("repro.encoding", None),
    "serving_encoders": ("repro.serving_encoders", None),
    "core": ("repro.core", None),
    "configs": ("repro.configs", None),
    "data": ("repro.data", None),
    "launch": ("repro.launch", None),
    "models": ("repro.models", None),
    "obs": ("repro.obs", None),
}

__all__ = sorted(_LAZY)


def __getattr__(name: str):
    if name not in _LAZY:
        raise AttributeError(f"module 'repro' has no attribute {name!r}")
    module, attr = _LAZY[name]
    mod = importlib.import_module(module)
    return mod if attr is None else getattr(mod, attr)
