"""Synthetic fleet building + request traffic, shared by the serve CLI
(``launch/serve.py --encoders``) and ``benchmarks/serving_bench.py`` so the
materialise → fit → save loop and the request-size distribution cannot
drift between the two drivers.

The fleet tier adds the **deterministic mixed-traffic trace**: a seeded,
checked-in request schedule (``benchmarks/traces/mixed_v1.json``) with
ragged row counts, a scored/unscored mix, multiple tenants, and Zipf-ish
model popularity over more models than a serving budget fits.  Tests and
``serving_bench.py --replay-trace`` replay the SAME trace — same packing,
same admission pressure, same eviction churn — so the p50/p99 gates and
the bit-identity gate (packed mixed waves vs per-request reference
serve) always measure the same workload.  The trace file stores only the
*structure* (model index, tenant, rows, scored flag) plus a sha256
digest over it; the float payloads are regenerated per entry from the
trace seed at replay time, so the checked-in file stays small and the
digest survives numpy version drift."""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os


def build_synthetic_fleet(workdir: str, n_models: int, *, n: int, p: int,
                          t: int, provenance: dict | None = None
                          ) -> list[tuple[str, str]]:
    """Fit + save one pipeline-standardized bundle per synthetic subject.

    Existing bundles under ``workdir`` are reused (refits are the expensive
    half of "fit once, serve many").  Returns ``[(name, path), ...]``.
    """
    import jax
    from repro.data import fmri
    from repro.encoding import EncoderConfig, pipeline
    from repro.serving_encoders.bundle import BUNDLE_MANIFEST, EncoderBundle

    fleet = []
    for i in range(n_models):
        name = f"sub-{i + 1:02d}"
        path = os.path.join(workdir, name)
        if os.path.exists(os.path.join(path, BUNDLE_MANIFEST)):
            found = EncoderBundle.open(path).shape
            if found != (p, t):
                raise ValueError(
                    f"existing bundle {path} has shape (p, t)={found}, "
                    f"but (p={p}, t={t}) was requested — point at a fresh "
                    f"directory or delete the stale fleet")
            print(f"reusing bundle {path}")
        else:
            X, Y, _ = fmri.generate(jax.random.PRNGKey(i),
                                    fmri.SubjectSpec(n=n, p=p, t=t))
            state = pipeline.run_stages(X, Y, [
                pipeline.split(seed=i), pipeline.standardize(),
                pipeline.fit(EncoderConfig(solver="ridge"))])
            state.encoder.save(
                path, overwrite=True,
                provenance={"subject": name, "n": n, "synthetic": True,
                            **(provenance or {})})
            lam = state.report.best_lambda
            print(f"fitted {name} (λ={lam}) → saved bundle {path}")
        fleet.append((name, path))
    return fleet


def ragged_requests(rng, models: list[str], p: int, wave_rows: int,
                    count: int) -> list:
    """``count`` concurrent requests with ragged row sizes in
    ``[8, max(9, 2·wave_rows))`` spread randomly over ``models`` — the
    mixed traffic both drivers serve."""
    import numpy as np

    from repro.serving_encoders.service import PredictRequest

    lo, hi = 8, max(9, 2 * wave_rows)          # guard hi > lo
    return [PredictRequest(
                model=models[int(rng.integers(len(models)))],
                features=rng.standard_normal(
                    (int(rng.integers(lo, hi)), p)).astype(np.float32))
            for _ in range(count)]


# -- deterministic mixed-traffic traces --------------------------------------

_TRACE_VERSION = 1


@dataclasses.dataclass(frozen=True)
class TraceEntry:
    """One request in a trace: WHICH model, WHO is asking, HOW many rows,
    and whether targets ride along (scored).  Float payloads are not part
    of the trace — they are regenerated from ``(trace seed, entry index)``
    at replay time."""

    model_idx: int
    tenant: str
    rows: int
    scored: bool


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """A checked-in mixed-traffic schedule (see module docstring)."""

    seed: int
    p: int                       # feature dim every request must carry
    t: int                       # target dim scored requests carry
    n_models: int                # fleet size the trace indexes into
    entries: tuple               # TraceEntry, arrival order
    zipf_a: float = 1.1

    def digest(self) -> str:
        return trace_digest(self.entries)


def trace_digest(entries) -> str:
    """sha256 over the trace *structure* (model_idx, tenant, rows,
    scored) — stable across numpy/platform drift because no float bytes
    are hashed."""
    payload = json.dumps(
        [[e.model_idx, e.tenant, e.rows, int(e.scored)] for e in entries],
        separators=(",", ":")).encode()
    return hashlib.sha256(payload).hexdigest()


def make_mixed_trace(seed: int, *, n_models: int, n_requests: int, p: int,
                     t: int, wave_rows: int, scored_frac: float = 0.4,
                     zipf_a: float = 1.1, n_tenants: int = 4) -> TraceSpec:
    """Generate a mixed-traffic schedule: ragged row counts in
    ``[8, 2·wave_rows)``, ``scored_frac`` of requests scored, model
    popularity Zipf-ish (weight ``1/(rank+1)^a`` — rank-0 dominates, the
    tail keeps forcing eviction churn when ``n_models`` exceeds what the
    registry budget fits)."""
    import numpy as np

    rng = np.random.default_rng(seed)
    w = 1.0 / (np.arange(n_models) + 1.0) ** zipf_a
    w /= w.sum()
    lo, hi = 8, max(9, 2 * wave_rows)
    entries = tuple(
        TraceEntry(model_idx=int(rng.choice(n_models, p=w)),
                   tenant=f"tenant-{int(rng.integers(n_tenants)):02d}",
                   rows=int(rng.integers(lo, hi)),
                   scored=bool(rng.random() < scored_frac))
        for _ in range(n_requests))
    return TraceSpec(seed=seed, p=p, t=t, n_models=n_models,
                     entries=entries, zipf_a=zipf_a)


def save_trace(path: str, spec: TraceSpec) -> str:
    os.makedirs(os.path.dirname(os.path.abspath(path)) or ".",
                exist_ok=True)
    doc = {"version": _TRACE_VERSION, "seed": spec.seed, "p": spec.p,
           "t": spec.t, "n_models": spec.n_models, "zipf_a": spec.zipf_a,
           "digest": spec.digest(),
           "entries": [[e.model_idx, e.tenant, e.rows, int(e.scored)]
                       for e in spec.entries]}
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    return path


def load_trace(path: str) -> TraceSpec:
    """Load a checked-in trace, verifying its structure digest — a trace
    that drifted from what the benchmarks recorded is refused, not
    silently replayed."""
    with open(path) as f:
        doc = json.load(f)
    if doc.get("version") != _TRACE_VERSION:
        raise ValueError(f"trace {path}: unsupported version "
                         f"{doc.get('version')}")
    entries = tuple(TraceEntry(model_idx=int(m), tenant=str(tn),
                               rows=int(r), scored=bool(s))
                    for m, tn, r, s in doc["entries"])
    got = trace_digest(entries)
    if got != doc["digest"]:
        raise ValueError(f"trace {path}: digest mismatch — file says "
                         f"{doc['digest'][:12]}…, entries hash to "
                         f"{got[:12]}… (the trace was edited; regenerate "
                         f"it with make_mixed_trace + save_trace)")
    return TraceSpec(seed=int(doc["seed"]), p=int(doc["p"]),
                     t=int(doc["t"]), n_models=int(doc["n_models"]),
                     entries=entries, zipf_a=float(doc["zipf_a"]))


def replay_requests(spec: TraceSpec, models: list[str]) -> list:
    """Materialise the trace's ``PredictRequest`` list.

    Each entry's float payload comes from ``default_rng([seed, index])``
    — independent of every other entry, so any slice of the trace
    replays the same requests (the reference serve and the packed serve
    see bit-identical inputs by construction).
    """
    import numpy as np

    from repro.serving_encoders.service import PredictRequest

    if len(models) < spec.n_models:
        raise ValueError(f"trace wants {spec.n_models} models, fleet has "
                         f"{len(models)}")
    out = []
    for i, e in enumerate(spec.entries):
        rng = np.random.default_rng([spec.seed, i])
        X = rng.standard_normal((e.rows, spec.p)).astype(np.float32)
        Y = (rng.standard_normal((e.rows, spec.t)).astype(np.float32)
             if e.scored else None)
        out.append(PredictRequest(model=models[e.model_idx], features=X,
                                  targets=Y, tenant=e.tenant))
    return out


__all__ = ["TraceEntry", "TraceSpec", "build_synthetic_fleet", "load_trace",
           "make_mixed_trace", "ragged_requests", "replay_requests",
           "save_trace", "trace_digest"]
