"""Synthetic fleet building + request traffic, shared by the serve CLI
(``launch/serve.py --encoders``) and ``benchmarks/serving_bench.py`` so the
materialise → fit → save loop and the request-size distribution cannot
drift between the two drivers."""
from __future__ import annotations

import os


def build_synthetic_fleet(workdir: str, n_models: int, *, n: int, p: int,
                          t: int, provenance: dict | None = None
                          ) -> list[tuple[str, str]]:
    """Fit + save one pipeline-standardized bundle per synthetic subject.

    Existing bundles under ``workdir`` are reused (refits are the expensive
    half of "fit once, serve many").  Returns ``[(name, path), ...]``.
    """
    import jax
    from repro.data import fmri
    from repro.encoding import EncoderConfig, pipeline
    from repro.serving_encoders.bundle import BUNDLE_MANIFEST, EncoderBundle

    fleet = []
    for i in range(n_models):
        name = f"sub-{i + 1:02d}"
        path = os.path.join(workdir, name)
        if os.path.exists(os.path.join(path, BUNDLE_MANIFEST)):
            found = EncoderBundle.open(path).shape
            if found != (p, t):
                raise ValueError(
                    f"existing bundle {path} has shape (p, t)={found}, "
                    f"but (p={p}, t={t}) was requested — point at a fresh "
                    f"directory or delete the stale fleet")
            print(f"reusing bundle {path}")
        else:
            X, Y, _ = fmri.generate(jax.random.PRNGKey(i),
                                    fmri.SubjectSpec(n=n, p=p, t=t))
            state = pipeline.run_stages(X, Y, [
                pipeline.split(seed=i), pipeline.standardize(),
                pipeline.fit(EncoderConfig(solver="ridge"))])
            state.encoder.save(
                path, overwrite=True,
                provenance={"subject": name, "n": n, "synthetic": True,
                            **(provenance or {})})
            lam = state.report.best_lambda
            print(f"fitted {name} (λ={lam}) → saved bundle {path}")
        fleet.append((name, path))
    return fleet


def ragged_requests(rng, models: list[str], p: int, wave_rows: int,
                    count: int) -> list:
    """``count`` concurrent requests with ragged row sizes in
    ``[8, max(9, 2·wave_rows))`` spread randomly over ``models`` — the
    mixed traffic both drivers serve."""
    import numpy as np

    from repro.serving_encoders.service import PredictRequest

    lo, hi = 8, max(9, 2 * wave_rows)          # guard hi > lo
    return [PredictRequest(
                model=models[int(rng.integers(len(models)))],
                features=rng.standard_normal(
                    (int(rng.integers(lo, hi)), p)).astype(np.float32))
            for _ in range(count)]


__all__ = ["build_synthetic_fleet", "ragged_requests"]
