"""EncoderBundle — the on-disk contract for a fitted ``BrainEncoder``.

The paper's end product is one fitted ridge encoder per (subject, band,
backbone layer): Friends seasons 1–6 train once, season 3 / held-out
episodes predict forever after.  A bundle persists *everything needed to
predict without refitting*:

* the weight matrix ``W`` — column-sharded ``.npy`` leaves through
  ``checkpoint.io`` (bfloat16 stored as uint16 bit patterns, exactly like
  ``data.store.RunStore`` shards);
* the fitted per-column μ/σ ``Standardizer`` from the pipeline (when one
  was attached), so serving can replay the training-time transform on raw
  features;
* the selected λ per target batch (plus the per-target expansion), the CV
  curve, and the swept grid;
* the full ``EncoderConfig`` and the ``DispatchDecision`` that fitted it —
  the fold split (``n_folds``) and solver provenance ride in the manifest.

Layout on disk::

    <dir>/bundle.json        # manifest: shapes, dtypes, config, decision,
                             #   per-leaf shape/dtype table, provenance
    <dir>/step_0/            # checkpoint.io leaf directory (atomic)

Design points mirror ``RunStore``:

* **Atomic write.**  The whole bundle is staged in a tmp dir and renamed
  into place; a crashed save never leaves a half-valid bundle visible.
* **Eager validation.**  ``open()`` cross-checks every leaf's ``.npy``
  header shape/dtype against the bundle manifest before any prediction —
  a missing shard, a shape/dtype mismatch, or a manifest/checkpoint
  disagreement raises ``BundleError`` (a ``ValueError``), mirroring
  ``StoreError`` semantics.
* **Round-trip parity.**  ``load_encoder().predict(X)`` is bit-identical
  to the fitted encoder's ``predict(X)`` (f32 and bf16, sharded and
  unsharded) — locked down by ``tests/helpers/encoder_checks.py``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import tempfile

import numpy as np

from repro.checkpoint import io as ckpt_io
from repro.data.store import (  # shared npy-header / dtype helpers
    _dtype_from_name, _read_npy_header, _storage_dtype,
)
from repro.encoding.config import EncoderConfig
from repro.encoding.dispatch import DispatchDecision

BUNDLE_MANIFEST = "bundle.json"
_BUNDLE_VERSION = 1
_TUPLE_FIELDS = ("lambdas", "bands", "band_log_lambda_range")


class BundleError(ValueError):
    """Bundle inconsistency: missing/corrupt manifest, missing or
    mismatched leaf, unsupported version, or an unfit encoder."""


def config_to_dict(cfg: EncoderConfig) -> dict:
    return dataclasses.asdict(cfg)


def config_from_dict(d: dict) -> EncoderConfig:
    kw = dict(d)
    for f in _TUPLE_FIELDS:
        if kw.get(f) is not None:
            kw[f] = tuple(kw[f])
    known = {f.name for f in dataclasses.fields(EncoderConfig)}
    unknown = set(kw) - known
    if unknown:
        raise BundleError(f"bundle config has unknown EncoderConfig "
                          f"field(s) {sorted(unknown)}")
    return EncoderConfig(**kw)


def _shard_key(i: int) -> str:
    return f"{i:03d}"


def _weight_shard_bounds(t: int, n_shards: int) -> list[tuple[int, int]]:
    """Contiguous column blocks, as even as possible."""
    return [(t * i // n_shards, t * (i + 1) // n_shards)
            for i in range(n_shards)]


def _lambda_by_target(best_lambda: np.ndarray, t: int) -> np.ndarray | None:
    """Expand the per-batch λ to a (t,) per-target vector.

    Batches are contiguous equal column blocks of the (padded) target axis
    (Alg. 1 line 13 — one λ per target batch); MOR/banded reports carry an
    empty ``best_lambda`` and get no expansion.
    """
    b = np.asarray(best_lambda).ravel()
    if b.size == 0:
        return None
    per = -(-t // b.size)                      # ceil — padding-aware
    return np.repeat(b, per)[:t].astype(np.float64)


def save_bundle(bundle_dir: str, encoder, *, overwrite: bool = False,
                weight_shards: int | None = None,
                weight_dtype: str | np.dtype | None = None,
                provenance: dict | None = None) -> str:
    """Write a fitted ``BrainEncoder`` as an atomic bundle directory.

    ``weight_dtype`` casts ``W`` before writing (e.g. ``"bfloat16"`` to
    halve a whole-brain bundle).  Predict parity is then defined against
    the *cast* weights — a lossy storage choice the caller opts into.
    """
    import jax
    import jax.numpy as jnp

    report = encoder.report_
    if report is None:
        raise BundleError("encoder is not fitted (report_ is None) — "
                          "call fit() before save()")
    # Refuse BEFORE staging: serializing a whole-brain W costs GBs of I/O
    # that a pre-existing bundle would throw away (re-checked before the
    # final swap in case the directory appears mid-save).
    if os.path.exists(bundle_dir) and not overwrite:
        raise BundleError(f"bundle already exists at {bundle_dir}; "
                          f"pass overwrite=True to replace it")
    W = np.asarray(jax.device_get(report.weights))
    if weight_dtype is not None:
        W = np.asarray(jnp.asarray(W).astype(
            jnp.bfloat16 if str(weight_dtype) == "bfloat16"
            else np.dtype(weight_dtype)))
    p, t = W.shape
    n_shards = max(1, min(weight_shards or
                          max(1, report.decision.target_shards), t))
    bounds = _weight_shard_bounds(t, n_shards)

    tree: dict = {"W": {_shard_key(i): W[:, lo:hi]
                        for i, (lo, hi) in enumerate(bounds)}}
    tree["best_lambda"] = np.asarray(report.best_lambda, np.float64)
    tree["cv_scores"] = np.asarray(report.cv_scores, np.float64)
    lam_t = _lambda_by_target(report.best_lambda, t)
    if lam_t is not None:
        tree["lambda_by_target"] = lam_t
    if report.band_lambdas is not None:
        tree["band_lambdas"] = np.asarray(report.band_lambdas, np.float64)
    std = getattr(encoder, "standardizer_", None)
    std_flags = {"x": False, "y": False}
    if std is not None:
        if std.mu_x is not None:
            std_flags["x"] = True
            tree["mu_x"] = np.asarray(std.mu_x, np.float32)
            tree["sd_x"] = np.asarray(std.sd_x, np.float32)
        if std.mu_y is not None:
            std_flags["y"] = True
            tree["mu_y"] = np.asarray(std.mu_y, np.float32)
            tree["sd_y"] = np.asarray(std.sd_y, np.float32)

    # Key derivation MUST match checkpoint.io's flattening — reuse it so
    # the manifest's arrays table and the saved leaves can never drift.
    flat = ckpt_io._flatten(tree)

    parent = os.path.dirname(os.path.abspath(bundle_dir)) or "."
    os.makedirs(parent, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=parent, prefix=".tmpbundle_")
    try:
        ckpt_io.save(tmp, 0, tree)
        manifest = {
            "version": _BUNDLE_VERSION,
            "kind": "encoder_bundle",
            "p": int(p),
            "t": int(t),
            "weight_dtype": ("bfloat16" if W.dtype.name == "bfloat16"
                             else W.dtype.name),
            "weight_shards": n_shards,
            "weight_shard_bounds": [[int(lo), int(hi)] for lo, hi in bounds],
            "standardizer": std_flags,
            "config": config_to_dict(encoder.config),
            # The dispatch decision lives ONCE, inside the report dict —
            # a second top-level copy would be a drift hazard.
            "report": report.to_dict(),
            "arrays": {key: {"shape": list(arr.shape),
                             "dtype": ("bfloat16"
                                       if arr.dtype.name == "bfloat16"
                                       else arr.dtype.name)}
                       for key, arr in flat.items()},
            "provenance": provenance or {},
        }
        with open(os.path.join(tmp, BUNDLE_MANIFEST), "w") as f:
            json.dump(manifest, f, indent=2)
            f.write("\n")
        if os.path.exists(bundle_dir) and not overwrite:
            raise BundleError(f"bundle already exists at {bundle_dir}; "
                              f"pass overwrite=True to replace it")
        # Crash-safe swap shared with checkpoint.io: the old bundle is
        # renamed aside and restored on failure, so a crashed save never
        # leaves fewer than one complete bundle on disk.
        ckpt_io.atomic_replace_dir(tmp, bundle_dir)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return bundle_dir


class EncoderBundle:
    """A validated, *unloaded* bundle: manifest in memory, arrays on disk.

    ``open()`` is cheap (headers only) so a registry can hold many bundles
    and materialise device arrays lazily through ``load_encoder``.
    """

    def __init__(self, root: str, manifest: dict):
        self.root = root
        self.manifest = manifest

    # -- cheap metadata ------------------------------------------------------
    @property
    def shape(self) -> tuple[int, int]:
        """(p, t) of the weight matrix."""
        return self.manifest["p"], self.manifest["t"]

    @property
    def weight_dtype(self) -> np.dtype:
        return _dtype_from_name(self.manifest["weight_dtype"])

    @property
    def has_standardizer(self) -> bool:
        f = self.manifest["standardizer"]
        return bool(f.get("x") or f.get("y"))

    def config(self) -> EncoderConfig:
        return config_from_dict(self.manifest["config"])

    def decision(self) -> DispatchDecision:
        return DispatchDecision(**self.manifest["report"]["decision"])

    def weight_nbytes(self) -> int:
        p, t = self.shape
        return p * t * self.weight_dtype.itemsize

    # -- construction --------------------------------------------------------
    @classmethod
    def open(cls, root: str) -> "EncoderBundle":
        """Open and eagerly validate (headers only, no array data)."""
        path = os.path.join(root, BUNDLE_MANIFEST)
        if not os.path.exists(path):
            raise BundleError(f"no {BUNDLE_MANIFEST} under {root}")
        try:
            with open(path) as f:
                m = json.load(f)
        except json.JSONDecodeError as e:
            raise BundleError(f"corrupt {BUNDLE_MANIFEST} under {root}: {e}")
        if m.get("kind") != "encoder_bundle":
            raise BundleError(f"{root} is not an encoder bundle "
                              f"(kind={m.get('kind')!r})")
        if m.get("version") != _BUNDLE_VERSION:
            raise BundleError(f"unsupported bundle version {m.get('version')}")
        bundle = cls(root, m)
        bundle._validate()
        return bundle

    def _validate(self) -> None:
        m = self.manifest
        try:
            ckpt_manifest = ckpt_io._read_manifest(
                os.path.join(self.root, "step_0"))
        except ckpt_io.CheckpointError as e:
            raise BundleError(f"bundle {self.root}: {e}")
        leaves = ckpt_manifest["leaves"]
        bounds = m["weight_shard_bounds"]
        if len(bounds) != m["weight_shards"]:
            raise BundleError(f"bundle {self.root}: weight_shard_bounds has "
                              f"{len(bounds)} entries != weight_shards="
                              f"{m['weight_shards']}")
        pos = 0
        for lo, hi in bounds:
            if lo != pos or hi < lo:
                raise BundleError(f"bundle {self.root}: weight shard bounds "
                                  f"{bounds} overlap or gap the target axis")
            pos = hi
        if pos != m["t"]:
            raise BundleError(f"bundle {self.root}: weight shards cover "
                              f"{pos} target columns, manifest says {m['t']}")
        for i in range(m["weight_shards"]):
            key = f"W/{_shard_key(i)}"
            if key not in m["arrays"]:
                raise BundleError(f"bundle {self.root}: weight shard {key} "
                                  f"missing from the arrays table")
        for key, meta in m["arrays"].items():
            if key not in leaves:
                raise BundleError(
                    f"bundle {self.root}: leaf {key!r} in {BUNDLE_MANIFEST} "
                    f"but absent from the checkpoint manifest")
            npy = os.path.join(self.root, "step_0", leaves[key]["file"])
            if not os.path.exists(npy):
                raise BundleError(f"bundle {self.root}: leaf {key!r} shard "
                                  f"{os.path.basename(npy)} is missing")
            shape, dtype = _read_npy_header(npy)
            want_shape = tuple(meta["shape"])
            want_store = _storage_dtype(_dtype_from_name(meta["dtype"]))
            if shape != want_shape:
                raise BundleError(
                    f"bundle {self.root}: leaf {key!r} shape {shape} != "
                    f"manifest {want_shape}")
            if dtype != want_store:
                raise BundleError(
                    f"bundle {self.root}: leaf {key!r} dtype {dtype} != "
                    f"manifest storage dtype {want_store}")

    # -- materialisation -----------------------------------------------------
    def _leaves(self) -> dict:
        """Cached checkpoint-manifest leaf table (one json read)."""
        cached = getattr(self, "_leaf_table", None)
        if cached is None:
            cached = ckpt_io._read_manifest(
                os.path.join(self.root, "step_0"))["leaves"]
            self._leaf_table = cached
        return cached

    def load_arrays(self, keys: list[str] | None = None, *,
                    mmap: bool = False) -> dict[str, np.ndarray]:
        """Load checkpoint leaves — all of them, or just ``keys``.

        ``keys`` lets the lazy paths (``load_encoder``, the registry's
        shard-granular ``get_columns``) pull the small metadata leaves
        without materialising every weight shard; ``mmap=True`` returns
        read-only memmap views (pages fault in on first touch).
        """
        leaves = self._leaves()
        if keys is None:
            keys = list(leaves)
        else:
            missing = [k for k in keys if k not in leaves]
            if missing:
                raise BundleError(f"bundle {self.root}: requested leaf/leaves "
                                  f"{missing} not in the checkpoint manifest")
        src = os.path.join(self.root, "step_0")
        return {k: ckpt_io._load_leaf(src, k, leaves[k], mmap=mmap)
                for k in keys}

    def weight_shard_bounds(self) -> list[tuple[int, int]]:
        return [(int(lo), int(hi))
                for lo, hi in self.manifest["weight_shard_bounds"]]

    def shards_for_columns(self, lo: int, hi: int) -> list[int]:
        """Indices of the weight shards overlapping columns ``[lo, hi)``."""
        p, t = self.shape
        if not (0 <= lo <= hi <= t):
            raise BundleError(f"bundle {self.root}: column window "
                              f"[{lo}, {hi}) outside [0, {t})")
        return [i for i, (slo, shi) in enumerate(self.weight_shard_bounds())
                if slo < hi and lo < shi]

    def load_weight_shard(self, i: int, *, mmap: bool = False) -> np.ndarray:
        """Load ONE ``(p, width)`` weight column shard.

        ``mmap=True`` is the serving path: the shard is a read-only view
        into its ``.npy`` and only the pages a prediction actually reads
        are faulted in.
        """
        m = self.manifest
        if not (0 <= i < m["weight_shards"]):
            raise BundleError(f"bundle {self.root}: weight shard {i} out of "
                              f"range [0, {m['weight_shards']})")
        key = f"W/{_shard_key(i)}"
        return ckpt_io._load_leaf(os.path.join(self.root, "step_0"), key,
                                  self._leaves()[key], mmap=mmap)

    def load_standardizer(self, arrays: dict[str, np.ndarray]):
        from repro.encoding.pipeline import Standardizer

        if not self.has_standardizer:
            return None
        flags = self.manifest["standardizer"]
        std = Standardizer()
        if flags.get("x"):
            std.mu_x, std.sd_x = arrays["mu_x"], arrays["sd_x"]
        if flags.get("y"):
            std.mu_y, std.sd_y = arrays["mu_y"], arrays["sd_y"]
        return std

    def load_encoder(self, *, target_shards: int | None = None,
                     mmap: bool = False):
        """Materialise a fitted ``BrainEncoder`` (no refit).

        ``target_shards`` > 1 places ``W`` column-sharded over a fresh
        ``(1, target_shards)`` mesh — the serving layout.  ``t`` must
        divide evenly and enough local devices must exist.

        ``mmap=True`` reads the weight shards through read-only memmaps
        (the fleet registry's default): the bytes flow device-ward through
        the OS page cache, so N serving processes pointed at one artifact
        directory warm the disk read once between them — each process
        still owns its device copy.
        """
        import jax
        import jax.numpy as jnp

        from repro.encoding.estimator import BrainEncoder, EncodingReport

        m = self.manifest
        # Per-shard access (not one eager load-everything): the metadata
        # leaves are tiny, and the weight shards stream through
        # ``load_weight_shard`` so a future column-windowed caller shares
        # the exact same read path the registry's shard cache uses.
        arrays = self.load_arrays(
            [k for k in self._leaves() if not k.startswith("W/")])
        blocks = [self.load_weight_shard(i, mmap=mmap)
                  for i in range(m["weight_shards"])]
        W = blocks[0] if len(blocks) == 1 else np.concatenate(blocks, axis=1)
        Wj = jnp.asarray(W)
        cfg = self.config()
        if target_shards is not None and target_shards > 1:
            from jax.sharding import NamedSharding, PartitionSpec as P

            from repro.encoding.sharding import ShardingPlan

            p, t = self.shape
            if t % target_shards:
                raise BundleError(
                    f"t={t} targets do not divide over target_shards="
                    f"{target_shards} for sharded load")
            if target_shards > jax.device_count():
                raise BundleError(
                    f"sharded load wants {target_shards} devices, have "
                    f"{jax.device_count()}")
            plan = ShardingPlan(data_shards=1, target_shards=target_shards,
                                data_axis=cfg.data_axis,
                                target_axis=cfg.target_axis)
            mesh = plan.build_mesh()
            Wj = jax.device_put(
                Wj, NamedSharding(mesh, P(None, plan.target_axis)))
        enc = BrainEncoder(cfg)
        band = arrays.get("band_lambdas")
        enc.report_ = EncodingReport(
            weights=Wj,
            best_lambda=np.asarray(arrays["best_lambda"]),
            cv_scores=np.asarray(arrays["cv_scores"]),
            lambdas=tuple(m["report"]["lambdas"]),
            decision=self.decision(),
            band_lambdas=None if band is None else np.asarray(band))
        enc.standardizer_ = self.load_standardizer(arrays)
        return enc


__all__ = ["BundleError", "EncoderBundle", "save_bundle", "BUNDLE_MANIFEST",
           "config_to_dict", "config_from_dict"]
