"""EncoderService — mixed-wave prediction serving over a registry.

The LLM side of this repo serves decode traffic in fixed-shape *waves*
(``serving.engine.ServeEngine``: pad/stack → one compiled program reused
across waves).  This module is the same deployment pattern adapted to
encoding and hardened for the multi-tenant fleet: concurrent
``PredictRequest``\\ s are micro-batched per model, their rows concatenated
and cut into fixed-shape waves (the ragged tail zero-padded), and every
wave runs ONE compiled program per wave shape.  Fixed shapes mean one
compilation per distinct wave shape, reused forever after: the
``compile_count`` attribute counts actual traces and the fleet CI lane
asserts it equals the number of wave buckets actually flown.

**Mixed waves** (the fleet front-end).  Scored and unscored requests —
from any number of tenants — pack into the SAME waves.  The compiled
program (``_predict_mixed``) takes, next to the padded feature block, a
per-row request one-hot (``(wave_rows, score_slots)``; the
``foldstats._FixedShapeUpdate`` masking pattern) and a per-slot Pearson
sum carry, and emits the wave's predictions plus the updated ``(s, 5, t)``
running sums — so one program serves the whole traffic mix and the old
private-wave path for scored requests is retired.

Two exactness properties make the packed serve BIT-identical to serving
each request alone (the replay harness gates this):

* **Row independence.**  Each prediction row is ``x @ W`` standardized /
  de-standardized elementwise — the compiled program is keyed only by the
  wave shape, and a row's output never depends on what the other rows
  hold, so packing requests together (or padding with zeros) cannot
  change any row's bits.
* **Sequential sum chaining.**  The per-slot Pearson sums are reduced by
  a sequential scan over the wave's rows, seeded with the slot's carry
  from the request's previous wave.  A row whose one-hot weight is zero
  contributes an exact ``±0`` — and adding ``±0`` to a float accumulator
  is exact — so a request's final sums are the SAME sequential f32 chain
  over its own rows whether they sit at wave offset 0 (served alone) or
  anywhere inside a shared wave, for every wave-bucket ladder and cut.
  (A lane-parallel ``jnp.sum`` would regroup the chain by absolute row
  position and break this.)

Wave shapes come from ``wave_buckets`` (2–3 ladder sizes, each compiled
once, picked per wave by the rows remaining — mixed small/large traffic
stops paying the big shape's pad fraction) or the single ``wave_rows``;
``ServiceStats`` records pad economics per bucket AND per tenant
(rows/bytes/requests/errors — the fleet's accounting unit).

**Graceful degradation.**  A model whose bundle fails to load or
materialise mid-serve (truncated shard, flipped manifest bytes, eviction
race) degrades ONLY its own requests: the typed ``BundleError`` /
``RegistryError`` is surfaced on each affected ``PredictResult.error``,
the bundle is evicted, and the batch's other tenants are served normally.
Malformed *requests* still refuse the whole batch up front (pass 1), so a
bad client cannot waste another tenant's completed device work.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Sequence

import numpy as np

from repro import obs
from repro.serving_encoders.bundle import BundleError
from repro.serving_encoders.registry import EncoderRegistry, RegistryError


class ServiceError(ValueError):
    """Malformed request: unknown model is handled by the registry; this is
    for empty/shape-mismatched feature blocks and admission rejections."""


@dataclasses.dataclass
class PredictRequest:
    """One client request: raw (un-standardized) stimulus features for one
    model, optionally with measured targets to score against.  ``tenant``
    is the accounting principal (defaults to the model name)."""

    model: str
    features: np.ndarray                 # (rows, p) raw features
    targets: np.ndarray | None = None    # (rows, t) → score with Pearson r
    tenant: str | None = None

    @property
    def tenant_id(self) -> str:
        return self.tenant if self.tenant is not None else self.model


@dataclasses.dataclass
class PredictResult:
    model: str
    predictions: np.ndarray | None       # (rows, t) raw-unit predictions
    pearson_r: np.ndarray | None = None  # (t,) when targets were given
    # Typed load/serve fault (BundleError/RegistryError) that degraded
    # this request — the fleet's per-tenant failure unit.  None = served.
    error: Exception | None = None


@dataclasses.dataclass
class ServiceStats:
    waves: int = 0
    rows: int = 0                        # real (unpadded) rows served
    pad_rows: int = 0                    # zero rows added to fill waves
    requests: int = 0
    # Per wave shape actually flown: {wave_rows: {"waves", "rows",
    # "pad_rows"}} — the observable pad economics of bucketing.
    per_bucket: dict = dataclasses.field(default_factory=dict)
    # Per tenant: {"rows", "bytes", "requests", "scored", "errors",
    # "rejected"} — the fleet's accounting unit (bytes = feature + target
    # payload served for the tenant).
    per_tenant: dict = dataclasses.field(default_factory=dict)

    def record_wave(self, wave_rows: int, real: int) -> None:
        b = self.per_bucket.setdefault(
            wave_rows, {"waves": 0, "rows": 0, "pad_rows": 0})
        b["waves"] += 1
        b["rows"] += real
        b["pad_rows"] += wave_rows - real
        self.waves += 1
        self.pad_rows += wave_rows - real
        m = obs.get_metrics()
        m.counter("waves", bucket=wave_rows).inc()
        m.counter("wave_rows").inc(real)
        m.counter("wave_pad_rows").inc(wave_rows - real)

    def tenant(self, tenant: str) -> dict:
        return self.per_tenant.setdefault(
            tenant, {"rows": 0, "bytes": 0, "requests": 0, "scored": 0,
                     "errors": 0, "rejected": 0})

    def record_request(self, tenant: str, rows: int, nbytes: int,
                       scored: bool) -> None:
        acct = self.tenant(tenant)
        acct["rows"] += rows
        acct["bytes"] += nbytes
        acct["requests"] += 1
        acct["scored"] += int(scored)
        obs.get_metrics().counter("tenant_rows", tenant=tenant).inc(rows)

    def record_error(self, tenant: str) -> None:
        self.tenant(tenant)["errors"] += 1

    def record_rejected(self, tenant: str) -> None:
        self.tenant(tenant)["rejected"] += 1

    def to_dict(self) -> dict:
        """Shared ``repro.obs`` stats schema (kind ``"service"``) — the
        shape ``launch/serve.py``, the benches, and the fleet workers
        report, mergeable across processes by summing the flat fields."""
        return {
            "schema": obs.SCHEMA_VERSION,
            "kind": "service",
            "waves": int(self.waves),
            "rows": int(self.rows),
            "pad_rows": int(self.pad_rows),
            "requests": int(self.requests),
            "per_bucket": {int(k): dict(v)
                           for k, v in sorted(self.per_bucket.items())},
            "per_tenant": {k: dict(v)
                           for k, v in sorted(self.per_tenant.items())},
        }


# -- mixed-wave packing ------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WaveSegment:
    """Rows ``[req_lo, req_hi)`` of request ``req`` land at wave offset
    ``wave_lo``; ``slot`` is the request's Pearson score slot within this
    wave (None = unscored)."""

    req: int
    req_lo: int
    req_hi: int
    wave_lo: int
    slot: int | None


@dataclasses.dataclass(frozen=True)
class MixedWave:
    rows: int                    # wave shape flown (a bucket size)
    fill: int                    # real rows (rows - fill are padding)
    segments: tuple              # WaveSegment, contiguous from offset 0


def plan_mixed_waves(req_rows: Sequence[int], scored: Sequence[bool],
                     next_wave: Callable[[int], int],
                     score_slots: int) -> list[MixedWave]:
    """Pack ragged scored/unscored requests into fixed-shape mixed waves.

    Requests flow into waves in arrival order; ``next_wave(remaining)``
    picks each wave's shape (the bucket-ladder policy).  Every scored
    request intersecting a wave holds one of the wave's ``score_slots``
    one-hot slots; when a NEW scored request would need a slot and none is
    free the wave closes early (its tail rows become padding) — the slot
    count is static so the compiled program's shape never changes.

    Pure and deterministic: the property harness replays plans against a
    per-request reference serve, and the fleet bench replays the same
    traffic trace through the same packer.
    """
    if score_slots < 1:
        raise ServiceError(f"score_slots must be >= 1, got {score_slots}")
    waves: list[MixedWave] = []
    remaining = sum(req_rows)
    r, done = 0, 0                      # cursor: request index, rows consumed
    while remaining:
        w = next_wave(remaining)
        fill, slots = 0, 0
        segs: list[WaveSegment] = []
        while fill < w and r < len(req_rows):
            rows = req_rows[r]
            if done == rows:                       # exhausted → advance
                r, done = r + 1, 0
                continue
            slot = None
            if scored[r]:
                if slots == score_slots:
                    break                          # close the wave early
                slot, slots = slots, slots + 1
            take = min(w - fill, rows - done)
            segs.append(WaveSegment(r, done, done + take, fill, slot))
            fill += take
            done += take
            remaining -= take
            if done == rows:
                r, done = r + 1, 0
        waves.append(MixedWave(rows=w, fill=fill, segments=tuple(segs)))
    return waves


class EncoderService:
    """Micro-batching mixed-wave server over an ``EncoderRegistry``.

    >>> service = EncoderService(registry, wave_buckets=(32, 128))
    >>> results = service.serve([PredictRequest("sub-01", X1),
    ...                          PredictRequest("sub-02", X2, targets=Y2)])

    Requests for the same model are packed together — scored and unscored
    alike — so many small concurrent requests cost the same compiled
    program as one large one.  Wave shapes come from ``wave_buckets`` when
    given (each compiled once, picked per wave by the rows remaining) or
    the single ``wave_rows`` otherwise; ``serve(..., wave_rows=...)`` pins
    one shape per call.  Every distinct (program, wave shape) pair
    compiles exactly once per service lifetime — ``compile_count`` counts
    actual traces, and mixing scored/unscored traffic never adds one.

    ``score_slots`` bounds how many scored requests share one wave (the
    static one-hot width); ``prefetch_next=True`` touches the registry for
    the NEXT queued model on a background thread while the current model's
    waves are in flight (hot-bundle prefetch — needs the registry's lock,
    which ``EncoderRegistry`` always holds across mutations).
    """

    def __init__(self, registry: EncoderRegistry, *, wave_rows: int = 128,
                 wave_buckets: Sequence[int] | None = None,
                 score_slots: int = 4, prefetch_next: bool = False,
                 return_predictions: bool = True):
        import jax
        import jax.numpy as jnp

        self.registry = registry
        if wave_rows < 1:
            raise ServiceError(f"wave_rows must be >= 1, got {wave_rows}")
        self.wave_rows = wave_rows
        if wave_buckets is not None:
            wave_buckets = tuple(sorted({int(b) for b in wave_buckets}))
            if not wave_buckets or wave_buckets[0] < 1:
                raise ServiceError(f"wave_buckets must be positive ints, "
                                   f"got {wave_buckets}")
        self.wave_buckets = wave_buckets
        if score_slots < 1:
            raise ServiceError(f"score_slots must be >= 1, "
                               f"got {score_slots}")
        self.score_slots = score_slots
        self.prefetch_next = prefetch_next
        self.return_predictions = return_predictions
        self.compiles = obs.CompileCounter("service.predict")
        self._seen_shapes: set = set()
        self.stats = ServiceStats()

        def _predict(X, W, mu_x, sd_x, mu_y, sd_y):
            # Python side effect at TRACE time: runs once per distinct
            # (wave shape, weight shape/dtype/sharding) signature — the
            # compile counter the serving bench/CI lane asserts on.
            self.compiles.mark()
            Xs = (X - mu_x) / sd_x
            P = jnp.matmul(Xs, W, preferred_element_type=jnp.float32)
            return P * sd_y + mu_y

        def _predict_mixed(X, Yt, onehot, sums_in, W, mu_x, sd_x,
                           mu_y, sd_y):
            # THE fleet program: predictions for the whole mixed wave plus
            # the per-slot Pearson running sums, chained through sums_in.
            # The reduction over rows is a SEQUENTIAL scan (unrolled in
            # blocks of 8, still one chain): zero-weight rows add exact
            # ±0, so a request's sums are bit-identical at any wave
            # offset/cut to serving it alone — the replay-harness gate.
            self.compiles.mark()
            Xs = (X - mu_x) / sd_x
            P = jnp.matmul(Xs, W, preferred_element_type=jnp.float32)
            P = P * sd_y + mu_y
            m = X.shape[0]
            m8 = -(-m // 8) * 8
            pad = ((0, m8 - m), (0, 0))
            Yp = jnp.pad(Yt, pad)
            Pp = jnp.pad(P, pad)
            wp = jnp.pad(onehot, pad)               # pad rows: weight 0

            def step(sums, blk):
                y8, p8, w8 = blk                    # (8, t) (8, t) (8, s)
                for i in range(8):                  # sequential, in order
                    y, q, w = y8[i], p8[i], w8[i]
                    terms = jnp.stack([y, q, y * y, q * q, y * q])
                    sums = sums + w[:, None, None] * terms[None]
                return sums, None

            import jax as _jax
            sums_out, _ = _jax.lax.scan(
                step, sums_in,
                (Yp.reshape(m8 // 8, 8, -1), Pp.reshape(m8 // 8, 8, -1),
                 wp.reshape(m8 // 8, 8, -1)))
            return P, sums_out

        self._predict = jax.jit(_predict)
        self._predict_mixed = jax.jit(_predict_mixed)

    @property
    def compile_count(self) -> int:
        """Total traces of the two serve programs (thin alias over the
        shared :class:`repro.obs.CompileCounter`)."""
        return self.compiles.count

    def _expect_shape(self, key: tuple):
        """Strict-sentinel window for one wave flight: a shape key seen
        before must trace 0 new programs; a fresh key is allowed exactly
        one.  Under ``REPRO_OBS_STRICT=1`` a violation raises at trace
        time (``obs.RecompileError``) instead of skewing the counter."""
        fresh = key not in self._seen_shapes
        self._seen_shapes.add(key)
        return self.compiles.expect(at_most=1 if fresh else 0)

    # -- wave planning -------------------------------------------------------
    def _plan_waves(self, n_rows: int, wave_rows: int | None) -> list[int]:
        """Wave shapes covering ``n_rows``: the pinned single shape, or a
        bucket-ladder plan — the largest bucket while full waves remain,
        then the min-pad cover of the tail (a single bucket that swallows
        it, or the greedy descending ladder when that pads less — e.g. a
        33-row tail on (32, 128) flies 32+32, pad 31, not 128, pad 95);
        equal pad prefers the single wave (fewer dispatches)."""
        if wave_rows is not None or self.wave_buckets is None:
            w = wave_rows if wave_rows is not None else self.wave_rows
            return [w] * -(-n_rows // w)
        big = self.wave_buckets[-1]
        sizes = [big] * (n_rows // big)
        tail = n_rows - big * len(sizes)
        if not tail:
            return sizes
        single = [next(b for b in self.wave_buckets if b >= tail)]
        ladder, rem = [], tail
        for b in reversed(self.wave_buckets):
            take = rem // b
            ladder += [b] * take
            rem -= b * take
        if rem:
            ladder.append(self.wave_buckets[0])
        return sizes + (ladder if sum(ladder) < single[0] else single)

    def _next_wave(self, remaining: int, wave_rows: int | None) -> int:
        """First wave of the ladder plan for ``remaining`` rows — the
        incremental form the mixed packer re-plans with after an early
        (slot-exhausted) wave close."""
        return self._plan_waves(remaining, wave_rows)[0]

    def _pad(self, block: np.ndarray, rows: int) -> np.ndarray:
        pad = rows - block.shape[0]
        if not pad:
            return block
        return np.concatenate(
            [block, np.zeros((pad, block.shape[1]), np.float32)])

    # -- windowed serving (whole-brain bundles) ------------------------------
    def predict_columns(self, model: str, features: np.ndarray,
                        col_range: tuple[int, int], *,
                        wave_rows: int | None = None) -> np.ndarray:
        """Predict ONE target-column window of one model.

        The whole-brain serving path: the registry pages in (and charges)
        only the weight column shards overlapping ``col_range`` — a
        request for 2k voxels of a 262k-voxel bundle faults in one mmap'd
        shard, not the ``p·t`` matrix.  Rows fly in the same fixed-shape
        waves as ``serve`` and each (wave shape, shard width) pair
        compiles once, reused across shards, waves, and calls.

        Returns the ``(rows, hi - lo)`` raw-unit predictions.
        """
        import jax.numpy as jnp

        lo, hi = col_range
        bundle = self.registry.bundle(model)
        p, t = bundle.shape
        if not (0 <= lo < hi <= t):
            raise ServiceError(f"column window [{lo}, {hi}) invalid for "
                               f"{model!r} with t={t}")
        feats = np.asarray(features, np.float32)
        if feats.ndim != 2 or feats.shape[1] != p or not feats.size:
            raise ServiceError(f"request for {model!r}: features "
                               f"{feats.shape} incompatible with p={p}")
        if wave_rows is not None and wave_rows < 1:
            raise ServiceError(f"wave_rows must be >= 1, got {wave_rows}")
        max_wave = wave_rows if wave_rows is not None else (
            self.wave_buckets[-1] if self.wave_buckets else self.wave_rows)
        shards = self.registry.get_columns(model, (lo, hi),
                                           wave_rows=max_wave)
        first_lo = shards[0].bounds[0]
        # Enqueue all (wave × shard) programs before any host pull —
        # async dispatch overlaps them with the padding of later waves.
        parts, counts = [], []
        pos = 0
        for w in self._plan_waves(feats.shape[0], wave_rows):
            with obs.span("serve.wave.build", rows=w, model=model):
                chunk = jnp.asarray(self._pad(feats[pos:pos + w], w))
            real = min(w, feats.shape[0] - pos)
            outs = []
            with obs.span("serve.wave.execute", rows=w,
                          shards=len(shards)):
                for e in shards:
                    with self._expect_shape(
                            ("predict", w, p, int(e.W.shape[1]))):
                        outs.append(self._predict(chunk, e.W, e.mu_x,
                                                  e.sd_x, e.mu_y, e.sd_y))
            parts.append(outs)
            counts.append(real)
            self.stats.record_wave(w, real)
            pos += w
        host = []
        for outs, c in zip(parts, counts):
            row = (np.concatenate([np.asarray(o) for o in outs], axis=1)
                   if len(outs) > 1 else np.asarray(outs[0]))
            host.append(row[:c])
        out = np.concatenate(host) if len(host) > 1 else host[0]
        self.stats.rows += feats.shape[0]
        self.stats.requests += 1
        return out[:, lo - first_lo:hi - first_lo]

    # -- serving -------------------------------------------------------------
    def _serve_group(self, model: str, reqs: list[PredictRequest],
                     blocks: list[np.ndarray], t: int, max_wave: int,
                     wave_rows: int | None) -> list[PredictResult]:
        """Fly one model's packed mixed waves; results in ``reqs`` order."""
        import jax.numpy as jnp

        from repro.kernels import ops

        entry = self.registry.get(model, wave_rows=max_wave,
                                  score_slots=self.score_slots)
        enc_args = (entry.weights, entry.mu_x, entry.sd_x,
                    entry.mu_y, entry.sd_y)
        s = self.score_slots
        scored = [r.targets is not None for r in reqs]
        targets = [None if r.targets is None
                   else np.asarray(r.targets, np.float32) for r in reqs]
        plan = plan_mixed_waves(
            [b.shape[0] for b in blocks], scored,
            lambda rem: self._next_wave(rem, wave_rows), s)

        # Per-request running Pearson sums — the f32 chain the compiled
        # scan continues from wave to wave (exact, see module docstring).
        req_sums = {j: np.zeros((5, t), np.float32)
                    for j, sc in enumerate(scored) if sc}
        p = blocks[0].shape[1]
        flown: list[tuple[MixedWave, object]] = []
        for wave in plan:
            with obs.span("serve.wave.build", rows=wave.rows,
                          fill=wave.fill, model=model):
                X = np.zeros((wave.rows, p), np.float32)
                Yt = np.zeros((wave.rows, t), np.float32)
                onehot = np.zeros((wave.rows, s), np.float32)
                sums_in = np.zeros((s, 5, t), np.float32)
                has_scored = False
                for seg in wave.segments:
                    dst = slice(seg.wave_lo,
                                seg.wave_lo + seg.req_hi - seg.req_lo)
                    X[dst] = blocks[seg.req][seg.req_lo:seg.req_hi]
                    if seg.slot is not None:
                        has_scored = True
                        Yt[dst] = targets[seg.req][seg.req_lo:seg.req_hi]
                        onehot[dst, seg.slot] = 1.0
                        sums_in[seg.slot] = req_sums[seg.req]
            with obs.span("serve.wave.execute", rows=wave.rows,
                          fill=wave.fill, model=model):
                with self._expect_shape(("mixed", wave.rows, p, t, s)):
                    P, sums_out = self._predict_mixed(
                        jnp.asarray(X), jnp.asarray(Yt), jnp.asarray(onehot),
                        jnp.asarray(sums_in), *enc_args)
                self.stats.record_wave(wave.rows, wave.fill)
                if has_scored:
                    # The chain is a data dependency: the slot carries must
                    # land on host before the request's NEXT wave is built.
                    # Unscored waves stay fully async-enqueued.
                    host_sums = np.asarray(sums_out)
                    for seg in wave.segments:
                        if seg.slot is not None:
                            req_sums[seg.req] = host_sums[seg.slot]
            flown.append((wave, P))

        out_pred = None
        if self.return_predictions:
            out_pred = {j: np.empty((b.shape[0], t), np.float32)
                        for j, b in enumerate(blocks)}
            for wave, P in flown:
                host = np.asarray(P)
                for seg in wave.segments:
                    out_pred[seg.req][seg.req_lo:seg.req_hi] = \
                        host[seg.wave_lo:seg.wave_lo + seg.req_hi - seg.req_lo]

        results = []
        for j, req in enumerate(reqs):
            r = None
            if scored[j]:
                # Finalise from the accumulated chain with the kernel's
                # formula — identical sums (packed vs alone) → identical r.
                r = np.asarray(ops.pearson_r_from_sums(
                    req_sums[j].astype(np.float64), blocks[j].shape[0]))
            results.append(PredictResult(
                model=model,
                predictions=None if out_pred is None else out_pred[j],
                pearson_r=r))
            self.stats.rows += blocks[j].shape[0]
            self.stats.record_request(
                req.tenant_id, blocks[j].shape[0],
                blocks[j].nbytes + (targets[j].nbytes if scored[j] else 0),
                scored[j])
        return results

    def _prefetch(self, model: str, max_wave: int) -> None:
        """Hot-bundle prefetch: touch the registry for the next queued
        model while the current model's waves are in flight.  Faults stay
        silent here — they surface (typed, per request) when the model is
        actually served."""
        try:
            self.registry.get(model, wave_rows=max_wave,
                              score_slots=self.score_slots)
        except Exception:
            pass

    def serve(self, requests: Sequence[PredictRequest], *,
              wave_rows: int | None = None) -> list[PredictResult]:
        if wave_rows is not None and wave_rows < 1:
            raise ServiceError(f"wave_rows must be >= 1, got {wave_rows}")
        # The largest shape this call may fly — what the residency account
        # must be charged at.
        max_wave = wave_rows if wave_rows is not None else (
            self.wave_buckets[-1] if self.wave_buckets else self.wave_rows)
        # Micro-batch: group request indices per model, preserving arrival
        # order within each model's queue.
        groups: dict[str, list[int]] = {}
        for i, req in enumerate(requests):
            groups.setdefault(req.model, []).append(i)

        # Pass 1 — validate EVERY request (features and targets) against
        # its bundle's MANIFEST before any device work, so one malformed
        # request cannot discard another model's completed predictions.
        # Manifest-only access keeps nothing resident: a batch spanning
        # more models than the registry budget fits must not pin them all
        # at once, so loading waits for pass 2 (one model at a time).
        prepared: dict[str, list] = {}
        for model, idxs in groups.items():
            p, t = self.registry.bundle(model).shape
            # A model whose bundle could never fit the budget at this wave
            # size dooms the batch — refuse before ANY model's compute.
            self.registry.ensure_servable(model, wave_rows=max_wave,
                                          score_slots=self.score_slots)
            blocks = []
            for i in idxs:
                feats = np.asarray(requests[i].features, np.float32)
                if feats.ndim != 2 or feats.shape[1] != p or not feats.size:
                    raise ServiceError(
                        f"request for {model!r}: features {feats.shape} "
                        f"incompatible with the bundle's p={p}")
                if requests[i].targets is not None and \
                        np.shape(requests[i].targets) != (feats.shape[0], t):
                    raise ServiceError(
                        f"request for {model!r}: targets "
                        f"{np.shape(requests[i].targets)} != expected "
                        f"({feats.shape[0]}, {t})")
                blocks.append(feats)
            prepared[model] = blocks

        # Pass 2 — load (LRU touch, residency charged at the largest wave
        # actually flown), pack, and fly each model's mixed waves.  A
        # load/serve fault degrades ONLY that model's requests.
        results: list[PredictResult | None] = [None] * len(requests)
        order = list(groups)
        pending: threading.Thread | None = None
        for gi, model in enumerate(order):
            if pending is not None:
                pending.join()                     # prefetched THIS model
                pending = None
            if self.prefetch_next and gi + 1 < len(order):
                pending = threading.Thread(
                    target=self._prefetch, args=(order[gi + 1], max_wave),
                    daemon=True)
                pending.start()
            idxs = groups[model]
            t = self.registry.bundle(model).shape[1]
            try:
                group_results = self._serve_group(
                    model, [requests[i] for i in idxs], prepared[model],
                    t, max_wave, wave_rows)
            except (BundleError, RegistryError) as err:
                # Graceful degradation: evict the faulty bundle, surface
                # the typed error on each of the model's requests, keep
                # serving the other tenants.
                self.registry.evict(model)
                group_results = []
                for i in idxs:
                    self.stats.record_error(requests[i].tenant_id)
                    group_results.append(PredictResult(
                        model=model, predictions=None, error=err))
            for i, res in zip(idxs, group_results):
                results[i] = res
            self.stats.requests += len(idxs)
        if pending is not None:
            pending.join()
        return results                                 # arrival order


def reference_serve(service: EncoderService,
                    requests: Sequence[PredictRequest], *,
                    wave_rows: int | None = None) -> list[PredictResult]:
    """The per-request reference: each request served ALONE (no packing,
    no wave sharing).  The replay harness and the property tests gate the
    packed mixed-wave serve bit-identical against this."""
    return [service.serve([req], wave_rows=wave_rows)[0]
            for req in requests]


__all__ = ["EncoderService", "MixedWave", "PredictRequest", "PredictResult",
           "ServiceError", "ServiceStats", "WaveSegment", "plan_mixed_waves",
           "reference_serve"]
