"""EncoderService — wave-batched prediction serving over a registry.

The LLM side of this repo serves decode traffic in fixed-shape *waves*
(``serving.engine.ServeEngine``: pad/stack → one compiled program reused
across waves).  This module is the same deployment pattern adapted to
encoding: concurrent ``PredictRequest``\\ s are micro-batched per model,
their rows concatenated and cut into fixed ``wave_rows``-row waves (the
ragged tail zero-padded), and each wave runs ONE compiled program —
standardize → ``X @ W`` → de-standardize — whose compilation is keyed by
the wave shape (plus the weight shape/dtype/sharding).  Fixed shapes mean
one compilation per distinct wave shape, reused forever after: the
``compile_count`` attribute counts actual traces and the serving CI lane
asserts it equals the number of distinct shapes served.

Scoring rides along: a request that carries ``targets`` gets its per-target
Pearson r (the paper's §4.1 metric) computed on the unpadded rows.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.serving_encoders.registry import EncoderRegistry


class ServiceError(ValueError):
    """Malformed request: unknown model handled by the registry; this is
    for empty/shape-mismatched feature blocks."""


@dataclasses.dataclass
class PredictRequest:
    """One client request: raw (un-standardized) stimulus features for one
    model, optionally with measured targets to score against."""

    model: str
    features: np.ndarray                 # (rows, p) raw features
    targets: np.ndarray | None = None    # (rows, t) → score with Pearson r


@dataclasses.dataclass
class PredictResult:
    model: str
    predictions: np.ndarray | None       # (rows, t) raw-unit predictions
    pearson_r: np.ndarray | None = None  # (t,) when targets were given


@dataclasses.dataclass
class ServiceStats:
    waves: int = 0
    rows: int = 0                        # real (unpadded) rows served
    pad_rows: int = 0                    # zero rows added to fill waves
    requests: int = 0


class EncoderService:
    """Micro-batching wave server over an ``EncoderRegistry``.

    >>> service = EncoderService(registry, wave_rows=128)
    >>> results = service.serve([PredictRequest("sub-01", X1),
    ...                          PredictRequest("sub-02", X2, targets=Y2)])

    Requests for the same model are packed together (their rows
    concatenated before waving), so many small concurrent requests cost
    the same compiled program as one large one.  ``serve(...,
    wave_rows=...)`` overrides the wave shape per call — each distinct
    shape compiles exactly once per service lifetime.
    """

    def __init__(self, registry: EncoderRegistry, *, wave_rows: int = 128,
                 return_predictions: bool = True):
        import jax
        import jax.numpy as jnp

        self.registry = registry
        self.wave_rows = wave_rows
        self.return_predictions = return_predictions
        self.compile_count = 0
        self.stats = ServiceStats()

        def _predict(X, W, mu_x, sd_x, mu_y, sd_y):
            # Python side effect at TRACE time: runs once per distinct
            # (wave shape, weight shape/dtype/sharding) signature — the
            # compile counter the serving bench/CI lane asserts on.
            self.compile_count += 1
            Xs = (X - mu_x) / sd_x
            P = jnp.matmul(Xs, W, preferred_element_type=jnp.float32)
            return P * sd_y + mu_y

        self._predict = jax.jit(_predict)

    # -- serving -------------------------------------------------------------
    def serve(self, requests: Sequence[PredictRequest], *,
              wave_rows: int | None = None) -> list[PredictResult]:
        import jax.numpy as jnp

        from repro.core import scoring

        if wave_rows is None:
            wave_rows = self.wave_rows
        if wave_rows < 1:
            raise ServiceError(f"wave_rows must be >= 1, got {wave_rows}")
        # Micro-batch: group request indices per model, preserving arrival
        # order within each model's queue.
        groups: dict[str, list[int]] = {}
        for i, req in enumerate(requests):
            groups.setdefault(req.model, []).append(i)

        # Pass 1 — validate EVERY request (features and targets) against
        # its bundle's MANIFEST before any device work, so one malformed
        # request cannot discard another model's completed predictions.
        # Manifest-only access keeps nothing resident: a batch spanning
        # more models than the registry budget fits must not pin them all
        # at once, so loading waits for pass 2 (one model at a time).
        prepared: dict[str, list] = {}
        for model, idxs in groups.items():
            p, t = self.registry.bundle(model).shape
            # A model whose bundle could never fit the budget at this wave
            # size dooms the batch — refuse before ANY model's compute.
            self.registry.ensure_servable(model, wave_rows=wave_rows)
            blocks = []
            for i in idxs:
                feats = np.asarray(requests[i].features, np.float32)
                if feats.ndim != 2 or feats.shape[1] != p or not feats.size:
                    raise ServiceError(
                        f"request for {model!r}: features {feats.shape} "
                        f"incompatible with the bundle's p={p}")
                if requests[i].targets is not None and \
                        np.shape(requests[i].targets) != (feats.shape[0], t):
                    raise ServiceError(
                        f"request for {model!r}: targets "
                        f"{np.shape(requests[i].targets)} != expected "
                        f"({feats.shape[0]}, {t})")
                blocks.append(feats)
            prepared[model] = blocks

        # Pass 2 — load (LRU touch, residency charged at the wave size
        # actually flown), wave, and serve each model's packed rows.
        results: list[PredictResult | None] = [None] * len(requests)
        for model, idxs in groups.items():
            blocks = prepared[model]
            entry = self.registry.get(model, wave_rows=wave_rows)
            p, t = entry.bundle.shape
            rows = np.concatenate(blocks) if len(blocks) > 1 else blocks[0]
            n_real = rows.shape[0]

            # Enqueue every wave before pulling any result to host: JAX's
            # async dispatch overlaps the compiled predicts with the
            # host-side padding of subsequent chunks.
            parts, counts = [], []
            for lo in range(0, n_real, wave_rows):
                chunk = rows[lo:lo + wave_rows]
                pad = wave_rows - chunk.shape[0]
                if pad:                                # fixed-shape wave
                    chunk = np.concatenate(
                        [chunk, np.zeros((pad, p), np.float32)])
                    self.stats.pad_rows += pad
                parts.append(self._predict(jnp.asarray(chunk),
                                           entry.weights,
                                           entry.mu_x, entry.sd_x,
                                           entry.mu_y, entry.sd_y))
                counts.append(wave_rows - pad)
                self.stats.waves += 1
            host = [np.asarray(o)[:c] for o, c in zip(parts, counts)]
            preds = np.concatenate(host) if len(host) > 1 else host[0]
            self.stats.rows += n_real
            self.stats.requests += len(idxs)

            pos = 0
            for i, block in zip(idxs, blocks):
                req = requests[i]
                pred_i = preds[pos:pos + block.shape[0]]
                pos += block.shape[0]
                r = None
                if req.targets is not None:
                    Yt = np.asarray(req.targets, np.float32)
                    r = np.asarray(scoring.pearson_r(jnp.asarray(Yt),
                                                     jnp.asarray(pred_i)))
                results[i] = PredictResult(
                    model=model,
                    predictions=pred_i if self.return_predictions else None,
                    pearson_r=r)
        return results                                 # arrival order


__all__ = ["EncoderService", "PredictRequest", "PredictResult",
           "ServiceError", "ServiceStats"]
