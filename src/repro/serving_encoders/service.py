"""EncoderService — wave-batched prediction serving over a registry.

The LLM side of this repo serves decode traffic in fixed-shape *waves*
(``serving.engine.ServeEngine``: pad/stack → one compiled program reused
across waves).  This module is the same deployment pattern adapted to
encoding: concurrent ``PredictRequest``\\ s are micro-batched per model,
their rows concatenated and cut into fixed ``wave_rows``-row waves (the
ragged tail zero-padded), and each wave runs ONE compiled program —
standardize → ``X @ W`` → de-standardize — whose compilation is keyed by
the wave shape (plus the weight shape/dtype/sharding).  Fixed shapes mean
one compilation per distinct wave shape, reused forever after: the
``compile_count`` attribute counts actual traces and the serving CI lane
asserts it equals the number of distinct shapes served.

Two serving refinements ride on the same fixed-shape contract:

* **Wave-shape bucketing** — ``wave_buckets=(32, 128, 512)`` picks each
  wave's shape from a small ladder by the rows left to serve (largest
  bucket while full waves remain, then the smallest bucket that swallows
  the tail) instead of padding everything to one shape.  Each bucket
  compiles once; mixed small/large traffic stops paying the big shape's
  pad fraction.  ``ServiceStats.per_bucket`` records waves/rows/pad per
  shape so the pad economics are observable (``BENCH_serving.json``).
* **Fused scoring** — a request that carries ``targets`` is served by a
  second compiled program that emits, next to the predictions, the five
  per-target Pearson sums of the wave (``kernels.pearsonr`` running
  sums, masked to the valid rows).  The host accumulates the ``(5, t)``
  sums across the request's waves in float64 and finalises r with the
  kernel's formula (``ops.pearson_r_from_sums``) — score-heavy
  evaluation traffic never re-reads the ``(rows, t)`` predictions on the
  host (the paper's §4.1 metric at one extra ``O(t)`` hop).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.serving_encoders.registry import EncoderRegistry


class ServiceError(ValueError):
    """Malformed request: unknown model handled by the registry; this is
    for empty/shape-mismatched feature blocks."""


@dataclasses.dataclass
class PredictRequest:
    """One client request: raw (un-standardized) stimulus features for one
    model, optionally with measured targets to score against."""

    model: str
    features: np.ndarray                 # (rows, p) raw features
    targets: np.ndarray | None = None    # (rows, t) → score with Pearson r


@dataclasses.dataclass
class PredictResult:
    model: str
    predictions: np.ndarray | None       # (rows, t) raw-unit predictions
    pearson_r: np.ndarray | None = None  # (t,) when targets were given


@dataclasses.dataclass
class ServiceStats:
    waves: int = 0
    rows: int = 0                        # real (unpadded) rows served
    pad_rows: int = 0                    # zero rows added to fill waves
    requests: int = 0
    # Per wave shape actually flown: {wave_rows: {"waves", "rows",
    # "pad_rows"}} — the observable pad economics of bucketing.
    per_bucket: dict = dataclasses.field(default_factory=dict)

    def record_wave(self, wave_rows: int, real: int) -> None:
        b = self.per_bucket.setdefault(
            wave_rows, {"waves": 0, "rows": 0, "pad_rows": 0})
        b["waves"] += 1
        b["rows"] += real
        b["pad_rows"] += wave_rows - real
        self.waves += 1
        self.pad_rows += wave_rows - real


class EncoderService:
    """Micro-batching wave server over an ``EncoderRegistry``.

    >>> service = EncoderService(registry, wave_buckets=(32, 128))
    >>> results = service.serve([PredictRequest("sub-01", X1),
    ...                          PredictRequest("sub-02", X2, targets=Y2)])

    Requests for the same model are packed together (their rows
    concatenated before waving), so many small concurrent requests cost
    the same compiled program as one large one.  Wave shapes come from
    ``wave_buckets`` when given (2–3 ladder sizes, each compiled once,
    picked per wave by the rows remaining) or the single ``wave_rows``
    otherwise; ``serve(..., wave_rows=...)`` pins one shape per call.
    Every distinct (program, wave shape) pair compiles exactly once per
    service lifetime — ``compile_count`` counts actual traces.
    """

    def __init__(self, registry: EncoderRegistry, *, wave_rows: int = 128,
                 wave_buckets: Sequence[int] | None = None,
                 return_predictions: bool = True):
        import jax
        import jax.numpy as jnp

        self.registry = registry
        if wave_rows < 1:
            raise ServiceError(f"wave_rows must be >= 1, got {wave_rows}")
        self.wave_rows = wave_rows
        if wave_buckets is not None:
            wave_buckets = tuple(sorted({int(b) for b in wave_buckets}))
            if not wave_buckets or wave_buckets[0] < 1:
                raise ServiceError(f"wave_buckets must be positive ints, "
                                   f"got {wave_buckets}")
        self.wave_buckets = wave_buckets
        self.return_predictions = return_predictions
        self.compile_count = 0
        self.stats = ServiceStats()

        def _predict(X, W, mu_x, sd_x, mu_y, sd_y):
            # Python side effect at TRACE time: runs once per distinct
            # (wave shape, weight shape/dtype/sharding) signature — the
            # compile counter the serving bench/CI lane asserts on.
            self.compile_count += 1
            Xs = (X - mu_x) / sd_x
            P = jnp.matmul(Xs, W, preferred_element_type=jnp.float32)
            return P * sd_y + mu_y

        def _predict_score(X, Yt, n_valid, W, mu_x, sd_x, mu_y, sd_y):
            # The scoring wave: predictions PLUS the five Pearson running
            # sums of the wave's valid rows, so score-heavy traffic never
            # pays a second host-side pass over (rows, t) predictions.
            # Pad rows must be masked — a padded feature row predicts the
            # de-standardized zero-vector response, NOT zero — while the
            # zero-padded targets already add nothing to any sum.
            self.compile_count += 1
            from repro.kernels import ops
            Xs = (X - mu_x) / sd_x
            P = jnp.matmul(Xs, W, preferred_element_type=jnp.float32)
            P = P * sd_y + mu_y
            valid = (jnp.arange(X.shape[0]) < n_valid)[:, None]
            sums = ops.pearson_sums(Yt, jnp.where(valid, P, 0.0))
            return P, sums

        self._predict = jax.jit(_predict)
        self._predict_score = jax.jit(_predict_score)

    # -- wave planning -------------------------------------------------------
    def _plan_waves(self, n_rows: int, wave_rows: int | None) -> list[int]:
        """Wave shapes covering ``n_rows``: the pinned single shape, or a
        bucket-ladder plan — the largest bucket while full waves remain,
        then the min-pad cover of the tail (a single bucket that swallows
        it, or the greedy descending ladder when that pads less — e.g. a
        33-row tail on (32, 128) flies 32+32, pad 31, not 128, pad 95);
        equal pad prefers the single wave (fewer dispatches)."""
        if wave_rows is not None or self.wave_buckets is None:
            w = wave_rows if wave_rows is not None else self.wave_rows
            return [w] * -(-n_rows // w)
        big = self.wave_buckets[-1]
        sizes = [big] * (n_rows // big)
        tail = n_rows - big * len(sizes)
        if not tail:
            return sizes
        single = [next(b for b in self.wave_buckets if b >= tail)]
        ladder, rem = [], tail
        for b in reversed(self.wave_buckets):
            take = rem // b
            ladder += [b] * take
            rem -= b * take
        if rem:
            ladder.append(self.wave_buckets[0])
        return sizes + (ladder if sum(ladder) < single[0] else single)

    def _pad(self, block: np.ndarray, rows: int) -> np.ndarray:
        pad = rows - block.shape[0]
        if not pad:
            return block
        return np.concatenate(
            [block, np.zeros((pad, block.shape[1]), np.float32)])

    # -- windowed serving (whole-brain bundles) ------------------------------
    def predict_columns(self, model: str, features: np.ndarray,
                        col_range: tuple[int, int], *,
                        wave_rows: int | None = None) -> np.ndarray:
        """Predict ONE target-column window of one model.

        The whole-brain serving path: the registry pages in (and charges)
        only the weight column shards overlapping ``col_range`` — a
        request for 2k voxels of a 262k-voxel bundle faults in one mmap'd
        shard, not the ``p·t`` matrix.  Rows fly in the same fixed-shape
        waves as ``serve`` and each (wave shape, shard width) pair
        compiles once, reused across shards, waves, and calls.

        Returns the ``(rows, hi - lo)`` raw-unit predictions.
        """
        import jax.numpy as jnp

        lo, hi = col_range
        bundle = self.registry.bundle(model)
        p, t = bundle.shape
        if not (0 <= lo < hi <= t):
            raise ServiceError(f"column window [{lo}, {hi}) invalid for "
                               f"{model!r} with t={t}")
        feats = np.asarray(features, np.float32)
        if feats.ndim != 2 or feats.shape[1] != p or not feats.size:
            raise ServiceError(f"request for {model!r}: features "
                               f"{feats.shape} incompatible with p={p}")
        if wave_rows is not None and wave_rows < 1:
            raise ServiceError(f"wave_rows must be >= 1, got {wave_rows}")
        max_wave = wave_rows if wave_rows is not None else (
            self.wave_buckets[-1] if self.wave_buckets else self.wave_rows)
        shards = self.registry.get_columns(model, (lo, hi),
                                           wave_rows=max_wave)
        first_lo = shards[0].bounds[0]
        # Enqueue all (wave × shard) programs before any host pull —
        # async dispatch overlaps them with the padding of later waves.
        parts, counts = [], []
        pos = 0
        for w in self._plan_waves(feats.shape[0], wave_rows):
            chunk = jnp.asarray(self._pad(feats[pos:pos + w], w))
            real = min(w, feats.shape[0] - pos)
            parts.append([self._predict(chunk, e.W, e.mu_x, e.sd_x,
                                        e.mu_y, e.sd_y) for e in shards])
            counts.append(real)
            self.stats.record_wave(w, real)
            pos += w
        host = []
        for outs, c in zip(parts, counts):
            row = (np.concatenate([np.asarray(o) for o in outs], axis=1)
                   if len(outs) > 1 else np.asarray(outs[0]))
            host.append(row[:c])
        out = np.concatenate(host) if len(host) > 1 else host[0]
        self.stats.rows += feats.shape[0]
        self.stats.requests += 1
        return out[:, lo - first_lo:hi - first_lo]

    # -- serving -------------------------------------------------------------
    def serve(self, requests: Sequence[PredictRequest], *,
              wave_rows: int | None = None) -> list[PredictResult]:
        import jax.numpy as jnp

        from repro.kernels import ops

        if wave_rows is not None and wave_rows < 1:
            raise ServiceError(f"wave_rows must be >= 1, got {wave_rows}")
        # The largest shape this call may fly — what the residency account
        # must be charged at.
        max_wave = wave_rows if wave_rows is not None else (
            self.wave_buckets[-1] if self.wave_buckets else self.wave_rows)
        # Micro-batch: group request indices per model, preserving arrival
        # order within each model's queue.
        groups: dict[str, list[int]] = {}
        for i, req in enumerate(requests):
            groups.setdefault(req.model, []).append(i)

        # Pass 1 — validate EVERY request (features and targets) against
        # its bundle's MANIFEST before any device work, so one malformed
        # request cannot discard another model's completed predictions.
        # Manifest-only access keeps nothing resident: a batch spanning
        # more models than the registry budget fits must not pin them all
        # at once, so loading waits for pass 2 (one model at a time).
        prepared: dict[str, list] = {}
        for model, idxs in groups.items():
            p, t = self.registry.bundle(model).shape
            # A model whose bundle could never fit the budget at this wave
            # size dooms the batch — refuse before ANY model's compute.
            self.registry.ensure_servable(model, wave_rows=max_wave)
            blocks = []
            for i in idxs:
                feats = np.asarray(requests[i].features, np.float32)
                if feats.ndim != 2 or feats.shape[1] != p or not feats.size:
                    raise ServiceError(
                        f"request for {model!r}: features {feats.shape} "
                        f"incompatible with the bundle's p={p}")
                if requests[i].targets is not None and \
                        np.shape(requests[i].targets) != (feats.shape[0], t):
                    raise ServiceError(
                        f"request for {model!r}: targets "
                        f"{np.shape(requests[i].targets)} != expected "
                        f"({feats.shape[0]}, {t})")
                blocks.append(feats)
            prepared[model] = blocks

        # Pass 2 — load (LRU touch, residency charged at the largest wave
        # actually flown), wave, and serve each model's packed rows.
        results: list[PredictResult | None] = [None] * len(requests)
        for model, idxs in groups.items():
            block_of = dict(zip(idxs, prepared[model]))
            entry = self.registry.get(model, wave_rows=max_wave)
            enc_args = (entry.weights, entry.mu_x, entry.sd_x,
                        entry.mu_y, entry.sd_y)
            # Scored requests fly their own waves (their (5, t) Pearson
            # sums are per request); plain requests pack together.
            plain = [i for i in idxs if requests[i].targets is None]
            scored = [i for i in idxs if requests[i].targets is not None]

            # Enqueue every wave before pulling any result to host: JAX's
            # async dispatch overlaps the compiled programs with the
            # host-side padding of subsequent chunks.
            plain_parts, plain_counts = [], []
            if plain:
                rows = (np.concatenate([block_of[i] for i in plain])
                        if len(plain) > 1 else block_of[plain[0]])
                lo = 0
                for w in self._plan_waves(rows.shape[0], wave_rows):
                    chunk = self._pad(rows[lo:lo + w], w)
                    real = min(w, rows.shape[0] - lo)
                    plain_parts.append(self._predict(
                        jnp.asarray(chunk), *enc_args))
                    plain_counts.append(real)
                    self.stats.record_wave(w, real)
                    lo += w
            per_scored: dict[int, tuple[list, list, list]] = {}
            for i in scored:
                block = block_of[i]
                Yt = np.asarray(requests[i].targets, np.float32)
                parts, sums, counts = [], [], []
                lo = 0
                for w in self._plan_waves(block.shape[0], wave_rows):
                    real = min(w, block.shape[0] - lo)
                    P, S = self._predict_score(
                        jnp.asarray(self._pad(block[lo:lo + w], w)),
                        jnp.asarray(self._pad(Yt[lo:lo + w], w)),
                        np.int32(real), *enc_args)
                    parts.append(P)
                    sums.append(S)
                    counts.append(real)
                    self.stats.record_wave(w, real)
                    lo += w
                per_scored[i] = (parts, sums, counts)

            # Pull to host and reassemble in arrival order.
            host = [np.asarray(o)[:c]
                    for o, c in zip(plain_parts, plain_counts)]
            preds = (np.concatenate(host) if len(host) > 1
                     else host[0] if host else None)
            pos = 0
            for i in plain:
                m = block_of[i].shape[0]
                results[i] = PredictResult(
                    model=model,
                    predictions=(preds[pos:pos + m]
                                 if self.return_predictions else None))
                pos += m
                self.stats.rows += m
            for i in scored:
                parts, sums, counts = per_scored[i]
                n_real = sum(counts)
                # Accumulate the five per-target sums across the request's
                # waves in float64, then finalise with the kernel formula
                # — one O(t) hop instead of an O(rows·t) host re-read.
                total = np.zeros(np.shape(sums[0]), np.float64)
                for S in sums:
                    total += np.asarray(S, np.float64)
                r = np.asarray(ops.pearson_r_from_sums(total, n_real))
                pred_i = None
                if self.return_predictions:
                    hp = [np.asarray(o)[:c] for o, c in zip(parts, counts)]
                    pred_i = np.concatenate(hp) if len(hp) > 1 else hp[0]
                results[i] = PredictResult(model=model, predictions=pred_i,
                                           pearson_r=r)
                self.stats.rows += n_real
            self.stats.requests += len(idxs)
        return results                                 # arrival order


__all__ = ["EncoderService", "PredictRequest", "PredictResult",
           "ServiceError", "ServiceStats"]
