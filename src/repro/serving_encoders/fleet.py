"""Fleet tier — N serving workers, one artifact directory, shared state.

Three pieces turn the single-process ``EncoderRegistry``/``EncoderService``
pair into a multi-tenant fleet:

* ``ResidencyMap`` — a small on-disk JSON map (``residency.json`` next to
  the bundles) recording which worker holds which bundles resident and at
  what byte charge.  Every update takes an ``fcntl.flock`` on a sidecar
  lock file and rewrites the map atomically (tmp + rename, the
  ``RunStore`` manifest idiom), so N worker *processes* see one coherent
  fleet view: who is hot for a model (route there, page cache is warm),
  and what the fleet-wide resident total is.
* ``FleetRegistry`` — an ``EncoderRegistry`` that publishes its residency
  transitions (loads AND evictions, including LRU pressure evictions) to
  a ``ResidencyMap`` under its worker id.  Weight reads stay mmap'd
  read-only (the registry default), so co-located workers share the OS
  page cache for the bytes themselves — the map shares only the
  *bookkeeping*.
* ``FleetFrontend`` — continuous admission under a latency SLO: a bounded
  queue in ROWS (the unit the SLO budget is actually spent on).  A
  ``submit`` that would overflow the bound is REJECTED with a typed
  ``ServiceError`` (recorded per tenant in ``ServiceStats``) — the
  backpressure contract is "reject early, never OOM or stall".  ``flush``
  drains the queue through one mixed-wave ``serve`` call, so everything
  admitted in a window packs into shared waves; the service's
  ``prefetch_next`` touches the registry for the next queued model while
  the current model's waves are in flight.

Workers are launched by ``repro.launch.serve --workers N`` — each worker
is its own process with its own device copies; what they share is the
artifact directory (page cache) and the residency map (state).
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import time
from typing import Sequence

import numpy as np

from repro import obs
from repro.serving_encoders.registry import EncoderRegistry
from repro.serving_encoders.service import (
    EncoderService, PredictRequest, PredictResult, ServiceError,
)

RESIDENCY_MAP = "residency.json"


class FleetError(RuntimeError):
    """Fleet coordination fault (lock-acquire timeout, lease violation)."""


class WorkerLost(FleetError):
    """The worker serving a batch died mid-flight.  Raised by transports /
    the fault-injection harness; ``FleetFrontend.flush`` re-admits the
    batch instead of dropping it, and ``replay`` retries the drain."""


class ResidencyMap:
    """File-lock-guarded on-disk residency map shared by fleet workers.

    Layout::

        {"workers": {"<worker>": {"models": {"<model>": bytes},
                                  "resident_bytes": int,
                                  "loads": int, "evictions": int,
                                  "heartbeat": float}}}

    Every mutation runs read-modify-write under an exclusive ``flock`` on
    ``<path>.lock`` and lands via tmp + ``os.replace`` — concurrent
    workers serialize on the lock and a crashed writer never leaves a
    torn map.  The map is *bookkeeping only*: losing it costs telemetry,
    never correctness.

    **Leases, not assertions.**  Each worker row is heartbeat-stamped
    (``publish``/``heartbeat`` refresh the stamp); a row whose stamp is
    older than a TTL is a DEAD worker's stale claim — ``expire_dead``
    reaps such rows and ``holders(ttl_s=...)`` ignores them, so routing
    never trusts a holder that stopped proving it is alive.

    **Bounded lock wait.**  A worker killed while holding the fcntl lock
    releases it with its fd (the OS guarantees that), but a *wedged*
    holder would block every peer forever — ``lock_timeout_s`` bounds the
    acquire with a typed :class:`FleetError` instead.  ``clock``/``sleep``
    are injectable so lease/lock tests run on virtual time.
    """

    def __init__(self, path: str, *, lock_timeout_s: float = 30.0,
                 clock=time.time, sleep=time.sleep):
        self.path = path
        self.lock_timeout_s = lock_timeout_s
        self._clock = clock
        self._sleep = sleep
        self._lockpath = path + ".lock"
        os.makedirs(os.path.dirname(os.path.abspath(path)) or ".",
                    exist_ok=True)

    def _locked(self):
        import fcntl

        timeout = self.lock_timeout_s
        clock = self._clock
        sleep = self._sleep
        lockpath = self._lockpath

        class _Lock:
            def __enter__(_self):
                _self.fd = os.open(lockpath, os.O_CREAT | os.O_RDWR, 0o644)
                deadline = clock() + timeout
                while True:
                    try:
                        fcntl.flock(_self.fd, fcntl.LOCK_EX | fcntl.LOCK_NB)
                        return _self.fd
                    except OSError:
                        if clock() >= deadline:
                            os.close(_self.fd)
                            obs.instant("fleet.lock_timeout", path=lockpath)
                            raise FleetError(
                                f"could not acquire residency lock "
                                f"{lockpath} within {timeout}s — a peer "
                                f"worker is wedged while holding it")
                        sleep(0.01)

            def __exit__(_self, *exc):
                import fcntl as _f
                _f.flock(_self.fd, _f.LOCK_UN)
                os.close(_self.fd)
                return False

        return _Lock()

    def _read(self) -> dict:
        if not os.path.exists(self.path):
            return {"workers": {}}
        try:
            with open(self.path) as f:
                return json.load(f)
        except (json.JSONDecodeError, OSError):
            # A torn map should be impossible (atomic replace) but a
            # deleted/garbled one must not take the fleet down.
            return {"workers": {}}

    def _write(self, data: dict) -> None:
        d = os.path.dirname(os.path.abspath(self.path)) or "."
        fd, tmp = tempfile.mkstemp(dir=d, prefix=".tmpresidency_")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(data, f, indent=2, sort_keys=True)
                f.write("\n")
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def publish(self, worker: str, models: dict, *, loads: int = 0,
                evictions: int = 0) -> None:
        """Replace ``worker``'s residency row with ``{model: bytes}``.
        The row is heartbeat-stamped: publishing IS proof of life."""
        with self._locked():
            data = self._read()
            data["workers"][worker] = {
                "models": {m: int(b) for m, b in sorted(models.items())},
                "resident_bytes": int(sum(models.values())),
                "loads": int(loads), "evictions": int(evictions),
                "heartbeat": float(self._clock()),
            }
            self._write(data)

    def heartbeat(self, worker: str) -> None:
        """Refresh ``worker``'s lease stamp without touching its models.
        A worker with no row yet gets an empty one — heartbeating before
        the first load still claims the lease."""
        with self._locked():
            data = self._read()
            row = data["workers"].setdefault(
                worker, {"models": {}, "resident_bytes": 0,
                         "loads": 0, "evictions": 0})
            row["heartbeat"] = float(self._clock())
            self._write(data)

    def expire_dead(self, ttl_s: float, *, now: float | None = None
                    ) -> list[str]:
        """Reap every worker row whose heartbeat is older than ``ttl_s``
        (returned sorted).  Rows written by pre-lease code (no stamp)
        count as dead.  Each expiry bumps the ``lease_expirations``
        counter — a restarted fleet can assert the reap happened."""
        if now is None:
            now = self._clock()
        with self._locked():
            data = self._read()
            dead = sorted(
                w for w, row in data["workers"].items()
                if now - row.get("heartbeat", float("-inf")) > ttl_s)
            for w in dead:
                del data["workers"][w]
            if dead:
                self._write(data)
        for w in dead:
            obs.get_metrics().counter("lease_expirations").inc()
            obs.instant("fleet.lease_expired", worker=w)
        return dead

    def retire(self, worker: str) -> None:
        """Drop a worker's row (clean shutdown)."""
        with self._locked():
            data = self._read()
            if data["workers"].pop(worker, None) is not None:
                self._write(data)

    def snapshot(self) -> dict:
        """Point-in-time copy of the whole map (shared lock not needed:
        reads see either the old or the new atomic file)."""
        return self._read()

    def holders(self, model: str, *, ttl_s: float | None = None
                ) -> list[str]:
        """Workers currently holding ``model`` resident — the routing
        hint: their page cache (and device copy) is warm.  With
        ``ttl_s``, only workers whose lease is fresh count — a dead
        holder's stale claim is never routed to."""
        snap = self._read()
        now = self._clock()
        return sorted(
            w for w, row in snap["workers"].items()
            if model in row.get("models", {})
            and (ttl_s is None
                 or now - row.get("heartbeat", float("-inf")) <= ttl_s))

    def fleet_resident_bytes(self) -> int:
        snap = self._read()
        return sum(row.get("resident_bytes", 0)
                   for row in snap["workers"].values())


class FleetRegistry(EncoderRegistry):
    """An ``EncoderRegistry`` that mirrors its residency into a shared
    ``ResidencyMap`` under ``worker_id`` — loads, LRU evictions, and
    explicit fault evictions all publish, so the fleet view tracks the
    true per-process account (which the in-process lock already keeps
    exact)."""

    def __init__(self, *, worker_id: str, residency_map: ResidencyMap,
                 **kwargs):
        super().__init__(**kwargs)
        self.worker_id = worker_id
        self.residency_map = residency_map

    def _publish(self) -> None:
        with self._lock:
            models = {name: e.resident_bytes
                      for name, e in self._loaded.items()}
            for (name, i), e in self._shards.items():
                key = f"{name}#shard{i}"
                models[key] = e.resident_bytes
            loads = self.loads + self.shard_loads
            evictions = self.evictions
        self.residency_map.publish(self.worker_id, models,
                                   loads=loads, evictions=evictions)

    def get(self, name, *, wave_rows=None, score_slots=0):
        with self._lock:
            before = (self.loads, self.evictions)
            entry = super().get(name, wave_rows=wave_rows,
                                score_slots=score_slots)
            changed = (self.loads, self.evictions) != before
        if changed:
            self._publish()
        return entry

    def get_columns(self, name, col_range, *, wave_rows=None):
        with self._lock:
            before = (self.shard_loads, self.evictions)
            out = super().get_columns(name, col_range, wave_rows=wave_rows)
            changed = (self.shard_loads, self.evictions) != before
        if changed:
            self._publish()
        return out

    def evict(self, name):
        hit = super().evict(name)
        if hit:
            self._publish()
        return hit

    def heartbeat(self) -> None:
        """Refresh this worker's lease (call between serving windows —
        every publish also stamps it, so only an *idle* worker needs
        explicit heartbeats to keep its claims routable)."""
        self.residency_map.heartbeat(self.worker_id)

    def close(self) -> None:
        """Retire this worker's row from the shared map."""
        self.residency_map.retire(self.worker_id)


@dataclasses.dataclass
class _Pending:
    request: PredictRequest
    index: int              # submission order — results come back in it


class FleetFrontend:
    """Bounded-admission front door over an ``EncoderService``.

    >>> fe = FleetFrontend(service, max_pending_rows=4096)
    >>> fe.submit(PredictRequest("sub-01", X))       # admitted (or raises)
    >>> results = fe.flush()                         # one mixed-wave batch

    ``submit`` admits a request only while the queued row total stays
    within ``max_pending_rows`` — the SLO knob: rows are what a wave
    spends latency on, so bounding rows bounds the worst-case drain time.
    Overflow raises a typed ``ServiceError`` (and bumps the tenant's
    ``rejected`` count): the client sheds load instead of the worker
    stalling or OOM-ing.  ``flush`` serves everything admitted so far in
    ONE ``serve`` call — same-model requests pack into shared mixed
    waves, and with ``prefetch_next`` on the service the next model's
    bundle is touched while the current one's waves are in flight.
    """

    def __init__(self, service: EncoderService, *,
                 max_pending_rows: int = 4096):
        if max_pending_rows < 1:
            raise ServiceError(f"max_pending_rows must be >= 1, "
                               f"got {max_pending_rows}")
        self.service = service
        self.max_pending_rows = max_pending_rows
        self._pending: list[_Pending] = []
        self._pending_rows = 0
        self.admitted = 0
        self.rejected = 0
        self.replayed = 0    # requests re-admitted after a lost worker

    @property
    def pending_rows(self) -> int:
        return self._pending_rows

    def submit(self, request: PredictRequest) -> int:
        """Admit one request; returns its submission index within the
        current window.  Raises ``ServiceError`` on backpressure."""
        rows = int(np_rows(request))
        if self._pending_rows + rows > self.max_pending_rows:
            self.rejected += 1
            self.service.stats.record_rejected(request.tenant_id)
            obs.get_metrics().counter("rejected_requests").inc()
            obs.instant("fleet.reject", tenant=request.tenant_id,
                        rows=rows, pending_rows=self._pending_rows)
            raise ServiceError(
                f"admission rejected for tenant {request.tenant_id!r}: "
                f"{rows} rows would put the queue at "
                f"{self._pending_rows + rows} > max_pending_rows="
                f"{self.max_pending_rows} — retry after a flush")
        idx = len(self._pending)
        self._pending.append(_Pending(request, idx))
        self._pending_rows += rows
        self.admitted += 1
        obs.get_metrics().counter("admitted_rows").inc(rows)
        obs.instant("fleet.admit", tenant=request.tenant_id, rows=rows)
        return idx

    def flush(self, *, wave_rows: int | None = None) -> list[PredictResult]:
        """Serve everything admitted since the last flush (one mixed-wave
        batch; results in submission order) and empty the queue.

        If the worker dies with the batch in flight (:class:`WorkerLost`),
        the batch is RE-ADMITTED — the queue is restored exactly as it
        was, ``requests_replayed`` counts the survivors, and the error
        propagates so the caller can retry the flush (``replay`` does).
        """
        if not self._pending:
            return []
        pending = self._pending
        batch = [p.request for p in pending]
        rows = self._pending_rows
        self._pending = []
        self._pending_rows = 0
        with obs.span("fleet.flush", requests=len(batch), rows=rows):
            try:
                return self.service.serve(batch, wave_rows=wave_rows)
            except WorkerLost:
                # The requests died with the worker — put them back in
                # admission order instead of dropping them on the floor.
                self._pending = pending
                self._pending_rows = rows
                self.replayed += len(batch)
                obs.get_metrics().counter("requests_replayed").inc(len(batch))
                obs.instant("fleet.replay", requests=len(batch), rows=rows)
                raise

    def replay(self, requests: Sequence[PredictRequest], *,
               wave_rows: int | None = None, max_flush_attempts: int = 3
               ) -> tuple[list[PredictResult | None], list[Exception]]:
        """Drain a traffic sequence through bounded admission, surviving
        lost workers: requests whose flush dies with a worker stay
        admitted and the flush is retried (up to ``max_flush_attempts``
        per window) — see the module-level :func:`replay`."""
        return replay(self, requests, wave_rows=wave_rows,
                      max_flush_attempts=max_flush_attempts)


def np_rows(request: PredictRequest) -> int:
    return int(np.shape(request.features)[0])


def replay(frontend: FleetFrontend, requests: Sequence[PredictRequest], *,
           wave_rows: int | None = None, max_flush_attempts: int = 3
           ) -> tuple[list[PredictResult | None], list[Exception]]:
    """Replay a traffic sequence through bounded admission: submit until
    backpressure, flush, resubmit — the drain loop every harness uses.
    A flush that dies with its worker (:class:`WorkerLost`) leaves the
    window re-admitted in the frontend (see ``flush``); the drain retries
    it up to ``max_flush_attempts`` times before giving up, so a worker
    lost mid-trace costs a retry, not the requests.  Returns (results in
    arrival order — ``None`` only if a request was rejected twice, i.e.
    it alone overflows the queue — , rejections)."""
    results: list[PredictResult | None] = [None] * len(requests)
    rejections: list[Exception] = []
    window: list[int] = []

    def drain():
        for attempt in range(max_flush_attempts):
            try:
                flushed = frontend.flush(wave_rows=wave_rows)
                break
            except WorkerLost:
                if attempt + 1 >= max_flush_attempts:
                    raise
        for i, res in zip(window, flushed):
            results[i] = res
        window.clear()

    for i, req in enumerate(requests):
        try:
            frontend.submit(req)
            window.append(i)
        except ServiceError as err:
            rejections.append(err)
            drain()
            try:
                frontend.submit(req)
                window.append(i)
            except ServiceError as err2:      # alone it overflows: skip
                rejections.append(err2)
    drain()
    return results, rejections


__all__ = ["FleetFrontend", "FleetRegistry", "ResidencyMap", "RESIDENCY_MAP",
           "FleetError", "WorkerLost", "replay"]
