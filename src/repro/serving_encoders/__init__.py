"""repro.serving_encoders — fitted-encoder artifacts + prediction serving.

The inference side of the fit/predict divide:

* ``bundle``   — ``EncoderBundle``: atomic on-disk persistence of a fitted
  ``BrainEncoder`` (sharded W with bf16-as-u16 storage, μ/σ, selected λ,
  config + dispatch provenance) with eager ``open()`` validation.
  ``BrainEncoder.save(dir)`` / ``BrainEncoder.load(dir)`` round-trip
  through it bit-identically.
* ``registry`` — ``EncoderRegistry``: many bundles, lazy device residency
  under a ``device_memory_budget`` with thread-safe LRU eviction and
  mmap'd read-only weight reads.
* ``service``  — ``EncoderService``: wave-batched compiled prediction —
  fixed-shape padded MIXED waves that pack scored and unscored requests
  from any tenants together (per-row request one-hot → per-slot Pearson
  sums from one compiled program per wave shape), micro-batched
  concurrent requests, per-tenant accounting, typed per-request fault
  degradation.
* ``traffic``  — synthetic fleets + the deterministic mixed-traffic
  trace (``TraceSpec``/``load_trace``/``replay_requests``) that tests and
  ``benchmarks/serving_bench.py`` replay identically.
* ``fleet``    — the multi-worker tier: ``ResidencyMap`` (file-locked
  on-disk residency shared across worker processes), ``FleetRegistry``
  (publishes loads/evictions to the map), ``FleetFrontend`` (bounded
  admission with typed backpressure rejections).

Fit once, serve many::

    enc = BrainEncoder().fit(X_train, Y_train)
    enc.save("bundles/sub-01_L12")

    reg = EncoderRegistry(device_memory_budget=512 * 2**20)
    reg.add("sub-01/L12", "bundles/sub-01_L12")
    service = EncoderService(reg, wave_buckets=(32, 128))
    out = service.serve([PredictRequest("sub-01/L12", X_new),
                         PredictRequest("sub-01/L12", X_val, targets=Y_val)])

Fleet workflow — N workers, one artifact dir, shared page cache::

    # each of N worker processes (launch/serve.py --encoders --workers N):
    rmap = ResidencyMap(os.path.join(workdir, RESIDENCY_MAP))
    reg = FleetRegistry(worker_id=f"w{i}", residency_map=rmap,
                        device_memory_budget=budget)   # mmap'd reads →
    #   co-located workers fault each weight shard from disk ONCE between
    #   them (shared OS page cache); device copies stay per-worker.
    service = EncoderService(reg, wave_buckets=(32, 128),
                             prefetch_next=True)
    frontend = FleetFrontend(service, max_pending_rows=4096)
    # admit until backpressure (typed ServiceError), then flush →
    # one mixed-wave batch; rmap.snapshot() is the fleet residency view.
"""
from repro.serving_encoders.bundle import (  # noqa: F401
    BundleError, EncoderBundle, save_bundle,
)
from repro.serving_encoders.fleet import (  # noqa: F401
    RESIDENCY_MAP, FleetError, FleetFrontend, FleetRegistry, ResidencyMap,
    WorkerLost,
)
from repro.serving_encoders.registry import (  # noqa: F401
    EncoderRegistry, LoadedEncoder, RegistryError, bundle_resident_bytes,
)
from repro.serving_encoders.service import (  # noqa: F401
    EncoderService, PredictRequest, PredictResult, ServiceError,
    plan_mixed_waves, reference_serve,
)
from repro.serving_encoders.traffic import (  # noqa: F401
    TraceSpec, load_trace, replay_requests, save_trace, trace_digest,
)
