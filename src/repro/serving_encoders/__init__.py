"""repro.serving_encoders — fitted-encoder artifacts + prediction serving.

The first subsystem on the *inference* side of the fit/predict divide:

* ``bundle``   — ``EncoderBundle``: atomic on-disk persistence of a fitted
  ``BrainEncoder`` (sharded W with bf16-as-u16 storage, μ/σ, selected λ,
  config + dispatch provenance) with eager ``open()`` validation.
  ``BrainEncoder.save(dir)`` / ``BrainEncoder.load(dir)`` round-trip
  through it bit-identically.
* ``registry`` — ``EncoderRegistry``: many bundles, lazy device residency
  under a ``device_memory_budget`` with LRU eviction.
* ``service``  — ``EncoderService``: wave-batched compiled prediction
  (fixed-shape padded waves, one compilation per wave shape, micro-batched
  concurrent requests, optional Pearson-r scoring).

Fit once, serve many::

    enc = BrainEncoder().fit(X_train, Y_train)
    enc.save("bundles/sub-01_L12")

    reg = EncoderRegistry(device_memory_budget=512 * 2**20)
    reg.add("sub-01/L12", "bundles/sub-01_L12")
    service = EncoderService(reg, wave_rows=128)
    out = service.serve([PredictRequest("sub-01/L12", X_new)])
"""
from repro.serving_encoders.bundle import (  # noqa: F401
    BundleError, EncoderBundle, save_bundle,
)
from repro.serving_encoders.registry import (  # noqa: F401
    EncoderRegistry, LoadedEncoder, RegistryError, bundle_resident_bytes,
)
from repro.serving_encoders.service import (  # noqa: F401
    EncoderService, PredictRequest, PredictResult, ServiceError,
)
