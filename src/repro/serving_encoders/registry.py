"""EncoderRegistry — many bundles, bounded device memory, LRU residency.

The production picture is a fleet of persisted per-(subject, band,
backbone-layer) encoders far larger than any one accelerator's memory.
The registry holds every bundle's *manifest* (cheap: ``EncoderBundle.open``
reads headers only) and materialises device arrays lazily on ``get``,
evicting least-recently-used entries whenever the resident-bytes account
would exceed ``device_memory_budget``.

Accounting reuses ``encoding.dispatch.estimated_resident_bytes`` for the
activation term: serving a wave of ``wave_rows`` rows holds
``wave_rows·(p + t_shard)`` floats resident next to the ``p·t`` weight
matrix, which is exactly the dispatch estimator evaluated at
``n = wave_rows``; mixed (scored) waves add
``dispatch.mixed_wave_scoring_bytes`` for the padded target block, the
request one-hot, and the per-slot Pearson-sum carries.

**Fleet-safe.**  All bookkeeping (``get`` / ``get_columns`` / eviction /
counters) runs under one registry lock, so N ``EncoderService`` threads
can hammer a shared registry without the LRU account drifting or
``resident_bytes`` overshooting the budget between check and load; the
observed high-water mark is tracked in ``peak_resident_bytes``.  Weight
shards are read through read-only mmap (``mmap_weights=True``, the way
``RunStore`` maps data shards), so N serving *processes* pointed at one
artifact directory share the OS page cache for the read path — each
process still owns its device copies.  Any fault while materialising a
bundle (truncated shard, flipped checkpoint manifest, vanished leaf)
surfaces as a typed ``BundleError`` so the service can degrade just that
model's tenants.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict

import numpy as np

from repro import obs
from repro.checkpoint import io as ckpt_io
from repro.encoding.dispatch import (
    estimated_resident_bytes, mixed_wave_scoring_bytes,
)
from repro.resilience.policy import FaultPolicy, retry_call
from repro.serving_encoders.bundle import BundleError, EncoderBundle


class RegistryError(ValueError):
    """Unknown model name, duplicate registration, or a bundle whose
    resident estimate alone exceeds the registry's memory budget."""


def bundle_resident_bytes(bundle: EncoderBundle, wave_rows: int,
                          target_shards: int | None = None,
                          score_slots: int = 0) -> int:
    """Device bytes one loaded bundle pins while serving ``wave_rows`` waves:
    the weight matrix + μ/σ vectors + the per-wave activation working set
    (``dispatch.estimated_resident_bytes`` at ``n = wave_rows``), plus —
    when the caller flies MIXED waves — the scoring extras
    (``dispatch.mixed_wave_scoring_bytes``: padded targets, request
    one-hot, per-slot Pearson-sum carries).

    The μ/σ term is charged unconditionally: ``_serving_arrays`` fills in
    identity vectors for standardizer-less bundles (one compiled signature
    for all), so the four ``(p,)``/``(t,)`` arrays are always resident.
    """
    p, t = bundle.shape
    std = 2 * (p + t) * 4
    act = estimated_resident_bytes(wave_rows, p, t,
                                   target_shards=target_shards or 1)
    act += mixed_wave_scoring_bytes(wave_rows, t, score_slots)
    return bundle.weight_nbytes() + std + act


@dataclasses.dataclass
class LoadedEncoder:
    """A resident registry entry: the encoder plus serving-ready device
    arrays (identity μ/σ when the bundle has no standardizer, so the
    compiled predict has ONE signature across standardized and raw
    bundles)."""

    name: str
    bundle: EncoderBundle
    encoder: "object"
    resident_bytes: int
    charged_wave_rows: int  # wave size the resident_bytes account assumed
    charged_score_slots: int  # mixed-wave slot count the account assumed
    mu_x: "object"          # (p,) device array
    sd_x: "object"
    mu_y: "object"          # (t,) device array
    sd_y: "object"
    load_seconds: float

    @property
    def weights(self):
        return self.encoder.weights_


@dataclasses.dataclass
class LoadedShard:
    """A resident weight COLUMN shard (the whole-brain serving granule).

    Where ``LoadedEncoder`` pins a bundle's full ``(p, t)`` matrix, a
    shard entry pins one ``(p, width)`` column window plus its μ/σ slice
    — ``get_columns`` pages these in individually (mmap-backed reads, so
    only the touched shard's file pages fault), and the LRU evicts them
    individually too."""

    name: str
    shard: int
    bounds: tuple[int, int]  # [lo, hi) target columns of the bundle
    W: "object"              # (p, width) device array
    mu_x: "object"           # (p,)
    sd_x: "object"
    mu_y: "object"           # (width,) — the shard's slice
    sd_y: "object"
    resident_bytes: int
    charged_wave_rows: int
    load_seconds: float


def shard_resident_bytes(bundle: EncoderBundle, width: int, wave_rows: int
                         ) -> int:
    """Device bytes one column shard pins while serving ``wave_rows``
    waves: its weight slice + μ/σ (the x vectors plus the shard's y
    slice) + the windowed activation working set."""
    p, _ = bundle.shape
    w_bytes = p * width * bundle.weight_dtype.itemsize
    std = (2 * p + 2 * width) * 4
    return w_bytes + std + estimated_resident_bytes(wave_rows, p, width)


def _serving_arrays(encoder, p: int, t: int):
    import jax.numpy as jnp

    std = encoder.standardizer_
    mu_x = jnp.zeros((p,), jnp.float32)
    sd_x = jnp.ones((p,), jnp.float32)
    mu_y = jnp.zeros((t,), jnp.float32)
    sd_y = jnp.ones((t,), jnp.float32)
    if std is not None:
        if std.mu_x is not None:
            mu_x = jnp.asarray(std.mu_x, jnp.float32)
            sd_x = jnp.asarray(std.sd_x, jnp.float32)
        if std.mu_y is not None:
            mu_y = jnp.asarray(std.mu_y, jnp.float32)
            sd_y = jnp.asarray(std.sd_y, jnp.float32)
    return mu_x, sd_x, mu_y, sd_y


class EncoderRegistry:
    """Lazy-loading, budget-bounded collection of encoder bundles.

    >>> reg = EncoderRegistry(device_memory_budget=256 * 2**20)
    >>> reg.add("sub-01/L12", "/bundles/sub-01_L12")
    >>> entry = reg.get("sub-01/L12")     # loads; LRU-evicts if over budget
    >>> entry.encoder.predict(X)

    ``get`` on a resident entry is a hit (moves it to most-recently-used);
    a miss loads the bundle, first evicting LRU entries until the new
    resident total fits the budget.  A single bundle that cannot fit at
    all raises ``RegistryError`` instead of thrashing.
    """

    def __init__(self, *, device_memory_budget: int | None = None,
                 wave_rows: int = 128, target_shards: int | None = None,
                 mmap_weights: bool = True,
                 fault_policy: FaultPolicy | None = None):
        self.device_memory_budget = device_memory_budget
        self.wave_rows = wave_rows
        self.target_shards = target_shards
        self.mmap_weights = mmap_weights
        #: transient-fault retry for bundle/shard materialisation; retries
        #: and give-ups surface as ``io_retries{op=registry.*}`` counters,
        #: exhausted retries still raise the typed ``BundleError``.
        self.fault_policy = fault_policy
        self._bundles: dict[str, EncoderBundle] = {}
        self._loaded: "OrderedDict[str, LoadedEncoder]" = OrderedDict()
        # Shard-granular residency pool (whole-brain serving): keyed by
        # (model, shard index), LRU-ordered, charged against the SAME
        # budget as the full-bundle pool.
        self._shards: "OrderedDict[tuple[str, int], LoadedShard]" \
            = OrderedDict()
        self._std_host: dict[str, tuple] = {}   # host μ/σ cache per model
        # ONE lock over all bookkeeping: the LRU maps, the byte account,
        # and the counters.  Reentrant because get_columns' load path
        # nests _std_host_arrays and _evict_until_fits.
        self._lock = threading.RLock()
        self.hits = 0
        self.loads = 0
        self.evictions = 0
        self.shard_hits = 0
        self.shard_loads = 0
        self.peak_resident_bytes = 0
        m = obs.get_metrics()
        self._m_hits = m.counter("registry_hits")
        self._m_loads = m.counter("registry_loads")
        self._m_evictions = m.counter("registry_evictions")

    # -- registration --------------------------------------------------------
    def add(self, name: str, path: str) -> EncoderBundle:
        """Register a bundle directory (opened + validated eagerly, arrays
        stay on disk)."""
        with self._lock:
            if name in self._bundles:
                raise RegistryError(f"model {name!r} already registered")
            bundle = EncoderBundle.open(path)
            self._bundles[name] = bundle
            return bundle

    def bundle(self, name: str) -> EncoderBundle:
        """Manifest-only access (shapes/dtypes/config) — no array load, no
        LRU touch.  Lets callers validate requests against a model without
        forcing it resident."""
        if name not in self._bundles:
            raise RegistryError(f"unknown model {name!r}; registered: "
                                f"{sorted(self._bundles)}")
        return self._bundles[name]

    def ensure_servable(self, name: str, wave_rows: int | None = None,
                        score_slots: int = 0) -> None:
        """Raise ``RegistryError`` NOW if ``name`` could never be served at
        this wave size (its lone resident estimate — including the mixed
        scoring extras when ``score_slots`` > 0 — exceeds the budget).
        Manifest-only — lets a server refuse a doomed batch before doing
        any device work for the other models in it."""
        need = bundle_resident_bytes(self.bundle(name),
                                     max(self.wave_rows, wave_rows or 0),
                                     self.target_shards, score_slots)
        budget = self.device_memory_budget
        if budget is not None and need > budget:
            raise RegistryError(
                f"bundle {name!r} needs {need / 2**20:.1f} MB resident at "
                f"wave size {max(self.wave_rows, wave_rows or 0)}, over "
                f"the registry budget {budget / 2**20:.1f} MB")

    def __len__(self) -> int:
        return len(self._bundles)

    def __contains__(self, name: str) -> bool:
        return name in self._bundles

    @property
    def names(self) -> list[str]:
        return list(self._bundles)

    @property
    def loaded_names(self) -> list[str]:
        """LRU → MRU order."""
        return list(self._loaded)

    @property
    def resident_bytes(self) -> int:
        with self._lock:
            return (sum(e.resident_bytes for e in self._loaded.values())
                    + sum(e.resident_bytes for e in self._shards.values()))

    @property
    def loaded_shards(self) -> list[tuple[str, int]]:
        """LRU → MRU order of the resident column shards."""
        return list(self._shards)

    # -- residency -----------------------------------------------------------
    def get(self, name: str, *, wave_rows: int | None = None,
            score_slots: int = 0) -> LoadedEncoder:
        """Resident entry for ``name`` (loading + LRU-evicting as needed).

        ``wave_rows`` is the wave size the CALLER is about to serve with —
        ``EncoderService`` passes its effective per-call value (and its
        mixed-wave ``score_slots``) so the activation term in the
        residency account reflects the waves actually flown, not just the
        registry's construction-time default (the larger of the two is
        charged).

        Thread-safe: the whole hit/recharge/evict/load/insert sequence
        holds the registry lock, so concurrent callers can never stack
        loads past the budget.  A fault while materialising the bundle
        (truncated shard, corrupted checkpoint manifest) raises a typed
        ``BundleError`` and leaves the registry state untouched.
        """
        with self._lock:
            if name not in self._bundles:
                raise RegistryError(f"unknown model {name!r}; registered: "
                                    f"{sorted(self._bundles)}")
            eff_wave = max(self.wave_rows, wave_rows or 0)
            budget = self.device_memory_budget
            if name in self._loaded:
                self.hits += 1
                self._m_hits.inc()
                obs.instant("registry.hit", model=name)
                entry = self._loaded[name]
                self._loaded.move_to_end(name)
                if eff_wave > entry.charged_wave_rows \
                        or score_slots > entry.charged_score_slots:
                    # Bigger waves (or a wider slot one-hot) against a
                    # resident entry pin a bigger activation set —
                    # re-charge the account and make room.  An unservable
                    # wave size refuses up front WITHOUT flushing the
                    # other residents.
                    eff_wave = max(eff_wave, entry.charged_wave_rows)
                    slots = max(score_slots, entry.charged_score_slots)
                    new_need = bundle_resident_bytes(
                        entry.bundle, eff_wave, self.target_shards, slots)
                    if budget is not None and new_need > budget:
                        raise RegistryError(
                            f"bundle {name!r} needs {new_need / 2**20:.1f} "
                            f"MB resident at wave size {eff_wave}, over "
                            f"the registry budget {budget / 2**20:.1f} MB")
                    entry.resident_bytes = new_need
                    entry.charged_wave_rows = eff_wave
                    entry.charged_score_slots = slots
                    self._evict_until_fits(extra_need=0, keep=name)
                    self._note_peak()
                return entry
            bundle = self._bundles[name]
            need = bundle_resident_bytes(bundle, eff_wave,
                                         self.target_shards, score_slots)
            if budget is not None and need > budget:
                raise RegistryError(
                    f"bundle {name!r} needs {need / 2**20:.1f} MB "
                    f"resident, over the registry budget "
                    f"{budget / 2**20:.1f} MB — raise the budget or shard "
                    f"the targets")
            # Evict BEFORE loading so the peak never exceeds budget.
            self._evict_until_fits(extra_need=need)
            t0 = time.perf_counter()
            with obs.span("registry.load", model=name, bytes=need):
                try:
                    encoder = retry_call(
                        lambda: bundle.load_encoder(
                            target_shards=self.target_shards,
                            mmap=self.mmap_weights),
                        self.fault_policy, "registry.load_encoder")
                except BundleError:
                    raise
                except (ckpt_io.CheckpointError, OSError, ValueError) as e:
                    # Anything the disk path throws mid-materialisation —
                    # truncated .npy, vanished leaf, corrupted checkpoint
                    # manifest — becomes the typed fault the service
                    # degrades on, and no partial entry is ever inserted.
                    raise BundleError(
                        f"bundle {name!r} failed to materialise: {e}") from e
                p, t = bundle.shape
                mu_x, sd_x, mu_y, sd_y = _serving_arrays(encoder, p, t)
            entry = LoadedEncoder(
                name=name, bundle=bundle, encoder=encoder,
                resident_bytes=need, charged_wave_rows=eff_wave,
                charged_score_slots=score_slots,
                mu_x=mu_x, sd_x=sd_x, mu_y=mu_y, sd_y=sd_y,
                load_seconds=time.perf_counter() - t0)
            self._loaded[name] = entry
            self.loads += 1
            self._m_loads.inc()
            self._note_peak()
            return entry

    def _note_peak(self) -> None:
        resident = (sum(e.resident_bytes for e in self._loaded.values())
                    + sum(e.resident_bytes for e in self._shards.values()))
        if resident > self.peak_resident_bytes:
            self.peak_resident_bytes = resident

    # -- shard-granular residency (whole-brain serving) ----------------------
    def _std_host_arrays(self, name: str) -> tuple:
        """Host-side μ/σ of a bundle, cached once per model (the vectors
        are O(p + t) — tiny next to any weight shard) so windowed gets
        never re-read the standardizer leaves per shard."""
        cached = self._std_host.get(name)
        if cached is None:
            bundle = self.bundle(name)
            p, t = bundle.shape
            mu_x = np.zeros((p,), np.float32)
            sd_x = np.ones((p,), np.float32)
            mu_y = np.zeros((t,), np.float32)
            sd_y = np.ones((t,), np.float32)
            flags = bundle.manifest["standardizer"]
            keys = (["mu_x", "sd_x"] if flags.get("x") else []) + \
                   (["mu_y", "sd_y"] if flags.get("y") else [])
            if keys:
                arrays = bundle.load_arrays(keys)
                if flags.get("x"):
                    mu_x = np.asarray(arrays["mu_x"], np.float32)
                    sd_x = np.asarray(arrays["sd_x"], np.float32)
                if flags.get("y"):
                    mu_y = np.asarray(arrays["mu_y"], np.float32)
                    sd_y = np.asarray(arrays["sd_y"], np.float32)
            cached = (mu_x, sd_x, mu_y, sd_y)
            self._std_host[name] = cached
        return cached

    def get_columns(self, name: str, col_range: tuple[int, int], *,
                    wave_rows: int | None = None) -> list[LoadedShard]:
        """Resident shard entries covering target columns ``[lo, hi)``.

        ONLY the bundle's shards overlapping the window are charged and
        paged in (mmap-backed ``load_weight_shard``, so even the read
        faults just that shard's file) — a wave that touches one column
        window of a whole-brain bundle never pays for the rest of it.
        Each shard is an independent LRU resident, evicted individually.
        Thread-safe: the whole plan/hit/evict/load walk holds the registry
        lock; load faults surface as typed ``BundleError``.
        """
        import jax.numpy as jnp

        with self._lock:
            bundle = self.bundle(name)
            lo, hi = col_range
            idxs = bundle.shards_for_columns(lo, hi)
            if not idxs:
                raise RegistryError(f"column window [{lo}, {hi}) of "
                                    f"{name!r} touches no weight shard")
            eff_wave = max(self.wave_rows, wave_rows or 0)
            budget = self.device_memory_budget
            bounds = bundle.weight_shard_bounds()
            wanted = frozenset((name, i) for i in idxs)
            out = []
            for i in idxs:
                key = (name, i)
                slo, shi = bounds[i]
                if key in self._shards:
                    self.shard_hits += 1
                    self._m_hits.inc()
                    obs.instant("registry.hit", model=name, shard=i)
                    entry = self._shards[key]
                    self._shards.move_to_end(key)
                    if eff_wave > entry.charged_wave_rows:
                        new_need = shard_resident_bytes(bundle, shi - slo,
                                                        eff_wave)
                        if budget is not None and new_need > budget:
                            raise RegistryError(
                                f"shard {i} of {name!r} needs "
                                f"{new_need / 2**20:.1f} MB resident at "
                                f"wave size {eff_wave}, over the registry "
                                f"budget {budget / 2**20:.1f} MB")
                        entry.resident_bytes = new_need
                        entry.charged_wave_rows = eff_wave
                        self._evict_until_fits(extra_need=0,
                                               keep_shards=wanted)
                        self._note_peak()
                    out.append(entry)
                    continue
                need = shard_resident_bytes(bundle, shi - slo, eff_wave)
                if budget is not None and need > budget:
                    raise RegistryError(
                        f"shard {i} of {name!r} needs {need / 2**20:.1f} "
                        f"MB resident, over the registry budget "
                        f"{budget / 2**20:.1f} MB — re-save with narrower "
                        f"weight shards")
                self._evict_until_fits(extra_need=need, keep_shards=wanted)
                t0 = time.perf_counter()
                with obs.span("registry.load", model=name, shard=i,
                              bytes=need):
                    try:
                        W = jnp.asarray(retry_call(
                            lambda: bundle.load_weight_shard(i, mmap=True),
                            self.fault_policy, "registry.load_shard"))
                        mu_x, sd_x, mu_y, sd_y = retry_call(
                            lambda: self._std_host_arrays(name),
                            self.fault_policy, "registry.load_std")
                    except BundleError:
                        raise
                    except (ckpt_io.CheckpointError, OSError,
                            ValueError) as e:
                        raise BundleError(
                            f"shard {i} of {name!r} failed to materialise: "
                            f"{e}") from e
                entry = LoadedShard(
                    name=name, shard=i, bounds=(slo, shi), W=W,
                    mu_x=jnp.asarray(mu_x), sd_x=jnp.asarray(sd_x),
                    mu_y=jnp.asarray(mu_y[slo:shi]),
                    sd_y=jnp.asarray(sd_y[slo:shi]),
                    resident_bytes=need, charged_wave_rows=eff_wave,
                    load_seconds=time.perf_counter() - t0)
                self._shards[key] = entry
                self.shard_loads += 1
                self._m_loads.inc()
                self._note_peak()
                out.append(entry)
            return out

    def _evict_until_fits(self, extra_need: int, keep: str | None = None,
                          keep_shards: frozenset = frozenset()) -> None:
        """Evict LRU-first (sparing ``keep``/``keep_shards``) until
        ``extra_need`` more bytes fit the budget.  Shard entries go first
        — they are the finer granule, and dropping one column window is
        cheaper to undo than reloading a whole bundle.  Callers pre-check
        that the kept/incoming entry alone fits, so the loop always
        terminates within budget."""
        budget = self.device_memory_budget
        while budget is not None \
                and self.resident_bytes + extra_need > budget:
            skey = next((k for k in self._shards if k not in keep_shards),
                        None)
            if skey is not None:
                del self._shards[skey]
                self.evictions += 1
                self._m_evictions.inc()
                obs.instant("registry.evict", model=skey[0], shard=skey[1])
                continue
            victim = next((n for n in self._loaded if n != keep), None)
            if victim is None:
                return
            del self._loaded[victim]
            self.evictions += 1
            self._m_evictions.inc()
            obs.instant("registry.evict", model=victim)

    def evict(self, name: str) -> bool:
        """Drop a resident entry — the full-bundle entry AND any of the
        model's resident column shards (device arrays become collectable),
        plus the host μ/σ cache so a repaired bundle re-reads fresh."""
        with self._lock:
            hit = False
            if name in self._loaded:
                del self._loaded[name]
                self.evictions += 1
                self._m_evictions.inc()
                obs.instant("registry.evict", model=name)
                hit = True
            for key in [k for k in self._shards if k[0] == name]:
                del self._shards[key]
                self.evictions += 1
                self._m_evictions.inc()
                obs.instant("registry.evict", model=key[0], shard=key[1])
                hit = True
            self._std_host.pop(name, None)
            return hit

    def stats(self) -> dict:
        with self._lock:
            return {"registered": len(self._bundles),
                    "loaded": len(self._loaded),
                    "loaded_shards": len(self._shards),
                    "resident_bytes": self.resident_bytes,
                    "peak_resident_bytes": self.peak_resident_bytes,
                    "hits": self.hits, "loads": self.loads,
                    "shard_hits": self.shard_hits,
                    "shard_loads": self.shard_loads,
                    "evictions": self.evictions}


__all__ = ["EncoderRegistry", "RegistryError", "LoadedEncoder",
           "LoadedShard", "bundle_resident_bytes", "shard_resident_bytes"]
