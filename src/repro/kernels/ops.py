"""Public jit'd wrappers over the Pallas kernels.

On CPU (this container, and any unit-test environment) the kernels run in
``interpret=True`` mode automatically; on TPU they compile to Mosaic.  Set
``REPRO_PALLAS_FORCE_INTERPRET=1`` to force interpretation everywhere, or
``=0`` to force compilation.
"""
from __future__ import annotations

import os

import jax

from repro.kernels import gram as _gram
from repro.kernels import pearsonr as _pearsonr
from repro.kernels import ridge_solve as _ridge_solve


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_FORCE_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def kernel_tier_auto() -> bool:
    """Whether auto dispatch (``use_pallas=None``) turns the kernel tier on.

    True on TPU (the kernels compile to Mosaic and ARE the fast path) and
    on CPU when ``REPRO_PALLAS_FORCE_INTERPRET`` is set truthy — the CI
    pallas lane sets it so the fused code path is exercised end to end in
    interpret mode.  Plain CPU/GPU sessions default off: interpret mode is
    a correctness harness, not a fast path, and would slow every
    default-config fit by orders of magnitude.  An explicit
    ``use_pallas=True/False`` in ``EncoderConfig`` always wins over this.
    """
    if jax.default_backend() == "tpu":
        return True
    env = os.environ.get("REPRO_PALLAS_FORCE_INTERPRET")
    return env is not None and env not in ("0", "false", "False")


def gram(x, **kw):
    """XᵀX, f32 accumulation.  (n, p) → (p, p)."""
    kw.setdefault("interpret", _interpret())
    return _gram.gram(x, **kw)


def xty(x, y, **kw):
    """XᵀY, f32 accumulation.  (n, p), (n, q) → (p, q)."""
    kw.setdefault("interpret", _interpret())
    return _gram.xty(x, y, **kw)


def xty_folds(x, y, bounds, **kw):
    """Per-fold XᵀY tiles in one HBM pass.  (n, p), (n, q) → (k, p, q)."""
    kw.setdefault("interpret", _interpret())
    return _gram.xty_folds(x, y, tuple(tuple(b) for b in bounds), **kw)


def xty_folds_masked(x, z, onehot, **kw):
    """Fused masked per-slot cross-Gram (the streamed chunk update's
    ``(s, p, q)`` ``[G|C]`` contribution) in one HBM pass.  (m, p), (m, q),
    (m, s) → (s, p, q)."""
    kw.setdefault("interpret", _interpret())
    return _gram.xty_folds_masked(x, z, onehot, **kw)


def solve_lambda_grid(q, evals, a, lambdas, **kw):
    """Fused multi-λ eigenbasis solve.  → (r, p, t)."""
    kw.setdefault("interpret", _interpret())
    return _ridge_solve.solve_lambda_grid(q, evals, a, lambdas, **kw)


def pearson_r(y_true, y_pred, **kw):
    """Per-target Pearson correlation.  (n, t) × (n, t) → (t,)."""
    kw.setdefault("interpret", _interpret())
    return _pearsonr.pearson_r(y_true, y_pred, **kw)


def pearson_sums(y_true, y_pred):
    """The kernel's five running sums, traceable.  (n, t) ×2 → (5, t)."""
    return _pearsonr.pearson_sums(y_true, y_pred)


def pearson_r_from_sums(sums, n_true):
    """Finalise r from accumulated sums (the kernel's formula, host-safe)."""
    return _pearsonr.pearson_r_from_sums(sums, n_true)


def flash_attention(q, k, v, **kw):
    """Streaming attention, (BH, S, K) layout.  See kernels.flash_attention."""
    from repro.kernels import flash_attention as _fa
    kw.setdefault("interpret", _interpret())
    return _fa.flash_attention(q, k, v, **kw)


def mha_flash(q, k, v, n_kv, **kw):
    """Model-layout flash attention: q (B,S,H,K), GQA k/v (B,T,N,K)."""
    from repro.kernels import flash_attention as _fa
    kw.setdefault("interpret", _interpret())
    return _fa.mha_flash(q, k, v, n_kv, **kw)


def ssd_intra(cb, la, x, **kw):
    """Fused Mamba2 SSD within-chunk contraction.  See kernels.ssd."""
    from repro.kernels import ssd as _ssd
    kw.setdefault("interpret", _interpret())
    return _ssd.ssd_intra(cb, la, x, **kw)
