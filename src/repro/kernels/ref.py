"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def xty(x: jax.Array, y: jax.Array) -> jax.Array:
    """Oracle for kernels.gram.xty."""
    return jnp.matmul(x.T.astype(jnp.float32), y.astype(jnp.float32),
                      preferred_element_type=jnp.float32)


def gram(x: jax.Array) -> jax.Array:
    return xty(x, x)


def solve_lambda_grid(q: jax.Array, evals: jax.Array, a: jax.Array,
                      lambdas: jax.Array) -> jax.Array:
    """Oracle for kernels.ridge_solve.solve_lambda_grid: (r, p, t)."""
    q = q.astype(jnp.float32)
    a = a.astype(jnp.float32)
    scale = 1.0 / (evals[None, :] + lambdas[:, None])          # (r, p)
    scaled = a[None, :, :] * scale[:, :, None]                 # (r, p, t)
    return jnp.einsum("ik,rkt->rit", q, scaled,
                      preferred_element_type=jnp.float32)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    softcap: float | None = None) -> jax.Array:
    """Oracle for kernels.flash_attention: dense-materialised attention.
    q (BH,S,K) pre-scaled; k/v (BH,T,K)."""
    s = jnp.einsum("hsk,htk->hst", q.astype(jnp.float32),
                   k.astype(jnp.float32))
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    S, T = q.shape[1], k.shape[1]
    dist = jnp.arange(S)[:, None] - jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= dist >= 0
    if window is not None:
        mask &= dist < window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hst,htk->hsk", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


def pearson_r(y_true: jax.Array, y_pred: jax.Array) -> jax.Array:
    """Oracle for kernels.pearsonr.pearson_r: (t,)."""
    yt = y_true.astype(jnp.float32)
    yp = y_pred.astype(jnp.float32)
    yt = yt - jnp.mean(yt, axis=0, keepdims=True)
    yp = yp - jnp.mean(yp, axis=0, keepdims=True)
    num = jnp.sum(yt * yp, axis=0)
    den = jnp.sqrt(jnp.sum(yt ** 2, axis=0) * jnp.sum(yp ** 2, axis=0))
    return num / jnp.maximum(den, 1e-12)


def ssd_intra(cb: jax.Array, la: jax.Array, x: jax.Array) -> jax.Array:
    """Oracle for kernels.ssd.ssd_intra (dense-materialised)."""
    cb = cb.astype(jnp.float32)
    la = la.astype(jnp.float32)
    x = x.astype(jnp.float32)
    q = cb.shape[1]
    diff = la[:, :, None, :] - la[:, None, :, :]        # (N,Q,Q,H)
    mask = jnp.tril(jnp.ones((q, q), bool))[None, :, :, None]
    decay = jnp.exp(jnp.where(mask, diff, -jnp.inf))
    prod = decay * cb[:, :, :, None]
    return jnp.einsum("nqkh,nkhp->nqhp", prod, x,
                      preferred_element_type=jnp.float32)
