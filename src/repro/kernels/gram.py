"""Tiled cross-Gram kernel: ``out = XᵀY`` (so ``XᵀX`` when Y is X).

This is the dominant distributed-ridge primitive (DESIGN §2): each shard's
contribution to the Gram/cross-covariance statistics is a tall-skinny matmul
over the local time samples.  On TPU the MXU wants 128-aligned tiles and the
reduction over the (large) time dimension must be blocked through VMEM.

Tiling (HBM→VMEM):
  grid = (p_i tiles, p_j tiles, n tiles); the n axis is the innermost
  reduction so each (i, j) output tile stays resident in VMEM while the
  kernel streams X/Y row blocks.  With the default blocks
  (bn=512, bp=256) the working set is
  X tile 512×256×4B = 512 KiB, Y tile 512 KiB, acc 256×256×4B = 256 KiB
  → ~1.3 MiB, comfortably inside the ~16 MiB/core VMEM budget of v5e while
  leaving room for double buffering.

Accumulation is always float32 (``preferred_element_type``), matching the
f64→f32 adaptation note in DESIGN §2: the paper uses float64 BLAS, we use
f32 accumulators over bf16/f32 inputs and test against a float64 oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_N = 512
DEFAULT_BLOCK_P = 256


def _xty_kernel(x_ref, y_ref, o_ref):
    """One (i, j) VMEM tile; reduction over the n grid axis (axis 2)."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]            # (bn, bpi)
    y = y_ref[...]            # (bn, bpj)
    o_ref[...] += jnp.dot(x.T, y, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_n", "block_p", "interpret"))
def xty(x: jax.Array, y: jax.Array, *, block_n: int = DEFAULT_BLOCK_N,
        block_p: int = DEFAULT_BLOCK_P, interpret: bool = False) -> jax.Array:
    """``XᵀY`` with explicit VMEM tiling.  x: (n, p), y: (n, q) → (p, q) f32.

    Inputs are zero-padded up to tile multiples (zeros contribute nothing to
    the reduction), output sliced back.
    """
    n, p = x.shape
    n2, q = y.shape
    assert n == n2, (x.shape, y.shape)
    bn = min(block_n, _ceil_mult(n, 8))
    bp = min(block_p, _ceil_mult(max(p, q), 128))
    n_pad, p_pad, q_pad = _pad_to(n, bn), _pad_to(p, bp), _pad_to(q, bp)
    if (n_pad, p_pad, q_pad) == (n, p, q):
        # Tile-aligned fast path: the operands already ARE the padded
        # layout, so hand them to the kernel untouched — no pad copy in,
        # no slice copy out (the aligned-dtype mirror of
        # ``RunStore.iter_chunks``' zero-copy contract; a test asserts no
        # ``pad``/``slice`` op is traced on this path).
        xp, yp = x, y
    else:
        xp = jnp.pad(x, ((0, n_pad - n), (0, p_pad - p)))
        yp = jnp.pad(y, ((0, n_pad - n), (0, q_pad - q)))

    grid = (p_pad // bp, q_pad // bp, n_pad // bn)
    out = pl.pallas_call(
        _xty_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bp), lambda i, j, k: (k, i)),
            pl.BlockSpec((bn, bp), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bp, bp), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((p_pad, q_pad), jnp.float32),
        interpret=interpret,
    )(xp, yp)
    if (p_pad, q_pad) == (p, q):
        return out
    return out[:p, :q]


def gram(x: jax.Array, *, block_n: int = DEFAULT_BLOCK_N,
         block_p: int = DEFAULT_BLOCK_P, interpret: bool = False) -> jax.Array:
    """``XᵀX`` (p×p, f32)."""
    return xty(x, x, block_n=block_n, block_p=block_p, interpret=interpret)


def _make_xty_folds_kernel(blocks_per_fold: int):
    """One (i, j) tile of one fold's output; reduction over that fold's
    row blocks (grid axis 2).  The accumulator tile is zeroed at the fold's
    first row block — a static modulus, since every fold spans exactly
    ``blocks_per_fold`` blocks of the repacked row stream."""

    def kernel(x_ref, y_ref, o_ref):
        @pl.when(pl.program_id(2) % blocks_per_fold == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        x = x_ref[...]            # (bn, bpi)
        y = y_ref[...]            # (bn, bpj)
        o_ref[0, :, :] += jnp.dot(x.T, y,
                                  preferred_element_type=jnp.float32)

    return kernel


@functools.partial(jax.jit, static_argnames=("bounds", "block_n", "block_p",
                                             "interpret"))
def xty_folds(x: jax.Array, y: jax.Array, bounds: tuple[tuple[int, int], ...],
              *, block_n: int = DEFAULT_BLOCK_N,
              block_p: int = DEFAULT_BLOCK_P,
              interpret: bool = False) -> jax.Array:
    """Per-fold cross-Gram tiles ``out[f] = X_fᵀY_f`` in one HBM pass.

    ``bounds`` are the (static) contiguous fold row ranges of
    ``foldstats.fold_bounds`` — disjoint, covering ``[0, n)``.  The rows are
    repacked so every fold occupies the same whole number of row blocks
    (zero padding contributes nothing to the reduction, and fold sizes
    differ by at most one row, so the waste is < k blocks); the fold of a
    row block is then the static arithmetic ``b // blocks_per_fold``, which
    steers each block's partial product into its fold's ``(f, i, j)``
    output tile.  That tile stays resident in VMEM across the fold's
    contiguous run of row blocks (the n axis is the innermost grid
    dimension) and is zero-initialised at the fold's first block.  Net
    effect: the full k-fold statistics cost one pass over ``X``/``Y``
    instead of one pass per fold.

    x: (n, p), y: (n, q) → (k, p, q) float32.
    """
    n, p = x.shape
    n2, q = y.shape
    assert n == n2, (x.shape, y.shape)
    assert bounds and bounds[0][0] == 0 and bounds[-1][1] == n and all(
        bounds[i][1] == bounds[i + 1][0] for i in range(len(bounds) - 1)), (
        f"bounds {bounds} must be contiguous over [0, {n})")
    k = len(bounds)
    max_fold = max(hi - lo for lo, hi in bounds)
    bn = min(block_n, _ceil_mult(max_fold, 8))
    bp = min(block_p, _ceil_mult(max(p, q), 128))
    p_pad, q_pad = _pad_to(p, bp), _pad_to(q, bp)

    # Repack rows: fold f lives in blocks [f·B, (f+1)·B) of the row stream.
    blocks_per_fold = pl.cdiv(max_fold, bn)
    stride = blocks_per_fold * bn
    xp = jnp.zeros((k * stride, p_pad), x.dtype)
    yp = jnp.zeros((k * stride, q_pad), y.dtype)
    for f, (lo, hi) in enumerate(bounds):
        xp = xp.at[f * stride:f * stride + (hi - lo), :p].set(x[lo:hi])
        yp = yp.at[f * stride:f * stride + (hi - lo), :q].set(y[lo:hi])

    grid = (p_pad // bp, q_pad // bp, k * blocks_per_fold)
    out = pl.pallas_call(
        _make_xty_folds_kernel(blocks_per_fold),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bp), lambda i, j, b: (b, i)),
            pl.BlockSpec((bn, bp), lambda i, j, b: (b, j)),
        ],
        out_specs=pl.BlockSpec(
            (1, bp, bp), lambda i, j, b: (b // blocks_per_fold, i, j)),
        out_shape=jax.ShapeDtypeStruct((k, p_pad, q_pad), jnp.float32),
        interpret=interpret,
    )(xp, yp)
    return out[:, :p, :q]


def _xty_masked_kernel(x_ref, z_ref, w_ref, o_ref):
    """One (slot, i, j) tile of the masked per-slot cross-Gram; reduction
    over the row-block grid axis (axis 3, innermost).  The slot's 0/1 row
    mask rides in as a (bn, 1) column and is applied on the VMEM-resident
    tile — the masked operand ``X·w_s`` is never materialised in HBM."""

    @pl.when(pl.program_id(3) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]                    # (bn, bpi)
    z = z_ref[...]                    # (bn, bpj)
    w = w_ref[...].astype(x.dtype)    # (bn, 1) 0/1 slot mask
    o_ref[0, :, :] += jnp.dot((x * w).T, z,
                              preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_n", "block_p",
                                             "interpret"))
def xty_folds_masked(x: jax.Array, z: jax.Array, onehot: jax.Array, *,
                     block_n: int = DEFAULT_BLOCK_N,
                     block_p: int = DEFAULT_BLOCK_P,
                     interpret: bool = False) -> jax.Array:
    """Per-slot masked cross-Gram ``out[s] = (X·w_s)ᵀZ`` in one HBM pass.

    The streamed fold-statistics update (``foldstats._FixedShapeUpdate``)
    presents every chunk as a fixed ``(chunk_rows, p)`` block of rows plus
    per-row slot one-hots ``onehot: (chunk_rows, s)`` (TRACED — slot
    contents change per chunk, the compiled program does not).  The XLA
    formulation materialises the masked operand
    ``Xw = X[None] * onehotᵀ[:, :, None]`` — an ``(s, m, p)`` HBM
    intermediate — before the ``einsum("smp,mq->spq")``.  Here the mask is
    applied per VMEM tile inside the same blocked reduction that computes
    the ``[G | C]`` contribution, so the chunk costs exactly one read of
    ``X``/``Z`` and the intermediate never exists.

    Grid ``(s, p tiles, q tiles, row blocks)`` with the row axis innermost:
    each slot's ``(i, j)`` accumulator tile stays VMEM-resident across the
    whole row sweep and is zero-initialised at the first row block.  Unused
    slots carry all-zero masks and emit exact zero tiles (the scatter-add
    downstream is then a no-op for them).

    x: (m, p), z: (m, q), onehot: (m, s) → (s, p, q) float32.
    """
    m, p = x.shape
    m2, q = z.shape
    m3, s = onehot.shape
    assert m == m2 == m3, (x.shape, z.shape, onehot.shape)
    bn = min(block_n, _ceil_mult(m, 8))
    bp = min(block_p, _ceil_mult(max(p, q), 128))
    m_pad, p_pad, q_pad = _pad_to(m, bn), _pad_to(p, bp), _pad_to(q, bp)
    if (m_pad, p_pad) != (m, p):
        x = jnp.pad(x, ((0, m_pad - m), (0, p_pad - p)))
    if (m_pad, q_pad) != (m, q):
        z = jnp.pad(z, ((0, m_pad - m), (0, q_pad - q)))
    if m_pad != m:
        # Pad rows carry a zero mask, so they contribute exact zeros.
        onehot = jnp.pad(onehot, ((0, m_pad - m), (0, 0)))

    grid = (s, p_pad // bp, q_pad // bp, m_pad // bn)
    out = pl.pallas_call(
        _xty_masked_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bp), lambda si, i, j, b: (b, i)),
            pl.BlockSpec((bn, bp), lambda si, i, j, b: (b, j)),
            pl.BlockSpec((bn, 1), lambda si, i, j, b: (b, si)),
        ],
        out_specs=pl.BlockSpec((1, bp, bp), lambda si, i, j, b: (si, i, j)),
        out_shape=jax.ShapeDtypeStruct((s, p_pad, q_pad), jnp.float32),
        interpret=interpret,
    )(x, z, onehot)
    if (p_pad, q_pad) == (p, q):
        return out
    return out[:, :p, :q]


def _pad_to(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


def _ceil_mult(v: int, m: int) -> int:
    return _pad_to(v, m)
