"""Tiled cross-Gram kernel: ``out = XᵀY`` (so ``XᵀX`` when Y is X).

This is the dominant distributed-ridge primitive (DESIGN §2): each shard's
contribution to the Gram/cross-covariance statistics is a tall-skinny matmul
over the local time samples.  On TPU the MXU wants 128-aligned tiles and the
reduction over the (large) time dimension must be blocked through VMEM.

Tiling (HBM→VMEM):
  grid = (p_i tiles, p_j tiles, n tiles); the n axis is the innermost
  reduction so each (i, j) output tile stays resident in VMEM while the
  kernel streams X/Y row blocks.  With the default blocks
  (bn=512, bp=256) the working set is
  X tile 512×256×4B = 512 KiB, Y tile 512 KiB, acc 256×256×4B = 256 KiB
  → ~1.3 MiB, comfortably inside the ~16 MiB/core VMEM budget of v5e while
  leaving room for double buffering.

Accumulation is always float32 (``preferred_element_type``), matching the
f64→f32 adaptation note in DESIGN §2: the paper uses float64 BLAS, we use
f32 accumulators over bf16/f32 inputs and test against a float64 oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_N = 512
DEFAULT_BLOCK_P = 256


def _xty_kernel(x_ref, y_ref, o_ref):
    """One (i, j) VMEM tile; reduction over the n grid axis (axis 2)."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]            # (bn, bpi)
    y = y_ref[...]            # (bn, bpj)
    o_ref[...] += jnp.dot(x.T, y, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_n", "block_p", "interpret"))
def xty(x: jax.Array, y: jax.Array, *, block_n: int = DEFAULT_BLOCK_N,
        block_p: int = DEFAULT_BLOCK_P, interpret: bool = False) -> jax.Array:
    """``XᵀY`` with explicit VMEM tiling.  x: (n, p), y: (n, q) → (p, q) f32.

    Inputs are zero-padded up to tile multiples (zeros contribute nothing to
    the reduction), output sliced back.
    """
    n, p = x.shape
    n2, q = y.shape
    assert n == n2, (x.shape, y.shape)
    bn = min(block_n, _ceil_mult(n, 8))
    bp = min(block_p, _ceil_mult(max(p, q), 128))
    n_pad, p_pad, q_pad = _pad_to(n, bn), _pad_to(p, bp), _pad_to(q, bp)
    xp = jnp.pad(x, ((0, n_pad - n), (0, p_pad - p)))
    yp = jnp.pad(y, ((0, n_pad - n), (0, q_pad - q)))

    grid = (p_pad // bp, q_pad // bp, n_pad // bn)
    out = pl.pallas_call(
        _xty_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bp), lambda i, j, k: (k, i)),
            pl.BlockSpec((bn, bp), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bp, bp), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((p_pad, q_pad), jnp.float32),
        interpret=interpret,
    )(xp, yp)
    return out[:p, :q]


def gram(x: jax.Array, *, block_n: int = DEFAULT_BLOCK_N,
         block_p: int = DEFAULT_BLOCK_P, interpret: bool = False) -> jax.Array:
    """``XᵀX`` (p×p, f32)."""
    return xty(x, x, block_n=block_n, block_p=block_p, interpret=interpret)


def _pad_to(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


def _ceil_mult(v: int, m: int) -> int:
    return _pad_to(v, m)
