"""Fused multi-λ eigenbasis ridge solve: ``out[r] = Q · diag(1/(Λ+λ_r)) · A``.

After the Gram eigendecomposition ``G = QΛQᵀ`` and the rotation
``A = Qᵀ(XᵀY)``, sweeping the paper's λ grid (Eq. 5) is, per λ, a diagonal
rescale of ``A`` followed by a matmul with ``Q``.  Done naively this
materialises ``r`` rescaled copies of ``A`` (r·p·t floats) in HBM before the
matmuls.  This kernel fuses the rescale into the matmul's VMEM pipeline: the
``A`` tile is scaled by ``1/(Λ_k + λ_r)`` *after* it lands in VMEM, so HBM
traffic is the same as a single matmul per λ and the rescaled operand never
exists in HBM.

Tiling: grid = (r, p_i, t_j, k); ``Q`` tile (bi, bk), ``A`` tile (bk, bj),
eigenvalue slice (1, bk) broadcast down the tile, λ passed as an (r, 1)
column so each grid-r step reads one scalar.  Default blocks
(bi=bj=bk=256): Q 256 KiB + A 256 KiB + acc 256 KiB ≈ 0.75 MiB of VMEM.
The k axis is innermost so the (r, i, j) accumulator tile is revisited.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 256


def _solve_kernel(lam_ref, ev_ref, q_ref, a_ref, o_ref):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    lam = lam_ref[0, 0]                     # scalar λ_r for this grid step
    ev = ev_ref[0, :]                       # (bk,) eigenvalue slice
    a = a_ref[...]                          # (bk, bj)
    scaled = a * (1.0 / (ev + lam))[:, None]
    o_ref[0, :, :] += jnp.dot(q_ref[...], scaled,
                              preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("block_i", "block_j", "block_k",
                                    "interpret"))
def solve_lambda_grid(q: jax.Array, evals: jax.Array, a: jax.Array,
                      lambdas: jax.Array, *,
                      block_i: int = DEFAULT_BLOCK,
                      block_j: int = DEFAULT_BLOCK,
                      block_k: int = DEFAULT_BLOCK,
                      interpret: bool = False) -> jax.Array:
    """q: (p, p) eigenbasis, evals: (p,), a: (p, t) = Qᵀ(XᵀY), lambdas: (r,).

    Returns (r, p, t) float32 — the weight matrix per grid point.
    """
    p, p2 = q.shape
    assert p == p2 and a.shape[0] == p and evals.shape == (p,)
    t = a.shape[1]
    r = lambdas.shape[0]
    bi = min(block_i, _pad_to(p, 128))
    bk = min(block_k, _pad_to(p, 128))
    bj = min(block_j, _pad_to(t, 128))
    p_pad, t_pad = _pad_to(p, max(bi, bk)), _pad_to(t, bj)

    qp = jnp.pad(q, ((0, p_pad - p), (0, p_pad - p)))
    ap = jnp.pad(a, ((0, p_pad - p), (0, t_pad - t)))
    # Padded eigenvalues get value 1.0 so 1/(ev+λ) stays finite; the matching
    # rows of `a` are zero so they contribute nothing.
    evp = jnp.pad(evals, (0, p_pad - p), constant_values=1.0)[None, :]  # (1,P)
    lams = lambdas.astype(jnp.float32)[:, None]                         # (r,1)

    grid = (r, p_pad // bi, t_pad // bj, p_pad // bk)
    out = pl.pallas_call(
        _solve_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda l, i, j, k: (l, 0)),     # λ
            pl.BlockSpec((1, bk), lambda l, i, j, k: (0, k)),    # eigenvalues
            pl.BlockSpec((bi, bk), lambda l, i, j, k: (i, k)),   # Q
            pl.BlockSpec((bk, bj), lambda l, i, j, k: (k, j)),   # A
        ],
        out_specs=pl.BlockSpec((1, bi, bj), lambda l, i, j, k: (l, i, j)),
        out_shape=jax.ShapeDtypeStruct((r, p_pad, t_pad), jnp.float32),
        interpret=interpret,
    )(lams, evp, qp, ap)
    return out[:, :p, :t]


def _pad_to(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m
