"""Pallas TPU kernels for the ridge pipeline hot-spots.

The paper's performance story is "pick a better BLAS" (MKL vs OpenBLAS,
§4.3) plus batching; on TPU the analogous lever is explicit VMEM tiling of
the three dominant primitives:

  gram.py        — tall-skinny XᵀX / XᵀY with f32 accumulation
  ridge_solve.py — fused multi-λ eigenbasis solve Q·diag(1/(Λ+λᵣ))·A
  pearsonr.py    — single-pass streaming Pearson-r scoring

``ops.py`` holds the jit'd public wrappers (auto interpret=True off-TPU);
``ref.py`` the pure-jnp oracles every kernel is allclose-tested against.
"""
from repro.kernels import ops, ref  # noqa: F401
