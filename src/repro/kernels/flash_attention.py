"""Pallas TPU flash attention: tiled streaming softmax, never materialising
the S×T score matrix in HBM.

Motivation (EXPERIMENTS §Roofline): every assigned arch × shape is
memory-term-dominated, and the dominant HBM traffic at long sequence is the
attention score tensor.  The pure-jnp blockwise path
(``models.layers._blockwise_attention``) fixes the *lowering*; this kernel is
the TPU-native version for the MXU: one (batch·head, q-block) program
instance streams KV tiles through VMEM with a running max/sum carry in
scratch.

Tiling (HBM→VMEM), defaults bq=bk=512, head_dim K≤256:
  q tile 512×256×4B = 512 KiB; k/v tiles 512 KiB each; scores 512×512×4B =
  1 MiB; acc 512×256×4B = 512 KiB → ~3 MiB working set, double-bufferable
  in the 16 MiB VMEM of a v5e core.  MXU dims (512×256·256) are 128-aligned.

Supports: causal masking, sliding-window (banded KV loop is expressed by
masking — the grid still visits all tiles; the banded *skip* lives in the
jnp path), logit softcap (gemma2/grok), GQA via caller-side KV expansion.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512
NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  causal: bool, window: int | None, softcap: float | None,
                  bq: int, bk: int, nk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    i = pl.program_id(1)
    q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    dist = q_pos - k_pos

    # Tiles entirely outside the causal/window band contribute nothing;
    # cheap early-out keeps the grid dense but the MXU idle time bounded.
    live = True
    if causal:
        live = jnp.logical_and(live, (i + 1) * bq - 1 >= j * bk)
    if window is not None:
        live = jnp.logical_and(live, i * bq < (j + 1) * bk + window)

    @pl.when(live)
    def _tile():
        q = q_ref[0].astype(jnp.float32)               # (bq, K)
        k = k_ref[0].astype(jnp.float32)               # (bk, K)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= dist >= 0
        if window is not None:
            mask &= dist < window
        s = jnp.where(mask, s, NEG)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        v = v_ref[0].astype(jnp.float32)               # (bk, K)
        acc_ref[...] = acc_ref[...] * corr[:, None] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finalise():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "softcap",
                                    "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    softcap: float | None = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> jax.Array:
    """q: (BH, S, K) pre-scaled; k/v: (BH, T, K) (GQA pre-expanded).

    Returns (BH, S, K) in q's dtype.  S must divide block_q·nq etc. — the
    wrapper pads.
    """
    bh, s, kd = q.shape
    t = k.shape[1]
    bq, bk = min(block_q, s), min(block_k, t)
    s_pad, t_pad = _pad_to(s, bq), _pad_to(t, bk)
    qp = jnp.pad(q, ((0, 0), (0, s_pad - s), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, t_pad - t), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, t_pad - t), (0, 0)))
    # Padded key positions must never win the softmax: causal masking covers
    # the q-pad; key-pad is masked via window/dist only when causal.  For
    # non-causal, mask by clamping scores with an explicit validity column
    # is unnecessary because padded keys are all-zero → score 0, which CAN
    # perturb the softmax; so for non-causal inputs we require t % bk == 0.
    if not causal:
        assert t_pad == t, "non-causal flash requires t % block_k == 0"

    nq, nk = s_pad // bq, t_pad // bk
    kernel = functools.partial(_flash_kernel, causal=causal, window=window,
                               softcap=softcap, bq=bq, bk=bk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, kd), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, kd), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, bk, kd), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, kd), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s_pad, kd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # running max
            pltpu.VMEM((bq,), jnp.float32),       # running sum
            pltpu.VMEM((bq, kd), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :s, :]


def mha_flash(q: jax.Array, k: jax.Array, v: jax.Array, n_kv: int, *,
              causal: bool = True, window: int | None = None,
              softcap: float | None = None, interpret: bool = False,
              block_q: int = DEFAULT_BLOCK_Q,
              block_k: int = DEFAULT_BLOCK_K) -> jax.Array:
    """Model-layout wrapper: q (B,S,H,K), k/v (B,T,N,K) GQA → (B,S,H,K)."""
    b, s, h, kd = q.shape
    t = k.shape[1]
    g = h // n_kv
    k = jnp.repeat(k, g, axis=2)
    v = jnp.repeat(v, g, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, kd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, t, kd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, t, kd)
    out = flash_attention(qf, kf, vf, causal=causal, window=window,
                          softcap=softcap, interpret=interpret,
                          block_q=block_q, block_k=block_k)
    return out.reshape(b, h, s, kd).transpose(0, 2, 1, 3)


def _pad_to(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m
