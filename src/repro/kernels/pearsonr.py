"""Tiled Pearson-r scoring kernel: per-target correlation over time.

Brain-encoding evaluation (paper §4.1) computes, for every spatial target,
the Pearson correlation between measured and predicted time series.  At
whole-brain resolution that is t≈265k targets × n≈7k test samples — a
bandwidth-bound streaming reduction, ideal for a single-pass kernel that
keeps only 5 running sums per target in VMEM (Σx, Σy, Σx², Σy², Σxy) and
never re-reads the time series.

Tiling: grid = (t tiles, n tiles), n innermost; both inputs are streamed as
(bn, bt) tiles; a (8, bt) f32 scratch accumulator holds the sums (rows 0-4
used, 8 for sublane alignment).  At the last n step the correlation is
finalised from the raw sums with the true sample count (zero padding adds
nothing to any sum):  r = (nΣxy − ΣxΣy) / √((nΣx²−(Σx)²)(nΣy²−(Σy)²)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_N = 1024
DEFAULT_BLOCK_T = 256


def _pearson_kernel(yt_ref, yp_ref, o_ref, acc_ref, *, n_true: int,
                    n_steps: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    yt = yt_ref[...].astype(jnp.float32)     # (bn, bt)
    yp = yp_ref[...].astype(jnp.float32)
    acc_ref[0, :] += jnp.sum(yt, axis=0)
    acc_ref[1, :] += jnp.sum(yp, axis=0)
    acc_ref[2, :] += jnp.sum(yt * yt, axis=0)
    acc_ref[3, :] += jnp.sum(yp * yp, axis=0)
    acc_ref[4, :] += jnp.sum(yt * yp, axis=0)

    @pl.when(pl.program_id(1) == n_steps - 1)
    def _finalise():
        n = jnp.float32(n_true)
        sx, sy = acc_ref[0, :], acc_ref[1, :]
        sxx, syy, sxy = acc_ref[2, :], acc_ref[3, :], acc_ref[4, :]
        num = n * sxy - sx * sy
        var_x = jnp.maximum(n * sxx - sx * sx, 0.0)
        var_y = jnp.maximum(n * syy - sy * sy, 0.0)
        den = jnp.sqrt(var_x * var_y)
        o_ref[0, :] = num / jnp.maximum(den, 1e-12)


@functools.partial(jax.jit, static_argnames=("block_n", "block_t",
                                             "interpret"))
def pearson_r(y_true: jax.Array, y_pred: jax.Array, *,
              block_n: int = DEFAULT_BLOCK_N,
              block_t: int = DEFAULT_BLOCK_T,
              interpret: bool = False) -> jax.Array:
    """Per-target Pearson r.  (n, t) × (n, t) → (t,) float32."""
    n, t = y_true.shape
    assert y_pred.shape == (n, t)
    bn = min(block_n, _pad_to(n, 8))
    bt = min(block_t, _pad_to(t, 128))
    n_pad, t_pad = _pad_to(n, bn), _pad_to(t, bt)
    ytp = jnp.pad(y_true, ((0, n_pad - n), (0, t_pad - t)))
    ypp = jnp.pad(y_pred, ((0, n_pad - n), (0, t_pad - t)))
    n_steps = n_pad // bn

    out = pl.pallas_call(
        functools.partial(_pearson_kernel, n_true=n, n_steps=n_steps),
        grid=(t_pad // bt, n_steps),
        in_specs=[
            pl.BlockSpec((bn, bt), lambda j, k: (k, j)),
            pl.BlockSpec((bn, bt), lambda j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((1, bt), lambda j, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, t_pad), jnp.float32),
        scratch_shapes=[pltpu.VMEM((8, bt), jnp.float32)],
        interpret=interpret,
    )(ytp, ypp)
    return out[0, :t]


def _pad_to(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


def pearson_sums(y_true: jax.Array, y_pred: jax.Array) -> jax.Array:
    """The kernel's five running sums as one traceable reduction.

    ``(n, t) × (n, t) → (5, t)`` float32: ``[Σy, Σŷ, Σy², Σŷ², Σyŷ]`` —
    the same per-target accumulator rows the tiled kernel keeps in VMEM.
    Zero-padded rows add nothing to any sum, so callers may sum over
    fixed-shape padded blocks (the serving wave pattern) and finalise with
    ``pearson_r_from_sums`` using the TRUE row count.
    """
    yt = y_true.astype(jnp.float32)
    yp = y_pred.astype(jnp.float32)
    return jnp.stack([jnp.sum(yt, axis=0), jnp.sum(yp, axis=0),
                      jnp.sum(yt * yt, axis=0), jnp.sum(yp * yp, axis=0),
                      jnp.sum(yt * yp, axis=0)])


def pearson_r_from_sums(sums, n_true):
    """Finalise per-target Pearson r from the five raw sums.

    Exactly the kernel's ``_finalise`` formula (r = (nΣxy − ΣxΣy) /
    √((nΣx²−(Σx)²)(nΣy²−(Σy)²)), variances clamped at 0, denominator
    floored at 1e-12), factored out for hosts that accumulate ``sums``
    across waves/blocks.  Dtype-generic: numpy float64 in → float64 out
    (what the serving path uses to finalise many-wave accumulations
    without f32 cancellation), jnp in → jnp out.
    """
    import numpy as np
    xp = jnp if isinstance(sums, jax.Array) else np
    sx, sy, sxx, syy, sxy = (sums[i] for i in range(5))
    n = sums.dtype.type(n_true)
    num = n * sxy - sx * sy
    var_x = xp.maximum(n * sxx - sx * sx, 0.0)
    var_y = xp.maximum(n * syy - sy * sy, 0.0)
    den = xp.sqrt(var_x * var_y)
    return num / xp.maximum(den, 1e-12)
