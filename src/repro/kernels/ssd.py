"""Fused SSD within-chunk kernel (Mamba2 state-space duality, TPU-native).

Motivation (EXPERIMENTS §Perf pair B): the within-chunk term of the chunked
SSD forward,

    y[q,h,p] = Σ_{k≤q} exp(La[q,h] − La[k,h]) · (C_q·B_k) · x[k,h,p],

is bytes-bound in the pure-XLA lowering because the head-expanded products
(decay·scores, size Q×Q×H per chunk) round-trip HBM.  This kernel keeps
them in VMEM: one grid instance owns one (batch·chunk, head-tile) pair,
builds the decay matrix from the La cumsums on the fly, fuses the mask and
the C·B scores, and contracts against x without ever writing the (Q,Q,H)
tensor to HBM.

VMEM budget per instance (Q=256, bh=8, P=64):
  cb 256² ×4B = 256 KiB; decay 256²×8×4B = 2 MiB; x/y 256×8×64×4B = 0.5 MiB
  → ~3.3 MiB, double-bufferable on v5e.

This is the hardware-adaptation answer for the SSD paper's CUDA kernel: the
GPU implementation tiles over warps/SMs; on TPU the same fusion maps to a
VMEM-resident masked-matmul with MXU contractions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_HEAD_BLOCK = 8


def _ssd_intra_kernel(cb_ref, la_ref, x_ref, o_ref):
    cb = cb_ref[0].astype(jnp.float32)                 # (Q, Q)
    la = la_ref[0].astype(jnp.float32)                 # (Q, bh)
    x = x_ref[0].astype(jnp.float32)                   # (Q, bh, P)
    q = cb.shape[0]
    # decay[q,k,h] = exp(la[q,h] − la[k,h]) masked to k ≤ q (log-space mask
    # before exp so the upper triangle cannot overflow).
    diff = la[:, None, :] - la[None, :, :]             # (Q, Q, bh)
    row = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    mask = (col <= row)[:, :, None]
    prod = jnp.exp(jnp.where(mask, diff, -jnp.inf)) * cb[:, :, None]
    # y[q,h,p] = Σ_k prod[q,k,h]·x[k,h,p]  (batched over h on the MXU)
    y = jax.lax.dot_general(
        prod.transpose(2, 0, 1), x.transpose(1, 0, 2),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)            # (bh, Q, P)
    o_ref[0] = y.transpose(1, 0, 2).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("head_block", "interpret"))
def ssd_intra(cb: jax.Array, la: jax.Array, x: jax.Array, *,
              head_block: int = DEFAULT_HEAD_BLOCK,
              interpret: bool = False) -> jax.Array:
    """Fused within-chunk SSD contraction.

    cb: (N, Q, Q) group scores C_q·B_k (n_groups=1 layout, as in the
        assigned mamba2/zamba2 configs); la: (N, Q, H) cumulative log decay;
    x:  (N, Q, H, P) Δt-scaled inputs.  → (N, Q, H, P) float32,
    where N = batch·n_chunks.
    """
    n, q, _ = cb.shape
    h, p = x.shape[2], x.shape[3]
    bh = min(head_block, h)
    assert h % bh == 0, (h, bh)
    out = pl.pallas_call(
        _ssd_intra_kernel,
        grid=(n, h // bh),
        in_specs=[
            pl.BlockSpec((1, q, q), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, q, bh), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, q, bh, p), lambda i, j: (i, 0, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, bh, p), lambda i, j: (i, 0, j, 0)),
        out_shape=jax.ShapeDtypeStruct((n, q, h, p), jnp.float32),
        interpret=interpret,
    )(cb, la, x)
    return out
