"""seamless-m4t-medium — encoder-decoder, multimodal [arXiv:2308.11596].

Assigned: 12L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=256206.

Per the carve-out the audio frontend (mel + conformer conv feature
extractor) is a STUB: ``src_embeds`` arrive as precomputed frame embeddings
(B, frames, d_model); this config is the text/unit transformer backbone
(12L encoder + 12L decoder).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,               # decoder depth
    n_encoder_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=256_206,
    pattern=("global_attn",),
    mlp_act="gelu",
    tie_embeddings=True,
    frontend="audio_stub",
    source="[arXiv:2308.11596] SeamlessM4T medium: 12L enc/dec, d=1024, "
           "16H, ffn 4096, vocab 256206",
)
