"""Assigned-architecture configs (``--arch <id>``) + smoke reduction.

Every module in this package defines ``CONFIG`` with the exact assigned
numbers (source cited in ``ModelConfig.source``).  ``smoke(cfg)`` derives
the reduced same-family variant used by CPU smoke tests (≤2 effective
layers, d_model ≤ 512, ≤ 4 experts, per the assignment rules).
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig, MoEConfig, SSMConfig

ARCH_IDS = (
    "mamba2-130m",
    "qwen3-1.7b",
    "phi3.5-moe-42b-a6.6b",
    "llava-next-34b",
    "zamba2-2.7b",
    "gemma-7b",
    "grok-1-314b",
    "gemma3-12b",
    "seamless-m4t-medium",
    "gemma2-2b",
)

_MODULES = {
    "mamba2-130m": "mamba2_130m",
    "qwen3-1.7b": "qwen3_1_7b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "llava-next-34b": "llava_next_34b",
    "zamba2-2.7b": "zamba2_2_7b",
    "gemma-7b": "gemma_7b",
    "grok-1-314b": "grok1_314b",
    "gemma3-12b": "gemma3_12b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "gemma2-2b": "gemma2_2b",
}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def smoke(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant for single-CPU smoke tests."""
    # Keep pattern diversity with ≤2 entries: first and last kinds.
    pattern = cfg.pattern if len(cfg.pattern) <= 2 else \
        (cfg.pattern[0], cfg.pattern[-1])
    n_heads = 4
    n_kv = min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else n_heads
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(cfg.moe, n_experts=4,
                                  top_k=min(cfg.moe.top_k, 2), group_size=64)
    ssm = None
    if cfg.ssm is not None:
        ssm = dataclasses.replace(cfg.ssm, d_state=16, head_dim=32, chunk=8)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=len(pattern) * 1,           # one repeat of a ≤2-entry pattern
        pattern=pattern,
        d_model=256,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=64,
        d_ff=512 if cfg.d_ff else 0,
        vocab=512,
        window=min(cfg.window, 32),
        shared_attn_window=(min(cfg.shared_attn_window, 32)
                            if cfg.shared_attn_window else None),
        moe=moe,
        ssm=ssm,
        n_encoder_layers=2 if cfg.n_encoder_layers else 0,
        param_dtype=cfg.param_dtype,
    )
