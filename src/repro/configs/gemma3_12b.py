"""gemma3-12b — 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt family card].

Assigned: 48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144.
Pattern: 5 sliding-window (1024) layers per global layer.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15_360,
    vocab=262_144,
    pattern=("local_attn",) * 5 + ("global_attn",),
    window=1024,
    mlp_act="geglu",
    qk_norm=True,
    scale_embedding=True,
    use_post_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    source="[hf:google/gemma-3-1b-pt] gemma3 family: 5:1 local:global, "
           "window 1024; 12B dims 48L/3840/16H/kv8/15360",
)
