"""phi3.5-moe-42b-a6.6b — 16-expert top-2 MoE
[hf:microsoft/Phi-3.5-MoE-instruct].

Assigned: 32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064,
MoE 16e top-2.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab=32_064,
    pattern=("global_attn",),
    mlp_act="swiglu",
    tie_embeddings=False,
    moe=MoEConfig(n_experts=16, top_k=2, capacity_factor=1.25,
                  group_size=4096),
    source="[hf:microsoft/Phi-3.5-MoE-instruct] 32L/4096/32H/kv8/6400/16e@2",
)
