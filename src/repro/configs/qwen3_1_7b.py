"""qwen3-1.7b — dense GQA decoder with qk-norm [hf:Qwen/Qwen3-8B family].

Assigned: 28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936, qk_norm.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab=151_936,
    pattern=("global_attn",),
    mlp_act="swiglu",
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    source="[hf:Qwen/Qwen3-8B] (1.7B sibling card: 28L/2048/16H/kv8/6144)",
)
