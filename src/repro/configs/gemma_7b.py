"""gemma-7b — dense GeGLU decoder, head_dim=256 [arXiv:2403.08295].

Assigned: 28L d_model=3072 16H (GQA kv=16) d_ff=24576 vocab=256000.
(MQA is used on the 2b sibling; 7b is MHA, kv=16.)
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24_576,
    vocab=256_000,
    pattern=("global_attn",),
    mlp_act="geglu",
    scale_embedding=True,
    tie_embeddings=True,
    source="[arXiv:2403.08295] Gemma: 7B = 28L/3072/16H/hd256/24576/256k vocab",
)
