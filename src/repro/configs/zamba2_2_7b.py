"""zamba2-2.7b — hybrid Mamba2 backbone + shared attention [arXiv:2411.15242].

Assigned: 54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000,
ssm_state=64.

Encoding: 54 Mamba2 blocks with the weight-*shared* attention+MLP block
applied every 6 blocks → pattern (mamba×6, shared_attn) × 9 repeats.
``n_layers`` counts pattern slots (54 mamba + 9 shared applications = 63);
the shared block has ONE copy of its weights (the Zamba2 signature).
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=63,                      # 54 mamba slots + 9 shared-attn slots
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,                    # MHA in the shared block
    head_dim=80,
    d_ff=10_240,
    vocab=32_000,
    pattern=("mamba",) * 6 + ("shared_attn",),
    mlp_act="geglu",
    tie_embeddings=True,
    ssm=SSMConfig(d_state=64, expand=2, head_dim=64, n_groups=1,
                  conv_kernel=4, chunk=256),
    source="[arXiv:2411.15242] Zamba2: 54 mamba2 blocks, shared attn block, "
           "d=2560, state=64",
)
