"""mamba2-130m — SSD (state-space duality) [arXiv:2405.21060].

Assigned: 24L d_model=768 (attn-free) d_ff=0 vocab=50280, ssm_state=128.
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=24,          # d_inner / head_dim = (2·768)/64
    n_kv_heads=24,
    d_ff=0,              # attention-free, no FFN (Mamba2 pure backbone)
    vocab=50_280,
    pattern=("mamba",),
    mlp_act="gelu",
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64, n_groups=1,
                  conv_kernel=4, chunk=256),
    source="[arXiv:2405.21060] Mamba2: Transformers are SSMs (SSD); "
           "130m model card dims",
)
