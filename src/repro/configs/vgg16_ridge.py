"""The paper's own workload: VGG16-FC2 features → ridge → fMRI targets.

This is not a transformer config — it is the brain-encoding workload of the
paper (§2.2): feature dimension p = 4 TRs × 4096 FC2 units = 16384, time
samples n = 69,202, targets t per resolution (Table 1).  Benchmarks and the
encoding launcher parameterise from here.
"""
import dataclasses

from repro.core.complexity import PAPER_WORKLOADS, RidgeWorkload
from repro.core.ridge import PAPER_LAMBDA_GRID


@dataclasses.dataclass(frozen=True)
class EncodingConfig:
    name: str
    workload: RidgeWorkload
    lambdas: tuple = PAPER_LAMBDA_GRID
    n_folds: int = 5
    test_frac: float = 0.1        # paper: 90/10 random split


RESOLUTIONS = {
    res: EncodingConfig(name=f"vgg16-ridge-{res}", workload=w)
    for res, w in PAPER_WORKLOADS.items()
}

CONFIG = RESOLUTIONS["whole_brain_bmor"]
