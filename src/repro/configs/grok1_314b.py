"""grok-1-314b — 8-expert top-2 MoE [hf:xai-org/grok-1].

Assigned: 64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072,
MoE 8e top-2.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32_768,
    vocab=131_072,
    pattern=("global_attn",),
    mlp_act="swiglu",
    tie_embeddings=False,
    attn_logit_softcap=30.0,     # grok uses attn logit capping (30)
    final_logit_softcap=30.0,
    moe=MoEConfig(n_experts=8, top_k=2, capacity_factor=1.25,
                  group_size=4096),
    source="[hf:xai-org/grok-1] 64L/6144/48H/kv8/32768/8e@2, logit softcap 30",
)
