"""llava-next-34b — VLM with anyres tiling
[hf:llava-hf/llava-v1.6-mistral-7b-hf; 34B uses the NousHermes-Yi-34B LM].

Assigned: 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.

Per the carve-out the vision tower is a STUB: ``prefix_embeds`` are
precomputed anyres patch embeddings of shape (B, n_patches, d_model) fed
through a learned projector; this config is the language decoder that
consumes them.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20_480,
    vocab=64_000,
    pattern=("global_attn",),
    mlp_act="swiglu",
    rope_theta=5_000_000.0,
    tie_embeddings=False,
    frontend="vision_stub",
    source="[hf:llava-hf/llava-v1.6-mistral-7b-hf] anyres VLM; 34B LM dims "
           "(Yi-34B: 60L/7168/56H/kv8/20480)",
)
