"""gemma2-2b — alternating local/global attention + logit softcaps
[arXiv:2408.00118].

Assigned: 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab=256_000,
    pattern=("local_attn", "global_attn"),
    window=4096,
    mlp_act="geglu",
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    use_post_norm=True,
    scale_embedding=True,
    tie_embeddings=True,
    source="[arXiv:2408.00118] Gemma2: 2B = 26L/2304/8H/kv4/9216; "
           "local:global alternation w=4096; softcaps 50/30",
)
