"""Single-pass fold-aware Gram statistics (CV by downdating, not recompute).

The paper's mutualisation lever (Eq. 4-5) computes the expensive statistics
once and reuses them across all targets and all λ — but k-fold CV naively
re-accumulates the Gram matrix ``G_train = X_trᵀX_tr`` for every split,
paying the dominant ``T_W = O(np²)`` term ``k`` times (each split covers
``(k-1)/k`` of the rows, so the total is ``(k-1)·np²`` plus ``np²`` for the
full-data refit).

This module reformulates CV on *sufficient statistics*: every per-fold
partial statistic

    G_f = X_fᵀX_f        C_f = X_fᵀY_f        (plus first/second moments)

is accumulated in ONE streaming pass over the rows — each row enters exactly
one fold's accumulator — and every training-split statistic is then derived
by subtraction (the Gram downdate identity, exact in exact arithmetic):

    G_train(f) = Σ_g G_g − G_f        C_train(f) = Σ_g C_g − C_f

The full-data refit statistics are the sums themselves, so a complete
k-fold CV + refit costs a single ``np²`` accumulation.  The same identity
is what makes the distributed B-MOR path a single ``psum`` over row shards
(``repro.core.bmor``); here it is factored out so the single-shard
``ridge.ridge_cv``, the dual path, B-MOR, and the Pallas kernel
(``repro.kernels.gram.xty_folds``) all consume one implementation.

The per-row moment statistics (``xsum``, ``ysum``, ``ysq``, ``count``) make
validation scores computable from the statistics alone (no validation-row
matrix needed): for weights ``W`` the held-out sums are ``Σŷ = xsum_fᵀW``,
``Σŷ² = diag(WᵀG_fW)``, ``Σyŷ = diag(C_fᵀW)`` — which is what opens the
out-of-core path (``FoldStatsAccumulator`` / ``BrainEncoder.fit_chunks``)
where ``X`` arrives as row batches larger than device memory.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import jax
import jax.numpy as jnp

from repro import obs


def fold_bounds(n: int, n_folds: int) -> list[tuple[int, int]]:
    """Contiguous k-fold boundaries (static, trace-time).

    The first ``n % n_folds`` folds get the extra row, matching
    scikit-learn's ``KFold`` and the seed ``ridge._fold_bounds``.
    """
    if not 1 <= n_folds <= n:
        raise ValueError(f"need 1 <= n_folds <= n, got n_folds={n_folds}, "
                         f"n={n}")
    sizes = [n // n_folds + (1 if i < n % n_folds else 0)
             for i in range(n_folds)]
    bounds, start = [], 0
    for s in sizes:
        bounds.append((start, start + s))
        start += s
    return bounds


def fold_of_rows(row_ids: jax.Array, n_total: int, n_folds: int) -> jax.Array:
    """Contiguous fold id of each global row (same split as ``fold_bounds``).

    Traced-index variant for sharded rows, where a shard's slice of the
    global row range is only known at run time (``jax.lax.axis_index``).
    """
    base, rem = divmod(n_total, n_folds)
    # Rows [0, (base+1)*rem) live in folds of size base+1; the rest size base.
    big = (base + 1) * rem
    in_big = row_ids < big
    fold_big = row_ids // jnp.maximum(base + 1, 1)
    fold_small = rem + (row_ids - big) // jnp.maximum(base, 1)
    return jnp.where(in_big, fold_big, fold_small).astype(jnp.int32)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class FoldStats:
    """Per-fold sufficient statistics of a supervised row stream.

    All statistics are f32 accumulations regardless of the input dtype
    (bf16/f32 inputs hit the MXU with ``preferred_element_type=float32``,
    the DESIGN §2 adaptation of the paper's float64 BLAS).
    """

    G: jax.Array        # (k, p, p)  per-fold XᵀX
    C: jax.Array        # (k, p, t)  per-fold XᵀY
    xsum: jax.Array     # (k, p)     per-fold Σ x
    ysum: jax.Array     # (k, t)     per-fold Σ y
    # Per-fold CENTRED second moment Σ (y − ȳ_f)², not the raw Σ y²: raw
    # second moments cancel catastrophically in f32 (ss_tot = Σy² − mȳ²)
    # for targets with large means, flipping λ selection on un-standardized
    # data.  The streaming accumulator maintains it with the Chan et al.
    # pairwise-combination update, so it stays exact under chunking.
    ysq: jax.Array      # (k, t)     per-fold Σ (y − ȳ_f)²
    count: jax.Array    # (k,)       per-fold row count

    @property
    def n_folds(self) -> int:
        return self.G.shape[0]

    @property
    def G_total(self) -> jax.Array:
        """Full-data Gram — the sums over folds ARE the refit statistics."""
        return jnp.sum(self.G, axis=0)

    @property
    def C_total(self) -> jax.Array:
        return jnp.sum(self.C, axis=0)

    def train(self, f: int) -> tuple[jax.Array, jax.Array]:
        """Downdated training statistics ``(G_tr, C_tr)`` for split ``f``.

        ``G_total − G_f`` equals ``X_trᵀX_tr`` exactly in exact arithmetic
        (it is a sum over disjoint row sets), so this is Algorithm 1's
        per-split factorisation input without re-touching the rows.
        """
        return self.G_total - self.G[f], self.C_total - self.C[f]


def _xty(X: jax.Array, Y: jax.Array) -> jax.Array:
    return jnp.matmul(X.T, Y, preferred_element_type=jnp.float32)


def compute(X: jax.Array, Y: jax.Array, n_folds: int, *,
            use_pallas: bool = False) -> FoldStats:
    """All per-fold statistics in one pass over the rows.

    Fold membership is contiguous and trace-time static (``fold_bounds``),
    so each fold's ``{G_f, C_f}`` is a matmul over exactly its own rows —
    no row is touched by more than one accumulation, no per-fold
    ``concatenate`` copy of ``X`` is made.  With ``use_pallas`` the fold
    tiles come from ``kernels.gram.xty_folds``, which streams HBM row
    blocks once and scatters each block's contribution to its fold's
    output tile.
    """
    n, p = X.shape
    bounds = fold_bounds(n, n_folds)
    if use_pallas:
        from repro.kernels import ops
        # One fused kernel invocation: Xᵀ[X | Y] per fold — a single repack
        # and a single HBM sweep of X instead of separate G and C passes.
        dt = jnp.promote_types(X.dtype, Y.dtype)
        Z = jnp.concatenate([X.astype(dt), Y.astype(dt)], axis=1)
        GC = ops.xty_folds(X.astype(dt), Z, tuple(bounds))
        G, C = GC[:, :, :p], GC[:, :, p:]
    else:
        G = jnp.stack([_xty(X[lo:hi], X[lo:hi]) for lo, hi in bounds])
        C = jnp.stack([_xty(X[lo:hi], Y[lo:hi]) for lo, hi in bounds])
    Xf = X.astype(jnp.float32)
    Yf = Y.astype(jnp.float32)
    xsum = jnp.stack([jnp.sum(Xf[lo:hi], axis=0) for lo, hi in bounds])
    ysum = jnp.stack([jnp.sum(Yf[lo:hi], axis=0) for lo, hi in bounds])
    ysq = jnp.stack([
        jnp.sum((Yf[lo:hi] - jnp.mean(Yf[lo:hi], axis=0)) ** 2, axis=0)
        for lo, hi in bounds])
    count = jnp.asarray([hi - lo for lo, hi in bounds], jnp.float32)
    return FoldStats(G=G, C=C, xsum=xsum, ysum=ysum, ysq=ysq, count=count)


def partial_fold_stats(X: jax.Array, Y: jax.Array, fold_ids: jax.Array,
                       n_folds: int) -> tuple[jax.Array, jax.Array]:
    """Per-fold ``{G_f, C_f}`` from traced fold membership (sharded rows).

    Inside ``shard_map`` a shard's global row range depends on
    ``axis_index`` — not trace-time static — so fold membership is a mask,
    not a slice.  Each fold costs a masked matmul over the local rows; the
    payoff is collective, not FLOP, economy: the stacked ``(k, p, ·)``
    result is ONE ``psum`` and the total/training statistics then derive
    by summation/downdating with no further collectives (B-MOR previously
    paid ``k+1`` psums of the same bytes).
    """
    def one(f: int) -> tuple[jax.Array, jax.Array]:
        m = (fold_ids == f).astype(X.dtype)[:, None]
        Xm = X * m
        return _xty(Xm, Xm), _xty(Xm, Y * m)
    per_fold = [one(f) for f in range(n_folds)]
    return (jnp.stack([g for g, _ in per_fold]),
            jnp.stack([c for _, c in per_fold]))


class _FixedShapeUpdate:
    """The ONE compiled program of the streaming accumulation.

    Every chunk — fold-aligned or not, full or ragged — is presented to
    this update as the SAME fixed shape: ``(chunk_rows, p)`` rows plus a
    per-row slot one-hot (``(chunk_rows, s_max)``; zero rows are padding)
    and the traced fold index of each slot.  The ``(k, p, p+t)`` partial
    update is then a single masked einsum + scatter-add, so the whole
    stream traces exactly once per ``(chunk_rows, p, t, k, s_max, dtype)``
    signature instead of once per distinct fold-segment length (the
    eager per-segment path recompiled at every fold boundary, ragged
    tail, and chunk/fold misalignment — a compile storm the oocore bench
    measured at >10 traces per stream).

    A chunk of ``chunk_rows`` contiguous rows intersects at most
    ``s_max = (chunk_rows − 2) // min_fold + 2`` folds, so the masked
    work is a small constant multiple (2 for ``chunk_rows ≤ min_fold``)
    of the unmasked matmul — paid once, unlike a recompile.  Unused
    slots carry an all-zero mask and contribute exact zeros through the
    scatter-``add``, so duplicate slot→fold indices are harmless.
    """

    def __init__(self) -> None:
        self.compiles = obs.CompileCounter("foldstats.chunk_update")
        self._fn = jax.jit(self._update, static_argnames=("use_pallas",))

    @property
    def compile_count(self) -> int:
        return self.compiles.count

    def __call__(self, stats: FoldStats, X, Y, onehot, slot_fold, *,
                 use_pallas: bool = False) -> FoldStats:
        return self._fn(stats, X, Y, onehot, slot_fold,
                        use_pallas=use_pallas)

    def _update(self, stats: FoldStats, X: jax.Array, Y: jax.Array,
                onehot: jax.Array, slot_fold: jax.Array,
                use_pallas: bool = False) -> FoldStats:
        # Python side effect at TRACE time only: counts actual program
        # builds, the O(1)-compiles contract tests and the oocore bench
        # assert on.  Under REPRO_OBS_STRICT=1 an open expect() window
        # turns an excess trace into a RecompileError right here.
        self.compiles.mark()
        p = X.shape[1]
        dt = jnp.promote_types(X.dtype, Y.dtype)
        # One fused Xᵀ[X | Y] per slot — a single batched GEMM per chunk.
        Z = jnp.concatenate([X.astype(dt), Y.astype(dt)], axis=1)
        w = onehot                                          # (m, s) f32 0/1
        if use_pallas:
            # Kernel tier: mask + Gram + cross-covariance fused into one
            # VMEM-resident blocked reduction — one HBM pass per chunk,
            # the (s, m, p) masked intermediate never materialised.
            from repro.kernels import ops
            GC = ops.xty_folds_masked(X.astype(dt), Z,
                                      w.astype(dt))         # (s, p, p+t)
        else:
            Xw = (X.astype(dt)[None]
                  * jnp.swapaxes(w, 0, 1)[:, :, None].astype(dt))
            GC = jnp.einsum("smp,mq->spq", Xw, Z,
                            preferred_element_type=jnp.float32)
        Xf, Yf = X.astype(jnp.float32), Y.astype(jnp.float32)
        cnt = jnp.sum(w, axis=0)                             # (s,)
        xsum = jnp.einsum("ms,mp->sp", w, Xf,
                          preferred_element_type=jnp.float32)
        ysum = jnp.einsum("ms,mt->st", w, Yf,
                          preferred_element_type=jnp.float32)
        # Chan et al. pairwise combination of the centred second moment:
        # M2_{a∪b} = M2_a + M2_b + (μ_a − μ_b)²·n_a n_b/(n_a+n_b) — exact,
        # and free of the Σy² − mȳ² cancellation.  Per-slot quantities are
        # gathered from / scattered back to each slot's fold; an empty
        # slot has cnt = 0 so every one of its additions is exactly 0.
        mu_b = ysum / jnp.maximum(cnt, 1.0)[:, None]
        d = Yf[None, :, :] - mu_b[:, None, :]                # (s, m, t)
        m2 = jnp.einsum("ms,smt->st", w, d * d,
                        preferred_element_type=jnp.float32)
        n_a = stats.count[slot_fold]                         # (s,)
        mu_a = stats.ysum[slot_fold] / jnp.maximum(n_a, 1.0)[:, None]
        both = ((n_a > 0) & (cnt > 0))[:, None]
        delta2 = jnp.where(both, (mu_a - mu_b) ** 2, 0.0)
        ysq_add = m2 + delta2 * (n_a * cnt
                                 / jnp.maximum(n_a + cnt, 1.0))[:, None]
        return FoldStats(
            G=stats.G.at[slot_fold].add(GC[:, :, :p]),
            C=stats.C.at[slot_fold].add(GC[:, :, p:]),
            xsum=stats.xsum.at[slot_fold].add(xsum),
            ysum=stats.ysum.at[slot_fold].add(ysum),
            ysq=stats.ysq.at[slot_fold].add(ysq_add),
            count=stats.count.at[slot_fold].add(cnt))


# Module-level singleton: shards and repeated streams share one jit cache,
# so e.g. 8 shard accumulators with identical chunk shapes cost ONE trace.
_FIXED_UPDATE = _FixedShapeUpdate()


def chunk_update_compile_count() -> int:
    """Trace count of the fixed-shape chunk update (monotonic, process-wide).

    Take a delta around a stream to measure its compiles; the contract is
    ``delta == 1`` for a fresh ``(chunk_rows, p, t, k)`` signature and
    ``0`` for a repeat, regardless of fold alignment or ragged tails.

    (Thin alias over ``chunk_update_compiles().count`` — the shared
    ``obs.CompileCounter`` primitive; kept so existing gates read the
    same number they always did.)
    """
    return _FIXED_UPDATE.compiles.count


def chunk_update_compiles() -> "obs.CompileCounter":
    """The chunk update's :class:`repro.obs.CompileCounter` — open an
    ``expect(at_most=...)`` window around a stream to arm the recompile
    sentinel (raises at trace time under ``REPRO_OBS_STRICT=1``)."""
    return _FIXED_UPDATE.compiles


class FoldStatsAccumulator:
    """Streaming builder of ``FoldStats`` from ordered row chunks.

    The out-of-core entry point (``BrainEncoder.fit_chunks``): rows arrive
    as host-sized batches; each batch is padded to one fixed chunk shape
    and applied through the single jitted masked update
    (``_FixedShapeUpdate``) — fold boundaries, ragged tails, and
    chunk/fold misalignment change only the mask contents, never the
    compiled program.  Rows must arrive in global row order; ``finalize``
    checks that exactly the owned row window was seen.

    ``chunk_rows`` pins the fixed shape up front (what the store-streaming
    callers do, so every shard shares one program signature); when omitted
    it is inferred from the first chunk.  Oversized batches are split,
    undersized ones zero-padded — the pad rows carry an all-zero mask, so
    they contribute exact zeros to every statistic.

    ``row_start``/``row_stop`` restrict the accumulator to a contiguous
    window of the global rows — the sharded out-of-core path gives each
    shard its own window (``shard_row_ranges``) and combines the partial
    ``FoldStats`` afterwards (``combine`` / ``compute_sharded_chunked``).
    Fold membership always derives from the GLOBAL ``(n_total, n_folds)``
    split, so a shard boundary in the middle of a fold is handled exactly:
    the fold's statistics simply arrive as two partials that ``combine``
    merges with the Chan update.
    """

    def __init__(self, n_total: int, n_folds: int, *, row_start: int = 0,
                 row_stop: int | None = None,
                 chunk_rows: int | None = None,
                 use_pallas: bool = False):
        self.n_total = n_total
        # Kernel-tier flag for the heavy [G|C] contribution.  Static under
        # the jit, so fused and unfused streams are distinct signatures —
        # each still traces exactly once (the compile_count contract is
        # per signature, and a process never mixes tiers mid-stream).
        self.use_pallas = use_pallas
        self.bounds = fold_bounds(n_total, n_folds)
        self.row_start = row_start
        self.row_stop = n_total if row_stop is None else row_stop
        if not 0 <= self.row_start < self.row_stop <= n_total:
            raise ValueError(
                f"need 0 <= row_start < row_stop <= n_total, got "
                f"[{row_start}, {row_stop}) with n_total={n_total}")
        if chunk_rows is not None and chunk_rows < 1:
            raise ValueError(f"chunk_rows must be >= 1, got {chunk_rows}")
        self._offset = self.row_start
        self._stats: FoldStats | None = None
        # Fixed shape of the compiled update: pin to the caller's chunk
        # size (never more than the data) or infer from the first chunk.
        self._fixed_rows = (None if chunk_rows is None
                            else min(chunk_rows, n_total))

    def _init_stats(self, p: int, t: int) -> FoldStats:
        k = len(self.bounds)
        z = jnp.zeros
        return FoldStats(G=z((k, p, p), jnp.float32),
                         C=z((k, p, t), jnp.float32),
                         xsum=z((k, p), jnp.float32),
                         ysum=z((k, t), jnp.float32),
                         ysq=z((k, t), jnp.float32),
                         count=z((k,), jnp.float32))

    def _max_slots(self) -> int:
        """Folds a ``_fixed_rows`` window can intersect: it fully contains
        every fold but its two ends, each of size ≥ ``min_fold``."""
        min_fold = min(hi - lo for lo, hi in self.bounds)
        return min(len(self.bounds),
                   max(1, (self._fixed_rows - 2) // min_fold + 2))

    def _slot_mask(self, m: int) -> tuple:
        """(onehot (fixed, s_max) f32, slot_fold (s_max,) i32) for the
        ``m`` valid rows at the current offset (pad rows all-zero)."""
        import numpy as np
        s_max = self._max_slots()
        onehot = np.zeros((self._fixed_rows, s_max), np.float32)
        slot_fold = np.zeros((s_max,), np.int32)
        s = 0
        for f, (lo, hi) in enumerate(self.bounds):
            seg_lo = max(lo, self._offset) - self._offset
            seg_hi = min(hi, self._offset + m) - self._offset
            if seg_lo >= seg_hi:
                continue
            assert s < s_max, "slot bound violated (fold split bug)"
            onehot[seg_lo:seg_hi, s] = 1.0
            slot_fold[s] = f
            s += 1
        return onehot, slot_fold

    def _apply(self, Xs, Ys, onehot, slot_fold) -> None:
        """Apply one fixed-shape padded chunk to the running statistics.

        The single overridable seam of the streaming machinery: subclasses
        that accumulate a different statistic from the same masked chunks
        (``repro.wholebrain.ColumnBlockAccumulator``) replace only this —
        splitting, padding, slot masks, offsets, and the finalize contract
        stay shared.
        """
        self._stats = _FIXED_UPDATE(self._stats, jnp.asarray(Xs),
                                    jnp.asarray(Ys), onehot, slot_fold,
                                    use_pallas=self.use_pallas)

    def update(self, X_chunk: jax.Array, Y_chunk: jax.Array) -> None:
        import numpy as np
        m = X_chunk.shape[0]
        if self._offset + m > self.row_stop:
            raise ValueError(
                f"chunk of {m} rows at offset {self._offset} overruns "
                f"row_stop={self.row_stop}")
        if self._stats is None:
            self._stats = self._init_stats(X_chunk.shape[1],
                                           Y_chunk.shape[1])
        if self._fixed_rows is None:
            self._fixed_rows = m
        fixed = self._fixed_rows
        lo = 0
        while lo < m:                       # oversized batches: split
            hi = min(lo + fixed, m)
            Xs, Ys = X_chunk[lo:hi], Y_chunk[lo:hi]
            if hi - lo < fixed:             # ragged: zero-pad to the shape
                Xp = np.zeros((fixed, Xs.shape[1]), np.asarray(Xs).dtype)
                Yp = np.zeros((fixed, Ys.shape[1]), np.asarray(Ys).dtype)
                Xp[:hi - lo], Yp[:hi - lo] = Xs, Ys
                Xs, Ys = Xp, Yp
            onehot, slot_fold = self._slot_mask(hi - lo)
            self._apply(Xs, Ys, onehot, slot_fold)
            self._offset += hi - lo
            lo = hi
        # Synchronize before returning: jnp.asarray's host→device transfer
        # is ASYNC, and a prefetched source recycles its staging buffer as
        # soon as the next chunk is requested — returning with the copy
        # still in flight would let the reader overwrite rows the update
        # has not yet consumed.  Blocking on the (tiny) count output fences
        # the whole executable; chunk updates are sequentially dependent,
        # so no cross-chunk pipelining is lost, and the reader thread still
        # overlaps the next read with this compute.
        jax.block_until_ready(self._stats.count)

    def finalize(self) -> FoldStats:
        if self._stats is None or self._offset != self.row_stop:
            raise ValueError(
                f"saw rows [{self.row_start}, {self._offset}), expected the "
                f"full window [{self.row_start}, {self.row_stop})")
        return self._stats


def compute_chunked(chunks: Iterable[tuple[jax.Array, jax.Array]],
                    n_total: int, n_folds: int, *,
                    chunk_rows: int | None = None,
                    use_pallas: bool = False) -> FoldStats:
    """One-call streaming accumulation over ``(X_chunk, Y_chunk)`` batches.

    ``chunk_rows`` pins the fixed shape of the compiled masked update up
    front (one trace for the whole stream); omitted, it is inferred from
    the first chunk.  ``use_pallas`` routes the heavy [G|C] contribution
    through the fused ``kernels.gram.xty_folds_masked`` tier.  Iterators
    with a ``close`` method (the prefetching store reader) are closed on
    every exit path.
    """
    acc = FoldStatsAccumulator(n_total, n_folds, chunk_rows=chunk_rows,
                               use_pallas=use_pallas)
    # Recompile sentinel: one fixed shape → at most one fresh trace for
    # the whole stream (zero when the signature is already warm).
    with _FIXED_UPDATE.compiles.expect(at_most=1):
        try:
            for X_chunk, Y_chunk in chunks:
                with obs.span("fit.foldstats.chunk_update",
                              rows=int(X_chunk.shape[0])):
                    acc.update(X_chunk, Y_chunk)
        finally:
            if hasattr(chunks, "close"):
                chunks.close()
    return acc.finalize()


@jax.jit
def _combine_pair(a: FoldStats, b: FoldStats) -> FoldStats:
    """Chan et al. pairwise combination of two per-fold partials.

    ``G``/``C``/``xsum``/``ysum``/``count`` are plain sums over disjoint row
    sets; the centred second moment needs the pairwise update
    ``M2_{a∪b} = M2_a + M2_b + (μ_a − μ_b)²·n_a n_b/(n_a+n_b)`` per fold —
    exact, and free of the ``Σy² − mȳ²`` cancellation (the reason
    ``FoldStats.ysq`` is stored centred at all).
    """
    n_a = a.count[:, None]                                   # (k, 1)
    n_b = b.count[:, None]
    mu_a = a.ysum / jnp.maximum(n_a, 1.0)
    mu_b = b.ysum / jnp.maximum(n_b, 1.0)
    both = (n_a > 0) & (n_b > 0)
    delta2 = jnp.where(both, (mu_a - mu_b) ** 2, 0.0)
    ysq = a.ysq + b.ysq + delta2 * n_a * n_b / jnp.maximum(n_a + n_b, 1.0)
    return FoldStats(G=a.G + b.G, C=a.C + b.C, xsum=a.xsum + b.xsum,
                     ysum=a.ysum + b.ysum, ysq=ysq, count=a.count + b.count)


def combine(parts: Sequence[FoldStats]) -> FoldStats:
    """Merge per-shard partial ``FoldStats`` into the global statistics.

    Pairwise (tree) reduction: exact for the summed statistics and applies
    the Chan update to the centred moments at every merge, so the result is
    invariant (to f32 rounding) under how the rows were split into shards.
    """
    if not parts:
        raise ValueError("combine() needs at least one partial FoldStats")
    parts = list(parts)
    while len(parts) > 1:
        merged = [_combine_pair(parts[i], parts[i + 1])
                  for i in range(0, len(parts) - 1, 2)]
        if len(parts) % 2:
            merged.append(parts[-1])
        parts = merged
    return parts[0]


def shard_row_ranges(n_total: int, n_shards: int) -> list[tuple[int, int]]:
    """Contiguous near-equal row windows, one per shard (``data_axis``).

    Same size policy as ``fold_bounds`` (first ``n % s`` shards get the
    extra row) — but the two splits are independent: shard windows may cut
    folds anywhere, ``combine`` reconciles the partials.
    """
    if not 1 <= n_shards <= n_total:
        raise ValueError(f"need 1 <= n_shards <= n_total, got "
                         f"n_shards={n_shards}, n={n_total}")
    return fold_bounds(n_total, n_shards)


def compute_sharded_chunked(
        shard_streams: Sequence[Iterable[tuple[jax.Array, jax.Array]]],
        n_total: int, n_folds: int, *, mesh=None,
        data_axis: str = "data",
        chunk_rows: int | None = None,
        use_pallas: bool = False) -> FoldStats:
    """Sharded out-of-core accumulation along ``data_axis``.

    ``shard_streams[s]`` yields shard ``s``'s row chunks, covering exactly
    the window ``shard_row_ranges(n_total, len(shard_streams))[s]`` in
    global row order.  Each shard accumulates its own partial ``FoldStats``
    (``FoldStatsAccumulator`` with the shard's row window — the streaming
    mirror of ``partial_fold_stats``'s masked accumulation inside B-MOR's
    ``shard_map``); the finalize step then combines the partials:

    * the heavy ``(k, p, p+t)`` stacks ``[G | C]`` merge in a SINGLE
      ``psum`` over ``data_axis`` when a ``mesh`` is given (one collective
      for all folds, the same economy ``bmor.bmor_fit`` gets from the
      stacked layout), or a host-side tree reduction otherwise;
    * the small centred moment statistics merge with the Chan pairwise
      update (``combine``), which a plain ``psum`` cannot express.

    ``chunk_rows`` pins the fixed shape of the compiled masked update so
    EVERY shard's stream shares one program signature (one trace total,
    however the shard windows cut the folds).  Streams are consumed
    sequentially and closed (prefetching readers stop their thread and
    release their staging buffers as soon as their shard is done).
    """
    ranges = shard_row_ranges(n_total, len(shard_streams))
    parts: list[FoldStats] = []
    # Sentinel window: with chunk_rows pinned every shard shares ONE
    # program signature, so the whole sharded pass compiles at most once.
    # Left to infer (chunk_rows=None), ragged shard windows may pin
    # different first-chunk shapes per shard — allow one trace per shard.
    with _FIXED_UPDATE.compiles.expect(
            at_most=1 if chunk_rows else len(shard_streams)):
        for s, ((lo, hi), stream) in enumerate(zip(ranges, shard_streams)):
            acc = FoldStatsAccumulator(n_total, n_folds, row_start=lo,
                                       row_stop=hi, chunk_rows=chunk_rows,
                                       use_pallas=use_pallas)
            with obs.span("fit.foldstats.shard", shard=s, row_lo=lo,
                          row_hi=hi):
                try:
                    for X_chunk, Y_chunk in stream:
                        acc.update(X_chunk, Y_chunk)
                finally:
                    if hasattr(stream, "close"):
                        stream.close()
            parts.append(acc.finalize())
    if mesh is None or len(parts) == 1:
        return combine(parts)
    # Device-mesh finalize: the heavy (k, p, p+t) stacks reduce in ONE
    # psum over data_axis; only the (k, t)-sized moment statistics go
    # through the host-side Chan merge (stripped of their G/C so the big
    # tensors are reduced exactly once).
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.compat import shard_map

    if mesh.shape[data_axis] != len(parts):
        raise ValueError(
            f"mesh axis {data_axis!r} has {mesh.shape[data_axis]} shards "
            f"but {len(parts)} shard streams were accumulated")
    merged = combine([dataclasses.replace(s, G=s.G[:, :0, :0],
                                          C=s.C[:, :0, :0]) for s in parts])
    GC = jnp.stack([jnp.concatenate([s.G, s.C], axis=-1) for s in parts])
    GC = jax.device_put(GC, NamedSharding(mesh, P(data_axis)))
    reduced = jax.jit(shard_map(
        lambda gc: jax.lax.psum(gc[0], data_axis), mesh=mesh,
        in_specs=(P(data_axis),), out_specs=P(), check_vma=False))(GC)
    p = parts[0].G.shape[1]
    return dataclasses.replace(merged, G=reduced[..., :p],
                               C=reduced[..., p:])


class ColumnMoments:
    """Streaming per-column mean/variance over row chunks (Chan/Welford).

    The first pass of the two-pass streaming standardization
    (``pipeline.fit_chunked``): accumulates ``(count, mean, M2)`` per
    column in float64 on the host — the chunks are memmap views, so this
    pass costs one read of the rows and O(columns) residency.
    """

    def __init__(self) -> None:
        self.count = 0.0
        self.mean: "np.ndarray | None" = None
        self.m2: "np.ndarray | None" = None

    def update(self, A) -> None:
        import numpy as np
        A = np.asarray(A, np.float64)
        n_b = float(A.shape[0])
        if n_b == 0:
            return
        mu_b = A.mean(axis=0)
        m2_b = ((A - mu_b) ** 2).sum(axis=0)
        if self.mean is None:
            self.count, self.mean, self.m2 = n_b, mu_b, m2_b
            return
        n_a = self.count
        delta = mu_b - self.mean
        tot = n_a + n_b
        self.mean = self.mean + delta * (n_b / tot)
        self.m2 = self.m2 + m2_b + delta ** 2 * (n_a * n_b / tot)
        self.count = tot

    def std(self, eps: float = 1e-6) -> "np.ndarray":
        import numpy as np
        assert self.mean is not None, "no rows seen"
        return np.sqrt(self.m2 / self.count) + eps


def validation_scores_per_target(
        stats: FoldStats, f: int, Q: jax.Array, evals: jax.Array,
        C_tr: jax.Array, lambdas: jax.Array, scoring: str) -> jax.Array:
    """Per-λ, per-TARGET validation score of split ``f``, shape ``(r, t)``.

    The un-averaged form of ``validation_scores_from_stats`` (which is its
    mean over targets) — the column-blocked driver (``repro.wholebrain``)
    needs the per-column scores so it can aggregate across target blocks
    on the host without ever building a full-``t`` score tensor in one
    program.  Every contraction is per-column independent, so a column
    block of this function's output is bit-identical to the same columns
    of the full-width call (the property the target-block invariance
    harness locks down).

    With ``W_r = Q (Λ+λ_r)⁻¹ QᵀC_tr``, the held-out error needs only the
    fold's own statistics — no validation rows:

        Σŷ   = xsum_fᵀ W_r          Σŷ²  = diag(W_rᵀ G_f W_r)
        Σyŷ  = diag(C_fᵀ W_r)       ȳ, Σ(y−ȳ)², m  from the moment stats.

    Everything stays in the eigenbasis, so the per-λ work is diagonal plus
    one ``(p×p)·(p×t)`` contraction per λ — the mutualisation of Eq. 5
    extended to the scoring itself.  ``"r2"`` and ``"r"`` match
    ``ridge._score`` exactly in exact arithmetic (after the mean the
    wrapper below takes).

    Precision caveat: unlike the row-based CV loop (which centres the
    validation rows before any large contraction), statistics can only be
    centred *after* rotation, so f32 accuracy degrades roughly
    quadratically in ``|ȳ|/σ_y``.  λ selection stays robust for "r2"
    (score gaps between λ grow with the mean via the shrinkage penalty),
    but extreme un-standardized targets should be standardized first —
    ``BrainEncoder.fit_chunks`` enforces this.
    """
    # Coefficients in the eigenbasis, per λ: Z_r = (Λ+λ_r)⁻¹ QᵀC_tr.
    A = jnp.matmul(Q.T, C_tr, preferred_element_type=jnp.float32)  # (p, t)
    Z = A[None] / (evals[None, :, None] + lambdas[:, None, None])  # (r, p, t)
    m = stats.count[f]
    mu = (stats.ysum[f] / m)[None]                                 # (1, t) ȳ
    m2 = stats.ysq[f][None]                                        # Σ(y−ȳ)²
    # Rotate this fold's validation statistics into the eigenbasis, in
    # CENTRED form: Ghat_c/Chat_c are the rotations of Σ(x−x̄)(x−x̄)ᵀ and
    # Σ(x−x̄)(y−ȳ)ᵀ, so every per-λ contraction below runs at signal
    # scale — the raw-moment expansions (s_hat2 − mŷ̄², …) would cancel
    # catastrophically in f32 when predictions inherit large target means
    # (the regime FoldStats.ysq is centred for).
    u = jnp.matmul(stats.xsum[f], Q,
                   preferred_element_type=jnp.float32)             # (p,)
    Chat = jnp.matmul(Q.T, stats.C[f],
                      preferred_element_type=jnp.float32)          # (p, t)
    Chat_c = Chat - u[:, None] * mu
    Ghat = jnp.matmul(Q.T, jnp.matmul(stats.G[f], Q,
                                      preferred_element_type=jnp.float32),
                      preferred_element_type=jnp.float32)          # (p, p)
    Ghat_c = Ghat - u[:, None] * u[None, :] / m
    s_hat = jnp.einsum("p,rpt->rt", u, Z,
                       preferred_element_type=jnp.float32)         # Σŷ
    c_xy = jnp.einsum("pt,rpt->rt", Chat_c, Z,
                      preferred_element_type=jnp.float32)          # Σ(y−ȳ)ŷ
    c_p2 = jnp.einsum("rpt,pq,rqt->rt", Z, Ghat_c, Z,
                      preferred_element_type=jnp.float32)          # Σ(ŷ−ŷ̄)²
    if scoring == "r2":
        # Σ(y−ŷ)² = Σ(y−ȳ)² − 2Σ(y−ȳ)(ŷ−ŷ̄) + Σ(ŷ−ŷ̄)² + m(ŷ̄−ȳ)²,
        # with only the scalar fold means meeting at full magnitude.
        mean_term = m * (s_hat / m - mu) ** 2
        ss_res = m2 - 2.0 * c_xy + c_p2 + mean_term
        return 1.0 - ss_res / (m2 + 1e-12)
    # Pearson r from centred moments per target.
    den = jnp.sqrt(jnp.maximum(m2 * c_p2, 0.0)) + 1e-12
    return c_xy / den


def validation_scores_from_stats(
        stats: FoldStats, f: int, Q: jax.Array, evals: jax.Array,
        C_tr: jax.Array, lambdas: jax.Array, scoring: str) -> jax.Array:
    """Per-λ validation score of split ``f`` from sufficient statistics —
    the mean over targets of ``validation_scores_per_target``, shape
    ``(r,)``.  See that function for the algebra and the precision caveat;
    ``"r2"`` and ``"r"`` match ``ridge._score`` exactly in exact
    arithmetic."""
    return jnp.mean(validation_scores_per_target(
        stats, f, Q, evals, C_tr, lambdas, scoring), axis=1)


__all__: Sequence[str] = (
    "ColumnMoments", "FoldStats", "FoldStatsAccumulator",
    "chunk_update_compile_count", "chunk_update_compiles", "combine",
    "compute", "compute_chunked",
    "compute_sharded_chunked", "fold_bounds", "fold_of_rows",
    "partial_fold_stats", "shard_row_ranges", "validation_scores_from_stats",
    "validation_scores_per_target",
)
