"""Analytic time-complexity model of ridge variants (paper §3).

Floating-point multiplication counts for the three implementations the paper
compares.  The benchmark harness checks measured scaling against these
predictions (Eq. 6 and Eq. 7 of the paper) and the roofline analysis uses the
same terms to locate each configuration on the compute/memory/collective
rooflines of the production TPU mesh.

Notation (paper Table 3): n time samples, p features, t targets, r candidate
λ values, c concurrent workers (mesh shards here).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class RidgeWorkload:
    n: int          # time samples
    p: int          # features
    t: int          # brain targets
    r: int = 11     # λ grid size (paper §2.2.4)
    n_folds: int = 5


def t_m_naive(w: RidgeWorkload) -> float:
    """T_M without the SVD trick: invert (XᵀX+λI) per λ — O(p³r + p²nr)."""
    return float(w.p) ** 3 * w.r + float(w.p) ** 2 * w.n * w.r


def t_m(w: RidgeWorkload) -> float:
    """T_M with the factorisation mutualised across λ: O(p²nr + pr).

    (Paper §3.1.  The dominant O(p²n) SVD/eigh+rotation cost is paid once per
    CV split; the per-λ part is diagonal.)
    """
    return float(w.p) ** 2 * w.n * w.r + float(w.p) * w.r


def t_w(w: RidgeWorkload) -> float:
    """T_W: applying M(λ) to the targets across the grid — O(pntr)."""
    return float(w.p) * w.n * w.t * w.r


def t_w_per_fold(w: RidgeWorkload) -> float:
    """Gram-statistics cost of per-fold re-accumulation (the seed CV path).

    Each of the k splits recomputes ``X_trᵀX_tr`` over its ``(k−1)/k·n``
    training rows — ``(k−1)·np²`` total — and the full-data refit pays one
    more ``np²``: the dominant ``O(np²)`` term is on the critical path
    ``k`` times.
    """
    return float(w.n_folds) * w.n * float(w.p) ** 2


def t_w_folded(w: RidgeWorkload) -> float:
    """Gram-statistics cost with single-pass fold statistics — ``np²``.

    All per-fold partials ``{G_f, C_f}`` are accumulated in one pass over
    the rows (``repro.core.foldstats``); every training split derives by
    the exact downdate ``G_total − G_f`` and the refit statistics are the
    fold sums themselves, so the ``np²`` term is paid exactly once
    (k-independent) instead of ``t_w_per_fold``'s ``k·np²``.
    """
    return float(w.n) * float(w.p) ** 2


def t_w_folded_dual(w: RidgeWorkload) -> float:
    """Dual mirror of ``t_w_folded``: one n×n kernel accumulation (``n²p``).

    ``K = XXᵀ`` is built once and every CV split slices its training block
    ``K[tr, tr]`` from it, so the accumulation cost is k-independent just
    like the primal fold statistics.
    """
    return float(w.n) ** 2 * w.p


def fold_redundancy_factor(w: RidgeWorkload) -> float:
    """How much Gram work per-fold CV repeats vs the single-pass path (= k)."""
    return t_w_per_fold(w) / t_w_folded(w)


def t_m_dual(w: RidgeWorkload) -> float:
    """T_M in the dual/kernel form: factorise K = XXᵀ (n×n) — O(n²pr + nr).

    The dual mirror of ``t_m``: the paper's whole-brain-MOR workload
    (n=1,000 ≪ p=16,384) is exactly the regime where this term is the cheap
    one, which is what ``encoding.dispatch`` exploits.
    """
    return float(w.n) ** 2 * w.p * w.r + float(w.n) * w.r


def t_bmor_sharded(w: RidgeWorkload, c_data: int, c_target: int) -> float:
    """B-MOR with rows additionally sharded over ``c_data`` shards.

    Extends Eq. 7: the target-batch axis divides T_W (c⁻¹·T_W) while the
    row-shard axis divides the Gram accumulation inside T_M (the psum'd
    ``XᵀX`` is a sum over row shards — DESIGN §2).  The single-pass fold
    statistics (``t_w_folded``) ride the same row-shard axis, keeping this
    cost comparable with the ridge branch's ``t_w_folded + T_M`` (both
    paths pay the np² accumulation exactly once).
    """
    return t_w(w) / c_target + (t_m(w) + t_w_folded(w)) / c_data


def t_ridge_single(w: RidgeWorkload) -> float:
    """Single-worker mutualised RidgeCV: T_M + T_W (paper §3.1)."""
    return t_m(w) + t_w(w)


def t_mor(w: RidgeWorkload, c: int) -> float:
    """MOR: factorisation recomputed per *target* — Eq. 6: c⁻¹(T_W + t·T_M)."""
    return (t_w(w) + w.t * t_m(w)) / c


def t_bmor(w: RidgeWorkload, c: int) -> float:
    """B-MOR: one factorisation per *batch* — Eq. 7: c⁻¹·T_W + T_M."""
    return t_w(w) / c + t_m(w)


def predicted_speedup_bmor(w: RidgeWorkload, c: int) -> float:
    """DSU prediction: single-worker mutualised ridge over B-MOR on c workers."""
    return t_ridge_single(w) / t_bmor(w, c)


def mor_overhead_factor(w: RidgeWorkload, c: int) -> float:
    """How much slower MOR is than B-MOR at equal parallelism (→ (t-c)/c·T_M)."""
    return t_mor(w, c) / t_bmor(w, c)


# ---------------------------------------------------------------------------
# Paper workloads (Table 1), for benchmark parameterisation.
# ---------------------------------------------------------------------------
PAPER_P = 16384  # 4 TRs × 4096 VGG16 FC2 features (§2.2.2)

PAPER_WORKLOADS = {
    "parcels":          RidgeWorkload(n=69_202, p=PAPER_P, t=444),
    "roi":              RidgeWorkload(n=69_202, p=PAPER_P, t=6_728),
    "whole_brain":      RidgeWorkload(n=69_202, p=PAPER_P, t=264_805),
    "whole_brain_mor":  RidgeWorkload(n=1_000,  p=PAPER_P, t=2_000),
    "whole_brain_bmor": RidgeWorkload(n=10_000, p=PAPER_P, t=264_805),
}
