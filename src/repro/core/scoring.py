"""Brain-encoding performance metrics (paper §2.2.4, §4.1-4.2).

The paper's reported metric is the Pearson correlation coefficient between
the measured and predicted fMRI time series on the held-out test set, per
spatial target, plus a null-permutation control (§4.2) where features and
brain data are misaligned by random shuffling.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pearson_r(Y_true: jax.Array, Y_pred: jax.Array) -> jax.Array:
    """Per-target Pearson r between time series.  (n, t) → (t,)."""
    yt = Y_true - jnp.mean(Y_true, axis=0, keepdims=True)
    yp = Y_pred - jnp.mean(Y_pred, axis=0, keepdims=True)
    num = jnp.sum(yt * yp, axis=0)
    den = jnp.sqrt(jnp.sum(yt**2, axis=0) * jnp.sum(yp**2, axis=0))
    return num / jnp.maximum(den, 1e-12)


def r2_score(Y_true: jax.Array, Y_pred: jax.Array) -> jax.Array:
    """Per-target coefficient of determination.  (n, t) → (t,)."""
    ss_res = jnp.sum((Y_true - Y_pred) ** 2, axis=0)
    mu = jnp.mean(Y_true, axis=0, keepdims=True)
    ss_tot = jnp.sum((Y_true - mu) ** 2, axis=0)
    return 1.0 - ss_res / jnp.maximum(ss_tot, 1e-12)


def null_permutation_scores(key: jax.Array, X: jax.Array, Y: jax.Array,
                            W: jax.Array, n_perms: int = 10) -> jax.Array:
    """Null distribution of encoding scores with shuffled feature rows.

    Reproduces the paper's §4.2 control: when the correspondence between
    stimulus features and fMRI samples is destroyed by a random permutation,
    encoding accuracy collapses (r < ~0.05 vs up to ~0.5 aligned).
    Returns (n_perms, t) Pearson r under the null.
    """
    def one(k):
        perm = jax.random.permutation(k, X.shape[0])
        return pearson_r(Y, jnp.matmul(X[perm], W,
                                       preferred_element_type=jnp.float32))
    return jax.vmap(one)(jax.random.split(key, n_perms))


def train_test_split_indices(key: jax.Array, n: int, test_frac: float = 0.1
                             ) -> tuple[jax.Array, jax.Array]:
    """Paper's 90/10 random split (§2.2.4), returned as index arrays."""
    perm = jax.random.permutation(key, n)
    n_test = max(1, int(round(n * test_frac)))
    return perm[n_test:], perm[:n_test]
