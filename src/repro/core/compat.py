"""JAX version compatibility shims.

The repo targets both the installed JAX (0.4.x) and newer releases whose
public API moved:

* ``shard_map`` — ``jax.shard_map`` (new) vs
  ``jax.experimental.shard_map.shard_map`` (0.4.x).  The replication-check
  kwarg was also renamed ``check_rep`` → ``check_vma``.
* ``make_mesh`` — the ``axis_types=`` kwarg (and ``jax.sharding.AxisType``)
  only exist on newer JAX; on 0.4.x every mesh axis already behaves like the
  explicit-auto default, so the kwarg is dropped.

Everything in the repo goes through these wrappers instead of importing the
moved symbols directly — a bare ``from jax import shard_map`` is what broke
test collection on the seed.
"""
from __future__ import annotations

from typing import Any, Callable, Sequence

import jax

try:  # JAX >= 0.6 style
    from jax import shard_map as _shard_map
    _SHARD_MAP_NEW = True
except ImportError:  # 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_MAP_NEW = False

# jax.sharding.AxisType is absent on 0.4.x; expose None so callers can gate.
AxisType = getattr(jax.sharding, "AxisType", None)


def auto_axis_types(n: int):
    """``axis_types`` tuple for n Auto axes, or None where unsupported."""
    return None if AxisType is None else (AxisType.Auto,) * n


def shard_map(f: Callable, *, mesh, in_specs, out_specs,
              check_vma: bool = False) -> Callable:
    """``shard_map`` across JAX versions (``check_vma`` ≡ old ``check_rep``)."""
    if _SHARD_MAP_NEW:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=check_vma)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              *, devices: Sequence[Any] | None = None,
              axis_types: Sequence[Any] | None = None):
    """``jax.make_mesh`` that tolerates ``axis_types`` on old JAX."""
    kwargs: dict[str, Any] = {}
    if devices is not None:
        kwargs["devices"] = devices
    if axis_types is not None and AxisType is not None:
        try:
            return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                                 axis_types=tuple(axis_types), **kwargs)
        except TypeError:  # make_mesh predates the kwarg
            pass
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)
