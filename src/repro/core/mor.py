"""MOR — MultiOutput ridge baseline (paper §2.3.4, Fig. 8).

Faithful reproduction of scikit-learn's ``MultiOutputRegressor`` semantics:
one *independent* RidgeCV per target, so the feature-side factorisation is
recomputed for every target.  This is the baseline whose overhead
(``t · T_M`` in paper Eq. 6) the paper demonstrates to be impractical — it is
implemented here deliberately *without* mutualisation so the benchmark
harness can reproduce Fig. 8's result (MOR across many workers slower than
one mutualised worker).

The per-target loop is a ``lax.map`` so the factorisation lives inside the
loop body and is genuinely re-executed per target, matching the Dask task
graph of the paper (one task per target).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import ridge


@partial(jax.jit, static_argnames=("cfg",))
def mor_fit(X: jax.Array, Y: jax.Array,
            cfg: ridge.RidgeCVConfig = ridge.RidgeCVConfig()) -> jax.Array:
    """Fit t independent single-target RidgeCVs.  Returns weights (p, t).

    λ is selected *per target* (scikit-learn MultiOutput semantics), unlike
    the shared-λ mutualised path.

    NOTE (measured finding, EXPERIMENTS §Paper-validation): inside a single
    XLA program the per-target factorisation in this ``lax.map`` body is a
    loop invariant and XLA hoists it — i.e. JAX *structurally removes* the
    ``t·T_M`` redundancy the paper measures with Dask, where each target fit
    is an isolated task.  Use ``mor_fit_taskwise`` to reproduce the paper's
    MOR cost semantics (one dispatch per target, recompute guaranteed).
    """
    def fit_one(y: jax.Array) -> jax.Array:
        res = ridge.ridge_cv(X, y[:, None], cfg)
        return res.weights[:, 0]

    W_t = jax.lax.map(fit_one, Y.T)            # (t, p)
    return W_t.T


def mor_fit_taskwise(X: jax.Array, Y: jax.Array,
                     cfg: ridge.RidgeCVConfig = ridge.RidgeCVConfig()
                     ) -> jax.Array:
    """Faithful scikit-learn/Dask MOR: one isolated fit per target.

    Each target is a separate XLA execution (the Dask-task analog), so the
    factorisation is genuinely recomputed t times — the ``t·T_M`` overhead
    of paper Eq. 6 is physically paid, not optimised away.
    """
    fit_one = jax.jit(lambda X, y: ridge.ridge_cv(X, y[:, None], cfg)
                      .weights[:, 0])
    cols = [fit_one(X, Y[:, i]) for i in range(Y.shape[1])]
    return jnp.stack(cols, axis=1)


def mor_fit_distributed(X: jax.Array, Y: jax.Array, mesh: jax.sharding.Mesh,
                        axis: str = "model",
                        cfg: ridge.RidgeCVConfig = ridge.RidgeCVConfig()
                        ) -> jax.Array:
    """MOR parallelised over mesh shards (the Dask-distributed analog).

    Targets are split over ``axis`` shards; each shard still loops one
    RidgeCV per target.  Critical-path cost: c⁻¹·(T_W + t·T_M), paper Eq. 6.
    """
    from jax.sharding import PartitionSpec as P

    from repro.core.compat import shard_map

    def shard_fn(X_local: jax.Array, Y_local: jax.Array) -> jax.Array:
        return mor_fit(X_local, Y_local, cfg)

    fn = shard_map(shard_fn, mesh=mesh,
                   in_specs=(P(), P(None, axis)),
                   out_specs=P(None, axis), check_vma=False)
    return jax.jit(fn)(X, Y)
