"""Banded ridge regression — feature-space selection (la Tour et al. 2022,
the paper's ref [13], from which scikit-learn's mutualised solver comes).

Brain-encoding often concatenates several feature *spaces* (e.g. multiple
VGG16 layers, or several backbone depths); banded ridge gives each band b
its own regularisation λ_b, which performs feature-space selection:

    W* = argmin ‖Y − Σ_b X_b W_b‖² + Σ_b λ_b ‖W_b‖².

Implementation uses the Tikhonov substitution: with per-feature penalties
``λ_f`` (constant within a band), ``X̃ = X·diag(1/√λ_f)`` reduces the problem
to standard ridge at λ=1: ``W = diag(1/√λ_f)·W̃``.  Each candidate band
weighting therefore costs one mutualised factorisation — the same T_M
economics as the paper's RidgeCV, iterated over sampled band candidates
(himalaya-style random search instead of an exponential grid).

Distribution composes with B-MOR unchanged: bands live in the feature
dimension, targets stay sharded over the mesh.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import ridge
from repro.core.ridge import RidgeCVConfig


@dataclasses.dataclass(frozen=True)
class BandedConfig:
    bands: tuple[int, ...]                 # feature count per band (sum = p)
    n_candidates: int = 16                 # sampled band-weight vectors
    log_lambda_range: tuple[float, float] = (-2.0, 4.0)
    n_folds: int = 3
    jitter: float = 1e-6


def _feature_lambdas(band_lams: jax.Array, bands: Sequence[int]) -> jax.Array:
    """Expand per-band λ to per-feature λ.  band_lams: (B,) → (p,)."""
    return jnp.concatenate([
        jnp.full((n,), band_lams[i]) for i, n in enumerate(bands)])


def solve_banded(X: jax.Array, Y: jax.Array, band_lams: jax.Array,
                 bands: Sequence[int], jitter: float = 1e-6) -> jax.Array:
    """Closed-form banded ridge for one candidate.  → W (p, t)."""
    lam_f = _feature_lambdas(band_lams, bands)
    scale = 1.0 / jnp.sqrt(lam_f)
    Xs = X * scale[None, :]
    G = jnp.matmul(Xs.T, Xs, preferred_element_type=jnp.float32)
    G = G + jitter * jnp.eye(X.shape[1], dtype=jnp.float32)
    evals, Q = jnp.linalg.eigh(G)
    XtY = jnp.matmul(Xs.T, Y, preferred_element_type=jnp.float32)
    z = jnp.matmul(Q.T, XtY, preferred_element_type=jnp.float32)
    z = z / (evals + 1.0)[:, None]
    W_tilde = jnp.matmul(Q, z, preferred_element_type=jnp.float32)
    return W_tilde * scale[:, None]


@dataclasses.dataclass
class BandedResult:
    weights: jax.Array          # (p, t)
    band_lambdas: jax.Array     # (B,) winning candidate
    cv_scores: jax.Array        # (n_candidates,)
    candidates: jax.Array       # (n_candidates, B)


def banded_ridge_cv(key: jax.Array, X: jax.Array, Y: jax.Array,
                    cfg: BandedConfig) -> BandedResult:
    """Random-search banded RidgeCV (one factorisation per candidate/fold)."""
    n, p = X.shape
    assert sum(cfg.bands) == p, (cfg.bands, p)
    nb = len(cfg.bands)
    lo, hi = cfg.log_lambda_range
    cands = 10.0 ** jax.random.uniform(key, (cfg.n_candidates, nb),
                                       minval=lo, maxval=hi)
    bounds = ridge._fold_bounds(n, cfg.n_folds)

    def score_candidate(band_lams):
        scores = []
        for (lo_i, hi_i) in bounds:
            X_val, Y_val = X[lo_i:hi_i], Y[lo_i:hi_i]
            X_tr = jnp.concatenate([X[:lo_i], X[hi_i:]], axis=0)
            Y_tr = jnp.concatenate([Y[:lo_i], Y[hi_i:]], axis=0)
            W = solve_banded(X_tr, Y_tr, band_lams, cfg.bands, cfg.jitter)
            pred = jnp.matmul(X_val, W, preferred_element_type=jnp.float32)
            ss_res = jnp.sum((Y_val - pred) ** 2)
            mu = jnp.mean(Y_val, axis=0, keepdims=True)
            ss_tot = jnp.sum((Y_val - mu) ** 2)
            scores.append(1.0 - ss_res / jnp.maximum(ss_tot, 1e-12))
        return jnp.mean(jnp.stack(scores))

    cv = jax.lax.map(score_candidate, cands)
    best = jnp.argmax(cv)
    W = solve_banded(X, Y, cands[best], cfg.bands, cfg.jitter)
    return BandedResult(weights=W, band_lambdas=cands[best], cv_scores=cv,
                        candidates=cands)
