"""B-MOR — Batch Multi-Output Ridge, the paper's contribution (§2.3.5, Alg. 1).

The paper partitions the target matrix ``Y`` into ``c`` column batches, one
per Dask compute node; each node runs the SVD-mutualised RidgeCV on its batch.
On a TPU mesh the "compute node" axis is a mesh axis: ``Y`` is sharded over
``target_axis`` (c = axis size), and each shard owns one batch end-to-end —
cross-validated λ selection *per batch* (Algorithm 1 line 13) and final
weights for its targets.  Complexity: ``T_B-MOR = c⁻¹·T_W + T_M`` (Eq. 7).

TPU adaptation (DESIGN §2): rows of ``X``/``Y`` (time samples) are
additionally sharded over ``data_axis``, and the factorisation works on the
Gram matrix ``G = XᵀX`` — a *sum over row shards* — so distribution costs one
``psum`` of p² (+ p·t_local) elements instead of a distributed SVD.  The
eigenvalues of G are the squared singular values of X, so the λ sweep is the
same diagonal rescale as paper Eq. 5.

Cross-validation over row-sharded data uses the Gram downdate identity:
``G_train(fold) = G_total − G_fold`` and ``XᵀY_train = XᵀY_total − XᵀY_fold``,
with fold membership computed from global row indices.  Each fold still pays
its own eigendecomposition — the per-split ``svd(X_train)`` of Algorithm 1.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.compat import shard_map

from repro.core import ridge
from repro.core.ridge import RidgeCVConfig


@dataclasses.dataclass
class BMORResult:
    weights: jax.Array       # (p, t) — sharded over the target axis
    best_lambda: jax.Array   # (n_target_shards,) — per-batch λ (Alg. 1 l.13)
    cv_scores: jax.Array     # (n_target_shards, r)


def _global_row_ids(n_local: int, axis: str | tuple[str, ...]) -> jax.Array:
    """Global row indices of this shard's rows (row-major shard order)."""
    idx = jax.lax.axis_index(axis)
    return idx * n_local + jnp.arange(n_local)


def _fold_of_rows(row_ids: jax.Array, n_total: int, n_folds: int) -> jax.Array:
    """Contiguous fold id of each global row (same split as ridge._fold_bounds)."""
    base, rem = divmod(n_total, n_folds)
    # Rows [0, (base+1)*rem) live in folds of size base+1; the rest size base.
    big = (base + 1) * rem
    in_big = row_ids < big
    fold_big = row_ids // jnp.maximum(base + 1, 1)
    fold_small = rem + (row_ids - big) // jnp.maximum(base, 1)
    return jnp.where(in_big, fold_big, fold_small).astype(jnp.int32)


def _masked_gram(X: jax.Array, Y: jax.Array, mask: jax.Array
                 ) -> tuple[jax.Array, jax.Array]:
    Xm = X * mask[:, None]
    G = jnp.matmul(Xm.T, Xm, preferred_element_type=jnp.float32)
    XtY = jnp.matmul(Xm.T, Y * mask[:, None],
                     preferred_element_type=jnp.float32)
    return G, XtY


def bmor_fit(X: jax.Array, Y: jax.Array, mesh: Mesh,
             data_axis: str | tuple[str, ...] = "data",
             target_axis: str = "model",
             cfg: RidgeCVConfig = RidgeCVConfig()) -> BMORResult:
    """Distributed B-MOR fit.

    ``X``: (n, p) rows sharded over ``data_axis``; ``Y``: (n, t) rows sharded
    over ``data_axis``, columns over ``target_axis``.
    """
    n_total = X.shape[0]
    data_spec = data_axis if isinstance(data_axis, tuple) else (data_axis,)

    def shard_fn(X_l: jax.Array, Y_l: jax.Array):
        n_local, p = X_l.shape
        lams = jnp.asarray(cfg.lambdas, dtype=jnp.float32)          # (r,)
        rows = _global_row_ids(n_local, data_spec if len(data_spec) > 1
                               else data_spec[0])
        folds = _fold_of_rows(rows, n_total, cfg.n_folds)

        # Total Gram statistics: one psum over the row shards (DESIGN §2).
        G_tot, XtY_tot = _masked_gram(X_l, Y_l, jnp.ones((n_local,), X_l.dtype))
        G_tot = jax.lax.psum(G_tot, data_spec)
        XtY_tot = jax.lax.psum(XtY_tot, data_spec)
        eye = cfg.jitter * jnp.eye(p, dtype=jnp.float32)

        def fold_scores(f: int) -> jax.Array:
            val = (folds == f).astype(X_l.dtype)                    # (n_local,)
            G_f, XtY_f = _masked_gram(X_l, Y_l, val)
            G_f = jax.lax.psum(G_f, data_spec)
            XtY_f = jax.lax.psum(XtY_f, data_spec)
            # Gram downdate: training statistics for this split.
            evals, Q = jnp.linalg.eigh(G_tot - G_f + eye)           # per-split
            A = jnp.matmul(Q.T, XtY_tot - XtY_f,
                           preferred_element_type=jnp.float32)      # (p, t_l)
            Bv = jnp.matmul(X_l * val[:, None], Q,
                            preferred_element_type=jnp.float32)     # (n_l, p)
            # Per-λ validation predictions: Bv · diag(1/(Λ+λ)) · A.
            preds = jnp.einsum("np,rp,pt->rnt", Bv,
                               1.0 / (evals[None, :] + lams[:, None]), A,
                               preferred_element_type=jnp.float32)
            Yv = Y_l * val[:, None]
            ss_res = jax.lax.psum(
                jnp.sum((Yv[None] - preds * val[None, :, None]) ** 2,
                        axis=(1, 2)), data_spec)                    # (r,)
            n_val = jax.lax.psum(jnp.sum(val), data_spec)
            mu = jax.lax.psum(jnp.sum(Yv, axis=0), data_spec) / n_val
            ss_tot = jax.lax.psum(
                jnp.sum(((Y_l - mu[None, :]) * val[:, None]) ** 2), data_spec)
            return 1.0 - ss_res / jnp.maximum(ss_tot, 1e-12)        # (r,)

        scores = jnp.stack([fold_scores(f) for f in range(cfg.n_folds)])
        cv_scores = jnp.mean(scores, axis=0)                        # (r,)
        best = jnp.argmax(cv_scores)

        # Final refit on all rows with this batch's λ (Alg. 1 line 14).
        evals, Q = jnp.linalg.eigh(G_tot + eye)
        z = jnp.matmul(Q.T, XtY_tot, preferred_element_type=jnp.float32)
        z = z / (evals + lams[best])[:, None]
        W_l = jnp.matmul(Q, z, preferred_element_type=jnp.float32)  # (p, t_l)
        return W_l, lams[best][None], cv_scores[None, :]

    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(data_spec, None), P(data_spec, target_axis)),
        out_specs=(P(None, target_axis), P(target_axis), P(target_axis, None)),
        check_vma=False)
    # jit the mapped computation: eager shard_map dispatches each primitive
    # per shard (orders of magnitude of overhead on host platforms).
    W, best_lam, cv = jax.jit(fn)(X, Y)
    return BMORResult(weights=W, best_lambda=best_lam, cv_scores=cv)


def bmor_fit_jit(X: jax.Array, Y: jax.Array, mesh: Mesh,
                 data_axis="data", target_axis="model",
                 cfg: RidgeCVConfig = RidgeCVConfig()) -> BMORResult:
    """jit'd entry point with explicit input shardings."""
    data_spec = data_axis if isinstance(data_axis, tuple) else (data_axis,)
    fn = jax.jit(partial(bmor_fit, mesh=mesh, data_axis=data_axis,
                         target_axis=target_axis, cfg=cfg),
                 in_shardings=(
                     jax.sharding.NamedSharding(mesh, P(data_spec, None)),
                     jax.sharding.NamedSharding(mesh, P(data_spec, target_axis))))
    return fn(X, Y)


def encode_features(X: jax.Array, Y: jax.Array, mesh: Mesh,
                    cfg: RidgeCVConfig = RidgeCVConfig(),
                    data_axis="data", target_axis="model"
                    ) -> tuple[BMORResult, jax.Array]:
    """Fit B-MOR and return (result, test predictions on the training X).

    Convenience wrapper used by the encoding launcher; callers wanting a held
    out evaluation should split first (``scoring.train_test_split_indices``).
    """
    res = bmor_fit(X, Y, mesh, data_axis=data_axis, target_axis=target_axis,
                   cfg=cfg)
    preds = ridge.predict(X, res.weights)
    return res, preds


def bmor_fit_dual(X: jax.Array, Y: jax.Array, mesh: Mesh,
                  target_axis: str = "model",
                  cfg: RidgeCVConfig = RidgeCVConfig()) -> BMORResult:
    """B-MOR for the dual regime n < p (paper's whole-brain-MOR workload:
    n=1,000 ≪ p=16,384).

    In the dual form the factorisation lives on the kernel ``K = XXᵀ``
    (n×n), which is SMALL precisely when the dual form is chosen — so rows
    are replicated (no psum needed) and only the paper's batch axis (the
    targets) is sharded.  Each target batch pays one eigendecomposition per
    CV split, exactly Algorithm 1 with ``svd(X_train)`` replaced by
    ``eigh(K_train)`` (identical spectrum).
    """
    n = X.shape[0]
    bounds = ridge._fold_bounds(n, cfg.n_folds)

    def shard_fn(X_l: jax.Array, Y_l: jax.Array):
        lams = jnp.asarray(cfg.lambdas, dtype=jnp.float32)
        K = jnp.matmul(X_l, X_l.T, preferred_element_type=jnp.float32)

        def fold_scores(lo: int, hi: int) -> jax.Array:
            tr = jnp.concatenate([jnp.arange(lo), jnp.arange(hi, n)])
            K_tr = K[tr][:, tr]
            evals, P_ = jnp.linalg.eigh(
                K_tr + cfg.jitter * jnp.eye(tr.shape[0]))
            Y_tr = Y_l[tr]
            z = jnp.matmul(P_.T, Y_tr, preferred_element_type=jnp.float32)
            # α(λ) = P (Γ+λ)⁻¹ Pᵀ Y_tr;  preds = K_val,tr · α.
            K_vt = K[lo:hi][:, tr]                       # (n_val, n_tr)
            B_ = jnp.matmul(K_vt, P_, preferred_element_type=jnp.float32)
            preds = jnp.einsum("vp,rp,pt->rvt", B_,
                               1.0 / (evals[None, :] + lams[:, None]), z,
                               preferred_element_type=jnp.float32)
            Y_val = Y_l[lo:hi]
            ss_res = jnp.sum((Y_val[None] - preds) ** 2, axis=(1, 2))
            mu = jnp.mean(Y_val, axis=0, keepdims=True)
            ss_tot = jnp.sum((Y_val - mu) ** 2)
            return 1.0 - ss_res / jnp.maximum(ss_tot, 1e-12)

        scores = jnp.stack([fold_scores(lo, hi) for lo, hi in bounds])
        cv_scores = jnp.mean(scores, axis=0)
        best = jnp.argmax(cv_scores)
        evals, P_ = jnp.linalg.eigh(K + cfg.jitter * jnp.eye(n))
        z = jnp.matmul(P_.T, Y_l, preferred_element_type=jnp.float32)
        alpha = jnp.matmul(P_, z / (evals + lams[best])[:, None],
                           preferred_element_type=jnp.float32)
        W_l = jnp.matmul(X_l.T, alpha, preferred_element_type=jnp.float32)
        return W_l, lams[best][None], cv_scores[None, :]

    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(), P(None, target_axis)),
        out_specs=(P(None, target_axis), P(target_axis), P(target_axis, None)),
        check_vma=False)
    W, best_lam, cv = jax.jit(fn)(X, Y)
    return BMORResult(weights=W, best_lambda=best_lam, cv_scores=cv)
