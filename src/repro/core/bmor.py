"""B-MOR — Batch Multi-Output Ridge, the paper's contribution (§2.3.5, Alg. 1).

The paper partitions the target matrix ``Y`` into ``c`` column batches, one
per Dask compute node; each node runs the SVD-mutualised RidgeCV on its batch.
On a TPU mesh the "compute node" axis is a mesh axis: ``Y`` is sharded over
``target_axis`` (c = axis size), and each shard owns one batch end-to-end —
cross-validated λ selection *per batch* (Algorithm 1 line 13) and final
weights for its targets.  Complexity: ``T_B-MOR = c⁻¹·T_W + T_M`` (Eq. 7).

TPU adaptation (DESIGN §2): rows of ``X``/``Y`` (time samples) are
additionally sharded over ``data_axis``, and the factorisation works on the
Gram matrix ``G = XᵀX`` — a *sum over row shards* — so distribution costs one
``psum`` instead of a distributed SVD.  The eigenvalues of G are the squared
singular values of X, so the λ sweep is the same diagonal rescale as paper
Eq. 5.

Cross-validation over row-sharded data runs on the shared fold-statistics
subsystem (``repro.core.foldstats``): each shard accumulates its per-fold
partials ``{G_f, C_f}`` once, ONE ``psum`` of the stacked ``(k, p, ·)``
tensors globalises them, and every training split derives by the Gram
downdate ``G_train(f) = G_total − G_f`` (exact algebra — see the
Algorithm-1 fidelity note in ``repro.core.ridge``).  Each fold still pays
its own eigendecomposition — the per-split ``svd(X_train)`` of Algorithm 1.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.compat import shard_map

from repro.core import foldstats
from repro.core.ridge import RidgeCVConfig


@dataclasses.dataclass
class BMORResult:
    weights: jax.Array       # (p, t) — sharded over the target axis
    best_lambda: jax.Array   # (n_target_shards,) — per-batch λ (Alg. 1 l.13)
    cv_scores: jax.Array     # (n_target_shards, r)


def _global_row_ids(n_local: int, axis: str | tuple[str, ...]) -> jax.Array:
    """Global row indices of this shard's rows (row-major shard order)."""
    idx = jax.lax.axis_index(axis)
    return idx * n_local + jnp.arange(n_local)


def bmor_fit(X: jax.Array, Y: jax.Array, mesh: Mesh,
             data_axis: str | tuple[str, ...] = "data",
             target_axis: str = "model",
             cfg: RidgeCVConfig = RidgeCVConfig()) -> BMORResult:
    """Distributed B-MOR fit.

    ``X``: (n, p) rows sharded over ``data_axis``; ``Y``: (n, t) rows sharded
    over ``data_axis``, columns over ``target_axis``.
    """
    n_total = X.shape[0]
    data_spec = data_axis if isinstance(data_axis, tuple) else (data_axis,)

    def shard_fn(X_l: jax.Array, Y_l: jax.Array):
        n_local, p = X_l.shape
        lams = jnp.asarray(cfg.lambdas, dtype=jnp.float32)          # (r,)
        rows = _global_row_ids(n_local, data_spec if len(data_spec) > 1
                               else data_spec[0])
        folds = foldstats.fold_of_rows(rows, n_total, cfg.n_folds)

        # Per-fold partial statistics, globalised in ONE psum each (the
        # stacked (k, p, ·) layout replaces the seed's k+1 separate psums);
        # totals and training splits then derive by summation/downdating.
        G_folds, C_folds = foldstats.partial_fold_stats(
            X_l, Y_l, folds, cfg.n_folds)
        G_folds = jax.lax.psum(G_folds, data_spec)                  # (k,p,p)
        C_folds = jax.lax.psum(C_folds, data_spec)                  # (k,p,t_l)
        G_tot = jnp.sum(G_folds, axis=0)
        C_tot = jnp.sum(C_folds, axis=0)
        eye = cfg.jitter * jnp.eye(p, dtype=jnp.float32)

        def fold_scores(f: int) -> jax.Array:
            val = (folds == f).astype(X_l.dtype)                    # (n_local,)
            # Gram downdate: training statistics for this split.
            evals, Q = jnp.linalg.eigh(G_tot - G_folds[f] + eye)    # per-split
            A = jnp.matmul(Q.T, C_tot - C_folds[f],
                           preferred_element_type=jnp.float32)      # (p, t_l)
            Bv = jnp.matmul(X_l * val[:, None], Q,
                            preferred_element_type=jnp.float32)     # (n_l, p)
            # Per-λ validation predictions: Bv · diag(1/(Λ+λ)) · A.
            preds = jnp.einsum("np,rp,pt->rnt", Bv,
                               1.0 / (evals[None, :] + lams[:, None]), A,
                               preferred_element_type=jnp.float32)
            Yv = Y_l * val[:, None]
            ss_res = jax.lax.psum(
                jnp.sum((Yv[None] - preds * val[None, :, None]) ** 2,
                        axis=(1, 2)), data_spec)                    # (r,)
            n_val = jax.lax.psum(jnp.sum(val), data_spec)
            mu = jax.lax.psum(jnp.sum(Yv, axis=0), data_spec) / n_val
            ss_tot = jax.lax.psum(
                jnp.sum(((Y_l - mu[None, :]) * val[:, None]) ** 2), data_spec)
            return 1.0 - ss_res / jnp.maximum(ss_tot, 1e-12)        # (r,)

        scores = jnp.stack([fold_scores(f) for f in range(cfg.n_folds)])
        cv_scores = jnp.mean(scores, axis=0)                        # (r,)
        best = jnp.argmax(cv_scores)

        # Final refit on all rows with this batch's λ (Alg. 1 line 14).
        evals, Q = jnp.linalg.eigh(G_tot + eye)
        z = jnp.matmul(Q.T, C_tot, preferred_element_type=jnp.float32)
        z = z / (evals + lams[best])[:, None]
        W_l = jnp.matmul(Q, z, preferred_element_type=jnp.float32)  # (p, t_l)
        return W_l, lams[best][None], cv_scores[None, :]

    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(data_spec, None), P(data_spec, target_axis)),
        out_specs=(P(None, target_axis), P(target_axis), P(target_axis, None)),
        check_vma=False)
    # jit the mapped computation: eager shard_map dispatches each primitive
    # per shard (orders of magnitude of overhead on host platforms).
    W, best_lam, cv = jax.jit(fn)(X, Y)
    return BMORResult(weights=W, best_lambda=best_lam, cv_scores=cv)


def bmor_fit_dual(X: jax.Array, Y: jax.Array, mesh: Mesh,
                  target_axis: str = "model",
                  cfg: RidgeCVConfig = RidgeCVConfig()) -> BMORResult:
    """B-MOR for the dual regime n < p (paper's whole-brain-MOR workload:
    n=1,000 ≪ p=16,384).

    In the dual form the factorisation lives on the kernel ``K = XXᵀ``
    (n×n), which is SMALL precisely when the dual form is chosen — so rows
    are replicated (no psum needed) and only the paper's batch axis (the
    targets) is sharded.  ``K`` is accumulated once per shard and every CV
    split slices its training block ``K[tr, tr]`` out of it (the dual
    mirror of the Gram downdate); each target batch still pays one
    eigendecomposition per split, exactly Algorithm 1 with
    ``svd(X_train)`` replaced by ``eigh(K_train)`` (identical spectrum).
    """
    n = X.shape[0]
    bounds = foldstats.fold_bounds(n, cfg.n_folds)

    def shard_fn(X_l: jax.Array, Y_l: jax.Array):
        lams = jnp.asarray(cfg.lambdas, dtype=jnp.float32)
        K = jnp.matmul(X_l, X_l.T, preferred_element_type=jnp.float32)

        def fold_scores(lo: int, hi: int) -> jax.Array:
            tr = jnp.concatenate([jnp.arange(lo), jnp.arange(hi, n)])
            K_tr = K[tr][:, tr]
            evals, P_ = jnp.linalg.eigh(
                K_tr + cfg.jitter * jnp.eye(tr.shape[0]))
            Y_tr = Y_l[tr]
            z = jnp.matmul(P_.T, Y_tr, preferred_element_type=jnp.float32)
            # α(λ) = P (Γ+λ)⁻¹ Pᵀ Y_tr;  preds = K_val,tr · α.
            K_vt = K[lo:hi][:, tr]                       # (n_val, n_tr)
            B_ = jnp.matmul(K_vt, P_, preferred_element_type=jnp.float32)
            preds = jnp.einsum("vp,rp,pt->rvt", B_,
                               1.0 / (evals[None, :] + lams[:, None]), z,
                               preferred_element_type=jnp.float32)
            Y_val = Y_l[lo:hi]
            ss_res = jnp.sum((Y_val[None] - preds) ** 2, axis=(1, 2))
            mu = jnp.mean(Y_val, axis=0, keepdims=True)
            ss_tot = jnp.sum((Y_val - mu) ** 2)
            return 1.0 - ss_res / jnp.maximum(ss_tot, 1e-12)

        scores = jnp.stack([fold_scores(lo, hi) for lo, hi in bounds])
        cv_scores = jnp.mean(scores, axis=0)
        best = jnp.argmax(cv_scores)
        evals, P_ = jnp.linalg.eigh(K + cfg.jitter * jnp.eye(n))
        z = jnp.matmul(P_.T, Y_l, preferred_element_type=jnp.float32)
        alpha = jnp.matmul(P_, z / (evals + lams[best])[:, None],
                           preferred_element_type=jnp.float32)
        W_l = jnp.matmul(X_l.T, alpha, preferred_element_type=jnp.float32)
        return W_l, lams[best][None], cv_scores[None, :]

    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(), P(None, target_axis)),
        out_specs=(P(None, target_axis), P(target_axis), P(target_axis, None)),
        check_vma=False)
    W, best_lam, cv = jax.jit(fn)(X, Y)
    return BMORResult(weights=W, best_lambda=best_lam, cv_scores=cv)
