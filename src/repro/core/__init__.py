"""Core library: the paper's contribution — scalable multi-target ridge.

Public API:
  ridge.RidgeCVConfig / ridge.ridge_cv   — mutualised single-shard RidgeCV
  mor.mor_fit / mor.mor_fit_distributed  — MultiOutput baseline (paper Fig. 8)
  bmor.bmor_fit                          — Batch Multi-Output ridge (paper Alg. 1)
  scoring.pearson_r                      — encoding performance metric
  complexity                             — analytic cost model (paper §3)
"""
from repro.core import bmor, complexity, mor, ridge, scoring  # noqa: F401
from repro.core.bmor import BMORResult, bmor_fit  # noqa: F401
from repro.core.ridge import (  # noqa: F401
    PAPER_LAMBDA_GRID, RidgeCVConfig, RidgeCVResult, ridge_cv,
)
