"""Low-level solver layer: the paper's scalable multi-target ridge.

This is the *documented low-level layer*.  New code should go through the
estimator facade in ``repro.encoding`` (``BrainEncoder``), which resolves
the solver and mesh layout from the problem shape and owns all sharding
boilerplate.  The modules here stay importable for direct use, benchmarks,
and tests:

  ridge.RidgeCVConfig / ridge.ridge_cv   — mutualised single-shard RidgeCV
  foldstats.compute / FoldStatsAccumulator — single-pass fold statistics
                                           (downdating CV, out-of-core)
  mor.mor_fit / mor.mor_fit_distributed  — MultiOutput baseline (paper Fig. 8)
  bmor.bmor_fit / bmor.bmor_fit_dual     — Batch Multi-Output ridge (Alg. 1)
  banded.banded_ridge_cv                 — per-feature-space λ (ref [13])
  scoring.pearson_r                      — encoding performance metric
  complexity                             — analytic cost model (paper §3)
  compat.shard_map / compat.make_mesh    — JAX version shims
"""
from repro.core import (  # noqa: F401
    banded, bmor, compat, complexity, foldstats, mor, ridge, scoring,
)
from repro.core.foldstats import FoldStats, FoldStatsAccumulator  # noqa: F401
from repro.core.banded import BandedConfig, BandedResult  # noqa: F401
from repro.core.bmor import BMORResult, bmor_fit  # noqa: F401
from repro.core.ridge import (  # noqa: F401
    PAPER_LAMBDA_GRID, RidgeCVConfig, RidgeCVResult, ridge_cv,
)
