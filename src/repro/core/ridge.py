"""SVD/eigh-mutualised multi-target RidgeCV (paper §2.3.1, §3).

This module is the single-shard building block of the paper's pipeline: the
scikit-learn-style ridge regression whose factorisation is computed *once*
and reused across all targets and all candidate regularisation strengths.

Two algebraically equivalent factorisation paths are provided:

* ``eigh`` (primal, used when ``n >= p``): eigendecompose the Gram matrix
  ``G = XᵀX = Q Λ Qᵀ``.  Then ``M(λ) Y = Q (Λ+λI)⁻¹ Qᵀ (XᵀY)``.  The
  eigenvalues of ``G`` are the squared singular values of ``X``, so the λ
  sweep is the same diagonal rescale as scikit-learn's SVD path (Eq. 5 of the
  paper) — but ``G`` and ``XᵀY`` are *sums over rows* of ``X``/``Y``, which is
  what makes the distributed (B-MOR) version a single ``psum`` (see
  ``repro.core.bmor``).
* ``dual`` (kernel, used when ``n < p``): eigendecompose ``K = XXᵀ = P Γ Pᵀ``;
  dual coefficients ``α(λ) = P (Γ+λI)⁻¹ Pᵀ Y`` and ``W = Xᵀ α``.

Both keep the per-λ work diagonal: ``O(p)`` (or ``O(n)``) scaling per λ, as
in the paper's complexity analysis ``T_M = O(p² n r + p r)``.

Cross-validation (``ridge_cv``) runs on single-pass fold statistics
(``repro.core.foldstats``): per-fold partials ``{G_f, C_f}`` are accumulated
once and every training split derives by the Gram downdate
``G_train(f) = G_total − G_f``.  **Algorithm-1 fidelity note:** downdating is
algebraically *exact*, not an approximation — ``XᵀX`` is a sum over rows, so
subtracting a fold's partial sum reproduces ``X_trᵀX_tr`` identically (up to
f32 rounding in the accumulation order); every split still pays its own
``eigh``, exactly the per-split ``svd(X_train)`` of paper Algorithm 1.  The
dual mirror slices per-fold kernel blocks ``K[tr, tr]`` out of one ``XXᵀ``.
The seed per-fold re-accumulation is kept as ``ridge_cv_reference`` for
parity tests and the ``benchmarks/foldstats_bench.py`` trajectory.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Literal, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import foldstats

# The paper's λ grid (§2.2.4).
PAPER_LAMBDA_GRID: tuple[float, ...] = (
    0.1, 1.0, 100.0, 200.0, 300.0, 400.0, 600.0, 800.0, 900.0, 1000.0, 1200.0
)


@dataclasses.dataclass(frozen=True)
class RidgeCVConfig:
    """Configuration of the multi-target cross-validated ridge solve."""

    lambdas: tuple[float, ...] = PAPER_LAMBDA_GRID
    n_folds: int = 5
    method: Literal["auto", "eigh", "dual"] = "auto"
    # Small diagonal jitter added to the Gram matrix before eigh for numerical
    # stability in float32 (the paper runs float64 CPU BLAS; see DESIGN §2).
    jitter: float = 1e-6
    # Score used to select λ across folds: Pearson correlation ("r") matches
    # the paper's reported metric; "r2" is the classical ridge CV score.
    scoring: Literal["r", "r2"] = "r2"
    # Route the Gram/cross-covariance accumulations (fold statistics, dual
    # kernel, Xᵀα) through the Pallas TPU kernels (repro.kernels).  Off by
    # default: on CPU the kernels run in interpret mode (correct but slow);
    # on TPU this is the "better BLAS" lever of paper §4.3.
    use_pallas: bool = False

    def resolve_method(self, n: int, p: int) -> str:
        if self.method != "auto":
            return self.method
        return "eigh" if n >= p else "dual"


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RidgeFactors:
    """Reusable factorisation of the feature matrix.

    ``basis`` is ``Q`` (p×p, primal) or ``P`` (n×n, dual); ``evals`` are the
    eigenvalues of the corresponding Gram/kernel matrix, i.e. the squared
    singular values of ``X``.  ``M(λ)`` never needs to be materialised: the λ
    sweep only rescales coordinates in the eigenbasis.
    """

    basis: jax.Array        # (p,p) primal | (n,n) dual
    evals: jax.Array        # (p,) | (n,)
    primal: bool

    def tree_flatten(self):
        return (self.basis, self.evals), self.primal

    @classmethod
    def tree_unflatten(cls, aux, children):
        basis, evals = children
        return cls(basis=basis, evals=evals, primal=aux)


def gram(X: jax.Array) -> jax.Array:
    """``XᵀX`` with f32 accumulation (MXU-friendly)."""
    return jnp.matmul(X.T, X, preferred_element_type=jnp.float32)


def gram_xty(X: jax.Array, Y: jax.Array, *,
             use_pallas: bool = False) -> jax.Array:
    """``XᵀY`` with f32 accumulation (Pallas-routable)."""
    if use_pallas:
        from repro.kernels import ops
        return ops.xty(X, Y)
    return jnp.matmul(X.T, Y, preferred_element_type=jnp.float32)


def xxt(X: jax.Array, *, use_pallas: bool = False) -> jax.Array:
    """``XXᵀ`` (the dual-path kernel matrix) with f32 accumulation.

    The Pallas route reuses the tiled cross-Gram kernel on ``Xᵀ``:
    ``(Xᵀ)ᵀ(Xᵀ) = XXᵀ``.
    """
    if use_pallas:
        from repro.kernels import ops
        Xt = X.T
        return ops.xty(Xt, Xt)
    return jnp.matmul(X, X.T, preferred_element_type=jnp.float32)


def factorize(X: jax.Array, cfg: RidgeCVConfig) -> RidgeFactors:
    """Factorise ``X`` once; reused for every λ and every target (Eq. 4-5)."""
    n, p = X.shape
    method = cfg.resolve_method(n, p)
    if method == "eigh":
        if cfg.use_pallas:
            from repro.kernels import ops
            gram_fn = ops.gram
        else:
            gram_fn = gram
        G = gram_fn(X) + cfg.jitter * jnp.eye(p, dtype=jnp.float32)
        evals, Q = jnp.linalg.eigh(G)
        return RidgeFactors(basis=Q, evals=evals, primal=True)
    K = xxt(X, use_pallas=cfg.use_pallas)
    K = K + cfg.jitter * jnp.eye(n, dtype=jnp.float32)
    evals, P = jnp.linalg.eigh(K)
    return RidgeFactors(basis=P, evals=evals, primal=False)


def solve(factors: RidgeFactors, XtY_or_Y: jax.Array, lam: jax.Array,
          X: jax.Array | None = None, use_pallas: bool = False) -> jax.Array:
    """Apply ``M(λ)`` to the targets through the shared factorisation.

    Primal: pass ``XᵀY`` (p×t) → returns ``W = Q (Λ+λ)⁻¹ Qᵀ XᵀY`` (p×t).
    Dual:   pass ``Y`` (n×t) and ``X`` → ``W = Xᵀ α`` with dual coefficients
    ``α = P (Γ+λ)⁻¹ Pᵀ Y`` (the ``Xᵀα`` matmul is Pallas-routable).
    """
    B = factors.basis
    z = jnp.matmul(B.T, XtY_or_Y, preferred_element_type=jnp.float32)
    z = z / (factors.evals + lam)[:, None]
    out = jnp.matmul(B, z, preferred_element_type=jnp.float32)
    if factors.primal:
        return out
    assert X is not None, "dual solve needs X to map dual coeffs to weights"
    return gram_xty(X, out, use_pallas=use_pallas)


def solve_lambda_grid(factors: RidgeFactors, XtY_or_Y: jax.Array,
                      lambdas: Sequence[float],
                      X: jax.Array | None = None,
                      use_pallas: bool = False) -> jax.Array:
    """All-λ solve, stacked on a leading axis: (r, p, t).

    The rotation into the eigenbasis (``Qᵀ XᵀY``) is shared across the grid —
    this is exactly the mutualisation of paper Eq. 5, where only the diagonal
    ``(S²+λI)⁻¹`` depends on λ.
    """
    if use_pallas and factors.primal:
        from repro.kernels import ops
        a = jnp.matmul(factors.basis.T, XtY_or_Y,
                       preferred_element_type=jnp.float32)
        return ops.solve_lambda_grid(factors.basis, factors.evals, a,
                                     jnp.asarray(lambdas, jnp.float32))
    B = factors.basis
    z = jnp.matmul(B.T, XtY_or_Y, preferred_element_type=jnp.float32)
    lams = jnp.asarray(lambdas, dtype=z.dtype)                    # (r,)
    zs = z[None, :, :] / (factors.evals[None, :, None] + lams[:, None, None])
    out = jnp.einsum("ij,rjt->rit", B, zs,
                     preferred_element_type=jnp.float32)
    if factors.primal:
        return out
    assert X is not None
    if use_pallas:
        from repro.kernels import ops
        return jnp.stack([ops.xty(X, out[r]) for r in range(len(lambdas))])
    return jnp.einsum("ni,rnt->rit", X, out,
                      preferred_element_type=jnp.float32)


# Contiguous k-fold boundaries — canonical implementation lives in
# ``foldstats``; kept here under the historical name for existing callers
# (``banded.py``, ``bmor.bmor_fit_dual``, tests).
_fold_bounds = foldstats.fold_bounds


def _score(Y_true: jax.Array, Y_pred: jax.Array, kind: str) -> jax.Array:
    """Mean score across targets (higher is better)."""
    if kind == "r2":
        ss_res = jnp.sum((Y_true - Y_pred) ** 2, axis=0)
        ss_tot = jnp.sum((Y_true - jnp.mean(Y_true, axis=0)) ** 2, axis=0) + 1e-12
        return jnp.mean(1.0 - ss_res / ss_tot)
    yt = Y_true - jnp.mean(Y_true, axis=0)
    yp = Y_pred - jnp.mean(Y_pred, axis=0)
    num = jnp.sum(yt * yp, axis=0)
    den = jnp.sqrt(jnp.sum(yt ** 2, axis=0) * jnp.sum(yp ** 2, axis=0)) + 1e-12
    return jnp.mean(num / den)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RidgeCVResult:
    weights: jax.Array       # (p, t)
    best_lambda: jax.Array   # scalar
    best_index: jax.Array    # scalar int
    cv_scores: jax.Array     # (r,) mean validation score per λ


def _lambda_grid(cfg: RidgeCVConfig) -> jax.Array:
    # λ grid in f32 regardless of X.dtype: the whole solve accumulates in f32
    # (preferred_element_type), so bf16/f16 inputs must sweep — and select —
    # the identical grid, not a low-precision rounding of it.
    return jnp.asarray(cfg.lambdas, dtype=jnp.float32)


def _r2_scores_trace(Bv: jax.Array, A: jax.Array, Y_val: jax.Array,
                     evals: jax.Array, lams: jax.Array) -> jax.Array:
    """Mean-over-targets R² per λ without materialising predictions.

    With validation predictions ``P(λ) = Bv · diag(1/(Λ+λ)) · A`` the CV
    score ``mean_j (1 − ‖Y_j − P_j‖²/ss_tot_j)`` expands, via the centred
    decomposition ``‖Y_j − P_j‖² = ss_tot_j − 2⟨Y_j−ȳ_j, P_j−P̄_j⟩ +
    ‖P_j−P̄_j‖² + v(P̄_j−ȳ_j)²``, into λ-independent contractions plus a
    per-λ quadratic form in the diagonal:

        Σ_j ss_res_j/ss_tot_j = t₀ − 2·Dᵀε + Dᵀ(G_c ∘ S)D + v·Σ_j(P̄_j−ȳ_j)²/ss_tot_j

    (D = 1/(Λ+λ), ε = Σ_j A∘(BcᵀY_c)/ss_tot, S = A diag(1/ss_tot) Aᵀ,
    G_c = BcᵀBc with Bc the row-centred ``Bv``) — algebraically identical
    to scoring the r materialised prediction tensors but
    ``O(vpt + p²t + rpt + rp²)`` instead of ``O(r·v·p·t)``: the λ sweep
    stays diagonal even through the scoring, extending the Eq. 5
    mutualisation to the CV loop itself.  Every sum is over CENTRED
    quantities (only the per-target scalar fold means meet at full
    magnitude), so the f32 arithmetic stays stable for un-standardized
    large-mean targets — the ``Σy² − mȳ²`` raw-moment expansion would
    cancel catastrophically there (see ``foldstats.FoldStats.ysq``).
    """
    v, t = Y_val.shape
    Y32 = Y_val.astype(jnp.float32)
    mu = jnp.mean(Y32, axis=0)
    Yc = Y32 - mu
    inv = 1.0 / (jnp.sum(Yc ** 2, axis=0) + 1e-12)                 # 1/ss_tot
    t0 = jnp.sum(jnp.sum(Yc ** 2, axis=0) * inv)
    ub = jnp.mean(Bv, axis=0)                                      # (p,)
    Bc = Bv - ub                                                   # centred
    Mc = jnp.matmul(Bc.T, Yc, preferred_element_type=jnp.float32) * inv[None]
    eps = jnp.sum(A * Mc, axis=1)                                  # (p,)
    S = jnp.matmul(A * inv[None], A.T, preferred_element_type=jnp.float32)
    Gc = jnp.matmul(Bc.T, Bc, preferred_element_type=jnp.float32)
    F = Gc * S
    D = 1.0 / (evals[None, :] + lams[:, None])                     # (r, p)
    cross = D @ eps
    quad = jnp.einsum("rp,pq,rq->r", D, F, D,
                      preferred_element_type=jnp.float32)
    # Fold-mean predictions per λ: P̄(λ) = ubᵀ·diag(D)·A (r, t).
    pbar = jnp.einsum("p,rp,pt->rt", ub, D, A,
                      preferred_element_type=jnp.float32)
    mean_term = v * jnp.sum((pbar - mu[None]) ** 2 * inv[None], axis=1)
    return 1.0 - (t0 - 2.0 * cross + quad + mean_term) / t


def _fold_scores(Bv: jax.Array, A: jax.Array, Y_val: jax.Array,
                 evals: jax.Array, lams: jax.Array,
                 scoring: str) -> jax.Array:
    """Per-λ validation scores of one split, from eigenbasis factors.

    ``"r2"`` uses the trace identity above; ``"r"`` (per-target Pearson,
    nonlinear in the per-target moments) materialises the per-λ prediction
    tensor and scores it exactly like the seed path.
    """
    if scoring == "r2":
        return _r2_scores_trace(Bv, A, Y_val, evals, lams)
    Bs = Bv[None] / (evals[None, None, :] + lams[:, None, None])   # (r, v, p)
    preds = jnp.matmul(Bs, A[None], preferred_element_type=jnp.float32)
    return jax.vmap(lambda Yp: _score(Y_val, Yp, scoring))(preds)


def _ridge_cv_primal(X: jax.Array, Y: jax.Array,
                     cfg: RidgeCVConfig) -> RidgeCVResult:
    """Primal CV on downdated fold statistics — one Gram pass total.

    Per split: ``eigh(G_total − G_f)`` (Algorithm 1's per-split
    factorisation), validation predictions straight from the eigenbasis
    (``X_val Q · (Λ+λ)⁻¹ · Qᵀ C_tr``) so no per-λ weight matrix is ever
    materialised during CV, and the refit reuses ``G_total``/``C_total`` —
    the fold partials already sum to the full-data statistics.
    """
    n, p = X.shape
    bounds = foldstats.fold_bounds(n, cfg.n_folds)
    stats = foldstats.compute(X, Y, cfg.n_folds, use_pallas=cfg.use_pallas)
    eye = cfg.jitter * jnp.eye(p, dtype=jnp.float32)
    lams = _lambda_grid(cfg)
    per_lambda_scores = []
    for f, (lo, hi) in enumerate(bounds):
        G_tr, C_tr = stats.train(f)                   # Gram downdate (exact)
        evals, Q = jnp.linalg.eigh(G_tr + eye)        # per-split eigh
        A = jnp.matmul(Q.T, C_tr, preferred_element_type=jnp.float32)
        Bv = jnp.matmul(X[lo:hi], Q, preferred_element_type=jnp.float32)
        per_lambda_scores.append(
            _fold_scores(Bv, A, Y[lo:hi], evals, lams, cfg.scoring))
    cv_scores = jnp.mean(jnp.stack(per_lambda_scores), axis=0)    # (r,)
    best = jnp.argmax(cv_scores)
    # Refit on the full data: the summed fold statistics ARE the full-data
    # Gram/cross-covariance — no second pass over the rows.
    evals, Q = jnp.linalg.eigh(stats.G_total + eye)
    factors = RidgeFactors(basis=Q, evals=evals, primal=True)
    W = solve(factors, stats.C_total, lams[best])
    return RidgeCVResult(weights=W, best_lambda=lams[best], best_index=best,
                         cv_scores=cv_scores)


def _ridge_cv_dual(X: jax.Array, Y: jax.Array,
                   cfg: RidgeCVConfig) -> RidgeCVResult:
    """Dual CV on per-fold kernel blocks of one ``XXᵀ``.

    ``K = XXᵀ`` is accumulated once; every split's training kernel is the
    static block ``K[tr, tr]`` and the validation predictions are
    ``K[val, tr] · α(λ)`` — algebraically identical to ``X_val W(λ)`` but
    without rebuilding any kernel or materialising per-λ weights.
    """
    n, p = X.shape
    bounds = foldstats.fold_bounds(n, cfg.n_folds)
    K = xxt(X, use_pallas=cfg.use_pallas)             # one n×n accumulation
    lams = _lambda_grid(cfg)
    per_lambda_scores = []
    for lo, hi in bounds:
        tr = np.concatenate([np.arange(lo), np.arange(hi, n)])
        K_tr = K[tr][:, tr]                           # static block slice
        evals, P_ = jnp.linalg.eigh(
            K_tr + cfg.jitter * jnp.eye(tr.size, dtype=jnp.float32))
        z = jnp.matmul(P_.T, Y[tr], preferred_element_type=jnp.float32)
        Bv = jnp.matmul(K[lo:hi][:, tr], P_,
                        preferred_element_type=jnp.float32)
        per_lambda_scores.append(
            _fold_scores(Bv, z, Y[lo:hi], evals, lams, cfg.scoring))
    cv_scores = jnp.mean(jnp.stack(per_lambda_scores), axis=0)    # (r,)
    best = jnp.argmax(cv_scores)
    evals, P_ = jnp.linalg.eigh(K + cfg.jitter * jnp.eye(n, dtype=jnp.float32))
    factors = RidgeFactors(basis=P_, evals=evals, primal=False)
    W = solve(factors, Y, lams[best], X=X, use_pallas=cfg.use_pallas)
    return RidgeCVResult(weights=W, best_lambda=lams[best], best_index=best,
                         cv_scores=cv_scores)


@partial(jax.jit, static_argnames=("cfg",))
def ridge_cv(X: jax.Array, Y: jax.Array, cfg: RidgeCVConfig = RidgeCVConfig()
             ) -> RidgeCVResult:
    """Cross-validated multi-target ridge — scikit-learn ``RidgeCV`` analog.

    Faithful to paper Algorithm 1 at batch granularity: every CV split gets
    its own factorisation of the training statistics (the ``svd(X_train)``
    line), the λ grid is swept diagonally, scores averaged over splits, a
    single λ selected for *all* targets (§2.2.4), and the final weights refit
    on the full training set.  Unlike the reference implementation the
    expensive row statistics are accumulated exactly once (see the module
    docstring's Algorithm-1 fidelity note: the downdate is exact algebra,
    not an approximation).
    """
    n, p = X.shape
    if cfg.resolve_method(n, p) == "eigh":
        return _ridge_cv_primal(X, Y, cfg)
    return _ridge_cv_dual(X, Y, cfg)


def ridge_cv_from_stats(stats: "foldstats.FoldStats",
                        cfg: RidgeCVConfig = RidgeCVConfig()
                        ) -> RidgeCVResult:
    """Fit the CV'd ridge from pre-accumulated fold statistics alone.

    The out-of-core entry point: ``stats`` may come from
    ``foldstats.compute_chunked`` over row batches that never coexist in
    device memory.  Validation scores are computed from sufficient
    statistics (``foldstats.validation_scores_from_stats``), so no
    validation rows are needed — primal/eigh only, since the dual kernel is
    an n×n object that defeats the point of streaming rows.
    """
    if cfg.method == "dual":
        raise ValueError("ridge_cv_from_stats is primal-only: the dual "
                         "kernel XXᵀ cannot be built from streamed row "
                         "statistics")
    from repro import obs

    p = stats.G.shape[1]
    per_lambda_scores = []
    # Tracing note: the eigh/solve spans force their outputs only when a
    # tracer is installed, so the recorded durations are compute, not
    # async dispatch — with tracing off nothing is synchronised here.
    # eye/λ-grid construction lives inside the span: their first-touch
    # dispatch cost belongs to the factorisation phase it feeds.
    with obs.span("fit.eigh", folds=stats.n_folds, p=p):
        eye = cfg.jitter * jnp.eye(p, dtype=jnp.float32)
        lams = _lambda_grid(cfg)
        for f in range(stats.n_folds):
            G_tr, C_tr = stats.train(f)
            evals, Q = jnp.linalg.eigh(G_tr + eye)
            per_lambda_scores.append(foldstats.validation_scores_from_stats(
                stats, f, Q, evals, C_tr, lams, cfg.scoring))
        cv_scores = jnp.mean(jnp.stack(per_lambda_scores), axis=0)
        best = jnp.argmax(cv_scores)
        if obs.current() is not None:
            jax.block_until_ready(cv_scores)
    with obs.span("fit.solve", p=p):
        evals, Q = jnp.linalg.eigh(stats.G_total + eye)
        factors = RidgeFactors(basis=Q, evals=evals, primal=True)
        W = solve(factors, stats.C_total, lams[best])
        if obs.current() is not None:
            jax.block_until_ready(W)
    return RidgeCVResult(weights=W, best_lambda=lams[best], best_index=best,
                         cv_scores=cv_scores)


@partial(jax.jit, static_argnames=("cfg",))
def ridge_cv_reference(X: jax.Array, Y: jax.Array,
                       cfg: RidgeCVConfig = RidgeCVConfig()) -> RidgeCVResult:
    """Seed implementation: per-fold re-accumulation (baseline, kept on
    purpose).

    For every split this concatenates the training rows and recomputes their
    Gram/kernel from scratch — ``(k−1)·np²`` of redundant ``T_W`` work that
    ``ridge_cv`` now derives by downdating.  Parity tests
    (``tests/test_foldstats.py``) and ``benchmarks/foldstats_bench.py``
    measure the new path against this one; do not use it elsewhere.
    """
    n, p = X.shape
    bounds = foldstats.fold_bounds(n, cfg.n_folds)
    per_lambda_scores = []
    for (lo, hi) in bounds:
        X_val, Y_val = X[lo:hi], Y[lo:hi]
        X_tr = jnp.concatenate([X[:lo], X[hi:]], axis=0)
        Y_tr = jnp.concatenate([Y[:lo], Y[hi:]], axis=0)
        factors = factorize(X_tr, cfg)
        rhs = gram_xty(X_tr, Y_tr) if factors.primal else Y_tr
        Ws = solve_lambda_grid(factors, rhs, cfg.lambdas,
                               X=None if factors.primal else X_tr,
                               use_pallas=cfg.use_pallas)
        preds = jnp.einsum("np,rpt->rnt", X_val, Ws,
                           preferred_element_type=jnp.float32)
        scores = jax.vmap(lambda Yp: _score(Y_val, Yp, cfg.scoring))(preds)
        per_lambda_scores.append(scores)
    cv_scores = jnp.mean(jnp.stack(per_lambda_scores), axis=0)    # (r,)
    best = jnp.argmax(cv_scores)
    lams = _lambda_grid(cfg)
    # Refit on the full data with the selected λ.
    factors = factorize(X, cfg)
    rhs = gram_xty(X, Y) if factors.primal else Y
    W = solve(factors, rhs, lams[best], X=None if factors.primal else X)
    return RidgeCVResult(weights=W, best_lambda=lams[best], best_index=best,
                         cv_scores=cv_scores)


def predict(X: jax.Array, W: jax.Array) -> jax.Array:
    return jnp.matmul(X, W, preferred_element_type=jnp.float32)
