"""SVD/eigh-mutualised multi-target RidgeCV (paper §2.3.1, §3).

This module is the single-shard building block of the paper's pipeline: the
scikit-learn-style ridge regression whose factorisation is computed *once*
and reused across all targets and all candidate regularisation strengths.

Two algebraically equivalent factorisation paths are provided:

* ``eigh`` (primal, used when ``n >= p``): eigendecompose the Gram matrix
  ``G = XᵀX = Q Λ Qᵀ``.  Then ``M(λ) Y = Q (Λ+λI)⁻¹ Qᵀ (XᵀY)``.  The
  eigenvalues of ``G`` are the squared singular values of ``X``, so the λ
  sweep is the same diagonal rescale as scikit-learn's SVD path (Eq. 5 of the
  paper) — but ``G`` and ``XᵀY`` are *sums over rows* of ``X``/``Y``, which is
  what makes the distributed (B-MOR) version a single ``psum`` (see
  ``repro.core.bmor``).
* ``dual`` (kernel, used when ``n < p``): eigendecompose ``K = XXᵀ = P Γ Pᵀ``;
  dual coefficients ``α(λ) = P (Γ+λI)⁻¹ Pᵀ Y`` and ``W = Xᵀ α``.

Both keep the per-λ work diagonal: ``O(p)`` (or ``O(n)``) scaling per λ, as
in the paper's complexity analysis ``T_M = O(p² n r + p r)``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Literal, Sequence

import jax
import jax.numpy as jnp

# The paper's λ grid (§2.2.4).
PAPER_LAMBDA_GRID: tuple[float, ...] = (
    0.1, 1.0, 100.0, 200.0, 300.0, 400.0, 600.0, 800.0, 900.0, 1000.0, 1200.0
)


@dataclasses.dataclass(frozen=True)
class RidgeCVConfig:
    """Configuration of the multi-target cross-validated ridge solve."""

    lambdas: tuple[float, ...] = PAPER_LAMBDA_GRID
    n_folds: int = 5
    method: Literal["auto", "eigh", "dual"] = "auto"
    # Small diagonal jitter added to the Gram matrix before eigh for numerical
    # stability in float32 (the paper runs float64 CPU BLAS; see DESIGN §2).
    jitter: float = 1e-6
    # Score used to select λ across folds: Pearson correlation ("r") matches
    # the paper's reported metric; "r2" is the classical ridge CV score.
    scoring: Literal["r", "r2"] = "r2"
    # Route the Gram accumulation and the multi-λ solve through the Pallas
    # TPU kernels (repro.kernels).  Off by default: on CPU the kernels run
    # in interpret mode (correct but slow); on TPU this is the "better BLAS"
    # lever of paper §4.3.
    use_pallas: bool = False

    def resolve_method(self, n: int, p: int) -> str:
        if self.method != "auto":
            return self.method
        return "eigh" if n >= p else "dual"


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RidgeFactors:
    """Reusable factorisation of the feature matrix.

    ``basis`` is ``Q`` (p×p, primal) or ``P`` (n×n, dual); ``evals`` are the
    eigenvalues of the corresponding Gram/kernel matrix, i.e. the squared
    singular values of ``X``.  ``M(λ)`` never needs to be materialised: the λ
    sweep only rescales coordinates in the eigenbasis.
    """

    basis: jax.Array        # (p,p) primal | (n,n) dual
    evals: jax.Array        # (p,) | (n,)
    primal: bool

    def tree_flatten(self):
        return (self.basis, self.evals), self.primal

    @classmethod
    def tree_unflatten(cls, aux, children):
        basis, evals = children
        return cls(basis=basis, evals=evals, primal=aux)


def gram(X: jax.Array) -> jax.Array:
    """``XᵀX`` with f32 accumulation (MXU-friendly)."""
    return jnp.matmul(X.T, X, preferred_element_type=jnp.float32)


def factorize(X: jax.Array, cfg: RidgeCVConfig) -> RidgeFactors:
    """Factorise ``X`` once; reused for every λ and every target (Eq. 4-5)."""
    n, p = X.shape
    method = cfg.resolve_method(n, p)
    if method == "eigh":
        if cfg.use_pallas:
            from repro.kernels import ops
            gram_fn = ops.gram
        else:
            gram_fn = gram
        G = gram_fn(X) + cfg.jitter * jnp.eye(p, dtype=jnp.float32)
        evals, Q = jnp.linalg.eigh(G)
        return RidgeFactors(basis=Q, evals=evals, primal=True)
    K = jnp.matmul(X, X.T, preferred_element_type=jnp.float32)
    K = K + cfg.jitter * jnp.eye(n, dtype=jnp.float32)
    evals, P = jnp.linalg.eigh(K)
    return RidgeFactors(basis=P, evals=evals, primal=False)


def solve(factors: RidgeFactors, XtY_or_Y: jax.Array, lam: jax.Array,
          X: jax.Array | None = None) -> jax.Array:
    """Apply ``M(λ)`` to the targets through the shared factorisation.

    Primal: pass ``XᵀY`` (p×t) → returns ``W = Q (Λ+λ)⁻¹ Qᵀ XᵀY`` (p×t).
    Dual:   pass ``Y`` (n×t) and ``X`` → ``W = Xᵀ P (Γ+λ)⁻¹ Pᵀ Y``.
    """
    B = factors.basis
    z = jnp.matmul(B.T, XtY_or_Y, preferred_element_type=jnp.float32)
    z = z / (factors.evals + lam)[:, None]
    out = jnp.matmul(B, z, preferred_element_type=jnp.float32)
    if factors.primal:
        return out
    assert X is not None, "dual solve needs X to map dual coeffs to weights"
    return jnp.matmul(X.T, out, preferred_element_type=jnp.float32)


def solve_lambda_grid(factors: RidgeFactors, XtY_or_Y: jax.Array,
                      lambdas: Sequence[float],
                      X: jax.Array | None = None,
                      use_pallas: bool = False) -> jax.Array:
    """All-λ solve, stacked on a leading axis: (r, p, t).

    The rotation into the eigenbasis (``Qᵀ XᵀY``) is shared across the grid —
    this is exactly the mutualisation of paper Eq. 5, where only the diagonal
    ``(S²+λI)⁻¹`` depends on λ.
    """
    if use_pallas and factors.primal:
        from repro.kernels import ops
        a = jnp.matmul(factors.basis.T, XtY_or_Y,
                       preferred_element_type=jnp.float32)
        return ops.solve_lambda_grid(factors.basis, factors.evals, a,
                                     jnp.asarray(lambdas, jnp.float32))
    B = factors.basis
    z = jnp.matmul(B.T, XtY_or_Y, preferred_element_type=jnp.float32)
    lams = jnp.asarray(lambdas, dtype=z.dtype)                    # (r,)
    zs = z[None, :, :] / (factors.evals[None, :, None] + lams[:, None, None])
    out = jnp.einsum("ij,rjt->rit", B, zs,
                     preferred_element_type=jnp.float32)
    if factors.primal:
        return out
    assert X is not None
    return jnp.einsum("ni,rnt->rit", X, out,
                      preferred_element_type=jnp.float32)


def _fold_bounds(n: int, n_folds: int) -> list[tuple[int, int]]:
    """Contiguous k-fold boundaries (static, trace-time)."""
    sizes = [n // n_folds + (1 if i < n % n_folds else 0) for i in range(n_folds)]
    bounds, start = [], 0
    for s in sizes:
        bounds.append((start, start + s))
        start += s
    return bounds


def _score(Y_true: jax.Array, Y_pred: jax.Array, kind: str) -> jax.Array:
    """Mean score across targets (higher is better)."""
    if kind == "r2":
        ss_res = jnp.sum((Y_true - Y_pred) ** 2, axis=0)
        ss_tot = jnp.sum((Y_true - jnp.mean(Y_true, axis=0)) ** 2, axis=0) + 1e-12
        return jnp.mean(1.0 - ss_res / ss_tot)
    yt = Y_true - jnp.mean(Y_true, axis=0)
    yp = Y_pred - jnp.mean(Y_pred, axis=0)
    num = jnp.sum(yt * yp, axis=0)
    den = jnp.sqrt(jnp.sum(yt ** 2, axis=0) * jnp.sum(yp ** 2, axis=0)) + 1e-12
    return jnp.mean(num / den)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RidgeCVResult:
    weights: jax.Array       # (p, t)
    best_lambda: jax.Array   # scalar
    best_index: jax.Array    # scalar int
    cv_scores: jax.Array     # (r,) mean validation score per λ


@partial(jax.jit, static_argnames=("cfg",))
def ridge_cv(X: jax.Array, Y: jax.Array, cfg: RidgeCVConfig = RidgeCVConfig()
             ) -> RidgeCVResult:
    """Cross-validated multi-target ridge — scikit-learn ``RidgeCV`` analog.

    Faithful to paper Algorithm 1 at batch granularity: for every CV split a
    fresh factorisation of ``X_train`` is computed (the ``svd(X_train)`` line),
    then the λ grid is swept diagonally, scores averaged over splits, a single
    λ selected for *all* targets (§2.2.4: "a single λ is used for all
    targets"), and the final weights refit on the full training set.
    """
    n, p = X.shape
    bounds = _fold_bounds(n, cfg.n_folds)
    per_lambda_scores = []
    for (lo, hi) in bounds:
        X_val, Y_val = X[lo:hi], Y[lo:hi]
        X_tr = jnp.concatenate([X[:lo], X[hi:]], axis=0)
        Y_tr = jnp.concatenate([Y[:lo], Y[hi:]], axis=0)
        factors = factorize(X_tr, cfg)
        rhs = gram_xty(X_tr, Y_tr) if factors.primal else Y_tr
        Ws = solve_lambda_grid(factors, rhs, cfg.lambdas,
                               X=None if factors.primal else X_tr,
                               use_pallas=cfg.use_pallas)
        preds = jnp.einsum("np,rpt->rnt", X_val, Ws,
                           preferred_element_type=jnp.float32)
        scores = jax.vmap(lambda Yp: _score(Y_val, Yp, cfg.scoring))(preds)
        per_lambda_scores.append(scores)
    cv_scores = jnp.mean(jnp.stack(per_lambda_scores), axis=0)    # (r,)
    best = jnp.argmax(cv_scores)
    # λ grid in f32 regardless of X.dtype: the whole solve accumulates in f32
    # (preferred_element_type), so bf16/f16 inputs must sweep — and select —
    # the identical grid, not a low-precision rounding of it.
    lams = jnp.asarray(cfg.lambdas, dtype=jnp.float32)
    # Refit on the full data with the selected λ.
    factors = factorize(X, cfg)
    rhs = gram_xty(X, Y) if factors.primal else Y
    W = solve(factors, rhs, lams[best], X=None if factors.primal else X)
    return RidgeCVResult(weights=W, best_lambda=lams[best], best_index=best,
                         cv_scores=cv_scores)


def gram_xty(X: jax.Array, Y: jax.Array) -> jax.Array:
    """``XᵀY`` with f32 accumulation."""
    return jnp.matmul(X.T, Y, preferred_element_type=jnp.float32)


def predict(X: jax.Array, W: jax.Array) -> jax.Array:
    return jnp.matmul(X, W, preferred_element_type=jnp.float32)
