"""Mixture-of-Experts FFN: top-k router + capacity-bounded one-hot dispatch.

TPU-idiomatic (GShard/Switch-style) dispatch: tokens are processed in groups
of ``group_size`` so the (tokens × experts × capacity) one-hot dispatch
einsums stay a small fraction of the expert FLOPs; experts are sharded over
the ``model``/``expert`` mesh axis (expert parallelism), so dispatch/combine
lower to all-to-all-like collectives on the production mesh.

Used by phi3.5-moe (16e top-2) and grok-1 (8e top-2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.params import ParamDef


def moe_defs(cfg: ModelConfig) -> dict:
    assert cfg.moe is not None
    d, dff, e, dt = cfg.d_model, cfg.d_ff, cfg.moe.n_experts, cfg.param_dtype
    return {
        "router": ParamDef((d, e), ("embed", None), dtype=jnp.float32),
        "wi": ParamDef((e, d, 2, dff), ("expert", "embed", None, "mlp"),
                       dtype=dt, fan_in=d),
        "wo": ParamDef((e, dff, d), ("expert", "mlp", "embed"), dtype=dt,
                       fan_in=dff),
    }


def _dispatch_one_group(p, cfg: ModelConfig, x: jax.Array
                        ) -> tuple[jax.Array, jax.Array]:
    """x: (G, d) → (out (G, d), aux loss scalar)."""
    m = cfg.moe
    G, d = x.shape
    E, K = m.n_experts, m.top_k
    C = max(1, int(G * K * m.capacity_factor / E))

    logits = jnp.einsum("gd,de->ge", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                      # (G, E)
    gate_vals, idx = jax.lax.top_k(probs, K)                     # (G, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # One-hot expert selection per (token, k) slot, flattened in priority
    # order: slot 0 of every token outranks slot 1 (standard top-k priority).
    sel = jax.nn.one_hot(idx, E, dtype=jnp.float32)              # (G, K, E)
    sel_flat = sel.transpose(1, 0, 2).reshape(K * G, E)          # (K·G, E)
    pos = jnp.cumsum(sel_flat, axis=0) - 1.0                     # position in expert
    keep = (pos < C).astype(jnp.float32) * sel_flat
    disp_flat = keep[..., None] * jax.nn.one_hot(pos, C, dtype=jnp.float32)
    dispatch = disp_flat.reshape(K, G, E, C).transpose(1, 0, 2, 3)  # (G,K,E,C)

    combine = jnp.einsum("gk,gkec->gec", gate_vals, dispatch)    # (G, E, C)
    disp = jnp.sum(dispatch, axis=1)                             # (G, E, C)

    xin = jnp.einsum("gec,gd->ecd", disp, x.astype(jnp.float32)
                     ).astype(x.dtype)                           # (E, C, d)
    h = jnp.einsum("ecd,edgf->ecgf", xin, p["wi"])               # (E,C,2,f)
    h = jax.nn.silu(h[..., 0, :]) * h[..., 1, :]
    eout = jnp.einsum("ecf,efd->ecd", h, p["wo"])                # (E, C, d)
    out = jnp.einsum("gec,ecd->gd", combine, eout.astype(jnp.float32))

    # Switch-style load-balance auxiliary loss.
    frac_tokens = jnp.mean(jnp.sum(sel, axis=1), axis=0)         # (E,)
    frac_probs = jnp.mean(probs, axis=0)                         # (E,)
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return out.astype(x.dtype), aux


def moe_apply(p, cfg: ModelConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) → (out, aux_loss).  Tokens regrouped to ``group_size``."""
    m = cfg.moe
    b, s, d = x.shape
    tokens = b * s
    g = min(m.group_size, tokens)
    assert tokens % g == 0, (tokens, g)
    xg = x.reshape(tokens // g, g, d)
    out, aux = jax.vmap(lambda xx: _dispatch_one_group(p, cfg, xx))(xg)
    return out.reshape(b, s, d), jnp.mean(aux)
