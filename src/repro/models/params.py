"""Parameter definition trees: one source of truth for shapes, dtypes,
logical sharding axes, and initialisers.

Every model builder produces a pytree of ``ParamDef`` leaves.  From that
single tree we derive:

* ``abstract(tree)``        → ShapeDtypeStruct tree (multi-pod dry-run, no
                              allocation);
* ``init(key, tree)``       → materialised parameters (smoke tests, examples);
* ``specs(tree, rules)``    → ``PartitionSpec`` tree for pjit in/out shardings.

Logical axis names (MaxText-style) are mapped to mesh axes by a rule table,
so switching the sharding strategy (e.g. Megatron-TP baseline vs FSDP for the
§Perf iterations) is a one-line rule change, not a model edit.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# Logical axes used by the model zoo.
#   embed   — d_model dimension
#   mlp     — FFN hidden dimension
#   heads   — attention query heads (sharded over tensor axis)
#   kv      — KV heads
#   vocab   — vocabulary dimension
#   expert  — MoE expert dimension
#   state   — SSM state dimension
#   layer   — stacked (scanned) layer dimension, never sharded
#   None    — replicated

# Rule tables: logical axis → mesh axis (or None).
RULES = {
    # Paper-faithful baseline: tensor parallel over "model", batch over
    # "data" (+"pod"); weights replicated over data.
    "tp": {
        "embed": None, "mlp": "model", "heads": "model", "kv": "model",
        "vocab": "model", "expert": "model", "state": None, "layer": None,
        "conv": None, "dt": None, "batch": None, "cache_seq": None,
    },
    # FSDP variant (§Perf): weight embed dim additionally sharded over data.
    "tp_fsdp": {
        "embed": "data", "mlp": "model", "heads": "model", "kv": "model",
        "vocab": "model", "expert": "model", "state": None, "layer": None,
        "conv": None, "dt": None, "batch": None, "cache_seq": None,
    },
    # Decode variant (§Perf): KV-cache sequence dim sharded over the model
    # axis — for archs whose KV head count leaves the tensor axis idle
    # (kv=8 on a 16-way axis), distributing the cache as a flash-decode.
    "tp_cacheseq": {
        "embed": None, "mlp": "model", "heads": "model", "kv": "model",
        "vocab": "model", "expert": "model", "state": None, "layer": None,
        "conv": None, "dt": None, "batch": None, "cache_seq": "model",
    },
}


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """A single parameter: shape + dtype + logical axes + initialiser."""
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    dtype: jnp.dtype = jnp.bfloat16
    init: str = "normal"          # "normal" | "zeros" | "ones" | "scaled"
    scale: float | None = None    # stddev override for "normal"/"scaled"
    fan_in: int | None = None     # explicit fan-in when the heuristic fails

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _leaves(tree):
    return jax.tree_util.tree_leaves(tree, is_leaf=is_def)


def abstract(tree) -> dict:
    """ShapeDtypeStruct tree — for .lower() without allocation."""
    return jax.tree_util.tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), tree, is_leaf=is_def)


def specs(tree, rules: dict[str, str | None] | str = "tp",
          axis_sizes: dict[str, int] | None = None) -> dict:
    """PartitionSpec tree from the logical-axis rule table.

    ``axis_sizes`` (mesh axis → size) enables divisibility checking: a
    logical axis whose dimension is not divisible by its mesh axis size is
    left replicated (e.g. 8 KV heads on a 16-way model axis, or a vocab that
    is not a multiple of 16).  This mirrors how production frameworks degrade
    when a config under-fills the tensor-parallel axis.
    """
    table = RULES[rules] if isinstance(rules, str) else rules

    def one(d: ParamDef) -> P:
        mesh_axes = []
        used: set = set()
        for dim, a in zip(d.shape, d.axes):
            m = table.get(a, None) if a else None
            flat = m if isinstance(m, tuple) else (m,)
            # A mesh axis may appear once per spec: first logical axis wins
            # (e.g. MoE weights (expert, embed, ·, mlp): "expert" takes the
            # model axis, so the per-expert mlp dim stays unsharded).
            if m is not None and any(f in used for f in flat):
                m = None
            # Divisibility: replicate when the dim does not divide evenly.
            if m is not None and axis_sizes is not None:
                sz = math.prod(axis_sizes.get(f, 1) for f in flat)
                if dim % sz != 0:
                    m = None
            if m is not None:
                used.update(f for f in flat if f)
            mesh_axes.append(m)
        return P(*mesh_axes)

    return jax.tree_util.tree_map(one, tree, is_leaf=is_def)


def init(key: jax.Array, tree, dtype_override: jnp.dtype | None = None):
    """Materialise parameters.  Deterministic per-leaf folding of the key."""
    defs = _leaves(tree)
    keys = jax.random.split(key, max(len(defs), 1))
    it = iter(range(len(defs)))

    def one(d: ParamDef):
        i = next(it)
        dt = dtype_override or d.dtype
        if d.init == "zeros":
            return jnp.zeros(d.shape, dt)
        if d.init == "ones":
            return jnp.ones(d.shape, dt)
        # Fan-in: explicit when given, else the product of all input dims —
        # every dim except the output (last) one and any stacked "layer" axis.
        if d.fan_in is not None:
            fan_in = d.fan_in
        else:
            in_dims = [s for s, a in zip(d.shape[:-1], d.axes[:-1])
                       if a != "layer"]
            fan_in = math.prod(in_dims) if in_dims else d.shape[-1]
        std = d.scale if d.scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
        return (std * jax.random.normal(keys[i], d.shape, jnp.float32)).astype(dt)

    return jax.tree_util.tree_map(one, tree, is_leaf=is_def)


def count_params(tree) -> int:
    return sum(math.prod(d.shape) for d in _leaves(tree))


def param_bytes(tree) -> int:
    return sum(math.prod(d.shape) * jnp.dtype(d.dtype).itemsize
               for d in _leaves(tree))


def stack_layers(n: int, layer_tree) -> dict:
    """Prefix every ParamDef with a scanned layer axis of size n."""
    def one(d: ParamDef) -> ParamDef:
        return ParamDef(shape=(n, *d.shape), axes=("layer", *d.axes),
                        dtype=d.dtype, init=d.init, scale=d.scale)
    return jax.tree_util.tree_map(one, layer_tree, is_leaf=is_def)
