"""Decoder-only LM assembly (dense / MoE / VLM families).

Layers are grouped by the config's repeating ``pattern`` and executed with
``lax.scan`` over pattern *repeats* — the traced program contains one copy of
each pattern position regardless of depth (compile-time O(1) in layers; see
DESIGN §6).  KV caches are stacked the same way: one (repeats, ...) array per
pattern position.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers, moe as moe_lib
from repro.models.scanning import scan_blocks
from repro.models.config import ModelConfig
from repro.models.params import ParamDef, abstract, init as init_params

Params = Any


def _attn_variant(cfg: ModelConfig, kind: str) -> layers.AttnVariant:
    return layers.AttnVariant(
        window=cfg.window if kind == "local_attn" else None,
        softcap=cfg.attn_logit_softcap, causal=True)


def _block_defs(cfg: ModelConfig, kind: str) -> dict:
    defs = {
        "norm1": layers.rmsnorm_defs(cfg.d_model),
        "attn": layers.attention_defs(cfg),
        "norm2": layers.rmsnorm_defs(cfg.d_model),
    }
    if cfg.use_post_norm:
        defs["post_norm1"] = layers.rmsnorm_defs(cfg.d_model)
        defs["post_norm2"] = layers.rmsnorm_defs(cfg.d_model)
    if cfg.moe is not None:
        defs["ffn"] = moe_lib.moe_defs(cfg)
    else:
        defs["ffn"] = layers.mlp_defs(cfg)
    return defs


def _block_train(p: Params, cfg: ModelConfig, kind: str, h: jax.Array,
                 positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    a = layers.attention(p["attn"], cfg, _attn_variant(cfg, kind),
                         layers.rmsnorm(p["norm1"], h, cfg.norm_eps),
                         positions)
    if cfg.use_post_norm:
        a = layers.rmsnorm(p["post_norm1"], a, cfg.norm_eps)
    h = h + a
    f_in = layers.rmsnorm(p["norm2"], h, cfg.norm_eps)
    if cfg.moe is not None:
        f, aux = moe_lib.moe_apply(p["ffn"], cfg, f_in)
    else:
        f, aux = layers.mlp(p["ffn"], cfg, f_in), jnp.float32(0.0)
    if cfg.use_post_norm:
        f = layers.rmsnorm(p["post_norm2"], f, cfg.norm_eps)
    return h + f, aux


def _block_decode(p: Params, cfg: ModelConfig, kind: str, h: jax.Array,
                  pos: jax.Array, cache: dict) -> tuple[jax.Array, dict]:
    a, new_cache = layers.attention_decode(
        p["attn"], cfg, _attn_variant(cfg, kind),
        layers.rmsnorm(p["norm1"], h, cfg.norm_eps), pos, cache)
    if cfg.use_post_norm:
        a = layers.rmsnorm(p["post_norm1"], a, cfg.norm_eps)
    h = h + a
    f_in = layers.rmsnorm(p["norm2"], h, cfg.norm_eps)
    if cfg.moe is not None:
        f, _ = moe_lib.moe_apply(p["ffn"], cfg, f_in)
    else:
        f = layers.mlp(p["ffn"], cfg, f_in)
    if cfg.use_post_norm:
        f = layers.rmsnorm(p["post_norm2"], f, cfg.norm_eps)
    return h + f, new_cache


def _cache_len(cfg: ModelConfig, kind: str, seq_len: int) -> int:
    if kind == "local_attn":
        return min(cfg.window, seq_len)
    return seq_len


@dataclasses.dataclass
class DecoderLM:
    """Uniform model interface (see launch/steps.py for the step functions)."""

    cfg: ModelConfig
    # Rematerialise each scanned layer group in the backward pass: without
    # this, scan saves every block's attention intermediates for the whole
    # depth (O(190 GB/device) at train_4k pod scale — measured in the first
    # dry-run iteration; see EXPERIMENTS §Perf).
    remat: bool = True
    # Unrolled layer loop — only for the dry-run cost probes (scanning.py).
    unroll: bool = False

    # -- parameter / cache definition trees --------------------------------
    def param_defs(self) -> dict:
        cfg = self.cfg
        blocks = {}
        for i, kind in enumerate(cfg.pattern):
            blk = _block_defs(cfg, kind)
            blocks[f"b{i}"] = jax.tree_util.tree_map(
                lambda d: ParamDef((cfg.n_repeats, *d.shape),
                                   ("layer", *d.axes), dtype=d.dtype,
                                   init=d.init, scale=d.scale),
                blk, is_leaf=lambda x: isinstance(x, ParamDef))
        defs = {
            "embed": layers.embed_defs(cfg),
            "blocks": blocks,
            "final_norm": layers.rmsnorm_defs(cfg.d_model),
        }
        if cfg.frontend == "vision_stub":
            # Projector from the (stub) vision tower to the LM width.
            defs["projector"] = {
                "w": ParamDef((cfg.d_model, cfg.d_model), ("embed", None),
                              dtype=cfg.param_dtype)}
        return defs

    def cache_defs(self, batch: int, seq_len: int) -> dict:
        cfg = self.cfg
        out = {}
        for i, kind in enumerate(cfg.pattern):
            c = layers.attn_cache_defs(cfg, batch, _cache_len(cfg, kind,
                                                              seq_len))
            out[f"b{i}"] = jax.tree_util.tree_map(
                lambda d: ParamDef((cfg.n_repeats, *d.shape),
                                   ("layer", *d.axes), dtype=d.dtype,
                                   init=d.init),
                c, is_leaf=lambda x: isinstance(x, ParamDef))
        return out

    def init(self, key: jax.Array):
        return init_params(key, self.param_defs())

    def init_cache(self, batch: int, seq_len: int):
        return init_params(jax.random.PRNGKey(0),
                           self.cache_defs(batch, seq_len))

    # -- forward ------------------------------------------------------------
    def _inputs_to_h(self, params: Params, batch: dict) -> jax.Array:
        cfg = self.cfg
        h = layers.embed(params["embed"], cfg, batch["tokens"])
        if cfg.frontend == "vision_stub" and "prefix_embeds" in batch:
            pe = jnp.einsum("bsd,de->bse",
                            batch["prefix_embeds"].astype(h.dtype),
                            params["projector"]["w"])
            h = jnp.concatenate([pe, h], axis=1)
        return h

    def hidden_states(self, params: Params, batch: dict) -> jax.Array:
        """Full-sequence forward → final hidden states (B, S, d).

        This is the brain-encoding feature hook (DESIGN §4): features X for
        the ridge head are these states, as VGG16 FC2 activations are in the
        paper.
        """
        cfg = self.cfg
        h = self._inputs_to_h(params, batch)
        b, s, _ = h.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

        def body(carry, layer_params):
            hh, aux = carry
            for i, kind in enumerate(cfg.pattern):
                hh, a = _block_train(layer_params[f"b{i}"], cfg, kind, hh,
                                     positions)
                aux = aux + a
            return (hh, aux), None

        if self.remat:
            body = jax.checkpoint(body)
        (h, aux), _ = scan_blocks(body, (h, jnp.float32(0.0)),
                                  params["blocks"], self.unroll)
        self._last_aux = aux / cfg.n_layers
        return layers.rmsnorm(params["final_norm"], h, cfg.norm_eps)

    def forward(self, params: Params, batch: dict
                ) -> tuple[jax.Array, jax.Array]:
        """→ (logits (B, S, V), moe aux loss)."""
        h = self.hidden_states(params, batch)
        logits = layers.unembed(params["embed"], self.cfg, h)
        return logits, self._last_aux

    def loss(self, params: Params, batch: dict) -> jax.Array:
        """Next-token cross-entropy over the token (non-prefix) region."""
        from repro.models import losses
        h = self.hidden_states(params, batch)
        tokens = batch["tokens"]
        n_prefix = h.shape[1] - tokens.shape[1]
        ce = losses.next_token_nll(params["embed"], self.cfg,
                                   h[:, n_prefix:, :], tokens)
        w = self.cfg.moe.router_aux_weight if self.cfg.moe else 0.0
        return ce + w * self._last_aux

    # -- decode ---------------------------------------------------------------
    def prefill(self, params: Params, batch: dict
                ) -> tuple[jax.Array, dict]:
        """Full-sequence forward returning last-position logits + KV cache."""
        cfg = self.cfg
        h = self._inputs_to_h(params, batch)
        b, s, _ = h.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

        def body(hh, layer_params):
            caches = {}
            for i, kind in enumerate(cfg.pattern):
                blk = layer_params[f"b{i}"]
                x_in = layers.rmsnorm(blk["norm1"], hh, cfg.norm_eps)
                q, k, v = layers._qkv(blk["attn"], cfg, x_in, positions)
                C = _cache_len(cfg, kind, s)
                k_c = jnp.roll(k[:, -C:], s % C, axis=1)
                v_c = jnp.roll(v[:, -C:], s % C, axis=1)
                caches[f"b{i}"] = {"k": k_c.astype(cfg.param_dtype),
                                   "v": v_c.astype(cfg.param_dtype)}
                hh, _ = _block_train(blk, cfg, kind, hh, positions)
            return hh, caches

        h, cache = scan_blocks(body, h, params["blocks"], self.unroll)
        h = layers.rmsnorm(params["final_norm"], h, cfg.norm_eps)
        logits = layers.unembed(params["embed"], cfg, h[:, -1:, :])
        return logits, cache

    def decode_step(self, params: Params, cache: dict, tokens: jax.Array,
                    pos: jax.Array) -> tuple[jax.Array, dict]:
        """tokens: (B, 1) current token; pos: scalar absolute position.

        The stacked KV cache travels in the scan CARRY and is updated with
        dynamic_update_slice per repeat — passing it as scan xs/ys instead
        double-buffers the whole cache (input + output stacks both live),
        which measured ~2× decode temp at pod scale (EXPERIMENTS §Perf).
        """
        cfg = self.cfg
        h = layers.embed(params["embed"], cfg, tokens)

        def body(carry, xs):
            hh, full_cache = carry
            layer_params, idx = xs
            for i, kind in enumerate(cfg.pattern):
                c_i = jax.tree_util.tree_map(
                    lambda a: jax.lax.dynamic_index_in_dim(a, idx, 0, False),
                    full_cache[f"b{i}"])
                hh, nc = _block_decode(layer_params[f"b{i}"], cfg, kind, hh,
                                       pos, c_i)
                full_cache[f"b{i}"] = jax.tree_util.tree_map(
                    lambda a, x: jax.lax.dynamic_update_slice_in_dim(
                        a, x[None].astype(a.dtype), idx, 0),
                    full_cache[f"b{i}"], nc)
            return (hh, full_cache), None

        idxs = jnp.arange(cfg.n_repeats, dtype=jnp.int32)
        (h, new_cache), _ = scan_blocks(body, (h, dict(cache)),
                                        (params["blocks"], idxs), self.unroll)
        h = layers.rmsnorm(params["final_norm"], h, cfg.norm_eps)
        logits = layers.unembed(params["embed"], cfg, h)
        return logits, new_cache
