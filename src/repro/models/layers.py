"""Shared transformer building blocks: norms, RoPE, GQA attention (train +
cached decode), gated MLPs, embeddings.

All blocks are pure functions over ``ParamDef``-described parameter trees
(``repro.models.params``).  Attention supports the variant axes required by
the assigned pool: grouped KV heads (all archs), qk-norm (qwen3), attention
logit softcapping (gemma2), sliding windows (gemma2/3, long_500k overrides),
and ring-buffer KV caches for windowed decode.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.params import ParamDef

Params = Any  # nested dict of arrays


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_defs(d: int) -> dict:
    return {"scale": ParamDef((d,), (None,), init="ones", dtype=jnp.float32)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * p["scale"]).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings (NeoX interleaving)
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions broadcastable to (..., seq)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.log(theta) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(angles)[..., None, :]                        # (..., seq, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def attention_defs(cfg: ModelConfig) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    dt = cfg.param_dtype
    defs = {
        "wq": ParamDef((d, h, hd), ("embed", "heads", None), dtype=dt,
                       fan_in=d),
        "wk": ParamDef((d, kv, hd), ("embed", "kv", None), dtype=dt, fan_in=d),
        "wv": ParamDef((d, kv, hd), ("embed", "kv", None), dtype=dt, fan_in=d),
        "wo": ParamDef((h, hd, d), ("heads", None, "embed"), dtype=dt),
    }
    if cfg.qk_norm:
        defs["q_norm"] = rmsnorm_defs(hd)
        defs["k_norm"] = rmsnorm_defs(hd)
    return defs


@dataclasses.dataclass
class AttnVariant:
    window: int | None = None            # None → global causal
    softcap: float | None = None
    causal: bool = True                  # False for encoder self-attn
    use_rope: bool = True                # False for cross-attention


def _qkv(p: Params, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
         use_rope: bool = True):
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dnk->bsnk", x, p["wk"])
    v = jnp.einsum("bsd,dnk->bsnk", x, p["wv"])
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q * (hd ** -0.5), k, v


def _gqa_scores(q: jax.Array, k: jax.Array, n_kv: int) -> jax.Array:
    """q: (B,S,H,K), k: (B,T,N,K) → (B,N,G,S,T) with H = N·G."""
    b, s, h, hd = q.shape
    g = h // n_kv
    qg = q.reshape(b, s, n_kv, g, hd)
    return jnp.einsum("bsngk,btnk->bngst", qg, k,
                      preferred_element_type=jnp.float32)


def _gqa_out(probs: jax.Array, v: jax.Array) -> jax.Array:
    """probs: (B,N,G,S,T), v: (B,T,N,K) → (B,S,H,K)."""
    b, n, g, s, t = probs.shape
    out = jnp.einsum("bngst,btnk->bsngk", probs.astype(v.dtype), v)
    return out.reshape(b, s, n * g, v.shape[-1])


def _blockwise_attention(cfg: ModelConfig, var: AttnVariant, q: jax.Array,
                         k: jax.Array, v: jax.Array, positions: jax.Array,
                         kv_pos: jax.Array) -> jax.Array:
    """Streaming (flash-style) attention: two-level block scan with a
    running-softmax carry — S×T scores never materialise (§Perf iter 4).

    For sliding-window attention the inner loop is *banded*: only the
    ``window//kb + 1`` KV blocks that can intersect the window are visited
    per Q block, so local-attention FLOPs scale with S·window, not S·T.
    q: (B,S,H,K) pre-scaled; k/v: (B,T,N,K).  → (B,S,H,K).
    """
    B, S, H, K = q.shape
    T, N = k.shape[1], cfg.n_kv_heads
    G = H // N
    bs = cfg.flash_block
    qb, kb = min(bs, S), min(bs, T)
    nq, nk = S // qb, T // kb
    neg = jnp.float32(-1e30)

    q_blocks = q.reshape(B, nq, qb, H, K).transpose(1, 0, 2, 3, 4)
    qpos_blocks = positions.reshape(B, nq, qb).transpose(1, 0, 2)
    k_all = k.reshape(B, nk, kb, N, K)
    v_all = v.reshape(B, nk, kb, N, K)
    kpos_all = kv_pos.reshape(B, nk, kb)

    banded = var.window is not None and var.causal
    n_inner = min(nk, var.window // kb + 2) if banded else nk

    def q_body(_, q_sl):
        q_blk, q_pos, q_idx = q_sl                   # (B,qb,H,K),(B,qb),()
        qg = q_blk.reshape(B, qb, N, G, K)

        def kv_body(carry, j):
            m, l, acc = carry
            raw = (q_idx - (n_inner - 1) + j) if banded else j
            blk = jnp.clip(raw, 0, nk - 1)
            # Out-of-range banded visits are clipped for safe indexing and
            # masked out below (revisiting block 0 must not double-count).
            visit_ok = (raw >= 0) & (raw <= nk - 1)
            k_blk = jax.lax.dynamic_index_in_dim(k_all, blk, 1, False)
            v_blk = jax.lax.dynamic_index_in_dim(v_all, blk, 1, False)
            k_pos = jax.lax.dynamic_index_in_dim(kpos_all, blk, 1, False)
            s = jnp.einsum("bqngk,btnk->bngqt", qg, k_blk,
                           preferred_element_type=jnp.float32)
            s = _softcap(s, var.softcap)
            dist = q_pos[:, None, None, :, None] - \
                k_pos[:, None, None, None, :]
            mask = jnp.broadcast_to(visit_ok, dist.shape)
            if var.causal:
                mask &= dist >= 0
            if var.window is not None:
                mask &= dist < var.window
            s = jnp.where(mask, s, neg)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            upd = jnp.einsum("bngqt,btnk->bngqk", p,
                             v_blk.astype(jnp.float32))
            acc_new = acc * corr[..., None] + upd
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, N, G, qb), neg)
        l0 = jnp.zeros((B, N, G, qb), jnp.float32)
        a0 = jnp.zeros((B, N, G, qb, K), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0),
                                      jnp.arange(n_inner))
        out = acc / jnp.maximum(l, 1e-30)[..., None]         # (B,N,G,qb,K)
        out = out.transpose(0, 3, 1, 2, 4).reshape(B, qb, H, K)
        return None, out.astype(q.dtype)

    _, out_blocks = jax.lax.scan(
        q_body, None,
        (q_blocks, qpos_blocks, jnp.arange(nq)))
    return out_blocks.transpose(1, 0, 2, 3, 4).reshape(B, S, H, K)


def attention(p: Params, cfg: ModelConfig, var: AttnVariant, x: jax.Array,
              positions: jax.Array, kv_x: jax.Array | None = None,
              kv_positions: jax.Array | None = None) -> jax.Array:
    """Full-sequence attention (training / prefill).

    ``kv_x`` enables cross-attention (keys/values from another sequence).
    Switches to the blockwise streaming path when the sequence exceeds
    ``cfg.flash_threshold`` (None → always dense-materialised scores).
    """
    if kv_x is None:
        q, k, v = _qkv(p, cfg, x, positions, use_rope=var.use_rope)
        kv_pos = positions
    else:
        hd = cfg.resolved_head_dim
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        if cfg.qk_norm:
            q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        if var.use_rope:
            q = rope(q, positions, cfg.rope_theta)
        q = q * (hd ** -0.5)
        k = jnp.einsum("bsd,dnk->bsnk", kv_x, p["wk"])
        v = jnp.einsum("bsd,dnk->bsnk", kv_x, p["wv"])
        if cfg.qk_norm:
            k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
        kv_pos = kv_positions if kv_positions is not None else \
            jnp.broadcast_to(jnp.arange(kv_x.shape[1], dtype=jnp.int32)[None],
                             kv_x.shape[:2])
        if var.use_rope:
            k = rope(k, kv_pos, cfg.rope_theta)

    if cfg.flash_threshold is not None and \
            x.shape[1] >= cfg.flash_threshold and \
            x.shape[1] % cfg.flash_block == 0 and \
            k.shape[1] % cfg.flash_block == 0:
        if cfg.flash_kernel:
            from repro.kernels import ops as kernel_ops
            out = kernel_ops.mha_flash(
                q, k, v, cfg.n_kv_heads, causal=var.causal,
                window=var.window, softcap=var.softcap,
                block_q=cfg.flash_block, block_k=cfg.flash_block)
        else:
            out = _blockwise_attention(cfg, var, q, k, v, positions, kv_pos)
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"])

    scores = _gqa_scores(q, k, cfg.n_kv_heads)       # (B,N,G,S,T)
    scores = _softcap(scores, var.softcap)
    dist = positions[:, None, None, :, None] - kv_pos[:, None, None, None, :]
    mask = jnp.ones_like(dist, dtype=bool)
    if var.causal:
        mask &= dist >= 0
    if var.window is not None:
        mask &= dist < var.window
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, v)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# -- cached decode -----------------------------------------------------------

def attn_cache_defs(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    dt = cfg.param_dtype
    return {
        "k": ParamDef((batch, cache_len, kv, hd),
                      ("batch", "cache_seq", "kv", None), dtype=dt,
                      init="zeros"),
        "v": ParamDef((batch, cache_len, kv, hd),
                      ("batch", "cache_seq", "kv", None), dtype=dt,
                      init="zeros"),
    }


def attention_decode(p: Params, cfg: ModelConfig, var: AttnVariant,
                     x: jax.Array, pos: jax.Array, cache: dict
                     ) -> tuple[jax.Array, dict]:
    """One-token decode against a (possibly ring) KV cache.

    x: (B, 1, d); pos: scalar int32 — current absolute position (shared by
    the batch, as in steady-state batched serving); cache["k"/"v"]:
    (B, C, N, K) where C = min(window, max_seq).  Keys are stored
    RoPE-rotated at their absolute write position, so ring wraparound keeps
    relative phases exact.
    """
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, dtype=jnp.int32)
    q, k_new, v_new = _qkv(p, cfg, x, positions)
    C = cache["k"].shape[1]
    slot = (pos % C).astype(jnp.int32)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, slot, 0, 0))
    scores = _gqa_scores(q, k, cfg.n_kv_heads)       # (B,N,G,1,C)
    scores = _softcap(scores, var.softcap)
    # Slot j holds absolute position pos - ((pos - j) mod C); valid iff ≥ 0.
    j = jnp.arange(C, dtype=jnp.int32)
    age = (pos - j) % C                              # distance to current token
    valid = age <= pos
    if var.window is not None:
        valid &= age < var.window
    scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, v)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, {"k": k, "v": v}


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_defs(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, dff, dt = cfg.d_model, d_ff or cfg.d_ff, cfg.param_dtype
    if cfg.mlp_act in ("swiglu", "geglu"):
        return {
            "wi": ParamDef((d, 2, dff), ("embed", None, "mlp"), dtype=dt,
                           fan_in=d),
            "wo": ParamDef((dff, d), ("mlp", "embed"), dtype=dt),
        }
    return {
        "wi": ParamDef((d, dff), ("embed", "mlp"), dtype=dt),
        "wo": ParamDef((dff, d), ("mlp", "embed"), dtype=dt),
    }


def mlp(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.mlp_act in ("swiglu", "geglu"):
        h = jnp.einsum("bsd,dcf->bscf", x, p["wi"])
        gate, up = h[..., 0, :], h[..., 1, :]
        act = jax.nn.silu(gate) if cfg.mlp_act == "swiglu" else \
            jax.nn.gelu(gate, approximate=True)
        h = act * up
    else:
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["wi"]),
                        approximate=True)
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])


# ---------------------------------------------------------------------------
# Embeddings / head
# ---------------------------------------------------------------------------

def embed_defs(cfg: ModelConfig) -> dict:
    # std 0.02: keeps tied-unembedding logits O(1) at init (GPT-2 convention).
    defs = {"tok": ParamDef((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                            dtype=cfg.param_dtype, scale=0.02)}
    if not cfg.tie_embeddings:
        defs["out"] = ParamDef((cfg.d_model, cfg.vocab), ("embed", "vocab"),
                               dtype=cfg.param_dtype)
    return defs


def embed(p: Params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    x = jnp.take(p["tok"], tokens, axis=0)
    if cfg.scale_embedding:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def unembed(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, p["tok"],
                            preferred_element_type=jnp.float32)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, p["out"],
                            preferred_element_type=jnp.float32)
    return _softcap(logits, cfg.final_logit_softcap)
