"""Memory-efficient next-token cross-entropy.

The naive CE (``log_softmax`` on float32 logits + gather) materialises a
(B, S, V) float32 tensor and a cross-vocab-shard gather — at train_4k scale
on a 256-chip pod that is tens of GB per device.  Here the label logit is
computed *directly from the hidden states* (one (B,S,d)·(B,S,d) contraction
against the gathered label embeddings), so only the bf16 logits for the
logsumexp reduction ever exist, sharded over the vocab axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig


def _chunked_lse(embed_params, cfg: ModelConfig, h_pred: jax.Array
                 ) -> jax.Array:
    """logsumexp over the vocab in ``ce_vocab_chunks`` checkpointed passes:
    only one chunk's f32 logits are ever live (§Perf pair C follow-up)."""
    E = embed_params["tok"] if cfg.tie_embeddings else embed_params["out"].T
    C = cfg.ce_vocab_chunks
    V = E.shape[0]
    assert V % C == 0, (V, C)
    Ec = E.reshape(C, V // C, E.shape[1])

    def body(carry, E_chunk):
        m, s = carry
        logits = jnp.einsum("bsd,vd->bsv", h_pred, E_chunk,
                            preferred_element_type=jnp.float32)
        if cfg.final_logit_softcap is not None:
            logits = jnp.tanh(logits / cfg.final_logit_softcap) * \
                cfg.final_logit_softcap
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        s = s * jnp.exp(m - m_new) + \
            jnp.sum(jnp.exp(logits - m_new[..., None]), axis=-1)
        return (m_new, s), None

    b, t, _ = h_pred.shape
    init = (jnp.full((b, t), -jnp.inf, jnp.float32),
            jnp.zeros((b, t), jnp.float32))
    (m, s), _ = jax.lax.scan(jax.checkpoint(body), init, Ec)
    return m + jnp.log(s)


def next_token_nll(embed_params, cfg: ModelConfig, h: jax.Array,
                   tokens: jax.Array) -> jax.Array:
    """h: (B, S, d) final hidden states aligned with ``tokens`` (B, S)."""
    h_pred = h[:, :-1, :]
    tgt = tokens[:, 1:]
    if cfg.ce_vocab_chunks > 1:
        lse = _chunked_lse(embed_params, cfg, h_pred)
    else:
        # Full (sharded, bf16) logits feed only the logsumexp reduction.
        logits = layers.unembed(embed_params, cfg, h_pred)   # (B,S-1,V)
        lse = jax.scipy.special.logsumexp(logits.astype(jnp.float32),
                                          axis=-1)
    # Label logit from the embedding rows — no (B,S,V) gather.
    if cfg.tie_embeddings:
        e = jnp.take(embed_params["tok"], tgt, axis=0)       # (B,S-1,d)
    else:
        e = jnp.take(embed_params["out"].T, tgt, axis=0)
    lbl = jnp.einsum("bsd,bsd->bs", h_pred.astype(jnp.float32),
                     e.astype(jnp.float32))
    if cfg.final_logit_softcap is not None:
        lbl = jnp.tanh(lbl / cfg.final_logit_softcap) * cfg.final_logit_softcap
    return jnp.mean(lse - lbl)
