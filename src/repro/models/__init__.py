"""Model zoo: family dispatch for the assigned architecture pool."""
from __future__ import annotations

from repro.models.config import (  # noqa: F401
    INPUT_SHAPES, InputShape, ModelConfig, MoEConfig, SSMConfig,
)


def build_model(cfg: ModelConfig):
    """Return the family-appropriate model object (uniform interface:
    param_defs/init/hidden_states/forward/loss/prefill/decode_step)."""
    from repro.models.encdec import EncDecLM
    from repro.models.hybrid import HybridLM
    from repro.models.transformer import DecoderLM

    if cfg.family in ("dense", "moe", "vlm"):
        return DecoderLM(cfg)
    if cfg.family in ("ssm", "hybrid"):
        return HybridLM(cfg)
    if cfg.family == "audio":
        return EncDecLM(cfg)
    raise ValueError(f"unknown family: {cfg.family}")
