"""Scan-or-unroll helper.

Production models ``lax.scan`` over layer repeats (compile time and HLO size
O(1) in depth).  XLA's ``cost_analysis`` counts a while-loop body ONCE
regardless of trip count (verified empirically), so the dry-run's roofline
probes lower tiny *unrolled* variants (1 and 2 repeats) and reconstruct
``total = outside + R·(f₂ − f₁)``.  ``unroll=True`` switches every layer
scan to a Python loop for those probes.
"""
from __future__ import annotations

import jax


def scan_blocks(body, init, xs, unroll: bool = False):
    """Drop-in for ``jax.lax.scan(body, init, xs)`` with an unrolled mode."""
    if not unroll:
        return jax.lax.scan(body, init, xs)
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    carry, ys = init, []
    for i in range(n):
        x_i = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        stacked = jax.tree_util.tree_map(
            lambda *leaves: jax.numpy.stack(leaves, axis=0), *ys)
    else:
        stacked = None
    return carry, stacked
