"""Encoder-decoder transformer (Seamless-M4T-style audio family).

Per the assignment carve-out, the modality frontend (mel-spectrogram + conv
feature extractor) is a STUB: ``input_specs`` supplies precomputed frame
embeddings ``src_embeds`` of shape (B, S_src, d_model); this module is the
transformer backbone that consumes them — bidirectional encoder + causal
decoder with cross-attention.

Shape policy for the decode benchmark shapes (DESIGN §4): ``seq_len`` is the
*decoder* context; the cross-attention source is a fixed 4096-frame stub.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.scanning import scan_blocks
from repro.models.config import ModelConfig
from repro.models.params import ParamDef, init as init_params

Params = Any

CROSS_LEN = 4096   # stub source frames for decode shapes


def _enc_block_defs(cfg: ModelConfig) -> dict:
    return {
        "norm1": layers.rmsnorm_defs(cfg.d_model),
        "attn": layers.attention_defs(cfg),
        "norm2": layers.rmsnorm_defs(cfg.d_model),
        "mlp": layers.mlp_defs(cfg),
    }


def _dec_block_defs(cfg: ModelConfig) -> dict:
    return {
        "norm1": layers.rmsnorm_defs(cfg.d_model),
        "self_attn": layers.attention_defs(cfg),
        "norm_x": layers.rmsnorm_defs(cfg.d_model),
        "cross_attn": layers.attention_defs(cfg),
        "norm2": layers.rmsnorm_defs(cfg.d_model),
        "mlp": layers.mlp_defs(cfg),
    }


_ENC_VAR = layers.AttnVariant(causal=False)
_CROSS_VAR = layers.AttnVariant(causal=False, use_rope=False)


def _self_variant(cfg: ModelConfig) -> layers.AttnVariant:
    window = cfg.window if "local_attn" in cfg.pattern else None
    return layers.AttnVariant(window=window, softcap=cfg.attn_logit_softcap)


@dataclasses.dataclass
class EncDecLM:
    cfg: ModelConfig
    remat: bool = True        # checkpoint each scanned layer (see DecoderLM)
    unroll: bool = False      # unrolled layer loop for dry-run cost probes

    def param_defs(self) -> dict:
        cfg = self.cfg
        stack_n = lambda n, tree: jax.tree_util.tree_map(  # noqa: E731
            lambda d: ParamDef((n, *d.shape), ("layer", *d.axes),
                               dtype=d.dtype, init=d.init, scale=d.scale),
            tree, is_leaf=lambda x: isinstance(x, ParamDef))
        return {
            "embed": layers.embed_defs(cfg),
            "encoder": stack_n(cfg.n_encoder_layers, _enc_block_defs(cfg)),
            "decoder": stack_n(cfg.n_layers, _dec_block_defs(cfg)),
            "enc_final_norm": layers.rmsnorm_defs(cfg.d_model),
            "final_norm": layers.rmsnorm_defs(cfg.d_model),
        }

    def cache_defs(self, batch: int, seq_len: int,
                   cross_len: int = CROSS_LEN) -> dict:
        cfg = self.cfg
        self_len = min(seq_len, cfg.window) if "local_attn" in cfg.pattern \
            else seq_len
        kv, hd, dt = cfg.n_kv_heads, cfg.resolved_head_dim, cfg.param_dtype
        stack = lambda tree: jax.tree_util.tree_map(  # noqa: E731
            lambda d: ParamDef((cfg.n_layers, *d.shape), ("layer", *d.axes),
                               dtype=d.dtype, init=d.init),
            tree, is_leaf=lambda x: isinstance(x, ParamDef))
        return {
            "self": stack(layers.attn_cache_defs(cfg, batch, self_len)),
            # Precomputed encoder K/V per decoder layer (static during decode).
            "cross_k": ParamDef((cfg.n_layers, batch, cross_len, kv, hd),
                                ("layer", "batch", "cache_seq", "kv", None),
                                dtype=dt, init="zeros"),
            "cross_v": ParamDef((cfg.n_layers, batch, cross_len, kv, hd),
                                ("layer", "batch", "cache_seq", "kv", None),
                                dtype=dt, init="zeros"),
        }

    def init(self, key):
        return init_params(key, self.param_defs())

    def init_cache(self, batch: int, seq_len: int, cross_len: int = CROSS_LEN):
        return init_params(jax.random.PRNGKey(0),
                           self.cache_defs(batch, seq_len, cross_len))

    # -- encoder ---------------------------------------------------------------
    def encode(self, params: Params, src_embeds: jax.Array) -> jax.Array:
        cfg = self.cfg
        h = src_embeds.astype(cfg.param_dtype)
        b, s, _ = h.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                     (b, s))

        def body(hh, p):
            a = layers.attention(p["attn"], cfg, _ENC_VAR,
                                 layers.rmsnorm(p["norm1"], hh, cfg.norm_eps),
                                 positions)
            hh = hh + a
            f = layers.mlp(p["mlp"], cfg,
                           layers.rmsnorm(p["norm2"], hh, cfg.norm_eps))
            return hh + f, None

        if self.remat:
            body = jax.checkpoint(body)
        h, _ = scan_blocks(body, h, params["encoder"], self.unroll)
        return layers.rmsnorm(params["enc_final_norm"], h, cfg.norm_eps)

    # -- decoder (teacher forcing) ----------------------------------------------
    def _decode_blocks_train(self, params, h, enc_out, positions):
        cfg = self.cfg

        def body(hh, p):
            a = layers.attention(p["self_attn"], cfg, _self_variant(cfg),
                                 layers.rmsnorm(p["norm1"], hh, cfg.norm_eps),
                                 positions)
            hh = hh + a
            x = layers.attention(p["cross_attn"], cfg, _CROSS_VAR,
                                 layers.rmsnorm(p["norm_x"], hh, cfg.norm_eps),
                                 positions, kv_x=enc_out)
            hh = hh + x
            f = layers.mlp(p["mlp"], cfg,
                           layers.rmsnorm(p["norm2"], hh, cfg.norm_eps))
            return hh + f, None

        if self.remat:
            body = jax.checkpoint(body)
        h, _ = scan_blocks(body, h, params["decoder"], self.unroll)
        return h

    def hidden_states(self, params: Params, batch: dict) -> jax.Array:
        """Decoder final hidden states (the encoding-feature hook)."""
        cfg = self.cfg
        enc_out = self.encode(params, batch["src_embeds"])
        h = layers.embed(params["embed"], cfg, batch["tokens"])
        b, s, _ = h.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                     (b, s))
        h = self._decode_blocks_train(params, h, enc_out, positions)
        self._last_aux = jnp.float32(0.0)
        return layers.rmsnorm(params["final_norm"], h, cfg.norm_eps)

    def forward(self, params, batch):
        h = self.hidden_states(params, batch)
        return layers.unembed(params["embed"], self.cfg, h), self._last_aux

    def loss(self, params, batch):
        from repro.models import losses
        h = self.hidden_states(params, batch)
        return losses.next_token_nll(params["embed"], self.cfg, h,
                                     batch["tokens"])

    # -- incremental decode -------------------------------------------------------
    def prefill(self, params: Params, batch: dict) -> tuple[jax.Array, dict]:
        """Encode the (long) source, cross-attend from a BOS token."""
        cfg = self.cfg
        enc_out = self.encode(params, batch["src_embeds"])
        cross_len = enc_out.shape[1]
        b = enc_out.shape[0]
        # Precompute per-layer cross K/V once (reused every decode step).
        def kv_body(_, p):
            k = jnp.einsum("bsd,dnk->bsnk", enc_out, p["cross_attn"]["wk"])
            v = jnp.einsum("bsd,dnk->bsnk", enc_out, p["cross_attn"]["wv"])
            return None, (k.astype(cfg.param_dtype), v.astype(cfg.param_dtype))
        _, (cross_k, cross_v) = scan_blocks(kv_body, None,
                                            params["decoder"], self.unroll)

        tokens = batch.get("tokens")
        if tokens is None:
            tokens = jnp.zeros((b, 1), jnp.int32)
        seq_len = batch.get("decode_len", tokens.shape[1])
        cache = self.init_cache(b, seq_len, cross_len)
        cache["cross_k"], cache["cross_v"] = cross_k, cross_v
        logits, cache = self.decode_step(params, cache, tokens[:, :1],
                                         jnp.int32(0))
        return logits, cache

    def decode_step(self, params: Params, cache: dict, tokens: jax.Array,
                    pos: jax.Array) -> tuple[jax.Array, dict]:
        cfg = self.cfg
        h = layers.embed(params["embed"], cfg, tokens)
        b = tokens.shape[0]
        positions = jnp.full((b, 1), pos, dtype=jnp.int32)

        def body(carry, xs):
            hh, self_stack = carry
            p, ck, cv, idx = xs
            self_cache = jax.tree_util.tree_map(
                lambda a: jax.lax.dynamic_index_in_dim(a, idx, 0, False),
                self_stack)
            a, nc = layers.attention_decode(
                p["self_attn"], cfg, _self_variant(cfg),
                layers.rmsnorm(p["norm1"], hh, cfg.norm_eps), pos, self_cache)
            hh = hh + a
            # Cross-attention against the static encoder K/V.
            x_in = layers.rmsnorm(p["norm_x"], hh, cfg.norm_eps)
            q = jnp.einsum("bsd,dhk->bshk", x_in, p["cross_attn"]["wq"])
            if cfg.qk_norm:
                q = layers.rmsnorm(p["cross_attn"]["q_norm"], q, cfg.norm_eps)
            q = q * (cfg.resolved_head_dim ** -0.5)
            scores = layers._gqa_scores(q, ck, cfg.n_kv_heads)
            probs = jax.nn.softmax(scores, axis=-1)
            out = layers._gqa_out(probs, cv)
            hh = hh + jnp.einsum("bshk,hkd->bsd", out, p["cross_attn"]["wo"])
            f = layers.mlp(p["mlp"], cfg,
                           layers.rmsnorm(p["norm2"], hh, cfg.norm_eps))
            self_stack = jax.tree_util.tree_map(
                lambda a, x: jax.lax.dynamic_update_slice_in_dim(
                    a, x[None].astype(a.dtype), idx, 0), self_stack, nc)
            return (hh + f, self_stack), None

        idxs = jnp.arange(cfg.n_layers, dtype=jnp.int32)
        (h, new_self), _ = scan_blocks(
            body, (h, dict(cache["self"])),
            (params["decoder"], cache["cross_k"], cache["cross_v"], idxs),
            self.unroll)
        h = layers.rmsnorm(params["final_norm"], h, cfg.norm_eps)
        logits = layers.unembed(params["embed"], cfg, h)
        new_cache = dict(cache)
        new_cache["self"] = new_self
        return logits, new_cache
