"""Mamba2 — state-space duality (SSD) blocks [arXiv:2405.21060].

Training/prefill uses the chunked SSD algorithm: within-chunk terms are
attention-like masked matmuls (MXU-friendly), across-chunk state is a short
``lax.scan`` over ``T/chunk`` steps carrying the (H, N, P) state — this is
the TPU adaptation of the paper's hardware mapping (the CUDA kernel's
block-parallel structure becomes chunk matmuls + a tiny sequential scan).

Decode is the classical single-step recurrence on the carried state:
``h ← exp(ΔA)·h + (ΔB)⊗x``, ``y = C·h + D·x`` — constant memory, which is
why SSM/hybrid archs run ``long_500k`` natively (DESIGN §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, SSMConfig
from repro.models.params import ParamDef


def _dims(cfg: ModelConfig) -> tuple[int, int, int, int, int]:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    heads = d_inner // s.head_dim
    return d_inner, heads, s.head_dim, s.n_groups, s.d_state


def mamba_defs(cfg: ModelConfig) -> dict:
    s: SSMConfig = cfg.ssm
    d, dt = cfg.d_model, cfg.param_dtype
    d_inner, H, Pd, G, N = _dims(cfg)
    conv_ch = d_inner + 2 * G * N          # conv over [x, B, C] channels
    return {
        "wz": ParamDef((d, H, Pd), ("embed", "heads", None), dtype=dt,
                       fan_in=d),
        "wx": ParamDef((d, H, Pd), ("embed", "heads", None), dtype=dt,
                       fan_in=d),
        "wB": ParamDef((d, G, N), ("embed", None, "state"), dtype=dt,
                       fan_in=d),
        "wC": ParamDef((d, G, N), ("embed", None, "state"), dtype=dt,
                       fan_in=d),
        "wdt": ParamDef((d, H), ("embed", "heads"), dtype=dt),
        "dt_bias": ParamDef((H,), ("heads",), dtype=jnp.float32, init="zeros"),
        "A_log": ParamDef((H,), ("heads",), dtype=jnp.float32, init="zeros"),
        "D": ParamDef((H,), ("heads",), dtype=jnp.float32, init="ones"),
        "conv_w": ParamDef((s.conv_kernel, conv_ch), (None, None), dtype=dt,
                           scale=0.5),
        "conv_b": ParamDef((conv_ch,), (None,), dtype=dt, init="zeros"),
        "norm": ParamDef((H, Pd), ("heads", None), dtype=jnp.float32,
                         init="ones"),
        "wo": ParamDef((H, Pd, d), ("heads", None, "embed"), dtype=dt),
    }


def ssm_cache_defs(cfg: ModelConfig, batch: int) -> dict:
    s = cfg.ssm
    d_inner, H, Pd, G, N = _dims(cfg)
    conv_ch = d_inner + 2 * G * N
    return {
        "state": ParamDef((batch, H, N, Pd), ("batch", "heads", None, None),
                          dtype=jnp.float32, init="zeros"),
        "conv": ParamDef((batch, s.conv_kernel - 1, conv_ch),
                         ("batch", None, None), dtype=cfg.param_dtype,
                         init="zeros"),
    }


def _proj_xbc(p, cfg: ModelConfig, u: jax.Array):
    """Project input to x/B/C channels (pre-conv) and z/dt."""
    d_inner, H, Pd, G, N = _dims(cfg)
    x = jnp.einsum("bsd,dhp->bshp", u, p["wx"]).reshape(*u.shape[:2], H * Pd)
    Bm = jnp.einsum("bsd,dgn->bsgn", u, p["wB"]).reshape(*u.shape[:2], G * N)
    Cm = jnp.einsum("bsd,dgn->bsgn", u, p["wC"]).reshape(*u.shape[:2], G * N)
    xbc = jnp.concatenate([x, Bm, Cm], axis=-1)      # (B, S, conv_ch)
    z = jnp.einsum("bsd,dhp->bshp", u, p["wz"])      # (B, S, H, P)
    dt = jnp.einsum("bsd,dh->bsh", u, p["wdt"])      # (B, S, H)
    return xbc, z, dt


def _split_xbc(cfg: ModelConfig, xbc: jax.Array):
    d_inner, H, Pd, G, N = _dims(cfg)
    b, s, _ = xbc.shape
    x = xbc[..., :d_inner].reshape(b, s, H, Pd)
    Bm = xbc[..., d_inner:d_inner + G * N].reshape(b, s, G, N)
    Cm = xbc[..., d_inner + G * N:].reshape(b, s, G, N)
    return x, Bm, Cm


def _causal_conv(p, xbc: jax.Array, kernel: int) -> jax.Array:
    """Depthwise causal conv over time.  xbc: (B, S, C)."""
    pad = jnp.pad(xbc, ((0, 0), (kernel - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * p["conv_w"][i][None, None, :]
              for i in range(kernel))
    return jax.nn.silu(out + p["conv_b"][None, None, :])


def _gated_norm(p, y: jax.Array, z: jax.Array, eps: float) -> jax.Array:
    """Mamba2 gated RMSNorm: norm(y · silu(z)) with per-(head, dim) scale."""
    g = (y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32)))
    var = jnp.mean(g * g, axis=-1, keepdims=True)
    return (g * jax.lax.rsqrt(var + eps) * p["norm"]).astype(y.dtype)


def mamba_apply(p, cfg: ModelConfig, u: jax.Array,
                return_cache: bool = False):
    """Full-sequence SSD (training / prefill).  u: (B, S, d) → (B, S, d).

    With ``return_cache`` also returns the decode cache {state, conv}: the
    final SSD state is the last carry of the inter-chunk scan (no sequential
    token replay needed — this is the parallel prefill path)."""
    s_cfg = cfg.ssm
    d_inner, H, Pd, G, N = _dims(cfg)
    B_, S, _ = u.shape
    Q = min(s_cfg.chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    xbc, z, dt = _proj_xbc(p, cfg, u)
    xbc_raw = xbc
    xbc = _causal_conv(p, xbc, s_cfg.conv_kernel)
    x, Bm, Cm = _split_xbc(cfg, xbc)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    A = -jnp.exp(p["A_log"])                                      # (H,) < 0

    hpg = H // G
    # Chunked views.
    xc = (x.astype(jnp.float32) * dt[..., None]).reshape(B_, nc, Q, H, Pd)
    Bc = Bm.astype(jnp.float32).reshape(B_, nc, Q, G, N)
    Cc = Cm.astype(jnp.float32).reshape(B_, nc, Q, G, N)
    la = (dt * A[None, None, :]).reshape(B_, nc, Q, H)            # log decay
    La = jnp.cumsum(la, axis=2)                                   # within-chunk

    # Within-chunk (attention-like) term with decay mask
    #   L[i,j] = exp(La_i − La_j) · 1[j ≤ i].
    if s_cfg.use_kernel and G == 1:
        # Fused Pallas path: decay·scores·x stays in VMEM (kernels/ssd.py).
        from repro.kernels import ops as kernel_ops
        cb = jnp.einsum("bcqgn,bckgn->bcqk", Cc, Bc)
        y_intra = kernel_ops.ssd_intra(
            cb.reshape(B_ * nc, Q, Q), La.reshape(B_ * nc, Q, H),
            xc.reshape(B_ * nc, Q, H, Pd)).reshape(B_, nc, Q, H, Pd)
    else:
        diff = La[:, :, :, None, :] - La[:, :, None, :, :]        # (B,nc,Q,Q,H)
        mask = jnp.tril(jnp.ones((Q, Q), bool))
        # Mask in log space before exp: diff > 0 above the diagonal would
        # overflow.
        decay = jnp.exp(jnp.where(mask[None, None, :, :, None], diff,
                                  -jnp.inf))
        scores = jnp.einsum("bcqgn,bckgn->bcqkg", Cc, Bc)         # (B,nc,Q,Q,G)
        scores = jnp.repeat(scores, hpg, axis=-1) * decay         # → heads
        y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", scores, xc)

    # Chunk-boundary states and the sequential inter-chunk scan.
    seg = jnp.exp(La[:, :, -1:, :] - La)                          # decay to end
    Bh = jnp.repeat(Bc, hpg, axis=-2)                             # (B,nc,Q,H,N)
    S_local = jnp.einsum("bcqh,bcqhn,bcqhp->bchnp", seg, Bh, xc)
    chunk_decay = jnp.exp(La[:, :, -1, :])                        # (B,nc,H)

    def scan_body(carry, inp):
        s_loc, dec = inp                    # (B,H,N,P), (B,H)
        new = carry * dec[..., None, None] + s_loc
        return new, carry                   # emit state *before* this chunk

    init = jnp.zeros((B_, H, N, Pd), jnp.float32)
    S_final, S_prev = jax.lax.scan(scan_body,
                                   init,
                                   (S_local.swapaxes(0, 1),
                                    chunk_decay.swapaxes(0, 1)))
    S_prev = S_prev.swapaxes(0, 1)                                # (B,nc,H,N,P)

    Ch = jnp.repeat(Cc, hpg, axis=-2)                             # (B,nc,Q,H,N)
    y_inter = jnp.einsum("bcqh,bcqhn,bchnp->bcqhp", jnp.exp(La), Ch, S_prev)

    y = (y_intra + y_inter).reshape(B_, S, H, Pd)
    y = y + p["D"][None, None, :, None] * x.astype(jnp.float32)
    y = _gated_norm(p, y, z, cfg.norm_eps)
    out = jnp.einsum("bshp,hpd->bsd", y.astype(u.dtype), p["wo"])
    if not return_cache:
        return out
    k = s_cfg.conv_kernel
    cache = {"state": S_final,
             "conv": xbc_raw[:, S - (k - 1):, :].astype(cfg.param_dtype)}
    return out, cache


def mamba_decode(p, cfg: ModelConfig, u: jax.Array, cache: dict
                 ) -> tuple[jax.Array, dict]:
    """Single-token recurrent step.  u: (B, 1, d)."""
    s_cfg = cfg.ssm
    d_inner, H, Pd, G, N = _dims(cfg)
    xbc, z, dt = _proj_xbc(p, cfg, u)                 # (B,1,·)
    hist = jnp.concatenate([cache["conv"], xbc.astype(cache["conv"].dtype)],
                           axis=1)                    # (B, K, C)
    conv_out = jnp.einsum("bkc,kc->bc", hist, p["conv_w"]) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out)[:, None, :]
    new_conv = hist[:, 1:, :]

    x, Bm, Cm = _split_xbc(cfg, conv_out)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]   # (B,H)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A[None, :])                                        # (B,H)
    hpg = H // G
    Bh = jnp.repeat(Bm[:, 0], hpg, axis=-2)           # (B,H,N)
    Ch = jnp.repeat(Cm[:, 0], hpg, axis=-2)
    xd = x[:, 0].astype(jnp.float32) * dt[..., None]  # (B,H,P)
    state = cache["state"] * a[..., None, None] + \
        jnp.einsum("bhn,bhp->bhnp", Bh.astype(jnp.float32), xd)
    y = jnp.einsum("bhn,bhnp->bhp", Ch.astype(jnp.float32), state)
    y = y + p["D"][None, :, None] * x[:, 0].astype(jnp.float32)
    y = _gated_norm(p, y[:, None], z, cfg.norm_eps)
    out = jnp.einsum("bshp,hpd->bsd", y.astype(u.dtype), p["wo"])
    return out, {"state": state, "conv": new_conv}
