"""Zamba2-style hybrid backbone [arXiv:2411.15242]: Mamba2 blocks with a
*shared* (weight-tied) attention+MLP block interleaved at a fixed cadence.

The repeating pattern is ``(mamba × k, shared_attn)``; the shared block's
parameters live once at the top level and are closed over inside the
``lax.scan`` body, so every application reuses the same weights (the defining
property of Zamba2) while each application keeps its *own* KV cache slice.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers, ssm
from repro.models.scanning import scan_blocks
from repro.models.config import ModelConfig
from repro.models.params import ParamDef, init as init_params

Params = Any


def _shared_variant(cfg: ModelConfig) -> layers.AttnVariant:
    return layers.AttnVariant(window=cfg.shared_attn_window,
                              softcap=cfg.attn_logit_softcap)


def _shared_block_defs(cfg: ModelConfig) -> dict:
    return {
        "norm1": layers.rmsnorm_defs(cfg.d_model),
        "attn": layers.attention_defs(cfg),
        "norm2": layers.rmsnorm_defs(cfg.d_model),
        "mlp": layers.mlp_defs(cfg),
    }


def _shared_block_train(p, cfg, h, positions):
    a = layers.attention(p["attn"], cfg, _shared_variant(cfg),
                         layers.rmsnorm(p["norm1"], h, cfg.norm_eps),
                         positions)
    h = h + a
    f = layers.mlp(p["mlp"], cfg, layers.rmsnorm(p["norm2"], h, cfg.norm_eps))
    return h + f


def _shared_block_decode(p, cfg, h, pos, cache):
    a, nc = layers.attention_decode(
        p["attn"], cfg, _shared_variant(cfg),
        layers.rmsnorm(p["norm1"], h, cfg.norm_eps), pos, cache)
    h = h + a
    f = layers.mlp(p["mlp"], cfg, layers.rmsnorm(p["norm2"], h, cfg.norm_eps))
    return h + f, nc


def _mamba_block_defs(cfg: ModelConfig) -> dict:
    return {"norm": layers.rmsnorm_defs(cfg.d_model), "mixer": ssm.mamba_defs(cfg)}


@dataclasses.dataclass
class HybridLM:
    cfg: ModelConfig
    remat: bool = True        # checkpoint each scanned repeat (see DecoderLM)
    unroll: bool = False      # unrolled layer loop for dry-run cost probes

    @property
    def _n_mamba_per_repeat(self) -> int:
        return sum(1 for k in self.cfg.pattern if k == "mamba")

    def param_defs(self) -> dict:
        cfg = self.cfg
        stack = lambda tree: jax.tree_util.tree_map(  # noqa: E731
            lambda d: ParamDef((cfg.n_repeats, *d.shape), ("layer", *d.axes),
                               dtype=d.dtype, init=d.init, scale=d.scale),
            tree, is_leaf=lambda x: isinstance(x, ParamDef))
        blocks = {f"b{i}": stack(_mamba_block_defs(cfg))
                  for i, kind in enumerate(cfg.pattern) if kind == "mamba"}
        defs = {
            "embed": layers.embed_defs(cfg),
            "blocks": blocks,
            "final_norm": layers.rmsnorm_defs(cfg.d_model),
        }
        if "shared_attn" in cfg.pattern:
            defs["shared"] = _shared_block_defs(cfg)  # single copy — tied
        return defs

    def cache_defs(self, batch: int, seq_len: int) -> dict:
        cfg = self.cfg
        stack = lambda tree: jax.tree_util.tree_map(  # noqa: E731
            lambda d: ParamDef((cfg.n_repeats, *d.shape), ("layer", *d.axes),
                               dtype=d.dtype, init=d.init),
            tree, is_leaf=lambda x: isinstance(x, ParamDef))
        out = {f"b{i}": stack(ssm.ssm_cache_defs(cfg, batch))
               for i, kind in enumerate(cfg.pattern) if kind == "mamba"}
        if "shared_attn" in cfg.pattern:
            shared_len = min(seq_len, cfg.shared_attn_window or seq_len)
            out["shared"] = stack(layers.attn_cache_defs(cfg, batch,
                                                         shared_len))
        return out

    def init(self, key):
        return init_params(key, self.param_defs())

    def init_cache(self, batch: int, seq_len: int):
        return init_params(jax.random.PRNGKey(0),
                           self.cache_defs(batch, seq_len))

    # -- forward --------------------------------------------------------------
    def hidden_states(self, params: Params, batch: dict) -> jax.Array:
        cfg = self.cfg
        h = layers.embed(params["embed"], cfg, batch["tokens"])
        b, s, _ = h.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                     (b, s))
        shared = params.get("shared")

        def body(hh, layer_params):
            for i, kind in enumerate(cfg.pattern):
                if kind == "mamba":
                    blk = layer_params[f"b{i}"]
                    hh = hh + ssm.mamba_apply(
                        blk["mixer"], cfg,
                        layers.rmsnorm(blk["norm"], hh, cfg.norm_eps))
                else:
                    hh = _shared_block_train(shared, cfg, hh, positions)
            return hh, None

        if self.remat:
            body = jax.checkpoint(body)
        h, _ = scan_blocks(body, h, params["blocks"], self.unroll)
        self._last_aux = jnp.float32(0.0)
        return layers.rmsnorm(params["final_norm"], h, cfg.norm_eps)

    def forward(self, params, batch):
        h = self.hidden_states(params, batch)
        return layers.unembed(params["embed"], self.cfg, h), self._last_aux

    def loss(self, params, batch):
        from repro.models import losses
        h = self.hidden_states(params, batch)
        return losses.next_token_nll(params["embed"], self.cfg, h,
                                     batch["tokens"])

    # -- decode -----------------------------------------------------------------
    def prefill(self, params: Params, batch: dict) -> tuple[jax.Array, dict]:
        """Parallel prefill: one chunked-SSD forward pass; the decode cache
        (SSM final states + conv tails + shared-attention KV) falls out of
        the same pass — no sequential token replay."""
        cfg = self.cfg
        h = layers.embed(params["embed"], cfg, batch["tokens"])
        b, s, _ = h.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                     (b, s))
        shared = params.get("shared")
        shared_len = min(s, cfg.shared_attn_window or s)

        def body(hh, layer_params):
            caches = {}
            for i, kind in enumerate(cfg.pattern):
                if kind == "mamba":
                    blk = layer_params[f"b{i}"]
                    y, nc = ssm.mamba_apply(
                        blk["mixer"], cfg,
                        layers.rmsnorm(blk["norm"], hh, cfg.norm_eps),
                        return_cache=True)
                    hh = hh + y
                    caches[f"b{i}"] = nc
                else:
                    x_in = layers.rmsnorm(shared["norm1"], hh, cfg.norm_eps)
                    q, k, v = layers._qkv(shared["attn"], cfg, x_in,
                                          positions)
                    k_c = jnp.roll(k[:, -shared_len:], s % shared_len, axis=1)
                    v_c = jnp.roll(v[:, -shared_len:], s % shared_len, axis=1)
                    caches["shared"] = {"k": k_c.astype(cfg.param_dtype),
                                        "v": v_c.astype(cfg.param_dtype)}
                    hh = _shared_block_train(shared, cfg, hh, positions)
            return hh, caches

        h, cache = scan_blocks(body, h, params["blocks"], self.unroll)
        h = layers.rmsnorm(params["final_norm"], h, cfg.norm_eps)
        logits = layers.unembed(params["embed"], cfg, h[:, -1:, :])
        return logits, cache

    def decode_step(self, params: Params, cache: dict, tokens: jax.Array,
                    pos: jax.Array) -> tuple[jax.Array, dict]:
        """Cache travels in the scan carry (in-place update per repeat) —
        see DecoderLM.decode_step for the double-buffering rationale."""
        cfg = self.cfg
        h = layers.embed(params["embed"], cfg, tokens)
        shared = params.get("shared")

        def body(carry, xs):
            hh, full_cache = carry
            layer_params, idx = xs

            def take(tree):
                return jax.tree_util.tree_map(
                    lambda a: jax.lax.dynamic_index_in_dim(a, idx, 0, False),
                    tree)

            def put(tree, new):
                return jax.tree_util.tree_map(
                    lambda a, x: jax.lax.dynamic_update_slice_in_dim(
                        a, x[None].astype(a.dtype), idx, 0), tree, new)

            for i, kind in enumerate(cfg.pattern):
                if kind == "mamba":
                    blk = layer_params[f"b{i}"]
                    y, nc = ssm.mamba_decode(
                        blk["mixer"], cfg,
                        layers.rmsnorm(blk["norm"], hh, cfg.norm_eps),
                        take(full_cache[f"b{i}"]))
                    hh = hh + y
                    full_cache[f"b{i}"] = put(full_cache[f"b{i}"], nc)
                else:
                    hh, nc = _shared_block_decode(shared, cfg, hh, pos,
                                                  take(full_cache["shared"]))
                    full_cache["shared"] = put(full_cache["shared"], nc)
            return (hh, full_cache), None

        idxs = jnp.arange(cfg.n_repeats, dtype=jnp.int32)
        (h, new_cache), _ = scan_blocks(body, (h, dict(cache)),
                                        (params["blocks"], idxs), self.unroll)
        h = layers.rmsnorm(params["final_norm"], h, cfg.norm_eps)
        return layers.unembed(params["embed"], cfg, h), new_cache
